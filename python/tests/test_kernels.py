"""L1 correctness: Pallas kernels (interpret mode) vs the pure-jnp
oracles in ref.py — the core numeric signal, swept with hypothesis."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.batch_stats import batch_stats
from compile.kernels.iterate import iterate
from compile.kernels.ref import batch_stats_ref, iterate_ref, stream_agg_ref
from compile.kernels.stream_agg import stream_agg

finite = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32)


def test_stream_agg_matches_ref_basic():
    keys = jnp.array([0.0, 1.0, 2.0, 0.0], dtype=jnp.float32)
    vals = jnp.array([1.0, 2.0, 3.0, 4.0], dtype=jnp.float32)
    got = stream_agg(keys, vals, 3)
    np.testing.assert_allclose(got, [5.0, 2.0, 3.0], rtol=1e-6)
    np.testing.assert_allclose(got, stream_agg_ref(keys, vals, 3), rtol=1e-6)


def test_stream_agg_padding_invariance():
    # Padded slots (val 0) must not perturb the sums regardless of key.
    keys = jnp.array([1.0, 1.0, 0.0, 0.0], dtype=jnp.float32)
    vals = jnp.array([2.0, 3.0, 0.0, 0.0], dtype=jnp.float32)
    got = stream_agg(keys, vals, 2)
    np.testing.assert_allclose(got, [0.0, 5.0], rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    data=st.lists(st.tuples(st.integers(0, 7), finite), min_size=1, max_size=64),
    num_keys=st.integers(1, 8),
)
def test_stream_agg_matches_ref_hypothesis(data, num_keys):
    keys = jnp.array([k % num_keys for k, _ in data], dtype=jnp.float32)
    vals = jnp.array([v for _, v in data], dtype=jnp.float32)
    got = stream_agg(keys, vals, num_keys)
    want = stream_agg_ref(keys, vals, num_keys)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_iterate_matches_ref_basic():
    r = jnp.array([1.0, 0.0, 0.0, 0.0], dtype=jnp.float32)
    got = iterate(r)
    np.testing.assert_allclose(got, iterate_ref(r), rtol=1e-6)


def test_iterate_preserves_uniform_fixpoint():
    # A uniform vector is a fixed point of the damped ring propagation.
    r = jnp.full((8,), 0.125, dtype=jnp.float32)
    got = iterate(r)
    np.testing.assert_allclose(got, r, rtol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    vals=st.lists(finite, min_size=2, max_size=128),
    damping=st.floats(min_value=0.0, max_value=1.0),
)
def test_iterate_matches_ref_hypothesis(vals, damping):
    r = jnp.array(vals, dtype=jnp.float32)
    got = iterate(r, damping)
    want = iterate_ref(r, damping)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(vals=st.lists(finite, min_size=1, max_size=256))
def test_batch_stats_matches_ref_hypothesis(vals):
    v = jnp.array(vals, dtype=jnp.float32)
    got = batch_stats(v)
    want = batch_stats_ref(v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n", [1, 2, 8, 16, 128, 1024])
def test_stream_agg_shape_sweep(n):
    keys = jnp.zeros((n,), dtype=jnp.float32)
    vals = jnp.ones((n,), dtype=jnp.float32)
    got = stream_agg(keys, vals, 4)
    assert got.shape == (4,)
    np.testing.assert_allclose(got[0], float(n), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_iterate_dtype_sweep(dtype):
    # (x64 is disabled in this jax build; bf16 is the TPU-relevant dtype.)
    r = jnp.arange(8, dtype=dtype)
    got = iterate(r)
    want = iterate_ref(r)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
    )
    assert got.dtype == dtype
