"""Randomized model validation of the parallel recovery pipeline
(rust/src/ft/recovery.rs, `apply_plan_parallel` +
`FtSystem::recover_parallel`).

The container cannot execute the Rust test-suite, so this file keeps the
desk-check honest from the other side: a tiny executable model of the
decomposed rollback/replay protocol is driven over thousands of random
rollback plans x worker counts, and the structural properties the Rust
suite asserts (test_sharded_recovery.rs byte-equality grid) are
asserted on the model:

  1. *exactly-once restore partitioning*: the per-group ownership map
     (`group_of[p]`, the same assignment a parallel drain uses) covers
     every rolled-back processor exactly once — no proc is restored by
     two workers, none is skipped;
  2. *disjoint key ranges*: the durable keys a group touches during
     restore are exactly the `Key{proc,..}` ranges of its owned procs,
     so the per-group key sets are pairwise disjoint — the
     no-shared-state argument from ft/README.md;
  3. *per-edge replay order equivalence*: under every random thread
     interleaving of the per-group phase-3 production loops (local
     sends direct to channels, cross-group sends through per-group FIFO
     mailboxes drained after a barrier), each edge receives exactly the
     batch sequence the sequential replay produces — every edge has a
     single sending worker, and both engines walk that worker's procs
     and logs in the same ascending order;
  4. *parallelism gauge*: the number of groups that restore >= 1 proc
     equals the number of distinct groups among rolled-back procs
     (`RollbackPlan::rollback_groups`) — the value
     `FtStats.recovery_parallelism` records.

Stdlib only: run directly
(``python3 python/tests/test_parallel_recovery_invariants.py``) or
under pytest.
"""

import random

N_PLANS = 2000

TOP = "top"  # untouched: keeps its state, receives no replay
MID = "mid"  # rolled back to a checkpoint: restored + replayed into
BOT = "bot"  # reset to empty: restored; its own log is truncated away


def random_case(rng):
    """A random topology + rollback plan + per-proc replay log.

    Every edge has exactly one source proc (as in the engine, where an
    EdgeId is owned by a single upstream processor), which is the load-
    bearing fact behind per-edge order preservation.
    """
    n = rng.randint(2, 10)
    threads = rng.choice([2, 3, 4, 8])
    # The engine's shard_groups maps shard s of every logical vertex to
    # group s % T; on the model's flat proc list, proc index stands in
    # for the shard index.
    group_of = [p % threads for p in range(n)]
    edges = []  # edge index -> (src, dst)
    for src in range(n):
        for _ in range(rng.randint(0, 3)):
            dst = rng.randrange(n)
            if dst != src:
                edges.append((src, dst))
    plan = [rng.choice([TOP, MID, BOT]) for _ in range(n)]
    if all(f == TOP for f in plan):
        plan[rng.randrange(n)] = MID  # recover() asserts >= 1 failure
    # Per-proc log: ordered (edge, batch) entries over the proc's
    # out-edges. Batch ids are globally unique so order comparisons are
    # unambiguous.
    logs = [[] for _ in range(n)]
    batch_id = 0
    for p in range(n):
        out = [ei for ei, (s, _) in enumerate(edges) if s == p]
        for _ in range(rng.randint(0, 6)):
            if not out:
                break
            logs[p].append((rng.choice(out), batch_id))
            batch_id += 1
    # "Destination already holds this batch's effect" — a pure function
    # of the batch, so sequential and parallel replay agree on it
    # (mirrors f_dst.contains(batch.time)).
    covered = {b for p in range(n) for (_, b) in logs[p] if rng.random() < 0.25}
    return n, threads, group_of, edges, plan, logs, covered


def replay_filter(edges, plan, covered, p, entry):
    """The phase-3 filters, shared verbatim by both models."""
    ei, b = entry
    if plan[p] == BOT:
        return False  # log truncated to nothing
    _, dst = edges[ei]
    if plan[dst] == TOP:
        return False  # destination kept its queue
    if b in covered:
        return False  # destination retained this effect
    return True


def sequential_replay(n, edges, plan, logs, covered):
    """recovery.rs apply_plan phase 3: procs ascending, log order."""
    per_edge = {ei: [] for ei in range(len(edges))}
    for p in range(n):
        for entry in logs[p]:
            if replay_filter(edges, plan, covered, p, entry):
                per_edge[entry[0]].append(entry[1])
    return per_edge


def parallel_replay(n, threads, group_of, edges, plan, logs, covered, rng):
    """apply_plan_parallel phase 3 under a random thread interleaving.

    Each group walks its own procs ascending and its logs in order
    (that per-group program order is fixed); the *interleaving across
    groups* is adversarially random. Local sends append straight to the
    edge queue; cross-group sends ride a per-destination-group FIFO
    mailbox that the owner drains after the barrier.
    """
    per_edge = {ei: [] for ei in range(len(edges))}
    mailboxes = [[] for _ in range(threads)]
    # Per-group production streams, in group program order.
    streams = []
    for g in range(threads):
        stream = []
        for p in range(n):
            if group_of[p] != g:
                continue
            for entry in logs[p]:
                if replay_filter(edges, plan, covered, p, entry):
                    stream.append(entry)
        streams.append(stream)
    # Random interleaving: repeatedly pick a group with work left and
    # let it issue its next send.
    cursors = [0] * threads
    live = [g for g in range(threads) if streams[g]]
    while live:
        g = rng.choice(live)
        ei, b = streams[g][cursors[g]]
        cursors[g] += 1
        dst_group = group_of[edges[ei][1]]
        if dst_group == g:
            per_edge[ei].append(b)  # push_batch_replay on a local channel
        else:
            mailboxes[dst_group].append((ei, b))  # MailHub::send
        live = [g for g in range(threads) if cursors[g] < len(streams[g])]
    # Barrier, then every group drains its own mailbox FIFO.
    for g in range(threads):
        for ei, b in mailboxes[g]:
            per_edge[ei].append(b)  # WorkerState::accept_replay
    return per_edge


def check_one(seed):
    rng = random.Random(seed)
    n, threads, group_of, edges, plan, logs, covered = random_case(rng)
    rolled = {p for p in range(n) if plan[p] != TOP}

    # 1. Exactly-once restore partitioning.
    restored_by = {}
    for g in range(threads):
        for p in range(n):
            if group_of[p] == g and plan[p] != TOP:
                assert p not in restored_by, (
                    f"seed {seed}: proc {p} restored by groups "
                    f"{restored_by[p]} and {g}"
                )
                restored_by[p] = g
    assert set(restored_by) == rolled, (
        f"seed {seed}: restore partition covered {sorted(restored_by)} "
        f"but the plan rolls back {sorted(rolled)}"
    )

    # 2. Disjoint durable key ranges: a group's restore touches only
    # Key{proc,..} for procs it owns.
    key_ranges = [
        {p for p in range(n) if group_of[p] == g and plan[p] != TOP}
        for g in range(threads)
    ]
    for a in range(threads):
        for b in range(a + 1, threads):
            overlap = key_ranges[a] & key_ranges[b]
            assert not overlap, (
                f"seed {seed}: groups {a} and {b} both scan proc keys "
                f"{sorted(overlap)}"
            )

    # 3. Per-edge replay order equivalence, over several adversarial
    # interleavings of the same plan.
    want = sequential_replay(n, edges, plan, logs, covered)
    for trial in range(4):
        got = parallel_replay(
            n, threads, group_of, edges, plan, logs, covered,
            random.Random(seed * 31 + trial),
        )
        for ei in range(len(edges)):
            assert got[ei] == want[ei], (
                f"seed {seed} trial {trial}: edge {ei} replay order "
                f"{got[ei]} != sequential {want[ei]}"
            )

    # 4. The parallelism gauge equals the distinct rolled-back groups.
    groups_restoring = len({g for p, g in restored_by.items()})
    rollback_groups = len({group_of[p] for p in rolled})
    assert groups_restoring == rollback_groups, (
        f"seed {seed}: {groups_restoring} groups restored but the plan "
        f"spans {rollback_groups} groups"
    )


def test_parallel_recovery_invariants():
    for seed in range(N_PLANS):
        check_one(seed)


if __name__ == "__main__":
    test_parallel_recovery_invariants()
    print(f"ok: {N_PLANS} random rollback plans x worker counts")
