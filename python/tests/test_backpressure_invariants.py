"""Randomized model validation of the credit-based backpressure scheduler
(rust/src/engine/scheduler.rs, `mailbox_cap`).

The container cannot execute the Rust test-suite, so this file keeps the
desk-check honest from the other side: a tiny executable model of the
gated round-robin delivery loop is driven over thousands of random
layered dataflows, and the properties the Rust suite asserts
(test_parallel.rs, test_zero_copy.rs neighborhood) are asserted on the
model:

  1. *no deadlock*: every bounded run reaches quiescence — gating defers
     deliveries, it never denies them;
  2. *equivalence*: the per-edge delivered record multiset is identical
     with and without a mailbox budget — gating reorders cross-edge
     interleaving at fan-in, which is exactly the order the engine's
     canonical (order-quotiented) output comparison mods out, and on
     fan-in-free edges even the order is preserved;
  3. *bounded residency*: every interior queue (not fed directly by the
     ungated external-ingestion path, which mirrors Engine::push_input)
     peaks at <= cap + batch_cap - 1 records — a delivery is admitted
     only while the destination's out-queues are below the cap, and one
     delivery emits at most batch_cap records per out-edge;
  4. *pass-2 is a safety net*: on acyclic dataflows the ungated second
     pass never actually fires (some edge toward a sink is always
     deliverable), confirming that the deadlock-avoidance rule is a
     backstop, not the steady state.

Stdlib only: run directly
(``python3 python/tests/test_backpressure_invariants.py``) or under
pytest.
"""

import random

N_TOPOLOGIES = 400
EPOCHS = 3
MAX_STEPS = 200_000


def random_topology(rng):
    """Layered DAG: proc 0 is the source, last layer procs are sinks.

    Returns (num_procs, edges) with edges as (src, dst) tuples; edge
    index order is creation order, mirroring GraphBuilder.
    """
    layers = [[0]]
    next_id = 1
    for _ in range(rng.randint(1, 3)):
        width = rng.randint(1, 3)
        layers.append(list(range(next_id, next_id + width)))
        next_id += width
    edges = []
    for up, down in zip(layers, layers[1:]):
        for u in up:
            # Every proc feeds at least one downstream proc; some fan out.
            targets = rng.sample(down, rng.randint(1, len(down)))
            for d in targets:
                edges.append((u, d))
        for d in down:
            # Every downstream proc is fed by someone.
            if not any(dst == d for (_, dst) in edges):
                edges.append((rng.choice(up), d))
    return next_id, edges


class Model:
    """Gated round-robin delivery over per-edge FIFO record queues."""

    def __init__(self, num_procs, edges, batch_cap, mailbox_cap):
        self.edges = edges
        self.batch_cap = batch_cap
        self.mailbox_cap = mailbox_cap  # None = unbounded
        self.queues = [[] for _ in edges]
        self.out_edges = [[] for _ in range(num_procs)]
        for ei, (src, _dst) in enumerate(edges):
            self.out_edges[src].append(ei)
        self.delivered = [[] for _ in edges]  # per-edge delivery order
        self.peak = [0] * len(edges)
        self.cursor = 0
        self.forced_passes = 0

    def push_external(self, records):
        """Engine::push_input is never gated: the whole epoch lands on
        the source's out-edges before any drain."""
        for r in records:
            for ei in self.out_edges[0]:
                self.queues[ei].append(r)
                self.peak[ei] = max(self.peak[ei], len(self.queues[ei]))

    def gated(self, ei):
        if self.mailbox_cap is None:
            return False
        dst = self.edges[ei][1]
        return any(
            len(self.queues[oe]) >= self.mailbox_cap for oe in self.out_edges[dst]
        )

    def deliver(self, ei):
        batch = self.queues[ei][: self.batch_cap]
        del self.queues[ei][: self.batch_cap]
        self.delivered[ei].extend(batch)
        # The operator forwards every record to all out-edges.
        dst = self.edges[ei][1]
        for oe in self.out_edges[dst]:
            self.queues[oe].extend(batch)
            self.peak[oe] = max(self.peak[oe], len(self.queues[oe]))

    def step(self):
        """One scheduler step: two-pass round-robin (scheduler.rs
        step() phase 1). Returns False at message quiescence."""
        ne = len(self.edges)
        parked = False
        for off in range(ne):
            ei = (self.cursor + off) % ne
            if not self.queues[ei]:
                continue
            if self.gated(ei):
                parked = True
                continue
            self.deliver(ei)
            self.cursor = (ei + 1) % ne
            return True
        if parked:
            # Pass 2: credit can defer work, never deny it.
            self.forced_passes += 1
            for off in range(ne):
                ei = (self.cursor + off) % ne
                if self.queues[ei]:
                    self.deliver(ei)
                    self.cursor = (ei + 1) % ne
                    return True
        return False

    def run(self, epochs, records_per_epoch, rng):
        for ep in range(epochs):
            self.push_external(
                [(ep, i, rng.randint(0, 9)) for i in range(records_per_epoch)]
            )
            steps = 0
            while self.step():
                steps += 1
                assert steps < MAX_STEPS, "no quiescence: credit deadlock"
        assert all(not q for q in self.queues), "quiescence left records queued"
        return self.delivered


def check_one(seed):
    rng = random.Random(seed)
    num_procs, edges = random_topology(rng)
    batch_cap = rng.choice((1, 2, 8))
    records = rng.randint(4, 40)
    source_out = set()
    for ei, (src, _dst) in enumerate(edges):
        if src == 0:
            source_out.add(ei)

    # An edge whose entire upstream path is fan-in free delivers in a
    # deterministic order regardless of scheduling; fan-in edges are
    # compared as multisets (the canonical order-quotient, as in
    # bench_support::sharded::canonical_output).
    in_degree = [0] * num_procs
    for (_src, dst) in edges:
        in_degree[dst] += 1

    def order_free(ei):
        src, _dst = edges[ei]
        if src == 0:
            return False
        if in_degree[src] > 1:
            return True
        return any(order_free(up) for up, (_s, d) in enumerate(edges) if d == src)

    base = Model(num_procs, edges, batch_cap, None).run(
        EPOCHS, records, random.Random(seed + 1)
    )
    for cap in (1, 2, 64):
        m = Model(num_procs, edges, batch_cap, cap)
        got = m.run(EPOCHS, records, random.Random(seed + 1))
        for ei in range(len(edges)):
            if order_free(ei):
                assert sorted(got[ei]) == sorted(base[ei]), (
                    f"seed {seed}: edge {ei} multiset diverged under "
                    f"mailbox_cap={cap}"
                )
            else:
                assert got[ei] == base[ei], (
                    f"seed {seed}: fan-in-free edge {ei} order diverged "
                    f"under mailbox_cap={cap}"
                )
        for ei in range(len(edges)):
            if ei in source_out:
                continue  # external ingestion is ungated, as in the engine
            bound = cap + batch_cap - 1
            assert m.peak[ei] <= bound, (
                f"seed {seed}: interior edge {ei} peaked at {m.peak[ei]} "
                f"> {bound} (cap={cap}, batch_cap={batch_cap})"
            )
        assert m.forced_passes == 0, (
            f"seed {seed}: acyclic dataflow needed {m.forced_passes} "
            "ungated passes — pass 2 should be a cycle-only backstop"
        )


def test_backpressure_invariants():
    for seed in range(N_TOPOLOGIES):
        check_one(seed)


if __name__ == "__main__":
    test_backpressure_invariants()
    print(f"ok: {N_TOPOLOGIES} random dataflows x mailbox_cap in (1, 2, 64)")
