"""Schema checker for falkirk's machine-readable observability exports.

Three formats, all hand-rolled on the Rust side (rust/src/metrics/json.rs
has no serde), so this file is the executable contract that keeps them
honest from the consumer's side:

  1. ``falkirk-trace/1`` JSON lines (``FALKIRK_TRACE_JSON=file``,
     rust/src/trace/mod.rs) — one header object, then one event object
     per line. A file appended across runs (the fuzzer flushes one
     sorted batch per system generation, each with a fresh clock
     origin) contains several monotone *segments*; timestamps may step
     backwards only at a segment boundary.
  2. ``falkirk-metrics/1`` / ``falkirk-store/1`` single-document
     summaries (``--metrics-json``, ``store inspect --json``,
     rust/src/coordinator/cli.rs).
  3. Chrome ``trace_event`` JSON Array Format (``falkirk trace
     convert``, rust/src/trace/convert.rs).

Beyond well-formedness, every complete recovery timeline found in a
trace is structurally validated: the ``solver``, ``rollback``, and
``replay`` phases must nest inside the enclosing ``recovery`` span,
replay must begin at or after rollback ends, per-processor
``rollback_proc`` instants must sit inside the rollback span and agree
with the span's ``procs_rolled_back`` counter, and a ``detect`` instant
must precede the span in the same segment.

Usage (CI smoke, after generating the files with the CLI)::

    python3 python/tests/test_trace_schema.py \
        --trace trace.jsonl --expect-recovery trace.jsonl \
        --monotone trace.jsonl --metrics metrics.json \
        --chrome trace.chrome.json

With no arguments, runs the embedded self-test on synthetic documents.
Stdlib only; also runnable under pytest.
"""

import json
import sys

TRACE_SCHEMA = "falkirk-trace/1"
DOC_SCHEMAS = ("falkirk-metrics/1", "falkirk-store/1")
U64_MAX = 2**64 - 1
RECOVERY_PHASES = ("solver", "rollback", "replay")


class SchemaError(Exception):
    """A document violated the schema contract."""


def _err(path, msg):
    raise SchemaError("%s: %s" % (path, msg))


def _is_u64(v):
    return isinstance(v, int) and not isinstance(v, bool) and 0 <= v <= U64_MAX


def _parse_line(path, lineno, line):
    try:
        obj = json.loads(line)
    except ValueError as e:
        _err(path, "line %d: not JSON (%s)" % (lineno, e))
    if not isinstance(obj, dict):
        _err(path, "line %d: not a JSON object" % lineno)
    return obj


def _check_event(path, lineno, ev):
    for key in ("ts_ns", "dur_ns", "tid", "cat", "name"):
        if key not in ev:
            _err(path, "line %d: event missing '%s'" % (lineno, key))
    for key in ("ts_ns", "dur_ns", "tid"):
        if not _is_u64(ev[key]):
            _err(path, "line %d: '%s' is not a u64" % (lineno, key))
    if ev["ts_ns"] + ev["dur_ns"] > U64_MAX:
        _err(path, "line %d: span end overflows u64" % lineno)
    for key in ("cat", "name"):
        if not isinstance(ev[key], str) or not ev[key]:
            _err(path, "line %d: '%s' is not a non-empty string" % (lineno, key))
    args = ev.get("args", {})
    if not isinstance(args, dict):
        _err(path, "line %d: 'args' is not an object" % lineno)
    for k, v in args.items():
        if not isinstance(k, str) or not _is_u64(v):
            _err(path, "line %d: arg %r is not a str -> u64 pair" % (lineno, k))


def load_trace(path, text):
    """Parse a falkirk-trace/1 file into monotone segments of events.

    Returns a list of segments; each segment is a list of event dicts
    whose ``ts_ns`` are non-decreasing. A new segment starts at every
    header line and at every backwards timestamp step (one flushed,
    sorted batch per segment).
    """
    lines = [l for l in text.splitlines() if l.strip()]
    if not lines:
        _err(path, "empty trace file")
    segments = []
    seg = None
    for lineno, line in enumerate(lines, 1):
        obj = _parse_line(path, lineno, line)
        if "schema" in obj:
            if obj["schema"] != TRACE_SCHEMA:
                _err(path, "line %d: schema %r, want %r"
                     % (lineno, obj["schema"], TRACE_SCHEMA))
            seg = None
            continue
        if lineno == 1:
            _err(path, "first line is not a %s header" % TRACE_SCHEMA)
        _check_event(path, lineno, obj)
        if seg is None or obj["ts_ns"] < seg[-1]["ts_ns"]:
            seg = []
            segments.append(seg)
        seg.append(obj)
    return segments


def _end_ns(ev):
    return ev["ts_ns"] + ev["dur_ns"]


def _contains(parent, child):
    return parent["ts_ns"] <= child["ts_ns"] and _end_ns(child) <= _end_ns(parent)


def check_recovery_timelines(path, segments):
    """Validate every recovery timeline; return the enclosing spans."""
    spans = []
    for seg in segments:
        rec = [e for e in seg if e["cat"] == "recovery"]
        detects = [e for e in rec if e["name"] == "detect"]
        for span in rec:
            if span["name"] != "recovery" or span["dur_ns"] == 0:
                continue
            where = "recovery span at ts=%d" % span["ts_ns"]
            inner = [e for e in rec if e is not span and _contains(span, e)]
            phases = {}
            for name in RECOVERY_PHASES:
                found = [e for e in inner if e["name"] == name]
                if len(found) != 1:
                    _err(path, "%s: %d '%s' phases, want exactly 1"
                         % (where, len(found), name))
                phases[name] = found[0]
            if _end_ns(phases["rollback"]) > phases["replay"]["ts_ns"]:
                _err(path, "%s: replay begins before rollback ends" % where)
            per_proc = [e for e in inner if e["name"] == "rollback_proc"]
            for e in per_proc:
                if not _contains(phases["rollback"], e):
                    _err(path, "%s: rollback_proc instant outside the "
                               "rollback span" % where)
            claimed = span.get("args", {}).get("procs_rolled_back")
            if claimed != len(per_proc):
                _err(path, "%s: span claims procs_rolled_back=%r but %d "
                           "rollback_proc instants" % (where, claimed, len(per_proc)))
            if not any(d["ts_ns"] <= span["ts_ns"] for d in detects):
                _err(path, "%s: no detect instant precedes it" % where)
            spans.append(span)
    return spans


def check_trace(path, text):
    """Full trace-file check; returns (segments, recovery spans)."""
    segments = load_trace(path, text)
    return segments, check_recovery_timelines(path, segments)


def _check_histogram(path, h, field):
    if not isinstance(h, dict):
        _err(path, "'%s' is not an object" % field)
    for key in ("count", "p50_ns", "p99_ns", "max_ns"):
        if not _is_u64(h.get(key)):
            _err(path, "'%s.%s' is not a u64" % (field, key))
    if not isinstance(h.get("mean_ns"), (int, float)) or isinstance(h.get("mean_ns"), bool):
        _err(path, "'%s.mean_ns' is not a number" % field)
    if h["count"] > 0 and not h["p50_ns"] <= h["p99_ns"] <= h["max_ns"]:
        _err(path, "'%s' percentiles are not ordered" % field)


def check_metrics(path, text):
    """Validate a falkirk-metrics/1 or falkirk-store/1 document."""
    try:
        doc = json.loads(text)
    except ValueError as e:
        _err(path, "not JSON (%s)" % e)
    if not isinstance(doc, dict):
        _err(path, "not a JSON object")
    schema = doc.get("schema")
    if schema not in DOC_SCHEMAS:
        _err(path, "schema %r, want one of %r" % (schema, DOC_SCHEMAS))

    if schema == "falkirk-store/1":
        if not isinstance(doc.get("backend"), dict):
            _err(path, "'backend' is not an object")
        for field in ("kinds", "snapshot_chains"):
            if not isinstance(doc.get(field), list):
                _err(path, "'%s' is not an array" % field)
        return doc

    if not isinstance(doc.get("command"), str) or not doc["command"]:
        _err(path, "'command' is not a non-empty string")
    if "epoch_wall" in doc:
        _check_histogram(path, doc["epoch_wall"], "epoch_wall")
    if "counters" in doc:
        if not isinstance(doc["counters"], dict):
            _err(path, "'counters' is not an object")
        for k, v in doc["counters"].items():
            if not _is_u64(v):
                _err(path, "counter %r is not a u64" % k)
    if "recovery" in doc:
        rec = doc["recovery"]
        if not isinstance(rec, dict):
            _err(path, "'recovery' is not an object")
        if not isinstance(rec.get("victim"), str):
            _err(path, "'recovery.victim' is not a string")
        for key in ("replayed", "restored_from_checkpoint", "reset_to_empty",
                    "untouched"):
            if not _is_u64(rec.get(key)):
                _err(path, "'recovery.%s' is not a u64" % key)
    if "verdicts" in doc:
        if not isinstance(doc["verdicts"], list):
            _err(path, "'verdicts' is not an array")
        for i, v in enumerate(doc["verdicts"]):
            if not isinstance(v, dict) or not isinstance(v.get("pass"), bool) \
                    or not _is_u64(v.get("seed")):
                _err(path, "verdict %d is malformed" % i)
    return doc


def check_chrome(path, text):
    """Validate a Chrome trace_event JSON Array Format document."""
    try:
        doc = json.loads(text)
    except ValueError as e:
        _err(path, "not JSON (%s)" % e)
    evs = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(evs, list):
        _err(path, "'traceEvents' is not an array")
    for i, ev in enumerate(evs):
        where = "traceEvents[%d]" % i
        if not isinstance(ev, dict):
            _err(path, "%s is not an object" % where)
        for key in ("name", "cat"):
            if not isinstance(ev.get(key), str):
                _err(path, "%s.%s is not a string" % (where, key))
        for key in ("pid", "tid"):
            if not _is_u64(ev.get(key)):
                _err(path, "%s.%s is not a u64" % (where, key))
        if not isinstance(ev.get("ts"), (int, float)):
            _err(path, "%s.ts is not a number" % where)
        ph = ev.get("ph")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                _err(path, "%s: complete event without a valid dur" % where)
        elif ph == "i":
            if ev.get("s") != "t":
                _err(path, "%s: instant without thread scope" % where)
        else:
            _err(path, "%s.ph is %r, want 'X' or 'i'" % (where, ph))
    return len(evs)


# ---------------------------------------------------------------------------
# Embedded self-test (runs when invoked with no file arguments).

def _header():
    return json.dumps({"schema": TRACE_SCHEMA, "clock": "mono_ns"})


def _ev(ts, dur, cat, name, tid=0, args=None):
    return json.dumps({"ts_ns": ts, "dur_ns": dur, "tid": tid, "cat": cat,
                       "name": name, "args": args or {}})


def _good_trace():
    lines = [
        _header(),
        _ev(5, 0, "engine", "deliver", tid=1, args={"proc": 3, "records": 8}),
        _ev(10, 0, "recovery", "detect", args={"procs": 1}),
        _ev(20, 100, "recovery", "recovery",
            args={"replayed": 4, "procs_rolled_back": 1,
                  "replayed_total": 4, "rolled_back_total": 1}),
        _ev(20, 10, "recovery", "solver", args={"procs": 7}),
        _ev(35, 30, "recovery", "rollback", args={"procs": 1}),
        _ev(40, 0, "recovery", "rollback_proc", args={"proc": 3}),
        _ev(70, 40, "recovery", "replay", args={"records": 4}),
        # Second flushed batch: clock origin resets (new segment).
        _ev(2, 0, "ft", "checkpoint", args={"proc": 1, "bytes": 64}),
    ]
    return "\n".join(lines) + "\n"


def _expect_error(fn, what):
    try:
        fn()
    except SchemaError:
        return
    raise AssertionError("accepted %s" % what)


def self_test():
    segs, spans = check_trace("good", _good_trace())
    assert len(segs) == 2, segs
    assert len(spans) == 1
    assert [e["name"] for e in segs[0]] == \
        ["deliver", "detect", "recovery", "solver", "rollback",
         "rollback_proc", "replay"]

    _expect_error(lambda: check_trace("t", _ev(0, 0, "a", "b") + "\n"),
                  "a trace without a header")
    _expect_error(lambda: check_trace(
        "t", _header() + "\n" + '{"ts_ns": -1, "dur_ns": 0, "tid": 0, '
        '"cat": "a", "name": "b", "args": {}}\n'), "a negative timestamp")
    # Replay starting inside the rollback span is a malformed timeline.
    bad = "\n".join([
        _header(),
        _ev(0, 0, "recovery", "detect", args={"procs": 1}),
        _ev(10, 100, "recovery", "recovery", args={"procs_rolled_back": 0}),
        _ev(10, 5, "recovery", "solver"),
        _ev(20, 40, "recovery", "rollback"),
        _ev(30, 20, "recovery", "replay"),
    ]) + "\n"
    _expect_error(lambda: check_trace("t", bad), "replay inside rollback")
    # procs_rolled_back must equal the rollback_proc instant count.
    bad = "\n".join([
        _header(),
        _ev(0, 0, "recovery", "detect", args={"procs": 1}),
        _ev(10, 100, "recovery", "recovery", args={"procs_rolled_back": 2}),
        _ev(10, 5, "recovery", "solver"),
        _ev(20, 10, "recovery", "rollback"),
        _ev(25, 0, "recovery", "rollback_proc", args={"proc": 0}),
        _ev(40, 10, "recovery", "replay"),
    ]) + "\n"
    _expect_error(lambda: check_trace("t", bad), "a per-proc count mismatch")

    good_metrics = json.dumps({
        "schema": "falkirk-metrics/1", "command": "fig1", "seed": 7,
        "epoch_wall": {"count": 4, "mean_ns": 10.5, "p50_ns": 9,
                       "p99_ns": 20, "max_ns": 21},
        "counters": {"responses": 96, "storage_errors": 0},
        "recovery": {"victim": "rank_store", "replayed": 3,
                     "restored_from_checkpoint": 1, "reset_to_empty": 0,
                     "untouched": 6},
    })
    check_metrics("m", good_metrics)
    check_metrics("m", json.dumps({
        "schema": "falkirk-metrics/1", "command": "fuzz", "seed": 7,
        "verdicts": [{"seed": 7, "pass": True, "digest": "00ff",
                      "recoveries": 2, "violations": 0}],
    }))
    check_metrics("m", json.dumps({
        "schema": "falkirk-store/1", "dir": "/tmp/s",
        "backend": {"name": "wal", "segments": 1},
        "kinds": [], "snapshot_chains": [],
    }))
    _expect_error(lambda: check_metrics("m", json.dumps({"schema": "nope"})),
                  "an unknown schema")
    _expect_error(lambda: check_metrics("m", json.dumps({
        "schema": "falkirk-metrics/1", "command": "fig1",
        "epoch_wall": {"count": 1, "mean_ns": 1, "p50_ns": 9, "p99_ns": 5,
                       "max_ns": 9}})), "unordered percentiles")
    _expect_error(lambda: check_metrics("m", json.dumps({
        "schema": "falkirk-metrics/1", "command": "fig1",
        "counters": {"x": -1}})), "a negative counter")

    good_chrome = json.dumps({"traceEvents": [
        {"name": "recovery", "cat": "recovery", "pid": 1, "tid": 0,
         "ts": 0.02, "ph": "X", "dur": 0.1, "args": {}},
        {"name": "detect", "cat": "recovery", "pid": 1, "tid": 0,
         "ts": 0.01, "ph": "i", "s": "t", "args": {}},
    ], "displayTimeUnit": "ns"})
    assert check_chrome("c", good_chrome) == 2
    _expect_error(lambda: check_chrome("c", json.dumps({"traceEvents": [
        {"name": "x", "cat": "c", "pid": 1, "tid": 0, "ts": 0, "ph": "B"},
    ]})), "an unsupported phase")

    print("test_trace_schema: self-test OK "
          "(trace segmentation, timeline nesting, metrics, chrome)")


# Pytest entry points.
def test_self():
    self_test()


def _read(path):
    with open(path, "r") as f:
        return f.read()


def main(argv):
    if len(argv) <= 1:
        self_test()
        return 0
    i, checked = 1, 0
    traces = {}
    while i < len(argv):
        flag = argv[i]
        if flag not in ("--trace", "--metrics", "--chrome", "--monotone",
                        "--expect-recovery"):
            sys.stderr.write("unknown argument %r\n" % flag)
            return 2
        if i + 1 >= len(argv):
            sys.stderr.write("%s needs a file argument\n" % flag)
            return 2
        path = argv[i + 1]
        i += 2
        try:
            if flag == "--trace":
                segs, spans = check_trace(path, _read(path))
                traces[path] = (segs, spans)
                n = sum(len(s) for s in segs)
                print("%s: %d events in %d segment(s), %d recovery "
                      "timeline(s)" % (path, n, len(segs), len(spans)))
            elif flag == "--monotone":
                segs, _ = traces.get(path) or check_trace(path, _read(path))
                if len(segs) > 1:
                    _err(path, "expected a single monotone segment, "
                               "found %d" % len(segs))
            elif flag == "--expect-recovery":
                _, spans = traces.get(path) or check_trace(path, _read(path))
                if not spans:
                    _err(path, "expected at least one complete recovery "
                               "timeline, found none")
            elif flag == "--metrics":
                doc = check_metrics(path, _read(path))
                print("%s: valid %s document" % (path, doc["schema"]))
            else:
                n = check_chrome(path, _read(path))
                print("%s: valid chrome trace (%d events)" % (path, n))
            checked += 1
        except SchemaError as e:
            sys.stderr.write("FAIL %s\n" % e)
            return 1
        except OSError as e:
            sys.stderr.write("FAIL %s: %s\n" % (path, e))
            return 1
    print("test_trace_schema: %d check(s) passed" % checked)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
