"""L2/AOT checks: model shapes, lowering to HLO text, determinism, and
numeric agreement of the lowered modules with ref.py (the exact compute
the Rust runtime will execute)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels.ref import batch_stats_ref, iterate_ref, stream_agg_ref
from compile.model import analytics_step, batch_stats_step, iterative_step


def test_model_shapes():
    keys = jnp.zeros((aot.WINDOW,), jnp.float32)
    vals = jnp.ones((aot.WINDOW,), jnp.float32)
    (sums,) = analytics_step(keys, vals, aot.NUM_KEYS)
    assert sums.shape == (aot.NUM_KEYS,)
    (r,) = iterative_step(jnp.ones((aot.RANK_N,), jnp.float32))
    assert r.shape == (aot.RANK_N,)
    (s,) = batch_stats_step(vals)
    assert s.shape == (3,)


def test_hlo_text_emission():
    arts = aot.artifacts()
    assert set(arts) == {"stream_agg", "iterate", "batch_stats"}
    for name, lowered in arts.items():
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ROOT" in text
        # The tuple-return convention the Rust loader expects.
        assert "tuple" in text.lower()


def test_hlo_text_deterministic():
    a = {k: aot.to_hlo_text(v) for k, v in aot.artifacts().items()}
    b = {k: aot.to_hlo_text(v) for k, v in aot.artifacts().items()}
    assert a == b, "lowering must be reproducible for artifact caching"


def test_lowered_module_numerics_match_ref():
    """Execute the same jitted functions that get lowered and compare to
    the oracles — what the Rust PJRT client will compute."""
    keys = jnp.array([i % aot.NUM_KEYS for i in range(aot.WINDOW)], jnp.float32)
    vals = jnp.linspace(-1.0, 1.0, aot.WINDOW, dtype=jnp.float32)
    (sums,) = jax.jit(lambda k, v: analytics_step(k, v, aot.NUM_KEYS))(keys, vals)
    np.testing.assert_allclose(
        sums, stream_agg_ref(keys, vals, aot.NUM_KEYS), rtol=1e-5, atol=1e-5
    )
    r0 = jnp.abs(vals[: aot.RANK_N]) + 0.1
    (r1,) = jax.jit(iterative_step)(r0)
    np.testing.assert_allclose(r1, iterate_ref(r0), rtol=1e-5)
    (st,) = jax.jit(batch_stats_step)(vals)
    np.testing.assert_allclose(st, batch_stats_ref(vals), rtol=1e-5)


def test_rust_mock_agreement_vectors():
    """Golden vectors shared with the Rust mock kernels (see
    operators::tensor::mock tests): guards the mock/XLA equivalence the
    examples rely on when artifacts are absent."""
    keys = jnp.array([0, 1, 2, 0, 1, 2, 0, 0], jnp.float32)
    vals = jnp.array([1, 2, 3, 4, 5, 6, 7, 8], jnp.float32)
    got = np.asarray(stream_agg_ref(keys, vals, 3))
    np.testing.assert_allclose(got, [20.0, 7.0, 9.0])
    r = jnp.array([1.0, 0.0, 0.0, 0.0], jnp.float32)
    got = np.asarray(iterate_ref(r, 0.85))
    # (1-d)/4 * 1 = 0.0375; neighbours of the unit mass get d/2 = 0.425.
    np.testing.assert_allclose(got, [0.0375, 0.4625, 0.0375, 0.4625], rtol=1e-6)
