"""Randomized model validation of the content-addressed snapshot-chain
invariants the Rust store asserts (rust/src/ft/storage.rs
stage_put_snapshot / materialize_snapshot and rust/src/ft/harness.rs
sweep_unreachable_snapshots).

The container cannot execute the Rust test-suite, so this file keeps the
desk-check honest from the other side: a tiny executable model of the
chunked checkpoint representation is driven over thousands of random
state histories (overwrites, appends, truncations), and the invariants
the Rust suites assert are checked on the model:

  1. materialization is lossless — walking a delta chain newest-to-
     oldest with first-hash-wins per position reassembles the reference
     state byte-identically, for every live chain entry, under Full and
     Delta policies alike;
  2. chain depth never exceeds max_chain — the forced-full bound caps
     every materialization walk;
  3. the reachability sweep is exact — after GC-prefix or crash-suffix
     truncation it keeps a snapshot record iff some live entry's walk
     touches it and a chunk iff a retained snapshot lists its hash, so
     survivors still materialize and the store holds nothing else;
  4. dedup accounting — a chunk whose hash is already resident is never
     rewritten, so Delta durable bytes scale with the changed span
     (an append-only epoch rewrites only the trailing chunks).

Stdlib only: run directly
(``python3 python/tests/test_snapshot_chain_invariants.py``) or under
pytest.
"""

import random

CHUNK = 8  # model's SNAPSHOT_CHUNK_BYTES; tiny so chains have many chunks
MAX_CHAIN_CHOICES = (1, 2, 8)
N_HISTORIES = 1500
N_STEPS = 40


def fnv1a(data):
    """fnv1a-64, bit-compatible with rust/src/util (the chunk address)."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def chunks_of(state):
    """(pos, hash, bytes) for every CHUNK-sized span; final span ragged."""
    out = []
    for pos in range(0, max(1, (len(state) + CHUNK - 1) // CHUNK)):
        span = bytes(state[pos * CHUNK : (pos + 1) * CHUNK])
        out.append((pos, fnv1a(span), span))
    return out


class ModelStore:
    """Chunk store + snapshot records of one processor."""

    def __init__(self, max_chain):
        self.max_chain = max_chain  # None models SnapshotPolicy::Full
        self.chunks = {}  # hash -> bytes
        self.snaps = {}  # tag -> (state_len, [(pos, hash)], prior_tag | None)
        self.next_tag = 1
        self.chunks_written = 0
        self.chunks_reused = 0

    def chain_depth(self, tag):
        depth, seen = 0, set()
        while tag is not None and tag not in seen:
            seen.add(tag)
            depth += 1
            tag = self.snaps[tag][2]
        return depth

    def put_snapshot(self, state, last_acked):
        """stage_put_snapshot: full listing, or a sparse delta on a base."""
        tag = self.next_tag
        self.next_tag += 1
        all_chunks = chunks_of(state)
        base = None
        if (
            self.max_chain is not None
            and last_acked is not None
            and self.chain_depth(last_acked) < self.max_chain
        ):
            base = last_acked
        if base is None:
            listed = [(p, h) for p, h, _ in all_chunks]
        else:
            base_state = self.materialize(base)
            base_hashes = {p: h for p, h, _ in chunks_of(base_state)}
            listed = [
                (p, h) for p, h, _ in all_chunks if base_hashes.get(p) != h
            ]
        for p, h, span in all_chunks:
            if (p, h) not in listed:
                continue
            if h in self.chunks:
                self.chunks_reused += 1
            else:
                self.chunks[h] = span
                self.chunks_written += 1
        self.snaps[tag] = (len(state), listed, base)
        return tag

    def materialize(self, tag):
        """Walk newest-to-oldest, first hash wins per position."""
        state_len, _, _ = self.snaps[tag]
        n = max(1, (state_len + CHUNK - 1) // CHUNK)
        hashes = [None] * n
        cur = tag
        while cur is not None:
            _, listed, prior = self.snaps[cur]
            for p, h in listed:
                if p < n and hashes[p] is None:
                    hashes[p] = h
            if all(h is not None for h in hashes):
                break
            assert prior is None or prior < cur, "chain must descend"
            cur = prior
        out = bytearray()
        for p, h in enumerate(hashes):
            assert h is not None, f"tag {tag}: position {p} unreachable"
            span = self.chunks[h]
            assert len(span) == min(CHUNK, state_len - p * CHUNK) or (
                state_len == 0 and len(span) == 0
            ), f"tag {tag}: chunk span mismatch at {p}"
            out += span
        return bytes(out[:state_len])

    def sweep(self, live_tags):
        """sweep_unreachable_snapshots: retain what live walks touch."""
        reachable = set()
        for t in live_tags:
            while t is not None and t not in reachable:
                reachable.add(t)
                t = self.snaps[t][2]
        self.snaps = {t: s for t, s in self.snaps.items() if t in reachable}
        listed = {h for _, l, _ in self.snaps.values() for _, h in l}
        self.chunks = {h: b for h, b in self.chunks.items() if h in listed}
        return reachable


def mutate(rng, state):
    """One epoch of state evolution: overwrite, append, or truncate."""
    op = rng.randrange(10)
    if op < 5 and state:  # overwrite a span in place
        at = rng.randrange(len(state))
        for i in range(at, min(len(state), at + rng.randrange(1, 2 * CHUNK))):
            state[i] = rng.randrange(256)
    elif op < 9:  # append (the Buffer-collector shape: old chunks stable)
        state += bytes(rng.randrange(256) for _ in range(rng.randrange(1, 3 * CHUNK)))
    elif state:  # truncate
        del state[rng.randrange(len(state)) :]
    return state


def run_history(seed):
    rng = random.Random(seed)
    max_chain = rng.choice((None,) + MAX_CHAIN_CHOICES)  # None = Full
    store = ModelStore(max_chain)
    state = bytearray(rng.randrange(256) for _ in range(rng.randrange(4 * CHUNK)))
    chain = []  # live entries: (tag, reference bytes at checkpoint time)
    for step in range(N_STEPS):
        tag_msg = f"seed {seed} step {step} max_chain {max_chain}"
        mutate(rng, state)
        last = chain[-1][0] if chain else None
        t = store.put_snapshot(state, last)
        chain.append((t, bytes(state)))

        # Invariant 2: the forced-full bound caps every walk.
        for tg, _ in chain:
            depth = store.chain_depth(tg)
            bound = 1 if max_chain is None else max_chain
            assert depth <= bound, f"{tag_msg}: tag {tg} depth {depth} > {bound}"

        # Occasional truncation, then the reachability sweep.
        if chain and rng.randrange(4) == 0:
            if rng.randrange(2):  # GC: monitor drops a prefix
                chain = chain[rng.randrange(len(chain)) :]
            else:  # crash/repair: conservative suffix drop
                chain = chain[: rng.randrange(len(chain)) + 1]
            reachable = store.sweep([tg for tg, _ in chain])
            # Invariant 3: exact — nothing beyond the reachable set stays.
            assert set(store.snaps) == reachable, f"{tag_msg}: sweep kept orphans"
            listed = {h for _, l, _ in store.snaps.values() for _, h in l}
            assert set(store.chunks) == listed, f"{tag_msg}: chunk set != listed set"

        # Invariant 1: every live entry still materializes byte-identically.
        for tg, ref in chain:
            got = store.materialize(tg)
            assert got == ref, f"{tag_msg}: tag {tg} materialized {got!r} != {ref!r}"


def test_snapshot_chain_invariants_over_random_histories():
    for seed in range(N_HISTORIES):
        run_history(seed)


def test_append_only_delta_writes_only_the_tail():
    # Invariant 4: with Delta and append-only growth, each checkpoint
    # rewrites at most the previously-ragged boundary chunk plus the new
    # tail — never the stable interior.
    store = ModelStore(max_chain=8)
    state = bytearray()
    last = None
    for step in range(64):
        before = store.chunks_written
        grown = bytes((step + i) % 256 for i in range(5))
        state += grown
        last = store.put_snapshot(state, last)
        new_chunks = store.chunks_written - before
        worst = (len(grown) + CHUNK - 1) // CHUNK + 1
        assert new_chunks <= worst, (
            f"append step {step}: wrote {new_chunks} chunks, tail bound {worst}"
        )
        assert store.materialize(last) == bytes(state)


def test_full_policy_dedups_but_never_chains():
    # Full relists everything each time; dedup still skips unchanged
    # chunks, and no record carries a prior pointer.
    store = ModelStore(max_chain=None)
    state = bytearray(range(64))
    t1 = store.put_snapshot(state, None)
    state[0] ^= 0xFF  # dirty exactly one chunk
    t2 = store.put_snapshot(state, t1)
    assert store.snaps[t2][2] is None, "Full snapshot must not chain"
    assert store.chunks_reused >= len(store.snaps[t2][1]) - 1, (
        "unchanged chunks must dedup, not rewrite"
    )
    assert store.materialize(t2) == bytes(state)


if __name__ == "__main__":
    test_snapshot_chain_invariants_over_random_histories()
    test_append_only_delta_writes_only_the_tail()
    test_full_policy_dedups_but_never_chains()
    print("ok: snapshot-chain invariants hold over "
          f"{N_HISTORIES} random histories (+2 directed scenarios)")
