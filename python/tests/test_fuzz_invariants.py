"""Randomized model validation of the staged-persistence invariants the
Rust fuzz oracle (rust/src/fuzz/oracle.rs) asserts after every recovery.

The container cannot execute the Rust test-suite, so this file keeps the
desk-check honest from the other side: a tiny executable model of the
staged/acked/offered chain (ft/storage.rs + ft/harness.rs +
ft/recovery.rs availability()) is driven over thousands of random
histories, and the same invariants the Rust oracle checks are asserted
on the model:

  1. offered(p) is exactly the acked prefix of the mirror chain — every
     offered checkpoint is durable (seq <= acked watermark), and nothing
     acked is withheld;
  2. acked(p) <= staged(p) at every step, and both are monotone outside
     crashes;
  3. the GC low watermark never passes the acked watermark — the monitor
     learns of checkpoints only via pump (acked entries only), so GC can
     never release state that recovery could still need;
  4. discard_unacked (crash) leaves mirror == acked prefix and
     staged == acked, and replayed history after the crash re-stages the
     suffix with fresh (higher) sequence numbers.

Stdlib only: run directly (``python3 python/tests/test_fuzz_invariants.py``)
or under pytest.
"""

import random

ACK_EVERY_CHOICES = (1, 2, 4, 16)
N_HISTORIES = 2000
N_STEPS = 120


class ModelStore:
    """Per-processor staged/acked watermark model of ft/storage.rs."""

    def __init__(self, ack_every):
        self.ack_every = ack_every
        self.staged = 0  # next sequence number to assign
        self.acked = 0   # watermark: seq <= acked are durable
        self.pending = 0  # staged - acked, queued in the writer

    def stage(self):
        seq = self.staged + 1
        self.staged = seq
        self.pending += 1
        return seq

    def writer_drain_batch(self):
        """Background writer applies up to ack_every ops, then acks."""
        n = min(self.pending, self.ack_every)
        self.pending -= n
        self.acked += n

    def flush(self):
        """Staging barrier (Store::flush_staged)."""
        self.pending = 0
        self.acked = self.staged

    def discard_unacked(self):
        """Crash: queued-unapplied operations are dropped."""
        self.pending = 0
        self.staged = self.acked


class ModelProc:
    """Mirror chain + monitor view of one processor."""

    def __init__(self, ack_every):
        self.store = ModelStore(ack_every)
        self.chain = []  # list of seq numbers, ascending (mirror of Xi records)
        self.gc_watermark = 0  # number of chain entries the monitor released
        self.monitor_seen = 0  # chain entries pumped to the monitor so far

    def checkpoint(self):
        self.chain.append(self.store.stage())

    def offered(self):
        """availability(): the acked prefix of the mirror chain."""
        w = self.store.acked
        k = 0
        while k < len(self.chain) and self.chain[k] <= w:
            k += 1
        return self.chain[:k]

    def pump_monitor(self):
        """FtSystem::pump_monitor reports only acked Xi records."""
        self.monitor_seen = len(self.offered())

    def apply_gc(self, rng):
        """Monitor releases some prefix of what it has seen."""
        if self.monitor_seen > self.gc_watermark:
            self.gc_watermark = rng.randint(self.gc_watermark, self.monitor_seen)

    def crash(self):
        """inject_failures: discard_unacked + mirror suffix truncation."""
        self.store.discard_unacked()
        self.chain = self.offered()


def check_invariants(proc, tag):
    store = proc.store
    assert store.acked <= store.staged, f"{tag}: acked > staged"
    assert store.staged - store.acked == store.pending, f"{tag}: pending gauge drift"

    offered = proc.offered()
    # Invariant 1: offered is a prefix of the mirror and entirely durable.
    assert offered == proc.chain[: len(offered)], f"{tag}: offered not a mirror prefix"
    assert all(s <= store.acked for s in offered), f"{tag}: offered an unacked checkpoint"
    # ...and nothing acked is withheld: the first non-offered entry is unacked.
    if len(offered) < len(proc.chain):
        assert proc.chain[len(offered)] > store.acked, f"{tag}: withheld an acked checkpoint"
    # Mirror chain sequence numbers ascend (chains ascend in frontier order;
    # staging preserves per-processor FIFO, so seqs ascend too).
    assert all(a < b for a, b in zip(proc.chain, proc.chain[1:])), f"{tag}: chain not ascending"

    # Invariant 3: GC released <= monitor-seen <= offered <= durable.
    assert proc.gc_watermark <= proc.monitor_seen, f"{tag}: GC ahead of monitor"
    assert proc.monitor_seen <= len(offered), f"{tag}: monitor saw unacked state"
    if proc.gc_watermark > 0:
        released_top = proc.chain[proc.gc_watermark - 1]
        assert released_top <= store.acked, f"{tag}: GC released past the acked watermark"


def run_history(seed):
    rng = random.Random(seed)
    proc = ModelProc(rng.choice(ACK_EVERY_CHOICES))
    acked_before = 0
    for step in range(N_STEPS):
        tag = f"seed {seed} step {step}"
        op = rng.randrange(100)
        if op < 45:
            proc.checkpoint()
        elif op < 70:
            proc.store.writer_drain_batch()
        elif op < 80:
            proc.store.flush()
        elif op < 88:
            proc.pump_monitor()
            proc.apply_gc(rng)
        elif op < 96:
            # Invariant 2: acked is monotone outside crashes...
            assert proc.store.acked >= acked_before, f"{tag}: acked regressed without a crash"
        else:
            pre_offered = proc.offered()
            pre_staged = proc.store.staged
            proc.crash()
            # Invariant 4: crash leaves exactly the acked prefix.
            assert proc.chain == pre_offered, f"{tag}: crash kept unacked mirror entries"
            assert proc.store.staged == proc.store.acked, f"{tag}: crash left staged != acked"
            assert proc.store.pending == 0, f"{tag}: crash left queued ops"
            # GC watermark must still be covered by the surviving chain.
            assert proc.gc_watermark <= len(proc.chain), f"{tag}: GC released vanished state"
            proc.monitor_seen = min(proc.monitor_seen, len(proc.chain))
            # Replay re-stages the suffix with fresh sequence numbers.
            for _ in range(rng.randrange(3)):
                proc.checkpoint()
                assert proc.chain[-1] > min(pre_staged, proc.store.acked), (
                    f"{tag}: replayed checkpoint reused a stale sequence number"
                )
        acked_before = proc.store.acked
        check_invariants(proc, tag)


def test_staged_chain_invariants_over_random_histories():
    for seed in range(N_HISTORIES):
        run_history(seed)


def test_sync_mode_keeps_watermarks_equal():
    # Sync persistence = stage + immediate flush: offered is always the
    # whole mirror, so a crash loses nothing from the chain.
    rng = random.Random(7)
    proc = ModelProc(1)
    for step in range(200):
        proc.checkpoint()
        proc.store.flush()
        assert proc.offered() == proc.chain, f"sync step {step}: withheld checkpoint"
        if rng.randrange(10) == 0:
            pre = list(proc.chain)
            proc.crash()
            assert proc.chain == pre, f"sync step {step}: crash lost acked state"
        check_invariants(proc, f"sync step {step}")


def test_gc_never_outruns_durability_even_when_pumped_eagerly():
    # Pump + GC after every single stage: the monitor still only ever
    # sees acked entries, so the released top stays durable throughout.
    rng = random.Random(11)
    proc = ModelProc(16)
    for step in range(300):
        proc.checkpoint()
        proc.pump_monitor()
        proc.apply_gc(rng)
        if rng.randrange(4) == 0:
            proc.store.writer_drain_batch()
        check_invariants(proc, f"eager-gc step {step}")


if __name__ == "__main__":
    test_staged_chain_invariants_over_random_histories()
    test_sync_mode_keeps_watermarks_equal()
    test_gc_never_outruns_durability_even_when_pumped_eagerly()
    print("ok: staged-chain invariants hold over "
          f"{N_HISTORIES} random histories (+2 directed scenarios)")
