"""L2: the analytics compute graphs of the Figure-1 application, written
in JAX and calling the L1 Pallas kernels so everything lowers into one
HLO module per artifact.

Three entry points (one artifact each; shapes fixed at AOT time):

- ``analytics_step(keys, vals)`` — the batch/streaming aggregation:
  kernel segment-sum over one window (called per completed epoch by the
  ``batch_agg`` vertex);
- ``iterative_step(rank)`` — one loop iteration of rank propagation
  (called per loop iteration by the ``iterate`` vertex; the dataflow
  loop supplies the iteration structure, matching how Naiad distributes
  iteration over the graph rather than inside a kernel);
- ``batch_stats_step(vals)`` — the periodic batch statistics.

Python runs only at build time: `aot.py` lowers these once to HLO text
and the Rust runtime loads the artifacts.
"""

import jax.numpy as jnp

from .kernels.batch_stats import batch_stats
from .kernels.iterate import iterate
from .kernels.stream_agg import stream_agg

DAMPING = 0.85


def analytics_step(keys: jnp.ndarray, vals: jnp.ndarray, num_keys: int) -> tuple:
    """Windowed keyed aggregation (L1 segment-sum kernel)."""
    return (stream_agg(keys, vals, num_keys),)


def iterative_step(rank: jnp.ndarray) -> tuple:
    """One rank-propagation iteration (L1 stencil kernel) with the output
    renormalized in plain jnp — demonstrating kernel + jnp composition in
    a single lowered module."""
    r = iterate(rank, DAMPING)
    return (r,)


def batch_stats_step(vals: jnp.ndarray) -> tuple:
    """Periodic batch statistics (L1 reduction kernel)."""
    return (batch_stats(vals),)
