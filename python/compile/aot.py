"""AOT lowering: JAX (L2, calling L1 Pallas kernels) → HLO **text**
artifacts the Rust runtime loads via PJRT.

HLO text — not ``lowered.compile().serialize()`` and not a serialized
``HloModuleProto`` — is the interchange format: jax ≥ 0.5 emits protos
with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser on the Rust side
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the Rust side unpacks a tuple uniformly.

Usage: ``python -m compile.aot --out ../artifacts`` (the Makefile's
``make artifacts``). Shapes are fixed here and must match the Rust
coordinator's defaults (Fig1Config: window 16, keys 8).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import analytics_step, batch_stats_step, iterative_step

# Compiled shapes (keep in sync with rust Fig1Config defaults).
WINDOW = 16
NUM_KEYS = 8
RANK_N = 8


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifacts() -> dict:
    """name -> lowered jax computation, at the compiled shapes."""
    f32 = jnp.float32
    keys = jax.ShapeDtypeStruct((WINDOW,), f32)
    vals = jax.ShapeDtypeStruct((WINDOW,), f32)
    rank = jax.ShapeDtypeStruct((RANK_N,), f32)
    return {
        "stream_agg": jax.jit(
            functools.partial(analytics_step, num_keys=NUM_KEYS)
        ).lower(keys, vals),
        "iterate": jax.jit(iterative_step).lower(rank),
        "batch_stats": jax.jit(batch_stats_step).lower(vals),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for name, lowered in artifacts().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {len(text)}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "MANIFEST"), "w") as f:
        f.write(f"window={WINDOW} num_keys={NUM_KEYS} rank_n={RANK_N}\n")
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
