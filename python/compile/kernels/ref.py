"""Pure-jnp reference oracles for the Pallas kernels.

These definitions are the single source of truth for kernel semantics:
- ``python/tests`` asserts the Pallas kernels (interpret mode) match them
  bit-for-bit / allclose across shape and value sweeps (hypothesis);
- the Rust mock kernels (``operators::tensor::mock``) mirror them so the
  dataflow tests are numerically identical with or without artifacts.
"""

import jax.numpy as jnp


def stream_agg_ref(keys: jnp.ndarray, vals: jnp.ndarray, num_keys: int) -> jnp.ndarray:
    """Keyed segment-sum over one window.

    ``keys`` are f32 bucket ids in [0, num_keys); padded slots carry
    val == 0 so they are sum-invariant regardless of their key.
    """
    one_hot = (keys[:, None].astype(jnp.int32) == jnp.arange(num_keys)[None, :]).astype(
        vals.dtype
    )
    return vals @ one_hot


def iterate_ref(rank: jnp.ndarray, damping: float = 0.85) -> jnp.ndarray:
    """One step of rank propagation on a ring graph of n nodes.

    r'[i] = (1-d)/n * sum(r) + d * (r[i-1] + r[i+1]) / 2
    """
    n = rank.shape[0]
    total = jnp.sum(rank)
    left = jnp.roll(rank, 1)
    right = jnp.roll(rank, -1)
    return (1.0 - damping) / n * total + damping * (left + right) / 2.0


def batch_stats_ref(v: jnp.ndarray) -> jnp.ndarray:
    """[sum, mean, max] of a window (the batch regime's statistics)."""
    s = jnp.sum(v)
    return jnp.stack([s, s / v.shape[0], jnp.max(v)])
