"""L1 Pallas kernel: one rank-propagation step on a ring graph.

The loop body of the Figure-1 iterative regime. On TPU this is a
stencil + reduction: the ring adjacency is materialized as rolls rather
than a sparse gather (gathers are the GPU idiom; rolls lower to cheap
lane rotations on TPU vector registers). The full rank vector lives in
one VMEM block (n ≤ 4096 ⇒ 16 KiB), so no grid is needed; bigger graphs
would tile with a halo of 1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iterate_kernel(damping: float, r_ref, o_ref):
    r = r_ref[...]
    n = r.shape[0]
    total = jnp.sum(r)
    left = jnp.roll(r, 1)
    right = jnp.roll(r, -1)
    o_ref[...] = (1.0 - damping) / n * total + damping * (left + right) / 2.0


def iterate(rank: jnp.ndarray, damping: float = 0.85) -> jnp.ndarray:
    """One Pallas rank-propagation step (see module docstring)."""
    return pl.pallas_call(
        functools.partial(_iterate_kernel, damping),
        out_shape=jax.ShapeDtypeStruct(rank.shape, rank.dtype),
        interpret=True,
    )(rank)
