"""L1 Pallas kernel: windowed keyed segment-sum.

The GPU idiom for this operation is scatter-add over shared memory; the
TPU re-think (DESIGN.md §Hardware-Adaptation) expresses it as a dense
one-hot matmul so it lands on the MXU systolic array: the (1, W) value
row multiplies the (W, K) one-hot key matrix built in VMEM. For the
window/key sizes this library compiles (W ≤ 1024, K ≤ 128 ⇒ ≤ 512 KiB
one-hot in f32) a single block fits comfortably in the ~16 MiB VMEM, so
the BlockSpec keeps whole-array blocks; larger windows would tile W.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowering produces plain HLO that the
Rust runtime executes. Real-TPU performance is *estimated* in
EXPERIMENTS.md from the block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(num_keys: int, keys_ref, vals_ref, o_ref):
    keys = keys_ref[...]
    vals = vals_ref[...]
    one_hot = (keys[:, None].astype(jnp.int32) == jnp.arange(num_keys)[None, :]).astype(
        vals.dtype
    )
    # (W,) @ (W, K) -> (K,): the MXU-friendly contraction.
    o_ref[...] = vals @ one_hot


def stream_agg(keys: jnp.ndarray, vals: jnp.ndarray, num_keys: int) -> jnp.ndarray:
    """Pallas segment-sum: see module docstring."""
    return pl.pallas_call(
        functools.partial(_agg_kernel, num_keys),
        out_shape=jax.ShapeDtypeStruct((num_keys,), vals.dtype),
        interpret=True,
    )(keys, vals)
