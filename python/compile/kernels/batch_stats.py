"""L1 Pallas kernel: window statistics [sum, mean, max] for the batch
regime's periodic reduction. A single-block VMEM reduction."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(v_ref, o_ref):
    v = v_ref[...]
    s = jnp.sum(v)
    o_ref[...] = jnp.stack([s, s / v.shape[0], jnp.max(v)])


def batch_stats(v: jnp.ndarray) -> jnp.ndarray:
    """Pallas [sum, mean, max] reduction."""
    return pl.pallas_call(
        _stats_kernel,
        out_shape=jax.ShapeDtypeStruct((3,), v.dtype),
        interpret=True,
    )(v)
