//! Batch-throughput bench: records/sec of the Fig. 1 application and the
//! sharded keyed-aggregation job across `batch_cap ∈ {1, 8, 64, 512}`.
//!
//! `batch_cap = 1` reproduces the pre-batching record-at-a-time
//! delivery (one record per step, original order, identical outputs);
//! larger caps coalesce same-time channel
//! enqueues into batches that move through delivery, the Table-1
//! harness (one log write per batch) and the sharded exchange as single
//! units. Before timing, the bench asserts the observable outputs are
//! identical across all caps — Fig. 1 responses / db commits, and the
//! sharded job's canonical collector bytes — so the speedup is measured
//! on provably equivalent executions.

use falkirk::bench_support::sharded::{
    canonical_output, drive_workload, pipeline, ShardedConfig,
};
use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::coordinator::fig1::{run as run_fig1, Fig1Config};

const CAPS: [usize; 4] = [1, 8, 64, 512];

const SHARD_EPOCHS: u64 = 4;
const SHARD_RECORDS: usize = 512;
const SHARD_KEYS: u64 = 64;

fn fig1_cfg(batch_cap: usize) -> Fig1Config {
    Fig1Config {
        epochs: 4,
        queries_per_epoch: 16,
        records_per_epoch: 256,
        use_xla: false, // deterministic reference kernels
        batch_cap,
        ..Default::default()
    }
}

fn shard_cfg(batch_cap: usize) -> ShardedConfig {
    ShardedConfig { workers: 4, two_stage: true, batch_cap, ..Default::default() }
}

fn main() {
    let mut b = Bencher::with_config(
        "batch_throughput",
        BenchConfig { warmup_iters: 1, sample_iters: 5 },
    );

    // Equivalence gate: every cap must produce the cap-1 output.
    let base_fig1 = run_fig1(&fig1_cfg(1));
    let base_shard = {
        let mut p = pipeline(&shard_cfg(1));
        drive_workload(&mut p, 7, SHARD_EPOCHS, SHARD_RECORDS, SHARD_KEYS);
        canonical_output(&p.sys, p.collect_proc())
    };
    for cap in CAPS {
        let out = run_fig1(&fig1_cfg(cap));
        assert_eq!(out.responses, base_fig1.responses, "fig1 responses diverged at cap {cap}");
        assert_eq!(out.db_commits, base_fig1.db_commits, "fig1 db commits diverged at cap {cap}");
        let mut p = pipeline(&shard_cfg(cap));
        drive_workload(&mut p, 7, SHARD_EPOCHS, SHARD_RECORDS, SHARD_KEYS);
        assert_eq!(
            canonical_output(&p.sys, p.collect_proc()),
            base_shard,
            "sharded output diverged at cap {cap}"
        );
    }
    // …and across mailbox budgets: backpressure defers deliveries but
    // must never change the bytes.
    for mbox in [2usize, 64] {
        let mut p = pipeline(&ShardedConfig { mailbox_cap: Some(mbox), ..shard_cfg(1) });
        drive_workload(&mut p, 7, SHARD_EPOCHS, SHARD_RECORDS, SHARD_KEYS);
        assert_eq!(
            canonical_output(&p.sys, p.collect_proc()),
            base_shard,
            "sharded output diverged at mailbox_cap {mbox}"
        );
    }
    b.note("equivalence: outputs byte-identical across all caps (cap 1 = record-at-a-time)");

    // Fig. 1 workload.
    for cap in CAPS {
        let cfg = fig1_cfg(cap);
        let records = (cfg.queries_per_epoch + cfg.records_per_epoch) as f64 * cfg.epochs as f64;
        b.run(&format!("fig1_cap{cap}"), records, || {
            run_fig1(&cfg);
        });
    }

    // Sharded keyed aggregation (W = 4, two-stage exchange).
    for cap in CAPS {
        let cfg = shard_cfg(cap);
        let records = (SHARD_EPOCHS * SHARD_RECORDS as u64) as f64;
        b.run(&format!("shard_W4_cap{cap}"), records, || {
            let mut p = pipeline(&cfg);
            drive_workload(&mut p, 7, SHARD_EPOCHS, SHARD_RECORDS, SHARD_KEYS);
        });
    }
    // Backpressure price: the same sharded workload at cap 8 under
    // per-edge mailbox budgets (bounded peak queue residency) vs. the
    // unbounded shard_W4_cap8 row above.
    for mbox in [2usize, 64] {
        let cfg = ShardedConfig { mailbox_cap: Some(mbox), ..shard_cfg(8) };
        let records = (SHARD_EPOCHS * SHARD_RECORDS as u64) as f64;
        b.run(&format!("shard_W4_cap8_mbox{mbox}"), records, || {
            let mut p = pipeline(&cfg);
            drive_workload(&mut p, 7, SHARD_EPOCHS, SHARD_RECORDS, SHARD_KEYS);
        });
    }
    b.note("ops/s = source records/sec end to end; larger caps amortize per-event scheduling, metadata and log writes");
    b.note("shard_W4_cap8_mbox*: credit-based backpressure overhead — compare against shard_W4_cap8");
}
