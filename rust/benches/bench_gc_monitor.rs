//! E8 (§4.2): garbage-collection monitor — incremental low-watermark
//! updates vs batch recomputation, and storage actually reclaimed.
//!
//! Expected shape: the incremental update cost is roughly independent of
//! graph size for a localized Ξ arrival (it touches the affected region),
//! while batch recomputation grows with the graph; watermark advances
//! release storage monotonically.

use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::frontier::Frontier;
use falkirk::ft::meta::CkptMeta;
use falkirk::ft::monitor::Monitor;
use falkirk::graph::{EdgeId, GraphBuilder, ProcId, Projection, Topology};
use falkirk::time::TimeDomain;
use std::sync::Arc;

fn chain_topo(n: usize) -> (Arc<Topology>, Vec<Vec<EdgeId>>, Vec<Vec<EdgeId>>) {
    let mut g = GraphBuilder::new();
    let procs: Vec<_> =
        (0..n).map(|i| g.add_proc(&format!("p{i}"), TimeDomain::EPOCH)).collect();
    let mut ins = vec![Vec::new(); n];
    let mut outs = vec![Vec::new(); n];
    for i in 1..n {
        let e = g.connect(procs[i - 1], procs[i], Projection::Identity);
        outs[i - 1].push(e);
        ins[i].push(e);
    }
    (Arc::new(g.build().unwrap()), ins, outs)
}

fn ck(e: u64, ins: &[EdgeId], outs: &[EdgeId]) -> CkptMeta {
    let f = Frontier::upto_epoch(e);
    CkptMeta {
        f: f.clone(),
        n_bar: f.clone(),
        m_bar: ins.iter().map(|d| (*d, f.clone())).collect(),
        d_bar: outs.iter().map(|o| (*o, f.clone())).collect(),
        phi: outs.iter().map(|o| (*o, f.clone())).collect(),
    }
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 1, sample_iters: 6 };
    let mut b = Bencher::with_config("gc_monitor", cfg);

    for n in [10usize, 100, 1000] {
        // Incremental: every processor persists epochs 1..=R in turn —
        // R·n Ξ updates through the incremental path.
        const R: u64 = 5;
        b.run(&format!("incremental_total/n={n}"), (R as f64) * n as f64, || {
            let (topo, ins, outs) = chain_topo(n);
            let mut mon = Monitor::new(topo, vec![false; n], vec![false; n]);
            for ep in 1..=R {
                for i in 0..n {
                    mon.on_persisted(ProcId(i as u32), ck(ep, &ins[i], &outs[i]));
                }
            }
            assert_eq!(
                mon.low_watermark(ProcId(0)),
                &Frontier::upto_epoch(R),
                "watermark must reach the persisted epoch"
            );
        });
        // Batch recomputation at the same final state.
        b.run(&format!("batch_recompute/n={n}"), 1.0, || {
            let (topo, ins, outs) = chain_topo(n);
            let mut mon = Monitor::new(topo, vec![false; n], vec![false; n]);
            for i in 0..n {
                mon.on_persisted(ProcId(i as u32), ck(1, &ins[i], &outs[i]));
            }
            mon.recompute_batch();
        });
    }

    // One more localized-update probe: a single Ξ arrival on a large,
    // already-converged graph.
    {
        let n = 2000usize;
        let (topo, ins, outs) = chain_topo(n);
        let mut mon = Monitor::new(topo, vec![false; n], vec![false; n]);
        for i in 0..n {
            mon.on_persisted(ProcId(i as u32), ck(1, &ins[i], &outs[i]));
        }
        let mut ep = 2u64;
        b.run("single_update/n=2000", 1.0, || {
            // Only one processor advances: the watermark cannot move, so
            // the incremental pass should stay local.
            mon.on_persisted(ProcId(17), ck(ep, &ins[17], &outs[17]));
            ep += 1;
        });
    }
    b.note("expected: single localized Ξ update ≪ batch recompute at same n");
}
