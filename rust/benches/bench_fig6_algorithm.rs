//! E5 (Fig. 6): scaling of the consistent-frontier fixed point.
//!
//! Random layered DAGs (plus a loop variant) of n = 10…3000 processors
//! with varying checkpoint-chain depth; measures the batch solve and the
//! incremental growth path. Expected shape: near-linear in |E| for chains
//! of bounded depth; incremental update ≪ batch for a single-Ξ change.

use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::frontier::Frontier;
use falkirk::ft::meta::CkptMeta;
use falkirk::ft::rollback::{
    choose_frontiers, grow_frontiers, verify_plan, Available, RollbackInput,
};
use falkirk::graph::{EdgeId, GraphBuilder, ProcId, Projection, Topology};
use falkirk::time::TimeDomain;
use falkirk::util::rng::Rng;

fn epoch_ckpt(e: u64, ins: &[EdgeId], outs: &[EdgeId]) -> CkptMeta {
    let f = Frontier::upto_epoch(e);
    CkptMeta {
        f: f.clone(),
        n_bar: f.clone(),
        m_bar: ins.iter().map(|d| (*d, f.clone())).collect(),
        d_bar: outs.iter().map(|o| (*o, f.clone())).collect(),
        phi: outs.iter().map(|o| (*o, f.clone())).collect(),
    }
}

struct Case {
    topo: Topology,
    avail: Vec<Available>,
}

fn random_case(n: usize, chain_depth: u64, fail_frac: f64, seed: u64) -> Case {
    let mut rng = Rng::new(seed);
    let mut g = GraphBuilder::new();
    let procs: Vec<_> =
        (0..n).map(|i| g.add_proc(&format!("p{i}"), TimeDomain::EPOCH)).collect();
    let mut ins: Vec<Vec<EdgeId>> = vec![Vec::new(); n];
    let mut outs: Vec<Vec<EdgeId>> = vec![Vec::new(); n];
    for i in 1..n {
        for _ in 0..=rng.below(2) {
            let j = rng.index(i);
            let e = g.connect(procs[j], procs[i], Projection::Identity);
            outs[j].push(e);
            ins[i].push(e);
        }
    }
    let topo = g.build().unwrap();
    let avail = (0..n)
        .map(|i| {
            if rng.chance(fail_frac) {
                Available::chain(vec![])
            } else {
                let base = rng.below(4);
                Available::chain(
                    (0..chain_depth).map(|k| epoch_ckpt(base + k, &ins[i], &outs[i])).collect(),
                )
            }
        })
        .collect();
    Case { topo, avail }
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 2, sample_iters: 8 };
    let mut b = Bencher::with_config("fig6_solver", cfg);

    for n in [10usize, 50, 200, 1000, 3000] {
        let case = random_case(n, 4, 0.1, 42);
        b.run(&format!("batch/n={n}"), n as f64, || {
            let input = RollbackInput { topo: &case.topo, avail: &case.avail };
            let plan = choose_frontiers(&input);
            std::hint::black_box(&plan);
        });
    }
    // Verify correctness once per size (kept out of the timed loop).
    for n in [10usize, 200, 1000] {
        let case = random_case(n, 4, 0.1, 42);
        let input = RollbackInput { topo: &case.topo, avail: &case.avail };
        let plan = choose_frontiers(&input);
        verify_plan(&input, &plan).expect("solver must satisfy §3.5");
    }

    // Incremental (§4.2 GC path): one processor adds a checkpoint.
    for n in [50usize, 200, 1000, 3000] {
        let mut case = random_case(n, 4, 0.0, 7);
        let plan0 = {
            let input = RollbackInput { topo: &case.topo, avail: &case.avail };
            choose_frontiers(&input)
        };
        // The processor whose chain we extend each iteration.
        let victim = n / 2;
        b.run(&format!("incremental/n={n}"), 1.0, || {
            let mut plan = plan0.clone();
            if let Available::Chain { chain, .. } = &mut case.avail[victim] {
                let top = chain.last().unwrap().f.max_epoch().unwrap();
                let ins: Vec<EdgeId> =
                    case.topo.in_edges(ProcId(victim as u32)).to_vec();
                let outs: Vec<EdgeId> =
                    case.topo.out_edges(ProcId(victim as u32)).to_vec();
                chain.push(epoch_ckpt(top + 1, &ins, &outs));
            }
            {
                let input = RollbackInput { topo: &case.topo, avail: &case.avail };
                grow_frontiers(&input, &mut plan, ProcId(victim as u32));
            }
            std::hint::black_box(&plan);
        });
    }
    // Chain-depth sensitivity.
    for depth in [1u64, 4, 16, 64] {
        let case = random_case(400, depth, 0.1, 9);
        b.run(&format!("chain_depth/{depth}"), 400.0, || {
            let input = RollbackInput { topo: &case.topo, avail: &case.avail };
            std::hint::black_box(choose_frontiers(&input));
        });
    }
    b.note("expected: batch ~linear in |E|·depth; incremental ≪ batch at same n");
}
