//! E2 (Fig. 2): per-event cost of the three logical-time domains.
//!
//! Measures event tagging + frontier/φ bookkeeping for (a) sequence
//! numbers, (b) epochs, (c) structured times with a loop — the overhead
//! the framework adds on the message hot path. Expected shape: seq-number
//! tracking cheapest, structured/loop tracking more expensive but still
//! small relative to processing; all ≫ 10⁵ events/s.

use falkirk::bench_support::Bencher;
use falkirk::engine::{Delivery, Engine, Processor, Record};
use falkirk::graph::{GraphBuilder, ProcId, Projection};
use falkirk::operators::{shared_vec, Feedback, Ingress, Sink, Source, SumByTime};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

const EVENTS: usize = 20_000;

/// (a) seq-number pipeline: src → f(x) → sink, seq-domain receivers.
fn run_seq() {
    let mut g = GraphBuilder::new();
    let s = g.add_proc("src", TimeDomain::EPOCH);
    let m = g.add_proc("mid", TimeDomain::Seq);
    let k = g.add_proc("sink", TimeDomain::Seq);
    g.connect(s, m, Projection::PerCheckpoint);
    g.connect(m, k, Projection::PerCheckpoint);
    let out = shared_vec();
    struct Fwd;
    impl Processor for Fwd {
        fn on_message(&mut self, _p: usize, _t: Time, d: Record, ctx: &mut falkirk::engine::Ctx) {
            ctx.send(0, d);
        }
    }
    let procs: Vec<Box<dyn Processor>> =
        vec![Box::new(Source), Box::new(Fwd), Box::new(Sink(out))];
    let mut eng = Engine::new(Arc::new(g.build().unwrap()), procs, Delivery::Fifo);
    for i in 0..EVENTS {
        eng.push_input(ProcId(0), Time::epoch(0), Record::Int(i as i64));
    }
    eng.run_to_quiescence(10 * EVENTS);
    assert_eq!(eng.events_processed() as usize, 3 * EVENTS);
}

/// (b) epoch pipeline with notifications every `per_epoch` records.
fn run_epoch(per_epoch: usize) {
    let mut g = GraphBuilder::new();
    let s = g.add_proc("src", TimeDomain::EPOCH);
    let m = g.add_proc("sum", TimeDomain::EPOCH);
    let k = g.add_proc("sink", TimeDomain::EPOCH);
    g.connect(s, m, Projection::Identity);
    g.connect(m, k, Projection::Identity);
    let out = shared_vec();
    let procs: Vec<Box<dyn Processor>> =
        vec![Box::new(Source), Box::new(SumByTime::default()), Box::new(Sink(out))];
    let mut eng = Engine::new(Arc::new(g.build().unwrap()), procs, Delivery::Fifo);
    let epochs = EVENTS / per_epoch;
    for ep in 0..epochs {
        eng.advance_input(ProcId(0), Time::epoch(ep as u64));
        for i in 0..per_epoch {
            eng.push_input(ProcId(0), Time::epoch(ep as u64), Record::Int(i as i64));
        }
    }
    eng.close_input(ProcId(0));
    eng.run_to_quiescence(10 * EVENTS);
}

/// (c) structured times: epoch stream through a 4-iteration loop.
fn run_loop(per_epoch: usize, iters: u64) {
    let d1 = TimeDomain::Structured { depth: 1 };
    let mut g = GraphBuilder::new();
    let s = g.add_proc("src", TimeDomain::EPOCH);
    let ing = g.add_proc("ingress", d1);
    let fb = g.add_proc("feedback", d1);
    let k = g.add_proc("sink", TimeDomain::EPOCH);
    g.connect(s, ing, Projection::LoopEnter);
    g.connect(ing, fb, Projection::Identity);
    g.connect(fb, ing, Projection::LoopFeedback);
    g.connect(ing, k, Projection::LoopExit);
    let out = shared_vec();
    struct Body;
    impl Processor for Body {
        fn on_message(&mut self, _p: usize, _t: Time, d: Record, ctx: &mut falkirk::engine::Ctx) {
            ctx.send(0, d.clone());
            ctx.send(1, d);
        }
    }
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Body),
        Box::new(Feedback::new(iters)),
        Box::new(Sink(out)),
    ];
    let _ = Ingress; // (plain forwarders suffice; Body fans out)
    let mut eng = Engine::new(Arc::new(g.build().unwrap()), procs, Delivery::Fifo);
    let epochs = EVENTS / (per_epoch * iters as usize);
    for ep in 0..epochs.max(1) {
        eng.advance_input(ProcId(0), Time::epoch(ep as u64));
        for i in 0..per_epoch {
            eng.push_input(ProcId(0), Time::epoch(ep as u64), Record::Int(i as i64));
        }
    }
    eng.close_input(ProcId(0));
    eng.run_to_quiescence(100 * EVENTS);
}

fn main() {
    let mut b = Bencher::new("fig2_time_domains");
    b.run("a_seq_numbers", EVENTS as f64, run_seq);
    b.run("b_epochs_100_per", EVENTS as f64, || run_epoch(100));
    b.run("b_epochs_10_per", EVENTS as f64, || run_epoch(10));
    b.run("c_loop_4iters", EVENTS as f64, || run_loop(50, 4));
    b.note("expected: (a) cheapest per event; (c) adds loop-counter tagging + cyclic progress tracking");
}
