//! E3 (Fig. 3): selective rollback vs the two alternatives the paper
//! says it avoids.
//!
//! The Select→Sum→Buffer fragment with two interleaved logical times.
//! Compares:
//! 1. **selective**: interleaved delivery + selective checkpoint (the
//!    paper's design — checkpoint contains only completed times, so the
//!    Sum checkpoints empty state);
//! 2. **ordered-stall**: delivery restricted to one time at a time
//!    (epoch-serial), modelling "suspend delivery until all messages
//!    with earlier times had been processed";
//! 3. **full-state**: interleaved delivery but whole-state checkpoints
//!    (Chandy–Lamport style) — measured by checkpoint *size*.
//!
//! Expected shape: selective ≈ interleaved throughput with empty
//! checkpoints; ordered-stall pays a serialization penalty (epochs
//! cannot overlap); full-state checkpoints are strictly larger.

use falkirk::bench_support::Bencher;
use falkirk::engine::{Delivery, Processor, Record};
use falkirk::frontier::Frontier;
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{GraphBuilder, ProcId, Projection};
use falkirk::operators::{Buffer, Select, Source, SumByTime};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

const EPOCHS: u64 = 40;
const PER_EPOCH: usize = 100;

fn build(delivery: Delivery) -> FtSystem {
    let mut g = GraphBuilder::new();
    let s = g.add_proc("src", TimeDomain::EPOCH);
    let sel = g.add_proc("select", TimeDomain::EPOCH);
    let sum = g.add_proc("sum", TimeDomain::EPOCH);
    let buf = g.add_proc("buffer", TimeDomain::EPOCH);
    g.connect(s, sel, Projection::Identity);
    g.connect(sel, sum, Projection::Identity);
    g.connect(sum, buf, Projection::Identity);
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Select),
        Box::new(SumByTime::default()),
        Box::new(Buffer::default()),
    ];
    FtSystem::new(
        Arc::new(g.build().unwrap()),
        procs,
        vec![
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Lazy { every: 1, log_outputs: false },
            Policy::Lazy { every: 1, log_outputs: false },
        ],
        delivery,
        Store::new(1),
    )
}

/// Interleaved: two epochs in flight at once (the Fig. 3 timeline).
fn run_interleaved(delivery: Delivery) -> FtSystem {
    let mut sys = build(delivery);
    let src = ProcId(0);
    for pair in 0..(EPOCHS / 2) {
        let (a, b) = (Time::epoch(2 * pair), Time::epoch(2 * pair + 1));
        sys.advance_input(src, a);
        // Interleave messages of times A and B.
        for i in 0..PER_EPOCH {
            let t = if i % 2 == 0 { a } else { b };
            sys.push_input(src, t, Record::Int(i as i64));
        }
        sys.advance_input(src, Time::epoch(2 * pair + 2));
        sys.run_to_quiescence(1_000_000);
    }
    sys.close_input(src);
    sys.run_to_quiescence(1_000_000);
    sys
}

/// Epoch-serial: each time fully delivered (and completed) before the
/// next is admitted — the stall the paper avoids.
fn run_serial() -> FtSystem {
    let mut sys = build(Delivery::Fifo);
    let src = ProcId(0);
    for ep in 0..EPOCHS {
        let t = Time::epoch(ep);
        sys.advance_input(src, t);
        for i in 0..(PER_EPOCH / 2) {
            sys.push_input(src, t, Record::Int(i as i64));
        }
        sys.advance_input(src, Time::epoch(ep + 1));
        // Run to quiescence *per epoch*: the serialization barrier.
        sys.run_to_quiescence(1_000_000);
    }
    sys.close_input(src);
    sys.run_to_quiescence(1_000_000);
    sys
}

fn main() {
    let mut b = Bencher::new("fig3_selective_rollback");
    let events = (EPOCHS as f64) * (PER_EPOCH as f64);
    b.run("selective_interleaved", events, || {
        run_interleaved(Delivery::Selective);
    });
    b.run("fifo_interleaved", events, || {
        run_interleaved(Delivery::Fifo);
    });
    b.run("ordered_stall", events, || {
        run_serial();
    });

    // Checkpoint-size comparison: selective (completed times only) vs
    // full-state (everything, including the in-flight time B).
    let mut sys = build(Delivery::Selective);
    let src = ProcId(0);
    let (a, bt) = (Time::epoch(0), Time::epoch(1));
    sys.advance_input(src, a);
    for i in 0..PER_EPOCH {
        let t = if i % 2 == 0 { a } else { bt };
        sys.push_input(src, t, Record::Int(i as i64));
    }
    // Complete A but not B.
    sys.advance_input(src, bt);
    sys.run_to_quiescence(1_000_000);
    let sum = ProcId(2);
    let selective = sys.engine.proc(sum).checkpoint_upto(&Frontier::upto_epoch(0));
    let full = sys.engine.proc(sum).checkpoint_upto(&Frontier::Top);
    println!(
        "note fig3_selective_rollback/ckpt_bytes selective={} full_state={}",
        selective.len(),
        full.len()
    );
    assert!(selective.len() < full.len(), "selective checkpoint must be smaller");
    b.note("expected: selective ≈ fifo interleaved; ordered_stall slower; selective ckpt ≪ full");
}
