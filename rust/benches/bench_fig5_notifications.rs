//! E4 (Fig. 5): cost and effect of the notification-frontier constraints.
//!
//! Solves rollback on the Fig. 5 diamond (p,q → r → x) and on random
//! graphs, with and without N̄ metadata (setting N̄ = f_n = ∅ "omits"
//! notification frontiers per §3.5), measuring the solver-time delta —
//! the constraints' cost is expected to be negligible — and verifying the
//! hazard is excluded exactly when the constraints are on.

use falkirk::bench_support::Bencher;
use falkirk::frontier::Frontier;
use falkirk::ft::meta::CkptMeta;
use falkirk::ft::rollback::{choose_frontiers, verify_plan, Available, RollbackInput};
use falkirk::graph::{EdgeId, GraphBuilder, Projection, Topology};
use falkirk::time::TimeDomain;
use falkirk::util::rng::Rng;
use std::collections::BTreeMap;

fn epoch_ckpt(
    e: u64,
    ins: &[EdgeId],
    outs: &[EdgeId],
    with_notifications: bool,
) -> CkptMeta {
    let f = Frontier::upto_epoch(e);
    CkptMeta {
        f: f.clone(),
        n_bar: if with_notifications { f.clone() } else { Frontier::Bottom },
        m_bar: ins.iter().map(|d| (*d, f.clone())).collect(),
        d_bar: outs.iter().map(|o| (*o, f.clone())).collect(),
        phi: outs.iter().map(|o| (*o, f.clone())).collect(),
    }
}

/// Random layered DAG with `n` processors, each checkpointed at a random
/// epoch ≤ 8, a random subset failed.
fn random_case(n: usize, seed: u64, with_notifications: bool) -> (Topology, Vec<Available>) {
    let mut rng = Rng::new(seed);
    let mut g = GraphBuilder::new();
    let procs: Vec<_> =
        (0..n).map(|i| g.add_proc(&format!("p{i}"), TimeDomain::EPOCH)).collect();
    let mut edges: Vec<(usize, Vec<EdgeId>, Vec<EdgeId>)> =
        (0..n).map(|i| (i, Vec::new(), Vec::new())).collect();
    for i in 1..n {
        // 1–2 upstream edges from earlier layers.
        for _ in 0..=rng.below(2) {
            let j = rng.index(i);
            let e = g.connect(procs[j], procs[i], Projection::Identity);
            edges[j].2.push(e);
            edges[i].1.push(e);
        }
    }
    let topo = g.build().unwrap();
    let avail = (0..n)
        .map(|i| {
            if rng.chance(0.15) {
                Available::chain(vec![]) // failed
            } else {
                let ep = rng.below(8);
                Available::chain(vec![epoch_ckpt(ep, &edges[i].1, &edges[i].2, with_notifications)])
            }
        })
        .collect();
    (topo, avail)
}

fn solve_many(n: usize, cases: u64, with_notifications: bool) {
    for seed in 0..cases {
        let (topo, avail) = random_case(n, seed, with_notifications);
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
    }
}

fn main() {
    let mut b = Bencher::new("fig5_notification_frontiers");
    for n in [20usize, 100, 400] {
        b.run(&format!("with_nbar/n={n}"), 20.0, || solve_many(n, 20, true));
        b.run(&format!("without_nbar/n={n}"), 20.0, || solve_many(n, 20, false));
    }

    // The hazard check itself (Fig. 5 exact graph).
    let mut g = GraphBuilder::new();
    let p = g.add_proc("p", TimeDomain::EPOCH);
    let q = g.add_proc("q", TimeDomain::EPOCH);
    let r = g.add_proc("r", TimeDomain::EPOCH);
    let x = g.add_proc("x", TimeDomain::EPOCH);
    let e1 = g.connect(p, r, Projection::Identity);
    let e2 = g.connect(q, r, Projection::Identity);
    let e3 = g.connect(r, x, Projection::Identity);
    let topo = g.build().unwrap();
    let f1 = Frontier::upto_epoch(1);
    let make = |with_n: bool| -> Vec<Available> {
        let n_or = |f: &Frontier| if with_n { f.clone() } else { Frontier::Bottom };
        vec![
            Available::chain(vec![CkptMeta {
                f: f1.clone(),
                n_bar: n_or(&f1),
                m_bar: BTreeMap::new(),
                d_bar: [(e1, Frontier::Bottom)].into_iter().collect(),
                phi: [(e1, f1.clone())].into_iter().collect(),
            }]),
            Available::chain(vec![]), // q failed
            Available::chain(vec![CkptMeta {
                f: f1.clone(),
                n_bar: Frontier::Bottom,
                m_bar: [(e1, f1.clone()), (e2, Frontier::Bottom)].into_iter().collect(),
                d_bar: [(e3, Frontier::Bottom)].into_iter().collect(),
                phi: [(e3, f1.clone())].into_iter().collect(),
            }]),
            Available::chain(vec![CkptMeta {
                f: f1.clone(),
                n_bar: n_or(&f1),
                m_bar: [(e3, Frontier::Bottom)].into_iter().collect(),
                d_bar: BTreeMap::new(),
                phi: BTreeMap::new(),
            }]),
        ]
    };
    let with_n = make(true);
    let plan = choose_frontiers(&RollbackInput { topo: &topo, avail: &with_n });
    let without_n = make(false);
    let plan_no = choose_frontiers(&RollbackInput { topo: &topo, avail: &without_n });
    println!(
        "note fig5_notification_frontiers/hazard with_nbar: f(x)={} (excluded) | without_nbar: f(x)={} (admitted)",
        plan.f[3], plan_no.f[3]
    );
    assert!(plan.f[3].is_bottom(), "constraints must exclude the inconsistent state");
    assert_eq!(plan_no.f[3], f1, "without N̄ the hazard assignment is chosen");
    b.note("expected: solver cost delta from N̄ constraints is small; hazard excluded only with them");
}
