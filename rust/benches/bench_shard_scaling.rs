//! Shard-scaling bench: events/sec of the sharded keyed-aggregation job
//! at W = 1, 2, 4, 8 worker shards.
//!
//! Two groups:
//! - `engine/…`: fault tolerance off (everything ephemeral, zero-cost
//!   store) — pure cost of the sharded execution layer (exchange
//!   fan-out, per-shard routing, per-shard progress tracking);
//! - `ft/…`: the default policies (source log firewall, per-shard lazy
//!   selective checkpoints) — what recovery-capable deployments pay.
//!
//! The engine is single-process and event-at-a-time, so events/sec is
//! expected roughly flat in W; what this bench pins down is the *price*
//! of sharding (exchange edges multiply the graph, reachability scans
//! grow) so regressions in the sharded layer show up as a slope.

use falkirk::bench_support::sharded::{drive_epoch, pipeline, ShardedConfig};
use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::ft::Policy;

const EPOCHS: u64 = 4;
const RECORDS: usize = 256;
const KEYS: u64 = 64;

fn cfg(workers: u32, ft: bool) -> ShardedConfig {
    if ft {
        ShardedConfig { workers, two_stage: true, ..Default::default() }
    } else {
        ShardedConfig {
            workers,
            two_stage: true,
            count_policy: Policy::Ephemeral,
            collect_policy: Policy::Ephemeral,
            write_cost: 0,
            ..Default::default()
        }
    }
}

/// Run the job to completion; returns engine events processed.
fn run_job(cfg: &ShardedConfig) -> u64 {
    let mut p = pipeline(cfg);
    for ep in 0..EPOCHS {
        drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.sys.run_to_quiescence(10_000_000);
    p.sys.engine.events_processed()
}

fn main() {
    let mut b = Bencher::with_config(
        "shard_scaling",
        BenchConfig { warmup_iters: 1, sample_iters: 5 },
    );
    for ft in [false, true] {
        for workers in [1u32, 2, 4, 8] {
            let c = cfg(workers, ft);
            let units = run_job(&c) as f64; // events per iteration (dry run)
            let name =
                format!("{}_W{workers}", if ft { "ft" } else { "engine" });
            b.run(&name, units, || {
                run_job(&c);
            });
        }
    }
    b.note("ops/s = engine events/sec; exchange fan-out grows edges O(W^2) between sharded stages");
}
