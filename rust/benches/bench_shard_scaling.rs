//! Shard-scaling bench: throughput of the sharded keyed-aggregation job
//! at W = 1, 2, 4, 8 worker shards, sequential and multi-threaded.
//!
//! Three groups:
//! - `engine/…`: fault tolerance off (everything ephemeral, zero-cost
//!   store), single-threaded — pure cost of the sharded execution layer
//!   (exchange fan-out, per-shard routing, per-shard progress tracking);
//! - `ft/…`: the default policies (source log firewall, per-shard lazy
//!   selective checkpoints), single-threaded — what recovery-capable
//!   deployments pay;
//! - `par/…`: the fixed W = 8 workload drained on the parallel engine at
//!   T ∈ {1, 2, 4, 8} OS threads (ops/s = source records/sec). T = 1 is
//!   the sequential engine, so `par_W8_T1` is the baseline the speedup
//!   at T = 4/8 is measured against.
//!
//! The sequential engine is event-at-a-time, so `engine/ft` ops/s is
//! expected roughly flat in W; what those groups pin down is the *price*
//! of sharding (exchange edges multiply the graph, reachability scans
//! grow) so regressions in the sharded layer show up as a slope. The
//! `par` group is the scaling claim itself: records/sec per thread
//! count.

use falkirk::bench_support::sharded::{drive_epoch, drive_workload, pipeline, ShardedConfig};
use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::ft::Policy;

const EPOCHS: u64 = 4;
const RECORDS: usize = 256;
const KEYS: u64 = 64;

fn cfg(workers: u32, ft: bool, threads: usize) -> ShardedConfig {
    if ft {
        ShardedConfig { workers, two_stage: true, threads, ..Default::default() }
    } else {
        ShardedConfig {
            workers,
            two_stage: true,
            threads,
            count_policy: Policy::Ephemeral,
            collect_policy: Policy::Ephemeral,
            write_cost: 0,
            ..Default::default()
        }
    }
}

/// Run the job to completion; returns engine events processed.
fn run_job(cfg: &ShardedConfig) -> u64 {
    let mut p = pipeline(cfg);
    for ep in 0..EPOCHS {
        drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(10_000_000);
    p.sys.engine.events_processed()
}

fn main() {
    let mut b = Bencher::with_config(
        "shard_scaling",
        BenchConfig { warmup_iters: 1, sample_iters: 5 },
    );
    for ft in [false, true] {
        for workers in [1u32, 2, 4, 8] {
            let c = cfg(workers, ft, 1);
            let units = run_job(&c) as f64; // events per iteration (dry run)
            let name =
                format!("{}_W{workers}", if ft { "ft" } else { "engine" });
            b.run(&name, units, || {
                run_job(&c);
            });
        }
    }
    // Parallel scaling: fixed W = 8 workload, T threads; ops/s = source
    // records/sec end to end (same driver as `falkirk shard --threads`).
    for threads in [1usize, 2, 4, 8] {
        let c = cfg(8, true, threads);
        let records = (EPOCHS as usize * RECORDS) as f64;
        b.run(&format!("par_W8_T{threads}"), records, || {
            let mut p = pipeline(&c);
            let tp = drive_workload(&mut p, 7, EPOCHS, RECORDS, KEYS);
            assert_eq!(tp.records, EPOCHS * RECORDS as u64);
        });
    }
    // Credit-based backpressure at the scaling point: the T = 4 workload
    // with per-edge mailbox budgets vs. the unbounded par_W8_T4 row.
    for mbox in [2usize, 64] {
        let c = ShardedConfig { mailbox_cap: Some(mbox), ..cfg(8, true, 4) };
        let records = (EPOCHS as usize * RECORDS) as f64;
        b.run(&format!("par_W8_T4_mbox{mbox}"), records, || {
            let mut p = pipeline(&c);
            let tp = drive_workload(&mut p, 7, EPOCHS, RECORDS, KEYS);
            assert_eq!(tp.records, EPOCHS * RECORDS as u64);
        });
    }
    b.note(
        "engine/ft: ops/s = events/sec, single-threaded (exchange fan-out grows edges O(W^2)); \
         par_W8_T*: ops/s = records/sec at T worker threads — speedup = par_W8_T4 / par_W8_T1",
    );
    b.note("par_W8_T4_mbox*: bounded mailboxes on the parallel drain — compare against par_W8_T4");
}
