//! E6 (Fig. 7): the three worked rollback examples, measuring work
//! preserved vs redone.
//!
//! (a) sequence numbers, everyone logs: non-failed keep state, the
//!     failed processor replays from upstream logs;
//! (b) epochs/Spark: the RDD firewall keeps p,q,r untouched; the failed
//!     stage and its downstream reset and recompute from the log;
//! (c) Naiad loop: the loop restarts from the logged entry message while
//!     the producer outside the loop is untouched.
//!
//! Reported: recovery wall time, messages replayed, processors touched,
//! and events to re-quiesce (work redone).

use falkirk::baselines::{exactly_once, spark_lineage};
use falkirk::bench_support::Bencher;
use falkirk::engine::{Delivery, Processor, Record};
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{GraphBuilder, ProcId, Projection};
use falkirk::operators::{shared_vec, Egress, Feedback, Ingress, Sink, Source};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

const N: i64 = 500;

fn panel_a() -> (usize, u64) {
    let mut sc = exactly_once(1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    for i in 0..N {
        sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(i));
    }
    sc.sys.run_to_quiescence(1_000_000);
    sc.sys.inject_failures(&[sc.mid]);
    let rep = sc.sys.recover();
    let ev0 = sc.sys.engine.events_processed();
    sc.sys.run_to_quiescence(1_000_000);
    (rep.replayed, sc.sys.engine.events_processed() - ev0)
}

fn panel_b() -> (usize, u64) {
    let mut sc = spark_lineage(1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    for i in 0..N {
        sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(i));
    }
    sc.sys.advance_input(sc.src, Time::epoch(1));
    sc.sys.run_to_quiescence(1_000_000);
    sc.sys.inject_failures(&[sc.sink_proc]);
    let rep = sc.sys.recover();
    assert!(rep.plan.f[sc.src.0 as usize].is_top());
    assert!(rep.plan.f[sc.mid.0 as usize].is_top());
    let ev0 = sc.sys.engine.events_processed();
    sc.sys.run_to_quiescence(1_000_000);
    (rep.replayed, sc.sys.engine.events_processed() - ev0)
}

fn panel_c() -> (usize, u64) {
    struct Body;
    impl Processor for Body {
        fn on_message(&mut self, _p: usize, _t: Time, d: Record, ctx: &mut falkirk::engine::Ctx) {
            ctx.send(0, d.clone());
            ctx.send(1, d);
        }
    }
    let d1 = TimeDomain::Structured { depth: 1 };
    let mut g = GraphBuilder::new();
    let p = g.add_proc("p", TimeDomain::EPOCH);
    let ing = g.add_proc("ingress", d1);
    let body = g.add_proc("body", d1);
    let fb = g.add_proc("feedback", d1);
    let eg = g.add_proc("egress", TimeDomain::EPOCH);
    let y = g.add_proc("y", TimeDomain::EPOCH);
    g.connect(p, ing, Projection::LoopEnter);
    g.connect(ing, body, Projection::Identity);
    g.connect(body, fb, Projection::Identity);
    g.connect(fb, body, Projection::LoopFeedback);
    g.connect(body, eg, Projection::LoopExit);
    g.connect(eg, y, Projection::Identity);
    let out = shared_vec();
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Ingress),
        Box::new(Body),
        Box::new(Feedback::new(8)),
        Box::new(Egress),
        Box::new(Sink(out)),
    ];
    let mut sys = FtSystem::new(
        Arc::new(g.build().unwrap()),
        procs,
        vec![
            Policy::LogOutputs,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
        ],
        Delivery::Fifo,
        Store::new(1),
    );
    sys.advance_input(p, Time::epoch(0));
    for i in 0..(N / 8) {
        sys.push_input(p, Time::epoch(0), Record::Int(i));
    }
    sys.advance_input(p, Time::epoch(1));
    sys.run_to_quiescence(1_000_000);
    sys.inject_failures(&[y]);
    let rep = sys.recover();
    assert!(rep.plan.f[p.0 as usize].is_top(), "p stays (its log firewalls the loop)");
    let ev0 = sys.engine.events_processed();
    sys.run_to_quiescence(1_000_000);
    (rep.replayed, sys.engine.events_processed() - ev0)
}

fn main() {
    let mut b = Bencher::new("fig7_rollback_examples");
    b.run("a_seq_logged", N as f64, || {
        std::hint::black_box(panel_a());
    });
    b.run("b_spark_firewall", N as f64, || {
        std::hint::black_box(panel_b());
    });
    b.run("c_naiad_loop", (N / 8) as f64, || {
        std::hint::black_box(panel_c());
    });
    let (ra, wa) = panel_a();
    let (rb, wb) = panel_b();
    let (rc, wc) = panel_c();
    println!("note fig7_rollback_examples/work a: replayed={ra} requiesce={wa} | b: replayed={rb} requiesce={wb} | c: replayed={rc} requiesce={wc}");
    println!("note fig7_rollback_examples/shape (a) failed proc replays log, others keep state; (b) firewall confines redo to the failed stage; (c) loop restarts from the logged entry");
}
