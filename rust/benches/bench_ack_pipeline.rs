//! Asynchronous-persistence bench: Eager-policy records/sec with the FT
//! write path on vs. off the compute hot path.
//!
//! The workload is the worst case for synchronous persistence — an
//! `Eager` processor checkpoints (state + Ξ) after *every* event and the
//! source logs every input, so each record costs several acknowledged
//! writes. Variants compare [`PersistMode::Sync`] against the staged
//! writer pipeline across group-commit widths `ack_every ∈ {1, 8, 64}`
//! and WAL flush widths `flush_every_n ∈ {1, 64}`, on both the in-memory
//! and the file (WAL) backend, and report the peak ack-lag each async
//! run accumulated.
//!
//! Expected shape: on the file backend, async with wide `ack_every`
//! approaches the in-memory rate (the compute loop no longer waits on
//! the WAL), while sync pays the full write path per event; `ack_every=1`
//! shows pure pipelining with no group-commit amortization. The output
//! is provably identical across variants (the equivalence grids in
//! `test_parallel.rs` / `test_sharded_recovery.rs` pin that down).

use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::engine::{Delivery, Processor, Record};
use falkirk::ft::{FileBackendOptions, FtSystem, PersistMode, Policy, Store};
use falkirk::graph::{GraphBuilder, Projection};
use falkirk::operators::{shared_vec, Sink, Source, SumByTime};
use falkirk::time::{Time, TimeDomain};
use falkirk::util::tmp::TempDir;
use std::sync::Arc;

const EPOCHS: u64 = 8;
const RECORDS_PER_EPOCH: usize = 64;

/// src (LogOutputs) → sum (Eager) → sink: every record is one delivered
/// event at `sum`, hence one state+Ξ checkpoint pair plus a log entry.
fn build(store: Store) -> (FtSystem, falkirk::graph::ProcId) {
    let mut g = GraphBuilder::new();
    let src = g.add_proc("src", TimeDomain::EPOCH);
    let sum = g.add_proc("sum", TimeDomain::EPOCH);
    let snk = g.add_proc("sink", TimeDomain::EPOCH);
    g.connect(src, sum, Projection::Identity);
    g.connect(sum, snk, Projection::Identity);
    let topo = Arc::new(g.build().unwrap());
    let out = shared_vec();
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(SumByTime::default()),
        Box::new(Sink(out)),
    ];
    let policies = vec![Policy::LogOutputs, Policy::Eager, Policy::Ephemeral];
    let sys = FtSystem::new(topo, procs, policies, Delivery::Fifo, store);
    (sys, src)
}

/// Drive the workload end to end; returns the peak ack-lag observed.
fn drive(store: Store) -> u64 {
    let (mut sys, src) = build(store);
    for ep in 0..EPOCHS {
        sys.advance_input(src, Time::epoch(ep));
        for i in 0..RECORDS_PER_EPOCH {
            sys.push_input(src, Time::epoch(ep), Record::Int(i as i64));
        }
        sys.advance_input(src, Time::epoch(ep + 1));
        sys.run_to_quiescence(5_000_000);
    }
    sys.close_input(src);
    sys.run_to_quiescence(5_000_000);
    // The run is only "done" once its writes are durable: the flush is
    // part of the measured work, so async variants cannot win by simply
    // leaving the queue full.
    sys.store.flush_staged();
    assert!(sys.stats.checkpoints_taken > 0);
    sys.stats.ack_lag
}

fn file_store(dir: &std::path::Path, flush_every_n: usize, mode: PersistMode) -> Store {
    let s = Store::open_dir(
        dir,
        0,
        FileBackendOptions { flush_every_n, ..Default::default() },
    )
    .unwrap();
    s.set_persist_mode(mode);
    s
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 1, sample_iters: 5 };
    let mut b = Bencher::with_config("ack_pipeline", cfg);
    let records = (EPOCHS * RECORDS_PER_EPOCH as u64) as f64;

    // In-memory backend: isolates the pipeline overhead itself.
    b.run("eager_records/mem_sync", records, || {
        drive(Store::new(0));
    });
    b.run("eager_records/mem_async_ack8", records, || {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 8 });
        drive(s);
    });

    // File (WAL) backend: the case the pipeline exists for.
    for flush in [1usize, 64] {
        b.run(&format!("eager_records/file_sync_flush{flush}"), records, || {
            let t = TempDir::new("bench-ack-sync");
            drive(file_store(t.path(), flush, PersistMode::Sync));
        });
        for ack_every in [1usize, 8, 64] {
            let mut peak_lag = 0u64;
            b.run(
                &format!("eager_records/file_async_ack{ack_every}_flush{flush}"),
                records,
                || {
                    let t = TempDir::new("bench-ack-async");
                    let lag =
                        drive(file_store(t.path(), flush, PersistMode::Async { ack_every }));
                    peak_lag = peak_lag.max(lag);
                },
            );
            b.note(&format!(
                "peak ack-lag at ack_every={ack_every} flush={flush}: {peak_lag} staged ops"
            ));
        }
    }

    b.note("expected: file_async_ack64 ≫ file_sync_flush1, approaching mem rates");
}
