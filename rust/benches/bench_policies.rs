//! E7: the policy-tradeoff table the paper implies but never measured —
//! steady-state overhead and recovery cost for every §2 scheme plus the
//! paper's lazy regime at several checkpoint intervals, on the same
//! logical workload.
//!
//! Expected shape (§2's qualitative claims):
//! - eager/exactly-once: highest storage traffic, minimal re-execution;
//! - ephemeral/at-least-once: zero overhead, maximal re-execution;
//! - lazy(k): overhead ∝ 1/k, re-execution ∝ k — the tunable middle;
//! - Chandy–Lamport: snapshot cost scales with *global* state, recovery
//!   rolls back everyone.

use falkirk::baselines::{
    at_least_once, chandy_lamport::ClSystem, exactly_once, falkirk_lazy, spark_lineage, Scenario,
};
use falkirk::bench_support::Bencher;
use falkirk::engine::Record;
use falkirk::time::Time;

const EPOCHS: u64 = 10;
const PER_EPOCH: i64 = 100;

/// Steady-state drive (no failure): returns virtual storage latency as
/// the overhead proxy.
fn steady(mut sc: Scenario) -> u64 {
    for ep in 0..EPOCHS {
        let t = Time::epoch(ep);
        sc.sys.advance_input(sc.src, t);
        for i in 0..PER_EPOCH {
            sc.sys.push_input(sc.src, t, Record::Int(i));
        }
        sc.sys.advance_input(sc.src, Time::epoch(ep + 1));
        sc.sys.run_to_quiescence(1_000_000);
    }
    sc.sys.close_input(sc.src);
    sc.sys.run_to_quiescence(1_000_000);
    sc.sys.store.stats().virtual_latency
}

/// Failure after `EPOCHS` epochs: returns (recovery wall µs, re-execution
/// events).
fn recovery(mut sc: Scenario) -> (f64, u64) {
    let mut offered: Vec<(Time, Vec<Record>)> = Vec::new();
    for ep in 0..EPOCHS {
        let t = Time::epoch(ep);
        let batch: Vec<Record> = (0..PER_EPOCH).map(Record::Int).collect();
        offered.push((t, batch.clone()));
        sc.sys.advance_input(sc.src, t);
        for r in batch {
            sc.sys.push_input(sc.src, t, r);
        }
        sc.sys.advance_input(sc.src, Time::epoch(ep + 1));
        sc.sys.run_to_quiescence(1_000_000);
    }
    sc.sys.inject_failures(&[sc.mid]);
    let t0 = std::time::Instant::now();
    let rep = sc.sys.recover();
    let wall = t0.elapsed().as_nanos() as f64 / 1e3;
    // Client retry for whatever the source lost.
    let f_src = rep.plan.f[sc.src.0 as usize].clone();
    for (t, batch) in &offered {
        if !f_src.is_top() && !f_src.contains(t) {
            sc.sys.advance_input(sc.src, *t);
            for r in batch {
                sc.sys.push_input(sc.src, *t, r.clone());
            }
        }
    }
    sc.sys.advance_input(sc.src, Time::epoch(EPOCHS));
    let ev0 = sc.sys.engine.events_processed();
    sc.sys.run_to_quiescence(10_000_000);
    (wall, sc.sys.engine.events_processed() - ev0)
}

fn main() {
    const COST: u64 = 10;
    let mut b = Bencher::new("policies");
    let events = (EPOCHS * PER_EPOCH as u64) as f64;

    b.run("steady/at_least_once", events, || {
        std::hint::black_box(steady(at_least_once(COST)));
    });
    b.run("steady/exactly_once", events, || {
        std::hint::black_box(steady(exactly_once(COST)));
    });
    b.run("steady/spark_lineage", events, || {
        std::hint::black_box(steady(spark_lineage(COST)));
    });
    for k in [1u64, 4, 16] {
        b.run(&format!("steady/lazy_k{k}"), events, || {
            std::hint::black_box(steady(falkirk_lazy(k, COST)));
        });
    }

    // Storage-overhead table (single run each).
    println!("note policies/overhead_virtual_latency_units:");
    for (name, lat) in [
        ("at_least_once", steady(at_least_once(COST))),
        ("exactly_once", steady(exactly_once(COST))),
        ("spark_lineage", steady(spark_lineage(COST))),
        ("lazy_k1", steady(falkirk_lazy(1, COST))),
        ("lazy_k4", steady(falkirk_lazy(4, COST))),
        ("lazy_k16", steady(falkirk_lazy(16, COST))),
    ] {
        println!("note policies/overhead {name} = {lat}");
    }

    // Recovery table.
    println!("note policies/recovery (wall µs, re-execution events):");
    for (name, sc) in [
        ("at_least_once", at_least_once(COST)),
        ("exactly_once", exactly_once(COST)),
        ("spark_lineage", spark_lineage(COST)),
        ("lazy_k1", falkirk_lazy(1, COST)),
        ("lazy_k4", falkirk_lazy(4, COST)),
        ("lazy_k16", falkirk_lazy(16, COST)),
    ] {
        let (wall, redo) = recovery(sc);
        println!("note policies/recovery {name} wall_us={wall:.1} redo_events={redo}");
    }

    // Chandy–Lamport global snapshot + all-roll-back recovery.
    b.run("cl/snapshot_ring32", 32.0, || {
        let mut sys = ClSystem::new(32, &ring_edges(32), 1);
        for k in 0..256 {
            sys.inject(k % 32, k as u64);
        }
        sys.initiate_snapshot(0, 1);
        sys.run_until_quiet(1_000_000);
        assert!(sys.snapshot_done());
        std::hint::black_box(sys.recorded_total());
    });
    b.run("cl/restore_ring32", 32.0, || {
        let mut sys = ClSystem::new(32, &ring_edges(32), 1);
        for k in 0..256 {
            sys.inject(k % 32, k as u64);
        }
        sys.initiate_snapshot(0, 1);
        sys.run_until_quiet(1_000_000);
        sys.restore_snapshot();
        std::hint::black_box(sys.delivered);
    });
    b.note("expected: overhead eager ≫ lazy_k1 > lazy_k16 > ephemeral=0; redo inverse; CL rolls everyone");
}

fn ring_edges(n: usize) -> Vec<(usize, usize)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}
