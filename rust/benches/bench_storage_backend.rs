//! Storage-backend bench: Mem vs File WAL across group-commit widths
//! `flush_every_n ∈ {1, 8, 64}` — acknowledged writes/sec on the put
//! path, and recovery-scan (reopen + index rebuild) time.
//!
//! Expected shape: write-through (`flush1`) pays a syscall per record;
//! wider group commit amortizes it toward (but never past) the
//! in-memory backend; the recovery scan is linear in live log bytes.
//!
//! The `large_state_*` group measures the incremental-checkpoint win at
//! scale: one million 8-byte keys (~8 MB of state), a thousand point
//! updates per epoch, checkpointed as monolithic-equivalent `Full`
//! listings vs `Delta { max_chain: 8 }` chains. Expected shape: staged
//! bytes per checkpoint scale with the touched span under `Delta`
//! (content-addressed dedup already spares unchanged *chunks* under
//! `Full`; the delta additionally shrinks the listing record), and the
//! cold reopen+materialize walks at most `max_chain` records.

use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::ft::storage::{chunk_hashes, plan_snapshot, SnapshotBase};
use falkirk::ft::{FileBackendOptions, Key, Kind, SnapshotPolicy, Store};
use falkirk::util::tmp::TempDir;

const N: u64 = 2_000;
const PROCS: u64 = 8;

const LARGE_KEYS: usize = 1_000_000;
const CELL: usize = 8;
const TOUCHED: usize = 1_000;

/// One million 8-byte cells of keyed state, deterministically filled.
fn large_state() -> Vec<u8> {
    (0..LARGE_KEYS * CELL).map(|i| (i.wrapping_mul(31) % 251) as u8).collect()
}

/// One epoch of updates: `TOUCHED` point writes scattered over the key
/// space (what a keyed operator dirties between checkpoints).
fn touch(state: &mut [u8], epoch: u64) {
    let stride = LARGE_KEYS / TOUCHED;
    for k in 0..TOUCHED {
        let key = (k * stride + epoch as usize * 7919) % LARGE_KEYS;
        let at = key * CELL;
        state[at] = state[at].wrapping_add(1).wrapping_add(epoch as u8);
    }
}

/// Plan + stage one checkpoint of `state`; returns the diff base the
/// next checkpoint chains on (what the harness tracks per processor).
fn checkpoint_large(
    s: &Store,
    state: &[u8],
    base: Option<&SnapshotBase>,
    tag: u64,
    policy: SnapshotPolicy,
) -> SnapshotBase {
    let snap = plan_snapshot(state, base, policy);
    let walk = match snap.prior_snapshot {
        Some(_) => base.expect("a delta always has a base").walk_len + 1,
        None => 1,
    };
    s.stage_put_snapshot(0, tag, &snap, state).expect("checkpoint within limits");
    SnapshotBase { tag, hashes: chunk_hashes(state), walk_len: walk }
}

fn fill(s: &Store, blob: &[u8]) {
    for tag in 0..N {
        s.put_log(
            Key { proc: (tag % PROCS) as u32, kind: Kind::LogEntry, tag },
            blob.to_vec(),
            1,
        );
    }
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 1, sample_iters: 5 };
    let mut b = Bencher::with_config("storage_backend", cfg);
    let blob = vec![7u8; 128];

    b.run("acked_writes/mem", N as f64, || {
        let s = Store::new(0);
        fill(&s, &blob);
        assert_eq!(s.stats().writes, N);
    });

    for flush in [1usize, 8, 64] {
        b.run(&format!("acked_writes/file_flush{flush}"), N as f64, || {
            let t = TempDir::new("bench-wal");
            let s = Store::open_dir(
                t.path(),
                0,
                FileBackendOptions { flush_every_n: flush, ..Default::default() },
            )
            .unwrap();
            fill(&s, &blob);
            s.sync();
        });
    }

    // Recovery scan: a prebuilt directory, reopened per iteration (what
    // a cold restart pays before any replay begins).
    let t = TempDir::new("bench-wal-scan");
    {
        let s = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions { flush_every_n: 64, ..Default::default() },
        )
        .unwrap();
        fill(&s, &blob);
    }
    b.run("recovery_scan/file", N as f64, || {
        let s = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
        assert_eq!(s.backend_info().live_keys, N);
    });

    // GC + compaction: delete most keys, forcing tombstones and segment
    // rewrites, on a small-segment store.
    b.run("gc_compact/file", N as f64, || {
        let t = TempDir::new("bench-wal-gc");
        let s = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions {
                flush_every_n: 8,
                segment_bytes: 16 << 10,
                compact_ratio: 0.5,
                fsync: false,
            },
        )
        .unwrap();
        fill(&s, &blob);
        for proc in 0..PROCS {
            s.delete_matching(proc as u32, |k| k.tag < (N * 3 / 4));
        }
        assert!(s.backend_info().compactions > 0);
    });

    // Incremental checkpoints at large state: Full vs Delta{8} on the
    // same million-key workload — staged bytes per checkpoint, then the
    // cold reopen + chain materialization a restart pays.
    for (name, policy) in
        [("full", SnapshotPolicy::Full), ("delta8", SnapshotPolicy::Delta { max_chain: 8 })]
    {
        let t = TempDir::new("bench-wal-snap");
        let mut state = large_state();
        let mut base: Option<SnapshotBase> = None;
        let (mut tag, mut epoch) = (0u64, 0u64);
        let s = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions { flush_every_n: 64, fsync: false, ..Default::default() },
        )
        .unwrap();
        b.run(&format!("large_state_checkpoint/{name}"), LARGE_KEYS as f64, || {
            touch(&mut state, epoch);
            epoch += 1;
            tag += 1;
            base = Some(checkpoint_large(&s, &state, base.as_ref(), tag, policy));
        });
        let total_bytes = s.stats().bytes_written;
        let (newest_tag, checkpoints) = (tag, epoch);
        drop(s); // graceful: the buffered WAL tail flushes
        b.run(&format!("large_state_reopen/{name}"), LARGE_KEYS as f64, || {
            let s = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
            let got = s.materialize_snapshot(0, newest_tag).expect("newest chain materializes");
            assert_eq!(got.len(), LARGE_KEYS * CELL);
        });
        b.note(&format!(
            "large_state/{name}: {checkpoints} checkpoints of {} bytes staged {total_bytes} \
             durable bytes ({} per checkpoint)",
            LARGE_KEYS * CELL,
            total_bytes / checkpoints.max(1)
        ));
    }

    b.note("expected: file_flush1 ≪ file_flush64 ≤ mem on acked writes/sec");
    b.note("expected: delta8 stages ~TOUCHED chunks/checkpoint ≪ full's listing");
}
