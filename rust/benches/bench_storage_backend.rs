//! Storage-backend bench: Mem vs File WAL across group-commit widths
//! `flush_every_n ∈ {1, 8, 64}` — acknowledged writes/sec on the put
//! path, and recovery-scan (reopen + index rebuild) time.
//!
//! Expected shape: write-through (`flush1`) pays a syscall per record;
//! wider group commit amortizes it toward (but never past) the
//! in-memory backend; the recovery scan is linear in live log bytes.

use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::ft::{FileBackendOptions, Key, Kind, Store};
use falkirk::util::tmp::TempDir;

const N: u64 = 2_000;
const PROCS: u64 = 8;

fn fill(s: &Store, blob: &[u8]) {
    for tag in 0..N {
        s.put_log(
            Key { proc: (tag % PROCS) as u32, kind: Kind::LogEntry, tag },
            blob.to_vec(),
            1,
        );
    }
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 1, sample_iters: 5 };
    let mut b = Bencher::with_config("storage_backend", cfg);
    let blob = vec![7u8; 128];

    b.run("acked_writes/mem", N as f64, || {
        let s = Store::new(0);
        fill(&s, &blob);
        assert_eq!(s.stats().writes, N);
    });

    for flush in [1usize, 8, 64] {
        b.run(&format!("acked_writes/file_flush{flush}"), N as f64, || {
            let t = TempDir::new("bench-wal");
            let s = Store::open_dir(
                t.path(),
                0,
                FileBackendOptions { flush_every_n: flush, ..Default::default() },
            )
            .unwrap();
            fill(&s, &blob);
            s.sync();
        });
    }

    // Recovery scan: a prebuilt directory, reopened per iteration (what
    // a cold restart pays before any replay begins).
    let t = TempDir::new("bench-wal-scan");
    {
        let s = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions { flush_every_n: 64, ..Default::default() },
        )
        .unwrap();
        fill(&s, &blob);
    }
    b.run("recovery_scan/file", N as f64, || {
        let s = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
        assert_eq!(s.backend_info().live_keys, N);
    });

    // GC + compaction: delete most keys, forcing tombstones and segment
    // rewrites, on a small-segment store.
    b.run("gc_compact/file", N as f64, || {
        let t = TempDir::new("bench-wal-gc");
        let s = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions {
                flush_every_n: 8,
                segment_bytes: 16 << 10,
                compact_ratio: 0.5,
                fsync: false,
            },
        )
        .unwrap();
        fill(&s, &blob);
        for proc in 0..PROCS {
            s.delete_matching(proc as u32, |k| k.tag < (N * 3 / 4));
        }
        assert!(s.backend_info().compactions > 0);
    });

    b.note("expected: file_flush1 ≪ file_flush64 ≤ mem on acked writes/sec");
}
