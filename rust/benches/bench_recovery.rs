//! Recovery-path bench: §4.4 rollback/replay latency and cold-reopen
//! wall time, sequential vs. decomposed on the worker pool.
//!
//! Two groups of rows over the grid W ∈ {4, 8} × T ∈ {1, 4} ×
//! snapshot ∈ {Full, Delta{8}}:
//!
//! - `recover_…`: the two-stage sharded job is driven through `EPOCHS`
//!   closed epochs plus one *open* in-flight epoch (pushed, never
//!   closed), so the source log holds an undelivered suffix. Each
//!   iteration injects a two-shard failure (count#0 and count#2 —
//!   distinct shard groups at T = 4, so the parallel path genuinely
//!   restores and replays on ≥ 2 workers), runs `FtSystem::recover`
//!   (T = 1) or `FtSystem::recover_parallel` (T = 4), and drains back to
//!   quiescence. Rollback returns the victims to their newest checkpoint
//!   and replay re-sends the open epoch's logged records into their key
//!   ranges, so the cycle is a fixed point: every iteration performs the
//!   identical failure→recovered-quiescence cycle, and ops/s is
//!   recoveries/sec. The T4/T1 ratio is the parallel-recovery speedup.
//! - `reopen_…`: the same job is driven against a durable WAL directory
//!   and dropped mid-flight (buffered tail discarded via
//!   `simulate_crash`); each iteration cold-restarts from the directory
//!   (`FtSystem::reopen_sharded_parallel` via
//!   `bench_support::sharded::reopen_pipeline`), which scans every
//!   per-proc key range, materializes snapshot chains (delta rows walk
//!   `prior_snapshot` links), and runs the everyone-crashed recovery —
//!   fanned across T workers. The first reopen deletes whatever orphans
//!   the crash left, so warmup absorbs it and sampled iterations reopen
//!   a stable store.
//!
//! The sequential and parallel paths are byte-identical in output (the
//! `test_sharded_recovery` grids pin that); this bench prices them.

use falkirk::bench_support::sharded::{
    drive_epoch, epoch_records, pipeline_with_store, reopen_pipeline, ShardedConfig,
};
use falkirk::bench_support::{BenchConfig, Bencher};
use falkirk::ft::{FileBackendOptions, SnapshotPolicy, Store};
use falkirk::time::Time;
use falkirk::util::tmp::TempDir;

const EPOCHS: u64 = 4;
const RECORDS: usize = 256;
const KEYS: u64 = 64;
const FAIL_SHARDS: [usize; 2] = [0, 2];

fn cfg(workers: u32, threads: usize, snapshot: SnapshotPolicy) -> ShardedConfig {
    ShardedConfig {
        workers,
        two_stage: true,
        threads,
        snapshot_policy: snapshot,
        ..Default::default()
    }
}

fn snap_tag(s: SnapshotPolicy) -> &'static str {
    match s {
        SnapshotPolicy::Full => "full",
        SnapshotPolicy::Delta { .. } => "delta8",
    }
}

fn main() {
    let mut b = Bencher::with_config(
        "recovery",
        BenchConfig { warmup_iters: 1, sample_iters: 5 },
    );

    let grid = [SnapshotPolicy::Full, SnapshotPolicy::Delta { max_chain: 8 }];
    for snapshot in grid {
        for workers in [4u32, 8] {
            for threads in [1usize, 4] {
                let c = cfg(workers, threads, snapshot);

                // ---- recovery latency: prepared state with an open
                // in-flight epoch; per-iteration recovery cycle.
                let mut p = pipeline_with_store(&c, Store::new(c.write_cost));
                for ep in 0..EPOCHS {
                    drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
                }
                let src = p.src_proc();
                p.sys.advance_input(src, Time::epoch(EPOCHS));
                for r in epoch_records(7, EPOCHS, RECORDS, KEYS) {
                    p.sys.push_input(src, Time::epoch(EPOCHS), r);
                }
                p.run(10_000_000);
                let victims: Vec<_> =
                    FAIL_SHARDS.iter().map(|&s| p.plan.proc(p.count, s)).collect();
                let name =
                    format!("recover_W{workers}_T{threads}_{}", snap_tag(snapshot));
                b.run(&name, 1.0, || {
                    p.sys.inject_failures(&victims);
                    let rep = if p.threads > 1 {
                        p.sys.recover_parallel(&p.groups, p.threads)
                    } else {
                        p.sys.recover()
                    };
                    assert_eq!(
                        rep.plan.rolled_back().len(),
                        victims.len(),
                        "exactly the two failed shards roll back"
                    );
                    assert!(rep.replayed > 0, "the open epoch's suffix replays");
                    p.run(10_000_000);
                });
                if threads > 1 {
                    assert!(
                        p.sys.stats.recovery_parallelism >= 2,
                        "parallel recovery must restore on >= 2 workers"
                    );
                    assert!(
                        p.sys.stats.replay_workers >= 1,
                        "parallel recovery must replay on >= 1 worker"
                    );
                }
                drop(p);

                // ---- cold-reopen wall: drive a durable run, crash the
                // process, reopen per iteration.
                let dir = TempDir::new("bench-recovery");
                let store = Store::open_dir(
                    dir.path(),
                    c.write_cost,
                    FileBackendOptions::default(),
                )
                .expect("opening WAL store");
                let mut p = pipeline_with_store(&c, store.clone());
                for ep in 0..EPOCHS {
                    drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
                }
                drop(p);
                store.simulate_crash();
                drop(store);
                let name =
                    format!("reopen_W{workers}_T{threads}_{}", snap_tag(snapshot));
                b.run(&name, 1.0, || {
                    let store = Store::open_dir(
                        dir.path(),
                        c.write_cost,
                        FileBackendOptions::default(),
                    )
                    .expect("reopening WAL store");
                    let (p, rep) = reopen_pipeline(&c, store);
                    assert!(
                        rep.restored_from_checkpoint + rep.reset_to_empty > 0,
                        "cold reopen recovers every processor"
                    );
                    drop(p);
                });
            }
        }
    }

    b.note(
        "recover_*: ops/s = complete failure->recovered-quiescence cycles/sec \
         (count#0 and count#2 fail — distinct shard groups at T=4); speedup = \
         recover_W8_T4_* over recover_W8_T1_*",
    );
    b.note(
        "reopen_*: ops/s = cold restarts/sec from the same durable WAL \
         (per-proc key scans + chain materialization + everyone-crashed \
         recovery, fanned across T workers at T > 1)",
    );
    b.note(
        "delta8 rows materialize checkpoint chains by prior_snapshot walk; \
         compare against their full twins for the delta read amplification",
    );
}
