//! Integration test for the paper's Figure 3: selective rollback on the
//! Select → Sum → Buffer fragment with interleaved logical times.
//!
//! Reproduces the figure's timeline: messages at times A and B are
//! interleaved; each processor checkpoints selectively after the last
//! time-A message (a state it may never have actually been in); a
//! rollback then restores "all A, no B", and re-execution of the B
//! messages returns the system to its pre-rollback state.

use falkirk::engine::{Delivery, Processor, Record};
use falkirk::frontier::Frontier;
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{GraphBuilder, ProcId, Projection};
use falkirk::operators::{Buffer, Select, Source, SumByTime};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

fn build() -> FtSystem {
    let mut g = GraphBuilder::new();
    let src = g.add_proc("src", TimeDomain::EPOCH);
    let sel = g.add_proc("select", TimeDomain::EPOCH);
    let sum = g.add_proc("sum", TimeDomain::EPOCH);
    let buf = g.add_proc("buffer", TimeDomain::EPOCH);
    g.connect(src, sel, Projection::Identity);
    g.connect(sel, sum, Projection::Identity);
    g.connect(sum, buf, Projection::Identity);
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Select),
        Box::new(SumByTime::default()),
        Box::new(Buffer::default()),
    ];
    FtSystem::new(
        Arc::new(g.build().unwrap()),
        procs,
        vec![
            Policy::LogOutputs,
            Policy::Ephemeral,
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::Lazy { every: 1, log_outputs: false },
        ],
        Delivery::Selective,
        Store::new(1),
    )
}

fn buffer_contents(sys: &FtSystem) -> Vec<(Time, Vec<Record>)> {
    let buf = sys.topology().find("buffer").unwrap();
    let blob = sys.engine.proc(buf).checkpoint_upto(&Frontier::Top);
    let mut b = Buffer::default();
    b.restore(&blob);
    b.contents()
}

/// The figure's words: "two" then "three" at time A; "one" at time B,
/// interleaved between them.
fn drive(sys: &mut FtSystem) {
    let src = ProcId(0);
    let (a, b) = (Time::epoch(0), Time::epoch(1));
    sys.advance_input(src, a);
    sys.push_input(src, a, Record::text("two"));
    sys.push_input(src, b, Record::text("one")); // B interleaved!
    sys.push_input(src, a, Record::text("three"));
    // A completes (the dashed line in the figure); B stays open.
    sys.advance_input(src, b);
    sys.run_to_quiescence(100_000);
}

#[test]
fn sum_emits_and_discards_on_completion() {
    let mut sys = build();
    drive(&mut sys);
    // Sum emitted 2+3=5 for time A and discarded A's state; B=1 still held.
    let contents = buffer_contents(&sys);
    assert_eq!(contents, vec![(Time::epoch(0), vec![Record::kv(0, 5.0)])]);
    let sum = sys.topology().find("sum").unwrap();
    // Selective checkpoint at ↓A is EMPTY (state for A was discarded after
    // the notification) — the paper's headline point.
    let ck = sys.engine.proc(sum).checkpoint_upto(&Frontier::upto_epoch(0));
    let mut empty_probe = SumByTime::default();
    empty_probe.restore(&ck);
    assert!(ck.len() <= 1, "selective checkpoint after A completes is empty");
    // But the full current state holds B.
    let full = sys.engine.proc(sum).checkpoint_upto(&Frontier::Top);
    assert!(full.len() > ck.len());
}

#[test]
fn selective_rollback_restores_all_a_no_b() {
    let mut sys = build();
    drive(&mut sys);
    let sum = sys.topology().find("sum").unwrap();
    // Crash Sum while B is open.
    sys.inject_failures(&[sum]);
    let rep = sys.recover();
    assert_eq!(
        rep.plan.f[sum.0 as usize],
        Frontier::upto_epoch(0),
        "sum restored to 'all A, no B'"
    );
    // B's message is replayed from the logs and the system reconverges.
    sys.close_input(ProcId(0));
    sys.run_to_quiescence(100_000);
    let contents = buffer_contents(&sys);
    assert_eq!(
        contents,
        vec![
            (Time::epoch(0), vec![Record::kv(0, 5.0)]),
            (Time::epoch(1), vec![Record::kv(0, 1.0)]),
        ],
        "after re-execution the state returns to that before the rollback"
    );
}

#[test]
fn selective_equals_failure_free_under_interleaving() {
    // Equivalence under failure at each point of the interleaved run.
    let clean = {
        let mut sys = build();
        drive(&mut sys);
        sys.close_input(ProcId(0));
        sys.run_to_quiescence(100_000);
        buffer_contents(&sys)
    };
    for victim in ["select", "sum", "buffer"] {
        let mut sys = build();
        drive(&mut sys);
        let v = sys.topology().find(victim).unwrap();
        sys.inject_failures(&[v]);
        sys.recover();
        sys.close_input(ProcId(0));
        sys.run_to_quiescence(100_000);
        assert_eq!(buffer_contents(&sys), clean, "victim {victim} diverged");
    }
}
