//! Property tests (in-repo harness — `proptest` is unavailable offline):
//! frontier algebra laws, the §3.3 re-ordering rule, solver-output
//! validity on random graphs (plain and sharded), and the monotonicity
//! claims of §3.6/§4.2.

use falkirk::bench_support::sharded::{drive_epoch, pipeline, ShardedConfig};
use falkirk::engine::channel::{Batch, Channel, Delivery, Message};
use falkirk::engine::Record;
use falkirk::ft::Policy;
use falkirk::frontier::Frontier;
use falkirk::ft::meta::CkptMeta;
use falkirk::ft::rollback::{
    choose_frontiers, grow_frontiers, verify_plan, Available, RollbackInput,
};
use falkirk::graph::{EdgeId, GraphBuilder, ProcId, Projection, Topology};
use falkirk::prop_assert;
use falkirk::time::{Time, TimeDomain};
use falkirk::util::prop::{check, check_with, Config};
use falkirk::util::rng::Rng;

fn arb_time(rng: &mut Rng, depth: usize) -> Time {
    let epoch = rng.below(6);
    let cs: Vec<u64> = (0..depth).map(|_| rng.below(5)).collect();
    Time::structured(epoch, &cs)
}

fn arb_frontier(rng: &mut Rng, depth: usize) -> Frontier {
    match rng.below(10) {
        0 => Frontier::Bottom,
        1 => Frontier::Top,
        _ => {
            let k = 1 + rng.index(3);
            Frontier::down_close((0..k).map(|_| arb_time(rng, depth)))
        }
    }
}

#[test]
fn frontier_downward_closure() {
    check("frontiers are downward-closed", |rng| {
        let f = arb_frontier(rng, 1);
        for _ in 0..20 {
            let t = arb_time(rng, 1);
            if f.contains(&t) {
                // every t' ≤ t also ∈ f
                let smaller = Time::structured(
                    t.epoch_of().saturating_sub(rng.below(2)),
                    &[t.loops_of().as_slice()[0].saturating_sub(rng.below(2))],
                );
                prop_assert!(
                    f.contains(&smaller),
                    "t={t} ∈ {f} but smaller {smaller} missing"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn frontier_lattice_laws() {
    check("union/intersect are lattice ops", |rng| {
        let a = arb_frontier(rng, 1);
        let b = arb_frontier(rng, 1);
        let u = a.union(&b);
        let i = a.intersect(&b);
        prop_assert!(a.is_subset(&u) && b.is_subset(&u), "a,b ⊆ a∪b");
        prop_assert!(i.is_subset(&a) && i.is_subset(&b), "a∩b ⊆ a,b");
        // Membership agrees pointwise.
        for _ in 0..20 {
            let t = arb_time(rng, 1);
            prop_assert!(
                u.contains(&t) == (a.contains(&t) || b.contains(&t)),
                "union membership mismatch at {t}: {a} ∪ {b}"
            );
            prop_assert!(
                i.contains(&t) == (a.contains(&t) && b.contains(&t)),
                "intersect membership mismatch at {t}"
            );
        }
        // Idempotence / absorption.
        prop_assert!(a.union(&a) == a && a.intersect(&a) == a);
        prop_assert!(a.union(&i) == a, "absorption a ∪ (a∩b) = a");
        Ok(())
    });
}

#[test]
fn frontier_subset_antisymmetry_and_encode() {
    use falkirk::util::ser::{Decode, Encode};
    check("subset antisymmetry + codec roundtrip", |rng| {
        let a = arb_frontier(rng, 1);
        let b = arb_frontier(rng, 1);
        if a.is_subset(&b) && b.is_subset(&a) {
            prop_assert!(a == b, "mutual subset ⇒ equal: {a} vs {b}");
        }
        let bytes = a.to_bytes();
        prop_assert!(Frontier::from_bytes(&bytes).unwrap() == a);
        Ok(())
    });
}

/// Reference model of the pre-index channel (a plain `Vec` with the old
/// tail-coalescing push and the old linear-scan selective pop) — the
/// indexed channel must stay *order-equivalent* to it: same queue
/// contents after every push, same batch popped by every selective pop.
struct ModelChannel {
    q: Vec<Batch>,
    cap: usize,
}

impl ModelChannel {
    fn new(cap: usize) -> ModelChannel {
        ModelChannel { q: Vec::new(), cap: cap.max(1) }
    }

    fn push_batch(&mut self, b: Batch) {
        if b.is_empty() {
            return;
        }
        let time = b.time;
        // The model deep-copies freely — it is the *behavioral* reference
        // (queue shapes and pop order), not the allocation reference.
        let mut data = b.into_records();
        if let Some(tail) = self.q.last_mut() {
            if tail.time == time && tail.len() < self.cap {
                let take = (self.cap - tail.len()).min(data.len());
                let mut merged = tail.records().to_vec();
                merged.extend(data.drain(..take));
                *tail = Batch::new(time, merged);
            }
        }
        while !data.is_empty() {
            let take = self.cap.min(data.len());
            let chunk: Vec<Record> = data.drain(..take).collect();
            self.q.push(Batch::new(time, chunk));
        }
    }

    /// The old O(n) scan: earliest batch with lex-minimal time.
    fn pop_selective(&mut self) -> Option<Batch> {
        use falkirk::time::LexTime;
        if self.q.is_empty() {
            return None;
        }
        let mut best = 0usize;
        for i in 1..self.q.len() {
            if LexTime(self.q[i].time) < LexTime(self.q[best].time) {
                best = i;
            }
        }
        Some(self.q.remove(best))
    }
}

/// §3.3 re-ordering rule on a channel, checked per pop: the popped batch
/// must have no earlier queued batch whose time is ≤ its time. Runs for
/// `cap = 1` (singleton batches, the pre-batching channel) and for
/// coalescing caps, where random insertion orders produce mixed
/// singleton/coalesced queues. Also checks that coalescing loses no
/// records and never grows a batch past the cap, and that the indexed
/// O(log n) implementation is order-equivalent to the old linear-scan
/// one ([`ModelChannel`]) push for push, pop for pop.
fn check_selective_reordering(cap: usize) {
    check(&format!("§3.3 re-ordering rule (cap {cap})"), |rng| {
        let mut ch = Channel::with_cap(cap);
        let mut model = ModelChannel::new(cap);
        let n = 1 + rng.index(30);
        let mut pushed = 0usize;
        for i in 0..n {
            // Mix singleton pushes with multi-record batch pushes.
            if rng.chance(0.3) {
                let k = 1 + rng.index(4);
                let t = arb_time(rng, 0);
                // Values disjoint from the singleton pushes (which use
                // 0..n), so batch equality below is unambiguous.
                let data: Vec<Record> =
                    (0..k).map(|j| Record::Int((1000 + i * 10 + j) as i64)).collect();
                ch.push_batch(Batch::new(t, data.clone()));
                model.push_batch(Batch::new(t, data));
                pushed += k;
            } else {
                let m = Message::new(arb_time(rng, 0), Record::Int(i as i64));
                ch.push(m.clone());
                model.push_batch(Batch::from(m));
                pushed += 1;
            }
            let got: Vec<Batch> = ch.iter().cloned().collect();
            prop_assert!(
                got == model.q,
                "queue diverged from the reference model after push {i} (cap {cap})"
            );
        }
        prop_assert!(ch.len() == pushed, "coalescing lost records: {} != {pushed}", ch.len());
        prop_assert!(
            ch.iter().all(|b| b.len() <= cap && !b.is_empty()),
            "a queued batch exceeds cap {cap} (or is empty)"
        );
        let mut popped = 0usize;
        while !ch.is_empty() {
            let before: Vec<Batch> = ch.iter().cloned().collect();
            let b = ch.pop(Delivery::Selective).unwrap();
            let m = model.pop_selective().unwrap();
            prop_assert!(
                b == m,
                "selective pop diverged from the old linear scan: {} vs {} (cap {cap})",
                b.time,
                m.time
            );
            popped += b.len();
            let idx = before.iter().position(|x| x == &b).unwrap();
            for bj in &before[..idx] {
                prop_assert!(
                    !bj.time.le(&b.time),
                    "earlier queued {} ≤ popped {} (cap {cap})",
                    bj.time,
                    b.time
                );
            }
        }
        prop_assert!(popped == pushed, "popped {popped} of {pushed} records");
        Ok(())
    });
}

#[test]
fn selective_pop_respects_reordering_rule() {
    check_selective_reordering(1);
}

#[test]
fn selective_pop_respects_reordering_rule_coalesced() {
    for cap in [2usize, 8, 64] {
        check_selective_reordering(cap);
    }
}

/// Random epoch DAG + availability for the solver properties.
fn random_solver_case(
    rng: &mut Rng,
    n: usize,
) -> (Topology, Vec<Available>, Vec<(Vec<EdgeId>, Vec<EdgeId>)>) {
    let mut g = GraphBuilder::new();
    let procs: Vec<_> =
        (0..n).map(|i| g.add_proc(&format!("p{i}"), TimeDomain::EPOCH)).collect();
    let mut io: Vec<(Vec<EdgeId>, Vec<EdgeId>)> = vec![(Vec::new(), Vec::new()); n];
    for i in 1..n {
        for _ in 0..=rng.below(2) {
            let j = rng.index(i);
            let e = g.connect(procs[j], procs[i], Projection::Identity);
            io[j].1.push(e);
            io[i].0.push(e);
        }
    }
    let topo = g.build().unwrap();
    let mk = |e: u64, ins: &[EdgeId], outs: &[EdgeId], logs: bool| CkptMeta {
        f: Frontier::upto_epoch(e),
        n_bar: Frontier::upto_epoch(e),
        m_bar: ins.iter().map(|d| (*d, Frontier::upto_epoch(e))).collect(),
        d_bar: outs
            .iter()
            .map(|o| (*o, if logs { Frontier::Bottom } else { Frontier::upto_epoch(e) }))
            .collect(),
        phi: outs.iter().map(|o| (*o, Frontier::upto_epoch(e))).collect(),
    };
    let avail: Vec<Available> = (0..n)
        .map(|i| match rng.below(5) {
            0 => Available::chain(vec![]),
            1 => Available::any(rng.chance(0.5)),
            _ => {
                let logs = rng.chance(0.5);
                let base = rng.below(4);
                let depth = 1 + rng.below(3);
                Available::chain(
                    (0..depth).map(|k| mk(base + k, &io[i].0, &io[i].1, logs)).collect(),
                )
            }
        })
        .collect();
    (topo, avail, io)
}

#[test]
fn solver_output_always_satisfies_constraints() {
    check_with(Config { cases: 60, base_seed: 0xF16 }, "Fig-6 output valid", |rng| {
        let n = 3 + rng.index(25);
        let (topo, avail, _) = random_solver_case(rng, n);
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        verify_plan(&input, &plan).map_err(|e| format!("n={n}: {e}"))
    });
}

#[test]
fn adding_checkpoints_never_shrinks_solution() {
    // §3.6: "adding choices of f to F*(p) will never cause f(p') to get
    // smaller for any p'".
    check_with(Config { cases: 40, base_seed: 0xACE }, "monotone in F*", |rng| {
        let n = 3 + rng.index(15);
        let (topo, mut avail, io) = random_solver_case(rng, n);
        let plan_before = {
            let input = RollbackInput { topo: &topo, avail: &avail };
            choose_frontiers(&input)
        };
        // Extend one random chain.
        let victim = rng.index(n);
        if let Available::Chain { chain, .. } = &mut avail[victim] {
            let top =
                chain.last().map(|c| c.f.max_epoch().unwrap_or(0)).unwrap_or(0);
            let e = top + 1 + rng.below(2);
            let f = Frontier::upto_epoch(e);
            chain.push(CkptMeta {
                f: f.clone(),
                n_bar: f.clone(),
                m_bar: io[victim].0.iter().map(|d| (*d, f.clone())).collect(),
                d_bar: io[victim].1.iter().map(|o| (*o, f.clone())).collect(),
                phi: io[victim].1.iter().map(|o| (*o, f.clone())).collect(),
            });
        } else {
            return Ok(()); // nothing to extend
        }
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan_after = choose_frontiers(&input);
        for p in 0..n {
            prop_assert!(
                plan_before.f[p].is_subset(&plan_after.f[p]),
                "f(p{p}) shrank: {} → {}",
                plan_before.f[p],
                plan_after.f[p]
            );
        }
        Ok(())
    });
}

#[test]
fn incremental_growth_equals_batch() {
    check_with(Config { cases: 40, base_seed: 0x9C }, "grow == batch", |rng| {
        let n = 3 + rng.index(15);
        let (topo, mut avail, io) = random_solver_case(rng, n);
        let mut plan = {
            let input = RollbackInput { topo: &topo, avail: &avail };
            choose_frontiers(&input)
        };
        // Several rounds of random chain extensions, each applied
        // incrementally and compared to a fresh batch solve.
        for _ in 0..3 {
            let victim = rng.index(n);
            if let Available::Chain { chain, .. } = &mut avail[victim] {
                let top =
                    chain.last().map(|c| c.f.max_epoch().unwrap_or(0)).unwrap_or(0);
                let f = Frontier::upto_epoch(top + 1);
                chain.push(CkptMeta {
                    f: f.clone(),
                    n_bar: f.clone(),
                    m_bar: io[victim].0.iter().map(|d| (*d, f.clone())).collect(),
                    d_bar: io[victim].1.iter().map(|o| (*o, f.clone())).collect(),
                    phi: io[victim].1.iter().map(|o| (*o, f.clone())).collect(),
                });
            } else {
                continue;
            }
            let input = RollbackInput { topo: &topo, avail: &avail };
            grow_frontiers(&input, &mut plan, ProcId(victim as u32));
            let batch = choose_frontiers(&input);
            prop_assert!(plan == batch, "incremental diverged from batch at n={n}");
        }
        Ok(())
    });
}

/// Fig. 6 on *sharded* topologies, with availability taken from a live
/// system rather than synthesized: for a seeded grid of (W, topology,
/// policy, drive length, failed-shard set), the per-shard frontiers the
/// solver picks satisfy the §3.5 constraints (`verify_plan` accepts
/// every plan `choose_frontiers` emits), failed shards never keep ⊤, and
/// the engine-level recovery applies exactly that plan.
#[test]
fn sharded_solver_output_always_satisfies_constraints() {
    check_with(Config { cases: 25, base_seed: 0x5A4D }, "sharded Fig-6 valid", |rng| {
        let workers = 1 + rng.below(4) as u32;
        let two_stage = rng.chance(0.5);
        let count_policy = *rng.choose(&[
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::Lazy { every: 2, log_outputs: true },
            Policy::Lazy { every: 1, log_outputs: false },
            Policy::FullHistory,
        ]);
        let cfg = ShardedConfig { workers, two_stage, count_policy, ..Default::default() };
        let mut p = pipeline(&cfg);
        let seed = rng.next_u64();
        let epochs = 1 + rng.below(3);
        for ep in 0..epochs {
            drive_epoch(&mut p, seed, ep, 12, 8);
        }
        // Leave a partial epoch in flight so failures land mid-exchange.
        let src = p.src_proc();
        p.sys.advance_input(src, Time::epoch(epochs));
        for i in 0..rng.index(10) {
            p.sys.push_input(src, Time::epoch(epochs), Record::kv(i as i64 % 8, 1.0));
        }
        p.sys.run_to_quiescence(rng.index(40));

        // Crash a random nonempty set of shards (count, sometimes map).
        let mut victims = Vec::new();
        for s in 0..workers as usize {
            if rng.chance(0.4) {
                victims.push(p.plan.proc(p.count, s));
            }
        }
        if let Some(m) = p.map {
            if rng.chance(0.3) {
                victims.push(p.plan.proc(m, rng.index(workers as usize)));
            }
        }
        if victims.is_empty() {
            victims.push(p.plan.proc(p.count, rng.index(workers as usize)));
        }
        p.sys.inject_failures(&victims);

        let avail = p.sys.availability();
        let input = RollbackInput { topo: &p.plan.topo, avail: &avail };
        let plan = choose_frontiers(&input);
        verify_plan(&input, &plan)
            .map_err(|e| format!("W={workers} two_stage={two_stage} {count_policy:?}: {e}"))?;
        for i in 0..plan.f.len() {
            prop_assert!(
                plan.f_n[i].is_subset(&plan.f[i]),
                "f_n ⊄ f at p{i} (W={workers})"
            );
        }
        for &v in &victims {
            prop_assert!(!plan.f[v.0 as usize].is_top(), "failed shard {v} kept ⊤");
        }
        // The engine-level recovery path must choose the same plan and
        // drive the system back to a runnable state.
        let rep = p.sys.recover();
        prop_assert!(rep.plan == plan, "recover() diverged from the batch solve");
        p.sys.advance_input(src, Time::epoch(epochs + 1));
        p.sys.run_to_quiescence(5_000_000);
        prop_assert!(p.sys.engine.is_quiescent(), "system wedged after recovery");
        Ok(())
    });
}

/// Sibling isolation: under logging policies, crashing one count shard
/// never rolls back its siblings (their frontiers stay ⊤), whatever the
/// failure step.
#[test]
fn sharded_siblings_stay_untouched_under_logging() {
    check_with(Config { cases: 25, base_seed: 0xD15C }, "sibling isolation", |rng| {
        let workers = 2 + rng.below(3) as u32;
        let cfg = ShardedConfig { workers, ..Default::default() };
        let mut p = pipeline(&cfg);
        let seed = rng.next_u64();
        let epochs = 1 + rng.below(3);
        for ep in 0..epochs {
            drive_epoch(&mut p, seed, ep, 12, 8);
        }
        let src = p.src_proc();
        p.sys.advance_input(src, Time::epoch(epochs));
        for i in 0..rng.index(8) {
            p.sys.push_input(src, Time::epoch(epochs), Record::kv(i as i64, 1.0));
        }
        let s = rng.index(workers as usize);
        let victim = p.plan.proc(p.count, s);
        p.sys.inject_failures(&[victim]);
        let rep = p.sys.recover();
        prop_assert!(
            rep.plan.rolled_back() == vec![victim],
            "W={workers}: rolled back {:?}, expected only count#{s}",
            rep.plan.rolled_back()
        );
        Ok(())
    });
}

#[test]
fn projection_preimage_galois() {
    // φ(preimage(F)) ⊆ F and preimage is pointwise-maximal.
    check("preimage Galois connection", |rng| {
        // (projection, source depth, image/limit depth)
        for (proj, src_depth, limit_depth) in [
            (Projection::LoopEnter, 0u8, 1usize),
            (Projection::LoopExit, 1, 0),
            (Projection::LoopFeedback, 1, 1),
            (Projection::Identity, 1, 1),
        ] {
            let limit = arb_frontier(rng, limit_depth);
            let pre = match proj.preimage(&limit, src_depth) {
                Some(p) => p,
                None => continue,
            };
            if let Some(img) = proj.apply(&pre) {
                prop_assert!(
                    img.is_subset(&limit),
                    "{proj:?}: φ(pre)={img} ⊄ limit={limit}"
                );
            }
            for _ in 0..10 {
                let t = arb_time(rng, src_depth as usize);
                let img_t = proj.apply(&Frontier::below(t)).unwrap();
                if img_t.is_subset(&limit) {
                    prop_assert!(
                        pre.contains(&t),
                        "{proj:?}: {t} should be in preimage of {limit} (pre={pre})"
                    );
                }
            }
        }
        Ok(())
    });
}
