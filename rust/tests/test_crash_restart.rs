//! True cold-restart recovery: the process dies (operator states,
//! channels, frontiers and the store's unflushed group-commit tail all
//! vanish), a fresh process reopens the durable WAL directory, and
//! [`FtSystem::reopen`] must reconstruct the Table-1 state and replay to
//! **byte-identical** observable output versus an uninterrupted run —
//! including after tail corruption and after segment compaction.

use falkirk::bench_support::sharded::{
    canonical_output, epoch_records, pipeline, pipeline_with_store, reopen_pipeline,
    ShardedConfig, ShardedPipeline,
};
use falkirk::coordinator::{build_fig1_with_store, reopen_fig1, Fig1Config};
use falkirk::engine::Record;
use falkirk::frontier::Frontier;
use falkirk::ft::external::ExternalInput;
use falkirk::ft::monitor::GcAction;
use falkirk::ft::{FileBackendOptions, Kind, PersistMode, Snapshot, SnapshotPolicy, Store};
use falkirk::time::Time;
use falkirk::util::rng::Rng;
use falkirk::util::tmp::TempDir;
use std::path::Path;

const SEED: u64 = 11;
const EPOCHS: u64 = 5;
const RECORDS: usize = 24;
const KEYS: u64 = 8;

fn file_store(dir: &Path, flush_every_n: usize) -> Store {
    Store::open_dir(dir, 1, FileBackendOptions { flush_every_n, ..Default::default() })
        .expect("opening WAL store")
}

/// Offer epoch `ep`'s batch to the external service and drive it through.
fn offer_and_drive(p: &mut ShardedPipeline, ext: &mut ExternalInput, ep: u64) {
    let src = p.src_proc();
    let recs = epoch_records(SEED, ep, RECORDS, KEYS);
    ext.offer(Time::epoch(ep), recs.clone());
    p.sys.advance_input(src, Time::epoch(ep));
    for r in recs {
        p.sys.push_input(src, Time::epoch(ep), r);
    }
    p.sys.advance_input(src, Time::epoch(ep + 1));
    p.run(5_000_000);
}

/// The uninterrupted reference output (backend-independent).
fn expected_output(cfg: &ShardedConfig) -> Vec<u8> {
    let mut p = pipeline(cfg);
    let mut ext = ExternalInput::new();
    for ep in 0..EPOCHS {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(5_000_000);
    canonical_output(&p.sys, p.collect_proc())
}

/// Drive epochs 0..3 fully, crash mid-drain of epoch 3, reopen, resupply
/// from the external service, finish epochs 4.., and compare outputs.
fn sharded_crash_restart(batch_cap: usize, flush_every_n: usize, corrupt_tail: bool) {
    let cfg = ShardedConfig { workers: 4, batch_cap, ..Default::default() };
    let expected = expected_output(&cfg);

    let t = TempDir::new("crash-shard");
    let mut ext = ExternalInput::new();
    {
        let store = file_store(t.path(), flush_every_n);
        let mut p = pipeline_with_store(&cfg, store.clone());
        for ep in 0..3 {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        // Epoch 3: inputs land, the epoch closes, and the process dies a
        // few deliveries into the drain.
        let src = p.src_proc();
        let recs = epoch_records(SEED, 3, RECORDS, KEYS);
        ext.offer(Time::epoch(3), recs.clone());
        p.sys.advance_input(src, Time::epoch(3));
        for r in recs {
            p.sys.push_input(src, Time::epoch(3), r);
        }
        p.sys.advance_input(src, Time::epoch(4));
        p.sys.run_to_quiescence(40); // mid-drain
        drop(p);
        store.simulate_crash(); // the buffered WAL tail dies with it
    }
    if corrupt_tail {
        // Additionally chop the newest segment mid-record.
        let seg = newest_segment(t.path());
        let len = std::fs::metadata(&seg).unwrap().len();
        if len > 24 {
            std::fs::OpenOptions::new()
                .write(true)
                .open(&seg)
                .unwrap()
                .set_len(len - 7)
                .unwrap();
        }
    }

    // Cold restart.
    let store = file_store(t.path(), flush_every_n);
    let (mut p, report) = reopen_pipeline(&cfg, store);
    let src = p.src_proc();
    let f_src = report.plan.frontier(src).clone();
    // §4.3 client retry: everything unacked beyond the source's
    // recovered input frontier.
    for (tm, recs) in ext.replay_from(&f_src) {
        p.sys.advance_input(src, tm);
        for r in recs {
            p.sys.push_input(src, tm, r);
        }
    }
    p.sys.advance_input(src, Time::epoch(4));
    p.run(5_000_000);
    for ep in 4..EPOCHS {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(
        canonical_output(&p.sys, p.collect_proc()),
        expected,
        "cold restart (cap {batch_cap}, flush {flush_every_n}, corrupt {corrupt_tail}) diverged"
    );
}

fn newest_segment(dir: &Path) -> std::path::PathBuf {
    std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "seg").unwrap_or(false))
        .max()
        .expect("WAL directory has segments")
}

/// Crash with an asynchronous persistence pipeline holding a staged,
/// unacknowledged tail: the writer is paused before the final epoch so
/// *everything* that epoch staged is still queued when the process dies.
/// The durable image is therefore the acked prefix only, and the cold
/// restart must still reconverge — byte-identical to the sync-mode run —
/// once the §4.3 services resupply the unacked inputs.
fn async_crash_with_unacked_tail(ack_every: usize, batch_cap: usize) {
    let sync_cfg = ShardedConfig { workers: 4, batch_cap, ..Default::default() };
    let expected = expected_output(&sync_cfg);
    let cfg = ShardedConfig {
        persist_mode: PersistMode::Async { ack_every },
        ..sync_cfg.clone()
    };

    let t = TempDir::new("crash-async-tail");
    let mut ext = ExternalInput::new();
    {
        let store = file_store(t.path(), 8);
        let mut p = pipeline_with_store(&cfg, store.clone());
        for ep in 0..2 {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        p.sys.store.flush_staged(); // epochs 0–1 fully acked
        // Epoch 2 runs entirely against the parked writer: checkpoints,
        // log entries and marker advances all stage but never ack.
        p.sys.store.pause_persistence();
        offer_and_drive(&mut p, &mut ext, 2);
        assert!(p.sys.ack_lag() > 0, "the crash must catch staged writes in flight");
        drop(p);
        store.simulate_crash(); // queued staged tail + WAL buffer die
    }

    // Cold restart: durable state is the epoch 0–1 prefix; epoch 2 is
    // resupplied by the external service exactly like any crash window.
    let store = file_store(t.path(), 8);
    let (mut p, report) = reopen_pipeline(&cfg, store);
    let src = p.src_proc();
    let f_src = report.plan.frontier(src).clone();
    assert!(
        !f_src.contains(&Time::epoch(2)),
        "the unacked epoch cannot be certified by the recovered marker"
    );
    for (tm, recs) in ext.replay_from(&f_src) {
        p.sys.advance_input(src, tm);
        for r in recs {
            p.sys.push_input(src, tm, r);
        }
    }
    p.sys.advance_input(src, Time::epoch(3));
    p.run(5_000_000);
    for ep in 3..EPOCHS {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(
        canonical_output(&p.sys, p.collect_proc()),
        expected,
        "async crash-restart (ack_every {ack_every}, cap {batch_cap}) diverged from sync"
    );
}

#[test]
fn async_crash_with_unacked_tail_ack8() {
    async_crash_with_unacked_tail(8, 1);
}

#[test]
fn async_crash_with_unacked_tail_ack64() {
    async_crash_with_unacked_tail(64, 8);
}

/// Satellite: a *live* `fail_proc` with staged-but-unacknowledged writes
/// rolls back to the ack watermark — the in-memory mirror suffix beyond
/// it is discarded with the staged ops, so the Fig. 6 solver restores the
/// last acknowledged checkpoint, and the run still reconverges to the
/// sync-mode output.
#[test]
fn live_failure_with_unacked_tail_rolls_back_to_acked_watermark() {
    let sync_cfg = ShardedConfig { workers: 4, ..Default::default() };
    let expected = expected_output(&sync_cfg);
    let cfg = ShardedConfig {
        persist_mode: PersistMode::Async { ack_every: 8 },
        ..sync_cfg
    };
    let mut p = pipeline(&cfg);
    let mut ext = ExternalInput::new();
    for ep in 0..2 {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    p.sys.store.flush_staged(); // every shard's ↓0, ↓1 checkpoints acked
    let victim = p.plan.proc(p.count, 2);
    assert_eq!(p.sys.chain_len(victim), 2);

    // Epoch 2 completes against the parked writer: count#2 takes its ↓2
    // checkpoint, but the write never acks.
    p.sys.store.pause_persistence();
    offer_and_drive(&mut p, &mut ext, 2);
    assert_eq!(p.sys.chain_len(victim), 3, "the ↓2 checkpoint is staged in the mirror");
    assert!(p.sys.ack_lag() > 0);

    p.sys.inject_failures(&[victim]);
    assert_eq!(
        p.sys.chain_len(victim),
        2,
        "injection discards the staged-unacked checkpoint from the mirror"
    );
    let rep = p.sys.recover();
    assert_eq!(
        rep.plan.frontier(victim),
        &Frontier::upto_epoch(1),
        "the solver lands on the acked watermark, not the staged ↓2 checkpoint"
    );
    p.sys.store.resume_persistence();

    // The discarded suffix is simply re-executed: epoch 2's records in
    // the victim's key range replay from the (non-failed) source's log,
    // and the rest of the run is ordinary.
    for ep in 3..EPOCHS {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(
        canonical_output(&p.sys, p.collect_proc()),
        expected,
        "live unacked-tail failure diverged from the sync-mode run"
    );
}

#[test]
fn sharded_cold_restart_cap1() {
    sharded_crash_restart(1, 1, false);
}

#[test]
fn sharded_cold_restart_cap8() {
    sharded_crash_restart(8, 8, false);
}

#[test]
fn sharded_cold_restart_survives_torn_tail() {
    sharded_crash_restart(1, 8, true);
}

/// With write-through flushing, everything acknowledged is durable: the
/// source resumes at its full input-frontier marker and every count
/// shard restores from a checkpoint instead of recomputing from ∅.
#[test]
fn cold_restart_restores_from_checkpoints() {
    let cfg = ShardedConfig { workers: 4, ..Default::default() };
    let t = TempDir::new("crash-restore");
    let mut ext = ExternalInput::new();
    {
        let store = file_store(t.path(), 1);
        let mut p = pipeline_with_store(&cfg, store.clone());
        for ep in 0..3 {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        drop(p);
        store.simulate_crash(); // nothing buffered at flush_every_n = 1
    }
    let store = file_store(t.path(), 1);
    let (p, report) = reopen_pipeline(&cfg, store);
    let src = p.src_proc();
    assert_eq!(
        report.plan.frontier(src),
        &Frontier::upto_epoch(2),
        "the durable input-frontier marker carries the source past ∅"
    );
    for s in 0..4 {
        assert!(
            !report.plan.frontier(p.plan.proc(p.count, s)).is_bottom(),
            "count#{s} must restore from a durable checkpoint"
        );
    }
    assert!(report.restored_from_checkpoint >= 4, "all count shards restored");
}

/// Reopening after a *clean* shutdown reproduces the full output with no
/// resupply at all, and a second reopen agrees with the first.
#[test]
fn reopen_after_clean_shutdown_reproduces_output() {
    let cfg = ShardedConfig { workers: 4, ..Default::default() };
    let expected = expected_output(&cfg);
    let t = TempDir::new("clean-reopen");
    {
        let store = file_store(t.path(), 4);
        let mut p = pipeline_with_store(&cfg, store);
        let mut ext = ExternalInput::new();
        for ep in 0..EPOCHS {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        let src = p.src_proc();
        p.sys.close_input(src);
        p.run(5_000_000);
        assert_eq!(canonical_output(&p.sys, p.collect_proc()), expected);
        // Graceful drop: the WAL tail flushes.
    }
    for _ in 0..2 {
        let store = file_store(t.path(), 4);
        let (mut p, _report) = reopen_pipeline(&cfg, store);
        p.run(5_000_000); // deliver the replayed Q′ queues
        assert_eq!(
            canonical_output(&p.sys, p.collect_proc()),
            expected,
            "reopen from a cleanly shut down WAL reproduces the output"
        );
        // Graceful drop again; the next loop iteration reopens the
        // directory as mutated by this recovery.
    }
}

/// GC-driven tombstones push segments over the dead-byte threshold,
/// compaction rewrites them, and a cold restart from the compacted WAL
/// is still byte-identical.
#[test]
fn cold_restart_after_gc_compaction() {
    let cfg = ShardedConfig { workers: 4, ..Default::default() };
    let expected = expected_output(&cfg);
    let t = TempDir::new("crash-compact");
    let mut ext = ExternalInput::new();
    {
        let store = Store::open_dir(
            t.path(),
            1,
            FileBackendOptions {
                flush_every_n: 1,
                segment_bytes: 2048, // rotate often so compaction has prey
                compact_ratio: 0.4,
                fsync: false,
            },
        )
        .unwrap();
        let mut p = pipeline_with_store(&cfg, store.clone());
        let collect = p.collect_proc();
        for ep in 0..4 {
            offer_and_drive(&mut p, &mut ext, ep);
            // The collector's Buffer never requests notifications, so
            // checkpoint it explicitly at the completed epoch — that is
            // what authorizes GC of upstream logs (its low-watermark).
            p.sys.checkpoint_now(collect, Frontier::upto_epoch(ep));
            if ep >= 2 {
                let wm = Frontier::upto_epoch(ep - 2);
                let topo = p.sys.topology();
                let src = p.src_proc();
                let mut actions = vec![GcAction::DropCheckpointsBelow {
                    proc: collect,
                    watermark: wm.clone(),
                }];
                for e in topo.out_edges(src) {
                    actions.push(GcAction::DropLogWithin {
                        proc: src,
                        edge: *e,
                        watermark: wm.clone(),
                    });
                }
                for s in 0..4 {
                    let cp = p.plan.proc(p.count, s);
                    actions.push(GcAction::DropCheckpointsBelow {
                        proc: cp,
                        watermark: wm.clone(),
                    });
                    for e in topo.out_edges(cp) {
                        actions.push(GcAction::DropLogWithin {
                            proc: cp,
                            edge: *e,
                            watermark: wm.clone(),
                        });
                    }
                }
                for a in &actions {
                    p.sys.apply_gc(a);
                }
            }
        }
        assert!(
            store.backend_info().compactions > 0,
            "GC tombstones must have triggered segment compaction: {:?}",
            store.backend_info()
        );
        drop(p);
        store.simulate_crash();
    }
    let store = file_store(t.path(), 1);
    let (mut p, report) = reopen_pipeline(&cfg, store);
    let src = p.src_proc();
    // The GC monitor resumes from the reopened Ξ chains: with every
    // count and the collector durably checkpointed through epoch 3, the
    // restarted low-watermark lands there immediately.
    {
        let np = p.sys.topology().num_procs();
        let mut stateless = vec![false; np];
        let mut logs = vec![false; np];
        stateless[src.0 as usize] = true;
        logs[src.0 as usize] = true;
        let mon = p.sys.rebuild_monitor(stateless, logs);
        assert_eq!(
            mon.low_watermark(p.collect_proc()),
            &Frontier::upto_epoch(3),
            "reopened monitor watermark reflects the durable chains"
        );
    }
    let f_src = report.plan.frontier(src).clone();
    for (tm, recs) in ext.replay_from(&f_src) {
        p.sys.advance_input(src, tm);
        for r in recs {
            p.sys.push_input(src, tm, r);
        }
    }
    p.sys.advance_input(src, Time::epoch(4));
    p.run(5_000_000);
    for ep in 4..EPOCHS {
        offer_and_drive(&mut p, &mut ext, ep);
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(
        canonical_output(&p.sys, p.collect_proc()),
        expected,
        "cold restart after compaction diverged"
    );
}

// ---------------------------------------------------------------------
// Incremental content-addressed checkpoints: the same crash-restart
// scenarios with checkpoint state stored as delta chains. The invariant
// is representation-transparency — byte-identical observable output
// versus the monolithic-Full in-memory reference, whichever snapshot
// policy wrote the WAL and wherever the kill lands (mid-chain, or after
// compaction has folded the cold WAL prefix).
// ---------------------------------------------------------------------

/// Durable `Kind::Snapshot` records of `store` that are deltas (carry a
/// `prior_snapshot` base) — direct evidence the WAL holds a chain, not
/// just monolithic-equivalent fulls.
fn durable_delta_records(store: &Store) -> usize {
    use falkirk::util::ser::Decode;
    let mut n = 0;
    for proc in store.procs() {
        for key in store.keys_for(proc, Kind::Snapshot) {
            let Some(bytes) = store.get(&key) else { continue };
            if let Ok(snap) = Snapshot::from_bytes(&bytes) {
                if snap.prior_snapshot.is_some() {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Mid-chain kill: epochs 0..3 complete (so `Delta {2}` chains have
/// built, hit the forced-full bound, and started a new delta on top),
/// the process dies mid-drain of epoch 3, and the cold restart must
/// materialize states by walking the surviving chains.
fn delta_crash_restart_mid_chain(batch_cap: usize, flush_every_n: usize) {
    let full_cfg = ShardedConfig { workers: 4, batch_cap, ..Default::default() };
    let expected = expected_output(&full_cfg);
    for policy in [SnapshotPolicy::Full, SnapshotPolicy::Delta { max_chain: 2 }] {
        let cfg = ShardedConfig { snapshot_policy: policy, ..full_cfg.clone() };
        let t = TempDir::new("crash-delta-chain");
        let mut ext = ExternalInput::new();
        {
            let store = file_store(t.path(), flush_every_n);
            let mut p = pipeline_with_store(&cfg, store.clone());
            for ep in 0..3 {
                offer_and_drive(&mut p, &mut ext, ep);
            }
            let src = p.src_proc();
            let recs = epoch_records(SEED, 3, RECORDS, KEYS);
            ext.offer(Time::epoch(3), recs.clone());
            p.sys.advance_input(src, Time::epoch(3));
            for r in recs {
                p.sys.push_input(src, Time::epoch(3), r);
            }
            p.sys.advance_input(src, Time::epoch(4));
            p.sys.run_to_quiescence(40); // mid-drain
            drop(p);
            store.simulate_crash();
        }

        let store = file_store(t.path(), flush_every_n);
        let deltas = durable_delta_records(&store);
        match policy {
            SnapshotPolicy::Full => assert_eq!(
                deltas, 0,
                "Full policy must never write a chained snapshot record"
            ),
            SnapshotPolicy::Delta { .. } => assert!(
                deltas > 0,
                "Delta policy left no durable chain to recover from — the kill \
                 missed the representation this test exists to cover"
            ),
        }
        let (mut p, report) = reopen_pipeline(&cfg, store);
        let src = p.src_proc();
        let f_src = report.plan.frontier(src).clone();
        for (tm, recs) in ext.replay_from(&f_src) {
            p.sys.advance_input(src, tm);
            for r in recs {
                p.sys.push_input(src, tm, r);
            }
        }
        p.sys.advance_input(src, Time::epoch(4));
        p.run(5_000_000);
        for ep in 4..EPOCHS {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        let src = p.src_proc();
        p.sys.close_input(src);
        p.run(5_000_000);
        assert_eq!(
            canonical_output(&p.sys, p.collect_proc()),
            expected,
            "mid-chain cold restart (cap {batch_cap}, {policy:?}) diverged from Full"
        );
    }
}

/// Post-compaction kill: GC tombstones push segments over the dead-byte
/// threshold, compaction folds the surviving cold prefix into per-
/// processor fold records, and only then does the process die. The cold
/// restart replays folds, repairs whatever chain suffix the crash tore,
/// and must still be byte-identical — and its reopen scan must touch
/// O(live state) keys, not O(history).
fn delta_crash_restart_post_compaction(batch_cap: usize) {
    let full_cfg = ShardedConfig { workers: 4, batch_cap, ..Default::default() };
    let expected = expected_output(&full_cfg);
    for policy in [SnapshotPolicy::Full, SnapshotPolicy::Delta { max_chain: 2 }] {
        let cfg = ShardedConfig { snapshot_policy: policy, ..full_cfg.clone() };
        let t = TempDir::new("crash-delta-compact");
        let mut ext = ExternalInput::new();
        {
            let store = Store::open_dir(
                t.path(),
                1,
                FileBackendOptions {
                    flush_every_n: 1,
                    segment_bytes: 2048, // rotate often so compaction has prey
                    compact_ratio: 0.4,
                    fsync: false,
                },
            )
            .unwrap();
            let mut p = pipeline_with_store(&cfg, store.clone());
            let collect = p.collect_proc();
            for ep in 0..4 {
                offer_and_drive(&mut p, &mut ext, ep);
                p.sys.checkpoint_now(collect, Frontier::upto_epoch(ep));
                if ep >= 2 {
                    let wm = Frontier::upto_epoch(ep - 2);
                    let topo = p.sys.topology();
                    let src = p.src_proc();
                    let mut actions = vec![GcAction::DropCheckpointsBelow {
                        proc: collect,
                        watermark: wm.clone(),
                    }];
                    for e in topo.out_edges(src) {
                        actions.push(GcAction::DropLogWithin {
                            proc: src,
                            edge: *e,
                            watermark: wm.clone(),
                        });
                    }
                    for s in 0..4 {
                        let cp = p.plan.proc(p.count, s);
                        actions.push(GcAction::DropCheckpointsBelow {
                            proc: cp,
                            watermark: wm.clone(),
                        });
                        for e in topo.out_edges(cp) {
                            actions.push(GcAction::DropLogWithin {
                                proc: cp,
                                edge: *e,
                                watermark: wm.clone(),
                            });
                        }
                    }
                    for a in &actions {
                        p.sys.apply_gc(a);
                    }
                }
            }
            assert!(
                store.backend_info().compactions > 0,
                "GC tombstones must have triggered compaction before the kill: {:?}",
                store.backend_info()
            );
            drop(p);
            store.simulate_crash(); // the post-compaction kill
        }

        let store = file_store(t.path(), 1);
        let live = store.backend_info().live_keys;
        store.reset_stats();
        let (mut p, report) = reopen_pipeline(&cfg, store.clone());
        // Reopen walks the live index a bounded number of times (per-kind
        // range scans per processor) — O(live keys), never O(written
        // history). Dead keys are gone from the index post-compaction, so
        // a regression that re-reads history shows up as a scan count far
        // above this bound.
        let scanned = store.stats().keys_scanned;
        assert!(
            scanned <= 8 * live + 64,
            "cold reopen scanned {scanned} keys against {live} live — \
             not O(live state) ({policy:?})"
        );
        let src = p.src_proc();
        let f_src = report.plan.frontier(src).clone();
        for (tm, recs) in ext.replay_from(&f_src) {
            p.sys.advance_input(src, tm);
            for r in recs {
                p.sys.push_input(src, tm, r);
            }
        }
        p.sys.advance_input(src, Time::epoch(4));
        p.run(5_000_000);
        for ep in 4..EPOCHS {
            offer_and_drive(&mut p, &mut ext, ep);
        }
        let src = p.src_proc();
        p.sys.close_input(src);
        p.run(5_000_000);
        assert_eq!(
            canonical_output(&p.sys, p.collect_proc()),
            expected,
            "post-compaction cold restart (cap {batch_cap}, {policy:?}) diverged from Full"
        );
    }
}

#[test]
fn delta_chain_cold_restart_mid_chain_cap1() {
    delta_crash_restart_mid_chain(1, 1);
}

#[test]
fn delta_chain_cold_restart_mid_chain_cap8() {
    delta_crash_restart_mid_chain(8, 8);
}

#[test]
fn delta_chain_cold_restart_post_compaction_cap1() {
    delta_crash_restart_post_compaction(1);
}

#[test]
fn delta_chain_cold_restart_post_compaction_cap8() {
    delta_crash_restart_post_compaction(8);
}

// ---------------------------------------------------------------------
// Figure-1: the four-regime application, crash-restarted mid-drain. The
// externally-visible database commits (the eager regime's contract) must
// match the uninterrupted run exactly — the deduplicating external
// consumer survives the crash, so replayed commits are suppressed by
// sequence number.
// ---------------------------------------------------------------------

fn fig1_cfg() -> Fig1Config {
    Fig1Config {
        epochs: 4,
        queries_per_epoch: 3,
        records_per_epoch: 12,
        iters: 3,
        window: 8,
        num_keys: 4,
        use_xla: false,
        ..Default::default()
    }
}

/// The synthetic per-epoch inputs, generated exactly as
/// `coordinator::fig1::run` does so both runs see identical streams.
fn fig1_epoch_data(cfg: &Fig1Config) -> Vec<(Vec<Record>, Vec<Record>)> {
    let mut rng = Rng::new(cfg.seed);
    let words = ["one", "two", "three", "four", "five", "six", "seven", "eight"];
    (0..cfg.epochs)
        .map(|_| {
            let queries: Vec<Record> = (0..cfg.queries_per_epoch)
                .map(|_| Record::text(words[rng.index(words.len())]))
                .collect();
            let records: Vec<Record> = (0..cfg.records_per_epoch)
                .map(|_| Record::kv(rng.below(cfg.num_keys as u64) as i64, rng.f64() * 10.0))
                .collect();
            (queries, records)
        })
        .collect()
}

fn fig1_drive_epoch(
    app: &mut falkirk::coordinator::Fig1App,
    q_ext: &mut ExternalInput,
    d_ext: &mut ExternalInput,
    ep: u64,
    data: &(Vec<Record>, Vec<Record>),
) {
    let t = Time::epoch(ep);
    q_ext.offer(t, data.0.clone());
    d_ext.offer(t, data.1.clone());
    app.sys.advance_input(app.q_src, t);
    app.sys.advance_input(app.d_src, t);
    for q in &data.0 {
        app.sys.push_input(app.q_src, t, q.clone());
    }
    for r in &data.1 {
        app.sys.push_input(app.d_src, t, r.clone());
    }
    app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
    app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
    app.sys.run_to_quiescence(2_000_000);
}

#[test]
fn fig1_cold_restart_preserves_db_commits() {
    let cfg = fig1_cfg();
    let data = fig1_epoch_data(&cfg);

    // Uninterrupted reference run (in-memory store).
    let clean = {
        let mut app = build_fig1_with_store(&cfg, Store::new(cfg.write_cost));
        let (mut q_ext, mut d_ext) = (ExternalInput::new(), ExternalInput::new());
        for ep in 0..cfg.epochs {
            fig1_drive_epoch(&mut app, &mut q_ext, &mut d_ext, ep, &data[ep as usize]);
        }
        app.sys.close_input(app.q_src);
        app.sys.close_input(app.d_src);
        app.sys.run_to_quiescence(2_000_000);
        let db = app.db.lock().unwrap();
        db.contents()
    };
    assert!(!clean.is_empty());

    // Crash run: epochs 0–1 complete, the process dies mid-drain of
    // epoch 2.
    let t = TempDir::new("crash-fig1");
    let (mut q_ext, mut d_ext) = (ExternalInput::new(), ExternalInput::new());
    let db_handle;
    {
        let store = file_store(t.path(), 4);
        let mut app = build_fig1_with_store(&cfg, store.clone());
        db_handle = app.db.clone(); // the external DB consumer survives
        for ep in 0..2 {
            fig1_drive_epoch(&mut app, &mut q_ext, &mut d_ext, ep, &data[ep as usize]);
        }
        let ep = 2u64;
        let tm = Time::epoch(ep);
        q_ext.offer(tm, data[2].0.clone());
        d_ext.offer(tm, data[2].1.clone());
        app.sys.advance_input(app.q_src, tm);
        app.sys.advance_input(app.d_src, tm);
        for q in &data[2].0 {
            app.sys.push_input(app.q_src, tm, q.clone());
        }
        for r in &data[2].1 {
            app.sys.push_input(app.d_src, tm, r.clone());
        }
        app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
        app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
        app.sys.run_to_quiescence(300); // mid-drain
        drop(app);
        store.simulate_crash();
    }

    // Cold restart against the surviving external services.
    let store = file_store(t.path(), 4);
    let (mut app, report) = reopen_fig1(&cfg, store, db_handle);
    let fq = report.plan.frontier(app.q_src).clone();
    let fd = report.plan.frontier(app.d_src).clone();
    for (tm, batch) in q_ext.replay_from(&fq) {
        app.sys.advance_input(app.q_src, tm);
        for r in batch {
            app.sys.push_input(app.q_src, tm, r);
        }
    }
    for (tm, batch) in d_ext.replay_from(&fd) {
        app.sys.advance_input(app.d_src, tm);
        for r in batch {
            app.sys.push_input(app.d_src, tm, r);
        }
    }
    app.sys.advance_input(app.q_src, Time::epoch(3));
    app.sys.advance_input(app.d_src, Time::epoch(3));
    app.sys.run_to_quiescence(2_000_000);
    for ep in 3..cfg.epochs {
        fig1_drive_epoch(&mut app, &mut q_ext, &mut d_ext, ep, &data[ep as usize]);
    }
    app.sys.close_input(app.q_src);
    app.sys.close_input(app.d_src);
    app.sys.run_to_quiescence(2_000_000);

    let db = app.db.lock().unwrap();
    assert_eq!(
        db.contents(),
        clean,
        "externally-committed database state diverged across the cold restart"
    );
}
