//! Replays every recorded fuzz seed in `rust/tests/corpus/` and asserts the
//! two properties that make a recorded seed a regression test: the run is
//! green under the full oracle, and running it twice yields the identical
//! verdict digest (bit-for-bit reproducibility of shape, knobs, fault plan,
//! outputs, and violations). Also smoke-tests the `falkirk fuzz` CLI path.

use std::fs;
use std::path::{Path, PathBuf};

use falkirk::fuzz;

const DEFAULT_STEPS: usize = 5_000_000;

struct Case {
    name: String,
    seed: u64,
    steps: usize,
}

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/corpus")
}

fn parse_case(path: &Path) -> Case {
    let text = fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("<corpus case>")
        .to_string();
    let mut seed: Option<u64> = None;
    let mut steps = DEFAULT_STEPS;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .unwrap_or_else(|| panic!("{name}:{}: expected `key = value`, got {line:?}", lineno + 1));
        let value = value.trim();
        match key.trim() {
            "seed" => {
                seed = Some(value.parse().unwrap_or_else(|e| {
                    panic!("{name}:{}: bad seed {value:?}: {e}", lineno + 1)
                }))
            }
            "steps" => {
                steps = value.parse().unwrap_or_else(|e| {
                    panic!("{name}:{}: bad steps {value:?}: {e}", lineno + 1)
                })
            }
            other => panic!("{name}:{}: unknown key {other:?}", lineno + 1),
        }
    }
    let seed = seed.unwrap_or_else(|| panic!("{name}: missing `seed = N` line"));
    Case { name, seed, steps }
}

fn load_corpus() -> Vec<Case> {
    let dir = corpus_dir();
    let mut cases: Vec<Case> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("read corpus dir {}: {e}", dir.display()))
        .map(|entry| entry.expect("corpus dir entry").path())
        .filter(|p| p.extension().is_some_and(|ext| ext == "case"))
        .map(|p| parse_case(&p))
        .collect();
    cases.sort_by(|a, b| a.name.cmp(&b.name));
    cases
}

#[test]
fn corpus_holds_at_least_five_seeds() {
    let cases = load_corpus();
    assert!(
        cases.len() >= 5,
        "fuzz corpus shrank to {} cases; recorded regression seeds must not be dropped",
        cases.len()
    );
    let mut seeds: Vec<u64> = cases.iter().map(|c| c.seed).collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), cases.len(), "corpus contains duplicate seeds");
}

#[test]
fn corpus_seeds_replay_green_and_deterministic() {
    for case in load_corpus() {
        let first = fuzz::run_one(case.seed, case.steps);
        assert!(
            first.pass,
            "{}: seed {} regressed: violations {:?} (shape: {}; knobs: {}; faults: {})",
            case.name, case.seed, first.violations, first.shape, first.knobs, first.faults
        );
        let second = fuzz::run_one(case.seed, case.steps);
        assert_eq!(
            first.digest, second.digest,
            "{}: seed {} is not deterministic across replays",
            case.name, case.seed
        );
    }
}

#[test]
fn cli_fuzz_smoke_run_passes() {
    let args: Vec<String> = ["fuzz", "--seed", "7", "--runs", "5"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let code = falkirk::coordinator::cli::run(&args);
    assert_eq!(code, 0, "`falkirk fuzz --seed 7 --runs 5` exited nonzero");
}
