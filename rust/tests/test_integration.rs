//! Cross-module integration tests: multi-domain bridging, recovery
//! equivalence matrices, the GC monitor wired to a live harness, external
//! ack/retry end-to-end, and failure-schedule-driven runs.

use falkirk::coordinator::{run_fig1, Fig1Config};
use falkirk::engine::{Delivery, Processor, Record};
use falkirk::failure::{DetectorModel, FailureSchedule};
use falkirk::frontier::Frontier;
use falkirk::ft::external::{ExternalInput, ExternalOutput};
use falkirk::ft::monitor::Monitor;
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{GraphBuilder, ProcId, Projection};
use falkirk::operators::{Buffer, CountByKey, Source};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

fn small_fig1() -> Fig1Config {
    Fig1Config {
        epochs: 5,
        queries_per_epoch: 4,
        records_per_epoch: 24,
        iters: 3,
        window: 8,
        num_keys: 4,
        use_xla: false,
        ..Default::default()
    }
}

/// Failure-equivalence matrix over the whole Figure-1 app: every victim,
/// two failure points — db commits must always match the clean run.
#[test]
fn fig1_equivalence_matrix() {
    let clean = run_fig1(&small_fig1());
    assert!(clean.db_commits > 0);
    for victim in [
        "q_select", "reduce", "batch_agg", "t_collect", "iterate", "rank_store",
        "join_batch", "join_iter", "db", "resp",
    ] {
        for fail_after in [1u64, 3] {
            let mut cfg = small_fig1();
            cfg.fail_proc = Some(victim.to_string());
            cfg.fail_after_epoch = fail_after;
            let out = run_fig1(&cfg);
            assert_eq!(
                out.db_commits, clean.db_commits,
                "victim {victim} @epoch {fail_after}: db commits diverged"
            );
            assert!(out.recovery.is_some());
        }
    }
}

/// Two simultaneous failures in different regimes.
#[test]
fn fig1_double_failure() {
    let clean = run_fig1(&small_fig1());
    // Drive manually to inject two failures at once.
    let cfg = small_fig1();
    let mut app = falkirk::coordinator::build_fig1(&cfg);
    let mut q_ext = ExternalInput::new();
    let mut d_ext = ExternalInput::new();
    let mut rng = falkirk::util::rng::Rng::new(cfg.seed);
    let words = ["one", "two", "three", "four", "five", "six", "seven", "eight"];
    for ep in 0..cfg.epochs {
        let t = Time::epoch(ep);
        let queries: Vec<Record> = (0..cfg.queries_per_epoch)
            .map(|_| Record::text(words[rng.index(words.len())]))
            .collect();
        let records: Vec<Record> = (0..cfg.records_per_epoch)
            .map(|_| Record::kv(rng.below(cfg.num_keys as u64) as i64, rng.f64() * 10.0))
            .collect();
        q_ext.offer(t, queries.clone());
        d_ext.offer(t, records.clone());
        app.sys.advance_input(app.q_src, t);
        app.sys.advance_input(app.d_src, t);
        for q in queries {
            app.sys.push_input(app.q_src, t, q);
        }
        for r in records {
            app.sys.push_input(app.d_src, t, r);
        }
        app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
        app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
        app.sys.run_to_quiescence(2_000_000);
        if ep == 2 {
            let v1 = app.sys.topology().find("rank_store").unwrap();
            let v2 = app.sys.topology().find("reduce").unwrap();
            app.sys.inject_failures(&[v1, v2]);
            let rep = app.sys.recover();
            let fq = rep.plan.f[app.q_src.0 as usize].clone();
            let fd = rep.plan.f[app.d_src.0 as usize].clone();
            for (t, batch) in q_ext.replay_from(&fq) {
                app.sys.advance_input(app.q_src, t);
                for r in batch {
                    app.sys.push_input(app.q_src, t, r);
                }
            }
            for (t, batch) in d_ext.replay_from(&fd) {
                app.sys.advance_input(app.d_src, t);
                for r in batch {
                    app.sys.push_input(app.d_src, t, r);
                }
            }
            app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
            app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
            app.sys.run_to_quiescence(2_000_000);
        }
    }
    app.sys.close_input(app.q_src);
    app.sys.close_input(app.d_src);
    app.sys.run_to_quiescence(2_000_000);
    let db = app.db.lock().unwrap();
    let commits = db.contents().first().map(|(_, v)| v.len()).unwrap_or(0);
    assert_eq!(commits, clean.db_commits, "double failure diverged");
}

/// GC monitor wired to a live harness: checkpoints stream into the
/// monitor; watermark advances let the store reclaim bytes and the
/// external input acknowledge batches.
#[test]
fn gc_monitor_with_live_harness() {
    let mut g = GraphBuilder::new();
    let src = g.add_proc("src", TimeDomain::EPOCH);
    let agg = g.add_proc("agg", TimeDomain::EPOCH);
    let buf = g.add_proc("buffer", TimeDomain::EPOCH);
    g.connect(src, agg, Projection::Identity);
    g.connect(agg, buf, Projection::Identity);
    let topo = Arc::new(g.build().unwrap());
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(CountByKey::default()),
        Box::new(Buffer::default()),
    ];
    let mut sys = FtSystem::new(
        topo.clone(),
        procs,
        vec![
            Policy::LogOutputs,
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::Lazy { every: 1, log_outputs: false },
        ],
        Delivery::Fifo,
        Store::new(1),
    );
    let mut mon = Monitor::new(topo, vec![true, false, false], vec![true, false, false]);
    let mut ext = ExternalInput::new();
    let mut reported = vec![0usize; 3];

    for ep in 0..6u64 {
        let t = Time::epoch(ep);
        let batch: Vec<Record> = (0..8).map(|i| Record::kv(i % 3, 1.0)).collect();
        ext.offer(t, batch.clone());
        sys.advance_input(src, t);
        for r in batch {
            sys.push_input(src, t, r);
        }
        sys.advance_input(src, Time::epoch(ep + 1));
        sys.run_to_quiescence(100_000);
        // Buffer never requests notifications, so drive its checkpoints
        // explicitly at the (now complete) epoch frontier.
        sys.checkpoint_now(buf, Frontier::upto_epoch(ep));
        // Stream freshly persisted Ξ to the monitor and apply the GC
        // actions it emits back to the harness (checkpoint/log pruning +
        // storage reclamation).
        for p in [agg, buf] {
            let chain = sys.chain_len(p);
            for k in reported[p.0 as usize]..chain {
                let meta = sys.checkpoint_meta(p, k);
                for action in mon.on_persisted(p, meta) {
                    sys.apply_gc(&action);
                }
            }
            reported[p.0 as usize] = chain;
        }
        // The reader acknowledges external batches at its low-watermark.
        let wm = mon.low_watermark(src).clone();
        ext.ack_upto(&wm);
        if ep >= 2 {
            assert!(
                !mon.low_watermark(buf).is_bottom(),
                "watermark must have advanced by epoch {ep}"
            );
        }
    }
    // Everything except the in-flight tail is acknowledged.
    assert!(ext.pending() <= 2, "watermark-driven acks reclaimed the backlog");
    // GC pruned the chains down to the restore point + tail…
    assert!(sys.chain_len(agg) <= 3, "agg chain pruned (was 6)");
    assert!(sys.chain_len(buf) <= 3, "buf chain pruned (was 6)");
    // …and recovery still works afterwards from the surviving state.
    sys.inject_failures(&[agg]);
    let rep = sys.recover();
    assert!(
        !rep.plan.f[agg.0 as usize].is_bottom(),
        "post-GC recovery restores from the retained checkpoint"
    );
}

/// External output dedup composes with recovery-driven re-sends.
#[test]
fn external_output_exactly_once_visibility() {
    let mut out = ExternalOutput::new();
    // First delivery of 3 records at epoch 0.
    for i in 0..3 {
        assert!(out.deliver(Time::epoch(0), i, Record::Int(i as i64)));
    }
    // Post-recovery duplicate re-sends (same indices).
    for i in 0..3 {
        assert!(!out.deliver(Time::epoch(0), i, Record::Int(i as i64)));
    }
    // New work continues.
    assert!(out.deliver(Time::epoch(0), 3, Record::Int(3)));
    assert_eq!(out.contents()[0].1.len(), 4);
    assert_eq!(out.duplicates, 3);
}

/// Failure schedule + detector model drive repeated crashes of random
/// victims; system reconverges every time.
#[test]
fn scheduled_random_failures_reconverge() {
    let cfg = small_fig1();
    let clean = run_fig1(&cfg);
    let det = DetectorModel::default();
    assert!(det.confirmation_delay() > 0);
    // Three different random schedules.
    for seed in [11u64, 22, 33] {
        let mut sched = FailureSchedule::random(
            seed,
            2,
            cfg.epochs,
            &[ProcId(4), ProcId(11), ProcId(13)], // reduce, rank_store, join_iter
        );
        // Reinterpret schedule times as epochs.
        let mut cfgf = cfg.clone();
        let due = sched.due(cfg.epochs);
        if let Some(v) = due.first() {
            cfgf.fail_proc = Some(match v.0 {
                4 => "reduce".into(),
                11 => "rank_store".into(),
                _ => "join_iter".into(),
            });
            cfgf.fail_after_epoch = 2;
            let out = run_fig1(&cfgf);
            assert_eq!(out.db_commits, clean.db_commits, "seed {seed} diverged");
        }
    }
}

/// A seq-domain processor fed from an epoch domain via a per-checkpoint
/// transformer edge recovers without double-applying (domain bridging).
#[test]
fn epoch_to_seq_bridge_recovery() {
    let mut sc = falkirk::baselines::exactly_once(1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    for i in 1..=5 {
        sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(i));
    }
    sc.sys.run_to_quiescence(100_000);
    // Crash BOTH the accumulator and the sink.
    sc.sys.inject_failures(&[sc.mid, sc.sink_proc]);
    let rep = sc.sys.recover();
    assert!(rep.plan.f[sc.src.0 as usize].is_top());
    sc.sys.run_to_quiescence(100_000);
    // Sink re-received the logged outputs that were undone by its reset.
    let got = sc.out.lock().unwrap().clone();
    let final_total = got.iter().map(|(_, r)| r.as_kv().unwrap().1).fold(0.0, f64::max);
    assert_eq!(final_total, 15.0, "running sum state survived via its checkpoint chain");
}

/// The §3.2 worked example end-to-end: an epoch computation feeds an
/// eager seq-number consumer through the EpochToSeq buffering
/// transformer; a crash of the consumer recovers from its per-event
/// checkpoints with φ captured as message counts, and a crash of the
/// transformer replays from upstream logs without reordering epochs.
#[test]
fn epoch_to_seq_transformer_recovery() {
    use falkirk::baselines::scenarios::RunningSum;
    use falkirk::operators::EpochToSeq;
    let build = || {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let bridge = g.add_proc("bridge", TimeDomain::EPOCH);
        let db = g.add_proc("db", TimeDomain::Seq);
        g.connect(src, bridge, Projection::Identity);
        g.connect(bridge, db, Projection::PerCheckpoint);
        let topo = Arc::new(g.build().unwrap());
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(EpochToSeq::default()),
            Box::new(RunningSum::default()),
        ];
        FtSystem::new(
            topo,
            procs,
            vec![
                Policy::LogOutputs,
                Policy::Lazy { every: 1, log_outputs: true },
                Policy::Eager,
            ],
            Delivery::Fifo,
            Store::new(1),
        )
    };
    let drive = |sys: &mut FtSystem, fail: Option<&str>| -> (f64, u64) {
        let src = ProcId(0);
        for ep in 0..4u64 {
            sys.advance_input(src, Time::epoch(ep));
            for i in 0..5 {
                sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 * 10 + i));
            }
            sys.advance_input(src, Time::epoch(ep + 1));
            sys.run_to_quiescence(100_000);
            if ep == 1 {
                if let Some(name) = fail {
                    let v = sys.topology().find(name).unwrap();
                    sys.inject_failures(&[v]);
                    sys.recover();
                    sys.run_to_quiescence(100_000);
                }
            }
        }
        sys.close_input(src);
        sys.run_to_quiescence(100_000);
        let blob = sys.engine.proc(ProcId(2)).checkpoint_upto(&Frontier::Top);
        let mut probe = RunningSum::default();
        probe.restore(&blob);
        (probe.total, probe.count)
    };
    let mut clean = build();
    let want = drive(&mut clean, None);
    assert_eq!(want.1, 20, "4 epochs × 5 records");
    for victim in ["db", "bridge"] {
        let mut sys = build();
        let got = drive(&mut sys, Some(victim));
        assert_eq!(got, want, "victim {victim}: seq-domain state diverged");
    }
}

/// The ⊤/∅ frontier ends: a failure before anything ran, and a failure
/// after close with everything durable.
#[test]
fn edge_case_failures() {
    // Before anything ran.
    let mut sc = falkirk::baselines::falkirk_lazy(1, 1);
    sc.sys.inject_failures(&[sc.mid]);
    let rep = sc.sys.recover();
    assert!(rep.plan.f[sc.mid.0 as usize].is_bottom());
    // Then run normally.
    sc.sys.advance_input(sc.src, Time::epoch(0));
    sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(1));
    sc.sys.advance_input(sc.src, Time::epoch(1));
    sc.sys.run_to_quiescence(100_000);
    assert_eq!(sc.out.lock().unwrap().len(), 1);

    // Failure after the stream closed and all state durable.
    let mut sc = falkirk::baselines::falkirk_lazy(1, 1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(7));
    sc.sys.advance_input(sc.src, Time::epoch(1));
    sc.sys.close_input(sc.src);
    sc.sys.run_to_quiescence(100_000);
    sc.sys.inject_failures(&[sc.mid]);
    let rep = sc.sys.recover();
    assert_eq!(rep.plan.f[sc.mid.0 as usize], Frontier::upto_epoch(0));
    sc.sys.run_to_quiescence(100_000);
    assert_eq!(sc.out.lock().unwrap().len(), 1, "no duplicate emission after recovery");
}
