//! Runtime integration: load the AOT HLO-text artifacts through PJRT and
//! check the numerics against the in-process reference kernels (which
//! python/tests verified against the Pallas kernels — closing the
//! L1 ⇄ L2 ⇄ L3 loop).
//!
//! Skipped gracefully when `make artifacts` has not run.

use falkirk::operators::tensor::mock::{MockAgg, MockIterate, MockStats};
use falkirk::operators::Kernel;
use falkirk::runtime::ArtifactRegistry;
use falkirk::util::rng::Rng;

fn registry() -> Option<ArtifactRegistry> {
    let reg = ArtifactRegistry::default_dir();
    if reg.available("stream_agg") && reg.available("iterate") && reg.available("batch_stats") {
        Some(reg)
    } else {
        eprintln!("artifacts missing — run `make artifacts`; skipping runtime tests");
        None
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
    }
}

#[test]
fn stream_agg_artifact_matches_reference() {
    let Some(reg) = registry() else { return };
    let k = reg.kernel("stream_agg", 2).expect("load stream_agg");
    let mock = MockAgg { num_keys: 8 };
    let mut rng = Rng::new(5);
    for _ in 0..10 {
        let keys: Vec<f32> = (0..16).map(|_| rng.below(8) as f32).collect();
        let vals: Vec<f32> = (0..16).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect();
        let got = k.run(&[&keys, &vals]).expect("exec");
        let want = mock.run(&[&keys, &vals]).unwrap();
        assert_close(&got[0], &want[0], 1e-5);
    }
}

#[test]
fn iterate_artifact_matches_reference() {
    let Some(reg) = registry() else { return };
    let k = reg.kernel("iterate", 1).expect("load iterate");
    let mock = MockIterate { damping: 0.85 };
    let mut rng = Rng::new(9);
    for _ in 0..10 {
        let r: Vec<f32> = (0..8).map(|_| rng.f64() as f32).collect();
        let got = k.run(&[&r]).expect("exec");
        let want = mock.run(&[&r]).unwrap();
        assert_close(&got[0], &want[0], 1e-5);
    }
}

#[test]
fn batch_stats_artifact_matches_reference() {
    let Some(reg) = registry() else { return };
    let k = reg.kernel("batch_stats", 1).expect("load batch_stats");
    let mut rng = Rng::new(3);
    let v: Vec<f32> = (0..16).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect();
    let got = k.run(&[&v]).expect("exec");
    let want = MockStats.run(&[&v]).unwrap();
    assert_close(&got[0], &want[0], 1e-5);
}

#[test]
fn artifact_iteration_converges_like_reference() {
    // Drive 20 iterations through the XLA kernel and the mock; both must
    // converge to the uniform fixed point together.
    let Some(reg) = registry() else { return };
    let k = reg.kernel("iterate", 1).expect("load iterate");
    let mock = MockIterate { damping: 0.85 };
    let mut a: Vec<f32> = vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
    let mut b = a.clone();
    for _ in 0..20 {
        a = k.run(&[&a]).unwrap().remove(0);
        b = mock.run(&[&b]).unwrap().remove(0);
    }
    assert_close(&a, &b, 1e-4);
    let total: f32 = a.iter().sum();
    assert!((total - 1.0).abs() < 1e-3, "mass conserved");
    for x in &a {
        assert!((x - 0.125).abs() < 0.05, "converging to uniform");
    }
}

#[test]
fn mock_kernels_match_python_golden_vectors() {
    // Mirrors python/tests/test_model_aot.py::test_rust_mock_agreement_vectors.
    let agg = MockAgg { num_keys: 3 };
    let keys = [0f32, 1.0, 2.0, 0.0, 1.0, 2.0, 0.0, 0.0];
    let vals = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
    let got = agg.run(&[&keys, &vals]).unwrap();
    assert_eq!(got[0], vec![20.0, 7.0, 9.0]);
    let it = MockIterate { damping: 0.85 };
    let got = it.run(&[&[1.0f32, 0.0, 0.0, 0.0][..]]).unwrap();
    assert_close(&got[0], &[0.0375, 0.4625, 0.0375, 0.4625], 1e-6);
}
