//! Zero-copy hot path: clone accounting on FIFO delivery.
//!
//! `Batch` carries its records behind a shared `Arc` payload, so channel
//! coalescing, splits, capture aliases and log writes never duplicate
//! records; `Record::clone` is counted through a thread-local
//! ([`falkirk::engine::record_clones_on_this_thread`]) precisely so
//! these tests can assert the *absence* of copies instead of trusting
//! the implementation's intent. The contract:
//!
//! - capture-off delivery (the production hot path): **zero** record
//!   clones from channel to operator — unique batches move;
//! - ingestion: exactly one clone per pushed record (the
//!   `EventKind::Input` report copy), none in the downstream flush;
//! - capture-on delivery (full-history runs): the report *aliases* the
//!   payload (an `Arc` bump), and the only copy is the visible slice
//!   handed to the operator;
//! - sent-capture (the FT harness's logging view): report batches share
//!   their payload allocation with the queued batches byte for byte.

use falkirk::engine::{
    record_clones_on_this_thread, Delivery, Engine, Processor, Record,
};
use falkirk::graph::{GraphBuilder, Projection};
use falkirk::operators::{shared_vec, Map, Sink, Source};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

const EPOCHS: u64 = 3;
const RECORDS: i64 = 32;

/// src → map → sink, plain engine (no FT harness), coalescing channels.
fn build(batch_cap: usize) -> (Engine, falkirk::graph::ProcId) {
    let mut g = GraphBuilder::new();
    let src = g.add_proc("src", TimeDomain::EPOCH);
    let map = g.add_proc("map", TimeDomain::EPOCH);
    let sink = g.add_proc("sink", TimeDomain::EPOCH);
    g.connect(src, map, Projection::Identity);
    g.connect(map, sink, Projection::Identity);
    let topo = Arc::new(g.build().unwrap());
    let out = shared_vec();
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Map(|r: Record| r)),
        Box::new(Sink(out)),
    ];
    let eng = Engine::with_batch_cap(topo, procs, Delivery::Fifo, batch_cap);
    (eng, src)
}

fn push_epochs(eng: &mut Engine, src: falkirk::graph::ProcId) -> u64 {
    for ep in 0..EPOCHS {
        eng.advance_input(src, Time::epoch(ep));
        for v in 0..RECORDS {
            eng.push_input(src, Time::epoch(ep), Record::Int(v));
        }
        eng.advance_input(src, Time::epoch(ep + 1));
    }
    eng.close_input(src);
    EPOCHS * RECORDS as u64
}

/// The acceptance bar for the zero-copy pipeline: with capture off (the
/// default), draining every queued batch through two operator hops
/// performs **zero** `Record` clones — payloads move from ingestion to
/// sink, at every coalescing cap.
#[test]
fn capture_off_fifo_delivery_is_clone_free() {
    for batch_cap in [1usize, 8, 64] {
        let (mut eng, src) = build(batch_cap);
        let total = push_epochs(&mut eng, src);
        let before = record_clones_on_this_thread();
        let mut events = 0u64;
        while eng.step().is_some() {
            events += 1;
        }
        assert!(events >= total / batch_cap.max(1) as u64, "drain delivered the workload");
        assert_eq!(
            record_clones_on_this_thread(),
            before,
            "capture-off delivery must not clone records (batch_cap={batch_cap})"
        );
    }
}

/// Ingestion cost is exactly one clone per record — the copy placed in
/// the `EventKind::Input` report — and the flush into the source's
/// out-channel contributes none.
#[test]
fn ingestion_costs_exactly_the_report_copy() {
    let (mut eng, src) = build(8);
    eng.advance_input(src, Time::epoch(0));
    let before = record_clones_on_this_thread();
    for v in 0..RECORDS {
        eng.push_input(src, Time::epoch(0), Record::Int(v));
    }
    assert_eq!(
        record_clones_on_this_thread(),
        before + RECORDS as u64,
        "one report copy per pushed record, nothing else"
    );
}

/// With data capture on (what full-history policies require), the report
/// batch aliases the payload and the only per-delivery copy is the
/// visible slice handed to the operator: clones == records delivered.
#[test]
fn capture_on_delivery_costs_exactly_the_operator_copy() {
    let (mut eng, src) = build(8);
    eng.set_event_data_capture(true);
    let total = push_epochs(&mut eng, src);
    let before = record_clones_on_this_thread();
    while eng.step().is_some() {}
    // Two hops (src→map, map→sink): each record is delivered twice.
    assert_eq!(
        record_clones_on_this_thread(),
        before + 2 * total,
        "capture-on delivery clones exactly the operator's visible slice"
    );
}

/// Sent-capture (the FT harness's logging view): each report entry and
/// the queued batch are two handles on one payload allocation — the log
/// write path reads the same bytes the channel will later deliver,
/// without a copy.
#[test]
fn sent_capture_report_aliases_queued_batch() {
    let (mut eng, src) = build(8);
    eng.set_sent_capture(true);
    eng.advance_input(src, Time::epoch(0));
    let rep = eng.push_input(src, Time::epoch(0), Record::Int(7));
    let (e, sent) = &rep.sent[0];
    let queued = eng.channel(*e).iter().next().expect("flush queued the batch");
    assert!(sent.shares_payload(queued), "report and channel share one allocation");
    assert_eq!(sent.records(), queued.records());
}
