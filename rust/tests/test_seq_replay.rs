//! Regression guard for the scheduler's `set_seq_counter` path: after
//! `fail_proc` + recovery, re-executed sends into a sequence-number
//! domain must *reuse* the undone sequence numbers, so the destination
//! observes every `(e, s)` time exactly once, in order, with no gaps —
//! per-channel seq monotonicity survives rollback.
//!
//! Pipeline: src (epoch, logs outputs) → bridge (`EpochToSeq`, lazy
//! selective checkpoints + logged outputs, the §3.2 epoch→seq
//! transformer whose φ is a per-checkpoint message count) → probe (seq
//! domain, eager policy). The probe records every sequence number it is
//! ever delivered into an externally-held vector that survives crashes —
//! if recovery ever re-issues, skips, or duplicates a sequence number,
//! the observation log shows it.
//!
//! The failure step is swept over a window of engine-event counts so the
//! crash lands at every interesting interleaving: before the epoch
//! completes, between the bridge's notification and downstream delivery,
//! and mid-delivery.

use falkirk::engine::{Ctx, Delivery, Processor, Record};
use falkirk::frontier::Frontier;
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{EdgeId, GraphBuilder, ProcId, Projection};
use falkirk::operators::{EpochToSeq, Source};
use falkirk::time::{Time, TimeDomain};
use std::sync::{Arc, Mutex};

const EPOCHS: u64 = 4;
const PER_EPOCH: i64 = 3;
const TOTAL: u64 = EPOCHS * PER_EPOCH as u64;

/// Seq-domain consumer that records every delivered sequence number into
/// an external (crash-surviving) log. Internal state is a monolithic
/// applied-count, checkpointed eagerly.
struct SeqProbe {
    observed: Arc<Mutex<Vec<u64>>>,
    applied: u64,
}

impl Processor for SeqProbe {
    fn on_message(&mut self, _port: usize, t: Time, _d: Record, _ctx: &mut Ctx) {
        self.applied += 1;
        self.observed.lock().unwrap().push(t.seq_of());
    }

    fn statefulness(&self) -> falkirk::engine::Statefulness {
        falkirk::engine::Statefulness::Monolithic
    }

    fn checkpoint_upto(&self, _f: &Frontier) -> Vec<u8> {
        let mut w = falkirk::util::ser::Writer::new();
        w.varint(self.applied);
        w.into_bytes()
    }

    fn restore(&mut self, blob: &[u8]) {
        self.applied = if blob.is_empty() {
            0
        } else {
            falkirk::util::ser::Reader::new(blob).varint().expect("corrupt SeqProbe")
        };
    }

    fn reset(&mut self) {
        self.applied = 0;
    }
}

fn build(bridge_policy: Policy) -> (FtSystem, ProcId, ProcId, ProcId, EdgeId, Arc<Mutex<Vec<u64>>>) {
    let mut g = GraphBuilder::new();
    let src = g.add_proc("src", TimeDomain::EPOCH);
    let bridge = g.add_proc("bridge", TimeDomain::EPOCH);
    let probe = g.add_proc("probe", TimeDomain::Seq);
    g.connect(src, bridge, Projection::Identity);
    let seq_edge = g.connect(bridge, probe, Projection::PerCheckpoint);
    let topo = Arc::new(g.build().unwrap());
    let observed = Arc::new(Mutex::new(Vec::new()));
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(EpochToSeq::default()),
        Box::new(SeqProbe { observed: observed.clone(), applied: 0 }),
    ];
    let sys = FtSystem::new(
        topo,
        procs,
        vec![Policy::LogOutputs, bridge_policy, Policy::Eager],
        Delivery::Fifo,
        Store::new(1),
    );
    (sys, src, bridge, probe, seq_edge, observed)
}

/// Drive all epochs; crash `victim` after `fail_at_events` engine events
/// inside epoch 2 (None = failure-free). Returns (observed seqs, final
/// seq counter).
fn run(victim: Option<(&str, usize)>) -> (Vec<u64>, u64) {
    run_with(Policy::Lazy { every: 1, log_outputs: true }, victim)
}

fn run_with(bridge_policy: Policy, victim: Option<(&str, usize)>) -> (Vec<u64>, u64) {
    let (mut sys, src, bridge, probe, seq_edge, observed) = build(bridge_policy);
    for ep in 0..EPOCHS {
        sys.advance_input(src, Time::epoch(ep));
        for v in 0..PER_EPOCH {
            sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 * 10 + v));
        }
        sys.advance_input(src, Time::epoch(ep + 1));
        if let Some((name, steps)) = victim {
            if ep == 2 {
                sys.run_to_quiescence(steps);
                let victims = match name {
                    "bridge" => vec![bridge],
                    "probe" => vec![probe],
                    "both" => vec![bridge, probe],
                    other => panic!("unknown victim {other}"),
                };
                sys.inject_failures(&victims);
                sys.recover();
            }
        }
        sys.run_to_quiescence(100_000);
    }
    sys.close_input(src);
    sys.run_to_quiescence(100_000);
    let seqs = observed.lock().unwrap().clone();
    (seqs, sys.engine.seq_counter(seq_edge))
}

fn expect_contiguous(seqs: &[u64], ctx: &str) {
    assert_eq!(
        seqs,
        (1..=TOTAL).collect::<Vec<u64>>().as_slice(),
        "{ctx}: probe must observe seqs 1..={TOTAL} exactly once, in order"
    );
}

#[test]
fn failure_free_run_is_contiguous() {
    let (seqs, counter) = run(None);
    expect_contiguous(&seqs, "clean");
    assert_eq!(counter, TOTAL, "engine counter equals messages ever sent");
}

/// Crashing the bridge at every interleaving inside epoch 2: recovery
/// resets the per-channel counter to the restored checkpoint's φ count,
/// so re-executed sends reuse the undone numbers — no gaps, no
/// duplicates, no reordering at the seq-domain consumer.
#[test]
fn bridge_crash_preserves_seq_monotonicity_at_every_step() {
    for steps in 0..16 {
        let (seqs, counter) = run(Some(("bridge", steps)));
        expect_contiguous(&seqs, &format!("bridge crash after {steps} steps"));
        assert_eq!(counter, TOTAL, "counter restored+resumed (steps={steps})");
    }
}

/// Crashing the eager seq-domain consumer itself: it restores to its
/// newest (per-event) checkpoint and only genuinely-undelivered messages
/// are replayed from the bridge's log.
#[test]
fn probe_crash_preserves_seq_monotonicity_at_every_step() {
    for steps in 0..16 {
        let (seqs, counter) = run(Some(("probe", steps)));
        expect_contiguous(&seqs, &format!("probe crash after {steps} steps"));
        assert_eq!(counter, TOTAL, "counter unaffected by consumer crash (steps={steps})");
    }
}

/// The lifted FAILURE_MODES exclusion, swept over every interleaving: a
/// `FullHistory` bridge feeding the `PerCheckpoint` edge. Recovery
/// derives the history offer's φ from `HistoryEvent::sent_seq`, replays
/// the bridge's input history, renumbers the regenerated seq sends from
/// 1 exactly like the live flush, and restores the engine counter to
/// the regenerated total — the seq consumer must still observe
/// 1..=TOTAL exactly once at every crash point.
#[test]
fn full_history_bridge_crash_preserves_seq_monotonicity_at_every_step() {
    for steps in 0..16 {
        let (seqs, counter) = run_with(Policy::FullHistory, Some(("bridge", steps)));
        expect_contiguous(&seqs, &format!("FullHistory bridge crash after {steps} steps"));
        assert_eq!(counter, TOTAL, "counter restored+resumed (steps={steps})");
    }
}

/// Bridge and probe failing *together* under `FullHistory`: the probe's
/// restored completed-times must deduplicate exactly the regenerated
/// sends at or below its recovered frontier, and accept the rest — any
/// off-by-one between the renumbered replay and the probe's frontier
/// shows up as a gap or duplicate in the observation log.
#[test]
fn full_history_double_crash_stays_contiguous_at_every_step() {
    for steps in 0..16 {
        let (seqs, counter) = run_with(Policy::FullHistory, Some(("both", steps)));
        expect_contiguous(&seqs, &format!("FullHistory double crash after {steps} steps"));
        assert_eq!(counter, TOTAL, "counter restored+resumed (steps={steps})");
    }
}
