//! Sharded rollback recovery: the failure-transparency obligation on the
//! multi-worker layer.
//!
//! The core claim (Veresov et al., *Failure Transparency in Stateful
//! Dataflow Systems*, framing the paper's refinement argument): a
//! failed-and-recovered run must be observably identical to a
//! failure-free one. Here the observable output is the collector's
//! complete per-epoch record multiset, compared **byte for byte** via
//! `bench_support::sharded::canonical_output`.
//!
//! Two suites:
//! - a seeded deterministic grid over (topology, W, checkpoint policy,
//!   failure step) — every cell must produce byte-identical output;
//! - targeted assertions that a single-shard failure at W = 4 rolls back
//!   and replays only the failed shard's key range (per-shard frontiers
//!   + `FtStats` replay counts).

use falkirk::bench_support::sharded::{
    canonical_output, drive_epoch, epoch_records, pipeline, ShardedConfig,
};
use falkirk::engine::shard_of_record;
use falkirk::frontier::Frontier;
use falkirk::ft::recovery::RecoveryReport;
use falkirk::ft::{FtStats, PersistMode, Policy};
use falkirk::time::Time;

const EPOCHS: u64 = 4;
const RECORDS: usize = 24;
const KEYS: u64 = 8;

/// A failure injection point inside the driven workload.
#[derive(Copy, Clone, Debug)]
struct Failure {
    /// Which `count` shard crashes.
    shard: usize,
    /// The epoch during which the crash happens (before that epoch is
    /// closed; `records_before` of its batch have been pushed).
    epoch: u64,
    /// Records of the epoch's batch pushed before the crash.
    records_before: usize,
    /// Engine events processed after those pushes, before the crash
    /// (drives messages partway into the exchange).
    presteps: usize,
}

/// Drive the workload end to end, optionally crashing one count shard
/// and recovering. Returns the canonical observable output, the final
/// stats, and the recovery report if a failure was injected.
fn drive(
    cfg: &ShardedConfig,
    seed: u64,
    failure: Option<Failure>,
) -> (Vec<u8>, FtStats, Option<RecoveryReport>) {
    let mut p = pipeline(cfg);
    let src = p.src_proc();
    let mut report = None;
    for ep in 0..EPOCHS {
        match failure {
            // The crash epoch needs custom driving: open the epoch, push
            // part of its batch, step partway, crash, recover, resume.
            Some(f) if f.epoch == ep => {
                let recs = epoch_records(seed, ep, RECORDS, KEYS);
                p.sys.advance_input(src, Time::epoch(ep));
                for r in &recs[..f.records_before] {
                    p.sys.push_input(src, Time::epoch(ep), r.clone());
                }
                p.run(f.presteps);
                let victim = p.plan.proc(p.count, f.shard);
                p.sys.inject_failures(&[victim]);
                report = Some(p.sys.recover());
                for r in &recs[f.records_before..] {
                    p.sys.push_input(src, Time::epoch(ep), r.clone());
                }
                p.sys.advance_input(src, Time::epoch(ep + 1));
                p.run(5_000_000);
            }
            _ => drive_epoch(&mut p, seed, ep, RECORDS, KEYS),
        }
    }
    p.sys.close_input(src);
    p.run(5_000_000);
    let out = canonical_output(&p.sys, p.collect_proc());
    (out, p.sys.stats.clone(), report)
}

/// Drive the workload with a fixed two-shard mid-epoch failure (count#0
/// and count#3 — distinct shard groups at T ∈ {2, 4}, so the decomposed
/// path restores on ≥ 2 workers), recovering on the engine the
/// `decomposed` flag selects: `FtSystem::recover` (sequential) or
/// `FtSystem::recover_parallel` over the drain's own shard groups
/// (which itself degenerates to the sequential path at T = 1).
fn drive_two_shard_failure(
    cfg: &ShardedConfig,
    seed: u64,
    decomposed: bool,
) -> (Vec<u8>, FtStats, RecoveryReport) {
    const FAIL_SHARDS: [usize; 2] = [0, 3];
    let mut p = pipeline(cfg);
    let src = p.src_proc();
    for ep in 0..2u64 {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    // Open epoch 2, push half the batch, step partway into the exchange,
    // crash both shards, recover, resume.
    let recs = epoch_records(seed, 2, RECORDS, KEYS);
    p.sys.advance_input(src, Time::epoch(2));
    for r in &recs[..RECORDS / 2] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.run(60);
    let victims: Vec<_> = FAIL_SHARDS.iter().map(|&s| p.plan.proc(p.count, s)).collect();
    p.sys.inject_failures(&victims);
    let report = if decomposed {
        let (groups, threads) = (p.groups.clone(), p.threads);
        p.sys.recover_parallel(&groups, threads)
    } else {
        p.sys.recover()
    };
    for r in &recs[RECORDS / 2..] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.sys.advance_input(src, Time::epoch(3));
    p.run(5_000_000);
    for ep in 3..EPOCHS {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    p.sys.close_input(src);
    p.run(5_000_000);
    let out = canonical_output(&p.sys, p.collect_proc());
    (out, p.sys.stats.clone(), report)
}

/// The deterministic fault-injection grid: recovered output must be
/// byte-identical to the failure-free run in every cell.
#[test]
fn recovery_grid_is_byte_identical_to_failure_free() {
    let policies = [
        Policy::Lazy { every: 1, log_outputs: true },
        Policy::Lazy { every: 2, log_outputs: true },
        Policy::FullHistory,
    ];
    for two_stage in [false, true] {
        for workers in [1u32, 2, 4] {
            for count_policy in policies {
                let cfg = ShardedConfig {
                    workers,
                    two_stage,
                    count_policy,
                    ..Default::default()
                };
                let (clean, _, _) = drive(&cfg, 7, None);
                let failures = [
                    // Epoch boundary: epoch 1 just completed, 2 not begun.
                    Failure { shard: 0, epoch: 2, records_before: 0, presteps: 0 },
                    // Mid-epoch: half the batch pushed, nothing delivered.
                    Failure {
                        shard: workers as usize - 1,
                        epoch: 1,
                        records_before: RECORDS / 2,
                        presteps: 0,
                    },
                    // Mid-epoch, mid-exchange: messages partway through.
                    Failure {
                        shard: workers as usize / 2,
                        epoch: 2,
                        records_before: RECORDS / 2,
                        presteps: 60,
                    },
                ];
                for f in failures {
                    let (failed, stats, rep) = drive(&cfg, 7, Some(f));
                    assert!(rep.is_some());
                    assert_eq!(stats.recoveries, 1);
                    assert_eq!(
                        clean, failed,
                        "output diverged: W={workers} two_stage={two_stage} \
                         policy={count_policy:?} failure={f:?}"
                    );
                }
            }
        }
    }
}

/// The headline selective-rollback property: with per-shard checkpoint
/// chains and logged outputs, a single-shard failure at W = 4 rolls back
/// exactly one processor — the failed shard — and replays only messages
/// destined to its key range.
#[test]
fn single_shard_failure_recovers_only_its_key_range() {
    let cfg = ShardedConfig { workers: 4, ..Default::default() };
    let seed = 7;
    let mut p = pipeline(&cfg);
    let src = p.src_proc();
    // Two full epochs: every count shard checkpoints at ↓0 then ↓1.
    for ep in 0..2u64 {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    for s in 0..4 {
        assert_eq!(p.sys.chain_len(p.plan.proc(p.count, s)), 2, "count#{s} chain");
    }

    // Open epoch 2, push half the batch, crash count#2 mid-epoch.
    let recs = epoch_records(seed, 2, RECORDS, KEYS);
    let pushed = RECORDS / 2;
    p.sys.advance_input(src, Time::epoch(2));
    for r in &recs[..pushed] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    let victim = p.plan.proc(p.count, 2);
    p.sys.inject_failures(&[victim]);
    let rep = p.sys.recover();

    // Per-shard plan: only the failed shard rolls back, to its last
    // checkpoint; every other processor (source, sibling shards,
    // collector) keeps ⊤.
    assert_eq!(rep.plan.frontier(victim), &Frontier::upto_epoch(1));
    for s in [0usize, 1, 3] {
        assert!(
            rep.plan.frontier(p.plan.proc(p.count, s)).is_top(),
            "sibling count#{s} must stay untouched"
        );
    }
    assert_eq!(rep.plan.rolled_back(), vec![victim]);
    assert_eq!(rep.plan.untouched(), p.plan.topo.num_procs() - 1);
    assert_eq!(rep.restored_from_checkpoint, 1);
    assert_eq!(rep.reset_to_empty, 0);

    // Replay cost = exactly the in-flight epoch-2 records in the failed
    // shard's key range (key ≡ 2 mod 4), resupplied from the source log.
    let expected: usize =
        recs[..pushed].iter().filter(|r| shard_of_record(r, 4) == 2).count();
    assert!(expected > 0, "grid must exercise the failed key range");
    assert_eq!(rep.replayed, expected, "only the failed shard's key range replays");
    assert_eq!(p.sys.stats.messages_replayed, expected as u64);
    assert_eq!(p.sys.stats.procs_rolled_back, 1);
    assert_eq!(p.sys.stats.procs_untouched, p.plan.topo.num_procs() as u64 - 1);

    // Finish the epoch and the run: output matches the failure-free run.
    for r in &recs[pushed..] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.sys.advance_input(src, Time::epoch(3));
    p.sys.run_to_quiescence(5_000_000);
    for ep in 3..EPOCHS {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    p.sys.close_input(src);
    p.sys.run_to_quiescence(5_000_000);
    let failed_out = canonical_output(&p.sys, p.collect_proc());
    let (clean, _, _) = drive(&cfg, seed, None);
    assert_eq!(clean, failed_out, "recovered output is byte-identical");
}

/// The batching grid: the same fault-injection cells driven at
/// `batch_cap ∈ {1, 8, 64}`. Two obligations per cell:
/// (a) within a cap, the recovered output is byte-identical to that
///     cap's failure-free run;
/// (b) across caps, all outputs are equal — batching (whole per-shard
///     sub-batches through the exchange, one log write per batch,
///     batch-granular replay) must not change the observable output, at
///     any cap, failed or not. Cap 1 is the pre-batching engine.
#[test]
fn recovery_grid_is_byte_identical_across_batch_caps() {
    let mut outputs: Vec<Vec<u8>> = Vec::new();
    for batch_cap in [1usize, 8, 64] {
        for two_stage in [false, true] {
            let cfg = ShardedConfig {
                workers: 4,
                two_stage,
                batch_cap,
                ..Default::default()
            };
            let (clean, _, _) = drive(&cfg, 7, None);
            let failures = [
                Failure { shard: 0, epoch: 2, records_before: 0, presteps: 0 },
                Failure { shard: 3, epoch: 1, records_before: RECORDS / 2, presteps: 0 },
                Failure { shard: 2, epoch: 2, records_before: RECORDS / 2, presteps: 60 },
            ];
            for f in failures {
                let (failed, stats, rep) = drive(&cfg, 7, Some(f));
                assert!(rep.is_some());
                assert_eq!(stats.recoveries, 1);
                assert_eq!(
                    clean, failed,
                    "output diverged: batch_cap={batch_cap} two_stage={two_stage} \
                     failure={f:?}"
                );
            }
            if two_stage {
                outputs.push(clean);
            }
        }
    }
    // (b): equal across caps (two-stage cells compared).
    assert!(outputs.windows(2).all(|w| w[0] == w[1]), "output differs across batch caps");
}

/// Satellite: the fault-injection grid under `PersistMode::Async` —
/// failures now land while writes may still sit staged and
/// unacknowledged (sequential drains never flush, so injection genuinely
/// exercises `discard_unacked` + acked-prefix availability; parallel
/// drains flush at their quiescence barrier, exercising the settled
/// path). Output must equal the synchronous run in every cell.
#[test]
fn recovery_grid_is_byte_identical_under_async_persistence() {
    for batch_cap in [1usize, 8] {
        for threads in [1usize, 2, 4] {
            let sync_cfg =
                ShardedConfig { workers: 4, two_stage: true, batch_cap, ..Default::default() };
            let (clean_sync, _, _) = drive(&sync_cfg, 7, None);
            let cfg = ShardedConfig {
                threads,
                persist_mode: PersistMode::Async { ack_every: 8 },
                ..sync_cfg
            };
            let (clean_async, _, _) = drive(&cfg, 7, None);
            assert_eq!(
                clean_sync, clean_async,
                "async clean run diverged: threads={threads} cap={batch_cap}"
            );
            let failures = [
                Failure { shard: 0, epoch: 2, records_before: 0, presteps: 0 },
                Failure { shard: 3, epoch: 1, records_before: RECORDS / 2, presteps: 0 },
                Failure { shard: 2, epoch: 2, records_before: RECORDS / 2, presteps: 60 },
            ];
            for f in failures {
                let (failed, stats, rep) = drive(&cfg, 7, Some(f));
                assert!(rep.is_some());
                assert_eq!(stats.recoveries, 1);
                assert_eq!(
                    clean_sync, failed,
                    "async recovery diverged: threads={threads} cap={batch_cap} failure={f:?}"
                );
            }
        }
    }
}

/// The backpressure recovery grid: threads {1,2,4} × batch caps
/// {1,8,64} × mailbox caps {2,64,∞} under fault injection — recovered
/// output must stay byte-identical to the unbounded failure-free run in
/// every cell. Recovery's pause-drain runs with the budget logically
/// lifted (replayed batches enqueue unconditionally; forced rounds
/// guarantee the drain completes), so a crash landing on credit-parked
/// edges must neither wedge nor perturb replay.
#[test]
fn recovery_grid_is_byte_identical_under_mailbox_caps() {
    let (clean, _, _) = drive(
        &ShardedConfig { workers: 4, two_stage: true, batch_cap: 8, ..Default::default() },
        7,
        None,
    );
    for threads in [1usize, 2, 4] {
        for batch_cap in [1usize, 8, 64] {
            for mailbox_cap in [Some(2usize), Some(64), None] {
                let cfg = ShardedConfig {
                    workers: 4,
                    two_stage: true,
                    batch_cap,
                    threads,
                    mailbox_cap,
                    ..Default::default()
                };
                let failures = [
                    // Epoch boundary: nothing in flight, queues settled.
                    Failure { shard: 0, epoch: 2, records_before: 0, presteps: 0 },
                    // Mid-epoch, mid-exchange: the crash lands while the
                    // exchange (gated under a tiny cap) is partly drained.
                    Failure { shard: 2, epoch: 2, records_before: RECORDS / 2, presteps: 60 },
                ];
                for f in failures {
                    let (failed, stats, rep) = drive(&cfg, 7, Some(f));
                    assert!(rep.is_some());
                    assert_eq!(stats.recoveries, 1);
                    assert_eq!(
                        clean, failed,
                        "output diverged: threads={threads} batch_cap={batch_cap} \
                         mailbox_cap={mailbox_cap:?} failure={f:?}"
                    );
                }
            }
        }
    }
}

/// Crashing every shard of the vertex still recovers (degenerates to the
/// whole-vertex rollback a non-sharded system would do).
#[test]
fn all_shards_failing_still_recovers() {
    let cfg = ShardedConfig { workers: 2, ..Default::default() };
    let (clean, _, _) = drive(&cfg, 13, None);
    let mut p = pipeline(&cfg);
    let src = p.src_proc();
    for ep in 0..2u64 {
        drive_epoch(&mut p, 13, ep, RECORDS, KEYS);
    }
    let victims: Vec<_> = (0..2).map(|s| p.plan.proc(p.count, s)).collect();
    p.sys.inject_failures(&victims);
    let rep = p.sys.recover();
    for &v in &victims {
        assert_eq!(rep.plan.frontier(v), &Frontier::upto_epoch(1));
    }
    for ep in 2..EPOCHS {
        drive_epoch(&mut p, 13, ep, RECORDS, KEYS);
    }
    p.sys.close_input(src);
    p.sys.run_to_quiescence(5_000_000);
    assert_eq!(clean, canonical_output(&p.sys, p.collect_proc()));
}

/// The fault-injection grid rerun under parallel execution: failures are
/// injected and recovered between parallel drains (pause-drain-rollback
/// — workers are parked whenever the Fig. 6 plan is computed and
/// applied), and the recovered output must be byte-identical to the
/// sequential failure-free run.
#[test]
fn recovery_grid_is_byte_identical_under_parallel_execution() {
    let policies = [Policy::Lazy { every: 1, log_outputs: true }, Policy::FullHistory];
    let seq_cfg = ShardedConfig { workers: 4, two_stage: true, ..Default::default() };
    for count_policy in policies {
        let (clean_seq, _, _) =
            drive(&ShardedConfig { count_policy, ..seq_cfg.clone() }, 7, None);
        for threads in [2usize, 4] {
            let cfg = ShardedConfig { count_policy, threads, ..seq_cfg.clone() };
            let (clean_par, _, _) = drive(&cfg, 7, None);
            assert_eq!(
                clean_seq, clean_par,
                "parallel clean run diverged: threads={threads} {count_policy:?}"
            );
            let failures = [
                Failure { shard: 0, epoch: 2, records_before: 0, presteps: 0 },
                Failure { shard: 3, epoch: 1, records_before: RECORDS / 2, presteps: 0 },
                Failure { shard: 2, epoch: 2, records_before: RECORDS / 2, presteps: 60 },
            ];
            for f in failures {
                let (failed, stats, rep) = drive(&cfg, 7, Some(f));
                assert!(rep.is_some());
                assert_eq!(stats.recoveries, 1);
                assert_eq!(
                    clean_seq, failed,
                    "output diverged: threads={threads} {count_policy:?} failure={f:?}"
                );
            }
        }
    }
}

/// The §4.4 decomposed-recovery grid: the same two-shard failure
/// recovered by `recover_parallel` — rollback partitioned across the
/// shard-group workers, replay fanned through the per-group mailboxes —
/// must be byte-identical to the sequentially recovered run and to the
/// failure-free run in every cell of threads {1, 2, 4} × batch caps
/// {1, 8} × checkpoint policies {Lazy, FullHistory}. At T ≥ 2 the two
/// victims (count#0, count#3) land in distinct shard groups, so the
/// `recovery_parallelism` gauge must report ≥ 2 restoring workers; at
/// T = 1 the decomposed entry point degenerates to the sequential path
/// and the gauge stays 1.
#[test]
fn parallel_recovery_grid_is_byte_identical_to_sequential() {
    let policies = [Policy::Lazy { every: 1, log_outputs: true }, Policy::FullHistory];
    for count_policy in policies {
        let base =
            ShardedConfig { workers: 4, two_stage: true, count_policy, ..Default::default() };
        let (clean, _, _) = drive(&base, 7, None);
        // Sequential baseline: the identical failure recovered by
        // `FtSystem::recover` on the single-threaded engine.
        let (seq_out, seq_stats, seq_rep) = drive_two_shard_failure(&base, 7, false);
        assert_eq!(seq_rep.plan.rolled_back().len(), 2, "both victims roll back");
        assert!(seq_rep.replayed > 0, "the in-flight epoch replays");
        assert_eq!(seq_stats.recovery_parallelism, 1, "sequential recovery reports one worker");
        assert_eq!(clean, seq_out, "sequential recovery diverged: {count_policy:?}");
        for threads in [1usize, 2, 4] {
            for batch_cap in [1usize, 8] {
                let cfg = ShardedConfig { threads, batch_cap, ..base.clone() };
                let (out, stats, rep) = drive_two_shard_failure(&cfg, 7, true);
                assert_eq!(
                    rep.plan.rolled_back().len(),
                    2,
                    "both victims roll back: threads={threads} cap={batch_cap}"
                );
                assert!(rep.replayed > 0, "replay reached the victims' key ranges");
                assert_eq!(stats.recoveries, 1);
                assert_eq!(
                    seq_out, out,
                    "decomposed recovery diverged from sequential: threads={threads} \
                     cap={batch_cap} {count_policy:?}"
                );
                if threads >= 2 {
                    assert!(
                        stats.recovery_parallelism >= 2,
                        "two victims in distinct groups must restore on >= 2 workers: \
                         threads={threads} cap={batch_cap} (got {})",
                        stats.recovery_parallelism
                    );
                    assert!(
                        stats.replay_workers >= 1,
                        "at least one worker must replay: threads={threads} cap={batch_cap}"
                    );
                } else {
                    assert_eq!(
                        stats.recovery_parallelism, 1,
                        "T=1 degenerates to the sequential path"
                    );
                }
            }
        }
    }
}

/// Regression for the replay coalescing bypass: a *second* failure
/// injected immediately after recovery — while the first recovery's
/// replayed batches are still queued, undelivered — must recover to
/// byte-identical output. Before the bypass, tail-coalescing could merge
/// adjacent same-time replayed batches, so the second recovery (and any
/// full-history record of the interim deliveries) saw batch boundaries
/// that depended on queue adjacency rather than on the durable log.
#[test]
fn double_failure_during_recovery_is_transparent() {
    for count_policy in [Policy::Lazy { every: 1, log_outputs: true }, Policy::FullHistory] {
        for batch_cap in [1usize, 8] {
            let cfg = ShardedConfig { workers: 4, batch_cap, count_policy, ..Default::default() };
            let (clean, _, _) = drive(&cfg, 7, None);
            let mut p = pipeline(&cfg);
            let src = p.src_proc();
            for ep in 0..2u64 {
                drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
            }
            // Open epoch 2, push half the batch, crash count#2 mid-epoch.
            let recs = epoch_records(7, 2, RECORDS, KEYS);
            p.sys.advance_input(src, Time::epoch(2));
            for r in &recs[..RECORDS / 2] {
                p.sys.push_input(src, Time::epoch(2), r.clone());
            }
            p.sys.inject_failures(&[p.plan.proc(p.count, 2)]);
            let rep1 = p.sys.recover();
            assert!(rep1.replayed > 0, "first recovery must replay the in-flight range");
            // Second failure DURING recovery: the replayed batches are
            // still queued (no step has run). Crash the same shard plus a
            // sibling and recover again.
            p.sys.inject_failures(&[p.plan.proc(p.count, 2), p.plan.proc(p.count, 1)]);
            let rep2 = p.sys.recover();
            assert_eq!(p.sys.stats.recoveries, 2);
            assert!(rep2.replayed > 0, "second recovery replays from the log again");
            // Finish the epoch and the run.
            for r in &recs[RECORDS / 2..] {
                p.sys.push_input(src, Time::epoch(2), r.clone());
            }
            p.sys.advance_input(src, Time::epoch(3));
            p.run(5_000_000);
            for ep in 3..EPOCHS {
                drive_epoch(&mut p, 7, ep, RECORDS, KEYS);
            }
            p.sys.close_input(src);
            p.run(5_000_000);
            let failed = canonical_output(&p.sys, p.collect_proc());
            assert_eq!(
                clean, failed,
                "double failure diverged: {count_policy:?} batch_cap={batch_cap}"
            );
        }
    }
}

/// Observability satellite: a traced kill-and-recover run emits a
/// complete, well-nested recovery timeline — detection precedes the
/// enclosing `recovery` span; `solver`, `rollback`, and `replay` nest
/// inside it; replay strictly follows rollback; per-processor rollback
/// instants match the Fig. 6 plan exactly; and the span counters agree
/// with the [`RecoveryReport`]. Also pins the export-order contract
/// (start-time monotone) and that attaching a tracer does not perturb
/// the observable output.
#[test]
fn traced_recovery_emits_well_nested_timeline() {
    use falkirk::trace::Tracer;
    let cfg = ShardedConfig { workers: 4, ..Default::default() };
    let seed = 7;
    let (clean, _, _) = drive(&cfg, seed, None);
    let mut p = pipeline(&cfg);
    let tracer = Tracer::new();
    p.sys.set_tracer(Some(tracer.clone()));
    let src = p.src_proc();
    for ep in 0..2u64 {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }

    // Open epoch 2, push half the batch, crash count#2 mid-epoch.
    let recs = epoch_records(seed, 2, RECORDS, KEYS);
    p.sys.advance_input(src, Time::epoch(2));
    for r in &recs[..RECORDS / 2] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    let victim = p.plan.proc(p.count, 2);
    p.sys.inject_failures(&[victim]);
    let rep = p.sys.recover();

    let evs = tracer.events();
    // Export order is monotone in start time (the sorted-snapshot
    // contract the Python schema checker also enforces on files).
    assert!(evs.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns), "events sorted by start");
    // The driven epochs left engine and FT events behind.
    assert!(evs.iter().any(|e| e.cat == "engine" && e.name == "deliver"), "deliveries traced");
    let checkpoints = evs.iter().filter(|e| e.cat == "ft" && e.name == "checkpoint").count();
    assert_eq!(checkpoints as u64, p.sys.stats.checkpoints_taken, "one instant per checkpoint");

    let find = |name: &str| {
        evs.iter().filter(|e| e.cat == "recovery" && e.name == name).collect::<Vec<_>>()
    };
    let (detect, recovery, solver, rollback, replay) =
        (find("detect"), find("recovery"), find("solver"), find("rollback"), find("replay"));
    assert_eq!(
        (detect.len(), recovery.len(), solver.len(), rollback.len(), replay.len()),
        (1, 1, 1, 1, 1),
        "one timeline per recovery"
    );
    assert_eq!(detect[0].arg("procs"), Some(1), "one failure detected");

    // Nesting: detection precedes the recovery span; every phase is
    // contained in it; replay strictly follows rollback.
    assert!(detect[0].ts_ns <= recovery[0].ts_ns, "detect precedes recovery");
    for phase in [solver[0], rollback[0], replay[0]] {
        assert!(recovery[0].contains(phase), "{} nests inside recovery", phase.name);
    }
    assert!(rollback[0].end_ns() <= replay[0].ts_ns, "replay follows rollback");

    // Per-processor rollback instants match the Fig. 6 plan exactly:
    // one per non-⊤ frontier, inside the rollback span.
    let per_proc = find("rollback_proc");
    assert_eq!(per_proc.len(), rep.plan.rolled_back().len());
    assert_eq!(per_proc.len(), 1);
    assert_eq!(per_proc[0].arg("proc"), Some(victim.0 as u64));
    assert!(rollback[0].contains(per_proc[0]), "per-proc rollback inside the rollback span");

    // Span counters agree with the report.
    assert_eq!(solver[0].arg("procs"), Some(rep.plan.f.len() as u64));
    assert_eq!(replay[0].arg("records"), Some(rep.replayed as u64));
    assert_eq!(recovery[0].arg("replayed"), Some(rep.replayed as u64));
    assert_eq!(
        recovery[0].arg("procs_rolled_back"),
        Some((rep.restored_from_checkpoint + rep.reset_to_empty) as u64)
    );
    assert_eq!(recovery[0].arg("replayed_total"), Some(p.sys.stats.messages_replayed));
    assert_eq!(recovery[0].arg("rolled_back_total"), Some(p.sys.stats.procs_rolled_back));

    // Finish the run: the traced execution's observable output is
    // byte-identical to the untraced failure-free one.
    for r in &recs[RECORDS / 2..] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.sys.advance_input(src, Time::epoch(3));
    p.run(5_000_000);
    for ep in 3..EPOCHS {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(clean, canonical_output(&p.sys, p.collect_proc()), "tracing is observation-only");
}

/// Observability satellite, decomposed edition: a traced two-shard
/// kill-and-recover at T = 4 emits the *per-worker* recovery timeline.
/// The coordinator (tid 0) still owns the single enclosing `recovery`
/// span and the `solver` span; the rollback work appears as per-worker
/// `rollback` sub-spans on the worker tids (group + 1), one per shard
/// group that restores — with victims count#0 and count#3 that is
/// exactly groups 0 and 3. Every worker sub-span and every per-processor
/// `rollback_proc` instant must nest inside the coordinator's recovery
/// span, replay on a worker must follow that worker's rollback, and the
/// `recovery_parallelism` / `replay_workers` gauges must agree with the
/// span census. The traced, decomposed-recovered run stays
/// byte-identical to the sequential failure-free one.
#[test]
fn traced_parallel_recovery_emits_per_worker_timeline() {
    use falkirk::trace::Tracer;
    let seed = 7;
    let seq_cfg = ShardedConfig { workers: 4, ..Default::default() };
    let (clean, _, _) = drive(&seq_cfg, seed, None);
    let cfg = ShardedConfig { threads: 4, ..seq_cfg };
    let mut p = pipeline(&cfg);
    let tracer = Tracer::new();
    p.sys.set_tracer(Some(tracer.clone()));
    let src = p.src_proc();
    for ep in 0..2u64 {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }

    // Open epoch 2, push half the batch, step partway into the exchange,
    // then crash count#0 and count#3 — shard groups 0 and 3 at T = 4.
    let recs = epoch_records(seed, 2, RECORDS, KEYS);
    p.sys.advance_input(src, Time::epoch(2));
    for r in &recs[..RECORDS / 2] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.run(60);
    let victims = [p.plan.proc(p.count, 0), p.plan.proc(p.count, 3)];
    p.sys.inject_failures(&victims);
    let (groups, threads) = (p.groups.clone(), p.threads);
    let rep = p.sys.recover_parallel(&groups, threads);
    assert_eq!(rep.plan.rolled_back().len(), 2, "both victims roll back");
    assert!(rep.replayed > 0, "the in-flight epoch replays");

    let evs = tracer.events();
    assert!(evs.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns), "events sorted by start");
    let find = |name: &str| {
        evs.iter().filter(|e| e.cat == "recovery" && e.name == name).collect::<Vec<_>>()
    };
    let (detect, recovery, solver) = (find("detect"), find("recovery"), find("solver"));
    assert_eq!(
        (detect.len(), recovery.len(), solver.len()),
        (1, 1, 1),
        "one coordinator timeline per recovery"
    );
    assert_eq!(detect[0].arg("procs"), Some(2), "two failures detected");
    assert_eq!(recovery[0].tid, 0, "the enclosing recovery span belongs to the coordinator");
    assert_eq!(solver[0].tid, 0, "the Fig. 6 solve runs on the coordinator");
    assert!(recovery[0].contains(solver[0]), "solver nests inside recovery");

    // Rollback decomposes onto the workers: exactly one `rollback`
    // sub-span per restoring shard group (groups 0 and 3 → tids 1 and
    // 4), each nested in the coordinator's recovery span, together
    // accounting for both restored processors.
    let rollback = find("rollback");
    assert_eq!(rollback.len(), 2, "one rollback sub-span per restoring worker");
    for rb in &rollback {
        assert!(rb.tid >= 1, "rollback runs on a worker tid, not the coordinator");
        assert!(recovery[0].contains(rb), "worker rollback nests inside recovery");
    }
    let rb_tids: Vec<u32> = rollback.iter().map(|e| e.tid).collect();
    assert!(rb_tids.contains(&1) && rb_tids.contains(&4), "groups 0 and 3 restore");
    let restored: u64 = rollback.iter().filter_map(|e| e.arg("procs")).sum();
    assert_eq!(restored, 2, "the worker sub-spans account for both victims");

    // Per-processor rollback instants: one per victim, emitted by the
    // owning worker, inside the recovery span.
    let per_proc = find("rollback_proc");
    assert_eq!(per_proc.len(), rep.plan.rolled_back().len());
    let mut instant_procs: Vec<u64> = per_proc.iter().filter_map(|e| e.arg("proc")).collect();
    instant_procs.sort_unstable();
    let mut victim_ids: Vec<u64> = victims.iter().map(|v| v.0 as u64).collect();
    victim_ids.sort_unstable();
    assert_eq!(instant_procs, victim_ids, "one instant per victim, from its owner");
    for i in &per_proc {
        assert!(i.tid >= 1, "instants come from the owning worker");
        assert!(recovery[0].contains(i), "instants land inside the recovery span");
    }

    // Replay fans out on the workers too: here only group 0 owns a
    // replaying source (the logical `src`), and its records tally to the
    // report. On any one worker, replay follows that worker's rollback.
    let replay = find("replay");
    assert!(!replay.is_empty(), "at least one worker replays");
    let replayed: u64 = replay.iter().filter_map(|e| e.arg("records")).sum();
    assert_eq!(replayed, rep.replayed as u64, "worker replay spans tally to the report");
    for rp in &replay {
        assert!(rp.tid >= 1, "replay runs on a worker tid");
        assert!(recovery[0].contains(rp), "worker replay nests inside recovery");
        for rb in &rollback {
            if rb.tid == rp.tid {
                assert!(rb.end_ns() <= rp.ts_ns, "per-worker replay follows its rollback");
            }
        }
    }

    // The gauges agree with the span census.
    assert_eq!(p.sys.stats.recovery_parallelism, 2, "two workers restored in parallel");
    assert!(p.sys.stats.replay_workers >= 1, "at least one worker replayed");
    assert_eq!(recovery[0].arg("replayed"), Some(rep.replayed as u64));
    assert_eq!(
        recovery[0].arg("procs_rolled_back"),
        Some((rep.restored_from_checkpoint + rep.reset_to_empty) as u64)
    );

    // Finish the run: decomposed recovery under tracing is still
    // byte-identical to the sequential failure-free run.
    for r in &recs[RECORDS / 2..] {
        p.sys.push_input(src, Time::epoch(2), r.clone());
    }
    p.sys.advance_input(src, Time::epoch(3));
    p.run(5_000_000);
    for ep in 3..EPOCHS {
        drive_epoch(&mut p, seed, ep, RECORDS, KEYS);
    }
    p.sys.close_input(src);
    p.run(5_000_000);
    assert_eq!(clean, canonical_output(&p.sys, p.collect_proc()), "tracing is observation-only");
}
