//! Integration tests for the paper's Figure 7: the three worked rollback
//! examples, run end-to-end through the harness (not just the solver).

use falkirk::baselines::{exactly_once, spark_lineage};
use falkirk::engine::{Delivery, Processor, Record};
use falkirk::ft::{FtSystem, Policy, Store};
use falkirk::graph::{GraphBuilder, Projection};
use falkirk::operators::{shared_vec, Egress, Feedback, Ingress, Sink, Source};
use falkirk::time::{Time, TimeDomain};
use std::sync::Arc;

/// Panel (a): sequence numbers, everyone logs. After the middle processor
/// fails, non-failed processors keep their state; the failed one is
/// restored and upstream logs resupply exactly the undone messages.
#[test]
fn panel_a_seq_numbers_log_everything() {
    let mut sc = exactly_once(1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    for i in 1..=10 {
        sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(i));
    }
    sc.sys.run_to_quiescence(100_000);
    let before = sc.out.lock().unwrap().clone();
    assert_eq!(before.len(), 10);

    sc.sys.inject_failures(&[sc.mid]);
    let rep = sc.sys.recover();
    // The failed accumulator restored to its last per-event checkpoint
    // (all 10 events) — nothing replays, nothing re-executes.
    assert!(!rep.plan.f[sc.mid.0 as usize].is_bottom());
    assert!(rep.plan.f[sc.src.0 as usize].is_top(), "upstream untouched");
    assert!(rep.plan.f[sc.sink_proc.0 as usize].is_top(), "downstream untouched");
    sc.sys.run_to_quiescence(100_000);
    assert_eq!(sc.out.lock().unwrap().clone(), before, "no duplicates, no loss");

    // Continue streaming: sums continue from the restored state.
    sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(100));
    sc.sys.run_to_quiescence(100_000);
    let last = sc.out.lock().unwrap().last().unwrap().1.clone();
    assert_eq!(last, Record::kv(0, (1..=10).sum::<i64>() as f64 + 100.0));
}

/// Panel (b): epochs/Spark. p (the RDD) logged all outputs; x,y stateless
/// compute stages. When y fails, x and y restart from the logged edge;
/// p, q, r upstream of the firewall are untouched.
#[test]
fn panel_b_spark_rdd_firewall() {
    let mut sc = spark_lineage(1);
    sc.sys.advance_input(sc.src, Time::epoch(0));
    for i in 0..10 {
        sc.sys.push_input(sc.src, Time::epoch(0), Record::Int(i));
    }
    sc.sys.advance_input(sc.src, Time::epoch(1));
    sc.sys.run_to_quiescence(100_000);
    let n_before = sc.out.lock().unwrap().len();

    sc.sys.inject_failures(&[sc.sink_proc]);
    let rep = sc.sys.recover();
    assert!(rep.plan.f[sc.src.0 as usize].is_top(), "src untouched (Fig 7b)");
    assert!(rep.plan.f[sc.mid.0 as usize].is_top(), "rdd untouched (Fig 7b)");
    assert!(rep.plan.f[sc.sink_proc.0 as usize].is_bottom(), "failed stage restarts empty");
    assert_eq!(rep.replayed, 10, "the logged partition is re-sent");
    sc.sys.run_to_quiescence(100_000);
    assert_eq!(sc.out.lock().unwrap().len(), n_before + 10, "stage recomputed");
}

/// Panel (c): the Naiad loop. q (here `p`) logs messages entering the
/// loop; when the downstream consumer fails, the loop rolls back to ∅
/// and restarts from the logged time-(0,0) message, while p itself is
/// untouched.
#[test]
fn panel_c_loop_restart() {
    struct Body;
    impl Processor for Body {
        fn on_message(&mut self, _p: usize, _t: Time, d: Record, ctx: &mut falkirk::engine::Ctx) {
            let v = d.as_int().unwrap() + 1;
            ctx.send(0, Record::Int(v));
            ctx.send(1, Record::Int(v));
        }
    }
    let d1 = TimeDomain::Structured { depth: 1 };
    let mut g = GraphBuilder::new();
    let p = g.add_proc("p", TimeDomain::EPOCH);
    let ing = g.add_proc("ingress", d1);
    let body = g.add_proc("body", d1);
    let fb = g.add_proc("feedback", d1);
    let eg = g.add_proc("egress", TimeDomain::EPOCH);
    let y = g.add_proc("y", TimeDomain::EPOCH);
    g.connect(p, ing, Projection::LoopEnter);
    g.connect(ing, body, Projection::Identity);
    g.connect(body, fb, Projection::Identity);
    g.connect(fb, body, Projection::LoopFeedback);
    g.connect(body, eg, Projection::LoopExit);
    g.connect(eg, y, Projection::Identity);
    let out = shared_vec();
    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),
        Box::new(Ingress),
        Box::new(Body),
        Box::new(Feedback::new(3)),
        Box::new(Egress),
        Box::new(Sink(out.clone())),
    ];
    let mut sys = FtSystem::new(
        Arc::new(g.build().unwrap()),
        procs,
        vec![
            Policy::LogOutputs,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
            Policy::Ephemeral,
        ],
        Delivery::Fifo,
        Store::new(1),
    );
    sys.advance_input(p, Time::epoch(0));
    sys.push_input(p, Time::epoch(0), Record::Int(0));
    sys.advance_input(p, Time::epoch(1));
    sys.run_to_quiescence(100_000);
    let before = out.lock().unwrap().clone();
    assert_eq!(
        before.iter().map(|(_, r)| r.as_int().unwrap()).collect::<Vec<_>>(),
        vec![1, 2, 3],
        "three loop iterations exit at epoch 0"
    );

    sys.inject_failures(&[y]);
    let rep = sys.recover();
    assert!(rep.plan.f[p.0 as usize].is_top(), "p does not roll back (its log suffices)");
    for q in [ing, body, fb, eg, y] {
        assert!(rep.plan.f[q.0 as usize].is_bottom(), "loop member rolls to ∅");
    }
    assert_eq!(rep.replayed, 1, "the logged entry message restarts the loop");
    out.lock().unwrap().clear();
    sys.run_to_quiescence(100_000);
    let after = out.lock().unwrap().clone();
    assert_eq!(after, before, "the restarted loop reproduces the same values");
}
