//! Store-level backend equivalence: the file-backed WAL must be
//! observably identical to the in-memory default through the `Store`
//! handle — same get/scan/resident answers under randomized workloads —
//! plus durability behaviors only the WAL has (reopen, torn tails).

use falkirk::ft::{FileBackendOptions, Key, Kind, Store};
use falkirk::util::rng::Rng;
use falkirk::util::tmp::TempDir;

const KINDS: [Kind; 7] = [
    Kind::Meta,
    Kind::State,
    Kind::LogEntry,
    Kind::HistoryEvent,
    Kind::InputFrontier,
    Kind::Chunk,
    Kind::Snapshot,
];

fn random_blob(rng: &mut Rng) -> Vec<u8> {
    let n = rng.below(200) as usize;
    (0..n).map(|i| (rng.below(256) as u8).wrapping_add(i as u8)).collect()
}

/// Apply an identical randomized op sequence to both stores and compare
/// every observable.
#[test]
fn mem_and_file_stores_are_observably_identical() {
    let t = TempDir::new("parity");
    let mem = Store::new(3);
    let file = Store::open_dir(
        t.path(),
        3,
        FileBackendOptions {
            flush_every_n: 4,
            segment_bytes: 4096, // force rotation mid-sequence
            compact_ratio: 0.5,
            fsync: false,
        },
    )
    .unwrap();

    let mut rng = Rng::new(42);
    let mut live: Vec<Key> = Vec::new();
    for step in 0..600 {
        let proc = rng.below(5) as u32;
        let kind = KINDS[rng.index(KINDS.len())];
        let tag = rng.below(40);
        let key = Key { proc, kind, tag };
        if step % 5 == 4 && !live.is_empty() {
            let victim = live.swap_remove(rng.index(live.len()));
            mem.delete(&victim);
            file.delete(&victim);
        } else {
            let blob = random_blob(&mut rng);
            mem.put(key.clone(), blob.clone());
            file.put(key.clone(), blob);
            live.push(key);
        }
    }

    assert_eq!(mem.resident_bytes(), file.resident_bytes(), "resident-byte counters agree");
    assert_eq!(mem.procs(), file.procs(), "distinct processor sets agree");
    for proc in 0..6u32 {
        assert_eq!(mem.scan_keys(proc), file.scan_keys(proc), "proc {proc} key sets agree");
        assert_eq!(
            mem.scan_entries(proc),
            file.scan_entries(proc),
            "proc {proc} size metadata agrees"
        );
        for kind in KINDS {
            assert_eq!(mem.keys_for(proc, kind), file.keys_for(proc, kind));
        }
        for k in mem.scan_keys(proc) {
            assert_eq!(mem.get(&k), file.get(&k), "value at {k:?} agrees");
        }
    }
    let (ms, fs) = (mem.stats(), file.stats());
    assert_eq!(ms.writes, fs.writes);
    assert_eq!(ms.bytes_written, fs.bytes_written);
    assert_eq!(ms.deletes, fs.deletes);
    assert_eq!(mem.backend_info().live_keys, file.backend_info().live_keys);
    assert_eq!(mem.backend_info().live_bytes, file.backend_info().live_bytes);

    // …and the whole state survives a reopen byte-for-byte.
    drop(file);
    let reopened = Store::open_dir(t.path(), 3, FileBackendOptions::default()).unwrap();
    assert_eq!(mem.resident_bytes(), reopened.resident_bytes());
    for proc in 0..6u32 {
        for k in mem.scan_keys(proc) {
            assert_eq!(mem.get(&k), reopened.get(&k), "reopened value at {k:?}");
        }
        assert_eq!(mem.scan_keys(proc), reopened.scan_keys(proc));
    }
}

/// Acknowledged-but-buffered writes are readable through the handle
/// (group commit flushes on demand), and `sync` makes them crash-proof.
#[test]
fn group_commit_reads_and_sync() {
    let t = TempDir::new("group-commit");
    let store = Store::open_dir(
        t.path(),
        0,
        FileBackendOptions { flush_every_n: 100, ..Default::default() },
    )
    .unwrap();
    let k = Key { proc: 1, kind: Kind::State, tag: 1 };
    store.put(k.clone(), vec![1, 2, 3]);
    assert_eq!(store.get(&k), Some(vec![1, 2, 3]), "buffered write is readable");
    let k2 = Key { proc: 1, kind: Kind::State, tag: 2 };
    store.put(k2.clone(), vec![9]);
    store.sync();
    store.simulate_crash(); // post-sync crash loses nothing
    drop(store);
    let reopened = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
    assert_eq!(reopened.get(&k), Some(vec![1, 2, 3]));
    assert_eq!(reopened.get(&k2), Some(vec![9]));
}

/// An unsynced tail dies with a crash — and the survivor set is always a
/// prefix of the acknowledged writes (never a gap).
#[test]
fn crash_casualties_are_a_suffix() {
    let t = TempDir::new("suffix");
    {
        let store = Store::open_dir(
            t.path(),
            0,
            FileBackendOptions { flush_every_n: 7, ..Default::default() },
        )
        .unwrap();
        for tag in 0..20u64 {
            store.put(Key { proc: 0, kind: Kind::LogEntry, tag }, vec![tag as u8]);
        }
        store.simulate_crash();
    }
    let reopened = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
    let survivors: Vec<u64> =
        reopened.keys_for(0, Kind::LogEntry).into_iter().map(|k| k.tag).collect();
    // 20 writes at width 7 → 14 flushed, 6 lost.
    assert_eq!(survivors, (0..14).collect::<Vec<u64>>(), "suffix-only loss");
}

/// A value over the WAL's record-size limit is refused as a recoverable
/// error through `try_put` — nothing persisted, nothing accounted — and
/// the store keeps working.
#[test]
fn oversized_value_is_a_recoverable_error() {
    let t = TempDir::new("oversize");
    let store = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
    let k = Key { proc: 0, kind: Kind::State, tag: 1 };
    let huge = vec![0u8; (64 << 20) + 1]; // past the 64 MiB record limit
    assert!(store.try_put(k.clone(), huge).is_err());
    assert_eq!(store.get(&k), None);
    assert_eq!(store.stats().writes, 0, "a refused write is not acknowledged");
    assert_eq!(store.resident_bytes(), 0);
    store.put(k.clone(), vec![1, 2, 3]); // ordinary writes still fine
    assert_eq!(store.get(&k), Some(vec![1, 2, 3]));
    // The mem backend has no record format, hence no limit.
    let mem = Store::new(0);
    assert!(mem.try_put(k.clone(), vec![0u8; (64 << 20) + 1]).is_ok());
}

/// `resident_bytes` is maintained, not recomputed — and a reopened WAL
/// seeds the counter from its live index.
#[test]
fn resident_counter_survives_reopen() {
    let t = TempDir::new("resident");
    {
        let store = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
        store.put(Key { proc: 0, kind: Kind::State, tag: 1 }, vec![0; 100]);
        store.put(Key { proc: 0, kind: Kind::State, tag: 1 }, vec![0; 40]); // overwrite
        store.put(Key { proc: 1, kind: Kind::State, tag: 2 }, vec![0; 10]);
        store.delete(&Key { proc: 1, kind: Kind::State, tag: 2 });
        assert_eq!(store.resident_bytes(), 40);
    }
    let store = Store::open_dir(t.path(), 0, FileBackendOptions::default()).unwrap();
    assert_eq!(store.resident_bytes(), 40);
    assert_eq!(store.backend_info().live_keys, 1);
}
