//! Parallel multi-threaded shard execution: output equivalence with the
//! sequential engine.
//!
//! The contract under test: for any thread count T and batch cap B, the
//! parallel executor produces the same *observable* output as the
//! sequential engine — byte-identical canonical collector contents for
//! the FT-backed workload, and identical per-time record multisets for
//! the engine-only pipeline. Per-shard delivery order equals the
//! sequential round-robin restricted to the shard, and cross-shard
//! arrival order (which a keyed exchange does not define) is quotiented
//! away by the canonicalization — the same comparison the recovery suite
//! uses.

use falkirk::bench_support::sharded::{
    canonical_output, drive_workload, pipeline, ShardedConfig,
};
use falkirk::engine::{Delivery, ProcFactory, Record, ShardedEngine};
use falkirk::ft::PersistMode;
use falkirk::graph::{EdgeId, Projection};
use falkirk::operators::{shared_vec, CountByKey, Sink, Source};
use falkirk::time::{Time, TimeDomain};
use falkirk::ShardedBuilder;
use std::sync::Arc;

const EPOCHS: u64 = 3;
const RECORDS: usize = 64;
const KEYS: u64 = 16;

/// Drive the standard FT-backed workload and return its canonical output.
fn ft_output(threads: usize, batch_cap: usize, two_stage: bool, workers: u32) -> Vec<u8> {
    let mut p = pipeline(&ShardedConfig {
        workers,
        two_stage,
        batch_cap,
        threads,
        ..Default::default()
    });
    let tp = drive_workload(&mut p, 11, EPOCHS, RECORDS, KEYS);
    assert_eq!(tp.records, EPOCHS * RECORDS as u64);
    assert!(
        p.sys.engine.is_quiescent(),
        "parallel drain returned non-quiescent (threads={threads})"
    );
    canonical_output(&p.sys, p.collect_proc())
}

/// The acceptance grid: threads ∈ {1,2,4,8} × batch_cap ∈ {1,8,64} must
/// produce byte-identical merged output to the sequential engine.
#[test]
fn parallel_output_matches_sequential_across_threads_and_caps() {
    for two_stage in [false, true] {
        for batch_cap in [1usize, 8, 64] {
            let base = ft_output(1, batch_cap, two_stage, 8);
            assert!(!base.is_empty());
            for threads in [2usize, 4, 8] {
                let got = ft_output(threads, batch_cap, two_stage, 8);
                assert_eq!(
                    base, got,
                    "output diverged: threads={threads} batch_cap={batch_cap} \
                     two_stage={two_stage}"
                );
            }
        }
    }
}

/// The same workload with the FT write path taken off the compute hot
/// path: workers stage writes for the background persistence writer
/// instead of blocking on the store, and the observable output must stay
/// byte-identical to the synchronous single-threaded run across the
/// thread × cap grid.
#[test]
fn parallel_output_matches_sequential_under_async_persistence() {
    for batch_cap in [1usize, 8] {
        let base = ft_output(1, batch_cap, true, 8);
        for threads in [2usize, 4] {
            for ack_every in [1usize, 8] {
                let mut p = pipeline(&ShardedConfig {
                    workers: 8,
                    two_stage: true,
                    batch_cap,
                    threads,
                    persist_mode: PersistMode::Async { ack_every },
                    ..Default::default()
                });
                let tp = drive_workload(&mut p, 11, EPOCHS, RECORDS, KEYS);
                assert_eq!(tp.records, EPOCHS * RECORDS as u64);
                assert!(p.sys.engine.is_quiescent());
                assert_eq!(
                    base,
                    canonical_output(&p.sys, p.collect_proc()),
                    "async persistence diverged: threads={threads} cap={batch_cap} \
                     ack_every={ack_every}"
                );
                // The parallel drain's quiescence barrier settles the
                // writer: nothing staged may remain once workers park.
                assert_eq!(p.sys.ack_lag(), 0, "drain must end with a settled pipeline");
            }
        }
    }
}

/// The backpressure grid: threads {1,2,4} × batch caps {1,8,64} ×
/// mailbox caps {2,64,∞}. Credit can defer deliveries, never deny them,
/// so a bounded hot path must reach quiescence in every cell and produce
/// the same observable output as the unbounded sequential run — caps 1–2
/// run the engine permanently gated (every round ends in parked or
/// forced deliveries), which is exactly the regime the fuzz corpus seeds
/// pin.
#[test]
fn output_is_invariant_under_mailbox_caps() {
    let run = |threads: usize, batch_cap: usize, mailbox_cap: Option<usize>| -> Vec<u8> {
        let mut p = pipeline(&ShardedConfig {
            workers: 8,
            two_stage: true,
            batch_cap,
            threads,
            mailbox_cap,
            ..Default::default()
        });
        let tp = drive_workload(&mut p, 11, EPOCHS, RECORDS, KEYS);
        assert_eq!(tp.records, EPOCHS * RECORDS as u64);
        assert!(
            p.sys.engine.is_quiescent(),
            "capped drain wedged: threads={threads} batch_cap={batch_cap} \
             mailbox_cap={mailbox_cap:?}"
        );
        canonical_output(&p.sys, p.collect_proc())
    };
    let base = run(1, 8, None);
    assert!(!base.is_empty());
    for threads in [1usize, 2, 4] {
        for batch_cap in [1usize, 8, 64] {
            for mailbox_cap in [Some(2usize), Some(64), None] {
                assert_eq!(
                    base,
                    run(threads, batch_cap, mailbox_cap),
                    "output diverged: threads={threads} batch_cap={batch_cap} \
                     mailbox_cap={mailbox_cap:?}"
                );
            }
        }
    }
}

/// Skewed-key stress: every record carries the same key, funnelling both
/// whole epochs through one map shard and one count shard while the
/// mailbox budget sits at a small fraction of the epoch size. Three
/// obligations per cell: the drain completes (no deadlock — forced
/// rounds release the hot feedback edge), the output is byte-identical
/// to the unbounded run, and peak *interior* queue residency respects
/// the credit bound — a gated delivery finds its destination's
/// out-queues below the cap and overshoots by at most its own emission,
/// with forced-round / advisory-occupancy slack on top — far below the
/// epoch-sized pile-up an unbounded run could park on one edge.
#[test]
fn hot_key_slow_sink_is_bounded_and_deadlock_free() {
    const HOT_RECORDS: usize = 512;
    const CAP: usize = 4;
    const BATCH: usize = 8;
    let run = |mailbox_cap: Option<usize>, threads: usize| -> (Vec<u8>, usize) {
        let mut p = pipeline(&ShardedConfig {
            workers: 4,
            two_stage: true,
            batch_cap: BATCH,
            threads,
            mailbox_cap,
            ..Default::default()
        });
        let src = p.src_proc();
        for ep in 0..2u64 {
            p.sys.advance_input(src, Time::epoch(ep));
            for i in 0..HOT_RECORDS {
                p.sys.push_input(src, Time::epoch(ep), Record::kv(0, (i % 10) as f64));
            }
            p.sys.advance_input(src, Time::epoch(ep + 1));
            p.run(5_000_000);
        }
        p.sys.close_input(src);
        p.run(5_000_000);
        assert!(
            p.sys.engine.is_quiescent(),
            "hot-key drain wedged: threads={threads} mailbox_cap={mailbox_cap:?}"
        );
        // Interior residency only: external pushes land on the source's
        // out-edges before any drain runs (input is never refused), so
        // the budget governs every edge downstream of a gated delivery.
        let topo = &p.plan.topo;
        let interior_peak = (0..topo.num_edges() as u32)
            .map(EdgeId)
            .filter(|&e| topo.src(e) != src)
            .map(|e| p.sys.engine.channel(e).peak_records())
            .max()
            .expect("pipeline has interior edges");
        (canonical_output(&p.sys, p.collect_proc()), interior_peak)
    };
    let (base, _) = run(None, 1);
    assert!(!base.is_empty());
    for threads in [1usize, 4] {
        let (out, peak) = run(Some(CAP), threads);
        assert_eq!(out, base, "backpressure changed hot-key output (threads={threads})");
        assert!(
            peak <= CAP + 4 * BATCH,
            "interior queue exceeded the credit bound: peak={peak} records \
             (cap={CAP} batch={BATCH} threads={threads})"
        );
    }
}

/// Two identical parallel runs agree byte for byte (the canonical output
/// is a pure function of the workload, not of thread scheduling).
#[test]
fn parallel_execution_is_deterministic() {
    let a = ft_output(4, 8, true, 8);
    let b = ft_output(4, 8, true, 8);
    assert_eq!(a, b);
}

/// More threads than shards: the surplus groups stay empty and the
/// result is unchanged.
#[test]
fn thread_count_may_exceed_shard_count() {
    let base = ft_output(1, 8, true, 2);
    assert_eq!(base, ft_output(8, 8, true, 2));
}

/// Engine-level (no FT harness): a sharded keyed aggregation drained via
/// `ShardedEngine::run_to_quiescence_parallel` matches the sequential
/// engine's per-key sums at every thread count.
#[test]
fn engine_only_parallel_matches_sequential() {
    let run = |threads: usize| -> Vec<(i64, f64)> {
        let mut b = ShardedBuilder::new();
        let src = b.add_proc("src", TimeDomain::EPOCH);
        let count = b.add_sharded("count", TimeDomain::EPOCH, 4);
        let col = b.add_proc("collect", TimeDomain::EPOCH);
        b.connect(src, count, Projection::Identity);
        b.connect(count, col, Projection::Identity);
        let plan = Arc::new(b.build().unwrap());
        let out = shared_vec();
        let out2 = out.clone();
        let factories: Vec<ProcFactory> = vec![
            Box::new(|_| Box::new(Source)),
            Box::new(|_| Box::new(CountByKey::default())),
            Box::new(move |_| Box::new(Sink(out2.clone()))),
        ];
        let mut eng = ShardedEngine::new(plan, factories, Delivery::Fifo);
        let src = eng.plan.find("src").unwrap();
        for ep in 0..2u64 {
            eng.advance_input(src, Time::epoch(ep));
            for k in 0..24i64 {
                eng.push_input(src, Time::epoch(ep), Record::kv(k % 7, (k + 1) as f64));
            }
            eng.advance_input(src, Time::epoch(ep + 1));
            eng.run_to_quiescence_parallel(threads, 1_000_000);
        }
        eng.close_input(src);
        eng.run_to_quiescence_parallel(threads, 1_000_000);
        let mut got: Vec<(i64, f64)> = out
            .lock()
            .unwrap()
            .iter()
            .map(|(_, r)| r.as_kv().unwrap())
            .collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        got
    };
    let base = run(1);
    assert!(!base.is_empty());
    for threads in [2usize, 4, 8] {
        assert_eq!(base, run(threads), "threads={threads}");
    }
}

/// A bounded parallel drain (step budget) leaves a consistent engine:
/// the sequential engine can finish the work and the output still
/// matches.
#[test]
fn budgeted_parallel_drain_resumes_sequentially() {
    let clean = ft_output(1, 8, true, 4);
    let mut p = pipeline(&ShardedConfig {
        workers: 4,
        two_stage: true,
        batch_cap: 8,
        threads: 4,
        ..Default::default()
    });
    let src = p.src_proc();
    for ep in 0..EPOCHS {
        p.sys.advance_input(src, Time::epoch(ep));
        for r in falkirk::bench_support::sharded::epoch_records(11, ep, RECORDS, KEYS) {
            p.sys.push_input(src, Time::epoch(ep), r);
        }
        p.sys.advance_input(src, Time::epoch(ep + 1));
        // Tiny budget: the drain parks mid-exchange; spilled mailbox
        // traffic must re-enter the channels with accounting intact.
        p.sys.run_to_quiescence_parallel(&p.groups, 4, 25);
        // Finish the epoch on the sequential engine.
        p.sys.run_to_quiescence(5_000_000);
    }
    p.sys.close_input(src);
    p.sys.run_to_quiescence(5_000_000);
    assert!(p.sys.engine.is_quiescent());
    assert_eq!(clean, canonical_output(&p.sys, p.collect_proc()));
}
