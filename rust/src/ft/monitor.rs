//! The garbage-collection monitoring service (§4.2).
//!
//! Processors report Ξ(p,f) once storage acknowledges a checkpoint; the
//! monitor runs an *incremental* version of the Fig. 6 fixed point over
//! the durably-persisted availability (no ⊤ — the low-watermark must hold
//! in every failure scenario) and pushes low-watermark advances back out:
//! `p` may garbage-collect Ξ(p,f′) and S(p,f′) for f′ ⊂ f, and every
//! processor sending to `p` may discard logged messages with times inside
//! the watermark. The same watermark drives external input
//! acknowledgement and output-side state release (§4.3, see
//! [`crate::ft::external`]).
//!
//! Because storage is assumed reliable, the watermark is a true low bound:
//! no failure scenario can force a rollback beyond it. The monitor is
//! deterministic and monotone, so (as the paper notes) it could itself be
//! replicated; our implementation is a plain struct.

use crate::frontier::Frontier;
use crate::ft::meta::CkptMeta;
use crate::ft::rollback::{choose_frontiers, grow_frontiers, Available, RollbackInput, RollbackPlan};
use crate::graph::{ProcId, Topology};
use std::sync::Arc;

/// A garbage-collection instruction produced by a watermark advance.
#[derive(Clone, Debug, PartialEq)]
pub enum GcAction {
    /// `proc` may drop checkpoints with frontiers strictly below the
    /// watermark (keeping the newest one at or below it).
    DropCheckpointsBelow { proc: ProcId, watermark: Frontier },
    /// `proc` may drop logged messages on `edge` whose *message* times lie
    /// inside the destination's watermark.
    DropLogWithin { proc: ProcId, edge: crate::graph::EdgeId, watermark: Frontier },
}

/// The monitoring service.
pub struct Monitor {
    topo: Arc<Topology>,
    /// Durably persisted availability per processor (chains only; Any
    /// for the §3.4 stateless class, which never persists anything).
    avail: Vec<Available>,
    /// Current low-watermark solution.
    plan: RollbackPlan,
    /// Updates processed (benchmarks).
    pub updates: u64,
}

impl Monitor {
    /// `stateless[p]` marks processors of the restore-anywhere class
    /// (with `logs[p]` saying whether they log durably).
    pub fn new(topo: Arc<Topology>, stateless: Vec<bool>, logs: Vec<bool>) -> Monitor {
        let avail: Vec<Available> = (0..topo.num_procs())
            .map(|i| {
                if stateless[i] {
                    Available::any(logs[i])
                } else {
                    Available::chain(vec![])
                }
            })
            .collect();
        let plan = {
            let input = RollbackInput { topo: &topo, avail: &avail };
            choose_frontiers(&input)
        };
        Monitor { topo, avail, plan, updates: 0 }
    }

    /// Rebuild a monitor after a cold restart from the durably-reopened
    /// checkpoint chains (`chains[p]` = the Ξ records recovered for `p`;
    /// empty for stateless processors). Equivalent to replaying every Ξ
    /// through [`Monitor::on_persisted`], minus the incremental GC
    /// actions — those already happened in the previous life.
    pub fn reopen(
        topo: Arc<Topology>,
        stateless: Vec<bool>,
        logs: Vec<bool>,
        chains: Vec<Vec<CkptMeta>>,
    ) -> Monitor {
        assert_eq!(chains.len(), topo.num_procs());
        let avail: Vec<Available> = chains
            .into_iter()
            .enumerate()
            .map(|(i, chain)| {
                if stateless[i] {
                    debug_assert!(chain.is_empty(), "stateless processors persist no Ξ");
                    Available::any(logs[i])
                } else {
                    Available::chain(chain)
                }
            })
            .collect();
        let plan = {
            let input = RollbackInput { topo: &topo, avail: &avail };
            choose_frontiers(&input)
        };
        Monitor { topo, avail, plan, updates: 0 }
    }

    /// The current low-watermark at `p`: it will never need to roll back
    /// beyond this frontier in any failure scenario.
    pub fn low_watermark(&self, p: ProcId) -> &Frontier {
        &self.plan.f[p.0 as usize]
    }

    /// Ingest an acknowledged Ξ(p,f); returns the GC actions enabled by
    /// any watermark advances. Runs the incremental fixed point.
    pub fn on_persisted(&mut self, p: ProcId, meta: CkptMeta) -> Vec<GcAction> {
        self.updates += 1;
        match &mut self.avail[p.0 as usize] {
            Available::Chain { chain, .. } => {
                debug_assert!(
                    chain.last().map(|c| c.f.is_subset(&meta.f)).unwrap_or(true),
                    "checkpoint chain must ascend"
                );
                chain.push(meta);
            }
            Available::Any { .. } => {
                panic!("stateless processor {p} reported a checkpoint")
            }
        }
        let grew = {
            let input = RollbackInput { topo: &self.topo, avail: &self.avail };
            grow_frontiers(&input, &mut self.plan, p)
        };
        let mut actions = Vec::new();
        for q in grew {
            let new = &self.plan.f[q.0 as usize];
            actions.push(GcAction::DropCheckpointsBelow {
                proc: q,
                watermark: new.clone(),
            });
            for &d in self.topo.in_edges(q) {
                actions.push(GcAction::DropLogWithin {
                    proc: self.topo.src(d),
                    edge: d,
                    watermark: new.clone(),
                });
            }
        }
        actions
    }

    /// Recompute from scratch (reference implementation; the benches
    /// compare this against the incremental path).
    pub fn recompute_batch(&mut self) {
        let input = RollbackInput { topo: &self.topo, avail: &self.avail };
        self.plan = choose_frontiers(&input);
    }

    pub fn plan(&self) -> &RollbackPlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeId, GraphBuilder, Projection};
    use crate::time::TimeDomain;

    fn epoch_ckpt(e: u64, in_edges: &[EdgeId], out_edges: &[EdgeId]) -> CkptMeta {
        let f = Frontier::upto_epoch(e);
        CkptMeta {
            f: f.clone(),
            n_bar: f.clone(),
            m_bar: in_edges.iter().map(|d| (*d, f.clone())).collect(),
            d_bar: out_edges.iter().map(|o| (*o, f.clone())).collect(),
            phi: out_edges.iter().map(|o| (*o, f.clone())).collect(),
        }
    }

    fn pipeline() -> (Arc<Topology>, Vec<EdgeId>) {
        let mut g = GraphBuilder::new();
        let a = g.add_proc("a", TimeDomain::EPOCH);
        let b = g.add_proc("b", TimeDomain::EPOCH);
        let c = g.add_proc("c", TimeDomain::EPOCH);
        let e0 = g.connect(a, b, Projection::Identity);
        let e1 = g.connect(b, c, Projection::Identity);
        (Arc::new(g.build().unwrap()), vec![e0, e1])
    }

    #[test]
    fn watermark_rises_only_when_all_persist() {
        let (topo, es) = pipeline();
        let mut mon = Monitor::new(topo, vec![false, false, false], vec![false; 3]);
        let (a, b, c) = (ProcId(0), ProcId(1), ProcId(2));
        assert!(mon.low_watermark(b).is_bottom());
        // a persists epoch 1: nothing moves (b, c unpersisted).
        let acts = mon.on_persisted(a, epoch_ckpt(1, &[], &[es[0]]));
        assert!(acts.is_empty());
        assert!(mon.low_watermark(a).is_bottom());
        // b persists epoch 1: still gated by c.
        let acts = mon.on_persisted(b, epoch_ckpt(1, &[es[0]], &[es[1]]));
        assert!(acts.is_empty());
        // c persists epoch 1: the whole pipeline's watermark rises to ↓1.
        let acts = mon.on_persisted(c, epoch_ckpt(1, &[es[1]], &[]));
        assert!(!acts.is_empty());
        for p in [a, b, c] {
            assert_eq!(mon.low_watermark(p), &Frontier::upto_epoch(1));
        }
        // GC actions include dropping b's inbound log at a.
        assert!(acts.iter().any(|x| matches!(
            x,
            GcAction::DropLogWithin { proc, .. } if *proc == a
        )));
    }

    #[test]
    fn incremental_matches_batch() {
        let (topo, es) = pipeline();
        let mut mon = Monitor::new(topo.clone(), vec![false; 3], vec![false; 3]);
        let (a, b, c) = (ProcId(0), ProcId(1), ProcId(2));
        for ep in 1..=5 {
            mon.on_persisted(a, epoch_ckpt(ep, &[], &[es[0]]));
            mon.on_persisted(b, epoch_ckpt(ep, &[es[0]], &[es[1]]));
            mon.on_persisted(c, epoch_ckpt(ep, &[es[1]], &[]));
            let inc = mon.plan().clone();
            mon.recompute_batch();
            assert_eq!(&inc, mon.plan(), "incremental diverged at epoch {ep}");
            assert_eq!(mon.low_watermark(b), &Frontier::upto_epoch(ep));
        }
    }

    #[test]
    fn reopen_matches_replayed_updates() {
        let (topo, es) = pipeline();
        let mut mon = Monitor::new(topo.clone(), vec![false; 3], vec![false; 3]);
        for ep in 1..=3 {
            mon.on_persisted(ProcId(0), epoch_ckpt(ep, &[], &[es[0]]));
            mon.on_persisted(ProcId(1), epoch_ckpt(ep, &[es[0]], &[es[1]]));
            mon.on_persisted(ProcId(2), epoch_ckpt(ep, &[es[1]], &[]));
        }
        // A cold restart hands the monitor the reopened chains wholesale.
        let chains = vec![
            (1..=3).map(|ep| epoch_ckpt(ep, &[], &[es[0]])).collect(),
            (1..=3).map(|ep| epoch_ckpt(ep, &[es[0]], &[es[1]])).collect(),
            (1..=3).map(|ep| epoch_ckpt(ep, &[es[1]], &[])).collect(),
        ];
        let re = Monitor::reopen(topo, vec![false; 3], vec![false; 3], chains);
        assert_eq!(re.plan(), mon.plan(), "reopened watermark equals the replayed one");
        assert_eq!(re.low_watermark(ProcId(1)), &Frontier::upto_epoch(3));
    }

    #[test]
    fn stateless_members_follow_chain_members() {
        let (topo, es) = pipeline();
        // b is a stateless logging firewall.
        let mut mon = Monitor::new(topo, vec![false, true, false], vec![false, true, false]);
        let (a, c) = (ProcId(0), ProcId(2));
        mon.on_persisted(a, epoch_ckpt(2, &[], &[es[0]]));
        mon.on_persisted(c, epoch_ckpt(2, &[es[1]], &[]));
        // b's watermark = φ(a's) ∩ … = ↓2 (it can restore anywhere).
        assert_eq!(mon.low_watermark(ProcId(1)), &Frontier::upto_epoch(2));
    }
}
