//! The Falkirk Wheel fault-tolerance framework (§3–§4).
//!
//! - [`policy`]: per-processor checkpoint/logging policies (Fig. 1 regimes);
//! - [`meta`]: Table-1 checkpoint metadata Ξ(p,f);
//! - [`storage`]: the acknowledged durable-store substrate;
//! - [`harness`]: the system layer observing events and taking selective
//!   checkpoints;
//! - [`rollback`]: the §3.5 constraints and Fig. 6 fixed-point solver;
//! - [`recovery`]: §4.4 failure handling — pause, solve, reset, replay;
//! - [`monitor`]: the §4.2 garbage-collection monitoring service;
//! - [`external`]: §4.3 acknowledged external inputs/outputs.

pub mod external;
pub mod harness;
pub mod meta;
pub mod monitor;
pub mod policy;
pub mod recovery;
pub mod rollback;
pub mod storage;

pub use harness::{FtStats, FtSystem, HistoryEvent};
pub use meta::{CkptMeta, LogEntry, StoredCheckpoint};
pub use policy::Policy;
pub use rollback::{choose_frontiers, verify_plan, Available, RollbackInput, RollbackPlan};
pub use storage::{Key, Kind, Store};
