//! The Falkirk Wheel fault-tolerance framework (§3–§4).
//!
//! - [`policy`]: per-processor checkpoint/logging policies (Fig. 1 regimes);
//! - [`meta`]: Table-1 checkpoint metadata Ξ(p,f);
//! - [`storage`]: the acknowledged durable-store substrate behind the
//!   pluggable [`storage::StorageBackend`] trait;
//! - [`backend_file`]: the on-disk segmented write-ahead-log backend
//!   (group commit, crash-scan reopen, tombstones + compaction);
//! - [`harness`]: the system layer observing events and taking selective
//!   checkpoints, plus cold-restart reconstruction
//!   ([`harness::FtSystem::reopen`]);
//! - [`rollback`]: the §3.5 constraints and Fig. 6 fixed-point solver;
//! - [`recovery`]: §4.4 failure handling — pause, solve, reset, replay;
//! - [`monitor`]: the §4.2 garbage-collection monitoring service;
//! - [`external`]: §4.3 acknowledged external inputs/outputs.

pub mod backend_file;
pub mod external;
pub mod harness;
pub mod meta;
pub mod monitor;
pub mod policy;
pub mod recovery;
pub mod rollback;
pub mod storage;

pub use backend_file::{FileBackend, FileBackendOptions};
pub use harness::{FtStats, FtSystem, HistoryEvent, HistoryKind};
pub use meta::{CkptMeta, LogEntry, MetaRecord, Snapshot, StoredCheckpoint};
pub use policy::{Policy, SnapshotPolicy};
pub use rollback::{choose_frontiers, verify_plan, Available, RollbackInput, RollbackPlan};
pub use storage::{BackendInfo, Key, Kind, PersistMode, StorageBackend, StorageError, Store};
