//! Durable-storage substrate.
//!
//! The paper assumes "reliably persisting state [is] adequately covered by
//! existing techniques" (§1) and builds on acknowledged writes (§4.2: a
//! processor sends Ξ(p,f) to the monitor only after storage acknowledges
//! the checkpoint, state, and log). We model exactly that contract:
//! a key-value blob store with explicit acknowledgement accounting,
//! injectable write latency (in virtual cost units, so benches can charge
//! eager policies for their synchronous writes), and an optional
//! file-system backing for the examples.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A storage key: (processor, kind, discriminator).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub proc: u32,
    pub kind: Kind,
    pub tag: u64,
}

/// What a blob contains.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Checkpoint metadata Ξ(p,f).
    Meta,
    /// Checkpoint state S(p,f).
    State,
    /// A logged message (one entry of L(e,·)).
    LogEntry,
    /// Full-history event (H(p) entry).
    HistoryEvent,
}

/// Write/read accounting, for the policy-overhead benches.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StorageStats {
    pub writes: u64,
    pub bytes_written: u64,
    pub deletes: u64,
    pub reads: u64,
    /// Σ of per-write virtual latency (cost units): eager policies pay
    /// this on the critical path; lazy ones off it.
    pub virtual_latency: u64,
    /// Message-log writes (one per sent *batch* — the batching win on the
    /// durable path is `log_records / log_batches` records amortized per
    /// acknowledged write).
    pub log_batches: u64,
    /// Records covered by those log writes.
    pub log_records: u64,
}

/// In-memory durable store with ack semantics. Cloneable handle.
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<Inner>>,
}

struct Inner {
    blobs: BTreeMap<Key, Vec<u8>>,
    stats: StorageStats,
    /// Virtual cost charged per write (simulates fsync/replication).
    write_cost: u64,
}

impl Store {
    /// A store charging `write_cost` virtual latency units per write.
    pub fn new(write_cost: u64) -> Store {
        Store {
            inner: Arc::new(Mutex::new(Inner {
                blobs: BTreeMap::new(),
                stats: StorageStats::default(),
                write_cost,
            })),
        }
    }

    fn put_inner(&self, key: Key, value: Vec<u8>, log_records: Option<u64>) {
        let mut g = self.inner.lock().unwrap();
        g.stats.writes += 1;
        g.stats.bytes_written += value.len() as u64;
        g.stats.virtual_latency += g.write_cost;
        if let Some(records) = log_records {
            g.stats.log_batches += 1;
            g.stats.log_records += records;
        }
        g.blobs.insert(key, value);
    }

    /// Persist a blob; returns once "acknowledged" (synchronously here,
    /// with the virtual latency charged to the stats).
    pub fn put(&self, key: Key, value: Vec<u8>) {
        self.put_inner(key, value, None);
    }

    /// Persist one message-log blob covering `records` records. Identical
    /// ack semantics to [`Store::put`], plus batch/record accounting so
    /// the policy-overhead benches can report amortization honestly.
    pub fn put_log(&self, key: Key, value: Vec<u8>, records: u64) {
        self.put_inner(key, value, Some(records));
    }

    pub fn get(&self, key: &Key) -> Option<Vec<u8>> {
        let mut g = self.inner.lock().unwrap();
        g.stats.reads += 1;
        g.blobs.get(key).cloned()
    }

    pub fn delete(&self, key: &Key) {
        let mut g = self.inner.lock().unwrap();
        if g.blobs.remove(key).is_some() {
            g.stats.deletes += 1;
        }
    }

    /// Delete all blobs for `proc` matching `pred` (garbage collection).
    pub fn delete_matching<F: FnMut(&Key) -> bool>(&self, proc: u32, mut pred: F) -> usize {
        let mut g = self.inner.lock().unwrap();
        let doomed: Vec<Key> = g
            .blobs
            .keys()
            .filter(|k| k.proc == proc && pred(k))
            .cloned()
            .collect();
        let n = doomed.len();
        for k in &doomed {
            g.blobs.remove(k);
        }
        g.stats.deletes += n as u64;
        n
    }

    /// Keys currently stored for `proc` of a given kind.
    pub fn keys_for(&self, proc: u32, kind: Kind) -> Vec<Key> {
        let g = self.inner.lock().unwrap();
        g.blobs.keys().filter(|k| k.proc == proc && k.kind == kind).cloned().collect()
    }

    /// Total bytes resident (for GC benches).
    pub fn resident_bytes(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.blobs.values().map(|v| v.len() as u64).sum()
    }

    pub fn stats(&self) -> StorageStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn reset_stats(&self) {
        self.inner.lock().unwrap().stats = StorageStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(proc: u32, kind: Kind, tag: u64) -> Key {
        Key { proc, kind, tag }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = Store::new(5);
        s.put(k(1, Kind::State, 0), vec![1, 2, 3]);
        assert_eq!(s.get(&k(1, Kind::State, 0)), Some(vec![1, 2, 3]));
        assert_eq!(s.get(&k(1, Kind::State, 1)), None);
        let st = s.stats();
        assert_eq!(st.writes, 1);
        assert_eq!(st.bytes_written, 3);
        assert_eq!(st.virtual_latency, 5);
        assert_eq!(st.reads, 2);
    }

    #[test]
    fn delete_matching_gc() {
        let s = Store::new(0);
        for tag in 0..5 {
            s.put(k(1, Kind::Meta, tag), vec![0]);
        }
        s.put(k(2, Kind::Meta, 0), vec![0]);
        let n = s.delete_matching(1, |key| key.tag < 3);
        assert_eq!(n, 3);
        assert_eq!(s.keys_for(1, Kind::Meta).len(), 2);
        assert_eq!(s.keys_for(2, Kind::Meta).len(), 1);
    }

    #[test]
    fn resident_bytes_tracks_contents() {
        let s = Store::new(0);
        s.put(k(1, Kind::State, 0), vec![0; 100]);
        s.put(k(1, Kind::State, 1), vec![0; 50]);
        assert_eq!(s.resident_bytes(), 150);
        s.delete(&k(1, Kind::State, 0));
        assert_eq!(s.resident_bytes(), 50);
    }

    #[test]
    fn put_log_counts_batches_and_records() {
        let s = Store::new(2);
        s.put_log(k(1, Kind::LogEntry, 0), vec![0; 10], 4);
        s.put_log(k(1, Kind::LogEntry, 1), vec![0; 5], 1);
        s.put(k(1, Kind::State, 0), vec![0; 3]); // not a log write
        let st = s.stats();
        assert_eq!(st.writes, 3);
        assert_eq!(st.bytes_written, 18);
        assert_eq!(st.log_batches, 2);
        assert_eq!(st.log_records, 5);
        assert_eq!(st.virtual_latency, 6);
    }

    #[test]
    fn shared_handle_sees_writes() {
        let s = Store::new(0);
        let s2 = s.clone();
        s.put(k(9, Kind::LogEntry, 7), vec![42]);
        assert_eq!(s2.get(&k(9, Kind::LogEntry, 7)), Some(vec![42]));
    }
}
