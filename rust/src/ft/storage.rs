//! Durable-storage substrate.
//!
//! The paper assumes "reliably persisting state [is] adequately covered by
//! existing techniques" (§1) and builds on acknowledged writes (§4.2: a
//! processor sends Ξ(p,f) to the monitor only after storage acknowledges
//! the checkpoint, state, and log). We model exactly that contract behind
//! a pluggable [`StorageBackend`]:
//!
//! - [`MemBackend`] — the zero-regression default: an in-memory
//!   `BTreeMap` with virtual-latency accounting, for tests and benches
//!   that study policy overhead rather than durability;
//! - [`crate::ft::backend_file::FileBackend`] — a real on-disk segmented
//!   write-ahead log with group commit, crash-scan reopen, tombstones and
//!   compaction, for true cold-restart recovery
//!   ([`crate::ft::harness::FtSystem::reopen`]).
//!
//! The [`Store`] handle in front of the backend keeps the acknowledgement
//! accounting (write/read/delete counters, injectable virtual write
//! latency so benches can charge eager policies for their synchronous
//! writes) and a running resident-byte counter, so `resident_bytes` is
//! O(1) regardless of backend size.
//!
//! # The staged-write pipeline
//!
//! Every FT-layer mutation enters through the **staging** API
//! ([`Store::stage_put`] / [`Store::stage_put_log`] /
//! [`Store::stage_delete`]), which assigns the operation a monotone
//! per-processor **sequence number** and routes it by [`PersistMode`]:
//!
//! - [`PersistMode::Sync`] (the default) applies the operation to the
//!   backend before returning — today's acknowledged-write behavior
//!   byte-for-byte: the returned sequence number is already at or below
//!   the processor's **ack watermark** ([`Store::acked_seq`]).
//! - [`PersistMode::Async`] enqueues the operation into a lock-light
//!   staging queue and returns immediately; a background **writer
//!   thread** drains the queue in batches of up to `ack_every`
//!   operations, applies them through the backend, issues a single
//!   [`StorageBackend::sync`] per drained batch (group commit), and only
//!   then advances the per-processor ack watermarks.
//!
//! The watermark is the FT layer's availability gate: a checkpoint, log
//! entry or history event becomes *offerable* to the Fig. 6 solver only
//! once its sequence number is acknowledged, and
//! [`Store::discard_unacked`] (used by failure injection) atomically
//! drops a crashed processor's staged-but-unacknowledged tail — staging
//! preserves per-processor FIFO order, so the durable image is always a
//! *prefix* of the staged history, exactly the suffix-casualty crash
//! model the WAL backend already provides one level down.
//!
//! Reads (`get`, scans, `stats`, …) settle the staging queue first so
//! callers never observe a store image behind the mirrors — except while
//! persistence is [`Store::pause_persistence`]d (a testing hook), when
//! they serve the applied prefix, which is exactly what a crash-time
//! inspector wants to see.
//!
//! # Content-addressed checkpoint snapshots
//!
//! Checkpoint state rides the same pipeline in *chunked* form rather
//! than as one monolithic blob: the state is split into
//! [`SNAPSHOT_CHUNK_BYTES`]-sized chunks, each stored once under its
//! fnv1a hash (`Kind::Chunk`), and a [`Snapshot`] record
//! (`Kind::Snapshot`) lists the `(position, hash)` pairs — full, or as
//! a delta chained to a prior snapshot via `prior_snapshot`.
//! [`Store::stage_put_snapshot`] skips chunks already resident under
//! their hash (`StorageStats::chunks_reused` counts the skips), so
//! per-checkpoint durable bytes scale with the *change* between
//! checkpoints, not total state size; [`Store::materialize_snapshot`]
//! walks the chain newest→oldest to reassemble the bytes. The policy
//! layer ([`crate::ft::policy::SnapshotPolicy`]) decides full vs delta
//! and bounds the chain ([`plan_snapshot`]); the harness stages the
//! snapshot before its Ξ, so per-proc FIFO keeps "acked Ξ ⇒ acked
//! snapshot ⇒ acked chunks" — an unacked chain tail is discarded
//! exactly like any other unacked write.

use crate::ft::backend_file::{FileBackend, FileBackendOptions};
use crate::ft::meta::Snapshot;
use crate::ft::policy::SnapshotPolicy;
use crate::trace::Tracer;
use crate::util::hash::fnv1a;
use crate::util::ser::{Decode, Encode};
use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};

/// A storage key: (processor, kind, discriminator).
///
/// Ordering is `(proc, kind, tag)` — proc-major, which is what lets
/// backends serve per-processor scans from a range rather than a full
/// sweep.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub proc: u32,
    pub kind: Kind,
    pub tag: u64,
}

/// What a blob contains.
///
/// `Meta` must remain the first variant: backends compute per-processor
/// range bounds as `Key { proc, kind: Kind::Meta, tag: 0 }`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Checkpoint metadata Ξ(p,f) (a [`crate::ft::meta::MetaRecord`]).
    Meta,
    /// Checkpoint state S(p,f) as one monolithic blob — the
    /// pre-chunking representation. The checkpoint write path now
    /// stores state as `Snapshot` + `Chunk` records instead; the kind
    /// (and its on-disk code) remains valid for generic blobs and for
    /// reading WALs written before the chunked representation.
    State,
    /// A logged message (one entry of L(e,·)).
    LogEntry,
    /// Full-history event (H(p) entry).
    HistoryEvent,
    /// Durable input-frontier marker of a source processor (the §4.2
    /// Ξ(p,f) of a stateless logging source, whose state is trivially ∅:
    /// the frontier of input times the source has completely consumed
    /// *and* whose resulting sends are acknowledged in the log). One per
    /// processor, at tag 0, overwritten as the frontier advances.
    InputFrontier,
    /// One content-addressed chunk of checkpoint state: the tag is the
    /// fnv1a hash of the value bytes, so a chunk already resident under
    /// its hash is never rewritten (see [`Store::stage_put_snapshot`]).
    Chunk,
    /// A [`crate::ft::meta::Snapshot`] record: the list of chunk
    /// positions/hashes (full or delta) that materializes a checkpoint's
    /// state S(p,f), written under the same tag as its `Kind::Meta` Ξ.
    Snapshot,
}

impl Kind {
    /// Stable on-disk code (the WAL record format).
    pub fn code(self) -> u8 {
        match self {
            Kind::Meta => 0,
            Kind::State => 1,
            Kind::LogEntry => 2,
            Kind::HistoryEvent => 3,
            Kind::InputFrontier => 4,
            Kind::Chunk => 5,
            Kind::Snapshot => 6,
        }
    }

    /// Inverse of [`Kind::code`].
    pub fn from_code(c: u8) -> Option<Kind> {
        match c {
            0 => Some(Kind::Meta),
            1 => Some(Kind::State),
            2 => Some(Kind::LogEntry),
            3 => Some(Kind::HistoryEvent),
            4 => Some(Kind::InputFrontier),
            5 => Some(Kind::Chunk),
            6 => Some(Kind::Snapshot),
            _ => None,
        }
    }
}

// ----------------------------------------------------------------------
// Content-addressed snapshot chunking.
// ----------------------------------------------------------------------

/// Fixed chunk size of the content-addressed checkpoint representation.
/// Small enough that a point update to keyed state dirties O(1) chunks,
/// large enough that per-chunk key overhead (~20 bytes of WAL record
/// framing + snapshot listing) stays ~2% of payload.
pub const SNAPSHOT_CHUNK_BYTES: usize = 1024;

/// Number of chunk positions a state of `state_len` bytes occupies.
pub fn chunk_count(state_len: usize) -> usize {
    state_len.div_ceil(SNAPSHOT_CHUNK_BYTES)
}

/// Byte range of chunk position `pos` within a state of `state_len`
/// bytes (the last chunk is short).
pub fn chunk_span(pos: usize, state_len: usize) -> std::ops::Range<usize> {
    let start = pos * SNAPSHOT_CHUNK_BYTES;
    start..(start + SNAPSHOT_CHUNK_BYTES).min(state_len)
}

/// Per-position fnv1a hashes of `state`'s chunks.
pub fn chunk_hashes(state: &[u8]) -> Vec<u64> {
    state.chunks(SNAPSHOT_CHUNK_BYTES).map(fnv1a).collect()
}

/// The diff base for an incremental snapshot: the fully-resolved
/// position→hash view of a prior (acked) snapshot plus the number of
/// snapshot records a materialization of it walks.
#[derive(Clone, Debug)]
pub struct SnapshotBase {
    /// Storage tag of the base snapshot (what `prior_snapshot` points
    /// at).
    pub tag: u64,
    /// Per-position chunk hashes of the base's materialized state.
    pub hashes: Vec<u64>,
    /// Snapshot records a materialization of the base walks (≥ 1).
    pub walk_len: u64,
}

/// Plan the [`Snapshot`] record for `state`: a delta against `base`
/// when `policy` permits and the chain bound allows, a full snapshot
/// otherwise (no base, `SnapshotPolicy::Full`, or the walk would
/// exceed `max_chain` — the forced-full bound that keeps recovery walk
/// depth O(`max_chain`)). A delta lists exactly the positions whose
/// hash differs from the base view (including positions past the
/// base's end when the state grew); an unchanged state yields a valid
/// empty delta.
pub fn plan_snapshot(state: &[u8], base: Option<&SnapshotBase>, policy: SnapshotPolicy) -> Snapshot {
    let hashes = chunk_hashes(state);
    let full = || Snapshot {
        state_len: state.len() as u64,
        chunks: hashes.iter().enumerate().map(|(p, &h)| (p as u64, h)).collect(),
        prior_snapshot: None,
    };
    let (SnapshotPolicy::Delta { .. }, Some(base)) = (policy, base) else {
        return full();
    };
    if base.walk_len + 1 > policy.max_chain() {
        return full();
    }
    Snapshot {
        state_len: state.len() as u64,
        chunks: hashes
            .iter()
            .enumerate()
            .filter(|&(p, &h)| base.hashes.get(p) != Some(&h))
            .map(|(p, &h)| (p as u64, h))
            .collect(),
        prior_snapshot: Some(base.tag),
    }
}

/// When durable writes are applied and acknowledged (see the module docs
/// for the full pipeline description).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum PersistMode {
    /// Apply-before-return: every staged operation reaches the backend on
    /// the caller's thread and is acknowledged immediately — the
    /// pre-pipeline behavior, byte-for-byte.
    #[default]
    Sync,
    /// Queue-and-return: a background writer thread drains staged
    /// operations in group-commit batches of up to `ack_every`, issuing
    /// one [`StorageBackend::sync`] per batch before advancing the ack
    /// watermarks. Larger `ack_every` amortizes the sync over more
    /// writes at the price of a longer unacknowledged tail (more
    /// re-execution after a crash — never inconsistency).
    Async {
        /// Group-commit width of the writer thread (≥ 1).
        ack_every: usize,
    },
}

/// Write/read accounting, for the policy-overhead benches.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StorageStats {
    pub writes: u64,
    pub bytes_written: u64,
    pub deletes: u64,
    pub reads: u64,
    /// Σ of per-write virtual latency (cost units): eager policies pay
    /// this on the critical path; lazy ones off it.
    pub virtual_latency: u64,
    /// Message-log writes (one per sent *batch* — the batching win on the
    /// durable path is `log_records / log_batches` records amortized per
    /// acknowledged write).
    pub log_batches: u64,
    /// Records covered by those log writes.
    pub log_records: u64,
    /// Keys examined by scans (`keys_for` / `delete_matching` /
    /// `scan_keys`). Backends scan per-processor key *ranges*, so GC over
    /// one processor charges only that processor's keys here — the
    /// regression guard for the range-bounded scan path.
    pub keys_scanned: u64,
    /// Snapshot chunks a [`Store::stage_put_snapshot`] skipped because a
    /// chunk with the same hash was already resident (or staged) for the
    /// processor — the content-addressed dedup win.
    pub chunks_reused: u64,
    /// Payload bytes those skipped chunks would have written: with
    /// `SnapshotPolicy::Delta`, per-checkpoint durable bytes scale with
    /// the delta, and this counter is the proof.
    pub chunk_bytes_reused: u64,
}

/// A write the backend refused (the write was *not* acknowledged and
/// nothing was persisted). The §4.2 contract treats an acknowledged
/// write as irrevocable, so [`Store::put`] panics on these; callers that
/// can degrade gracefully (the FT harness, CLI tools, admission control)
/// use [`Store::try_put`] or the staging API, whose size pre-check makes
/// the refusal synchronous even under [`PersistMode::Async`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StorageError {
    /// The encoded record exceeds the backend's maximum record size
    /// (a restart's scanner would reject it as corruption, so it must
    /// never be acknowledged in the first place).
    ValueTooLarge { size: u64, max: u64 },
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::ValueTooLarge { size, max } => {
                write!(f, "value of {size} bytes exceeds the backend's {max}-byte record limit")
            }
        }
    }
}

impl std::error::Error for StorageError {}

/// Aggregate counters a backend reports about itself (`falkirk store
/// inspect`, the storage benches, and the compaction tests read these).
#[derive(Clone, Debug, PartialEq)]
pub struct BackendInfo {
    /// "mem" or "file".
    pub name: &'static str,
    /// Keys currently resolvable.
    pub live_keys: u64,
    /// Bytes of live blob payload.
    pub live_bytes: u64,
    /// Bytes occupied on disk (0 for mem): live + dead records across all
    /// segments, including the unflushed group-commit tail.
    pub file_bytes: u64,
    /// Segment files (0 for mem).
    pub segments: u64,
    /// Bytes owed to overwritten/deleted records and tombstones, awaiting
    /// compaction (0 for mem).
    pub dead_bytes: u64,
    /// Segment compactions performed since open.
    pub compactions: u64,
}

impl BackendInfo {
    fn mem(live_keys: u64, live_bytes: u64) -> BackendInfo {
        BackendInfo {
            name: "mem",
            live_keys,
            live_bytes,
            file_bytes: 0,
            segments: 0,
            dead_bytes: 0,
            compactions: 0,
        }
    }
}

/// A pluggable durable key-value backend. Writes are acknowledged on
/// return (the §4.2 contract); a backend with a group-commit buffer
/// additionally guarantees the buffered tail is an append-order *prefix*
/// casualty under a crash — a surviving record implies every earlier
/// write survived, which is what the input-frontier markers and the
/// state-then-Ξ ordering rely on.
///
/// `get`/`scan_keys` take `&mut self` because a write-ahead backend may
/// need to flush its buffered tail before serving a read.
pub trait StorageBackend: Send {
    /// Persist a blob; returns the size of any blob it replaced. `Err`
    /// means the write was refused and nothing was persisted (e.g. the
    /// value exceeds the backend's record-size limit) — the blob is NOT
    /// acknowledged.
    fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError>;

    fn get(&mut self, key: &Key) -> Option<Vec<u8>>;

    /// Remove a blob; returns its size if it existed.
    fn delete(&mut self, key: &Key) -> Option<u64>;

    /// All (key, value size) pairs for `proc`, ascending — size metadata
    /// without reading blob contents. Implementations scan only the
    /// processor's key range.
    fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)>;

    /// All keys for `proc`, ascending.
    fn scan_keys(&mut self, proc: u32) -> Vec<Key> {
        self.scan_entries(proc).into_iter().map(|(k, _)| k).collect()
    }

    /// Distinct processor ids present, ascending.
    fn procs(&mut self) -> Vec<u32>;

    /// Force any buffered writes durable.
    fn sync(&mut self);

    /// Aggregate self-description.
    fn info(&self) -> BackendInfo;

    /// The largest value (in bytes) a `put` is guaranteed to accept, if
    /// the backend has a record-size limit. The [`Store`] pre-checks
    /// staged writes against this so a refusal is synchronous — the
    /// backend itself refusing a pre-checked write is an invariant
    /// violation.
    fn max_value_len(&self) -> Option<u64> {
        None
    }

    /// Rewrite storage to drop dead records (no-op where meaningless).
    fn compact(&mut self) {}

    /// Attach (or detach) a structured tracer. Backends with interesting
    /// internal events (WAL segment rotation, compaction) record them
    /// through it; the default ignores it.
    fn set_tracer(&mut self, _tracer: Option<Tracer>) {}

    /// Testing hook: die as a crash would — the unflushed group-commit
    /// tail is lost and nothing further is written (not even on drop).
    fn simulate_crash(&mut self) {}
}

/// The in-memory default backend (the pre-durability behavior).
#[derive(Default)]
pub struct MemBackend {
    blobs: BTreeMap<Key, Vec<u8>>,
}

impl MemBackend {
    pub fn new() -> MemBackend {
        MemBackend::default()
    }
}

/// Ascending distinct processor ids from an ascending key iterator
/// (shared by the backends' `procs` implementations).
pub(crate) fn distinct_procs<'a, I: Iterator<Item = &'a Key>>(keys: I) -> Vec<u32> {
    let mut out: Vec<u32> = Vec::new();
    for k in keys {
        if out.last() != Some(&k.proc) {
            out.push(k.proc);
        }
    }
    out
}

/// The `(lo, hi)` bounds covering exactly `proc`'s keys under the
/// `(proc, kind, tag)` ordering.
pub(crate) fn proc_range(proc: u32) -> (Bound<Key>, Bound<Key>) {
    let lo = Bound::Included(Key { proc, kind: Kind::Meta, tag: 0 });
    let hi = match proc.checked_add(1) {
        Some(next) => Bound::Excluded(Key { proc: next, kind: Kind::Meta, tag: 0 }),
        None => Bound::Unbounded,
    };
    (lo, hi)
}

impl StorageBackend for MemBackend {
    fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError> {
        Ok(self.blobs.insert(key.clone(), value.to_vec()).map(|old| old.len() as u64))
    }

    fn get(&mut self, key: &Key) -> Option<Vec<u8>> {
        self.blobs.get(key).cloned()
    }

    fn delete(&mut self, key: &Key) -> Option<u64> {
        self.blobs.remove(key).map(|old| old.len() as u64)
    }

    fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)> {
        self.blobs.range(proc_range(proc)).map(|(k, v)| (k.clone(), v.len() as u64)).collect()
    }

    fn procs(&mut self) -> Vec<u32> {
        distinct_procs(self.blobs.keys())
    }

    fn sync(&mut self) {}

    fn info(&self) -> BackendInfo {
        BackendInfo::mem(
            self.blobs.len() as u64,
            self.blobs.values().map(|v| v.len() as u64).sum(),
        )
    }
}

/// One staged mutation (the queue payload of the async pipeline).
enum StagedOp {
    Put { key: Key, value: Vec<u8>, log_records: Option<u64> },
    Delete { key: Key },
}

impl StagedOp {
    fn proc(&self) -> u32 {
        match self {
            StagedOp::Put { key, .. } | StagedOp::Delete { key } => key.proc,
        }
    }
}

struct QueuedOp {
    seq: u64,
    op: StagedOp,
}

/// Staging-queue state (behind [`Staging::q`]).
struct StageState {
    mode: PersistMode,
    ops: VecDeque<QueuedOp>,
    /// Last sequence number handed out per processor.
    staged: BTreeMap<u32, u64>,
    /// Ack watermark per processor: every operation at or below it has
    /// been applied to the backend.
    acked: BTreeMap<u32, u64>,
    total_staged: u64,
    total_acked: u64,
    /// Operations dequeued by the writer, applied-but-not-yet-acked.
    in_flight: usize,
    /// Testing hook: the writer parks and takes nothing while set.
    paused: bool,
    /// Set on simulated crash and on final shutdown; staging refuses new
    /// work and the writer exits.
    shutdown: bool,
}

/// Shared staging queue + its two condition variables (`work` wakes the
/// writer, `done` wakes barriers; both pair with the `q` mutex), plus
/// two lock-free flags read on the hot path:
///
/// - `async_active` — false in [`PersistMode::Sync`], in which case
///   staged writes take a fast path that never touches the `q` mutex at
///   all (no sequencing needed: everything is trivially acknowledged,
///   mirrors carry sequence 0 which every watermark covers) and reads
///   skip the settle barrier. The default mode therefore costs exactly
///   what the pre-pipeline store did — one backend lock per operation.
/// - `value_limit` — the pre-check bound for staged writes
///   (`u64::MAX` = unlimited), kept outside the mutex so the fast path
///   can check it without locking.
struct Staging {
    q: Mutex<StageState>,
    work: Condvar,
    done: Condvar,
    async_active: AtomicBool,
    value_limit: AtomicU64,
    /// Content-addressed chunk index: `(proc, hash)` → staging sequence
    /// of the chunk's newest put (0 = sync-applied or inherited from a
    /// reopened backend). [`Store::stage_put_snapshot`] consults it to
    /// skip rewriting resident chunks; [`Store::stage`] maintains it
    /// centrally (chunk puts insert, chunk deletes remove) and
    /// [`Store::discard_unacked`] rewinds entries above the surviving
    /// watermark, so a dedup hit never references a chunk the durable
    /// image lost. Decisions are made at *stage* time, which keeps the
    /// durable image identical across `Sync` and `Async` modes.
    dedup: Mutex<BTreeMap<(u32, u64), u64>>,
    /// Capture-gated structured tracer (see [`crate::trace`]): the FT
    /// layer records checkpoint / refused-write / ack-watermark events
    /// through the store so both the sequential path and per-worker
    /// observers share one sink. `None` (the default) costs one mutex
    /// lock per *cold-path* event site and nothing on the staging fast
    /// path, which never touches it.
    tracer: Mutex<Option<Tracer>>,
}

impl Staging {
    /// Advance a processor's watermark to `seq` (watermarks are monotone;
    /// per-proc FIFO makes this a plain max).
    fn ack(q: &mut StageState, proc: u32, seq: u64) {
        let w = q.acked.entry(proc).or_insert(0);
        *w = (*w).max(seq);
        q.total_acked += 1;
    }

    /// The one drain-barrier loop: wait until the queue and any in-flight
    /// writer batch are empty. Escapes early on shutdown (a crashed store
    /// will never drain) and — when `escape_on_paused` — on a paused
    /// writer (callers that must not stall a deliberately-held tail).
    /// Returns the guard so callers can keep inspecting/mutating under
    /// the same critical section.
    fn wait_drained<'a>(
        &self,
        mut q: std::sync::MutexGuard<'a, StageState>,
        escape_on_paused: bool,
    ) -> std::sync::MutexGuard<'a, StageState> {
        while !(q.ops.is_empty() && q.in_flight == 0) {
            if q.shutdown || (escape_on_paused && q.paused) {
                break;
            }
            q = self.done.wait(q).unwrap();
        }
        q
    }
}

/// Drop guard shared by all [`Store`] clones (the writer thread holds
/// only weak/queue references, so this drops exactly when the last user
/// handle goes away): drains the staging queue, stops the writer, and
/// joins it — a graceful drop therefore leaves nothing staged, mirroring
/// the WAL backend's flush-on-drop one level down.
struct WriterGuard {
    staging: Arc<Staging>,
    /// Keeps the backend alive until the writer has drained and exited.
    inner: Arc<Mutex<Inner>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for WriterGuard {
    fn drop(&mut self) {
        {
            let mut q = self.staging.q.lock().unwrap();
            q.paused = false;
            self.staging.work.notify_all();
            // A crashed store never drains (the queue was discarded);
            // everything else does, now that the writer is unpaused.
            let mut q = self.staging.wait_drained(q, false);
            q.shutdown = true;
            self.staging.work.notify_all();
        }
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
        let _ = &self.inner; // dropped after the writer is gone
    }
}

/// The background writer: drain batches of up to `ack_every`, apply them
/// under the backend lock, group-commit with one `sync()`, then publish
/// the ack watermarks.
fn writer_loop(staging: Arc<Staging>, inner: Weak<Mutex<Inner>>) {
    loop {
        let batch: Vec<QueuedOp> = {
            let mut q = staging.q.lock().unwrap();
            loop {
                if q.shutdown {
                    return;
                }
                if !q.ops.is_empty() && !q.paused {
                    break;
                }
                q = staging.work.wait(q).unwrap();
            }
            let width = match q.mode {
                PersistMode::Async { ack_every } => ack_every.max(1),
                // Mode switched back to Sync with ops still queued cannot
                // happen (set_persist_mode barriers first), but drain
                // everything if it somehow does.
                PersistMode::Sync => q.ops.len(),
            };
            let take = width.min(q.ops.len());
            q.in_flight = take;
            q.ops.drain(..take).collect()
        };
        if let Some(inner) = inner.upgrade() {
            let mut g = inner.lock().unwrap();
            for qo in &batch {
                g.apply(&qo.op);
            }
            // Group commit: the whole drained batch rides one sync.
            g.backend.sync();
        }
        let mut q = staging.q.lock().unwrap();
        for qo in &batch {
            Staging::ack(&mut q, qo.op.proc(), qo.seq);
        }
        q.in_flight = 0;
        staging.done.notify_all();
        drop(q);
        if let Some(tr) = staging.tracer.lock().unwrap().as_ref() {
            for qo in &batch {
                tr.instant(0, "storage", "ack", &[("proc", qo.op.proc() as u64), ("seq", qo.seq)]);
            }
        }
    }
}

/// Durable store with ack semantics. Cloneable handle; the backend
/// serializes its own access through the handle's lock, and the staging
/// queue (see the module docs) serializes acknowledgement order.
///
/// The handle is `Send + Sync`, which is what lets both parallel drains
/// and parallel recovery share one store: every durable key is scoped to
/// a processor (`Key { proc, .. }`) and every processor has exactly one
/// owning worker, so concurrent scans, staged writes and deletions from
/// different workers touch disjoint key ranges — the lock only orders
/// physically interleaved operations, it never arbitrates a logical
/// conflict. During a parallel cold restart the index is effectively
/// read-only: the only writes are orphan deletions inside the scanning
/// worker's own per-proc range.
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<Inner>>,
    staging: Arc<Staging>,
    guard: Arc<WriterGuard>,
}

struct Inner {
    backend: Box<dyn StorageBackend>,
    stats: StorageStats,
    /// Virtual cost charged per write (simulates fsync/replication).
    write_cost: u64,
    /// Running Σ of live blob bytes (maintained on put/delete so
    /// `resident_bytes` never walks the blob set).
    resident: u64,
}

impl Inner {
    /// Apply one staged operation to the backend, with the acknowledged
    /// accounting. The staging layer pre-checked sizes, so a backend
    /// refusal here is an invariant violation, not a recoverable error.
    fn apply(&mut self, op: &StagedOp) {
        match op {
            StagedOp::Put { key, value, log_records } => {
                let replaced = self
                    .backend
                    .put(key, value)
                    .unwrap_or_else(|e| panic!("pre-checked durable write refused: {e}"))
                    .unwrap_or(0);
                self.stats.writes += 1;
                self.stats.bytes_written += value.len() as u64;
                self.stats.virtual_latency += self.write_cost;
                if let Some(records) = log_records {
                    self.stats.log_batches += 1;
                    self.stats.log_records += records;
                }
                self.resident = self.resident - replaced + value.len() as u64;
            }
            StagedOp::Delete { key } => {
                if let Some(n) = self.backend.delete(key) {
                    self.stats.deletes += 1;
                    self.resident -= n;
                }
            }
        }
    }
}

impl Store {
    /// An in-memory store charging `write_cost` virtual latency units per
    /// write (the zero-regression default backend).
    pub fn new(write_cost: u64) -> Store {
        Store::with_backend(Box::new(MemBackend::new()), write_cost)
    }

    /// A store over an arbitrary backend. The resident-byte counter is
    /// seeded from the backend's live bytes (nonzero for a reopened WAL),
    /// the chunk-dedup index from a key scan of its resident
    /// `Kind::Chunk` keys (so dedup survives a cold restart);
    /// persistence starts in [`PersistMode::Sync`].
    pub fn with_backend(mut backend: Box<dyn StorageBackend>, write_cost: u64) -> Store {
        let resident = backend.info().live_bytes;
        let value_limit = backend.max_value_len().unwrap_or(u64::MAX);
        let mut dedup = BTreeMap::new();
        for proc in backend.procs() {
            for key in backend.scan_keys(proc) {
                if key.kind == Kind::Chunk {
                    dedup.insert((proc, key.tag), 0u64);
                }
            }
        }
        let inner = Arc::new(Mutex::new(Inner {
            backend,
            stats: StorageStats::default(),
            write_cost,
            resident,
        }));
        let staging = Arc::new(Staging {
            q: Mutex::new(StageState {
                mode: PersistMode::Sync,
                ops: VecDeque::new(),
                staged: BTreeMap::new(),
                acked: BTreeMap::new(),
                total_staged: 0,
                total_acked: 0,
                in_flight: 0,
                paused: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            async_active: AtomicBool::new(false),
            value_limit: AtomicU64::new(value_limit),
            dedup: Mutex::new(dedup),
            tracer: Mutex::new(None),
        });
        let guard = Arc::new(WriterGuard {
            staging: staging.clone(),
            inner: inner.clone(),
            handle: Mutex::new(None),
        });
        Store { inner, staging, guard }
    }

    /// Open (or create) a [`FileBackend`] WAL under `dir`. Reopening an
    /// existing directory rebuilds the key index by scanning segments; a
    /// torn or corrupt tail is truncated, not fatal.
    pub fn open_dir(
        dir: impl AsRef<Path>,
        write_cost: u64,
        opts: FileBackendOptions,
    ) -> std::io::Result<Store> {
        let backend = FileBackend::open(dir.as_ref(), opts)?;
        Ok(Store::with_backend(Box::new(backend), write_cost))
    }

    /// Open a WAL for inspection only: no on-disk repair, mutating
    /// operations panic (`falkirk store inspect` uses this so examining a
    /// just-crashed directory cannot destroy its torn tail).
    pub fn open_dir_read_only(
        dir: impl AsRef<Path>,
        opts: FileBackendOptions,
    ) -> std::io::Result<Store> {
        let backend = FileBackend::open_read_only(dir.as_ref(), opts)?;
        Ok(Store::with_backend(Box::new(backend), 0))
    }

    /// Attach (or detach) a structured tracer: storage-layer events —
    /// ack-watermark movement from the writer thread, snapshot
    /// chain walks, plus the FT layer's checkpoint / refused-write
    /// instants recorded via [`Store::trace_instant`] — flow into it.
    /// Forwarded to the backend so WAL rotation/compaction trace too.
    pub fn set_tracer(&self, tracer: Option<Tracer>) {
        *self.staging.tracer.lock().unwrap() = tracer.clone();
        self.inner.lock().unwrap().backend.set_tracer(tracer);
    }

    /// Record one instant event on the attached tracer (no-op when
    /// tracing is off). The store is the FT layer's shared trace sink:
    /// per-worker observers and the sequential path both hold a store
    /// handle, so cold-path events route through here.
    pub(crate) fn trace_instant(
        &self,
        cat: &'static str,
        name: &'static str,
        args: &[(&'static str, u64)],
    ) {
        if let Some(tr) = self.staging.tracer.lock().unwrap().as_ref() {
            tr.instant(0, cat, name, args);
        }
    }

    /// The current persistence mode.
    pub fn persist_mode(&self) -> PersistMode {
        self.staging.q.lock().unwrap().mode
    }

    /// Switch the persistence mode. Barriers on the staging queue first,
    /// so a switch never reorders or drops staged work — and refuses
    /// (panics) if staged operations are pinned by a paused writer, where
    /// silently proceeding would let an older queued write land after a
    /// newer synchronous one. Switching to [`PersistMode::Async`] spawns
    /// the writer thread on first use.
    pub fn set_persist_mode(&self, mode: PersistMode) {
        let spawn = {
            let q = self.staging.q.lock().unwrap();
            let mut q = self.staging.wait_drained(q, true);
            assert!(!q.shutdown, "store used after simulated crash");
            assert!(
                q.ops.is_empty() && q.in_flight == 0,
                "cannot switch persist mode with staged operations pending \
                 (resume_persistence and flush first)"
            );
            if let PersistMode::Async { ack_every } = mode {
                assert!(ack_every >= 1, "ack_every must be at least 1");
            }
            q.mode = mode;
            self.staging
                .async_active
                .store(matches!(mode, PersistMode::Async { .. }), Ordering::SeqCst);
            matches!(mode, PersistMode::Async { .. })
        };
        if spawn {
            let mut h = self.guard.handle.lock().unwrap();
            if h.is_none() {
                let staging = self.staging.clone();
                let inner = Arc::downgrade(&self.inner);
                *h = Some(
                    std::thread::Builder::new()
                        .name("falkirk-persist".into())
                        .spawn(move || writer_loop(staging, inner))
                        .expect("spawning the persistence writer thread"),
                );
            }
        }
    }

    /// Refuse an oversized put before anything is staged (the size
    /// pre-check that makes refusal synchronous in every mode).
    fn pre_check(&self, op: &StagedOp) -> Result<(), StorageError> {
        if let StagedOp::Put { value, .. } = op {
            let max = self.staging.value_limit.load(Ordering::Relaxed);
            if value.len() as u64 > max {
                return Err(StorageError::ValueTooLarge { size: value.len() as u64, max });
            }
        }
        Ok(())
    }

    /// Keep the chunk-dedup index in step with a successfully staged
    /// operation: chunk puts insert their staging sequence, chunk
    /// deletes (GC) remove the entry. Non-chunk operations never touch
    /// the index mutex.
    fn note_chunk(&self, op: &StagedOp, seq: u64) {
        let key = match op {
            StagedOp::Put { key, .. } | StagedOp::Delete { key } => key,
        };
        if key.kind != Kind::Chunk {
            return;
        }
        let mut d = self.staging.dedup.lock().unwrap();
        match op {
            StagedOp::Put { .. } => {
                d.insert((key.proc, key.tag), seq);
            }
            StagedOp::Delete { .. } => {
                d.remove(&(key.proc, key.tag));
            }
        }
    }

    /// Stage one operation: pre-check, then apply inline (Sync — the
    /// lock-free fast path: no sequencing, everything trivially acked,
    /// sequence 0 returned, which every watermark covers) or assign the
    /// per-proc sequence number and enqueue for the writer (Async).
    /// Returns the operation's sequence number.
    fn stage(&self, op: StagedOp) -> Result<u64, StorageError> {
        self.pre_check(&op)?;
        if !self.staging.async_active.load(Ordering::Relaxed) {
            // Sync fast path: exactly the pre-pipeline cost — one backend
            // lock, no staging-mutex traffic for non-chunk writes.
            // (Switching modes barriers and asserts an empty queue, so
            // nothing can be in flight here; concurrent writes racing a
            // mode switch are unordered with it anyway.)
            self.inner.lock().unwrap().apply(&op);
            self.note_chunk(&op, 0);
            return Ok(0);
        }
        let mut q = self.staging.q.lock().unwrap();
        assert!(!q.shutdown, "store used after simulated crash");
        let proc = op.proc();
        let seq = {
            let s = q.staged.entry(proc).or_insert(0);
            *s += 1;
            *s
        };
        q.total_staged += 1;
        match q.mode {
            PersistMode::Sync => {
                // Raced a switch back to Sync: apply inline, keeping the
                // sequencing bookkeeping exact.
                drop(q);
                self.inner.lock().unwrap().apply(&op);
                self.note_chunk(&op, seq);
                let mut q = self.staging.q.lock().unwrap();
                Staging::ack(&mut q, proc, seq);
                Ok(seq)
            }
            PersistMode::Async { .. } => {
                self.note_chunk(&op, seq);
                q.ops.push_back(QueuedOp { seq, op });
                self.staging.work.notify_one();
                Ok(seq)
            }
        }
    }

    /// Stage a blob write under the current [`PersistMode`] discipline.
    /// `Err` means the write was refused synchronously (size pre-check)
    /// and nothing was staged.
    pub fn stage_put(&self, key: Key, value: Vec<u8>) -> Result<u64, StorageError> {
        self.stage(StagedOp::Put { key, value, log_records: None })
    }

    /// Stage one message-log blob covering `records` records (the
    /// batch/record accounting lands when the write is applied).
    pub fn stage_put_log(
        &self,
        key: Key,
        value: Vec<u8>,
        records: u64,
    ) -> Result<u64, StorageError> {
        self.stage(StagedOp::Put { key, value, log_records: Some(records) })
    }

    /// Stage a deletion. Deletions ride the same per-proc FIFO as puts,
    /// so a truncation's tombstone can never overtake the staged write it
    /// undoes.
    pub fn stage_delete(&self, key: Key) -> u64 {
        self.stage(StagedOp::Delete { key }).expect("deletes have no size to refuse")
    }

    /// Stage the durable form of one checkpoint state under the
    /// content-addressed representation: every chunk `snapshot` lists
    /// whose hash is not already resident (or staged) for `proc` is
    /// written as a `Kind::Chunk` blob, then the encoded [`Snapshot`]
    /// record itself under `Key { proc, Kind::Snapshot, tag }`. Skipped
    /// chunks are counted in [`StorageStats::chunks_reused`] /
    /// `chunk_bytes_reused` — the dedup win. Per-proc FIFO staging
    /// orders every chunk before the record, so an acked snapshot
    /// implies acked chunks; the caller stages its `Kind::Meta` Ξ after
    /// this returns, extending the same implication to the checkpoint.
    ///
    /// Refusal is atomic: every blob is pre-checked against the value
    /// limit first, so on `Err` nothing was staged. Returns the
    /// snapshot record's staging sequence.
    pub fn stage_put_snapshot(
        &self,
        proc: u32,
        tag: u64,
        snapshot: &Snapshot,
        state: &[u8],
    ) -> Result<u64, StorageError> {
        debug_assert_eq!(state.len() as u64, snapshot.state_len);
        let record = snapshot.to_bytes();
        let limit = self.staging.value_limit.load(Ordering::Relaxed);
        for len in snapshot
            .chunks
            .iter()
            .map(|&(pos, _)| chunk_span(pos as usize, state.len()).len() as u64)
            .chain(std::iter::once(record.len() as u64))
        {
            if len > limit {
                return Err(StorageError::ValueTooLarge { size: len, max: limit });
            }
        }
        for &(pos, hash) in &snapshot.chunks {
            let span = chunk_span(pos as usize, state.len());
            debug_assert_eq!(fnv1a(&state[span.clone()]), hash, "snapshot hash mismatch");
            if self.staging.dedup.lock().unwrap().contains_key(&(proc, hash)) {
                let mut g = self.inner.lock().unwrap();
                g.stats.chunks_reused += 1;
                g.stats.chunk_bytes_reused += span.len() as u64;
                continue;
            }
            self.stage_put(Key { proc, kind: Kind::Chunk, tag: hash }, state[span].to_vec())?;
        }
        self.stage_put(Key { proc, kind: Kind::Snapshot, tag }, record)
    }

    /// Materialize the state bytes of snapshot `tag` of `proc` by
    /// walking its `prior_snapshot` chain newest→oldest: each position
    /// takes the hash from the *newest* snapshot listing it, then the
    /// chunks are fetched by hash and concatenated in position order.
    /// Returns `None` if any snapshot record or chunk along the walk is
    /// missing, fails to decode, or has the wrong length — the
    /// conservative-repair signal cold reopen uses to drop an
    /// incomplete chain suffix instead of restoring torn state.
    pub fn materialize_snapshot(&self, proc: u32, tag: u64) -> Option<Vec<u8>> {
        let fetch = |t: u64| -> Option<Snapshot> {
            Snapshot::from_bytes(&self.get(&Key { proc, kind: Kind::Snapshot, tag: t })?).ok()
        };
        let newest = fetch(tag)?;
        let state_len = newest.state_len as usize;
        let n = chunk_count(state_len);
        let mut hashes: Vec<Option<u64>> = vec![None; n];
        let mut filled = 0usize;
        let mut depth: u64 = 1;
        let (mut cur, mut cur_tag) = (newest, tag);
        loop {
            for &(pos, h) in &cur.chunks {
                if let Some(slot) = hashes.get_mut(pos as usize) {
                    if slot.is_none() {
                        *slot = Some(h);
                        filled += 1;
                    }
                }
            }
            if filled == n {
                break;
            }
            // Unfilled positions left and no (valid) prior: the chain is
            // incomplete. Prior tags strictly decrease along a
            // well-formed chain (the base is an older checkpoint of the
            // same processor), so a non-decreasing pointer would cycle —
            // treat it as corruption.
            let prior = cur.prior_snapshot?;
            if prior >= cur_tag {
                return None;
            }
            cur = fetch(prior)?;
            cur_tag = prior;
            depth += 1;
        }
        self.trace_instant("storage", "chain_walk", &[("proc", proc as u64), ("depth", depth)]);
        let mut out = Vec::with_capacity(state_len);
        for (pos, h) in hashes.iter().enumerate() {
            let Some(h) = *h else { return None };
            let bytes = self.get(&Key { proc, kind: Kind::Chunk, tag: h })?;
            if bytes.len() != chunk_span(pos, state_len).len() {
                return None;
            }
            out.extend_from_slice(&bytes);
        }
        Some(out)
    }

    /// Persist a blob; returns once acknowledged under the current
    /// [`PersistMode`] discipline — for `Sync` that is now, for `Async`
    /// when the writer thread drains it (use [`Store::acked_seq`] /
    /// [`Store::flush_staged`] to observe). Panics if the write is
    /// refused — the legacy ack-or-panic entry point; the FT layer stages
    /// through [`Store::stage_put`] and handles refusal per processor.
    pub fn put(&self, key: Key, value: Vec<u8>) {
        self.stage_put(key, value)
            .map(|_| ())
            .unwrap_or_else(|e| panic!("unacknowledgeable durable write: {e}"));
    }

    /// Like [`Store::put`], but surfaces a refused write (e.g. a value
    /// over the backend's record-size limit) as a recoverable error
    /// instead of panicking. On `Err` nothing was persisted or staged.
    pub fn try_put(&self, key: Key, value: Vec<u8>) -> Result<(), StorageError> {
        self.stage_put(key, value).map(|_| ())
    }

    /// Persist one message-log blob covering `records` records. Identical
    /// ack semantics to [`Store::put`], plus batch/record accounting so
    /// the policy-overhead benches can report amortization honestly.
    pub fn put_log(&self, key: Key, value: Vec<u8>, records: u64) {
        self.stage_put_log(key, value, records)
            .map(|_| ())
            .unwrap_or_else(|e| panic!("unacknowledgeable durable log write: {e}"));
    }

    pub fn delete(&self, key: &Key) {
        self.stage_delete(key.clone());
    }

    /// Ack watermark of `proc`: every staged operation with a sequence
    /// number at or below this has been applied to the backend.
    pub fn acked_seq(&self, proc: u32) -> u64 {
        self.staging.q.lock().unwrap().acked.get(&proc).copied().unwrap_or(0)
    }

    /// Last sequence number staged for `proc`.
    pub fn staged_seq(&self, proc: u32) -> u64 {
        self.staging.q.lock().unwrap().staged.get(&proc).copied().unwrap_or(0)
    }

    /// Operations staged but not yet acknowledged, across all processors
    /// (0 in sync mode — the pipeline's lag gauge).
    pub fn ack_lag(&self) -> u64 {
        let q = self.staging.q.lock().unwrap();
        q.total_staged - q.total_acked
    }

    /// Barrier: wait until every staged operation has been applied and
    /// acknowledged (no-op in sync mode; returns immediately after a
    /// simulated crash or while persistence is paused — there is nothing
    /// a wait could accomplish then).
    pub fn flush_staged(&self) {
        if !self.staging.async_active.load(Ordering::Relaxed) {
            return;
        }
        let q = self.staging.q.lock().unwrap();
        let _ = self.staging.wait_drained(q, true);
    }

    /// Crash semantics for one processor (failure injection): discard its
    /// staged-but-unacknowledged operations and return the resulting ack
    /// watermark. Queued operations are removed before waiting out any
    /// in-flight writer batch, so on return the watermark is exact:
    /// everything at or below it is applied, everything above it was
    /// never applied and never will be. Per-proc FIFO staging makes the
    /// surviving durable image a prefix of the staged history — the same
    /// suffix-casualty model as a real crash.
    pub fn discard_unacked(&self, proc: u32) -> u64 {
        let mut q = self.staging.q.lock().unwrap();
        let before = q.ops.len();
        q.ops.retain(|qo| qo.op.proc() != proc);
        let removed = (before - q.ops.len()) as u64;
        q.total_staged -= removed;
        while q.in_flight > 0 && !q.shutdown {
            q = self.staging.done.wait(q).unwrap();
        }
        let w = q.acked.get(&proc).copied().unwrap_or(0);
        let crashed = q.shutdown;
        if let Some(s) = q.staged.get_mut(&proc) {
            debug_assert!(
                crashed || *s - w == removed,
                "discard accounting: staged {s} − acked {w} ≠ removed {removed}"
            );
            *s = w;
        }
        // Rewind the chunk-dedup index past the discarded suffix, so a
        // later snapshot re-stages any chunk the durable image never got
        // (entries at or below the watermark — including sync-mode 0 —
        // are applied and stay deduplicable).
        self.staging
            .dedup
            .lock()
            .unwrap()
            .retain(|&(p, _), &mut seq| p != proc || seq <= w);
        w
    }

    /// Testing hook: park the writer thread so staged operations
    /// accumulate unacknowledged (deterministic unacked tails for the
    /// crash suites). Reads served while paused reflect only the applied
    /// prefix.
    pub fn pause_persistence(&self) {
        self.staging.q.lock().unwrap().paused = true;
    }

    /// Undo [`Store::pause_persistence`].
    pub fn resume_persistence(&self) {
        let mut q = self.staging.q.lock().unwrap();
        q.paused = false;
        self.staging.work.notify_all();
    }

    /// Settle the staging queue before serving a read, so callers never
    /// observe the store behind its mirrors. Lock-free no-op in sync
    /// mode; while paused (or after a simulated crash) reads serve the
    /// applied prefix instead — exactly the crash-time durable image.
    fn settle_for_read(&self) {
        if !self.staging.async_active.load(Ordering::Relaxed) {
            return;
        }
        let q = self.staging.q.lock().unwrap();
        let _ = self.staging.wait_drained(q, true);
    }

    pub fn get(&self, key: &Key) -> Option<Vec<u8>> {
        self.settle_for_read();
        let mut g = self.inner.lock().unwrap();
        g.stats.reads += 1;
        g.backend.get(key)
    }

    /// Delete all blobs for `proc` matching `pred` (garbage collection).
    /// Scans only `proc`'s key range; the deletions are staged, so they
    /// order after any still-queued writes of the same processor.
    pub fn delete_matching<F: FnMut(&Key) -> bool>(&self, proc: u32, mut pred: F) -> usize {
        self.settle_for_read();
        let doomed: Vec<Key> = {
            let mut g = self.inner.lock().unwrap();
            let keys = g.backend.scan_keys(proc);
            g.stats.keys_scanned += keys.len() as u64;
            keys.into_iter().filter(|k| pred(k)).collect()
        };
        let n = doomed.len();
        for k in doomed {
            self.stage_delete(k);
        }
        n
    }

    /// Keys currently stored for `proc` of a given kind.
    pub fn keys_for(&self, proc: u32, kind: Kind) -> Vec<Key> {
        self.settle_for_read();
        let mut g = self.inner.lock().unwrap();
        let keys = g.backend.scan_keys(proc);
        g.stats.keys_scanned += keys.len() as u64;
        keys.into_iter().filter(|k| k.kind == kind).collect()
    }

    /// All keys for `proc`, ascending (the cold-restart loader reads each
    /// processor's durable state with one ranged scan).
    pub fn scan_keys(&self, proc: u32) -> Vec<Key> {
        self.settle_for_read();
        let mut g = self.inner.lock().unwrap();
        let keys = g.backend.scan_keys(proc);
        g.stats.keys_scanned += keys.len() as u64;
        keys
    }

    /// All (key, value size) pairs for `proc`, ascending — metadata only,
    /// no blob reads (`falkirk store inspect` sums sizes from this).
    pub fn scan_entries(&self, proc: u32) -> Vec<(Key, u64)> {
        self.settle_for_read();
        let mut g = self.inner.lock().unwrap();
        let entries = g.backend.scan_entries(proc);
        g.stats.keys_scanned += entries.len() as u64;
        entries
    }

    /// Distinct processor ids present, ascending.
    pub fn procs(&self) -> Vec<u32> {
        self.settle_for_read();
        self.inner.lock().unwrap().backend.procs()
    }

    /// Total live bytes resident. O(1): maintained on put/delete.
    pub fn resident_bytes(&self) -> u64 {
        self.settle_for_read();
        self.inner.lock().unwrap().resident
    }

    /// Force buffered writes durable (settles the staging queue, then
    /// syncs group-commit backends). While persistence is paused this
    /// covers only the *applied* prefix — a deliberately-held staged
    /// tail stays volatile until [`Store::resume_persistence`].
    pub fn sync(&self) {
        self.flush_staged();
        self.inner.lock().unwrap().backend.sync();
    }

    /// Rewrite storage to drop dead records (backend-specific; no-op for
    /// mem).
    pub fn compact(&self) {
        self.flush_staged();
        self.inner.lock().unwrap().backend.compact();
    }

    /// The backend's self-description (segments, live/dead bytes, …).
    pub fn backend_info(&self) -> BackendInfo {
        self.settle_for_read();
        self.inner.lock().unwrap().backend.info()
    }

    /// The effective value-size limit staged writes are pre-checked
    /// against (the backend's record limit, or a tighter override).
    pub fn max_value_len(&self) -> Option<u64> {
        match self.staging.value_limit.load(Ordering::Relaxed) {
            u64::MAX => None,
            v => Some(v),
        }
    }

    /// Testing / admission-control hook: tighten the value-size limit.
    /// The effective limit is the minimum of `limit` and the backend's
    /// own record limit.
    pub fn set_max_value_len(&self, limit: u64) {
        self.staging.value_limit.fetch_min(limit, Ordering::SeqCst);
    }

    /// Testing hook: crash the store — queued staged operations and the
    /// backend's unflushed group-commit tail are lost and nothing further
    /// reaches disk (not even on drop). The handle stays usable only for
    /// dropping.
    pub fn simulate_crash(&self) {
        {
            let mut q = self.staging.q.lock().unwrap();
            // Discard the unapplied staged tail, stop the writer, and let
            // any in-flight batch finish (its writes were applied — the
            // crash casualty is the queue suffix plus the backend tail).
            q.ops.clear();
            q.shutdown = true;
            self.staging.work.notify_all();
            while q.in_flight > 0 {
                q = self.staging.done.wait(q).unwrap();
            }
        }
        self.inner.lock().unwrap().backend.simulate_crash();
    }

    pub fn stats(&self) -> StorageStats {
        self.settle_for_read();
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn reset_stats(&self) {
        self.inner.lock().unwrap().stats = StorageStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(proc: u32, kind: Kind, tag: u64) -> Key {
        Key { proc, kind, tag }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = Store::new(5);
        s.put(k(1, Kind::State, 0), vec![1, 2, 3]);
        assert_eq!(s.get(&k(1, Kind::State, 0)), Some(vec![1, 2, 3]));
        assert_eq!(s.get(&k(1, Kind::State, 1)), None);
        let st = s.stats();
        assert_eq!(st.writes, 1);
        assert_eq!(st.bytes_written, 3);
        assert_eq!(st.virtual_latency, 5);
        assert_eq!(st.reads, 2);
    }

    #[test]
    fn delete_matching_gc() {
        let s = Store::new(0);
        for tag in 0..5 {
            s.put(k(1, Kind::Meta, tag), vec![0]);
        }
        s.put(k(2, Kind::Meta, 0), vec![0]);
        let n = s.delete_matching(1, |key| key.tag < 3);
        assert_eq!(n, 3);
        assert_eq!(s.keys_for(1, Kind::Meta).len(), 2);
        assert_eq!(s.keys_for(2, Kind::Meta).len(), 1);
    }

    /// The range-bounded scan: GC over one processor examines only that
    /// processor's keys, visible through `stats.keys_scanned`.
    #[test]
    fn scans_are_proc_ranged() {
        let s = Store::new(0);
        for tag in 0..4 {
            s.put(k(1, Kind::LogEntry, tag), vec![0]);
        }
        for tag in 0..100 {
            s.put(k(2, Kind::LogEntry, tag), vec![0]);
        }
        s.put(k(0, Kind::Meta, 0), vec![0]);
        s.reset_stats();
        assert_eq!(s.keys_for(1, Kind::LogEntry).len(), 4);
        assert_eq!(
            s.stats().keys_scanned,
            4,
            "scanning proc 1 must not touch proc 0/2 keys"
        );
        s.reset_stats();
        let n = s.delete_matching(1, |_| true);
        assert_eq!(n, 4);
        assert_eq!(s.stats().keys_scanned, 4);
        // The extreme proc id is range-scannable too (no overflow).
        s.put(k(u32::MAX, Kind::State, 9), vec![7]);
        assert_eq!(s.scan_keys(u32::MAX).len(), 1);
    }

    #[test]
    fn resident_bytes_tracks_contents() {
        let s = Store::new(0);
        s.put(k(1, Kind::State, 0), vec![0; 100]);
        s.put(k(1, Kind::State, 1), vec![0; 50]);
        assert_eq!(s.resident_bytes(), 150);
        s.delete(&k(1, Kind::State, 0));
        assert_eq!(s.resident_bytes(), 50);
        // Overwrites adjust, not accumulate.
        s.put(k(1, Kind::State, 1), vec![0; 20]);
        assert_eq!(s.resident_bytes(), 20);
        // Deleting a missing key is a no-op.
        s.delete(&k(9, Kind::State, 0));
        assert_eq!(s.resident_bytes(), 20);
        assert_eq!(s.stats().deletes, 1);
    }

    #[test]
    fn put_log_counts_batches_and_records() {
        let s = Store::new(2);
        s.put_log(k(1, Kind::LogEntry, 0), vec![0; 10], 4);
        s.put_log(k(1, Kind::LogEntry, 1), vec![0; 5], 1);
        s.put(k(1, Kind::State, 0), vec![0; 3]); // not a log write
        let st = s.stats();
        assert_eq!(st.writes, 3);
        assert_eq!(st.bytes_written, 18);
        assert_eq!(st.log_batches, 2);
        assert_eq!(st.log_records, 5);
        assert_eq!(st.virtual_latency, 6);
    }

    #[test]
    fn shared_handle_sees_writes() {
        let s = Store::new(0);
        let s2 = s.clone();
        s.put(k(9, Kind::LogEntry, 7), vec![42]);
        assert_eq!(s2.get(&k(9, Kind::LogEntry, 7)), Some(vec![42]));
    }

    #[test]
    fn kind_codes_roundtrip() {
        for kind in [
            Kind::Meta,
            Kind::State,
            Kind::LogEntry,
            Kind::HistoryEvent,
            Kind::InputFrontier,
            Kind::Chunk,
            Kind::Snapshot,
        ] {
            assert_eq!(Kind::from_code(kind.code()), Some(kind));
        }
        assert_eq!(Kind::from_code(99), None);
    }

    #[test]
    fn mem_backend_info() {
        let s = Store::new(0);
        s.put(k(1, Kind::State, 0), vec![0; 10]);
        let info = s.backend_info();
        assert_eq!(info.name, "mem");
        assert_eq!(info.live_keys, 1);
        assert_eq!(info.live_bytes, 10);
        assert_eq!(info.file_bytes, 0);
    }

    // ------------------------------------------------------------------
    // Staged-write pipeline.
    // ------------------------------------------------------------------

    /// Sync mode acknowledges at stage time via the lock-free fast path:
    /// sequence 0 is returned (at or below every watermark — trivially
    /// acked), the lag gauge stays at zero, and reads see the write
    /// immediately. Async sequencing starts at 1, so a sync-staged entry
    /// is acked under any later watermark too.
    #[test]
    fn sync_mode_acks_immediately() {
        let s = Store::new(0);
        assert_eq!(s.persist_mode(), PersistMode::Sync);
        let s1 = s.stage_put(k(3, Kind::State, 0), vec![1]).unwrap();
        let s2 = s.stage_put(k(3, Kind::State, 1), vec![2]).unwrap();
        assert_eq!((s1, s2), (0, 0), "sync fast path: trivially-acked sequence 0");
        assert!(s1 <= s.acked_seq(3), "a sync write is at or below the watermark");
        assert_eq!(s.ack_lag(), 0);
        assert_eq!(s.get(&k(3, Kind::State, 1)), Some(vec![2]));
        // Switching to async starts real sequencing above 0.
        s.set_persist_mode(PersistMode::Async { ack_every: 2 });
        let s3 = s.stage_put(k(3, Kind::State, 2), vec![3]).unwrap();
        assert_eq!(s3, 1);
        s.flush_staged();
        assert!(s.acked_seq(3) >= s3);
    }

    /// Async mode stages without applying until the writer drains; a
    /// flush barrier makes everything acked and readable.
    #[test]
    fn async_mode_acks_through_the_writer() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 4 });
        for tag in 0..10u64 {
            s.stage_put(k(1, Kind::State, tag), vec![tag as u8]).unwrap();
        }
        s.flush_staged();
        assert_eq!(s.acked_seq(1), 10);
        assert_eq!(s.ack_lag(), 0);
        for tag in 0..10u64 {
            assert_eq!(s.get(&k(1, Kind::State, tag)), Some(vec![tag as u8]));
        }
        assert_eq!(s.stats().writes, 10);
    }

    /// While paused, staged operations accumulate unacknowledged and
    /// reads serve the applied prefix; resume drains everything.
    #[test]
    fn paused_writer_leaves_a_deterministic_unacked_tail() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 2 });
        s.stage_put(k(1, Kind::State, 0), vec![9]).unwrap();
        s.flush_staged();
        assert_eq!(s.acked_seq(1), 1);
        s.pause_persistence();
        for tag in 1..5u64 {
            s.stage_put(k(1, Kind::State, tag), vec![tag as u8]).unwrap();
        }
        assert_eq!(s.acked_seq(1), 1, "paused: nothing acks");
        assert_eq!(s.staged_seq(1), 5);
        assert_eq!(s.ack_lag(), 4);
        // Reads while paused see only the applied prefix.
        assert_eq!(s.get(&k(1, Kind::State, 0)), Some(vec![9]));
        assert_eq!(s.get(&k(1, Kind::State, 3)), None);
        s.resume_persistence();
        s.flush_staged();
        assert_eq!(s.acked_seq(1), 5);
        assert_eq!(s.get(&k(1, Kind::State, 3)), Some(vec![3]));
    }

    /// `discard_unacked` drops exactly the staged-but-unacked suffix of
    /// one processor, leaving other processors' staged work intact.
    #[test]
    fn discard_unacked_is_per_proc_and_exact() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 8 });
        s.stage_put(k(1, Kind::State, 0), vec![1]).unwrap();
        s.stage_put(k(2, Kind::State, 0), vec![2]).unwrap();
        s.flush_staged();
        s.pause_persistence();
        s.stage_put(k(1, Kind::State, 1), vec![1]).unwrap();
        s.stage_put(k(2, Kind::State, 1), vec![2]).unwrap();
        let w = s.discard_unacked(1);
        assert_eq!(w, 1, "watermark = the applied prefix");
        assert_eq!(s.staged_seq(1), 1, "discarded ops rewind the staged counter");
        s.resume_persistence();
        s.flush_staged();
        // Proc 1's unacked write died; proc 2's survived.
        assert_eq!(s.get(&k(1, Kind::State, 1)), None);
        assert_eq!(s.get(&k(2, Kind::State, 1)), Some(vec![2]));
        // Staging resumes from the rewound sequence.
        assert_eq!(s.stage_put(k(1, Kind::State, 9), vec![0]).unwrap(), 2);
    }

    /// A simulated crash loses the queued staged tail (suffix-only), and
    /// per-proc FIFO guarantees no gaps.
    #[test]
    fn crash_loses_only_the_staged_suffix() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 4 });
        for tag in 0..4u64 {
            s.stage_put(k(1, Kind::LogEntry, tag), vec![tag as u8]).unwrap();
        }
        s.flush_staged();
        s.pause_persistence();
        for tag in 4..9u64 {
            s.stage_put(k(1, Kind::LogEntry, tag), vec![tag as u8]).unwrap();
        }
        s.simulate_crash();
        // The applied prefix survives in the backend; the queue suffix is
        // gone. (A MemBackend "crash" keeps applied blobs readable — the
        // file backend's own tail loss is tested in backend_file.)
        let survivors = s.inner.lock().unwrap().backend.scan_keys(1);
        assert_eq!(survivors.len(), 4, "exactly the acked prefix survives");
    }

    /// Deletions ride the same per-proc FIFO as puts: a staged
    /// put-then-delete lands in order, never resurrecting the blob.
    #[test]
    fn staged_deletes_order_after_staged_puts() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 64 });
        s.pause_persistence();
        s.stage_put(k(1, Kind::Meta, 7), vec![1]).unwrap();
        s.stage_delete(k(1, Kind::Meta, 7));
        s.resume_persistence();
        s.flush_staged();
        assert_eq!(s.get(&k(1, Kind::Meta, 7)), None);
        let st = s.stats();
        assert_eq!(st.writes, 1);
        assert_eq!(st.deletes, 1);
    }

    /// The size pre-check refuses oversized values synchronously in both
    /// modes, without consuming a sequence number.
    #[test]
    fn oversized_stage_put_is_refused_synchronously() {
        let s = Store::new(0);
        s.set_max_value_len(8);
        assert!(s.stage_put(k(1, Kind::State, 0), vec![0; 9]).is_err());
        assert_eq!(s.staged_seq(1), 0, "a refused write consumes no sequence number");
        s.set_persist_mode(PersistMode::Async { ack_every: 2 });
        assert!(s.stage_put(k(1, Kind::State, 0), vec![0; 9]).is_err());
        assert!(s.stage_put(k(1, Kind::State, 0), vec![0; 8]).is_ok());
        s.flush_staged();
        assert_eq!(s.get(&k(1, Kind::State, 0)), Some(vec![0; 8]));
    }

    // ------------------------------------------------------------------
    // Content-addressed snapshots.
    // ------------------------------------------------------------------

    /// A state whose chunks are position-distinct (so hashes differ).
    fn patterned_state(chunks: usize) -> Vec<u8> {
        (0..chunks * SNAPSHOT_CHUNK_BYTES).map(|i| (i % 251) as u8).collect()
    }

    #[test]
    fn chunk_helpers_split_and_hash() {
        assert_eq!(chunk_count(0), 0);
        assert_eq!(chunk_count(1), 1);
        assert_eq!(chunk_count(SNAPSHOT_CHUNK_BYTES), 1);
        assert_eq!(chunk_count(SNAPSHOT_CHUNK_BYTES + 1), 2);
        let state = patterned_state(2);
        let hashes = chunk_hashes(&state);
        assert_eq!(hashes.len(), 2);
        assert_eq!(hashes[0], fnv1a(&state[chunk_span(0, state.len())]));
        assert_eq!(hashes[1], fnv1a(&state[chunk_span(1, state.len())]));
        // A short tail chunk spans only the remainder.
        assert_eq!(chunk_span(1, SNAPSHOT_CHUNK_BYTES + 10).len(), 10);
    }

    /// Dedup: an unchanged chunk is never rewritten — within one
    /// snapshot's successor, and across full snapshots too.
    #[test]
    fn snapshot_dedup_hits_and_misses() {
        let s = Store::new(0);
        let mut state = patterned_state(3);
        let full = plan_snapshot(&state, None, SnapshotPolicy::Full);
        assert_eq!(full.chunks.len(), 3);
        s.stage_put_snapshot(7, 1, &full, &state).unwrap();
        assert_eq!(s.stats().chunks_reused, 0, "first write: all misses");
        assert_eq!(s.keys_for(7, Kind::Chunk).len(), 3);
        // Unchanged state: a second full snapshot rewrites nothing.
        let full2 = plan_snapshot(&state, None, SnapshotPolicy::Full);
        s.stage_put_snapshot(7, 2, &full2, &state).unwrap();
        let st = s.stats();
        assert_eq!(st.chunks_reused, 3);
        assert_eq!(st.chunk_bytes_reused, 3 * SNAPSHOT_CHUNK_BYTES as u64);
        assert_eq!(s.keys_for(7, Kind::Chunk).len(), 3, "no new chunks");
        // One dirtied chunk misses; the other two hit.
        state[SNAPSHOT_CHUNK_BYTES] ^= 0xff;
        let full3 = plan_snapshot(&state, None, SnapshotPolicy::Full);
        s.stage_put_snapshot(7, 3, &full3, &state).unwrap();
        assert_eq!(s.stats().chunks_reused, 5);
        assert_eq!(s.keys_for(7, Kind::Chunk).len(), 4);
        assert_eq!(s.materialize_snapshot(7, 3).unwrap(), state);
        // Dedup is per-processor: the same bytes under another proc
        // write their own chunks.
        s.stage_put_snapshot(8, 1, &full3, &state).unwrap();
        assert_eq!(s.keys_for(8, Kind::Chunk).len(), 3);
    }

    /// Delta planning lists only dirty positions, chains via
    /// `prior_snapshot`, and is forced full once the walk would exceed
    /// `max_chain`; materialization reassembles every link exactly.
    #[test]
    fn delta_chain_materializes_and_forces_full_at_max_chain() {
        let s = Store::new(0);
        let policy = SnapshotPolicy::Delta { max_chain: 2 };
        let mut state = patterned_state(2);
        state.extend_from_slice(&[42; 10]); // short tail chunk
        let s1 = plan_snapshot(&state, None, policy);
        assert!(s1.prior_snapshot.is_none(), "no base: full");
        assert_eq!(s1.chunks.len(), 3);
        s.stage_put_snapshot(4, 1, &s1, &state).unwrap();
        // Delta against the full base lists only the dirty chunk.
        let prev = state.clone();
        state[0] = 9;
        let base1 = SnapshotBase { tag: 1, hashes: chunk_hashes(&prev), walk_len: 1 };
        let s2 = plan_snapshot(&state, Some(&base1), policy);
        assert_eq!(s2.prior_snapshot, Some(1));
        assert_eq!(s2.chunks.len(), 1);
        assert_eq!(s2.chunks[0].0, 0);
        s.stage_put_snapshot(4, 2, &s2, &state).unwrap();
        assert_eq!(s.materialize_snapshot(4, 2).unwrap(), state);
        // A third link would make the walk 3 > max_chain: forced full.
        let prev2 = state.clone();
        state[SNAPSHOT_CHUNK_BYTES] = 7;
        let base2 = SnapshotBase { tag: 2, hashes: chunk_hashes(&prev2), walk_len: 2 };
        let s3 = plan_snapshot(&state, Some(&base2), policy);
        assert!(s3.prior_snapshot.is_none(), "forced full at max_chain");
        assert_eq!(s3.chunks.len(), 3);
        s.stage_put_snapshot(4, 3, &s3, &state).unwrap();
        assert_eq!(s.materialize_snapshot(4, 3).unwrap(), state);
        // An unchanged state under Delta is a valid *empty* delta.
        let base3 = SnapshotBase { tag: 3, hashes: chunk_hashes(&state), walk_len: 1 };
        let s4 = plan_snapshot(&state, Some(&base3), policy);
        assert_eq!(s4.chunks.len(), 0);
        assert_eq!(s4.prior_snapshot, Some(3));
        s.stage_put_snapshot(4, 4, &s4, &state).unwrap();
        assert_eq!(s.materialize_snapshot(4, 4).unwrap(), state);
        // Growth: a delta lists positions past the base's end.
        let prev4 = state.clone();
        state.extend_from_slice(&patterned_state(1));
        // Chain from the forced-full tag 3 (walk 1) — tag 4's own walk
        // is already 2, so another link over it would be forced full.
        let base4 = SnapshotBase { tag: 3, hashes: chunk_hashes(&prev4), walk_len: 1 };
        let s5 = plan_snapshot(&state, Some(&base4), policy);
        assert_eq!(s5.prior_snapshot, Some(3));
        // The old short tail chunk changed shape AND a new chunk
        // appeared past the old end.
        assert!(s5.chunks.iter().any(|&(p, _)| p as usize >= chunk_count(prev4.len())));
        s.stage_put_snapshot(4, 5, &s5, &state).unwrap();
        assert_eq!(s.materialize_snapshot(4, 5).unwrap(), state);
    }

    /// An unacked chain tail dies with `discard_unacked` exactly like
    /// any other unacked write, and the dedup index is rewound so
    /// recovery can re-stage the same content for real.
    #[test]
    fn unacked_snapshot_chain_tail_is_discarded() {
        let s = Store::new(0);
        s.set_persist_mode(PersistMode::Async { ack_every: 8 });
        let policy = SnapshotPolicy::Delta { max_chain: 8 };
        let mut state = patterned_state(2);
        let s1 = plan_snapshot(&state, None, policy);
        s.stage_put_snapshot(9, 1, &s1, &state).unwrap();
        s.flush_staged();
        // Stage a delta while the writer is paused: it never acks.
        s.pause_persistence();
        let prev = state.clone();
        state[0] = 0xaa;
        let base = SnapshotBase { tag: 1, hashes: chunk_hashes(&prev), walk_len: 1 };
        let s2 = plan_snapshot(&state, Some(&base), policy);
        assert_eq!(s2.chunks.len(), 1);
        s.stage_put_snapshot(9, 2, &s2, &state).unwrap();
        let w = s.discard_unacked(9);
        assert_eq!(w, 3, "acked prefix = 2 chunks + 1 snapshot record");
        s.resume_persistence();
        s.flush_staged();
        // The unacked tail (new chunk + snapshot record) never landed.
        assert_eq!(s.get(&Key { proc: 9, kind: Kind::Snapshot, tag: 2 }), None);
        assert_eq!(s.keys_for(9, Kind::Chunk).len(), 2);
        // The acked base still materializes.
        assert_eq!(s.materialize_snapshot(9, 1).unwrap(), prev);
        // The dedup index was rewound: re-staging the same delta under a
        // fresh tag writes the discarded chunk for real (no false hit).
        let s3 = s2.clone();
        s.stage_put_snapshot(9, 3, &s3, &state).unwrap();
        s.flush_staged();
        assert_eq!(s.keys_for(9, Kind::Chunk).len(), 3);
        assert_eq!(s.materialize_snapshot(9, 3).unwrap(), state);
    }

    /// Refusal (value-size pre-check) is atomic: nothing stages.
    #[test]
    fn snapshot_refusal_is_atomic() {
        let s = Store::new(0);
        s.set_max_value_len(16);
        let state = patterned_state(1);
        let snap = plan_snapshot(&state, None, SnapshotPolicy::Full);
        assert!(s.stage_put_snapshot(3, 1, &snap, &state).is_err());
        assert!(s.scan_keys(3).is_empty(), "refusal staged nothing");
        assert_eq!(s.stats().chunks_reused, 0);
    }

    /// The dedup index is reseeded from a reopened WAL, so dedup works
    /// across cold restarts.
    #[test]
    fn chunk_dedup_index_survives_reopen() {
        let dir = crate::util::tmp::TempDir::new("snap-dedup");
        let state = patterned_state(2);
        {
            let s =
                Store::open_dir(dir.path(), 0, FileBackendOptions::default()).unwrap();
            let snap = plan_snapshot(&state, None, SnapshotPolicy::Full);
            s.stage_put_snapshot(2, 1, &snap, &state).unwrap();
            s.sync();
        }
        let s = Store::open_dir(dir.path(), 0, FileBackendOptions::default()).unwrap();
        let snap2 = plan_snapshot(&state, None, SnapshotPolicy::Full);
        s.stage_put_snapshot(2, 2, &snap2, &state).unwrap();
        let st = s.stats();
        assert_eq!(st.chunks_reused, 2, "dedup index reseeded from the reopened WAL");
        assert_eq!(s.materialize_snapshot(2, 2).unwrap(), state);
    }

    /// A broken chain (missing prior, missing chunk, wrong-length chunk)
    /// materializes to `None`, never to wrong bytes.
    #[test]
    fn materialize_is_conservative_about_broken_chains() {
        let s = Store::new(0);
        let state = patterned_state(2);
        let full = plan_snapshot(&state, None, SnapshotPolicy::Full);
        s.stage_put_snapshot(5, 1, &full, &state).unwrap();
        // A delta whose prior is missing.
        let orphan = Snapshot {
            state_len: state.len() as u64,
            chunks: vec![],
            prior_snapshot: Some(99),
        };
        s.put(Key { proc: 5, kind: Kind::Snapshot, tag: 100 }, orphan.to_bytes());
        assert_eq!(s.materialize_snapshot(5, 100), None, "missing prior");
        // A cycle-shaped prior pointer (non-decreasing tag) is refused.
        let cyclic = Snapshot {
            state_len: state.len() as u64,
            chunks: vec![],
            prior_snapshot: Some(101),
        };
        s.put(Key { proc: 5, kind: Kind::Snapshot, tag: 101 }, cyclic.to_bytes());
        assert_eq!(s.materialize_snapshot(5, 101), None, "cyclic prior");
        // A missing chunk breaks materialization.
        let chunk_key = s.keys_for(5, Kind::Chunk)[0].clone();
        s.delete(&chunk_key);
        assert_eq!(s.materialize_snapshot(5, 1), None, "missing chunk");
    }

    /// Dropping the last handle drains the staging queue (graceful
    /// shutdown flushes, mirroring the WAL's flush-on-drop).
    #[test]
    fn drop_drains_staged_writes() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static APPLIED: AtomicU64 = AtomicU64::new(0);
        struct CountingBackend(MemBackend);
        impl StorageBackend for CountingBackend {
            fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError> {
                APPLIED.fetch_add(1, Ordering::SeqCst);
                self.0.put(key, value)
            }
            fn get(&mut self, key: &Key) -> Option<Vec<u8>> {
                self.0.get(key)
            }
            fn delete(&mut self, key: &Key) -> Option<u64> {
                self.0.delete(key)
            }
            fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)> {
                self.0.scan_entries(proc)
            }
            fn procs(&mut self) -> Vec<u32> {
                self.0.procs()
            }
            fn sync(&mut self) {}
            fn info(&self) -> BackendInfo {
                self.0.info()
            }
        }
        APPLIED.store(0, Ordering::SeqCst);
        {
            let s = Store::with_backend(Box::new(CountingBackend(MemBackend::new())), 0);
            s.set_persist_mode(PersistMode::Async { ack_every: 64 });
            for tag in 0..5u64 {
                s.stage_put(k(1, Kind::State, tag), vec![0]).unwrap();
            }
            // Dropped with the queue possibly still full.
        }
        assert_eq!(APPLIED.load(Ordering::SeqCst), 5, "drop drains the queue");
    }
}
