//! Durable-storage substrate.
//!
//! The paper assumes "reliably persisting state [is] adequately covered by
//! existing techniques" (§1) and builds on acknowledged writes (§4.2: a
//! processor sends Ξ(p,f) to the monitor only after storage acknowledges
//! the checkpoint, state, and log). We model exactly that contract behind
//! a pluggable [`StorageBackend`]:
//!
//! - [`MemBackend`] — the zero-regression default: an in-memory
//!   `BTreeMap` with virtual-latency accounting, for tests and benches
//!   that study policy overhead rather than durability;
//! - [`crate::ft::backend_file::FileBackend`] — a real on-disk segmented
//!   write-ahead log with group commit, crash-scan reopen, tombstones and
//!   compaction, for true cold-restart recovery
//!   ([`crate::ft::harness::FtSystem::reopen`]).
//!
//! The [`Store`] handle in front of the backend keeps the acknowledgement
//! accounting (write/read/delete counters, injectable virtual write
//! latency so benches can charge eager policies for their synchronous
//! writes) and a running resident-byte counter, so `resident_bytes` is
//! O(1) regardless of backend size.

use crate::ft::backend_file::{FileBackend, FileBackendOptions};
use std::collections::BTreeMap;
use std::ops::Bound;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// A storage key: (processor, kind, discriminator).
///
/// Ordering is `(proc, kind, tag)` — proc-major, which is what lets
/// backends serve per-processor scans from a range rather than a full
/// sweep.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub proc: u32,
    pub kind: Kind,
    pub tag: u64,
}

/// What a blob contains.
///
/// `Meta` must remain the first variant: backends compute per-processor
/// range bounds as `Key { proc, kind: Kind::Meta, tag: 0 }`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Checkpoint metadata Ξ(p,f) (a [`crate::ft::meta::MetaRecord`]).
    Meta,
    /// Checkpoint state S(p,f).
    State,
    /// A logged message (one entry of L(e,·)).
    LogEntry,
    /// Full-history event (H(p) entry).
    HistoryEvent,
    /// Durable input-frontier marker of a source processor (the §4.2
    /// Ξ(p,f) of a stateless logging source, whose state is trivially ∅:
    /// the frontier of input times the source has completely consumed
    /// *and* whose resulting sends are acknowledged in the log). One per
    /// processor, at tag 0, overwritten as the frontier advances.
    InputFrontier,
}

impl Kind {
    /// Stable on-disk code (the WAL record format).
    pub fn code(self) -> u8 {
        match self {
            Kind::Meta => 0,
            Kind::State => 1,
            Kind::LogEntry => 2,
            Kind::HistoryEvent => 3,
            Kind::InputFrontier => 4,
        }
    }

    /// Inverse of [`Kind::code`].
    pub fn from_code(c: u8) -> Option<Kind> {
        match c {
            0 => Some(Kind::Meta),
            1 => Some(Kind::State),
            2 => Some(Kind::LogEntry),
            3 => Some(Kind::HistoryEvent),
            4 => Some(Kind::InputFrontier),
            _ => None,
        }
    }
}

/// Write/read accounting, for the policy-overhead benches.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StorageStats {
    pub writes: u64,
    pub bytes_written: u64,
    pub deletes: u64,
    pub reads: u64,
    /// Σ of per-write virtual latency (cost units): eager policies pay
    /// this on the critical path; lazy ones off it.
    pub virtual_latency: u64,
    /// Message-log writes (one per sent *batch* — the batching win on the
    /// durable path is `log_records / log_batches` records amortized per
    /// acknowledged write).
    pub log_batches: u64,
    /// Records covered by those log writes.
    pub log_records: u64,
    /// Keys examined by scans (`keys_for` / `delete_matching` /
    /// `scan_keys`). Backends scan per-processor key *ranges*, so GC over
    /// one processor charges only that processor's keys here — the
    /// regression guard for the range-bounded scan path.
    pub keys_scanned: u64,
}

/// A write the backend refused (the write was *not* acknowledged and
/// nothing was persisted). The §4.2 contract treats an acknowledged
/// write as irrevocable, so [`Store::put`] panics on these; callers that
/// can degrade gracefully (CLI tools, admission control) use
/// [`Store::try_put`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StorageError {
    /// The encoded record exceeds the backend's maximum record size
    /// (a restart's scanner would reject it as corruption, so it must
    /// never be acknowledged in the first place).
    ValueTooLarge { size: u64, max: u64 },
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::ValueTooLarge { size, max } => {
                write!(f, "value of {size} bytes exceeds the backend's {max}-byte record limit")
            }
        }
    }
}

impl std::error::Error for StorageError {}

/// Aggregate counters a backend reports about itself (`falkirk store
/// inspect`, the storage benches, and the compaction tests read these).
#[derive(Clone, Debug, PartialEq)]
pub struct BackendInfo {
    /// "mem" or "file".
    pub name: &'static str,
    /// Keys currently resolvable.
    pub live_keys: u64,
    /// Bytes of live blob payload.
    pub live_bytes: u64,
    /// Bytes occupied on disk (0 for mem): live + dead records across all
    /// segments, including the unflushed group-commit tail.
    pub file_bytes: u64,
    /// Segment files (0 for mem).
    pub segments: u64,
    /// Bytes owed to overwritten/deleted records and tombstones, awaiting
    /// compaction (0 for mem).
    pub dead_bytes: u64,
    /// Segment compactions performed since open.
    pub compactions: u64,
}

impl BackendInfo {
    fn mem(live_keys: u64, live_bytes: u64) -> BackendInfo {
        BackendInfo {
            name: "mem",
            live_keys,
            live_bytes,
            file_bytes: 0,
            segments: 0,
            dead_bytes: 0,
            compactions: 0,
        }
    }
}

/// A pluggable durable key-value backend. Writes are acknowledged on
/// return (the §4.2 contract); a backend with a group-commit buffer
/// additionally guarantees the buffered tail is an append-order *prefix*
/// casualty under a crash — a surviving record implies every earlier
/// write survived, which is what the input-frontier markers and the
/// state-then-Ξ ordering rely on.
///
/// `get`/`scan_keys` take `&mut self` because a write-ahead backend may
/// need to flush its buffered tail before serving a read.
pub trait StorageBackend: Send {
    /// Persist a blob; returns the size of any blob it replaced. `Err`
    /// means the write was refused and nothing was persisted (e.g. the
    /// value exceeds the backend's record-size limit) — the blob is NOT
    /// acknowledged.
    fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError>;

    fn get(&mut self, key: &Key) -> Option<Vec<u8>>;

    /// Remove a blob; returns its size if it existed.
    fn delete(&mut self, key: &Key) -> Option<u64>;

    /// All (key, value size) pairs for `proc`, ascending — size metadata
    /// without reading blob contents. Implementations scan only the
    /// processor's key range.
    fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)>;

    /// All keys for `proc`, ascending.
    fn scan_keys(&mut self, proc: u32) -> Vec<Key> {
        self.scan_entries(proc).into_iter().map(|(k, _)| k).collect()
    }

    /// Distinct processor ids present, ascending.
    fn procs(&mut self) -> Vec<u32>;

    /// Force any buffered writes durable.
    fn sync(&mut self);

    /// Aggregate self-description.
    fn info(&self) -> BackendInfo;

    /// Rewrite storage to drop dead records (no-op where meaningless).
    fn compact(&mut self) {}

    /// Testing hook: die as a crash would — the unflushed group-commit
    /// tail is lost and nothing further is written (not even on drop).
    fn simulate_crash(&mut self) {}
}

/// The in-memory default backend (the pre-durability behavior).
#[derive(Default)]
pub struct MemBackend {
    blobs: BTreeMap<Key, Vec<u8>>,
}

impl MemBackend {
    pub fn new() -> MemBackend {
        MemBackend::default()
    }
}

/// Ascending distinct processor ids from an ascending key iterator
/// (shared by the backends' `procs` implementations).
pub(crate) fn distinct_procs<'a, I: Iterator<Item = &'a Key>>(keys: I) -> Vec<u32> {
    let mut out: Vec<u32> = Vec::new();
    for k in keys {
        if out.last() != Some(&k.proc) {
            out.push(k.proc);
        }
    }
    out
}

/// The `(lo, hi)` bounds covering exactly `proc`'s keys under the
/// `(proc, kind, tag)` ordering.
pub(crate) fn proc_range(proc: u32) -> (Bound<Key>, Bound<Key>) {
    let lo = Bound::Included(Key { proc, kind: Kind::Meta, tag: 0 });
    let hi = match proc.checked_add(1) {
        Some(next) => Bound::Excluded(Key { proc: next, kind: Kind::Meta, tag: 0 }),
        None => Bound::Unbounded,
    };
    (lo, hi)
}

impl StorageBackend for MemBackend {
    fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError> {
        Ok(self.blobs.insert(key.clone(), value.to_vec()).map(|old| old.len() as u64))
    }

    fn get(&mut self, key: &Key) -> Option<Vec<u8>> {
        self.blobs.get(key).cloned()
    }

    fn delete(&mut self, key: &Key) -> Option<u64> {
        self.blobs.remove(key).map(|old| old.len() as u64)
    }

    fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)> {
        self.blobs.range(proc_range(proc)).map(|(k, v)| (k.clone(), v.len() as u64)).collect()
    }

    fn procs(&mut self) -> Vec<u32> {
        distinct_procs(self.blobs.keys())
    }

    fn sync(&mut self) {}

    fn info(&self) -> BackendInfo {
        BackendInfo::mem(
            self.blobs.len() as u64,
            self.blobs.values().map(|v| v.len() as u64).sum(),
        )
    }
}

/// Durable store with ack semantics. Cloneable handle; the backend
/// serializes its own access through the handle's lock.
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<Inner>>,
}

struct Inner {
    backend: Box<dyn StorageBackend>,
    stats: StorageStats,
    /// Virtual cost charged per write (simulates fsync/replication).
    write_cost: u64,
    /// Running Σ of live blob bytes (maintained on put/delete so
    /// `resident_bytes` never walks the blob set).
    resident: u64,
}

impl Store {
    /// An in-memory store charging `write_cost` virtual latency units per
    /// write (the zero-regression default backend).
    pub fn new(write_cost: u64) -> Store {
        Store::with_backend(Box::new(MemBackend::new()), write_cost)
    }

    /// A store over an arbitrary backend. The resident-byte counter is
    /// seeded from the backend's live bytes (nonzero for a reopened WAL).
    pub fn with_backend(backend: Box<dyn StorageBackend>, write_cost: u64) -> Store {
        let resident = backend.info().live_bytes;
        Store {
            inner: Arc::new(Mutex::new(Inner {
                backend,
                stats: StorageStats::default(),
                write_cost,
                resident,
            })),
        }
    }

    /// Open (or create) a [`FileBackend`] WAL under `dir`. Reopening an
    /// existing directory rebuilds the key index by scanning segments; a
    /// torn or corrupt tail is truncated, not fatal.
    pub fn open_dir(
        dir: impl AsRef<Path>,
        write_cost: u64,
        opts: FileBackendOptions,
    ) -> std::io::Result<Store> {
        let backend = FileBackend::open(dir.as_ref(), opts)?;
        Ok(Store::with_backend(Box::new(backend), write_cost))
    }

    /// Open a WAL for inspection only: no on-disk repair, mutating
    /// operations panic (`falkirk store inspect` uses this so examining a
    /// just-crashed directory cannot destroy its torn tail).
    pub fn open_dir_read_only(
        dir: impl AsRef<Path>,
        opts: FileBackendOptions,
    ) -> std::io::Result<Store> {
        let backend = FileBackend::open_read_only(dir.as_ref(), opts)?;
        Ok(Store::with_backend(Box::new(backend), 0))
    }

    fn put_inner(
        &self,
        key: Key,
        value: Vec<u8>,
        log_records: Option<u64>,
    ) -> Result<(), StorageError> {
        let mut g = self.inner.lock().unwrap();
        // A refused write is not acknowledged: no stats, no residency.
        let replaced = g.backend.put(&key, &value)?.unwrap_or(0);
        g.stats.writes += 1;
        g.stats.bytes_written += value.len() as u64;
        g.stats.virtual_latency += g.write_cost;
        if let Some(records) = log_records {
            g.stats.log_batches += 1;
            g.stats.log_records += records;
        }
        g.resident = g.resident - replaced + value.len() as u64;
        Ok(())
    }

    /// Persist a blob; returns once "acknowledged" (synchronously here,
    /// with the virtual latency charged to the stats). Panics if the
    /// backend refuses the write — the FT layer has no continuation for
    /// an unacknowledgeable Ξ/state/log blob; use [`Store::try_put`] to
    /// handle refusal gracefully.
    pub fn put(&self, key: Key, value: Vec<u8>) {
        self.put_inner(key, value, None)
            .unwrap_or_else(|e| panic!("unacknowledgeable durable write: {e}"));
    }

    /// Like [`Store::put`], but surfaces a refused write (e.g. a value
    /// over the backend's record-size limit) as a recoverable error
    /// instead of panicking. On `Err` nothing was persisted.
    pub fn try_put(&self, key: Key, value: Vec<u8>) -> Result<(), StorageError> {
        self.put_inner(key, value, None)
    }

    /// Persist one message-log blob covering `records` records. Identical
    /// ack semantics to [`Store::put`], plus batch/record accounting so
    /// the policy-overhead benches can report amortization honestly.
    pub fn put_log(&self, key: Key, value: Vec<u8>, records: u64) {
        self.put_inner(key, value, Some(records))
            .unwrap_or_else(|e| panic!("unacknowledgeable durable log write: {e}"));
    }

    pub fn get(&self, key: &Key) -> Option<Vec<u8>> {
        let mut g = self.inner.lock().unwrap();
        g.stats.reads += 1;
        g.backend.get(key)
    }

    pub fn delete(&self, key: &Key) {
        let mut g = self.inner.lock().unwrap();
        if let Some(n) = g.backend.delete(key) {
            g.stats.deletes += 1;
            g.resident -= n;
        }
    }

    /// Delete all blobs for `proc` matching `pred` (garbage collection).
    /// Scans only `proc`'s key range.
    pub fn delete_matching<F: FnMut(&Key) -> bool>(&self, proc: u32, mut pred: F) -> usize {
        let mut g = self.inner.lock().unwrap();
        let keys = g.backend.scan_keys(proc);
        g.stats.keys_scanned += keys.len() as u64;
        let mut n = 0;
        for k in keys.into_iter().filter(|k| pred(k)) {
            if let Some(len) = g.backend.delete(&k) {
                g.stats.deletes += 1;
                g.resident -= len;
                n += 1;
            }
        }
        n
    }

    /// Keys currently stored for `proc` of a given kind.
    pub fn keys_for(&self, proc: u32, kind: Kind) -> Vec<Key> {
        let mut g = self.inner.lock().unwrap();
        let keys = g.backend.scan_keys(proc);
        g.stats.keys_scanned += keys.len() as u64;
        keys.into_iter().filter(|k| k.kind == kind).collect()
    }

    /// All keys for `proc`, ascending (the cold-restart loader reads each
    /// processor's durable state with one ranged scan).
    pub fn scan_keys(&self, proc: u32) -> Vec<Key> {
        let mut g = self.inner.lock().unwrap();
        let keys = g.backend.scan_keys(proc);
        g.stats.keys_scanned += keys.len() as u64;
        keys
    }

    /// All (key, value size) pairs for `proc`, ascending — metadata only,
    /// no blob reads (`falkirk store inspect` sums sizes from this).
    pub fn scan_entries(&self, proc: u32) -> Vec<(Key, u64)> {
        let mut g = self.inner.lock().unwrap();
        let entries = g.backend.scan_entries(proc);
        g.stats.keys_scanned += entries.len() as u64;
        entries
    }

    /// Distinct processor ids present, ascending.
    pub fn procs(&self) -> Vec<u32> {
        self.inner.lock().unwrap().backend.procs()
    }

    /// Total live bytes resident. O(1): maintained on put/delete.
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident
    }

    /// Force buffered writes durable (group-commit backends).
    pub fn sync(&self) {
        self.inner.lock().unwrap().backend.sync();
    }

    /// Rewrite storage to drop dead records (backend-specific; no-op for
    /// mem).
    pub fn compact(&self) {
        self.inner.lock().unwrap().backend.compact();
    }

    /// The backend's self-description (segments, live/dead bytes, …).
    pub fn backend_info(&self) -> BackendInfo {
        self.inner.lock().unwrap().backend.info()
    }

    /// Testing hook: crash the backend — the unflushed group-commit tail
    /// is lost and nothing further reaches disk (not even on drop). The
    /// handle stays usable only for dropping.
    pub fn simulate_crash(&self) {
        self.inner.lock().unwrap().backend.simulate_crash();
    }

    pub fn stats(&self) -> StorageStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn reset_stats(&self) {
        self.inner.lock().unwrap().stats = StorageStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(proc: u32, kind: Kind, tag: u64) -> Key {
        Key { proc, kind, tag }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = Store::new(5);
        s.put(k(1, Kind::State, 0), vec![1, 2, 3]);
        assert_eq!(s.get(&k(1, Kind::State, 0)), Some(vec![1, 2, 3]));
        assert_eq!(s.get(&k(1, Kind::State, 1)), None);
        let st = s.stats();
        assert_eq!(st.writes, 1);
        assert_eq!(st.bytes_written, 3);
        assert_eq!(st.virtual_latency, 5);
        assert_eq!(st.reads, 2);
    }

    #[test]
    fn delete_matching_gc() {
        let s = Store::new(0);
        for tag in 0..5 {
            s.put(k(1, Kind::Meta, tag), vec![0]);
        }
        s.put(k(2, Kind::Meta, 0), vec![0]);
        let n = s.delete_matching(1, |key| key.tag < 3);
        assert_eq!(n, 3);
        assert_eq!(s.keys_for(1, Kind::Meta).len(), 2);
        assert_eq!(s.keys_for(2, Kind::Meta).len(), 1);
    }

    /// The range-bounded scan: GC over one processor examines only that
    /// processor's keys, visible through `stats.keys_scanned`.
    #[test]
    fn scans_are_proc_ranged() {
        let s = Store::new(0);
        for tag in 0..4 {
            s.put(k(1, Kind::LogEntry, tag), vec![0]);
        }
        for tag in 0..100 {
            s.put(k(2, Kind::LogEntry, tag), vec![0]);
        }
        s.put(k(0, Kind::Meta, 0), vec![0]);
        s.reset_stats();
        assert_eq!(s.keys_for(1, Kind::LogEntry).len(), 4);
        assert_eq!(
            s.stats().keys_scanned,
            4,
            "scanning proc 1 must not touch proc 0/2 keys"
        );
        s.reset_stats();
        let n = s.delete_matching(1, |_| true);
        assert_eq!(n, 4);
        assert_eq!(s.stats().keys_scanned, 4);
        // The extreme proc id is range-scannable too (no overflow).
        s.put(k(u32::MAX, Kind::State, 9), vec![7]);
        assert_eq!(s.scan_keys(u32::MAX).len(), 1);
    }

    #[test]
    fn resident_bytes_tracks_contents() {
        let s = Store::new(0);
        s.put(k(1, Kind::State, 0), vec![0; 100]);
        s.put(k(1, Kind::State, 1), vec![0; 50]);
        assert_eq!(s.resident_bytes(), 150);
        s.delete(&k(1, Kind::State, 0));
        assert_eq!(s.resident_bytes(), 50);
        // Overwrites adjust, not accumulate.
        s.put(k(1, Kind::State, 1), vec![0; 20]);
        assert_eq!(s.resident_bytes(), 20);
        // Deleting a missing key is a no-op.
        s.delete(&k(9, Kind::State, 0));
        assert_eq!(s.resident_bytes(), 20);
        assert_eq!(s.stats().deletes, 1);
    }

    #[test]
    fn put_log_counts_batches_and_records() {
        let s = Store::new(2);
        s.put_log(k(1, Kind::LogEntry, 0), vec![0; 10], 4);
        s.put_log(k(1, Kind::LogEntry, 1), vec![0; 5], 1);
        s.put(k(1, Kind::State, 0), vec![0; 3]); // not a log write
        let st = s.stats();
        assert_eq!(st.writes, 3);
        assert_eq!(st.bytes_written, 18);
        assert_eq!(st.log_batches, 2);
        assert_eq!(st.log_records, 5);
        assert_eq!(st.virtual_latency, 6);
    }

    #[test]
    fn shared_handle_sees_writes() {
        let s = Store::new(0);
        let s2 = s.clone();
        s.put(k(9, Kind::LogEntry, 7), vec![42]);
        assert_eq!(s2.get(&k(9, Kind::LogEntry, 7)), Some(vec![42]));
    }

    #[test]
    fn kind_codes_roundtrip() {
        for kind in [
            Kind::Meta,
            Kind::State,
            Kind::LogEntry,
            Kind::HistoryEvent,
            Kind::InputFrontier,
        ] {
            assert_eq!(Kind::from_code(kind.code()), Some(kind));
        }
        assert_eq!(Kind::from_code(99), None);
    }

    #[test]
    fn mem_backend_info() {
        let s = Store::new(0);
        s.put(k(1, Kind::State, 0), vec![0; 10]);
        let info = s.backend_info();
        assert_eq!(info.name, "mem");
        assert_eq!(info.live_keys, 1);
        assert_eq!(info.live_bytes, 10);
        assert_eq!(info.file_bytes, 0);
    }
}
