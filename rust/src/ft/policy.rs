//! Checkpoint/logging policies — the per-regime fault-tolerance choices
//! of Figure 1.
//!
//! The paper's central argument is that these policies, which prior
//! systems hard-wired globally, can coexist per-processor within one
//! application. Each maps onto the framework as follows:
//!
//! | Policy        | Figure-1 regime   | F*(p)                  | logs?  | ack gate (async persistence)                         |
//! |---------------|-------------------|------------------------|--------|------------------------------------------------------|
//! | `Ephemeral`   | ephemeral         | any frontier (S = ∅)   | no     | none — persists nothing, nothing to acknowledge      |
//! | `LogOutputs`  | batch (Spark RDD) | any frontier (S = ∅)   | yes    | input-frontier marker offers only acked log prefixes |
//! | `Lazy{..}`    | lazy checkpoint   | selective ckpt chain   | option | a checkpoint is offerable once its Ξ write acks      |
//! | `Eager`       | eager checkpoint  | ckpt per event (seq)   | yes    | per-event checkpoints ack in order; crash = shorter chain |
//! | `FullHistory` | §4.1 fallback     | replay to any frontier | virtual| failed replay capped at the acked history prefix     |
//!
//! **Acknowledgement semantics under
//! [`crate::ft::storage::PersistMode::Async`].** Every policy's durable
//! writes are *staged* (the compute loop never blocks on storage) and
//! become recovery-relevant only once the store's per-processor ack
//! watermark passes them. Eager keeps its exactly-once contract with
//! respect to *durable* effects: a crash discards the unacked suffix of
//! per-event checkpoints, so recovery restores the newest acked one and
//! re-executes the suffix — exactly the rollback the paper's model
//! prescribes for unacknowledged work, never an inconsistency. For
//! Lazy/LogOutputs the lag only widens the replay window; Ephemeral is
//! unaffected by construction. Failed full-history processors replay the
//! acked history prefix; live ones replay their complete in-memory
//! mirror. In `PersistMode::Sync` staging acknowledges before returning
//! and every gate is trivially open (the pre-pipeline behavior).

/// A processor's fault-tolerance policy (see module docs).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Never persist anything; recover by upstream retry (clients of the
    /// ephemeral region re-send unacknowledged batches, §4.3).
    Ephemeral,
    /// Stateless processor that durably logs every sent message — the
    /// Spark-RDD "firewall" of §4.1 that stops rollback propagating
    /// upstream (Fig. 7b).
    LogOutputs,
    /// Selective checkpoints taken when logical times complete, once per
    /// `every` completions (the "lazy checkpoint" streaming regime).
    /// Optionally also logs outputs.
    Lazy { every: u64, log_outputs: bool },
    /// Exactly-once streaming (§2.1): persist state and outgoing messages
    /// after *every* event, before acknowledging — sequence-number
    /// domains (MillWheel/Storm-with-ackers).
    Eager,
    /// Log the full event history H(p); any deterministic processor gets
    /// fault tolerance with zero code — rollback replays the filtered
    /// history (§4.1). History grows without bound.
    FullHistory,
}

impl Policy {
    /// Whether sent messages are durably logged (D̄ = ∅).
    pub fn logs_outputs(&self) -> bool {
        matches!(
            self,
            Policy::LogOutputs | Policy::Eager | Policy::Lazy { log_outputs: true, .. }
        )
    }

    /// Whether the processor restores via an explicit checkpoint chain
    /// (vs. the "any frontier" stateless/replay class).
    pub fn has_chain(&self) -> bool {
        matches!(self, Policy::Lazy { .. } | Policy::Eager)
    }

    /// Whether the full event history is recorded.
    pub fn records_history(&self) -> bool {
        matches!(self, Policy::FullHistory)
    }

    /// Whether any Table-1 delta tracking is needed at all (Ephemeral
    /// processors run with zero fault-tolerance overhead).
    pub fn tracks_metadata(&self) -> bool {
        !matches!(self, Policy::Ephemeral)
    }
}

/// How a checkpoint's state payload is represented durably — orthogonal
/// to [`Policy`], which decides *when* checkpoints are taken. Either
/// way the state is split into content-addressed chunks
/// ([`crate::ft::storage::SNAPSHOT_CHUNK_BYTES`]) and a
/// [`crate::ft::meta::Snapshot`] record names them; chunk dedup means
/// an unchanged chunk is never rewritten even under `Full`. What
/// `Delta` adds is a *sparse* snapshot record chained to its base via
/// `prior_snapshot`, so the record itself also scales with the delta.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum SnapshotPolicy {
    /// Every checkpoint's snapshot lists every chunk position
    /// (materialization reads exactly one snapshot record).
    #[default]
    Full,
    /// List only the chunk positions that changed since the last
    /// *acked* snapshot, chaining via `prior_snapshot`. Every
    /// checkpoint whose materialization walk would exceed `max_chain`
    /// snapshot records is forced full, bounding recovery walk depth at
    /// O(`max_chain`); `max_chain` ≤ 1 therefore degenerates to `Full`.
    Delta {
        /// Upper bound on the snapshot records one materialization
        /// walks (clamped to ≥ 1).
        max_chain: u64,
    },
}

impl SnapshotPolicy {
    /// The effective walk-depth bound (1 for `Full`).
    pub fn max_chain(&self) -> u64 {
        match self {
            SnapshotPolicy::Full => 1,
            SnapshotPolicy::Delta { max_chain } => (*max_chain).max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_policy_chain_bound() {
        assert_eq!(SnapshotPolicy::Full.max_chain(), 1);
        assert_eq!(SnapshotPolicy::Delta { max_chain: 8 }.max_chain(), 8);
        assert_eq!(
            SnapshotPolicy::Delta { max_chain: 0 }.max_chain(),
            1,
            "degenerate bound clamps to Full behavior"
        );
        assert_eq!(SnapshotPolicy::default(), SnapshotPolicy::Full);
    }

    #[test]
    fn classification() {
        assert!(!Policy::Ephemeral.logs_outputs());
        assert!(Policy::LogOutputs.logs_outputs());
        assert!(Policy::Eager.logs_outputs());
        assert!(Policy::Lazy { every: 1, log_outputs: true }.logs_outputs());
        assert!(!Policy::Lazy { every: 1, log_outputs: false }.logs_outputs());
        assert!(Policy::Eager.has_chain());
        assert!(!Policy::FullHistory.has_chain());
        assert!(Policy::FullHistory.records_history());
        assert!(!Policy::Ephemeral.tracks_metadata());
    }
}
