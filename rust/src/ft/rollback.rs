//! Choosing consistent frontiers for rollback — §3.5 constraints and the
//! Figure-6 fixed-point algorithm.
//!
//! Given, for every processor, the set of frontiers it can restore to
//! ([`Available`]), the solver picks the **maximal** globally-consistent
//! assignment `f(p)` (plus the auxiliary notification frontiers `f_n(p)`
//! that rule out the Fig. 5 inconsistency). The §3.5 constraints:
//!
//! 1. *(creation-time)* a checkpoint for `f` is only saved once all times
//!    in `f` are complete at `p` — enforced by the harness, not here;
//! 2. ∀e ∈ Out(p): `D̄(e, f(p)) ⊆ f(dst(e))` — nobody may need a message
//!    `p` discarded;
//! 3. ∀d ∈ In(p): `M̄(d, f(p)) ⊆ φ(d)(f(src(d)))` — everything `p` kept
//!    must be "fixed" by its upstream's rollback;
//! 4. `f_n(p) ⊆ f(p)`, `N̄(p, f(p)) ⊆ f_n(p)`, and
//!    ∀d: `f_n(p) ⊆ φ(d)(f_n(src(d)))` — processed notifications must
//!    remain justified transitively.
//!
//! The solver is a monotone worklist fixed point: frontiers only shrink,
//! and `f(p) = f_n(p) = ∅` satisfies everything, so it terminates. Both a
//! batch solve (recovery, §4.4) and an incremental *increase* propagation
//! (the §4.2 garbage-collection monitor, where adding checkpoints can
//! only grow the solution) are provided.
//!
//! The solver sees only Ξ metadata ([`CkptMeta`]); how the chosen
//! checkpoint's *state bytes* are durably represented — one full
//! content-addressed snapshot record or a `prior_snapshot` delta chain
//! ([`crate::ft::policy::SnapshotPolicy`]) — is invisible here. Rollback
//! materializes the state by walking the chain
//! ([`crate::ft::storage::Store::materialize_snapshot`]) after this
//! solver has picked the frontier.

use crate::frontier::Frontier;
use crate::ft::meta::CkptMeta;
use crate::graph::{EdgeId, ProcId, Topology};
use crate::time::TimeDomain;
use std::collections::{BTreeSet, VecDeque};

/// What frontiers a processor can restore to.
///
/// `dedup` marks *epoch-idempotent* processors: their engine-level
/// completed-time dedup silently drops re-delivered messages at times
/// they have already completed, which mechanically enforces both the
/// delivered-message constraint (3) and the notification promise (4) for
/// times inside their checkpoints — so those constraints are relaxed.
/// This is what lets the Figure-1 regime boundaries (ephemeral → batch /
/// iterative) recover independently, the paper's motivating mixture.
#[derive(Clone, Debug)]
pub enum Available {
    /// An explicit ascending chain of checkpoints (∅ is always implicitly
    /// available and need not be listed). The last element may be the
    /// live-state pseudo-checkpoint at ⊤ (§4.4). For deduping processors
    /// `dedup` carries the live completed-time frontier: true checkpoints
    /// (complete by construction) are exempt from constraints 3–4, while
    /// the ⊤ pseudo-checkpoint is exempt only for its completed portion.
    Chain { chain: Vec<CkptMeta>, dedup: Option<Frontier> },
    /// §3.4's "restore to any requested frontier" class (stateless /
    /// full-history processors): S = ∅, φ(e)(f) = M̄(d,f) = N̄(p,f) = f,
    /// and D̄(e,f) = ∅ if `logs_outputs` else φ(e)(f). For deduping
    /// processors `completed` is their completed-time frontier, which
    /// additionally caps the restorable frontier (incomplete consumed
    /// times cannot be re-deduplicated) while exempting completed times
    /// from upstream coverage.
    Any {
        logs_outputs: bool,
        dedup_completed: Option<Frontier>,
    },
}

impl Available {
    /// Plain checkpoint chain (no dedup).
    pub fn chain(chain: Vec<CkptMeta>) -> Available {
        Available::Chain { chain, dedup: None }
    }

    /// Checkpoint chain of an epoch-idempotent processor with the given
    /// live completed-time frontier.
    pub fn chain_dedup(chain: Vec<CkptMeta>, completed: Frontier) -> Available {
        Available::Chain { chain, dedup: Some(completed) }
    }

    /// Restore-anywhere processor (no dedup).
    pub fn any(logs_outputs: bool) -> Available {
        Available::Any { logs_outputs, dedup_completed: None }
    }

    /// Restore-anywhere epoch-idempotent processor with the given
    /// completed-time frontier.
    pub fn any_dedup(logs_outputs: bool, completed: Frontier) -> Available {
        Available::Any { logs_outputs, dedup_completed: Some(completed) }
    }

    /// Whether this processor dedups completed-time deliveries.
    pub fn dedups(&self) -> bool {
        self.dedup_completed().is_some()
    }

    fn dedup_completed(&self) -> Option<&Frontier> {
        match self {
            Available::Chain { dedup, .. } => dedup.as_ref(),
            Available::Any { dedup_completed, .. } => dedup_completed.as_ref(),
        }
    }

    fn max_frontier(&self) -> Frontier {
        match self {
            Available::Any { .. } => Frontier::Top,
            Available::Chain { chain, .. } => {
                chain.last().map(|c| c.f.clone()).unwrap_or(Frontier::Bottom)
            }
        }
    }
}

/// Solver input: a topology plus per-processor availability.
pub struct RollbackInput<'a> {
    pub topo: &'a Topology,
    pub avail: &'a [Available],
}

/// Solver output: `f(p)` and `f_n(p)` per processor. In a sharded
/// topology each shard is a processor, so this *is* the per-shard
/// rollback plan — the helpers below are what the sharded recovery path
/// and its tests read.
#[derive(Clone, Debug, PartialEq)]
pub struct RollbackPlan {
    pub f: Vec<Frontier>,
    pub f_n: Vec<Frontier>,
}

impl RollbackPlan {
    /// The chosen frontier of processor (shard) `p`.
    pub fn frontier(&self, p: ProcId) -> &Frontier {
        &self.f[p.0 as usize]
    }

    /// Processors left untouched at ⊤ (no rollback at all).
    pub fn untouched(&self) -> usize {
        self.f.iter().filter(|f| f.is_top()).count()
    }

    /// Processors that actually roll back (chosen frontier below ⊤) —
    /// for a single-shard failure under logging policies this is exactly
    /// the failed shard.
    pub fn rolled_back(&self) -> Vec<ProcId> {
        self.f
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.is_top())
            .map(|(i, _)| ProcId(i as u32))
            .collect()
    }

    /// Distinct shard groups among the rolled-back processors under the
    /// given proc→group assignment — the restore parallelism a parallel
    /// recovery ([`crate::ft::FtSystem::recover_parallel`]) can achieve
    /// for this plan (its `FtStats::recovery_parallelism` gauge records
    /// exactly this when every group restores concurrently).
    pub fn rollback_groups(&self, group_of: &[usize]) -> usize {
        let mut groups: Vec<usize> = self
            .f
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.is_top())
            .map(|(i, _)| group_of[i])
            .collect();
        groups.sort_unstable();
        groups.dedup();
        groups.len()
    }
}

/// Evaluate φ(d)(g) for edge `d` given the *source's* chosen frontier `g`:
/// static projections compute it; per-checkpoint projections look it up in
/// the source's stored metadata (g is always one of the source's
/// checkpoints, or ∅/⊤).
fn phi_of_edge(input: &RollbackInput, d: EdgeId, g: &Frontier) -> Frontier {
    let proj = input.topo.projection(d);
    if let Some(f) = proj.apply(g) {
        return f;
    }
    // PerCheckpoint: find the metadata for g at the source.
    if g.is_bottom() {
        return Frontier::Bottom;
    }
    // ⊤ means the source keeps its whole history: everything it ever sent
    // is fixed.
    if g.is_top() {
        return Frontier::Top;
    }
    let src = input.topo.src(d);
    match &input.avail[src.0 as usize] {
        // A stateless processor never recorded per-checkpoint counts; the
        // only sound estimate for a mid-range frontier is ∅ (maximally
        // conservative, §3.2: "we could always set φ(e)(f) = ∅").
        Available::Any { .. } => Frontier::Bottom,
        Available::Chain { chain, .. } => chain
            .iter()
            .find(|c| &c.f == g)
            .unwrap_or_else(|| panic!("φ lookup: frontier {g} is not a checkpoint of {src}"))
            .phi_of(d)
            .clone(),
    }
}

/// The upper bound every in-edge imposes on an Any-processor's frontier
/// (constraints 3 and 4 with M̄ = N̄ = g).
fn any_upper_bound(input: &RollbackInput, p: ProcId, f: &[Frontier], f_n: &[Frontier]) -> Frontier {
    let dedup_completed = match &input.avail[p.0 as usize] {
        Available::Any { dedup_completed, .. } => dedup_completed.clone(),
        _ => None,
    };
    let mut g = Frontier::Top;
    match &dedup_completed {
        Some(completed) => {
            // Epoch-idempotent: completed times need no upstream coverage
            // (re-deliveries are dropped) and the notification promise is
            // enforced mechanically. Consumed-but-incomplete times cannot
            // be vouched for, so unless every upstream stays at ⊤ (no
            // re-execution at all), cap at the completed frontier.
            let all_top = input
                .topo
                .in_edges(p)
                .iter()
                .all(|&d| f[input.topo.src(d).0 as usize].is_top());
            if !all_top {
                g = g.intersect(completed);
            }
        }
        None => {
            for &d in input.topo.in_edges(p) {
                let src = input.topo.src(d);
                g = g.intersect(&phi_of_edge(input, d, &f[src.0 as usize]));
                g = g.intersect(&phi_of_edge(input, d, &f_n[src.0 as usize]));
            }
        }
    }
    // Constraint 2: D̄(e,g) ⊆ f(dst(e)). For Any processors D̄(e,g) is ∅
    // when logging, φ(e)(g) otherwise — in which case the bound is the
    // projection preimage of f(dst(e)).
    let logs = matches!(input.avail[p.0 as usize], Available::Any { logs_outputs: true, .. });
    if !logs {
        let depth = match input.topo.domain(p) {
            TimeDomain::Structured { depth } => depth,
            TimeDomain::Seq => 0,
        };
        for &e in input.topo.out_edges(p) {
            let dst = input.topo.dst(e);
            let fd = &f[dst.0 as usize];
            let pre = match input.topo.projection(e).preimage(fd, depth) {
                Some(pre) => pre,
                // Per-checkpoint projection with no recorded counts: only
                // the trivial bounds are sound — ⊤ when the destination
                // keeps everything, ∅ otherwise (the destination would
                // need messages this processor cannot identify).
                None if fd.is_top() => Frontier::Top,
                None => Frontier::Bottom,
            };
            g = g.intersect(&pre);
        }
    }
    g
}

/// Check constraints 2–4 for chain element `c` at processor `p` under the
/// current assignment. Returns the implied `f_n(p)` on success.
fn chain_elem_ok(
    input: &RollbackInput,
    p: ProcId,
    c: &CkptMeta,
    f: &[Frontier],
    f_n: &[Frontier],
    dedup: Option<&Frontier>,
) -> Option<Frontier> {
    // Constraint 2: discarded messages.
    for &e in input.topo.out_edges(p) {
        if !c.d_bar_of(e).is_subset(&f[input.topo.dst(e).0 as usize]) {
            return None;
        }
    }
    if let Some(completed) = dedup {
        // Epoch-idempotent: constraints 3 and 4 are enforced mechanically
        // by completed-time dedup for everything *complete*. True
        // checkpoints are complete by construction; the ⊤ live
        // pseudo-checkpoint additionally reflects consumed-but-incomplete
        // events, which upstream must still fix (constraint 3 on the
        // portion beyond `completed`).
        if c.f.is_top() {
            for &d in input.topo.in_edges(p) {
                let src = input.topo.src(d);
                let cover =
                    phi_of_edge(input, d, &f[src.0 as usize]).union(completed);
                if !c.m_bar_of(d).is_subset(&cover) {
                    return None;
                }
            }
            let g_n = completed.intersect(&f_n[p.0 as usize]);
            if !completed.is_subset(&g_n) {
                return None;
            }
            return Some(g_n);
        }
        return Some(c.f.intersect(&f_n[p.0 as usize]));
    }
    // Constraint 3: delivered messages.
    for &d in input.topo.in_edges(p) {
        let src = input.topo.src(d);
        if !c.m_bar_of(d).is_subset(&phi_of_edge(input, d, &f[src.0 as usize])) {
            return None;
        }
    }
    // Constraint 4: notification frontier. g_n = f'(p) ∩ f_n(p) ∩
    // ∩_d φ(d)(f_n(src(d))) must contain N̄(p, f'(p)).
    let mut g_n = c.f.intersect(&f_n[p.0 as usize]);
    for &d in input.topo.in_edges(p) {
        let src = input.topo.src(d);
        g_n = g_n.intersect(&phi_of_edge(input, d, &f_n[src.0 as usize]));
    }
    if !c.n_bar.is_subset(&g_n) {
        return None;
    }
    Some(g_n)
}

/// One per-processor update of the Fig. 6 fixed point. Returns the new
/// `(f(p), f_n(p))`.
fn update_proc(
    input: &RollbackInput,
    p: ProcId,
    f: &[Frontier],
    f_n: &[Frontier],
) -> (Frontier, Frontier) {
    match &input.avail[p.0 as usize] {
        Available::Any { .. } => {
            // f'(p) = the intersection of all upper bounds; N̄ = f' ⊆ g_n
            // = f' is immediate, so f_n' = f'.
            let g = f[p.0 as usize].intersect(&any_upper_bound(input, p, f, f_n));
            let g_n = g.intersect(&f_n[p.0 as usize]);
            // For Any processors N̄(p,g) = g must be ⊆ g_n; shrink g to
            // g_n to satisfy it (they are equal in all but pathological
            // assignments).
            (g_n.clone(), g_n)
        }
        Available::Chain { chain, dedup } => {
            // Largest chain element ⊆ f(p) passing all constraints; ∅ is
            // the always-valid fallback.
            for c in chain.iter().rev() {
                if !c.f.is_subset(&f[p.0 as usize]) {
                    continue;
                }
                if let Some(g_n) = chain_elem_ok(input, p, c, f, f_n, dedup.as_ref()) {
                    return (c.f.clone(), g_n);
                }
            }
            (Frontier::Bottom, Frontier::Bottom)
        }
    }
}

/// Batch solve: run the Fig. 6 fixed point to completion.
pub fn choose_frontiers(input: &RollbackInput) -> RollbackPlan {
    let n = input.topo.num_procs();
    // Initially f(p) = f_n(p) = max F*(p).
    let mut f: Vec<Frontier> = (0..n).map(|i| input.avail[i].max_frontier()).collect();
    let mut f_n = f.clone();

    let mut work: VecDeque<ProcId> = input.topo.proc_ids().collect();
    let mut queued: BTreeSet<ProcId> = work.iter().copied().collect();
    let mut iterations = 0usize;
    while let Some(p) = work.pop_front() {
        queued.remove(&p);
        iterations += 1;
        assert!(
            iterations <= 4 * n * n * (input.topo.num_edges() + n) + 64,
            "rollback fixed point failed to converge"
        );
        let (nf, nfn) = update_proc(input, p, &f, &f_n);
        debug_assert!(nf.is_subset(&f[p.0 as usize]), "frontier grew at {p}");
        if nf != f[p.0 as usize] || nfn != f_n[p.0 as usize] {
            f[p.0 as usize] = nf;
            f_n[p.0 as usize] = nfn;
            // Constraints couple p with both its upstream and downstream
            // neighbours; re-examine them.
            for &e in input.topo.out_edges(p) {
                let q = input.topo.dst(e);
                if queued.insert(q) {
                    work.push_back(q);
                }
            }
            for &d in input.topo.in_edges(p) {
                let q = input.topo.src(d);
                if queued.insert(q) {
                    work.push_back(q);
                }
            }
        }
    }
    RollbackPlan { f, f_n }
}

/// Verify that an assignment satisfies constraints 2–4 (used by the test
/// suite and the property tests; constraint 1 is a harness invariant).
pub fn verify_plan(input: &RollbackInput, plan: &RollbackPlan) -> Result<(), String> {
    for p in input.topo.proc_ids() {
        let fp = &plan.f[p.0 as usize];
        let fnp = &plan.f_n[p.0 as usize];
        if !fnp.is_subset(fp) {
            return Err(format!("{p}: f_n ⊄ f"));
        }
        let (n_bar, d_bar_of, m_bar_of): (
            Frontier,
            Box<dyn Fn(EdgeId) -> Frontier>,
            Box<dyn Fn(EdgeId) -> Frontier>,
        ) = match &input.avail[p.0 as usize] {
            Available::Any { logs_outputs, .. } => {
                let fp2 = fp.clone();
                let fp3 = fp.clone();
                let logs = *logs_outputs;
                let topo = input.topo;
                (
                    fp.clone(),
                    Box::new(move |e| {
                        if logs {
                            Frontier::Bottom
                        } else {
                            topo.projection(e).apply(&fp2).expect("static projection")
                        }
                    }),
                    Box::new(move |_| fp3.clone()),
                )
            }
            Available::Chain { chain, .. } => {
                if fp.is_bottom() {
                    continue; // ∅ satisfies everything.
                }
                let c = chain
                    .iter()
                    .find(|c| &c.f == fp)
                    .ok_or_else(|| format!("{p}: chosen frontier {fp} not in chain"))?
                    .clone();
                let c2 = c.clone();
                (
                    c.n_bar.clone(),
                    Box::new(move |e| c.d_bar_of(e).clone()),
                    Box::new(move |d| c2.m_bar_of(d).clone()),
                )
            }
        };
        for &e in input.topo.out_edges(p) {
            let dst = input.topo.dst(e);
            if !d_bar_of(e).is_subset(&plan.f[dst.0 as usize]) {
                return Err(format!("{p}: D̄({e}) ⊄ f({dst})"));
            }
        }
        match input.avail[p.0 as usize].dedup_completed() {
            Some(completed) => {
                // Epoch-idempotent: only the consumed-but-incomplete
                // portion of a ⊤ assignment needs upstream coverage.
                if fp.is_top() {
                    for &d in input.topo.in_edges(p) {
                        let src = input.topo.src(d);
                        let cover = phi_of_edge(input, d, &plan.f[src.0 as usize])
                            .union(completed);
                        if !m_bar_of(d).is_subset(&cover) {
                            return Err(format!("{p}: M̄({d}) ⊄ φ(f(src)) ∪ completed"));
                        }
                    }
                }
            }
            None => {
                for &d in input.topo.in_edges(p) {
                    let src = input.topo.src(d);
                    if !m_bar_of(d).is_subset(&phi_of_edge(input, d, &plan.f[src.0 as usize])) {
                        return Err(format!("{p}: M̄({d}) ⊄ φ(f({src}))"));
                    }
                    if !fnp.is_subset(&phi_of_edge(input, d, &plan.f_n[src.0 as usize])) {
                        return Err(format!("{p}: f_n ⊄ φ(f_n({src}))"));
                    }
                }
                if !n_bar.is_subset(fnp) {
                    return Err(format!("{p}: N̄ ⊄ f_n"));
                }
            }
        }
    }
    Ok(())
}

/// Incremental *increase* propagation for the GC monitor (§4.2): after new
/// checkpoints are added at `changed`, grow the previous solution. Valid
/// because adding elements to F*(p) never shrinks any f(p′) (§3.6's
/// monotonicity remark; the property suite checks equality with batch
/// solves on random graphs).
///
/// Two phases: (1) lift the *slack-connected* region around `changed` —
/// processors whose chain maximum exceeds their current assignment — to
/// their optimistic maxima; (2) run the decreasing fixed point over that
/// region (plus its boundary, whose notification frontiers may rise).
/// A localized Ξ arrival that cannot move the watermark touches O(slack
/// region), not the whole graph.
/// Returns the processors whose `f` actually changed (for the monitor's
/// GC-action diff — avoids an O(n) plan comparison per update).
pub fn grow_frontiers(
    input: &RollbackInput,
    plan: &mut RollbackPlan,
    changed: ProcId,
) -> Vec<ProcId> {
    // Saved entry values of everything we touch (lazily captured).
    let mut saved: std::collections::BTreeMap<ProcId, Frontier> = Default::default();
    // Phase 1: lift the slack-connected region.
    let mut seen: BTreeSet<ProcId> = BTreeSet::new();
    let mut stack = vec![changed];
    let mut region: Vec<ProcId> = Vec::new();
    while let Some(p) = stack.pop() {
        if !seen.insert(p) {
            continue;
        }
        let i = p.0 as usize;
        let max = input.avail[i].max_frontier();
        if max.is_subset(&plan.f[i]) {
            continue; // no slack: cannot rise, does not propagate lift
        }
        saved.entry(p).or_insert_with(|| plan.f[i].clone());
        plan.f[i] = max.clone();
        plan.f_n[i] = max;
        region.push(p);
        for &e in input.topo.out_edges(p) {
            stack.push(input.topo.dst(e));
        }
        for &d in input.topo.in_edges(p) {
            stack.push(input.topo.src(d));
        }
    }
    if region.is_empty() {
        return Vec::new();
    }
    // Phase 2: decreasing fixed point, seeded with the lifted region and
    // its boundary (whose f_n may rise via upstream lifts).
    let mut work: VecDeque<ProcId> = VecDeque::new();
    let mut queued: BTreeSet<ProcId> = BTreeSet::new();
    for &p in &region {
        if queued.insert(p) {
            work.push_back(p);
        }
        for &e in input.topo.out_edges(p) {
            let q = input.topo.dst(e);
            if queued.insert(q) {
                work.push_back(q);
            }
        }
    }
    while let Some(p) = work.pop_front() {
        queued.remove(&p);
        let (nf, nfn) = update_proc(input, p, &plan.f, &plan.f_n);
        if nf != plan.f[p.0 as usize] || nfn != plan.f_n[p.0 as usize] {
            saved.entry(p).or_insert_with(|| plan.f[p.0 as usize].clone());
            plan.f[p.0 as usize] = nf;
            plan.f_n[p.0 as usize] = nfn;
            for &e in input.topo.out_edges(p) {
                let q = input.topo.dst(e);
                if queued.insert(q) {
                    work.push_back(q);
                }
            }
            for &d in input.topo.in_edges(p) {
                let q = input.topo.src(d);
                if queued.insert(q) {
                    work.push_back(q);
                }
            }
        }
    }
    saved
        .into_iter()
        .filter(|(p, old)| &plan.f[p.0 as usize] != old)
        .map(|(p, _)| p)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Projection};
    use crate::time::TimeDomain;
    use std::collections::BTreeMap;

    /// Chain element for an epoch processor that has processed and
    /// checkpointed through epoch `e`, discarding its sent messages.
    fn epoch_ckpt(
        e: u64,
        in_edges: &[EdgeId],
        out_edges: &[EdgeId],
        logs: bool,
    ) -> CkptMeta {
        let f = Frontier::upto_epoch(e);
        CkptMeta {
            f: f.clone(),
            n_bar: f.clone(),
            m_bar: in_edges.iter().map(|d| (*d, f.clone())).collect(),
            d_bar: out_edges
                .iter()
                .map(|o| (*o, if logs { Frontier::Bottom } else { f.clone() }))
                .collect(),
            phi: out_edges.iter().map(|o| (*o, f.clone())).collect(),
        }
    }

    /// a → b → c epoch pipeline.
    fn pipeline3() -> (crate::graph::Topology, Vec<EdgeId>) {
        let mut g = GraphBuilder::new();
        let a = g.add_proc("a", TimeDomain::EPOCH);
        let b = g.add_proc("b", TimeDomain::EPOCH);
        let c = g.add_proc("c", TimeDomain::EPOCH);
        let e0 = g.connect(a, b, Projection::Identity);
        let e1 = g.connect(b, c, Projection::Identity);
        (g.build().unwrap(), vec![e0, e1])
    }

    #[test]
    fn all_checkpointed_at_same_epoch() {
        let (topo, es) = pipeline3();
        let avail = vec![
            Available::chain(vec![epoch_ckpt(2, &[], &[es[0]], false)]),
            Available::chain(vec![epoch_ckpt(2, &[es[0]], &[es[1]], false)]),
            Available::chain(vec![epoch_ckpt(2, &[es[1]], &[], false)]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        for f in &plan.f {
            assert_eq!(*f, Frontier::upto_epoch(2));
        }
    }

    #[test]
    fn mismatched_checkpoints_pull_down() {
        // b only has epoch 1; a and c have epoch 2. a must come down to 1
        // (its discarded messages at epoch 2 would be lost to b); c must
        // come down to 1 (its delivered epoch-2 messages aren't fixed).
        let (topo, es) = pipeline3();
        let avail = vec![
            Available::chain(vec![
                epoch_ckpt(1, &[], &[es[0]], false),
                epoch_ckpt(2, &[], &[es[0]], false),
            ]),
            Available::chain(vec![epoch_ckpt(1, &[es[0]], &[es[1]], false)]),
            Available::chain(vec![
                epoch_ckpt(1, &[es[1]], &[], false),
                epoch_ckpt(2, &[es[1]], &[], false),
            ]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        assert_eq!(plan.f[0], Frontier::upto_epoch(1));
        assert_eq!(plan.f[1], Frontier::upto_epoch(1));
        assert_eq!(plan.f[2], Frontier::upto_epoch(1));
    }

    #[test]
    fn logging_firewall_decouples_upstream() {
        // b logs its outputs (RDD firewall): even though c failed (only ∅
        // available), a and b keep their latest checkpoints (Fig. 7b).
        let (topo, es) = pipeline3();
        let avail = vec![
            Available::chain(vec![epoch_ckpt(2, &[], &[es[0]], true)]),
            Available::chain(vec![epoch_ckpt(2, &[es[0]], &[es[1]], true)]),
            Available::chain(vec![]), // failed: only ∅
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        assert_eq!(plan.f[0], Frontier::upto_epoch(2));
        assert_eq!(plan.f[1], Frontier::upto_epoch(2));
        assert_eq!(plan.f[2], Frontier::Bottom);
    }

    #[test]
    fn discarding_upstream_is_dragged_down_by_failure() {
        // Nobody logs: c's failure drags b to ∅ (b's discarded messages
        // can't be resupplied), which drags a to ∅ in turn.
        let (topo, es) = pipeline3();
        let avail = vec![
            Available::chain(vec![epoch_ckpt(2, &[], &[es[0]], false)]),
            Available::chain(vec![epoch_ckpt(2, &[es[0]], &[es[1]], false)]),
            Available::chain(vec![]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        assert_eq!(plan.f[0], Frontier::Bottom);
        assert_eq!(plan.f[1], Frontier::Bottom);
        assert_eq!(plan.f[2], Frontier::Bottom);
    }

    #[test]
    fn any_frontier_stateless_follows_neighbours() {
        // a (chain at 1) → b (stateless Any) → c (chain at 3): b lands at
        // φ(f(a)) ∩ … = epoch 1; c pulled to 1 as well.
        let (topo, es) = pipeline3();
        let avail = vec![
            Available::chain(vec![epoch_ckpt(1, &[], &[es[0]], false)]),
            Available::any(false),
            Available::chain(vec![
                epoch_ckpt(1, &[es[1]], &[], false),
                epoch_ckpt(3, &[es[1]], &[], false),
            ]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        assert_eq!(plan.f[0], Frontier::upto_epoch(1));
        assert_eq!(plan.f[1], Frontier::upto_epoch(1));
        assert_eq!(plan.f[2], Frontier::upto_epoch(1));
    }

    #[test]
    fn incremental_growth_matches_batch() {
        let (topo, es) = pipeline3();
        let mut avail = vec![
            Available::chain(vec![epoch_ckpt(1, &[], &[es[0]], false)]),
            Available::chain(vec![epoch_ckpt(1, &[es[0]], &[es[1]], false)]),
            Available::chain(vec![epoch_ckpt(1, &[es[1]], &[], false)]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let mut plan = choose_frontiers(&input);
        assert_eq!(plan.f[1], Frontier::upto_epoch(1));
        // b persists a new checkpoint at epoch 3 — nothing should move
        // (a's and c's chains still cap at 1… b itself can move to 3? No:
        // b's m_bar(3) ⊆ φ(f(a)) = ↓1 fails).
        if let Available::Chain { chain, .. } = &mut avail[1] {
            chain.push(epoch_ckpt(3, &[es[0]], &[es[1]], false));
        }
        let input = RollbackInput { topo: &topo, avail: &avail };
        grow_frontiers(&input, &mut plan, ProcId(1));
        let batch = choose_frontiers(&input);
        assert_eq!(plan, batch);
        // Now a and c catch up to 3: everyone should reach 3.
        if let Available::Chain { chain, .. } = &mut avail[0] {
            chain.push(epoch_ckpt(3, &[], &[es[0]], false));
        }
        if let Available::Chain { chain, .. } = &mut avail[2] {
            chain.push(epoch_ckpt(3, &[es[1]], &[], false));
        }
        let input = RollbackInput { topo: &topo, avail: &avail };
        grow_frontiers(&input, &mut plan, ProcId(0));
        grow_frontiers(&input, &mut plan, ProcId(2));
        let batch = choose_frontiers(&input);
        assert_eq!(plan, batch);
        assert_eq!(plan.f[1], Frontier::upto_epoch(3));
    }

    /// The Fig. 5 notification-hazard graph: p → r, q → r, r → x, and a
    /// direct q → x edge is NOT present — the hazard flows through r.
    /// p and q got notifications at time 1; x received a notification at
    /// time 1 after r forwarded p's message. Without the f_n constraints
    /// f(q) = ∅ with f(x) ∋ 1 would be accepted; with them it is not.
    #[test]
    fn fig5_notification_hazard_blocked() {
        let mut g = GraphBuilder::new();
        let p = g.add_proc("p", TimeDomain::EPOCH);
        let q = g.add_proc("q", TimeDomain::EPOCH);
        let r = g.add_proc("r", TimeDomain::EPOCH);
        let x = g.add_proc("x", TimeDomain::EPOCH);
        let e1 = g.connect(p, r, Projection::Identity);
        let e2 = g.connect(q, r, Projection::Identity);
        let e3 = g.connect(r, x, Projection::Identity);
        let topo = g.build().unwrap();

        let f1 = Frontier::upto_epoch(1);
        // q failed: only ∅ available (it had processed the time-1
        // notification but never checkpointed).
        // p's checkpoint: processed notification at 1, sent a logged
        // message at 1 on e1.
        let p_ck = CkptMeta {
            f: f1.clone(),
            n_bar: f1.clone(),
            m_bar: BTreeMap::new(),
            d_bar: [(e1, Frontier::Bottom)].into_iter().collect(),
            phi: [(e1, f1.clone())].into_iter().collect(),
        };
        // r: received p's message at 1, sent nothing, logged nothing.
        let r_ck = CkptMeta {
            f: f1.clone(),
            n_bar: Frontier::Bottom,
            m_bar: [(e1, f1.clone()), (e2, Frontier::Bottom)].into_iter().collect(),
            d_bar: [(e3, Frontier::Bottom)].into_iter().collect(),
            phi: [(e3, f1.clone())].into_iter().collect(),
        };
        // x: processed a notification for time 1 (N̄ = ↓1).
        let x_ck = CkptMeta {
            f: f1.clone(),
            n_bar: f1.clone(),
            m_bar: [(e3, Frontier::Bottom)].into_iter().collect(),
            d_bar: BTreeMap::new(),
            phi: BTreeMap::new(),
        };
        let avail = vec![
            Available::chain(vec![p_ck]),
            Available::chain(vec![]), // q failed → ∅
            Available::chain(vec![r_ck]),
            Available::chain(vec![x_ck]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        // q is at ∅, so f_n(q) = ∅ ⇒ f_n(r) = ∅ ⇒ x's N̄ = ↓1 ⊄ f_n ⇒ x
        // must fall to ∅: the Fig. 5 inconsistency is excluded.
        assert_eq!(plan.f[1], Frontier::Bottom, "q at ∅");
        assert_eq!(plan.f[3], Frontier::Bottom, "x forced to ∅ by notification frontiers");
        // Without the notification constraint x would have (wrongly)
        // stayed at ↓1: demonstrate by checking constraints 2–3 alone
        // would accept f(x) = ↓1.
        let lax = RollbackPlan {
            f: vec![f1.clone(), Frontier::Bottom, f1.clone(), f1.clone()],
            f_n: vec![f1.clone(), Frontier::Bottom, f1.clone(), f1.clone()],
        };
        let err = verify_plan(&input, &lax).unwrap_err();
        assert!(err.contains("f_n"), "rejected specifically by the f_n constraints: {err}");
    }

    #[test]
    fn loop_rollback_uses_projections() {
        // Fig. 7(c)-style: p →Enter→ body(loop) →Exit→ y, with feedback.
        // body checkpointed (1,∞) (epoch 0..1 complete for all
        // iterations); y failed. p logs its sends into the loop.
        let mut g = GraphBuilder::new();
        let p = g.add_proc("p", TimeDomain::EPOCH);
        let body = g.add_proc("body", TimeDomain::Structured { depth: 1 });
        let y = g.add_proc("y", TimeDomain::EPOCH);
        let e_in = g.connect(p, body, Projection::LoopEnter);
        let e_fb = g.connect(body, body, Projection::LoopFeedback);
        let e_out = g.connect(body, y, Projection::LoopExit);
        let topo = g.build().unwrap();

        let f_p = Frontier::upto_epoch(1);
        let f_body = Frontier::down_close([crate::time::Time::structured(
            1,
            &[crate::time::CTR_INF],
        )]);
        let p_ck = CkptMeta {
            f: f_p.clone(),
            n_bar: f_p.clone(),
            m_bar: BTreeMap::new(),
            d_bar: [(e_in, Frontier::Bottom)].into_iter().collect(), // logs
            phi: [(e_in, Projection::LoopEnter.apply(&f_p).unwrap())].into_iter().collect(),
        };
        let body_ck = CkptMeta {
            f: f_body.clone(),
            n_bar: f_body.clone(),
            m_bar: [(e_in, f_body.clone()), (e_fb, f_body.clone())].into_iter().collect(),
            d_bar: [
                (e_fb, Projection::LoopFeedback.apply(&f_body).unwrap()),
                (e_out, Projection::LoopExit.apply(&f_body).unwrap()),
            ]
            .into_iter()
            .collect(),
            phi: [
                (e_fb, Projection::LoopFeedback.apply(&f_body).unwrap()),
                (e_out, Projection::LoopExit.apply(&f_body).unwrap()),
            ]
            .into_iter()
            .collect(),
        };
        let avail = vec![
            Available::chain(vec![p_ck]),
            Available::chain(vec![body_ck]),
            Available::chain(vec![]), // y failed
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        // body discarded messages to y at epochs ≤ 1 (LoopExit of its
        // frontier), and y is at ∅ ⇒ body must fall to ∅; p survives at
        // its checkpoint because it logs into the loop.
        assert_eq!(plan.f[2], Frontier::Bottom);
        assert_eq!(plan.f[1], Frontier::Bottom);
        assert_eq!(plan.f[0], f_p, "p's log firewalls it from the loop's rollback");
    }

    #[test]
    fn top_pseudo_checkpoint_for_non_failed() {
        // §4.4: non-failed processors get ⊤; with everyone logging, a
        // failed c leaves a and b untouched at ⊤.
        let (topo, es) = pipeline3();
        let top_a = CkptMeta {
            f: Frontier::Top,
            n_bar: Frontier::upto_epoch(5),
            m_bar: BTreeMap::new(),
            d_bar: [(es[0], Frontier::Bottom)].into_iter().collect(),
            phi: [(es[0], Frontier::Top)].into_iter().collect(),
        };
        let top_b = CkptMeta {
            f: Frontier::Top,
            n_bar: Frontier::upto_epoch(5),
            m_bar: [(es[0], Frontier::upto_epoch(5))].into_iter().collect(),
            d_bar: [(es[1], Frontier::Bottom)].into_iter().collect(),
            phi: [(es[1], Frontier::Top)].into_iter().collect(),
        };
        let avail = vec![
            Available::chain(vec![top_a]),
            Available::chain(vec![top_b]),
            Available::chain(vec![]),
        ];
        let input = RollbackInput { topo: &topo, avail: &avail };
        let plan = choose_frontiers(&input);
        assert!(verify_plan(&input, &plan).is_ok());
        assert!(plan.f[0].is_top());
        assert!(plan.f[1].is_top());
        assert!(plan.f[2].is_bottom());
    }
}
