//! Checkpoint metadata — the paper's Table 1.
//!
//! For each available frontier `f ∈ F*(p)` a processor must be able to
//! recover: its internal state `S(p,f)`, the processed-notification
//! frontier `N̄(p,f)`, per-in-edge processed-message frontiers `M̄(d,f)`,
//! per-out-edge projections `φ(e)(f)` and discarded-message frontiers
//! `D̄(e,f)`, and the logged messages `L(e,f)`. [`CkptMeta`] is the
//! rollback-algorithm-facing subset Ξ(p,f) (§4.2); [`StoredCheckpoint`]
//! adds the state payload and the pending-notification set the engine
//! needs to actually restore.

use crate::frontier::Frontier;
use crate::graph::EdgeId;
use crate::time::Time;
use crate::util::ser::{Decode, Encode, Reader, SerError, Writer};
use std::collections::BTreeMap;

/// Ξ(p,f): the metadata the consistent-frontier algorithm consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptMeta {
    /// The frontier `f` this checkpoint restores to.
    pub f: Frontier,
    /// N̄(p,f): smallest frontier containing the notifications processed
    /// in `H(p)@f`.
    pub n_bar: Frontier,
    /// M̄(d,f) per input edge `d`: smallest frontier containing the
    /// messages delivered in `H(p)@f`.
    pub m_bar: BTreeMap<EdgeId, Frontier>,
    /// D̄(e,f) per output edge `e`: smallest frontier containing the
    /// messages sent-and-discarded in `H(p)@f` (times in the
    /// *destination's* domain).
    pub d_bar: BTreeMap<EdgeId, Frontier>,
    /// φ(e)(f) per output edge `e`, materialized at checkpoint time (for
    /// static projections this equals `projection.apply(f)`; for
    /// history-dependent ones it is captured from the live counts).
    pub phi: BTreeMap<EdgeId, Frontier>,
}

impl CkptMeta {
    /// The Ξ for the empty frontier ∅ — always available, always
    /// consistent (every processor can roll back to its initial state).
    pub fn empty(in_edges: &[EdgeId], out_edges: &[EdgeId]) -> CkptMeta {
        CkptMeta {
            f: Frontier::Bottom,
            n_bar: Frontier::Bottom,
            m_bar: in_edges.iter().map(|e| (*e, Frontier::Bottom)).collect(),
            d_bar: out_edges.iter().map(|e| (*e, Frontier::Bottom)).collect(),
            phi: out_edges.iter().map(|e| (*e, Frontier::Bottom)).collect(),
        }
    }

    pub fn m_bar_of(&self, d: EdgeId) -> &Frontier {
        self.m_bar.get(&d).unwrap_or(&Frontier::Bottom)
    }

    pub fn d_bar_of(&self, e: EdgeId) -> &Frontier {
        self.d_bar.get(&e).unwrap_or(&Frontier::Bottom)
    }

    pub fn phi_of(&self, e: EdgeId) -> &Frontier {
        self.phi.get(&e).unwrap_or(&Frontier::Bottom)
    }
}

fn encode_edge_map(m: &BTreeMap<EdgeId, Frontier>, w: &mut Writer) {
    w.varint(m.len() as u64);
    for (e, f) in m {
        w.varint(e.0 as u64);
        f.encode(w);
    }
}

fn decode_edge_map(r: &mut Reader) -> Result<BTreeMap<EdgeId, Frontier>, SerError> {
    let n = r.varint()? as usize;
    let mut m = BTreeMap::new();
    for _ in 0..n {
        let e = EdgeId(r.varint()? as u32);
        m.insert(e, Frontier::decode(r)?);
    }
    Ok(m)
}

impl Encode for CkptMeta {
    fn encode(&self, w: &mut Writer) {
        self.f.encode(w);
        self.n_bar.encode(w);
        encode_edge_map(&self.m_bar, w);
        encode_edge_map(&self.d_bar, w);
        encode_edge_map(&self.phi, w);
    }
}

impl Decode for CkptMeta {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        Ok(CkptMeta {
            f: Frontier::decode(r)?,
            n_bar: Frontier::decode(r)?,
            m_bar: decode_edge_map(r)?,
            d_bar: decode_edge_map(r)?,
            phi: decode_edge_map(r)?,
        })
    }
}

/// A persisted checkpoint: Ξ plus what restoration needs.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredCheckpoint {
    pub meta: CkptMeta,
    /// S(p,f): the operator state blob (empty for stateless processors).
    pub state: Vec<u8>,
    /// Notification requests outstanding at the checkpoint whose times
    /// lie in `f` (they must be re-armed on restore, since the requesting
    /// messages will not be re-delivered).
    pub pending_notify: Vec<Time>,
}

impl Encode for StoredCheckpoint {
    fn encode(&self, w: &mut Writer) {
        self.meta.encode(w);
        w.bytes(&self.state);
        w.varint(self.pending_notify.len() as u64);
        for t in &self.pending_notify {
            t.encode(w);
        }
    }
}

impl Decode for StoredCheckpoint {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let meta = CkptMeta::decode(r)?;
        let state = r.bytes()?.to_vec();
        let n = r.varint()? as usize;
        let mut pending_notify = Vec::with_capacity(n);
        for _ in 0..n {
            pending_notify.push(Time::decode(r)?);
        }
        Ok(StoredCheckpoint { meta, state, pending_notify })
    }
}

/// The durable form of Ξ(p,f) — what a `Kind::Meta` blob holds: the
/// solver-facing [`CkptMeta`] plus the pending-notification set a cold
/// reopen needs to re-arm (the state payload S(p,f) lives in a
/// [`Snapshot`] record under the same tag plus its content-addressed
/// chunks, all written *before* the Ξ so a torn WAL tail can lose the
/// Ξ but never leave one without its state — and a reopen that does
/// find an incomplete snapshot drops that chain suffix conservatively).
#[derive(Clone, Debug, PartialEq)]
pub struct MetaRecord {
    pub meta: CkptMeta,
    pub pending_notify: Vec<Time>,
}

impl Encode for MetaRecord {
    fn encode(&self, w: &mut Writer) {
        self.meta.encode(w);
        w.varint(self.pending_notify.len() as u64);
        for t in &self.pending_notify {
            t.encode(w);
        }
    }
}

impl Decode for MetaRecord {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let meta = CkptMeta::decode(r)?;
        let n = r.varint()? as usize;
        let mut pending_notify = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            pending_notify.push(Time::decode(r)?);
        }
        Ok(MetaRecord { meta, pending_notify })
    }
}

/// The durable form of a checkpoint's state payload under the
/// content-addressed representation (see `ft/README.md`, "Incremental
/// checkpoints and compaction"): the state S(p,f) is split into
/// fixed-size chunks ([`crate::ft::storage::SNAPSHOT_CHUNK_BYTES`]),
/// each stored once under its fnv1a hash as a `Kind::Chunk` blob, and
/// the snapshot lists `(position, hash)` pairs naming the chunk
/// occupying each position. A **full** snapshot lists every position
/// and has `prior_snapshot = None`; a **delta** lists only the
/// positions that changed since the base snapshot named by
/// `prior_snapshot` (a `Kind::Snapshot` tag of the same processor) —
/// materialization walks the prior chain newest→oldest, taking the
/// first hash seen for each position.
///
/// Chunk identity is the 64-bit fnv1a of the chunk bytes. fnv1a is not
/// collision-resistant; a colliding pair of distinct chunks within one
/// processor's live state would alias silently. At 64 bits the
/// birthday bound makes this negligible for the state sizes this crate
/// targets, and the hash stays consistent with the WAL's record
/// checksums — swap in a wider hash here if that ever changes.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Total state length in bytes (chunk sizes are implied: every
    /// position is a full chunk except the last).
    pub state_len: u64,
    /// `(position, fnv1a hash)` pairs, ascending by position.
    pub chunks: Vec<(u64, u64)>,
    /// Tag of the base snapshot this delta is against (`None` = full).
    pub prior_snapshot: Option<u64>,
}

impl Snapshot {
    /// Positions this snapshot itself lists (not the materialized
    /// total — a delta lists only changed positions).
    pub fn listed_chunks(&self) -> usize {
        self.chunks.len()
    }
}

impl Encode for Snapshot {
    fn encode(&self, w: &mut Writer) {
        w.varint(self.state_len);
        w.varint(self.chunks.len() as u64);
        for &(pos, hash) in &self.chunks {
            w.varint(pos);
            // Hashes are uniformly distributed — fixed 8-byte LE beats
            // a varint (which would average >9 bytes) and keeps the
            // record size exactly predictable.
            for shift in (0..64).step_by(8) {
                w.u8(((hash >> shift) & 0xff) as u8);
            }
        }
        match self.prior_snapshot {
            None => w.u8(0),
            Some(t) => {
                w.u8(1);
                w.varint(t);
            }
        }
    }
}

impl Decode for Snapshot {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let state_len = r.varint()?;
        let n = r.varint()? as usize;
        let mut chunks = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let pos = r.varint()?;
            let mut hash = 0u64;
            for shift in (0..64).step_by(8) {
                hash |= (r.u8()? as u64) << shift;
            }
            chunks.push((pos, hash));
        }
        let prior_snapshot = match r.u8()? {
            0 => None,
            _ => Some(r.varint()?),
        };
        Ok(Snapshot { state_len, chunks, prior_snapshot })
    }
}

/// One logged sent batch (an element of L(e,·)): the destination-domain
/// batch plus the time of the event at `p` that produced it, which is
/// what lets L(e,f) = entries with `event_time ∈ f` be computed exactly
/// even under selective rollback. One log write covers the whole batch —
/// the batching win on the durable path — and recovery replays the batch
/// byte-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct LogEntry {
    pub edge: EdgeId,
    /// Time (at the sender) of the event that caused this send.
    pub event_time: Time,
    /// The batch (time in the destination's domain; all records share it).
    pub batch: crate::engine::Batch,
}

impl LogEntry {
    /// Records carried by this entry.
    pub fn records(&self) -> usize {
        self.batch.len()
    }
}

impl Encode for LogEntry {
    fn encode(&self, w: &mut Writer) {
        w.varint(self.edge.0 as u64);
        self.event_time.encode(w);
        self.batch.encode(w);
    }
}

impl Decode for LogEntry {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        Ok(LogEntry {
            edge: EdgeId(r.varint()? as u32),
            event_time: Time::decode(r)?,
            batch: crate::engine::Batch::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Batch, Record};

    #[test]
    fn meta_roundtrip() {
        let mut m_bar = BTreeMap::new();
        m_bar.insert(EdgeId(0), Frontier::upto_epoch(3));
        let meta = CkptMeta {
            f: Frontier::upto_epoch(3),
            n_bar: Frontier::upto_epoch(2),
            m_bar,
            d_bar: BTreeMap::new(),
            phi: [(EdgeId(1), Frontier::upto_epoch(3))].into_iter().collect(),
        };
        let bytes = meta.to_bytes();
        assert_eq!(CkptMeta::from_bytes(&bytes).unwrap(), meta);
    }

    #[test]
    fn stored_checkpoint_roundtrip() {
        let sc = StoredCheckpoint {
            meta: CkptMeta::empty(&[EdgeId(0)], &[EdgeId(1)]),
            state: vec![9, 9, 9],
            pending_notify: vec![Time::epoch(4)],
        };
        let bytes = sc.to_bytes();
        assert_eq!(StoredCheckpoint::from_bytes(&bytes).unwrap(), sc);
    }

    #[test]
    fn log_entry_roundtrip() {
        let le = LogEntry {
            edge: EdgeId(2),
            event_time: Time::epoch(1),
            batch: Batch::new(
                Time::epoch(1),
                vec![Record::kv(3, 0.5), Record::kv(4, 1.5)],
            ),
        };
        assert_eq!(le.records(), 2);
        let bytes = le.to_bytes();
        assert_eq!(LogEntry::from_bytes(&bytes).unwrap(), le);
    }

    #[test]
    fn snapshot_roundtrip() {
        // Full snapshot: every position listed, no prior.
        let full = Snapshot {
            state_len: 2500,
            chunks: vec![(0, 0xdeadbeefdeadbeef), (1, 7), (2, u64::MAX)],
            prior_snapshot: None,
        };
        assert_eq!(full.listed_chunks(), 3);
        assert_eq!(Snapshot::from_bytes(&full.to_bytes()).unwrap(), full);
        // Delta: sparse positions against a prior tag.
        let delta = Snapshot {
            state_len: 2500,
            chunks: vec![(2, 0x0123456789abcdef)],
            prior_snapshot: Some(41),
        };
        assert_eq!(Snapshot::from_bytes(&delta.to_bytes()).unwrap(), delta);
        // Empty state is a valid (empty) snapshot.
        let empty = Snapshot { state_len: 0, chunks: vec![], prior_snapshot: None };
        assert_eq!(Snapshot::from_bytes(&empty.to_bytes()).unwrap(), empty);
    }

    #[test]
    fn meta_record_roundtrip() {
        let rec = MetaRecord {
            meta: CkptMeta::empty(&[EdgeId(0)], &[EdgeId(1)]),
            pending_notify: vec![Time::epoch(2), Time::epoch(5)],
        };
        let bytes = rec.to_bytes();
        assert_eq!(MetaRecord::from_bytes(&bytes).unwrap(), rec);
    }

    #[test]
    fn empty_meta_defaults() {
        let m = CkptMeta::empty(&[EdgeId(0)], &[EdgeId(1)]);
        assert!(m.f.is_bottom());
        assert!(m.m_bar_of(EdgeId(0)).is_bottom());
        assert!(m.phi_of(EdgeId(9)).is_bottom(), "unknown edges default to ∅");
    }
}
