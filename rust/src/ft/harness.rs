//! The fault-tolerance harness: wraps the engine, observes every event
//! report, and maintains the paper's Table-1 metadata per processor under
//! its chosen [`Policy`].
//!
//! The harness is the "system layer" of §4.1: it tracks N̄, M̄ and D̄
//! automatically, logs sent messages for processors that elected logging,
//! records full histories for [`Policy::FullHistory`] processors, and
//! takes **selective checkpoints** at completed times for
//! [`Policy::Lazy`] / per-event checkpoints for [`Policy::Eager`].
//! Recovery (§4.4) is implemented in [`crate::ft::recovery`] as further
//! methods on [`FtSystem`].
//!
//! All metadata is maintained at **batch granularity**: a batch of
//! records at one logical time is a single event, so one delivery updates
//! M̄ once, one send produces one [`LogEntry`] (one acknowledged storage
//! write, however many records it carries), and one history entry covers
//! the whole delivered batch. This is sound because every Table-1
//! structure is a *frontier of times* or a per-time count — none of them
//! distinguishes records within a time — and it is where batching pays on
//! the durable path.
//!
//! The observation path is written against the (crate-private) `FtView`
//! trait rather than the engine directly, because it runs in two
//! regimes: the sequential [`FtSystem::step`] loop, and — under
//! [`FtSystem::run_to_quiescence_parallel`] — **per worker thread**, with
//! each worker owning the `ProcFt` entries of its shard group and
//! sharing only the thread-safe [`Store`] handle. Per-shard metadata is
//! therefore maintained with no locking at all: every Table-1 structure
//! belongs to exactly one processor, every processor to exactly one
//! worker, and the store serializes its own writes.
//!
//! # Staged vs. acknowledged persistence
//!
//! Every durable mutation goes through the store's **staging** API
//! ([`Store::stage_put`]): under [`crate::ft::storage::PersistMode::Sync`]
//! it applies before returning (today's behavior), while under
//! `PersistMode::Async` it lands in a queue drained by a background
//! writer thread with group commit — taking the write entirely off the
//! compute hot path. Each mirror entry (checkpoint, log entry, history
//! event, input marker) remembers the per-processor **sequence number**
//! of its staged write; the store publishes a per-processor **ack
//! watermark** once writes are applied. The split matters in exactly
//! three places: a mirror entry is *offerable* to the Fig. 6 solver only
//! when its sequence is at or below the watermark
//! ([`FtSystem::availability`]), failure injection discards a crashed
//! processor's staged-but-unacknowledged tail
//! ([`FtSystem::inject_failures`]), and the §4.2 GC monitor learns of a
//! checkpoint only after its ack ([`FtSystem::pump_monitor`]) so the
//! low-watermark never references volatile state. The paper's model
//! makes the decoupling free: an unacknowledged suffix is exactly a
//! slightly older crash — recovery rolls back a little further and the
//! suffix is re-executed.

use crate::engine::scheduler::WorkerState;
use crate::engine::{Batch, Delivery, Engine, EventKind, EventReport, Processor, Record};
use crate::frontier::Frontier;
use crate::ft::meta::{CkptMeta, LogEntry, MetaRecord, Snapshot, StoredCheckpoint};
use crate::ft::policy::{Policy, SnapshotPolicy};
use crate::ft::storage::{chunk_hashes, plan_snapshot, Key, Kind, SnapshotBase, Store};
use crate::graph::{EdgeId, ProcId, Topology};
use crate::time::{LexTime, Time};
use crate::util::ser::{Decode, Encode, Reader, SerError};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// What happened in one event of a recorded history H(p) (for
/// [`Policy::FullHistory`]). A delivered batch is one history event —
/// replay re-delivers it whole; `data` *aliases* the delivered payload
/// (an `Arc` bump at capture time, not a deep copy).
#[derive(Clone, Debug, PartialEq)]
pub enum HistoryKind {
    Message { edge: EdgeId, time: Time, data: Batch },
    Notification { time: Time },
    Input { time: Time, data: Record },
}

/// One event of a recorded history H(p), with the durable bookkeeping
/// replay needs beyond the event itself: `sent_seq` counts the records
/// this event sent on each per-checkpoint-projection out-edge (sends
/// into sequence-number domains). The counts make `history_meta` exact —
/// a full-history processor's φ on such an edge is the sum of counts
/// over replayed events, which survives crashes where the volatile
/// `sent_events` delta does not.
#[derive(Clone, Debug, PartialEq)]
pub struct HistoryEvent {
    pub kind: HistoryKind,
    /// (out-edge, records sent on it) while handling this event —
    /// per-checkpoint-projection edges only; empty for most events.
    pub sent_seq: Vec<(EdgeId, u64)>,
}

impl HistoryEvent {
    /// The logical time of the event.
    pub fn time(&self) -> Time {
        match &self.kind {
            HistoryKind::Message { time, .. }
            | HistoryKind::Notification { time }
            | HistoryKind::Input { time, .. } => *time,
        }
    }
}

impl Encode for HistoryEvent {
    fn encode(&self, w: &mut crate::util::ser::Writer) {
        match &self.kind {
            HistoryKind::Message { edge, time, data } => {
                w.u8(0);
                w.varint(edge.0 as u64);
                time.encode(w);
                w.varint(data.len() as u64);
                for r in data.records() {
                    r.encode(w);
                }
            }
            HistoryKind::Notification { time } => {
                w.u8(1);
                time.encode(w);
            }
            HistoryKind::Input { time, data } => {
                w.u8(2);
                time.encode(w);
                data.encode(w);
            }
        }
        w.varint(self.sent_seq.len() as u64);
        for (e, n) in &self.sent_seq {
            w.varint(e.0 as u64);
            w.varint(*n);
        }
    }
}

impl Decode for HistoryEvent {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let kind = match r.u8()? {
            0 => {
                let edge = EdgeId(r.varint()? as u32);
                let time = Time::decode(r)?;
                let n = r.varint()? as usize;
                let mut data = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    data.push(Record::decode(r)?);
                }
                HistoryKind::Message { edge, time, data: Batch::new(time, data) }
            }
            1 => HistoryKind::Notification { time: Time::decode(r)? },
            2 => HistoryKind::Input { time: Time::decode(r)?, data: Record::decode(r)? },
            found => return Err(SerError::BadTag { expected: 0, found, at: 0 }),
        };
        let ns = r.varint()? as usize;
        let mut sent_seq = Vec::with_capacity(ns.min(1 << 12));
        for _ in 0..ns {
            sent_seq.push((EdgeId(r.varint()? as u32), r.varint()?));
        }
        Ok(HistoryEvent { kind, sent_seq })
    }
}

/// Storage tag + staging sequence number of one mirror entry's durable
/// blob. Tags key the blob in the store (so truncation/GC delete exactly
/// the right records); sequences gate *offerability* on the store's ack
/// watermark. Sequences ascend along each mirror vector (per-proc FIFO
/// staging), so the acknowledged subset is always a prefix.
#[derive(Copy, Clone, Debug)]
pub(crate) struct TagSeq {
    pub tag: u64,
    pub seq: u64,
}

/// Length of the acknowledged prefix of a mirror's tag vector under ack
/// watermark `w`. A sequence of [`UNACKABLE`] (a refused write) blocks
/// the prefix permanently, capping what recovery may rely on at the gap.
///
/// Deliberately a linear scan, not `partition_point`: an `UNACKABLE`
/// sentinel in the middle (followed by later real sequences) and
/// sync-mode zero sequences appended after async real ones both make
/// the vector non-monotone in `seq`, and a binary search over a
/// non-monotone predicate could count never-persisted entries as acked.
/// Prefix semantics are exactly `take_while` — the first unacked entry
/// caps everything after it, which is the crash model we want.
pub(crate) fn acked_prefix(tags: &[TagSeq], w: u64) -> usize {
    tags.iter().take_while(|ts| ts.seq <= w).count()
}

/// Sentinel sequence for a mirror entry whose durable write was refused
/// (oversized payload): never at or below any watermark, so the entry —
/// and, by prefix semantics, everything after it — is never offered from
/// durable state.
pub(crate) const UNACKABLE: u64 = u64::MAX;

/// Per-processor fault-tolerance state (volatile deltas + durable
/// mirrors).
pub(crate) struct ProcFt {
    pub policy: Policy,
    /// Delivered-message times per in-edge since the last checkpoint.
    pub delivered_new: BTreeMap<EdgeId, BTreeSet<LexTime>>,
    /// External-input times since the last checkpoint (inputs are
    /// messages on a virtual external edge — the paper's footnote 1;
    /// they widen eager checkpoint frontiers and are resupplied by the
    /// §4.3 external services rather than by M̄ constraints).
    pub input_new: BTreeSet<LexTime>,
    /// Notification times processed since the last checkpoint.
    pub notified_new: BTreeSet<LexTime>,
    /// (event time, message time) of *unlogged* sends per out-edge since
    /// the last checkpoint (D̄ deltas; message time is in the destination
    /// domain).
    pub discarded_new: BTreeMap<EdgeId, Vec<(Time, Time)>>,
    /// Event times of sends on per-checkpoint-projection out-edges since
    /// the last checkpoint (to materialize φ counts).
    pub sent_events: BTreeMap<EdgeId, Vec<Time>>,
    /// Total messages ever sent per out-edge (live φ for seq edges).
    pub sent_total: BTreeMap<EdgeId, u64>,
    /// Durable log of sent messages (mirror of what's in the store).
    pub log: Vec<LogEntry>,
    /// Storage tags + staging sequences of `log` entries (parallel
    /// vector), so truncation and GC can delete exactly the dropped
    /// blobs and availability can gate on the ack watermark.
    pub log_tags: Vec<TagSeq>,
    /// Durable full history (mirror), for [`Policy::FullHistory`].
    pub history: Vec<HistoryEvent>,
    /// Storage tags + sequences of `history` entries (parallel vector).
    pub history_tags: Vec<TagSeq>,
    /// F*(p): ascending chain of durable checkpoints (mirror).
    pub chain: Vec<StoredCheckpoint>,
    /// Storage tags + sequences of `chain` entries (parallel vector; one
    /// tag keys both the `Snapshot` record and the `Meta` blob of a
    /// checkpoint; the sequence is the Ξ write's — the chunks and the
    /// snapshot record land strictly earlier in FIFO order, so an acked
    /// Ξ implies an acked, materializable state).
    pub chain_tags: Vec<TagSeq>,
    /// Durable [`Snapshot`] records this processor still references,
    /// keyed by tag — the in-memory face of the content-addressed
    /// checkpoint representation. Holds every record reachable from a
    /// live chain entry via `prior_snapshot` (a delta's base record
    /// outlives its own chain entry for as long as anything chains to
    /// it), which is exactly the GC retention rule
    /// ([`sweep_unreachable_snapshots`]).
    pub snapshots: BTreeMap<u64, Snapshot>,
    /// How checkpoint states are represented durably (full snapshots vs
    /// bounded delta chains); see [`SnapshotPolicy`].
    pub snapshot_policy: SnapshotPolicy,
    /// Input-frontier marker intent (sources only): input times the
    /// processor has completely consumed with their resulting sends
    /// staged in the log — the §4.2 Ξ of a stateless logging source.
    /// Mirrors the newest *staged* `Kind::InputFrontier` blob at tag 0;
    /// [`ProcFt::input_mark_acked`] tracks the newest *acknowledged*
    /// version.
    pub input_mark: Frontier,
    /// Newest marker version whose write the store acknowledged.
    pub input_mark_acked: Frontier,
    /// Staged-but-not-yet-settled marker versions, oldest first: the
    /// marker blob is overwritten in place, so versions replace rather
    /// than accumulate; drained against the ack watermark by
    /// [`ProcFt::drain_acked_marks`] / collapsed by
    /// [`ProcFt::settle_marks_for_crash`].
    pub mark_pending: Vec<(u64, Frontier)>,
    /// Completed-time counter (drives [`Policy::Lazy`]).
    pub completions: u64,
    /// Marked by failure injection; cleared by recovery.
    pub failed: bool,
    /// Durable writes this processor had refused (oversized payloads) —
    /// the per-processor face of [`FtStats::storage_errors`].
    pub storage_errors: u64,
    /// A log or history write was refused: the input-frontier marker is
    /// frozen (it must never certify an event whose send is missing from
    /// the durable log), and the refused entry's [`UNACKABLE`] sequence
    /// caps what durable recovery may offer at the gap.
    pub persist_gap: bool,
    /// Chain entries already reported to the §4.2 monitor
    /// ([`FtSystem::pump_monitor`]'s cursor).
    pub chain_reported: usize,
    /// Monotone sequence for storage keys.
    next_key: u64,
}

impl ProcFt {
    pub(crate) fn new(policy: Policy) -> ProcFt {
        ProcFt {
            policy,
            delivered_new: BTreeMap::new(),
            input_new: BTreeSet::new(),
            notified_new: BTreeSet::new(),
            discarded_new: BTreeMap::new(),
            sent_events: BTreeMap::new(),
            sent_total: BTreeMap::new(),
            log: Vec::new(),
            log_tags: Vec::new(),
            history: Vec::new(),
            history_tags: Vec::new(),
            chain: Vec::new(),
            chain_tags: Vec::new(),
            snapshots: BTreeMap::new(),
            snapshot_policy: SnapshotPolicy::default(),
            input_mark: Frontier::Bottom,
            input_mark_acked: Frontier::Bottom,
            mark_pending: Vec::new(),
            completions: 0,
            failed: false,
            storage_errors: 0,
            persist_gap: false,
            chain_reported: 0,
            next_key: 0,
        }
    }

    /// Fold marker versions the store has acknowledged (sequence ≤ `w`)
    /// into [`ProcFt::input_mark_acked`], keeping the unacked suffix
    /// pending. Cheap bookkeeping run opportunistically on marker writes.
    pub(crate) fn drain_acked_marks(&mut self, w: u64) {
        // Prefix scan, not a binary search: sync-mode writes carry
        // sequence 0, so a mode switch can make the queue non-monotone —
        // see `acked_prefix`. Under-draining is merely conservative.
        let n = self.mark_pending.iter().take_while(|(s, _)| *s <= w).count();
        if n > 0 {
            self.input_mark_acked = self.mark_pending[n - 1].1.clone();
            self.mark_pending.drain(..n);
        }
    }

    /// Crash-settle the marker after the store discarded this
    /// processor's staged-but-unacked tail (watermark `w`). The value the
    /// surviving mirrors can actually certify is the *minimum* the marker
    /// ever held since the last acknowledged version: an unacked
    /// *advance* never entered the durable log it certifies, and an
    /// unacked *shrink* (a rollback) already truncated the in-memory
    /// mirrors — either way the entries beyond the minimum are gone from
    /// the mirror, so intersecting every pending version (after draining
    /// the acked prefix) is exactly right.
    pub(crate) fn settle_marks_for_crash(&mut self, w: u64) {
        self.drain_acked_marks(w);
        let mut settled = self.input_mark_acked.clone();
        for (_, f) in &self.mark_pending {
            settled = settled.intersect(f);
        }
        self.mark_pending.clear();
        self.input_mark = settled.clone();
        self.input_mark_acked = settled;
    }

    /// The metadata of the newest checkpoint (or the implicit ∅ one).
    pub fn base_meta(&self, in_edges: &[EdgeId], out_edges: &[EdgeId]) -> CkptMeta {
        self.chain
            .last()
            .map(|c| c.meta.clone())
            .unwrap_or_else(|| CkptMeta::empty(in_edges, out_edges))
    }

    /// Snapshot records a materialization of snapshot `tag` walks (1 for
    /// a full snapshot) — the quantity [`SnapshotPolicy::Delta`] bounds
    /// with its forced-full rule. Prior tags strictly decrease along a
    /// well-formed chain, so the walk terminates.
    pub(crate) fn snapshot_walk_len(&self, tag: u64) -> u64 {
        let mut len = 0u64;
        let mut cur = Some(tag);
        while let Some(t) = cur {
            len += 1;
            cur = self.snapshots.get(&t).and_then(|s| s.prior_snapshot);
        }
        len
    }

    /// The base a new delta checkpoint may diff against: the newest chain
    /// entry whose Ξ write the store acknowledged under watermark `w`. An
    /// *unacked* base would be unsound — a crash could discard it,
    /// stranding every delta chained on it — so an all-unacked chain
    /// yields `None` and the planner writes a full snapshot.
    fn acked_snapshot_base(&self, w: u64) -> Option<SnapshotBase> {
        let idx = acked_prefix(&self.chain_tags, w).checked_sub(1)?;
        let tag = self.chain_tags[idx].tag;
        Some(SnapshotBase {
            tag,
            hashes: chunk_hashes(&self.chain[idx].state),
            walk_len: self.snapshot_walk_len(tag),
        })
    }

    fn fresh_key(&mut self) -> u64 {
        self.next_key += 1;
        self.next_key
    }
}

/// The engine state a metadata observation needs to read about the
/// event's processor — plus the *restore hooks* the §4.4 rollback uses
/// to put that state back. Implemented by the sequential [`Engine`] and
/// by the parallel [`WorkerState`] (which owns the processor outright
/// during a drain — and, since recovery itself runs decomposed, during
/// a rollback too). The hooks mirror the engine's recovery primitives
/// exactly; the worker impl batches tracker effects into its deltas,
/// which `Engine::recompose` merges and applies.
pub(crate) trait FtView {
    /// Selective checkpoint state S(p, f).
    fn proc_state(&self, p: ProcId, f: &Frontier) -> Vec<u8>;
    /// Pending notification requests at `p`.
    fn proc_pending(&self, p: ProcId) -> Vec<Time>;
    /// Mutable operator access (checkpoint restore / §3.6 reset).
    fn proc_restore(&mut self, p: ProcId) -> &mut dyn Processor;
    /// Drop every pending notification request at `p`, releasing the
    /// capabilities.
    fn cancel_all_pending(&mut self, p: ProcId);
    /// Re-arm pending requests restored from checkpoint metadata.
    fn restore_pending(&mut self, p: ProcId, times: Vec<Time>);
    /// The completed-time frontier at `p`.
    fn completed(&self, p: ProcId) -> Frontier;
    /// Reset the completed-time frontier (from the checkpoint's N̄).
    fn set_completed(&mut self, p: ProcId, f: Frontier);
    /// Reset the sequence counter of one of `p`'s out-edges.
    fn set_seq_counter(&mut self, e: EdgeId, v: u64);
}

impl FtView for Engine {
    fn proc_state(&self, p: ProcId, f: &Frontier) -> Vec<u8> {
        self.proc(p).checkpoint_upto(f)
    }

    fn proc_pending(&self, p: ProcId) -> Vec<Time> {
        self.pending_notifications(p)
    }

    fn proc_restore(&mut self, p: ProcId) -> &mut dyn Processor {
        self.proc_mut(p)
    }

    fn cancel_all_pending(&mut self, p: ProcId) {
        self.cancel_pending(p, |_| true);
    }

    fn restore_pending(&mut self, p: ProcId, times: Vec<Time>) {
        Engine::restore_pending(self, p, times);
    }

    fn completed(&self, p: ProcId) -> Frontier {
        Engine::completed(self, p).clone()
    }

    fn set_completed(&mut self, p: ProcId, f: Frontier) {
        Engine::set_completed(self, p, f);
    }

    fn set_seq_counter(&mut self, e: EdgeId, v: u64) {
        Engine::set_seq_counter(self, e, v);
    }
}

impl FtView for WorkerState {
    fn proc_state(&self, p: ProcId, f: &Frontier) -> Vec<u8> {
        self.proc_ref(p).checkpoint_upto(f)
    }

    fn proc_pending(&self, p: ProcId) -> Vec<Time> {
        self.pending_of(p)
    }

    fn proc_restore(&mut self, p: ProcId) -> &mut dyn Processor {
        self.proc_dyn(p)
    }

    fn cancel_all_pending(&mut self, p: ProcId) {
        self.cancel_pending_all(p);
    }

    fn restore_pending(&mut self, p: ProcId, times: Vec<Time>) {
        self.restore_pending_times(p, times);
    }

    fn completed(&self, p: ProcId) -> Frontier {
        self.completed_of(p).clone()
    }

    fn set_completed(&mut self, p: ProcId, f: Frontier) {
        self.set_completed_of(p, f);
    }

    fn set_seq_counter(&mut self, e: EdgeId, v: u64) {
        WorkerState::set_seq_counter(self, e, v);
    }
}

/// Counters the policy benches report.
#[derive(Clone, Debug, Default)]
pub struct FtStats {
    pub checkpoints_taken: u64,
    /// Log entries written (one per sent batch).
    pub log_entries: u64,
    /// Records covered by those log entries.
    pub log_records: u64,
    pub history_events: u64,
    /// Events observed (one per delivered batch / notification / input).
    pub events_observed: u64,
    /// Records delivered inside observed message events.
    pub records_observed: u64,
    /// Recovery passes performed.
    pub recoveries: u64,
    /// Messages replayed from logs/history across all recoveries — the
    /// replay-cost counter the sharded tests assert on (a single-shard
    /// failure must replay only that shard's key range).
    pub messages_replayed: u64,
    /// Processors restored from a checkpoint or reset to ∅ across all
    /// recoveries (i.e. actually rolled back).
    pub procs_rolled_back: u64,
    /// Processors left untouched at ⊤ across all recoveries.
    pub procs_untouched: u64,
    /// Durable writes the store refused (oversized payloads), surfaced as
    /// recoverable per-processor degradation instead of a panic.
    pub storage_errors: u64,
    /// Peak staged-minus-acknowledged operations observed at drain /
    /// recovery boundaries — the async pipeline's lag gauge (0 under
    /// [`crate::ft::storage::PersistMode::Sync`]). A snapshot maximum,
    /// not an additive counter.
    pub ack_lag: u64,
    /// Peak number of worker groups that restored ≥1 rolled-back
    /// processor concurrently in a single recovery (1 for the sequential
    /// path). A snapshot maximum — the structural assertion that
    /// recovery actually ran in parallel where wall-clock can't be
    /// measured.
    pub recovery_parallelism: u64,
    /// Peak number of worker groups that replayed ≥1 logged/history
    /// record concurrently in a single recovery (1 for the sequential
    /// path when anything replayed). A snapshot maximum.
    pub replay_workers: u64,
}

impl FtStats {
    /// Fold another counter set in (counters are additive, the lag gauge
    /// folds by max — used to merge per-worker stats after a parallel
    /// drain).
    pub fn merge(&mut self, o: &FtStats) {
        self.checkpoints_taken += o.checkpoints_taken;
        self.log_entries += o.log_entries;
        self.log_records += o.log_records;
        self.history_events += o.history_events;
        self.events_observed += o.events_observed;
        self.records_observed += o.records_observed;
        self.recoveries += o.recoveries;
        self.messages_replayed += o.messages_replayed;
        self.procs_rolled_back += o.procs_rolled_back;
        self.procs_untouched += o.procs_untouched;
        self.storage_errors += o.storage_errors;
        self.ack_lag = self.ack_lag.max(o.ack_lag);
        self.recovery_parallelism = self.recovery_parallelism.max(o.recovery_parallelism);
        self.replay_workers = self.replay_workers.max(o.replay_workers);
    }
}

/// Frontier covering everything delivered so far at an eager (seq
/// domain) processor: the last checkpoint's frontier widened by every
/// delivered / notified / input time since.
fn eager_frontier_of(ft: &ProcFt) -> Frontier {
    let mut f = ft.chain.last().map(|c| c.meta.f.clone()).unwrap_or(Frontier::Bottom);
    for times in ft.delivered_new.values() {
        for lt in times {
            f.insert(lt.0);
        }
    }
    for lt in &ft.notified_new {
        f.insert(lt.0);
    }
    for lt in &ft.input_new {
        f.insert(lt.0);
    }
    f
}

/// Retain the entries of a mirror vector (and its parallel tag vector)
/// matching `keep`, invoking `on_drop(tag)` for each dropped entry —
/// linear and order-preserving, unlike per-index `Vec::remove`.
pub(crate) fn retain_with_tags<T, G: Copy>(
    items: &mut Vec<T>,
    tags: &mut Vec<G>,
    mut keep: impl FnMut(&T) -> bool,
    mut on_drop: impl FnMut(G),
) {
    debug_assert_eq!(items.len(), tags.len(), "mirror and tag vectors must stay parallel");
    let mut w = 0;
    for i in 0..items.len() {
        if keep(&items[i]) {
            items.swap(w, i);
            tags.swap(w, i);
            w += 1;
        } else {
            on_drop(tags[i]);
        }
    }
    items.truncate(w);
    tags.truncate(w);
}

/// Stage one history event. A refused write (oversized payload) keeps
/// the event in the *in-memory* mirror — live replay still works — under
/// the [`UNACKABLE`] sentinel, so durable recovery (a failed or
/// cold-restarted processor) is capped at the gap instead of replaying a
/// history with a hole.
fn persist_history(
    store: &Store,
    ft: &mut ProcFt,
    stats: &mut FtStats,
    proc: u32,
    ev: HistoryEvent,
) {
    let tag = ft.fresh_key();
    let seq = match store.stage_put(Key { proc, kind: Kind::HistoryEvent, tag }, ev.to_bytes()) {
        Ok(seq) => seq,
        Err(_) => {
            ft.storage_errors += 1;
            ft.persist_gap = true;
            stats.storage_errors += 1;
            store.trace_instant("storage", "storage_refused", &[("proc", proc as u64)]);
            UNACKABLE
        }
    };
    ft.history.push(ev);
    ft.history_tags.push(TagSeq { tag, seq });
    stats.history_events += 1;
}

/// Observe one event report for its processor: update deltas, logs,
/// histories, and run the policy triggers. One delivered batch is one
/// event. Runs on whichever thread processed the event — `ft` is that
/// processor's state, `view` the engine or worker that owns it.
fn observe_event<V: FtView>(
    topo: &Topology,
    ft: &mut ProcFt,
    store: &Store,
    stats: &mut FtStats,
    rep: &EventReport,
    view: &V,
) {
    stats.events_observed += 1;
    // The history entry (if any) is persisted *after* the sends loop so
    // it can carry the event's per-checkpoint send counts. The reorder is
    // safe: full-history is the only policy that records history and it
    // never logs outputs, so no same-processor durable write interleaves.
    let mut hist_kind: Option<HistoryKind> = None;
    let (proc, evt_time) = match &rep.kind {
        EventKind::Message { proc, edge, time, len, data } => {
            stats.records_observed += *len as u64;
            if ft.policy.tracks_metadata() {
                ft.delivered_new.entry(*edge).or_default().insert(LexTime(*time));
            }
            if ft.policy.records_history() {
                debug_assert_eq!(
                    data.len(),
                    *len,
                    "full-history policies require event-data capture"
                );
                // Aliases the captured payload — an `Arc` bump.
                hist_kind =
                    Some(HistoryKind::Message { edge: *edge, time: *time, data: data.clone() });
            }
            (*proc, *time)
        }
        EventKind::Notification { proc, time } => {
            if ft.policy.tracks_metadata() {
                ft.notified_new.insert(LexTime(*time));
            }
            if ft.policy.records_history() {
                hist_kind = Some(HistoryKind::Notification { time: *time });
            }
            ft.completions += 1;
            (*proc, *time)
        }
        EventKind::Input { proc, time, data } => {
            if ft.policy.tracks_metadata() {
                ft.input_new.insert(LexTime(*time));
            }
            if ft.policy.records_history() {
                hist_kind = Some(HistoryKind::Input { time: *time, data: data.clone() });
            }
            (*proc, *time)
        }
    };
    // Sends: one batch = one tracking/log unit.
    let logs = ft.policy.logs_outputs();
    let tracks = ft.policy.tracks_metadata();
    for (e, batch) in &rep.sent {
        // Real sends are never empty (the flush paths drop empty staged
        // batches), so an empty batch here means the engine was built
        // without sent-capture — which the FtSystem constructors enable.
        debug_assert!(
            !batch.is_empty(),
            "FT observation requires Engine::set_sent_capture(true)"
        );
        *ft.sent_total.entry(*e).or_insert(0) += batch.len() as u64;
        if !tracks {
            continue;
        }
        if topo.projection(*e).is_per_checkpoint() {
            // φ on per-checkpoint edges is a message *count*; batches
            // into seq domains are engine-split singletons, but stay
            // robust to multi-record batches here.
            for _ in 0..batch.len() {
                ft.sent_events.entry(*e).or_default().push(evt_time);
            }
        }
        if logs {
            let entry = LogEntry { edge: *e, event_time: evt_time, batch: batch.clone() };
            let tag = ft.fresh_key();
            match store.stage_put_log(
                Key { proc: proc.0, kind: Kind::LogEntry, tag },
                entry.to_bytes(),
                entry.records() as u64,
            ) {
                Ok(seq) => {
                    stats.log_records += entry.records() as u64;
                    ft.log.push(entry);
                    ft.log_tags.push(TagSeq { tag, seq });
                    stats.log_entries += 1;
                }
                Err(_) => {
                    // An unloggable (oversized) send degrades to the
                    // discard path: D̄ records it honestly, so if the
                    // destination ever needs it re-sent the solver rolls
                    // this processor back to regenerate it (constraint 2)
                    // — recoverable, where the old ack-or-panic path
                    // died mid-drain. The marker freezes: it must never
                    // certify an event whose send is not in the log.
                    ft.storage_errors += 1;
                    ft.persist_gap = true;
                    stats.storage_errors += 1;
                    store.trace_instant("storage", "storage_refused", &[("proc", proc.0 as u64)]);
                    ft.discarded_new.entry(*e).or_default().push((evt_time, batch.time));
                }
            }
        } else {
            // D̄ is a frontier of message times; the batch's records
            // all share one, so a single pair covers them.
            ft.discarded_new.entry(*e).or_default().push((evt_time, batch.time));
        }
    }
    // Persist the history entry with the event's per-checkpoint send
    // counts riding along: `sent_events` is volatile (a crash clears it),
    // so recovery rebuilds exact φ for per-checkpoint out-edges from
    // these durable counts instead of panicking on a missing static
    // projection.
    if let Some(kind) = hist_kind {
        let mut sent_seq: Vec<(EdgeId, u64)> = Vec::new();
        for (e, batch) in &rep.sent {
            if topo.projection(*e).is_per_checkpoint() {
                match sent_seq.iter_mut().find(|(se, _)| se == e) {
                    Some((_, n)) => *n += batch.len() as u64,
                    None => sent_seq.push((*e, batch.len() as u64)),
                }
            }
        }
        persist_history(store, ft, stats, proc.0, HistoryEvent { kind, sent_seq });
    }
    // Policy triggers.
    match ft.policy {
        Policy::Eager => {
            // Checkpoint the state reflecting everything delivered so
            // far — in the seq domain this frontier is trivially
            // complete (each (e,s) arrives exactly once).
            let f = eager_frontier_of(ft);
            checkpoint_proc(topo, ft, store, stats, proc, f, view);
        }
        Policy::Lazy { every, .. } => {
            if matches!(rep.kind, EventKind::Notification { .. }) && ft.completions % every == 0 {
                // Selective checkpoint: previous frontier ∪ ↓t.
                let mut f =
                    ft.chain.last().map(|c| c.meta.f.clone()).unwrap_or(Frontier::Bottom);
                f.insert(evt_time);
                checkpoint_proc(topo, ft, store, stats, proc, f, view);
            }
        }
        _ => {}
    }
}

/// Take a selective checkpoint of `p` at frontier `f` (must extend the
/// previous checkpoint's frontier; constraint 1 of §3.5 — all times in
/// `f` complete at `p` — is the caller's responsibility, upheld by the
/// policy triggers). Worker-safe: touches only `p`'s own state and the
/// shared store.
///
/// The metadata is computed *non-destructively* and the delta sets are
/// pruned only after both blobs stage successfully, so a refused write
/// (oversized state) skips the checkpoint cleanly: Table-1 deltas stay
/// intact, the previous checkpoint remains the restore point, and the
/// refusal is counted instead of panicking mid-drain. Returns whether a
/// checkpoint was taken.
fn checkpoint_proc<V: FtView>(
    topo: &Topology,
    ft: &mut ProcFt,
    store: &Store,
    stats: &mut FtStats,
    p: ProcId,
    f: Frontier,
    view: &V,
) -> bool {
    let in_edges = topo.in_edges(p).to_vec();
    let out_edges = topo.out_edges(p).to_vec();
    let base = ft.base_meta(&in_edges, &out_edges);
    assert!(
        base.f.is_subset(&f),
        "checkpoint frontiers must ascend: {} ⊄ {f}",
        base.f
    );

    // M̄(d, f) = M̄(d, base) ∪ ↓{delivered ∈ f}.
    let mut m_bar = base.m_bar.clone();
    for (&d, times) in &ft.delivered_new {
        let fold: Vec<Time> = times.iter().map(|lt| lt.0).filter(|t| f.contains(t)).collect();
        if !fold.is_empty() {
            let cur = m_bar.entry(d).or_insert(Frontier::Bottom);
            let mut nf = cur.clone();
            for t in &fold {
                nf.insert(*t);
            }
            *cur = nf;
        }
    }
    // N̄(p, f).
    let mut n_bar = base.n_bar.clone();
    for t in ft.notified_new.iter().map(|lt| lt.0).filter(|t| f.contains(t)) {
        n_bar.insert(t);
    }
    // D̄(e, f): unlogged sends caused by events in f.
    let mut d_bar = base.d_bar.clone();
    for (&e, pairs) in &ft.discarded_new {
        let cur = d_bar.entry(e).or_insert(Frontier::Bottom);
        let mut nf = cur.clone();
        for (_, msg_t) in pairs.iter().filter(|(evt, _)| f.contains(evt)) {
            nf.insert(*msg_t);
        }
        *cur = nf;
    }
    // φ(e)(f): static projections computed; per-checkpoint ones are
    // seq watermarks = sends caused by events in f (prefix property
    // holds for the chain policies' checkpoints).
    let mut phi = BTreeMap::new();
    for &e in &out_edges {
        let proj = topo.projection(e);
        let fr = match proj.apply(&f) {
            Some(fr) => fr,
            None => {
                let base_count = base.phi_of(e).watermark(e);
                let new = ft
                    .sent_events
                    .get(&e)
                    .map(|v| v.iter().filter(|t| f.contains(t)).count() as u64)
                    .unwrap_or(0);
                Frontier::seq_watermarks([(e, base_count + new)])
            }
        };
        phi.insert(e, fr);
    }
    let meta = CkptMeta { f: f.clone(), n_bar, m_bar, d_bar, phi };
    let state = view.proc_state(p, &f);
    let pending_notify: Vec<Time> =
        view.proc_pending(p).into_iter().filter(|t| f.contains(t)).collect();
    let stored = StoredCheckpoint { meta, state, pending_notify };
    // Persist state then Ξ (the §4.2 protocol: metadata reaches the
    // monitor only once everything is acknowledged — and in a WAL the
    // chunks and the snapshot record land strictly earlier in append
    // order, so a torn tail can lose the Ξ but never leave one whose
    // chain is missing a piece it wrote; under async staging, per-proc
    // FIFO preserves exactly the same ordering). The state goes down
    // content-addressed: a delta policy diffs against the newest *acked*
    // checkpoint — an unacked base could be discarded by a crash,
    // stranding the delta — and [`plan_snapshot`]'s walk-depth bound
    // forces a full snapshot every `max_chain`-th checkpoint.
    let tag = ft.fresh_key();
    let base = match ft.snapshot_policy {
        SnapshotPolicy::Full => None,
        SnapshotPolicy::Delta { .. } => ft.acked_snapshot_base(store.acked_seq(p.0)),
    };
    let snap = plan_snapshot(&stored.state, base.as_ref(), ft.snapshot_policy);
    if store.stage_put_snapshot(p.0, tag, &snap, &stored.state).is_err() {
        ft.storage_errors += 1;
        stats.storage_errors += 1;
        store.trace_instant("storage", "storage_refused", &[("proc", p.0 as u64)]);
        return false; // refusal is atomic — nothing staged, nothing pruned
    }
    let rec =
        MetaRecord { meta: stored.meta.clone(), pending_notify: stored.pending_notify.clone() };
    let meta_seq = match store.stage_put(Key { proc: p.0, kind: Kind::Meta, tag }, rec.to_bytes())
    {
        Ok(seq) => seq,
        Err(_) => {
            // Undo the orphan snapshot record (ordered after its put by
            // the per-proc FIFO) and skip the checkpoint. Chunks it
            // staged stay resident: content-addressed blobs are shared
            // with other snapshots, and the next reachability sweep
            // collects any left unreferenced.
            store.stage_delete(Key { proc: p.0, kind: Kind::Snapshot, tag });
            ft.storage_errors += 1;
            stats.storage_errors += 1;
            store.trace_instant("storage", "storage_refused", &[("proc", p.0 as u64)]);
            return false;
        }
    };
    // Both blobs staged: prune the delta sets the checkpoint absorbed.
    for times in ft.delivered_new.values_mut() {
        times.retain(|lt| !f.contains(&lt.0));
    }
    ft.notified_new.retain(|lt| !f.contains(&lt.0));
    ft.input_new.retain(|lt| !f.contains(&lt.0));
    for pairs in ft.discarded_new.values_mut() {
        pairs.retain(|(evt, _)| !f.contains(evt));
    }
    for v in ft.sent_events.values_mut() {
        v.retain(|t| !f.contains(t));
    }
    ft.snapshots.insert(tag, snap);
    store.trace_instant(
        "ft",
        "checkpoint",
        &[("proc", p.0 as u64), ("bytes", stored.state.len() as u64)],
    );
    ft.chain.push(stored);
    ft.chain_tags.push(TagSeq { tag, seq: meta_seq });
    stats.checkpoints_taken += 1;
    true
}

/// Sweep `proc`'s content-addressed snapshot store down to what its
/// surviving chain can still reach: a [`Snapshot`] record is retained
/// iff some live chain entry's materialization walk touches it (a
/// delta's base record must outlive its own chain entry), and a chunk is
/// retained iff a retained snapshot lists its hash. Everything else is
/// tombstoned. This is the §4.2 GC reachability rule under chunked
/// checkpoints — run after every chain truncation (monitor GC, rollback,
/// crash-discard, cold-reopen repair). Returns durable objects released.
pub(crate) fn sweep_unreachable_snapshots(store: &Store, proc: u32, ft: &mut ProcFt) -> usize {
    let mut reachable: BTreeSet<u64> = BTreeSet::new();
    for ts in &ft.chain_tags {
        let mut cur = Some(ts.tag);
        while let Some(t) = cur {
            if !reachable.insert(t) {
                break; // shared chain suffix already walked
            }
            cur = ft.snapshots.get(&t).and_then(|s| s.prior_snapshot);
        }
    }
    let mut released = 0usize;
    let dead: Vec<u64> =
        ft.snapshots.keys().filter(|t| !reachable.contains(t)).copied().collect();
    for t in dead {
        ft.snapshots.remove(&t);
        store.delete(&Key { proc, kind: Kind::Snapshot, tag: t });
        released += 1;
    }
    // A chunk survives iff a retained snapshot still lists its hash.
    // (Deleting through the staging FIFO also evicts the chunk from the
    // store's dedup index, so a later checkpoint re-writes it for real.)
    let live: BTreeSet<u64> =
        ft.snapshots.values().flat_map(|s| s.chunks.iter().map(|&(_, h)| h)).collect();
    for k in store.keys_for(proc, Kind::Chunk) {
        if !live.contains(&k.tag) {
            store.delete(&k);
            released += 1;
        }
    }
    released
}

/// Rebuild one processor's Table-1 mirrors from its durable key range
/// (the per-proc body of [`FtSystem::load_durable`], extracted so the
/// parallel cold restart can fan processors across a thread pool — the
/// scan touches only `Key{proc,..}` keys and this processor's `ProcFt`,
/// so concurrent loads are disjoint by construction). Checkpoint states
/// are materialized from their content-addressed snapshot chains; an
/// entry whose chain is incomplete is dropped together with every newer
/// entry, exactly as documented on [`FtSystem::load_durable`].
fn load_proc_durable(store: &Store, p: ProcId, ft: &mut ProcFt) {
    let keys = store.scan_keys(p.0);
    let mut metas: BTreeMap<u64, MetaRecord> = BTreeMap::new();
    let mut snaps: BTreeMap<u64, Snapshot> = BTreeMap::new();
    let mut logs: BTreeMap<u64, LogEntry> = BTreeMap::new();
    let mut hist: BTreeMap<u64, HistoryEvent> = BTreeMap::new();
    let mut mark = Frontier::Bottom;
    let mut next_key = 0u64;
    for k in keys {
        if k.kind == Kind::Chunk {
            // Content-addressed: the tag is a hash, not a counter
            // value (folding it into `next_key` would wreck the
            // key sequence); contents are fetched during
            // materialization, not here.
            continue;
        }
        next_key = next_key.max(k.tag);
        let blob = store.get(&k).expect("scanned key must resolve");
        match k.kind {
            Kind::Meta => {
                let rec = MetaRecord::from_bytes(&blob)
                    .expect("corrupt Ξ record below the WAL checksum layer");
                metas.insert(k.tag, rec);
            }
            Kind::Snapshot => {
                let s = Snapshot::from_bytes(&blob).expect("corrupt snapshot record");
                snaps.insert(k.tag, s);
            }
            Kind::State => {
                // A monolithic state blob: nothing on the
                // checkpoint path writes these anymore (the kind
                // remains valid for generic blobs) — an orphan.
                store.delete(&k);
            }
            Kind::Chunk => unreachable!("chunks skipped above"),
            Kind::LogEntry => {
                let le = LogEntry::from_bytes(&blob).expect("corrupt log entry");
                logs.insert(k.tag, le);
            }
            Kind::HistoryEvent => {
                let ev = HistoryEvent::from_bytes(&blob).expect("corrupt history event");
                hist.insert(k.tag, ev);
            }
            Kind::InputFrontier => {
                mark = Frontier::from_bytes(&blob).expect("corrupt input marker");
            }
        }
    }
    let mut broken = false;
    for (tag, rec) in metas {
        // Conservative repair: once one entry fails to
        // materialize, it and everything newer is deleted — the
        // chain ascends and later deltas may reference the hole.
        if !broken {
            match store.materialize_snapshot(p.0, tag) {
                Some(state) => {
                    debug_assert!(
                        ft.chain.last().map(|c| c.meta.f.is_subset(&rec.meta.f)).unwrap_or(true),
                        "reopened checkpoint chain must ascend"
                    );
                    ft.chain.push(StoredCheckpoint {
                        meta: rec.meta,
                        state,
                        pending_notify: rec.pending_notify,
                    });
                    // Reopened entries are durable by definition:
                    // sequence 0 sits at or below every watermark.
                    ft.chain_tags.push(TagSeq { tag, seq: 0 });
                }
                None => broken = true,
            }
        }
        if broken {
            store.delete(&Key { proc: p.0, kind: Kind::Meta, tag });
        }
    }
    // Mirror every surviving snapshot record, then sweep: orphan
    // records (a Ξ that never became durable, a repaired suffix)
    // and unreferenced chunks are collected here.
    ft.snapshots = snaps;
    sweep_unreachable_snapshots(store, p.0, ft);
    for (tag, le) in logs {
        ft.log.push(le);
        ft.log_tags.push(TagSeq { tag, seq: 0 });
    }
    for (tag, ev) in hist {
        ft.history.push(ev);
        ft.history_tags.push(TagSeq { tag, seq: 0 });
    }
    ft.input_mark = mark.clone();
    ft.input_mark_acked = mark;
    ft.next_key = next_key;
    // Best-effort cadence counter: a lazy processor checkpointed
    // once per `every` completions, so this restores the trigger
    // phase (never output-visible; exact for `every = 1`).
    ft.completions = match ft.policy {
        Policy::FullHistory => ft
            .history
            .iter()
            .filter(|e| matches!(e.kind, HistoryKind::Notification { .. }))
            .count() as u64,
        Policy::Lazy { every, .. } => ft.chain.len() as u64 * every,
        _ => 0,
    };
}

/// Per-worker FT observer for parallel drains: owns the [`ProcFt`]
/// entries of its shard group, shares the store handle, and accumulates
/// private stats merged back after the join.
pub(crate) struct FtWorkerObserver {
    topo: Arc<Topology>,
    ft: Vec<Option<ProcFt>>,
    store: Store,
    stats: FtStats,
}

impl crate::engine::parallel::EventObserver for FtWorkerObserver {
    fn on_event(&mut self, rep: &EventReport, view: &WorkerState) {
        let proc = match &rep.kind {
            EventKind::Message { proc, .. }
            | EventKind::Notification { proc, .. }
            | EventKind::Input { proc, .. } => *proc,
        };
        debug_assert!(view.owns(proc), "observer and worker group disagree on ownership");
        let ft = self.ft[proc.0 as usize]
            .as_mut()
            .expect("event observed at a processor outside this worker's group");
        observe_event(&self.topo, ft, &self.store, &mut self.stats, rep, view);
    }
}

/// Engine + fault-tolerance harness: the top-level object applications
/// drive.
pub struct FtSystem {
    pub engine: Engine,
    pub(crate) ft: Vec<ProcFt>,
    pub store: Store,
    pub(crate) topo: Arc<Topology>,
    pub stats: FtStats,
}

impl FtSystem {
    /// Build a record-at-a-time system (`batch_cap = 1`). `policies[i]`
    /// governs processor `i`.
    pub fn new(
        topo: Arc<Topology>,
        procs: Vec<Box<dyn Processor>>,
        policies: Vec<Policy>,
        delivery: Delivery,
        store: Store,
    ) -> FtSystem {
        FtSystem::new_with_cap(topo, procs, policies, delivery, store, 1)
    }

    /// Build a system whose channels coalesce same-time sends into
    /// batches of up to `batch_cap` records (see
    /// [`Engine::with_batch_cap`]); every FT structure then moves at
    /// batch granularity. Cap 1 reproduces record-at-a-time delivery
    /// exactly; log-entry granularity follows how senders staged
    /// records (one entry per staged batch) at every cap.
    pub fn new_with_cap(
        topo: Arc<Topology>,
        procs: Vec<Box<dyn Processor>>,
        policies: Vec<Policy>,
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
    ) -> FtSystem {
        assert_eq!(policies.len(), topo.num_procs());
        // Note: stateless policies feeding per-checkpoint-projection
        // edges are allowed; the solver then uses the maximally
        // conservative φ = ∅ for mid-range frontiers (§3.2). Policies
        // that need exact seq counts (Eager) record them per checkpoint.
        let ft: Vec<ProcFt> = policies.into_iter().map(ProcFt::new).collect();
        let mut engine = Engine::with_batch_cap(topo.clone(), procs, delivery, batch_cap);
        // Only full-history policies need the delivered payload echoed in
        // reports; everyone else rides the count-only hot path. Sent
        // payloads are always captured under the harness — logging and D̄
        // maintenance read them.
        if ft.iter().any(|f| f.policy.records_history()) {
            engine.set_event_data_capture(true);
        }
        engine.set_sent_capture(true);
        FtSystem { engine, ft, store, topo, stats: FtStats::default() }
    }

    /// Attach (or detach) a structured tracer ([`crate::trace`]) to the
    /// whole stack at once: the engine records delivery/stall/barrier
    /// events, the store records checkpoint/ack/refusal/WAL events, and
    /// the recovery path records its detect → solver → rollback → replay
    /// timeline. `None` (the default) restores the zero-instrumentation
    /// hot path.
    pub fn set_tracer(&mut self, tracer: Option<crate::trace::Tracer>) {
        self.engine.set_tracer(tracer.clone());
        self.store.set_tracer(tracer);
    }

    /// The attached tracer, if any (the recovery path records through
    /// this; shared with the engine by [`FtSystem::set_tracer`]).
    pub fn tracer(&self) -> Option<&crate::trace::Tracer> {
        self.engine.tracer()
    }

    /// Build a **sharded** system from a [`ShardPlan`]: one wrapped
    /// operator per physical shard (see
    /// [`crate::engine::sharded::ShardRouter`]), with per-*logical*-vertex
    /// policies replicated over that vertex's shards. Each shard then
    /// carries its own frontier, checkpoint chain and Table-1 metadata,
    /// so failures inject per shard
    /// (`inject_failures(&[plan.proc(v, s)])`) and the Fig. 6 solver
    /// produces a per-shard rollback plan.
    pub fn new_sharded(
        plan: &Arc<crate::graph::sharding::ShardPlan>,
        factories: Vec<crate::engine::sharded::ProcFactory>,
        logical_policies: &[Policy],
        delivery: Delivery,
        store: Store,
    ) -> FtSystem {
        FtSystem::new_sharded_with_cap(plan, factories, logical_policies, delivery, store, 1)
    }

    /// Sharded system with a channel coalescing cap: exchange-edge
    /// bundles then carry whole per-shard sub-batches instead of
    /// singleton messages.
    pub fn new_sharded_with_cap(
        plan: &Arc<crate::graph::sharding::ShardPlan>,
        factories: Vec<crate::engine::sharded::ProcFactory>,
        logical_policies: &[Policy],
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
    ) -> FtSystem {
        let procs = crate::engine::sharded::build_procs(plan, factories);
        let policies = plan.expand_per_proc(logical_policies);
        FtSystem::new_with_cap(plan.topo.clone(), procs, policies, delivery, store, batch_cap)
    }

    /// **Cold-restart recovery**: rebuild a system from a reopened
    /// durable store — the process died (taking every operator state,
    /// channel, frontier and unflushed write with it) and a fresh process
    /// reattaches to the same storage.
    ///
    /// `topo`/`procs`/`policies`/`delivery`/`batch_cap` must describe the
    /// same application as the run that wrote the store (fresh operator
    /// instances — their state is restored from checkpoints). The loader
    /// rescans each processor's key range into the Table-1 mirrors (Ξ
    /// records with their pending notifications, checkpoint states, logs,
    /// full histories, input-frontier markers), then treats the restart
    /// as the failure scenario in which **every** processor crashed at
    /// once: the Fig. 6 solver picks the maximal durably-consistent
    /// frontiers and the §3.6 reset restores states, re-arms
    /// notifications, and replays Q′ from the reopened logs. External
    /// inputs beyond the chosen source frontiers must be resupplied by
    /// the §4.3 services (`ExternalInput::replay_from`), exactly as after
    /// an in-process failure.
    ///
    /// Returns the system plus the recovery report (whose plan tells the
    /// caller which input frontier each source resumed from).
    pub fn reopen(
        topo: Arc<Topology>,
        procs: Vec<Box<dyn Processor>>,
        policies: Vec<Policy>,
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
    ) -> (FtSystem, crate::ft::recovery::RecoveryReport) {
        let mut sys = FtSystem::new_with_cap(topo, procs, policies, delivery, store, batch_cap);
        sys.load_durable();
        let all: Vec<ProcId> = sys.topo.proc_ids().collect();
        sys.inject_failures(&all);
        let report = sys.recover();
        (sys, report)
    }

    /// [`FtSystem::reopen`] for a sharded plan (the counterpart of
    /// [`FtSystem::new_sharded_with_cap`]).
    pub fn reopen_sharded(
        plan: &Arc<crate::graph::sharding::ShardPlan>,
        factories: Vec<crate::engine::sharded::ProcFactory>,
        logical_policies: &[Policy],
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
    ) -> (FtSystem, crate::ft::recovery::RecoveryReport) {
        let procs = crate::engine::sharded::build_procs(plan, factories);
        let policies = plan.expand_per_proc(logical_policies);
        FtSystem::reopen(plan.topo.clone(), procs, policies, delivery, store, batch_cap)
    }

    /// [`FtSystem::reopen`] with the whole pipeline fanned across
    /// `threads` workers: the per-proc key-range scans and snapshot-chain
    /// materializations run on a scoped thread pool
    /// ([`FtSystem::load_durable_parallel`]), and the everyone-crashed
    /// recovery runs decomposed onto the shard groups
    /// ([`FtSystem::recover_parallel`]). `group_of` maps each processor
    /// to its shard group, exactly as for
    /// [`FtSystem::run_to_quiescence_parallel`]. Output is
    /// byte-identical to the sequential reopen; `threads <= 1` *is* the
    /// sequential reopen.
    #[allow(clippy::too_many_arguments)]
    pub fn reopen_parallel(
        topo: Arc<Topology>,
        procs: Vec<Box<dyn Processor>>,
        policies: Vec<Policy>,
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
        group_of: &[usize],
        threads: usize,
    ) -> (FtSystem, crate::ft::recovery::RecoveryReport) {
        let mut sys = FtSystem::new_with_cap(topo, procs, policies, delivery, store, batch_cap);
        sys.load_durable_parallel(threads);
        let all: Vec<ProcId> = sys.topo.proc_ids().collect();
        sys.inject_failures(&all);
        let report = sys.recover_parallel(group_of, threads);
        (sys, report)
    }

    /// [`FtSystem::reopen_sharded`] on the worker pool: shard groups are
    /// derived from the plan ([`crate::engine::shard_groups`], the same
    /// mapping a parallel drain uses) and the reopen pipeline fans
    /// across them — see [`FtSystem::reopen_parallel`].
    pub fn reopen_sharded_parallel(
        plan: &Arc<crate::graph::sharding::ShardPlan>,
        factories: Vec<crate::engine::sharded::ProcFactory>,
        logical_policies: &[Policy],
        delivery: Delivery,
        store: Store,
        batch_cap: usize,
        threads: usize,
    ) -> (FtSystem, crate::ft::recovery::RecoveryReport) {
        let procs = crate::engine::sharded::build_procs(plan, factories);
        let policies = plan.expand_per_proc(logical_policies);
        let group_of = crate::engine::shard_groups(plan, threads.max(1));
        FtSystem::reopen_parallel(
            plan.topo.clone(),
            procs,
            policies,
            delivery,
            store,
            batch_cap,
            &group_of,
            threads,
        )
    }

    /// Bound every data channel to roughly `cap` queued records with
    /// credit-based backpressure (see [`Engine::set_mailbox_cap`]); `None`
    /// restores unbounded mailboxes. Not persisted: callers must re-apply
    /// after [`FtSystem::reopen`] / [`FtSystem::reopen_sharded`].
    pub fn set_mailbox_cap(&mut self, cap: Option<usize>) {
        self.engine.set_mailbox_cap(cap);
    }

    /// Set every processor's durable snapshot representation (full
    /// snapshots vs bounded delta chains — see [`SnapshotPolicy`]).
    /// Affects checkpoints taken from now on; earlier chain entries keep
    /// the representation they were written with (both materialize the
    /// same way). Not persisted: callers must re-apply after
    /// [`FtSystem::reopen`] / [`FtSystem::reopen_sharded`].
    pub fn set_snapshot_policy(&mut self, policy: SnapshotPolicy) {
        for ft in &mut self.ft {
            ft.snapshot_policy = policy;
        }
    }

    /// Rebuild every processor's Table-1 mirrors from the durable store
    /// (one ranged key scan per processor). Checkpoint states are
    /// materialized from their content-addressed snapshot chains; an
    /// entry whose chain is incomplete — possible when compaction
    /// relocated cold records and a torn tail then destroyed one — is
    /// dropped **together with every newer entry** (a later delta may
    /// chain on the broken one), which is exactly the rollback a
    /// slightly older crash would have forced. The §4.2 reachability
    /// sweep then collects snapshot records and chunks nothing retained
    /// references.
    fn load_durable(&mut self) {
        let store = self.store.clone();
        for p in self.topo.proc_ids() {
            load_proc_durable(&store, p, &mut self.ft[p.0 as usize]);
        }
    }

    /// [`FtSystem::load_durable`] fanned across a scoped thread pool:
    /// processors are dealt round-robin to `threads` workers, and each
    /// worker scans and rebuilds its processors' mirrors concurrently.
    /// Safe without locks: key ranges are per-proc disjoint
    /// (`Key{proc,..}`), the store's index is read-only during the scan
    /// (the only writes are orphan deletions inside the caller-owned
    /// range), and each `ProcFt` mirror has exactly one loading worker —
    /// so reopen wall time scales with the largest processor's range,
    /// not the sum.
    fn load_durable_parallel(&mut self, threads: usize) {
        if threads <= 1 || self.ft.len() <= 1 {
            return self.load_durable();
        }
        let store = self.store.clone();
        let lanes = threads.min(self.ft.len());
        let mut buckets: Vec<Vec<(ProcId, &mut ProcFt)>> =
            (0..lanes).map(|_| Vec::new()).collect();
        for (pi, ft) in self.ft.iter_mut().enumerate() {
            buckets[pi % lanes].push((ProcId(pi as u32), ft));
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    let store = store.clone();
                    s.spawn(move || {
                        for (p, ft) in bucket {
                            load_proc_durable(&store, p, ft);
                        }
                    })
                })
                .collect();
            for h in handles {
                if let Err(e) = h.join() {
                    std::panic::resume_unwind(e);
                }
            }
        });
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn policy(&self, p: ProcId) -> Policy {
        self.ft[p.0 as usize].policy
    }

    /// Reconstruct the §4.2 GC monitoring service after a cold restart
    /// from this system's reopened checkpoint chains (the counterpart of
    /// [`crate::ft::monitor::Monitor::reopen`] — the monitor's durable
    /// input IS the set of acknowledged Ξ records this system just
    /// reloaded). `stateless[p]`/`logs[p]` classify processors exactly as
    /// in [`crate::ft::monitor::Monitor::new`].
    pub fn rebuild_monitor(
        &self,
        stateless: Vec<bool>,
        logs: Vec<bool>,
    ) -> crate::ft::monitor::Monitor {
        let chains: Vec<Vec<CkptMeta>> = self
            .ft
            .iter()
            .enumerate()
            .map(|(i, ft)| {
                if stateless[i] {
                    Vec::new()
                } else {
                    ft.chain.iter().map(|c| c.meta.clone()).collect()
                }
            })
            .collect();
        crate::ft::monitor::Monitor::reopen(self.topo.clone(), stateless, logs, chains)
    }

    /// Feed the §4.2 monitoring service every checkpoint whose Ξ write
    /// the store has **acknowledged** and that has not been reported yet,
    /// returning the GC actions its watermark advances enabled. This is
    /// the ack-gated face of [`crate::ft::monitor::Monitor::on_persisted`]
    /// — under async persistence the monitor's low-watermark therefore
    /// never references a checkpoint that exists only in volatile staging
    /// (a crash could discard it, and GC driven past durable state would
    /// be unrecoverable).
    ///
    /// The per-processor cursor survives GC (which drops reported prefix
    /// entries) and clamps under rollback truncation. After a recovery
    /// that truncated chains, rebuild the monitor from the surviving
    /// chains ([`FtSystem::rebuild_monitor`]) before pumping further —
    /// the monitor's own availability is append-only.
    pub fn pump_monitor(
        &mut self,
        mon: &mut crate::ft::monitor::Monitor,
    ) -> Vec<crate::ft::monitor::GcAction> {
        let mut actions = Vec::new();
        for p in self.topo.proc_ids() {
            if !self.ft[p.0 as usize].policy.has_chain() {
                continue;
            }
            let w = self.store.acked_seq(p.0);
            let ft = &mut self.ft[p.0 as usize];
            let acked = acked_prefix(&ft.chain_tags, w);
            while ft.chain_reported < acked {
                let meta = ft.chain[ft.chain_reported].meta.clone();
                ft.chain_reported += 1;
                actions.extend(mon.on_persisted(p, meta));
            }
        }
        actions
    }

    /// Staged-minus-acknowledged durable operations right now (0 in sync
    /// mode). [`FtStats::ack_lag`] records the peak of this gauge at
    /// drain and recovery boundaries.
    pub fn ack_lag(&self) -> u64 {
        self.store.ack_lag()
    }

    /// Durable writes the store refused for `p` (oversized payloads).
    pub fn storage_errors(&self, p: ProcId) -> u64 {
        self.ft[p.0 as usize].storage_errors
    }

    /// Fold the current staging lag into the peak gauge.
    pub(crate) fn note_ack_lag(&mut self) {
        self.stats.ack_lag = self.stats.ack_lag.max(self.store.ack_lag());
    }

    /// Process one event, maintaining all FT metadata.
    pub fn step(&mut self) -> Option<EventReport> {
        let rep = self.engine.step()?;
        self.observe(&rep);
        Some(rep)
    }

    /// Run until quiescent (bounded), observing every event.
    pub fn run_to_quiescence(&mut self, max_steps: usize) -> usize {
        let mut n = 0;
        while n < max_steps {
            if self.step().is_none() {
                break;
            }
            n += 1;
        }
        self.note_ack_lag();
        n
    }

    /// Push external input (observed like any other event).
    pub fn push_input(&mut self, p: ProcId, t: Time, data: Record) -> EventReport {
        let rep = self.engine.push_input(p, t, data);
        self.observe(&rep);
        rep
    }

    pub fn advance_input(&mut self, p: ProcId, t: Time) {
        self.engine.advance_input(p, t);
        self.note_input_advance(p, Some(t));
    }

    pub fn close_input(&mut self, p: ProcId) {
        self.engine.close_input(p);
        self.note_input_advance(p, None);
    }

    /// Maintain the durable input-frontier marker of a logging source:
    /// moving the input capability past a time makes it *complete* at the
    /// source (no in-edges, no notifications — inputs are its only
    /// events), and all sends those inputs caused were already
    /// acknowledged in the log/history (they were written before this
    /// marker, and the WAL loses only suffixes). The marker is therefore
    /// a valid §4.2 Ξ(p,f) with S = ∅, which is what lets a *failed* (or
    /// cold-restarted) logging source offer a nonempty frontier instead
    /// of dragging the whole dataflow to ∅. `upto = None` means the
    /// stream closed: everything consumed is complete.
    fn note_input_advance(&mut self, p: ProcId, upto: Option<Time>) {
        if !self.topo.in_edges(p).is_empty() {
            return;
        }
        let store = self.store.clone();
        let ft = &mut self.ft[p.0 as usize];
        if !(ft.policy.logs_outputs() || ft.policy.records_history()) {
            return;
        }
        // A refused log/history write froze the marker: advancing it past
        // the gap would certify a send the durable log does not hold.
        if ft.persist_gap {
            return;
        }
        let mut mark = ft.input_mark.clone();
        let mut changed = false;
        for lt in &ft.input_new {
            let closed = match &upto {
                // Only times strictly below the capability are certainly
                // closed (incomparable times could still receive input —
                // the engine's push guard permits them).
                Some(t) => lt.0.lt(t),
                None => true,
            };
            if closed && !mark.contains(&lt.0) {
                mark.insert(lt.0);
                changed = true;
            }
        }
        if changed {
            // Opportunistically settle already-acked versions, then stage
            // the widened marker. The log entries it certifies were
            // staged strictly earlier, so per-proc FIFO upholds the
            // prefix property: an acked marker implies an acked log.
            ft.drain_acked_marks(store.acked_seq(p.0));
            match store
                .stage_put(Key { proc: p.0, kind: Kind::InputFrontier, tag: 0 }, mark.to_bytes())
            {
                Ok(seq) => {
                    ft.input_mark = mark.clone();
                    ft.mark_pending.push((seq, mark));
                }
                Err(_) => {
                    ft.storage_errors += 1;
                    self.stats.storage_errors += 1;
                    store.trace_instant("storage", "storage_refused", &[("proc", p.0 as u64)]);
                }
            }
        }
    }

    /// Observe an event report: update deltas, logs, histories, and run
    /// the policy triggers. One delivered batch is one event.
    fn observe(&mut self, rep: &EventReport) {
        let proc = match &rep.kind {
            EventKind::Message { proc, .. }
            | EventKind::Notification { proc, .. }
            | EventKind::Input { proc, .. } => *proc,
        };
        observe_event(
            &self.topo,
            &mut self.ft[proc.0 as usize],
            &self.store,
            &mut self.stats,
            rep,
            &self.engine,
        );
    }

    /// Drain to quiescence with one OS thread per worker group
    /// (`group_of[p]` assigns processors; see
    /// [`crate::engine::shard_groups`]). Each worker carries its group's
    /// `ProcFt` state and observes its own events inline — logs,
    /// histories and policy-triggered checkpoints are written on the
    /// worker thread at the event, exactly as in the sequential loop.
    /// Per-worker stats merge back afterwards. `threads <= 1` falls back
    /// to [`FtSystem::run_to_quiescence`]. Returns events processed.
    pub fn run_to_quiescence_parallel(
        &mut self,
        group_of: &[usize],
        threads: usize,
        max_steps: usize,
    ) -> usize {
        if threads <= 1 {
            return self.run_to_quiescence(max_steps);
        }
        let np = self.topo.num_procs();
        assert_eq!(group_of.len(), np, "one group per processor");
        let mut observers: Vec<FtWorkerObserver> = (0..threads)
            .map(|_| FtWorkerObserver {
                topo: self.topo.clone(),
                ft: (0..np).map(|_| None).collect(),
                store: self.store.clone(),
                stats: FtStats::default(),
            })
            .collect();
        for (pi, ft) in self.ft.iter_mut().enumerate() {
            observers[group_of[pi]].ft[pi] =
                Some(std::mem::replace(ft, ProcFt::new(Policy::Ephemeral)));
        }
        let events = crate::engine::parallel::drive_parallel(
            &mut self.engine,
            group_of,
            threads,
            max_steps,
            &mut observers,
        );
        for obs in observers {
            self.stats.merge(&obs.stats);
            for (pi, slot) in obs.ft.into_iter().enumerate() {
                if let Some(ft) = slot {
                    self.ft[pi] = ft;
                }
            }
        }
        // Quiescence barrier: record the peak lag the drain produced,
        // then settle the staging queue so the writer thread is idle
        // whenever workers are parked — pause-drain-rollback (and any
        // inspection between drains) sees a fully-applied store.
        self.note_ack_lag();
        self.store.flush_staged();
        events
    }

    /// The frontier of the newest checkpoint (∅ if none).
    pub fn base_frontier(&self, p: ProcId) -> Frontier {
        self.ft[p.0 as usize].chain.last().map(|c| c.meta.f.clone()).unwrap_or(Frontier::Bottom)
    }

    /// Take a selective checkpoint of `p` at frontier `f` (must extend the
    /// previous checkpoint's frontier; constraint 1 of §3.5 — all times in
    /// `f` complete at `p` — is the caller's responsibility, upheld by the
    /// policy triggers).
    pub fn checkpoint_now(&mut self, p: ProcId, f: Frontier) {
        checkpoint_proc(
            &self.topo,
            &mut self.ft[p.0 as usize],
            &self.store,
            &mut self.stats,
            p,
            f,
            &self.engine,
        );
    }

    /// The live pseudo-checkpoint Ξ(p, ⊤) for a non-failed chain
    /// processor (§4.4): cumulative M̄/N̄/D̄ plus current φ counts.
    pub(crate) fn live_top_meta(&self, p: ProcId) -> CkptMeta {
        let in_edges = self.topo.in_edges(p);
        let out_edges = self.topo.out_edges(p);
        let ft = &self.ft[p.0 as usize];
        let base = ft.base_meta(in_edges, out_edges);
        let mut m_bar = base.m_bar.clone();
        for (&d, times) in &ft.delivered_new {
            let cur = m_bar.entry(d).or_insert(Frontier::Bottom);
            let mut nf = cur.clone();
            for lt in times {
                nf.insert(lt.0);
            }
            *cur = nf;
        }
        let mut n_bar = base.n_bar.clone();
        for lt in &ft.notified_new {
            n_bar.insert(lt.0);
        }
        let mut d_bar = base.d_bar.clone();
        for (&e, pairs) in &ft.discarded_new {
            let cur = d_bar.entry(e).or_insert(Frontier::Bottom);
            let mut nf = cur.clone();
            for (_, msg_t) in pairs {
                nf.insert(*msg_t);
            }
            *cur = nf;
        }
        let mut phi = BTreeMap::new();
        for &e in out_edges {
            let fr = if self.topo.projection(e).is_per_checkpoint() {
                Frontier::seq_watermarks([(e, self.engine.seq_counter(e))])
            } else {
                Frontier::Top
            };
            phi.insert(e, fr);
        }
        CkptMeta { f: Frontier::Top, n_bar, m_bar, d_bar, phi }
    }

    /// The synthetic Ξ(p, f) a failed logging **source** can offer from
    /// its durable input-frontier marker (see
    /// [`ProcFt::input_mark`]): S = ∅ (stateless), M̄ = ∅ (no in-edges —
    /// external inputs are resupplied by the §4.3 services, footnote 1),
    /// N̄ = ∅ (sources process no notifications), D̄ = ∅ (every send
    /// inside the marker is acknowledged in the log / history), and φ
    /// from the static projections — or, for per-checkpoint edges, the
    /// acknowledged log's record count inside the marker.
    pub(crate) fn source_marker_meta(&self, p: ProcId) -> Option<CkptMeta> {
        let ft = &self.ft[p.0 as usize];
        if !self.topo.in_edges(p).is_empty()
            || ft.input_mark.is_bottom()
            || !(ft.policy.logs_outputs() || ft.policy.records_history())
        {
            return None;
        }
        let out_edges = self.topo.out_edges(p);
        let mut meta = CkptMeta::empty(&[], out_edges);
        meta.f = ft.input_mark.clone();
        for &e in out_edges {
            let fr = match self.topo.projection(e).apply(&meta.f) {
                Some(fr) => fr,
                None => {
                    let count: u64 = ft
                        .log
                        .iter()
                        .filter(|le| le.edge == e && meta.f.contains(&le.event_time))
                        .map(|le| le.records() as u64)
                        .sum();
                    Frontier::seq_watermarks([(e, count)])
                }
            };
            meta.phi.insert(e, fr);
        }
        Some(meta)
    }

    /// φ(e)(g) evaluated against the live system (recovery-time helper):
    /// static projections compute; per-checkpoint ones read the chain (or
    /// the live counters at ⊤, or a source's marker Ξ).
    pub(crate) fn phi_runtime(&self, e: EdgeId, g: &Frontier) -> Frontier {
        if let Some(f) = self.topo.projection(e).apply(g) {
            return f;
        }
        if g.is_bottom() {
            return Frontier::Bottom;
        }
        if g.is_top() {
            return Frontier::seq_watermarks([(e, self.engine.seq_counter(e))]);
        }
        let src = self.topo.src(e);
        if let Some(c) = self.ft[src.0 as usize].chain.iter().find(|c| &c.meta.f == g) {
            return c.meta.phi_of(e).clone();
        }
        match self.source_marker_meta(src) {
            Some(m) if &m.f == g => m.phi_of(e).clone(),
            _ => panic!("phi_runtime: {g} is not a checkpoint of {src}"),
        }
    }

    /// Number of durable checkpoints at `p` (tests/benches).
    pub fn chain_len(&self, p: ProcId) -> usize {
        self.ft[p.0 as usize].chain.len()
    }

    /// The Ξ metadata of the `k`-th durable checkpoint at `p` (what the
    /// processor reports to the §4.2 monitor once storage acknowledges).
    pub fn checkpoint_meta(&self, p: ProcId, k: usize) -> CkptMeta {
        self.ft[p.0 as usize].chain[k].meta.clone()
    }

    /// Apply a §4.2 garbage-collection action from the monitor: drop
    /// checkpoints strictly below the watermark (keeping the newest one
    /// at-or-below, which remains the restore point), or drop logged
    /// messages whose times the destination will never need re-sent.
    /// Every mirror entry carries its storage tag, so exactly the doomed
    /// blobs are deleted — which a [`crate::ft::backend_file::FileBackend`]
    /// turns into tombstones and, past the dead-byte threshold, segment
    /// compaction. A dropped checkpoint's *snapshot record and chunks*
    /// are not deleted by tag: a retained delta may still reach them via
    /// its `prior_snapshot` chain, so the reachability sweep
    /// ([`sweep_unreachable_snapshots`]) decides what actually dies.
    /// Returns the number of durable objects released.
    pub fn apply_gc(&mut self, action: &crate::ft::monitor::GcAction) -> usize {
        match action {
            crate::ft::monitor::GcAction::DropCheckpointsBelow { proc, watermark } => {
                let store = self.store.clone();
                let ft = &mut self.ft[proc.0 as usize];
                // Keep the newest checkpoint ⊆ watermark plus everything
                // above it; drop older ones.
                let keep_from = ft
                    .chain
                    .iter()
                    .rposition(|c| c.meta.f.is_subset(watermark))
                    .unwrap_or(0);
                let mut dropped = keep_from;
                if dropped > 0 {
                    ft.chain.drain(..dropped);
                    // The monitor cursor counts reported *prefix* entries;
                    // GC drops from the front, so it slides down with it.
                    ft.chain_reported = ft.chain_reported.saturating_sub(dropped);
                    for ts in ft.chain_tags.drain(..dropped) {
                        store.delete(&Key { proc: proc.0, kind: Kind::Meta, tag: ts.tag });
                    }
                    dropped += sweep_unreachable_snapshots(&store, proc.0, ft);
                }
                dropped
            }
            crate::ft::monitor::GcAction::DropLogWithin { proc, edge, watermark } => {
                let ft = &mut self.ft[proc.0 as usize];
                let store = self.store.clone();
                let mut dropped = 0;
                retain_with_tags(
                    &mut ft.log,
                    &mut ft.log_tags,
                    |le| le.edge != *edge || !watermark.contains(&le.batch.time),
                    |ts: TagSeq| {
                        store.delete(&Key { proc: proc.0, kind: Kind::LogEntry, tag: ts.tag });
                        dropped += 1;
                    },
                );
                dropped
            }
        }
    }

    /// Log length at `p` (tests/benches).
    pub fn log_len(&self, p: ProcId) -> usize {
        self.ft[p.0 as usize].log.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Projection};
    use crate::operators::{shared_vec, Sink, Source, SumByTime};
    use crate::time::TimeDomain;

    fn epoch_pipeline(policies: Vec<Policy>) -> (FtSystem, ProcId, crate::operators::SharedVec) {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let sum = g.add_proc("sum", TimeDomain::EPOCH);
        let snk = g.add_proc("sink", TimeDomain::EPOCH);
        g.connect(src, sum, Projection::Identity);
        g.connect(sum, snk, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let out = shared_vec();
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(SumByTime::default()),
            Box::new(Sink(out.clone())),
        ];
        let sys = FtSystem::new(topo, procs, policies, Delivery::Fifo, Store::new(1));
        (sys, src, out)
    }

    #[test]
    fn retain_with_tags_is_order_preserving() {
        let mut items = vec![10, 11, 12, 13, 14, 15];
        let mut tags = vec![1u64, 2, 3, 4, 5, 6];
        let mut dropped = Vec::new();
        retain_with_tags(&mut items, &mut tags, |x| x % 2 == 0, |t| dropped.push(t));
        assert_eq!(items, vec![10, 12, 14]);
        assert_eq!(tags, vec![1, 3, 5]);
        assert_eq!(dropped, vec![2, 4, 6]);
    }

    #[test]
    fn lazy_checkpoints_on_completion() {
        let (mut sys, src, out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::Lazy { every: 1, log_outputs: false },
            Policy::Ephemeral,
        ]);
        let sum = sys.topology().find("sum").unwrap();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.push_input(src, Time::epoch(0), Record::Int(5));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        assert_eq!(out.lock().unwrap().len(), 1);
        // One completion (epoch 0) → one checkpoint, at frontier ↓0, with
        // empty state (Sum discards completed sums — the §2.3 payoff).
        assert_eq!(sys.chain_len(sum), 1);
        let ck = &sys.ft[sum.0 as usize].chain[0];
        assert_eq!(ck.meta.f, Frontier::upto_epoch(0));
        // TimeState encodes a zero-length partition list for empty state.
        assert!(ck.state.len() <= 1, "selective checkpoint of Sum after completion is empty");
        assert_eq!(ck.meta.n_bar, Frontier::upto_epoch(0));
        assert_eq!(
            ck.meta.m_bar.get(&EdgeId(0)).unwrap(),
            &Frontier::upto_epoch(0)
        );
        // Sum does not log: its output at epoch 0 is in D̄.
        assert_eq!(ck.meta.d_bar.get(&EdgeId(1)).unwrap(), &Frontier::upto_epoch(0));
    }

    #[test]
    fn logging_policy_persists_entries() {
        let (mut sys, src, _out) = epoch_pipeline(vec![
            Policy::LogOutputs,
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::Ephemeral,
        ]);
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(1));
        sys.push_input(src, Time::epoch(0), Record::Int(2));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        assert_eq!(sys.log_len(src), 2, "source logged both forwards");
        let sum = sys.topology().find("sum").unwrap();
        assert_eq!(sys.log_len(sum), 1, "sum logged its one emission");
        // D̄ of the logging sum is empty.
        let ck = &sys.ft[sum.0 as usize].chain[0];
        assert!(ck.meta.d_bar.get(&EdgeId(1)).unwrap().is_bottom());
        // And the store holds the blobs durably.
        assert!(sys.store.keys_for(src.0, Kind::LogEntry).len() == 2);
    }

    #[test]
    fn lazy_every_k_checkpoints_every_kth_epoch() {
        let (mut sys, src, _out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::Lazy { every: 3, log_outputs: false },
            Policy::Ephemeral,
        ]);
        let sum = sys.topology().find("sum").unwrap();
        for ep in 0..9 {
            sys.advance_input(src, Time::epoch(ep));
            sys.push_input(src, Time::epoch(ep), Record::Int(1));
            sys.advance_input(src, Time::epoch(ep + 1));
            sys.run_to_quiescence(1000);
        }
        assert_eq!(sys.chain_len(sum), 3, "9 completions / every-3 = 3 checkpoints");
        let fs: Vec<Frontier> =
            sys.ft[sum.0 as usize].chain.iter().map(|c| c.meta.f.clone()).collect();
        assert_eq!(fs[0], Frontier::upto_epoch(2));
        assert_eq!(fs[1], Frontier::upto_epoch(5));
        assert_eq!(fs[2], Frontier::upto_epoch(8));
    }

    #[test]
    fn full_history_records_events() {
        let (mut sys, src, _out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::FullHistory,
            Policy::Ephemeral,
        ]);
        let sum = sys.topology().find("sum").unwrap();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(7));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        let h = &sys.ft[sum.0 as usize].history;
        assert_eq!(h.len(), 2, "one message + one notification");
        assert!(matches!(h[0].kind, HistoryKind::Message { .. }));
        assert!(matches!(h[1].kind, HistoryKind::Notification { .. }));
        assert!(
            h[0].sent_seq.is_empty(),
            "identity-projection out-edges carry no per-checkpoint counts"
        );
        assert!(!sys.store.keys_for(sum.0, Kind::HistoryEvent).is_empty());
    }

    #[test]
    fn ephemeral_has_zero_overhead() {
        let (mut sys, src, _out) =
            epoch_pipeline(vec![Policy::Ephemeral, Policy::Ephemeral, Policy::Ephemeral]);
        sys.advance_input(src, Time::epoch(0));
        for _ in 0..10 {
            sys.push_input(src, Time::epoch(0), Record::Int(1));
        }
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        let st = sys.store.stats();
        assert_eq!(st.writes, 0, "ephemeral writes nothing");
        assert_eq!(sys.stats.checkpoints_taken, 0);
    }

    fn epoch_pipeline_with_cap(
        policies: Vec<Policy>,
        batch_cap: usize,
    ) -> (FtSystem, ProcId, crate::operators::SharedVec) {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let sum = g.add_proc("sum", TimeDomain::EPOCH);
        let snk = g.add_proc("sink", TimeDomain::EPOCH);
        g.connect(src, sum, Projection::Identity);
        g.connect(sum, snk, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let out = shared_vec();
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(SumByTime::default()),
            Box::new(Sink(out.clone())),
        ];
        let sys =
            FtSystem::new_with_cap(topo, procs, policies, Delivery::Fifo, Store::new(1), batch_cap);
        (sys, src, out)
    }

    fn drive_six(sys: &mut FtSystem, src: ProcId) {
        sys.advance_input(src, Time::epoch(0));
        for v in 0..6 {
            sys.push_input(src, Time::epoch(0), Record::Int(v));
        }
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(10_000);
    }

    /// Satellite coverage: Eager vs Lazy write/byte accounting under
    /// batching. Eager charges one acknowledged checkpoint (a state +
    /// meta write pair) per *event* — which at `batch_cap = 8` is one
    /// delivered batch, not six records — and `bytes_written` on the log
    /// path matches the encoded sizes of the logged batches exactly.
    #[test]
    fn eager_vs_lazy_accounting_under_batching() {
        // Eager, record-at-a-time: 6 message events + 1 notification = 7
        // checkpoints.
        let (mut sys, src, _) = epoch_pipeline_with_cap(
            vec![Policy::LogOutputs, Policy::Eager, Policy::Ephemeral],
            1,
        );
        let sum = sys.topology().find("sum").unwrap();
        drive_six(&mut sys, src);
        assert_eq!(sys.chain_len(sum), 7, "eager checkpoints once per event at cap 1");
        assert_eq!(sys.store.keys_for(sum.0, Kind::Snapshot).len(), 7);
        assert_eq!(sys.store.keys_for(sum.0, Kind::Meta).len(), 7);

        // Eager, cap 8: the six same-epoch records coalesce into one
        // delivered batch — one event, so one checkpoint — plus the
        // notification. The batch is one acknowledged write, not six.
        let (mut sys8, src8, _) = epoch_pipeline_with_cap(
            vec![Policy::LogOutputs, Policy::Eager, Policy::Ephemeral],
            8,
        );
        let sum8 = sys8.topology().find("sum").unwrap();
        drive_six(&mut sys8, src8);
        assert_eq!(sys8.chain_len(sum8), 2, "one batch event + one notification");
        // 6 inputs at src, 1 coalesced batch + 1 notification at sum, and
        // sum's single emission delivered to the sink.
        assert_eq!(sys8.stats.events_observed, 6 + 1 + 1 + 1);
        assert_eq!(sys8.stats.records_observed, 6 + 1, "six-record batch at sum, one at sink");

        // Lazy, cap 8: one checkpoint per completion regardless of cap;
        // the log carries one entry per sent batch.
        let (mut lsys, lsrc, _) = epoch_pipeline_with_cap(
            vec![Policy::LogOutputs, Policy::Lazy { every: 1, log_outputs: true }, Policy::Ephemeral],
            8,
        );
        let lsum = lsys.topology().find("sum").unwrap();
        drive_six(&mut lsys, lsrc);
        assert_eq!(lsys.chain_len(lsum), 1);
        // src pushes are separate input events → 6 singleton log entries;
        // sum emits once on completion → 1 entry.
        assert_eq!(lsys.log_len(lsrc), 6);
        assert_eq!(lsys.log_len(lsum), 1);
        let st = lsys.store.stats();
        assert_eq!(st.log_batches, 7, "one acknowledged log write per sent batch");
        assert_eq!(st.log_records, 7);
        assert_eq!(st.log_batches, lsys.stats.log_entries);
        assert_eq!(st.log_records, lsys.stats.log_records);

        // Byte accounting: the durable LogEntry blobs are exactly the
        // encoded batches, byte for byte.
        for sys in [&sys8, &lsys] {
            for p in 0..3u32 {
                let durable: u64 = sys
                    .store
                    .keys_for(p, Kind::LogEntry)
                    .iter()
                    .map(|k| sys.store.get(k).unwrap().len() as u64)
                    .sum();
                let encoded: u64 =
                    sys.ft[p as usize].log.iter().map(|le| le.to_bytes().len() as u64).sum();
                assert_eq!(durable, encoded, "proc {p}: log bytes ≠ encoded batch sizes");
            }
        }
    }

    /// Batching must not change what a lazy checkpoint contains: same
    /// frontier, same (empty) post-completion state, same metadata as the
    /// record-at-a-time run.
    #[test]
    fn lazy_checkpoint_content_is_cap_invariant() {
        let run = |cap: usize| {
            let (mut sys, src, out) = epoch_pipeline_with_cap(
                vec![
                    Policy::Ephemeral,
                    Policy::Lazy { every: 1, log_outputs: false },
                    Policy::Ephemeral,
                ],
                cap,
            );
            let sum = sys.topology().find("sum").unwrap();
            drive_six(&mut sys, src);
            assert_eq!(out.lock().unwrap().len(), 1);
            assert_eq!(sys.chain_len(sum), 1);
            sys.ft[sum.0 as usize].chain[0].clone()
        };
        let base = run(1);
        for cap in [8usize, 64] {
            let ck = run(cap);
            assert_eq!(ck.meta, base.meta, "cap {cap} changed checkpoint metadata");
            assert_eq!(ck.state, base.state, "cap {cap} changed checkpoint state");
        }
    }

    /// Satellite: an oversized checkpoint payload is a recoverable
    /// per-proc FT error — the checkpoint is skipped (deltas intact, the
    /// previous restore point stands), counters tick, and nothing
    /// panics; the system keeps running and a later, smaller checkpoint
    /// still lands.
    #[test]
    fn oversized_checkpoint_is_skipped_not_fatal() {
        let (mut sys, src, out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::Lazy { every: 1, log_outputs: false },
            Policy::Ephemeral,
        ]);
        let sum = sys.topology().find("sum").unwrap();
        // Small enough that the Ξ record (frontiers + maps) is refused.
        sys.store.set_max_value_len(2);
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000); // would have panicked before
        assert_eq!(out.lock().unwrap().len(), 1, "compute is unaffected");
        assert_eq!(sys.chain_len(sum), 0, "refused checkpoint was skipped");
        assert!(sys.stats.storage_errors >= 1);
        assert!(sys.storage_errors(sum) >= 1);
        // Deltas were NOT pruned by the failed attempt: a failure now
        // rolls sum to ∅ and the Table-1 metadata stays coherent.
        sys.inject_failures(&[sum]);
        let rep = sys.recover();
        assert!(rep.plan.f[sum.0 as usize].is_bottom());
    }

    /// An unloggable (oversized) send degrades to D̄ and freezes the
    /// source's input-frontier marker: the marker must never certify an
    /// event whose send is missing from the durable log.
    #[test]
    fn oversized_log_entry_degrades_to_discard_and_freezes_marker() {
        let (mut sys, src, out) = epoch_pipeline(vec![
            Policy::LogOutputs,
            Policy::Ephemeral,
            Policy::Ephemeral,
        ]);
        sys.store.set_max_value_len(2);
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        assert_eq!(out.lock().unwrap().len(), 1);
        assert_eq!(sys.log_len(src), 0, "the refused entry is not in the log mirror");
        assert!(sys.stats.storage_errors >= 1);
        let ft = &sys.ft[src.0 as usize];
        assert!(ft.persist_gap);
        assert!(ft.input_mark.is_bottom(), "marker frozen at the gap");
        assert!(
            !ft.discarded_new.is_empty() || !ft.chain.is_empty(),
            "the send is tracked in D̄ instead"
        );
    }

    /// The §4.2 monitor learns of a checkpoint only after its Ξ write is
    /// acknowledged: with the writer paused the staged checkpoint is
    /// invisible (low-watermark stays ∅ — GC can never outrun durable
    /// state), and the flush makes it visible.
    #[test]
    fn pump_monitor_gates_on_ack_watermark() {
        use crate::ft::storage::PersistMode;
        let (mut sys, src, _out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::Lazy { every: 1, log_outputs: false },
            Policy::Ephemeral,
        ]);
        sys.store.set_persist_mode(PersistMode::Async { ack_every: 4 });
        let sum = sys.topology().find("sum").unwrap();
        let mut mon = crate::ft::monitor::Monitor::new(
            sys.topo.clone(),
            vec![true, false, true],
            vec![false, false, false],
        );
        sys.store.pause_persistence();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        assert_eq!(sys.chain_len(sum), 1, "checkpoint staged in the mirror");
        assert!(sys.stats.ack_lag > 0, "the staged writes are unacked");
        let acts = sys.pump_monitor(&mut mon);
        assert!(acts.is_empty());
        assert!(
            mon.low_watermark(sum).is_bottom(),
            "unacked checkpoint must not advance the GC watermark"
        );
        sys.store.resume_persistence();
        sys.store.flush_staged();
        sys.pump_monitor(&mut mon);
        assert_eq!(
            mon.low_watermark(sum),
            &Frontier::upto_epoch(0),
            "acked checkpoint advances the watermark"
        );
        // Idempotent: nothing new to report.
        assert!(sys.pump_monitor(&mut mon).is_empty());
    }

    /// Clean-run equivalence at the harness level: async staging changes
    /// *when* blobs land, never *what* lands — after a flush the durable
    /// image is byte-identical to the sync run's.
    #[test]
    fn async_staging_persists_the_same_blobs_as_sync() {
        use crate::ft::storage::PersistMode;
        let drive = |mode: Option<PersistMode>| {
            let (mut sys, src, _out) = epoch_pipeline(vec![
                Policy::LogOutputs,
                Policy::Lazy { every: 1, log_outputs: true },
                Policy::Ephemeral,
            ]);
            if let Some(m) = mode {
                sys.store.set_persist_mode(m);
            }
            drive_six(&mut sys, src);
            sys.store.flush_staged();
            let mut image: Vec<(Key, Vec<u8>)> = Vec::new();
            for p in 0..3u32 {
                for k in sys.store.scan_keys(p) {
                    let v = sys.store.get(&k).unwrap();
                    image.push((k, v));
                }
            }
            image
        };
        let sync_img = drive(None);
        assert!(!sync_img.is_empty());
        for ack_every in [1usize, 8, 64] {
            let async_img = drive(Some(PersistMode::Async { ack_every }));
            assert_eq!(sync_img, async_img, "ack_every {ack_every} changed the durable image");
        }
    }

    /// Tentpole: under `SnapshotPolicy::Delta` checkpoints chain via
    /// `prior_snapshot` against the last acked base, every `max_chain`-th
    /// one is forced full, every chain entry materializes byte-identical
    /// to the in-memory mirror, and GC's reachability sweep keeps a
    /// retained delta's base record alive past its own chain entry's
    /// death.
    #[test]
    fn delta_checkpoints_chain_and_survive_gc() {
        // src(LogOutputs) → buffer(Lazy): Buffer retains everything, so
        // selective checkpoints are non-empty and strictly growing — the
        // shape delta chains exist for. Buffer requests no
        // notifications, so checkpoints are driven explicitly.
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let buf = g.add_proc("buffer", TimeDomain::EPOCH);
        g.connect(src, buf, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(crate::operators::Buffer::default()),
        ];
        let mut sys = FtSystem::new(
            topo,
            procs,
            vec![Policy::LogOutputs, Policy::Lazy { every: 1_000_000, log_outputs: false }],
            Delivery::Fifo,
            Store::new(1),
        );
        sys.set_snapshot_policy(SnapshotPolicy::Delta { max_chain: 3 });
        let buf = ProcId(1);
        for ep in 0..6u64 {
            sys.advance_input(src, Time::epoch(ep));
            for v in 0..30i64 {
                sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 * 100 + v));
            }
            sys.advance_input(src, Time::epoch(ep + 1));
            sys.run_to_quiescence(10_000);
            sys.checkpoint_now(buf, Frontier::upto_epoch(ep));
        }
        assert_eq!(sys.chain_len(buf), 6);
        let ft = &sys.ft[buf.0 as usize];
        assert!(
            ft.chain.iter().all(|c| !c.state.is_empty()),
            "buffer checkpoints must carry real state"
        );
        let walks: Vec<u64> =
            ft.chain_tags.iter().map(|ts| ft.snapshot_walk_len(ts.tag)).collect();
        assert_eq!(walks, vec![1, 2, 3, 1, 2, 3], "forced full every max_chain-th checkpoint");
        // Every chain entry materializes byte-identically to its mirror.
        for (ck, ts) in ft.chain.iter().zip(&ft.chain_tags) {
            assert_eq!(
                sys.store.materialize_snapshot(buf.0, ts.tag).as_ref(),
                Some(&ck.state),
                "chain materialization diverged from the in-memory mirror"
            );
        }
        // GC below epoch 4: chain entries 0..4 drop, but the survivors
        // (walks 2 and 3 of the second chain) still reach the
        // forced-full base at index 3 — its snapshot record must
        // survive its own chain entry.
        let kept_base_tag = sys.ft[buf.0 as usize].chain_tags[3].tag;
        let act = crate::ft::monitor::GcAction::DropCheckpointsBelow {
            proc: buf,
            watermark: Frontier::upto_epoch(4),
        };
        let released = sys.apply_gc(&act);
        assert!(released >= 2, "old Ξ records and unreachable snapshots released");
        sys.store.flush_staged();
        let ft = &sys.ft[buf.0 as usize];
        assert_eq!(ft.chain.len(), 2);
        assert!(
            ft.snapshots.contains_key(&kept_base_tag),
            "a retained delta's base snapshot record outlives its chain entry"
        );
        assert!(
            sys.store
                .get(&Key { proc: buf.0, kind: Kind::Snapshot, tag: kept_base_tag })
                .is_some(),
            "base snapshot record still durable"
        );
        // And both survivors still materialize.
        for (ck, ts) in ft.chain.iter().zip(&ft.chain_tags) {
            assert_eq!(sys.store.materialize_snapshot(buf.0, ts.tag).as_ref(), Some(&ck.state));
        }
        // Failure + recovery after GC restores from the delta chain.
        sys.inject_failures(&[buf]);
        let rep = sys.recover();
        assert!(rep.restored_from_checkpoint >= 1);
        let blob = sys.engine.proc(buf).checkpoint_upto(&Frontier::Top);
        let mut b = crate::operators::Buffer::default();
        b.restore(&blob);
        assert_eq!(b.contents().len(), 6, "all six epochs restored from the delta chain");
    }

    /// A snapshot policy switch affects new checkpoints only, and
    /// delta-vs-full representation never changes what recovery restores.
    #[test]
    fn snapshot_policy_is_representation_only() {
        let run = |policy: SnapshotPolicy| {
            let (mut sys, src, out) = epoch_pipeline(vec![
                Policy::LogOutputs,
                Policy::Lazy { every: 1, log_outputs: true },
                Policy::Ephemeral,
            ]);
            sys.set_snapshot_policy(policy);
            let sum = sys.topology().find("sum").unwrap();
            for ep in 0..4u64 {
                sys.advance_input(src, Time::epoch(ep));
                sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 + 1));
                sys.advance_input(src, Time::epoch(ep + 1));
                sys.run_to_quiescence(1000);
                if ep == 2 {
                    sys.inject_failures(&[sum]);
                    sys.recover();
                }
            }
            sys.close_input(src);
            sys.run_to_quiescence(1000);
            out.lock().unwrap().clone()
        };
        let full = run(SnapshotPolicy::Full);
        assert!(!full.is_empty());
        for max_chain in [1u64, 2, 8] {
            assert_eq!(
                full,
                run(SnapshotPolicy::Delta { max_chain }),
                "Delta{{max_chain: {max_chain}}} changed recovered output"
            );
        }
    }

    #[test]
    fn live_top_meta_reflects_cumulative_state() {
        let (mut sys, src, _out) = epoch_pipeline(vec![
            Policy::Ephemeral,
            Policy::Lazy { every: 10, log_outputs: false },
            Policy::Ephemeral,
        ]);
        let sum = sys.topology().find("sum").unwrap();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(2));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        // No checkpoint yet (every: 10) — live ⊤ meta carries the deltas.
        assert_eq!(sys.chain_len(sum), 0);
        let top = sys.live_top_meta(sum);
        assert!(top.f.is_top());
        assert_eq!(top.m_bar.get(&EdgeId(0)).unwrap(), &Frontier::upto_epoch(0));
        assert_eq!(top.n_bar, Frontier::upto_epoch(0));
        assert_eq!(top.d_bar.get(&EdgeId(1)).unwrap(), &Frontier::upto_epoch(0));
    }
}
