//! On-disk segmented write-ahead-log backend for the durable store.
//!
//! # Layout
//!
//! A backend directory holds numbered segment files `wal-000001.seg`,
//! `wal-000002.seg`, … Each segment begins with an 8-byte magic
//! (`FKWAL001`) and then a sequence of self-delimiting records:
//!
//! ```text
//! ┌────────────┬────────────┬─────────────────────────────┐
//! │ len: u32LE │ crc: u64LE │ payload (len bytes)         │
//! └────────────┴────────────┴─────────────────────────────┘
//! payload := op:u8 (0 = put, 1 = tombstone, 2 = fold)
//!   op 0/1:  proc:varint  kind:u8  tag:varint
//!            value:length-prefixed bytes   (put only)
//!   op 2:    proc:varint  count:varint
//!            count × { kind:u8  tag:varint  value:length-prefixed }
//! ```
//!
//! A *fold* record (op 2) is a compaction artifact: one processor's live
//! records folded into a single materialized multi-entry put. Replaying
//! a fold is identical to replaying its entries as individual puts in
//! order; the index addresses each entry as (record location, sub-entry
//! index), and each entry owes the segment its own byte span (entry 0
//! additionally carries the record header and fold prelude), so
//! per-entry supersede/delete accounting keeps working.
//!
//! `crc` is FNV-1a over the payload ([`crate::util::hash::fnv1a`] — the
//! crate's one byte hash). The log is strictly append-only: an overwrite
//! appends a new put record, a delete appends a tombstone; the superseded
//! record's bytes become *dead* and are reclaimed by compaction.
//!
//! # Group commit
//!
//! Appends accumulate in an in-memory tail and reach the file every
//! [`FileBackendOptions::flush_every_n`] records (or on [`sync`], read of
//! a buffered record, rotation, drop). Because the tail flushes in append
//! order, a crash loses only a *suffix* of recent writes — a surviving
//! record implies every earlier record survived. The FT layer leans on
//! exactly this prefix property: state is written before its Ξ, log
//! entries before the input-frontier marker that certifies them, so a
//! truncated tail can only make recovery more conservative, never
//! inconsistent.
//!
//! A flush reaches the OS, not necessarily the platter: with
//! [`FileBackendOptions::fsync`] off, power-loss durability is
//! established by [`StorageBackend::sync`], which fsyncs every segment
//! written since the last sync *and* the directory whose entries changed
//! (segment files created or compacted away) — not just the active tail.
//!
//! # Reopen
//!
//! [`FileBackend::open`] rebuilds the in-memory `Key → (segment, offset)`
//! index by scanning every segment in order, replaying puts and
//! tombstones. A torn or corrupt *tail* (bad length, bad checksum,
//! undecodable payload in the final segment) is truncated and the open
//! succeeds — those records were never acknowledged-durable under the
//! crash model. Corruption in the *middle* of the log (a non-final
//! segment) is reported as an error: it means lost acknowledged state,
//! which must not be silently dropped.
//!
//! # Compaction
//!
//! Tombstones and overwrites leave dead bytes behind. After deletes (and
//! under explicit [`StorageBackend::compact`]) any *sealed* segment whose
//! dead fraction exceeds [`FileBackendOptions::compact_ratio`] is
//! rewritten: its live records move to the active segment and the file
//! is removed. The monitor's §4.2 GC actions therefore turn into
//! tombstones at the [`crate::ft::harness::FtSystem::apply_gc`] layer and
//! into reclaimed disk space here.
//!
//! The move *folds*: instead of re-appending one put per live key, the
//! victims' survivors are grouped per processor and written as op-2 fold
//! records — the cold WAL prefix of a processor collapses into a few
//! materialized snapshot-of-the-index records, so a cold-restart scan
//! decodes O(live state) with one record header per processor-batch
//! rather than one per historical put. Entries within a processor's fold
//! are ordered dependencies-first (state and chunks before snapshot
//! records, the Ξ metadata record strictly last), mirroring the FT
//! layer's write order: should a fold's tail ever be lost, no Ξ can
//! survive an entry it certifies. Folds split at roughly the segment
//! size; a batch of one falls back to a plain put record.
//!
//! Tombstones need care: a tombstone in a compacted segment may be the
//! only thing shadowing a superseded put in an *earlier, surviving*
//! segment — dropping it would resurrect the deleted key on the next
//! replay scan. The backend therefore tracks each deleted key's newest
//! tombstone and, when that tombstone's segment is compacted, re-appends
//! the tombstone to the active segment; it is elided only when its
//! segment is the oldest in existence (nothing it could shadow precedes
//! it), which is also what keeps tombstones from accumulating forever.
//! Before unlinking victims, compaction fsyncs the segments it wrote to
//! and the victims themselves (plus the directory) regardless of
//! `opts.fsync`, so state that was power-loss durable never silently
//! stops being so — and a power-lost unlink, which resurrects a file at
//! its last-fsynced length, can only bring back a victim whole.

use crate::ft::storage::{proc_range, BackendInfo, Key, Kind, StorageBackend, StorageError};
use crate::util::hash::fnv1a;
use crate::util::ser::{Reader, Writer};
use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write as IoWrite};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"FKWAL001";
const REC_HEADER: u64 = 4 + 8;
/// Upper bound on one record's payload — anything larger in a length
/// field is treated as corruption.
const MAX_PAYLOAD: u64 = 1 << 26;

/// Tuning knobs of the WAL backend.
#[derive(Clone, Copy, Debug)]
pub struct FileBackendOptions {
    /// Group-commit width: buffered records are written out once this
    /// many accumulate. 1 = write-through per record.
    pub flush_every_n: usize,
    /// Rotate to a fresh segment once the active one reaches this size.
    pub segment_bytes: u64,
    /// Compact a sealed segment once dead bytes exceed this fraction of
    /// its length.
    pub compact_ratio: f64,
    /// `fsync` each flush (off by default: the tests and benches exercise
    /// ordering, not disk hardware).
    pub fsync: bool,
}

impl Default for FileBackendOptions {
    fn default() -> Self {
        FileBackendOptions {
            flush_every_n: 8,
            segment_bytes: 1 << 20,
            compact_ratio: 0.5,
            fsync: false,
        }
    }
}

/// Sub-entry index marking a plain (non-fold) record.
const NO_SUB: u32 = u32::MAX;

/// Where a live record lives.
#[derive(Clone, Copy, Debug)]
struct Loc {
    seg: u64,
    /// Offset of the record header within the segment file.
    off: u64,
    /// Full record length (header + payload).
    len: u64,
    /// The byte share this entry owes its segment when it dies: `len`
    /// for plain records; for a fold entry, its own payload span (entry
    /// 0 also carries the record header and fold prelude). Costs of one
    /// record's entries sum to exactly `len`, so dead-byte accounting
    /// stays exact however a fold's entries die.
    cost: u64,
    /// Length of the stored value (for resident-byte accounting).
    value_len: u64,
    /// Entry index within a fold record; `NO_SUB` for plain records.
    sub: u32,
}

#[derive(Clone, Copy, Debug, Default)]
struct SegState {
    /// Bytes durably in the file (for the active segment the buffered
    /// tail comes on top).
    flushed_len: u64,
    /// Bytes owed to superseded records and tombstones.
    dead_bytes: u64,
}

/// The segmented-WAL storage backend. See module docs.
pub struct FileBackend {
    dir: PathBuf,
    opts: FileBackendOptions,
    index: BTreeMap<Key, Loc>,
    /// Newest tombstone per deleted key (disjoint from `index`). Needed
    /// by compaction: a tombstone in a dying segment still shadows puts
    /// in earlier surviving segments and must be carried forward.
    tombs: BTreeMap<Key, Loc>,
    segs: BTreeMap<u64, SegState>,
    /// Segment new appends go to (its file may not exist yet).
    active: u64,
    /// Unflushed tail of the active segment.
    buf: Vec<u8>,
    buffered_records: usize,
    /// Append handle for the active segment (lazily opened).
    writer: Option<File>,
    /// Segments appended to without an fsync since the last [`sync`]
    /// (only populated when `opts.fsync` is off).
    dirty_segs: BTreeSet<u64>,
    /// Segment files created or removed since the last directory fsync.
    dir_dirty: bool,
    /// Read handles, per segment.
    readers: BTreeMap<u64, File>,
    live_value_bytes: u64,
    compactions: u64,
    /// Fold records written by compaction plus those replayed on open.
    folds: u64,
    /// Bytes dropped from a torn tail during open.
    tail_truncated: u64,
    /// Guards against compaction re-entering itself through the rotations
    /// its own moves can trigger.
    in_compaction: bool,
    /// Opened via [`FileBackend::open_read_only`]: mutating operations
    /// panic and open performed no on-disk repair.
    read_only: bool,
    crashed: bool,
    /// Capture-gated structured tracer ([`crate::trace`]): segment
    /// rotations and compactions record "wal" events through it.
    tracer: Option<crate::trace::Tracer>,
}

fn seg_name(id: u64) -> String {
    format!("wal-{id:06}.seg")
}

fn parse_seg_name(name: &str) -> Option<u64> {
    name.strip_prefix("wal-")?.strip_suffix(".seg")?.parse().ok()
}

fn encode_payload(op: u8, key: &Key, value: Option<&[u8]>) -> Vec<u8> {
    let mut w = Writer::with_capacity(16 + value.map(|v| v.len()).unwrap_or(0));
    w.u8(op);
    w.varint(key.proc as u64);
    w.u8(key.kind.code());
    w.varint(key.tag);
    if let Some(v) = value {
        w.bytes(v);
    }
    w.into_bytes()
}

/// One decoded entry of a fold record.
struct FoldEntry {
    kind: Kind,
    tag: u64,
    value: Vec<u8>,
    /// The entry's byte share of the record (see [`Loc::cost`]).
    cost: u64,
}

/// A decoded record payload.
enum Payload {
    Put(Key, Vec<u8>),
    Tomb(Key),
    /// A compaction fold: many entries of one processor in one record.
    Fold(u32, Vec<FoldEntry>),
}

/// Encode a fold record's payload. Returns the payload and each entry's
/// byte cost; both sides measure actual encoded spans, so a reopen's
/// [`decode_payload`] rebuilds byte-identical accounting.
fn encode_fold(proc: u32, entries: &[(Key, Vec<u8>)]) -> (Vec<u8>, Vec<u64>) {
    let total: usize = entries.iter().map(|(_, v)| v.len() + 16).sum();
    let mut w = Writer::with_capacity(16 + total);
    w.u8(2);
    w.varint(proc as u64);
    w.varint(entries.len() as u64);
    let prelude = w.len() as u64;
    let mut costs = Vec::with_capacity(entries.len());
    for (key, value) in entries {
        debug_assert_eq!(key.proc, proc, "a fold holds one processor's records");
        let before = w.len() as u64;
        w.u8(key.kind.code());
        w.varint(key.tag);
        w.bytes(value);
        costs.push(w.len() as u64 - before);
    }
    costs[0] += REC_HEADER + prelude;
    (w.into_bytes(), costs)
}

/// Decode a record payload. `None` means corruption.
fn decode_payload(payload: &[u8]) -> Option<Payload> {
    let mut r = Reader::new(payload);
    let op = r.u8().ok()?;
    match op {
        0 | 1 => {
            let proc = r.varint().ok()?;
            if proc > u32::MAX as u64 {
                return None;
            }
            let kind = Kind::from_code(r.u8().ok()?)?;
            let tag = r.varint().ok()?;
            let key = Key { proc: proc as u32, kind, tag };
            if op == 0 {
                let v = r.bytes().ok()?.to_vec();
                if !r.is_empty() {
                    return None;
                }
                Some(Payload::Put(key, v))
            } else {
                if !r.is_empty() {
                    return None;
                }
                Some(Payload::Tomb(key))
            }
        }
        2 => {
            let proc = r.varint().ok()?;
            if proc > u32::MAX as u64 {
                return None;
            }
            let count = r.varint().ok()?;
            // Each entry takes at least 3 payload bytes; an impossible
            // count is corruption, not an allocation request.
            if count == 0 || count > payload.len() as u64 {
                return None;
            }
            let prelude = (payload.len() - r.remaining()) as u64;
            let mut entries = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let before = r.remaining();
                let kind = Kind::from_code(r.u8().ok()?)?;
                let tag = r.varint().ok()?;
                let value = r.bytes().ok()?.to_vec();
                let cost = (before - r.remaining()) as u64;
                entries.push(FoldEntry { kind, tag, value, cost });
            }
            if !r.is_empty() {
                return None;
            }
            entries[0].cost += REC_HEADER + prelude;
            Some(Payload::Fold(proc as u32, entries))
        }
        _ => None,
    }
}

impl FileBackend {
    /// Open (or create) a WAL under `dir`, rebuilding the key index by
    /// scanning the segments. A corrupt tail of the final segment is
    /// truncated (repaired on disk); corruption elsewhere is an error.
    pub fn open(dir: &Path, opts: FileBackendOptions) -> io::Result<FileBackend> {
        FileBackend::open_impl(dir, opts, true)
    }

    /// Open for inspection only: the index is rebuilt, but nothing on
    /// disk is repaired (no tail truncation, no bad-segment removal) and
    /// every mutating operation panics — examining a just-crashed WAL
    /// must not destroy its torn tail.
    pub fn open_read_only(dir: &Path, opts: FileBackendOptions) -> io::Result<FileBackend> {
        FileBackend::open_impl(dir, opts, false)
    }

    fn open_impl(dir: &Path, opts: FileBackendOptions, repair: bool) -> io::Result<FileBackend> {
        assert!(opts.flush_every_n >= 1, "flush_every_n must be at least 1");
        if repair {
            std::fs::create_dir_all(dir)?;
        } else if !dir.is_dir() {
            // Inspection of a mistyped path must not conjure an empty WAL.
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("no WAL directory at {}", dir.display()),
            ));
        }
        let mut ids: Vec<u64> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| parse_seg_name(&e.file_name().to_string_lossy()))
            .collect();
        ids.sort_unstable();

        let mut b = FileBackend {
            dir: dir.to_path_buf(),
            opts,
            index: BTreeMap::new(),
            tombs: BTreeMap::new(),
            segs: BTreeMap::new(),
            active: ids.last().copied().unwrap_or(0) + 1,
            buf: Vec::new(),
            buffered_records: 0,
            writer: None,
            dirty_segs: BTreeSet::new(),
            dir_dirty: false,
            readers: BTreeMap::new(),
            live_value_bytes: 0,
            compactions: 0,
            folds: 0,
            tail_truncated: 0,
            in_compaction: false,
            read_only: !repair,
            crashed: false,
            tracer: None,
        };

        for (i, &id) in ids.iter().enumerate() {
            let last = i + 1 == ids.len();
            b.scan_segment(id, last, repair)?;
        }
        // Segments inherited from a previous process instance may have
        // been flushed but never fsynced (and their directory entries
        // never made durable) — the first sync() must cover them, so
        // they start out dirty.
        b.dirty_segs = b.segs.keys().copied().collect();
        b.dir_dirty = !ids.is_empty();
        // Continue appending to the final segment if it has room,
        // otherwise start a fresh one (lazily — inspection of an existing
        // directory must not write).
        if let Some((&last, st)) = b.segs.iter().next_back() {
            if st.flushed_len < b.opts.segment_bytes {
                b.active = last;
            } else {
                b.active = last + 1;
            }
        } else {
            b.active = 1;
        }
        Ok(b)
    }

    /// Scan one segment into the index. A corrupt tail of the `last`
    /// segment is tolerated — and truncated on disk when `repair` is set;
    /// earlier segments must be fully valid.
    fn scan_segment(&mut self, id: u64, last: bool, repair: bool) -> io::Result<()> {
        let path = self.dir.join(seg_name(id));
        let data = std::fs::read(&path)?;
        let corrupt = |what: &str| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "segment {}: {what} (not the final segment — acknowledged state lost)",
                    seg_name(id)
                ),
            )
        };
        if data.len() < MAGIC.len() || &data[..MAGIC.len()] != MAGIC {
            if last {
                // Nothing decodable was ever acknowledged from this file.
                self.tail_truncated += data.len() as u64;
                if repair {
                    std::fs::remove_file(&path)?;
                }
                return Ok(());
            }
            return Err(corrupt("bad segment magic"));
        }
        let mut off = MAGIC.len() as u64;
        let total = data.len() as u64;
        let mut good = off;
        loop {
            if off == total {
                break; // clean end
            }
            let valid = (|| {
                if total - off < REC_HEADER {
                    return None;
                }
                let hdr = &data[off as usize..(off + REC_HEADER) as usize];
                let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as u64;
                let crc = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
                if len > MAX_PAYLOAD || off + REC_HEADER + len > total {
                    return None;
                }
                let payload =
                    &data[(off + REC_HEADER) as usize..(off + REC_HEADER + len) as usize];
                if fnv1a(payload) != crc {
                    return None;
                }
                decode_payload(payload).map(|p| (p, REC_HEADER + len))
            })();
            let Some((decoded, rec_len)) = valid else {
                if last {
                    // Torn/corrupt tail: drop the unacknowledged suffix.
                    self.tail_truncated += total - good;
                    if repair {
                        let f = OpenOptions::new().write(true).open(&path)?;
                        f.set_len(good)?;
                    }
                    self.segs.entry(id).or_default().flushed_len = good;
                    return Ok(());
                }
                return Err(corrupt("corrupt record"));
            };
            match decoded {
                Payload::Put(key, value) => {
                    let value_len = value.len() as u64;
                    let loc =
                        Loc { seg: id, off, len: rec_len, cost: rec_len, value_len, sub: NO_SUB };
                    self.tombs.remove(&key);
                    if let Some(old) = self.index.insert(key, loc) {
                        self.mark_dead(old);
                    }
                    self.live_value_bytes += value_len;
                }
                Payload::Tomb(key) => {
                    if let Some(old) = self.index.remove(&key) {
                        self.mark_dead(old);
                    }
                    // The tombstone itself is dead weight too, but stays
                    // tracked: compaction must not drop it while older
                    // segments could still hold the puts it shadows.
                    self.segs.entry(id).or_default().dead_bytes += rec_len;
                    self.tombs.insert(
                        key,
                        Loc { seg: id, off, len: rec_len, cost: rec_len, value_len: 0, sub: NO_SUB },
                    );
                }
                Payload::Fold(proc, entries) => {
                    // Replay each entry exactly as if it were its own put
                    // record at this location.
                    for (i, e) in entries.into_iter().enumerate() {
                        let key = Key { proc, kind: e.kind, tag: e.tag };
                        let value_len = e.value.len() as u64;
                        let loc = Loc {
                            seg: id,
                            off,
                            len: rec_len,
                            cost: e.cost,
                            value_len,
                            sub: i as u32,
                        };
                        self.tombs.remove(&key);
                        if let Some(old) = self.index.insert(key, loc) {
                            self.mark_dead(old);
                        }
                        self.live_value_bytes += value_len;
                    }
                    self.folds += 1;
                }
            }
            off += rec_len;
            good = off;
        }
        self.segs.entry(id).or_default().flushed_len = total;
        Ok(())
    }

    fn mark_dead(&mut self, old: Loc) {
        self.segs.entry(old.seg).or_default().dead_bytes += old.cost;
        self.live_value_bytes -= old.value_len;
    }

    fn active_len(&self) -> u64 {
        self.segs.get(&self.active).map(|s| s.flushed_len).unwrap_or(0) + self.buf.len() as u64
    }

    /// Append one record to the active segment (buffered; creates the
    /// segment header on first use). Returns the record's location.
    fn append_record(&mut self, payload: Vec<u8>, value_len: u64) -> Loc {
        assert!(!self.crashed, "FileBackend used after simulated crash");
        assert!(!self.read_only, "FileBackend opened read-only (inspection)");
        // Oversized puts are refused fallibly in `put` before reaching
        // here; tombstones and compaction re-appends are always within
        // bounds, so this is an internal invariant.
        debug_assert!(
            payload.len() as u64 <= MAX_PAYLOAD,
            "WAL record payload of {} bytes exceeds the {MAX_PAYLOAD}-byte limit",
            payload.len()
        );
        if !self.segs.contains_key(&self.active) {
            // Fresh segment: the header rides the buffer like any write.
            self.segs.insert(self.active, SegState::default());
            self.buf.extend_from_slice(MAGIC);
        }
        let off = self.active_len();
        let len = REC_HEADER + payload.len() as u64;
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self.buffered_records += 1;
        let loc = Loc { seg: self.active, off, len, cost: len, value_len, sub: NO_SUB };
        if self.buffered_records >= self.opts.flush_every_n {
            self.flush();
        }
        if self.active_len() >= self.opts.segment_bytes {
            self.rotate();
        }
        loc
    }

    /// Write the buffered tail to the active segment file.
    fn flush(&mut self) {
        if self.buf.is_empty() || self.crashed {
            self.buf.clear();
            self.buffered_records = 0;
            return;
        }
        if self.writer.is_none() {
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(self.dir.join(seg_name(self.active)))
                .expect("opening WAL segment for append");
            self.writer = Some(f);
            // The file may have just been created: its directory entry
            // needs an fsync of the directory before the segment's
            // contents can be called power-loss durable.
            if self.opts.fsync {
                self.fsync_dir();
            } else {
                self.dir_dirty = true;
            }
        }
        let w = self.writer.as_mut().unwrap();
        w.write_all(&self.buf).expect("appending to WAL segment");
        if self.opts.fsync {
            w.sync_data().expect("fsync of WAL segment");
        } else {
            self.dirty_segs.insert(self.active);
        }
        self.segs.get_mut(&self.active).expect("active segment state").flushed_len +=
            self.buf.len() as u64;
        self.buf.clear();
        self.buffered_records = 0;
    }

    /// Make segment-file creations/removals durable (fsync the WAL
    /// directory itself).
    fn fsync_dir(&mut self) {
        File::open(&self.dir)
            .and_then(|d| d.sync_all())
            .expect("fsync of WAL directory");
        self.dir_dirty = false;
    }

    /// fsync the given segments (through the live writer for the active
    /// one) and mark them clean. An fsync failure means acknowledged
    /// writes may not be durable — that must not be silent (reopen
    /// treats exactly this as fatal lost-acknowledged-state).
    fn fsync_segs(&mut self, ids: BTreeSet<u64>) {
        for id in ids {
            if !self.segs.contains_key(&id) {
                self.dirty_segs.remove(&id);
                continue;
            }
            if id == self.active && self.writer.is_some() {
                self.writer.as_mut().unwrap().sync_all().expect("fsync of WAL segment");
            } else {
                File::open(self.dir.join(seg_name(id)))
                    .and_then(|f| f.sync_all())
                    .expect("fsync of sealed WAL segment");
            }
            self.dirty_segs.remove(&id);
        }
    }

    /// Seal the active segment and direct future appends at a fresh one.
    /// Deliberately does NOT trigger compaction: rotation happens inside
    /// `append_record`, *before* the caller has updated the index, and
    /// compacting against a stale index could drop a just-written record
    /// or resurrect a superseded one. Compaction runs only from the
    /// post-index-update tails of `put`/`delete` (and explicit
    /// `compact()`).
    fn rotate(&mut self) {
        self.flush();
        self.writer = None;
        self.active += 1;
        if let Some(tr) = &self.tracer {
            tr.instant(0, "wal", "wal_rotate", &[("segment", self.active)]);
        }
    }

    /// Read a record's payload. Flushes first if the record is still in
    /// the buffered tail.
    fn read_payload(&mut self, loc: Loc) -> Vec<u8> {
        if loc.seg == self.active
            && loc.off + loc.len > self.segs.get(&loc.seg).map(|s| s.flushed_len).unwrap_or(0)
        {
            self.flush();
        }
        let f = self.readers.entry(loc.seg).or_insert_with(|| {
            File::open(self.dir.join(seg_name(loc.seg))).expect("opening WAL segment for read")
        });
        f.seek(SeekFrom::Start(loc.off)).expect("seeking WAL segment");
        let mut rec = vec![0u8; loc.len as usize];
        f.read_exact(&mut rec).expect("reading WAL record");
        rec.split_off(REC_HEADER as usize)
    }

    fn read_value(&mut self, loc: Loc) -> Vec<u8> {
        let payload = self.read_payload(loc);
        match decode_payload(&payload) {
            Some(Payload::Put(_, v)) if loc.sub == NO_SUB => v,
            Some(Payload::Fold(_, mut entries)) if (loc.sub as usize) < entries.len() => {
                std::mem::take(&mut entries[loc.sub as usize].value)
            }
            _ => panic!("indexed WAL record failed to decode (index/file out of sync)"),
        }
    }

    /// Rewrite every sealed segment whose dead fraction crossed the
    /// threshold: live records move to the active segment in one pass
    /// over the index (O(live keys) however many segments die), then the
    /// files go away. Reentrancy-guarded: the moves themselves append
    /// and may rotate, which must not recurse into compaction.
    fn maybe_compact(&mut self) {
        if self.in_compaction {
            return;
        }
        let victims: std::collections::BTreeSet<u64> = self
            .segs
            .iter()
            .filter(|(&id, st)| {
                id != self.active
                    && st.flushed_len > MAGIC.len() as u64
                    && (st.dead_bytes as f64) >= self.opts.compact_ratio * (st.flushed_len as f64)
            })
            .map(|(&id, _)| id)
            .collect();
        if victims.is_empty() {
            return;
        }
        self.in_compaction = true;
        // A victim tombstone is elided only when its segment is the
        // OLDEST in existence — victims included. Anything above that is
        // carried to the active segment: a put it shadows may live in an
        // older surviving segment, and even an older co-victim is not
        // safe to rely on, because unlink durability is not ordered
        // across power loss (a resurrected older victim file must still
        // find the tombstone that deletes its records).
        let min_seg = self.segs.keys().next().copied();
        // Segments that receive records during this compaction: the
        // durability barrier below fsyncs exactly these plus the
        // victims, not every dirty segment in the store.
        let mut touched: BTreeSet<u64> = BTreeSet::new();
        // Live records move per processor as fold records (op 2): the
        // victims' cold prefix collapses into a few materialized records
        // instead of one put per key. Within a processor the entries go
        // dependencies-first (see [`fold_rank`]), mirroring the FT
        // layer's write order. The old records' accounting dies with
        // their segments below.
        let mut by_proc: BTreeMap<u32, Vec<Key>> = BTreeMap::new();
        for (key, loc) in self.index.iter().filter(|(_, loc)| victims.contains(&loc.seg)) {
            by_proc.entry(key.proc).or_default().push(key.clone());
        }
        // Source records are decoded once: co-folded entries of a dying
        // fold share one read instead of one per entry.
        let mut unfolded: BTreeMap<(u64, u64), BTreeMap<u32, Vec<u8>>> = BTreeMap::new();
        // Splitting folds near the segment size keeps rotation
        // meaningful (and stays far inside MAX_PAYLOAD).
        let fold_cap = self.opts.segment_bytes.clamp(1024, MAX_PAYLOAD - 64);
        for (proc, mut keys) in by_proc {
            keys.sort_by_key(|key| (fold_rank(key.kind), key.tag));
            let mut batch: Vec<(Key, Vec<u8>)> = Vec::new();
            let mut batch_bytes = 0u64;
            for key in keys {
                let loc = self.index[&key];
                let value = self.moved_value(loc, &mut unfolded);
                let entry_bytes = value.len() as u64 + 16;
                if !batch.is_empty() && batch_bytes + entry_bytes > fold_cap {
                    let full = std::mem::take(&mut batch);
                    self.emit_fold(proc, full, &mut touched);
                    batch_bytes = 0;
                }
                batch_bytes += entry_bytes;
                batch.push((key, value));
            }
            if !batch.is_empty() {
                self.emit_fold(proc, batch, &mut touched);
            }
        }
        let victim_tombs: Vec<(Key, Loc)> = self
            .tombs
            .iter()
            .filter(|(_, loc)| victims.contains(&loc.seg))
            .map(|(k, loc)| (k.clone(), *loc))
            .collect();
        for (key, loc) in victim_tombs {
            debug_assert!(!self.index.contains_key(&key), "tombstoned key cannot be live");
            if min_seg.map_or(false, |m| m < loc.seg) {
                // A segment older than this tombstone may hold a put for
                // the key: move the tombstone to the active segment so a
                // replay scan still sees the delete.
                let new_loc = self.append_record(encode_payload(1, &key, None), 0);
                touched.insert(new_loc.seg);
                self.segs.entry(new_loc.seg).or_default().dead_bytes += new_loc.len;
                self.tombs.insert(key, new_loc);
            } else {
                // Nothing older than this tombstone exists anywhere: any
                // put it shadowed is in its own segment, and the barrier
                // below fsyncs that victim before the unlink, so the two
                // die — or resurrect — strictly together.
                self.tombs.remove(&key);
            }
        }
        // Durability barrier before any unlink, regardless of
        // `opts.fsync`. Two obligations: (1) the moved records and
        // carried tombstones must be POWER-LOSS durable — not merely in
        // the page cache — before their only other copy disappears, or a
        // compaction after a sync() silently un-durables acknowledged
        // state; (2) the victims' own unfsynced tails, because a
        // power-lost unlink resurrects a file at its last-fsynced
        // length, and an elided tombstone must still be inside the file
        // that holds the put it shadows. Only those segments (plus the
        // directory) are fsynced — unrelated dirty segments lose nothing
        // when a victim is unlinked and wait for the next sync().
        self.flush();
        let to_sync: BTreeSet<u64> = victims
            .iter()
            .chain(touched.iter())
            .copied()
            .filter(|id| self.dirty_segs.contains(id))
            .collect();
        self.fsync_segs(to_sync);
        if self.dir_dirty {
            self.fsync_dir();
        }
        let reclaimed: u64 = victims.iter().filter_map(|id| self.segs.get(id)).map(|s| s.flushed_len).sum();
        let n_victims = victims.len() as u64;
        for id in victims {
            self.segs.remove(&id);
            self.dirty_segs.remove(&id);
            self.readers.remove(&id);
            let _ = std::fs::remove_file(self.dir.join(seg_name(id)));
            self.compactions += 1;
        }
        if let Some(tr) = &self.tracer {
            tr.instant(0, "wal", "wal_compact", &[("segments", n_victims), ("bytes", reclaimed)]);
        }
        // The removals changed the directory; power-loss durability of
        // the new shape is re-established on the next fsync.
        if self.opts.fsync {
            self.fsync_dir();
        } else {
            self.dir_dirty = true;
        }
        self.in_compaction = false;
    }

    /// Read a record that compaction is about to move. Fold sources are
    /// decoded once; their remaining entries park in `unfolded` until
    /// their own turn comes.
    fn moved_value(
        &mut self,
        loc: Loc,
        unfolded: &mut BTreeMap<(u64, u64), BTreeMap<u32, Vec<u8>>>,
    ) -> Vec<u8> {
        if loc.sub == NO_SUB {
            return self.read_value(loc);
        }
        if let Some(vals) = unfolded.get_mut(&(loc.seg, loc.off)) {
            return vals.remove(&loc.sub).expect("fold entry moved twice");
        }
        let payload = self.read_payload(loc);
        let Some(Payload::Fold(_, entries)) = decode_payload(&payload) else {
            panic!("indexed WAL fold record failed to decode (index/file out of sync)");
        };
        let mut vals: BTreeMap<u32, Vec<u8>> =
            entries.into_iter().enumerate().map(|(i, e)| (i as u32, e.value)).collect();
        let v = vals.remove(&loc.sub).expect("fold sub-entry within range");
        unfolded.insert((loc.seg, loc.off), vals);
        v
    }

    /// Append one processor's batch of moved records: a fold record for
    /// two or more entries, a plain put for a batch of one. Updates the
    /// index and reports the segments written to.
    fn emit_fold(&mut self, proc: u32, batch: Vec<(Key, Vec<u8>)>, touched: &mut BTreeSet<u64>) {
        if batch.len() == 1 {
            let (key, value) = batch.into_iter().next().unwrap();
            let new_loc =
                self.append_record(encode_payload(0, &key, Some(&value)), value.len() as u64);
            touched.insert(new_loc.seg);
            self.index.insert(key, new_loc);
            return;
        }
        let (payload, costs) = encode_fold(proc, &batch);
        let base = self.append_record(payload, 0);
        touched.insert(base.seg);
        self.folds += 1;
        for (i, (key, value)) in batch.into_iter().enumerate() {
            let loc = Loc {
                seg: base.seg,
                off: base.off,
                len: base.len,
                cost: costs[i],
                value_len: value.len() as u64,
                sub: i as u32,
            };
            self.index.insert(key, loc);
        }
    }

    /// Bytes dropped from a torn tail when this backend was opened.
    pub fn tail_truncated_bytes(&self) -> u64 {
        self.tail_truncated
    }

    /// Fold records this backend has written by compaction or replayed
    /// from disk on open.
    pub fn fold_records(&self) -> u64 {
        self.folds
    }
}

/// The order of one processor's entries inside a fold: dependencies
/// first, dependents later, the Ξ metadata record (whose presence
/// certifies all the rest) strictly last — the FT layer's own write
/// order (log entries → input-frontier marker; state chunks → snapshot
/// record → Ξ). A fold record lands atomically under its checksum, but
/// folds can split near the segment size, and the suffix-loss crash
/// model then guarantees no Ξ survives an entry it depends on.
fn fold_rank(kind: Kind) -> u8 {
    match kind {
        Kind::State => 0,
        Kind::LogEntry => 1,
        Kind::HistoryEvent => 2,
        Kind::InputFrontier => 3,
        Kind::Chunk => 4,
        Kind::Snapshot => 5,
        Kind::Meta => 6,
    }
}

impl StorageBackend for FileBackend {
    fn put(&mut self, key: &Key, value: &[u8]) -> Result<Option<u64>, StorageError> {
        let payload = encode_payload(0, key, Some(value));
        if payload.len() as u64 > MAX_PAYLOAD {
            // The reopen scanner rejects larger length fields as
            // corruption; refuse (without acknowledging) rather than
            // persist a record a restart could never read back.
            return Err(StorageError::ValueTooLarge {
                size: payload.len() as u64,
                max: MAX_PAYLOAD,
            });
        }
        let loc = self.append_record(payload, value.len() as u64);
        self.live_value_bytes += value.len() as u64;
        self.tombs.remove(key);
        let old = self.index.insert(key.clone(), loc);
        let replaced = old.map(|old| {
            self.mark_dead(old);
            old.value_len
        });
        // Overwrites strand dead bytes too (e.g. the input-frontier
        // marker rewritten every epoch) — check the threshold now that
        // the index points at the new record.
        self.maybe_compact();
        Ok(replaced)
    }

    fn get(&mut self, key: &Key) -> Option<Vec<u8>> {
        assert!(!self.crashed, "FileBackend used after simulated crash");
        let loc = *self.index.get(key)?;
        Some(self.read_value(loc))
    }

    fn delete(&mut self, key: &Key) -> Option<u64> {
        if !self.index.contains_key(key) {
            return None;
        }
        let loc = self.append_record(encode_payload(1, key, None), 0);
        // The tombstone is dead weight the moment it lands, but tracked:
        // compaction must carry it while older segments may hold the
        // puts it shadows.
        self.segs.entry(loc.seg).or_default().dead_bytes += loc.len;
        self.tombs.insert(key.clone(), loc);
        let old = self.index.remove(key).expect("checked above");
        self.mark_dead(old);
        self.maybe_compact();
        Some(old.value_len)
    }

    fn scan_entries(&mut self, proc: u32) -> Vec<(Key, u64)> {
        self.index.range(proc_range(proc)).map(|(k, loc)| (k.clone(), loc.value_len)).collect()
    }

    fn procs(&mut self) -> Vec<u32> {
        crate::ft::storage::distinct_procs(self.index.keys())
    }

    fn sync(&mut self) {
        self.flush();
        // Everything written since the last sync — including segments
        // sealed in between, whose write handles are long gone — plus
        // the active writer, so the whole acknowledged prefix (not just
        // the active tail) is power-loss durable.
        let mut to_sync = std::mem::take(&mut self.dirty_segs);
        if self.writer.is_some() {
            to_sync.insert(self.active);
        }
        self.fsync_segs(to_sync);
        // …and the files themselves must be reachable after power loss.
        if self.dir_dirty {
            self.fsync_dir();
        }
    }

    fn max_value_len(&self) -> Option<u64> {
        // A put's payload carries the op byte, the key varints and the
        // value's length prefix on top of the value itself; 64 bytes
        // bounds that overhead, so any value at or under this limit
        // encodes within MAX_PAYLOAD. The store pre-checks staged writes
        // against it, making refusal synchronous even when a background
        // writer applies the put.
        Some(MAX_PAYLOAD - 64)
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            name: "file",
            live_keys: self.index.len() as u64,
            live_bytes: self.live_value_bytes,
            file_bytes: self.segs.values().map(|s| s.flushed_len).sum::<u64>()
                + self.buf.len() as u64,
            segments: self.segs.len() as u64,
            dead_bytes: self.segs.values().map(|s| s.dead_bytes).sum(),
            compactions: self.compactions,
        }
    }

    fn compact(&mut self) {
        self.maybe_compact();
    }

    fn set_tracer(&mut self, tracer: Option<crate::trace::Tracer>) {
        self.tracer = tracer;
    }

    fn simulate_crash(&mut self) {
        self.crashed = true;
        self.buf.clear();
        self.buffered_records = 0;
        self.writer = None;
    }
}

impl Drop for FileBackend {
    fn drop(&mut self) {
        if !self.crashed {
            self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn k(proc: u32, kind: Kind, tag: u64) -> Key {
        Key { proc, kind, tag }
    }

    fn opts(flush_every_n: usize) -> FileBackendOptions {
        FileBackendOptions { flush_every_n, ..Default::default() }
    }

    #[test]
    fn put_get_delete_roundtrip() {
        let t = TempDir::new("wal-basic");
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert_eq!(b.put(&k(1, Kind::State, 1), b"hello"), Ok(None));
        assert_eq!(b.get(&k(1, Kind::State, 1)), Some(b"hello".to_vec()));
        assert_eq!(b.put(&k(1, Kind::State, 1), b"hi"), Ok(Some(5)));
        assert_eq!(b.get(&k(1, Kind::State, 1)), Some(b"hi".to_vec()));
        assert_eq!(b.delete(&k(1, Kind::State, 1)), Some(2));
        assert_eq!(b.get(&k(1, Kind::State, 1)), None);
        assert_eq!(b.delete(&k(1, Kind::State, 1)), None);
    }

    #[test]
    fn group_commit_buffers_then_flushes() {
        let t = TempDir::new("wal-group");
        let mut b = FileBackend::open(t.path(), opts(4)).unwrap();
        for tag in 0..3 {
            b.put(&k(0, Kind::LogEntry, tag), &[tag as u8; 16]).unwrap();
        }
        // Nothing flushed yet; the buffered tail serves reads by flushing
        // on demand.
        assert!(b.segs.get(&b.active).map(|s| s.flushed_len).unwrap_or(0) < 16);
        assert_eq!(b.get(&k(0, Kind::LogEntry, 2)), Some(vec![2u8; 16]));
        assert!(b.buf.is_empty(), "read of a buffered record forces a flush");
        // The 4th write crosses the group-commit width by itself.
        b.put(&k(0, Kind::LogEntry, 3), &[9]).unwrap();
        for _ in 0..3 {
            b.put(&k(0, Kind::LogEntry, 99), &[1]).unwrap();
        }
        b.sync();
        assert!(b.buf.is_empty());
    }

    #[test]
    fn reopen_rebuilds_index() {
        let t = TempDir::new("wal-reopen");
        {
            let mut b = FileBackend::open(t.path(), opts(2)).unwrap();
            for tag in 0..10u32 {
                b.put(&k(tag % 3, Kind::LogEntry, tag as u64), &[tag as u8; 8]).unwrap();
            }
            b.put(&k(0, Kind::LogEntry, 0), b"overwritten").unwrap();
            b.delete(&k(1, Kind::LogEntry, 1));
            // Dropped here: Drop flushes the tail.
        }
        let mut b = FileBackend::open(t.path(), opts(2)).unwrap();
        assert_eq!(b.get(&k(0, Kind::LogEntry, 0)), Some(b"overwritten".to_vec()));
        assert_eq!(b.get(&k(1, Kind::LogEntry, 1)), None);
        assert_eq!(b.get(&k(2, Kind::LogEntry, 2)), Some(vec![2u8; 8]));
        assert_eq!(b.index.len(), 9, "10 puts, 1 tombstone");
        // Proc-ranged scans see only their processor.
        assert_eq!(b.scan_keys(1).len(), 3 - 1);
    }

    #[test]
    fn crash_loses_only_the_unflushed_suffix() {
        let t = TempDir::new("wal-crash");
        {
            let mut b = FileBackend::open(t.path(), opts(100)).unwrap();
            b.put(&k(0, Kind::State, 1), b"durable").unwrap();
            b.sync();
            b.put(&k(0, Kind::State, 2), b"lost").unwrap();
            b.simulate_crash();
            // Drop after crash must not write the tail.
        }
        let mut b = FileBackend::open(t.path(), opts(100)).unwrap();
        assert_eq!(b.get(&k(0, Kind::State, 1)), Some(b"durable".to_vec()));
        assert_eq!(b.get(&k(0, Kind::State, 2)), None, "unflushed write died with the crash");
    }

    #[test]
    fn torn_tail_is_truncated_on_open() {
        let t = TempDir::new("wal-torn");
        {
            let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
            b.put(&k(0, Kind::State, 1), b"keep-me").unwrap();
            b.put(&k(0, Kind::State, 2), b"torn-victim").unwrap();
        }
        // Chop the final record in half (simulates a crash mid-write).
        let seg = t.path().join(seg_name(1));
        let len = std::fs::metadata(&seg).unwrap().len();
        OpenOptions::new().write(true).open(&seg).unwrap().set_len(len - 5).unwrap();
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert!(b.tail_truncated_bytes() > 0);
        assert_eq!(b.get(&k(0, Kind::State, 1)), Some(b"keep-me".to_vec()));
        assert_eq!(b.get(&k(0, Kind::State, 2)), None);
        // The truncated file is clean again: append + reopen still works.
        b.put(&k(0, Kind::State, 3), b"after-truncate").unwrap();
        drop(b);
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert_eq!(b.get(&k(0, Kind::State, 3)), Some(b"after-truncate".to_vec()));
    }

    #[test]
    fn corrupt_checksum_tail_is_dropped() {
        let t = TempDir::new("wal-crc");
        {
            let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
            b.put(&k(0, Kind::State, 1), b"good").unwrap();
            b.put(&k(0, Kind::State, 2), b"flipped").unwrap();
        }
        let seg = t.path().join(seg_name(1));
        let mut data = std::fs::read(&seg).unwrap();
        let n = data.len();
        data[n - 1] ^= 0xff; // flip a payload bit of the last record
        std::fs::write(&seg, &data).unwrap();
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert_eq!(b.get(&k(0, Kind::State, 1)), Some(b"good".to_vec()));
        assert_eq!(b.get(&k(0, Kind::State, 2)), None);
    }

    #[test]
    fn rotation_and_compaction_reclaim_dead_segments() {
        let t = TempDir::new("wal-compact");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..40 {
            b.put(&k(0, Kind::LogEntry, tag), &[0u8; 32]).unwrap();
        }
        assert!(b.segs.len() > 2, "small segments must have rotated");
        let before = b.info();
        // Tombstone most of the early records: their segments cross the
        // dead threshold and compact away.
        for tag in 0..36 {
            b.delete(&k(0, Kind::LogEntry, tag));
        }
        let after = b.info();
        assert!(after.compactions > 0, "threshold-triggered compaction ran");
        assert!(
            after.file_bytes < before.file_bytes + 36 * 16,
            "compaction reclaimed dead segments (file {} → {})",
            before.file_bytes,
            after.file_bytes
        );
        // Survivors are intact, including after a reopen.
        for tag in 36..40 {
            assert_eq!(b.get(&k(0, Kind::LogEntry, tag)), Some(vec![0u8; 32]));
        }
        drop(b);
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..36 {
            assert_eq!(b.get(&k(0, Kind::LogEntry, tag)), None);
        }
        for tag in 36..40 {
            assert_eq!(b.get(&k(0, Kind::LogEntry, tag)), Some(vec![0u8; 32]));
        }
    }

    /// Compaction moves live records out of dying segments; those moves
    /// must be flushed before the source file is removed, or a crash in
    /// the group-commit window would lose *acknowledged* data (suffix-
    /// only loss is the WAL contract — regression test for exactly that).
    #[test]
    fn compaction_is_crash_safe_under_group_commit() {
        let t = TempDir::new("wal-compact-crash");
        let o = FileBackendOptions {
            flush_every_n: 1000, // nothing flushes on its own
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..40 {
            b.put(&k(0, Kind::LogEntry, tag), &[tag as u8; 32]).unwrap();
        }
        b.sync(); // all 40 durable
        // Tombstone 4 of every 5 records: every segment crosses the dead
        // threshold, so each survivor (tag ≡ 0 mod 5) is *moved* by
        // compaction into the group-commit buffer of the active segment.
        for tag in 0..40 {
            if tag % 5 != 0 {
                b.delete(&k(0, Kind::LogEntry, tag));
            }
        }
        assert!(b.info().compactions > 0, "compaction must have run");
        b.simulate_crash(); // die with the group-commit buffer unflushed
        drop(b);
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in (0..40).step_by(5) {
            assert_eq!(
                b.get(&k(0, Kind::LogEntry, tag)),
                Some(vec![tag as u8; 32]),
                "record moved by compaction must survive the crash"
            );
        }
        // (Unflushed tombstones may legitimately resurrect their keys —
        // the deletes were never acknowledged-durable; that is suffix
        // loss, not corruption.)
    }

    #[test]
    fn open_is_read_only() {
        let t = TempDir::new("wal-ro");
        {
            let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
            b.put(&k(0, Kind::State, 1), b"x").unwrap();
        }
        let files_before = std::fs::read_dir(t.path()).unwrap().count();
        let _inspect = FileBackend::open(t.path(), opts(1)).unwrap();
        let files_after = std::fs::read_dir(t.path()).unwrap().count();
        assert_eq!(files_before, files_after, "opening for inspection creates no files");
    }

    /// Inspection of a torn WAL must not repair it: the damaged tail
    /// stays on disk byte-for-byte while the read-only view still serves
    /// the valid prefix.
    #[test]
    fn read_only_open_leaves_torn_tail_untouched() {
        let t = TempDir::new("wal-ro-torn");
        {
            let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
            b.put(&k(0, Kind::State, 1), b"keep-me").unwrap();
            b.put(&k(0, Kind::State, 2), b"torn-victim").unwrap();
        }
        let seg = t.path().join(seg_name(1));
        let torn_len = std::fs::metadata(&seg).unwrap().len() - 5;
        OpenOptions::new().write(true).open(&seg).unwrap().set_len(torn_len).unwrap();
        let mut ro = FileBackend::open_read_only(t.path(), opts(1)).unwrap();
        assert!(ro.tail_truncated_bytes() > 0);
        assert_eq!(ro.get(&k(0, Kind::State, 1)), Some(b"keep-me".to_vec()));
        assert_eq!(ro.get(&k(0, Kind::State, 2)), None);
        drop(ro);
        assert_eq!(
            std::fs::metadata(&seg).unwrap().len(),
            torn_len,
            "read-only open must not truncate the file"
        );
        // A subsequent writable open still repairs and recovers.
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert_eq!(b.get(&k(0, Kind::State, 1)), Some(b"keep-me".to_vec()));
        assert!(std::fs::metadata(&seg).unwrap().len() < torn_len);
    }

    #[test]
    fn mid_log_corruption_is_an_error() {
        let t = TempDir::new("wal-midcorrupt");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 128,
            compact_ratio: 2.0, // never compact (keep the corrupted file)
            fsync: false,
        };
        {
            let mut b = FileBackend::open(t.path(), o).unwrap();
            for tag in 0..20 {
                b.put(&k(0, Kind::State, tag), &[1u8; 32]).unwrap();
            }
            assert!(b.segs.len() >= 2);
        }
        // Corrupt the FIRST segment: that is lost acknowledged state.
        let seg = t.path().join(seg_name(1));
        let mut data = std::fs::read(&seg).unwrap();
        data[MAGIC.len() + 5] ^= 0xff;
        std::fs::write(&seg, &data).unwrap();
        let err = FileBackend::open(t.path(), o).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    /// A tombstone in a compacted segment may be the only thing shadowing
    /// a superseded put in an *older surviving* segment. Compaction must
    /// carry it to the active segment, or the deleted key resurrects on
    /// the next reopen — the review scenario: a tombstone-heavy segment
    /// is ~100% dead, compacts away immediately, and the old put replays.
    #[test]
    fn compaction_carries_tombstones_shadowing_older_segments() {
        let t = TempDir::new("wal-tomb-carry");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        // Segment 1: the target put plus long-lived fillers (stays mostly
        // live, so it never becomes a compaction victim itself).
        let target = k(0, Kind::State, 0);
        b.put(&target, &[7u8; 32]).unwrap();
        let mut filler = 0u64;
        while b.active == 1 {
            b.put(&k(1, Kind::State, filler), &[1u8; 32]).unwrap();
            filler += 1;
        }
        // A batch of short-lived keys, then delete them AND the target:
        // the tombstones land in later segments.
        for tag in 0..6 {
            b.put(&k(2, Kind::State, tag), &[2u8; 32]).unwrap();
        }
        for tag in 0..6 {
            b.delete(&k(2, Kind::State, tag));
        }
        b.delete(&target);
        // Roll the tombstone-bearing segment shut, then kill it: stuff it
        // with throwaway records and delete them so its dead fraction
        // crosses the threshold.
        let tomb_seg = b.tombs[&target].seg;
        let mut extra = 0u64;
        while b.active == tomb_seg {
            b.put(&k(3, Kind::State, extra), &[3u8; 32]).unwrap();
            extra += 1;
        }
        for tag in 0..extra {
            b.delete(&k(3, Kind::State, tag));
        }
        b.compact();
        assert!(
            !b.segs.contains_key(&tomb_seg),
            "the tombstone's original segment must have been compacted away"
        );
        assert!(b.segs.contains_key(&1), "segment 1 (mostly live) must survive");
        assert!(b.tombs[&target].seg > tomb_seg, "tombstone was carried forward");
        drop(b);
        let mut b = FileBackend::open(t.path(), o).unwrap();
        assert_eq!(b.get(&target), None, "deleted key must not resurrect after compaction");
        for tag in 0..filler {
            assert_eq!(b.get(&k(1, Kind::State, tag)), Some(vec![1u8; 32]));
        }
    }

    /// The carry rule has a floor: once no segment older than a tombstone
    /// remains, the tombstone is elided instead of shuffled forward
    /// forever — deleting everything eventually shrinks the WAL to
    /// (almost) nothing instead of accumulating tombstones.
    #[test]
    fn tombstones_are_elided_once_nothing_older_survives() {
        let t = TempDir::new("wal-tomb-elide");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..12 {
            b.put(&k(0, Kind::State, tag), &[5u8; 32]).unwrap();
        }
        for tag in 0..12 {
            b.delete(&k(0, Kind::State, tag));
        }
        // Carried tombstones can seal one more segment per round; a few
        // rounds reach the fixed point where eliding empties the WAL.
        for _ in 0..4 {
            b.compact();
        }
        // Only tombstones in the still-open active segment may remain
        // tracked; everything in compacted segments was elided.
        assert!(b.tombs.values().all(|loc| b.segs.contains_key(&loc.seg)));
        let files = std::fs::read_dir(t.path()).unwrap().count();
        assert!(files <= 1, "deleting everything must not leave segments behind ({files} files)");
        drop(b);
        let b2 = FileBackend::open(t.path(), o).unwrap();
        assert_eq!(b2.info().live_keys, 0);
    }

    /// `sync()` must cover the whole acknowledged prefix: segments sealed
    /// since the last sync (whose write handles are long gone) and the
    /// directory entries for created/removed segment files, not just the
    /// active tail.
    #[test]
    fn sync_covers_sealed_segments_and_directory() {
        let t = TempDir::new("wal-sync-all");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 2.0, // keep every segment
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..30 {
            b.put(&k(0, Kind::State, tag), &[0u8; 32]).unwrap();
        }
        assert!(b.segs.len() >= 3, "rotations must have sealed segments");
        assert!(b.dirty_segs.len() >= 3, "sealed segments are tracked as unsynced");
        assert!(b.dir_dirty, "segment creation dirties the directory");
        b.sync();
        assert!(b.dirty_segs.is_empty(), "sync must fsync every written segment");
        assert!(!b.dir_dirty, "sync must fsync the directory");
        b.put(&k(0, Kind::State, 99), &[0u8; 8]).unwrap();
        assert!(!b.dirty_segs.is_empty(), "new writes re-dirty the active segment");
    }

    /// Segments inherited from a previous process instance start out
    /// dirty: that instance may have flushed them without ever fsyncing,
    /// so the first sync() after a reopen must cover them — not just
    /// what the new instance wrote itself.
    #[test]
    fn reopened_segments_start_dirty_until_synced() {
        let t = TempDir::new("wal-reopen-dirty");
        {
            let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
            b.put(&k(0, Kind::State, 1), b"inherited").unwrap();
            // Drop flushes the tail but never fsyncs.
        }
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert!(!b.dirty_segs.is_empty(), "inherited segments must start dirty");
        assert!(b.dir_dirty, "inherited directory state must start dirty");
        b.sync();
        assert!(b.dirty_segs.is_empty());
        assert!(!b.dir_dirty);
    }

    /// An oversized value is refused as an error — not a process panic —
    /// and the backend stays fully usable afterwards.
    #[test]
    fn oversized_put_is_refused_not_fatal() {
        let t = TempDir::new("wal-oversize");
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        let huge = vec![0u8; MAX_PAYLOAD as usize + 1];
        match b.put(&k(0, Kind::State, 1), &huge) {
            Err(StorageError::ValueTooLarge { size, max }) => {
                assert!(size > max);
                assert_eq!(max, MAX_PAYLOAD);
            }
            other => panic!("expected ValueTooLarge, got {other:?}"),
        }
        // Nothing was persisted or accounted.
        assert_eq!(b.get(&k(0, Kind::State, 1)), None);
        assert_eq!(b.info().live_keys, 0);
        b.put(&k(0, Kind::State, 1), b"small").unwrap();
        drop(b);
        let mut b = FileBackend::open(t.path(), opts(1)).unwrap();
        assert_eq!(b.get(&k(0, Kind::State, 1)), Some(b"small".to_vec()));
    }

    /// The fold payload codec roundtrips, and the per-entry byte costs
    /// computed at encode time agree with decode time and sum to the
    /// whole record — the invariant that keeps dead-byte accounting
    /// exact across a reopen.
    #[test]
    fn fold_payload_roundtrip_and_cost_accounting() {
        let entries: Vec<(Key, Vec<u8>)> = (0..5u64)
            .map(|i| (k(3, Kind::Chunk, 1000 + i), vec![i as u8; 10 + i as usize]))
            .collect();
        let (payload, costs) = encode_fold(3, &entries);
        assert_eq!(costs.len(), 5);
        assert_eq!(
            costs.iter().sum::<u64>(),
            REC_HEADER + payload.len() as u64,
            "entry costs must sum to the full record length"
        );
        match decode_payload(&payload) {
            Some(Payload::Fold(proc, dec)) => {
                assert_eq!(proc, 3);
                assert_eq!(dec.len(), 5);
                for (i, e) in dec.iter().enumerate() {
                    assert_eq!(e.kind, Kind::Chunk);
                    assert_eq!(e.tag, 1000 + i as u64);
                    assert_eq!(e.value, entries[i].1);
                    assert_eq!(e.cost, costs[i], "encode/decode costs must agree");
                }
            }
            _ => panic!("fold payload failed to decode"),
        }
    }

    /// Compaction folds the victims' surviving records into per-proc
    /// op-2 records; survivors read back correctly both live and across
    /// a reopen that replays the folds.
    #[test]
    fn compaction_folds_live_records_per_proc() {
        let t = TempDir::new("wal-fold");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..40 {
            b.put(&k(1, Kind::Chunk, tag), &[1u8; 24]).unwrap();
            b.put(&k(2, Kind::LogEntry, tag), &[2u8; 24]).unwrap();
        }
        b.put(&k(1, Kind::Meta, 7), b"xi-1").unwrap();
        // Kill 4 of every 5: every sealed segment crosses the dead
        // threshold, so the spread-out survivors get folded.
        for tag in 0..40 {
            if tag % 5 != 0 {
                b.delete(&k(1, Kind::Chunk, tag));
                b.delete(&k(2, Kind::LogEntry, tag));
            }
        }
        b.compact();
        assert!(b.fold_records() > 0, "surviving cold prefix must have been folded");
        for tag in (0..40).step_by(5) {
            assert_eq!(b.get(&k(1, Kind::Chunk, tag)), Some(vec![1u8; 24]));
            assert_eq!(b.get(&k(2, Kind::LogEntry, tag)), Some(vec![2u8; 24]));
        }
        assert_eq!(b.get(&k(1, Kind::Meta, 7)), Some(b"xi-1".to_vec()));
        drop(b);
        let mut b = FileBackend::open(t.path(), o).unwrap();
        assert!(b.fold_records() > 0, "reopen must have replayed fold records");
        for tag in 0..40 {
            let expect_live = tag % 5 == 0;
            assert_eq!(b.get(&k(1, Kind::Chunk, tag)).is_some(), expect_live);
            assert_eq!(b.get(&k(2, Kind::LogEntry, tag)).is_some(), expect_live);
        }
        assert_eq!(b.get(&k(1, Kind::Meta, 7)), Some(b"xi-1".to_vec()));
    }

    /// Individual entries of a fold record supersede and delete like any
    /// put: the index addresses them by sub-entry, per-entry byte costs
    /// keep segment accounting coherent, and a crash after the fold
    /// still replays consistently.
    #[test]
    fn fold_entries_supersede_delete_and_survive_crash() {
        let t = TempDir::new("wal-fold-crash");
        let o = FileBackendOptions {
            flush_every_n: 1,
            segment_bytes: 256,
            compact_ratio: 0.5,
            fsync: false,
        };
        let mut b = FileBackend::open(t.path(), o).unwrap();
        for tag in 0..40 {
            b.put(&k(1, Kind::Chunk, tag), &[1u8; 24]).unwrap();
        }
        for tag in 0..40 {
            if tag % 5 != 0 {
                b.delete(&k(1, Kind::Chunk, tag));
            }
        }
        b.compact();
        assert!(b.fold_records() > 0);
        // Supersede one folded entry, delete another.
        b.put(&k(1, Kind::Chunk, 0), &[9u8; 24]).unwrap();
        b.delete(&k(1, Kind::Chunk, 5));
        for (id, st) in &b.segs {
            assert!(
                st.dead_bytes <= st.flushed_len + b.buf.len() as u64,
                "segment {id}: dead bytes {} exceed its length {}",
                st.dead_bytes,
                st.flushed_len
            );
        }
        b.sync();
        b.simulate_crash();
        drop(b);
        let mut b = FileBackend::open(t.path(), o).unwrap();
        assert_eq!(b.get(&k(1, Kind::Chunk, 0)), Some(vec![9u8; 24]));
        assert_eq!(b.get(&k(1, Kind::Chunk, 5)), None);
        for tag in (10..40).step_by(5) {
            assert_eq!(b.get(&k(1, Kind::Chunk, tag)), Some(vec![1u8; 24]));
        }
        for (id, st) in &b.segs {
            assert!(
                st.dead_bytes <= st.flushed_len,
                "reopen rebuilt segment {id} accounting: dead {} > len {}",
                st.dead_bytes,
                st.flushed_len
            );
        }
    }
}
