//! External inputs and outputs (§4.3).
//!
//! The paper assumes stream services with acknowledge-and-retry semantics
//! (Kafka, Event Hubs): an input service keeps each batch available for
//! re-delivery until acknowledged; an output consumer tolerates duplicate
//! sends until it acknowledges. Both plug into the garbage-collection
//! watermark: input batches are acknowledged once the reading processor's
//! low-watermark passes them (it will never need them re-sent), and an
//! output processor reports `f` as "persisted" once the consumer has
//! acknowledged every record at times in `f`, releasing upstream state.

use crate::engine::Record;
use crate::frontier::Frontier;
use crate::time::{LexTime, Time};
use std::collections::BTreeMap;

/// A replayable input service feeding one source processor.
///
/// Batches are keyed by logical time. [`ExternalInput::replay_from`]
/// yields everything not yet acknowledged — exactly what a client
/// re-sends after the ephemeral region rolls back (§2.1's "clients retry
/// on failure").
#[derive(Clone, Debug, Default)]
pub struct ExternalInput {
    batches: BTreeMap<LexTime, Vec<Record>>,
    acked: Option<Frontier>,
    /// Total re-deliveries performed (benchmarks).
    pub redeliveries: u64,
}

impl ExternalInput {
    pub fn new() -> ExternalInput {
        ExternalInput::default()
    }

    /// Offer a batch at `t` (the service keeps it until acknowledged).
    pub fn offer(&mut self, t: Time, records: Vec<Record>) {
        self.batches.entry(LexTime(t)).or_default().extend(records);
    }

    /// Acknowledge everything at times within `f` (driven by the GC
    /// monitor's low-watermark for the reading processor).
    pub fn ack_upto(&mut self, f: &Frontier) {
        self.batches.retain(|lt, _| !f.contains(&lt.0));
        self.acked = Some(f.clone());
    }

    /// Batches that would be re-sent on request: everything unacked at
    /// times outside `resume_from` (the reader's rollback frontier).
    pub fn replay_from(&mut self, resume_from: &Frontier) -> Vec<(Time, Vec<Record>)> {
        let out: Vec<(Time, Vec<Record>)> = self
            .batches
            .iter()
            .filter(|(lt, _)| !resume_from.contains(&lt.0))
            .map(|(lt, rs)| (lt.0, rs.clone()))
            .collect();
        self.redeliveries += out.iter().map(|(_, rs)| rs.len() as u64).sum::<u64>();
        out
    }

    /// Unacknowledged batch count.
    pub fn pending(&self) -> usize {
        self.batches.len()
    }
}

/// A deduplicating output consumer.
///
/// The system "must be willing to re-send a batch of data multiple times
/// until it is acknowledged"; the consumer deduplicates by (time, index)
/// so at-least-once delivery from the dataflow becomes exactly-once
/// externally.
#[derive(Clone, Debug, Default)]
pub struct ExternalOutput {
    /// Accepted records per time (deduplicated).
    accepted: BTreeMap<LexTime, Vec<Record>>,
    /// Per-time count already acknowledged (dedup horizon).
    acked_counts: BTreeMap<LexTime, usize>,
    /// Duplicates suppressed (benchmarks).
    pub duplicates: u64,
}

impl ExternalOutput {
    pub fn new() -> ExternalOutput {
        ExternalOutput::default()
    }

    /// Deliver the `idx`-th record at time `t` (idx is the sender's
    /// per-time sequence). Returns true if newly accepted.
    pub fn deliver(&mut self, t: Time, idx: usize, r: Record) -> bool {
        let seen = self.acked_counts.entry(LexTime(t)).or_insert(0);
        if idx < *seen {
            self.duplicates += 1;
            return false;
        }
        debug_assert_eq!(idx, *seen, "output delivered out of order within a time");
        *seen += 1;
        self.accepted.entry(LexTime(t)).or_default().push(r);
        true
    }

    /// The frontier of fully-acknowledged times given that the sender has
    /// finished sending all records for times in `complete`.
    pub fn acked_frontier(&self, complete: &Frontier) -> Frontier {
        // Everything accepted at complete times is acknowledged.
        let times = self.accepted.keys().map(|lt| lt.0).filter(|t| complete.contains(t));
        Frontier::down_close(times)
    }

    /// Accepted records in time order (for assertions).
    pub fn contents(&self) -> Vec<(Time, Vec<Record>)> {
        self.accepted.iter().map(|(lt, v)| (lt.0, v.clone())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_ack_and_replay() {
        let mut inp = ExternalInput::new();
        inp.offer(Time::epoch(0), vec![Record::Int(1)]);
        inp.offer(Time::epoch(1), vec![Record::Int(2), Record::Int(3)]);
        assert_eq!(inp.pending(), 2);
        // Reader's watermark passes epoch 0: batch 0 released.
        inp.ack_upto(&Frontier::upto_epoch(0));
        assert_eq!(inp.pending(), 1);
        // Rollback to ∅… only unacked batches replay.
        let replay = inp.replay_from(&Frontier::Bottom);
        assert_eq!(replay.len(), 1);
        assert_eq!(replay[0].0, Time::epoch(1));
        assert_eq!(inp.redeliveries, 2);
        // Rollback to ↓1 keeps epoch 1's effects: nothing to replay.
        assert!(inp.replay_from(&Frontier::upto_epoch(1)).is_empty());
    }

    #[test]
    fn output_dedup_on_resend() {
        let mut out = ExternalOutput::new();
        assert!(out.deliver(Time::epoch(0), 0, Record::Int(1)));
        assert!(out.deliver(Time::epoch(0), 1, Record::Int(2)));
        // Re-send after recovery: suppressed.
        assert!(!out.deliver(Time::epoch(0), 0, Record::Int(1)));
        assert!(!out.deliver(Time::epoch(0), 1, Record::Int(2)));
        assert_eq!(out.duplicates, 2);
        assert_eq!(out.contents()[0].1.len(), 2);
    }

    #[test]
    fn acked_frontier_respects_completion() {
        let mut out = ExternalOutput::new();
        out.deliver(Time::epoch(0), 0, Record::Int(1));
        out.deliver(Time::epoch(2), 0, Record::Int(2));
        let f = out.acked_frontier(&Frontier::upto_epoch(1));
        assert!(f.contains(&Time::epoch(0)));
        assert!(!f.contains(&Time::epoch(2)), "epoch 2 not complete yet");
    }
}
