//! Recovery from failure (§4.4) and the §3.6 state reset.
//!
//! The flow: failures are injected (crash semantics — the processor's
//! volatile state, input-queue contents and pending notifications are
//! destroyed); the system pauses (our engine is event-at-a-time, so any
//! inter-step point is a pause); availability is assembled — failed
//! processors offer only their durable chains (or ∅), non-failed ones get
//! the ⊤ pseudo-checkpoint; the Fig. 6 solver picks maximal consistent
//! frontiers; and the state reset applies them:
//!
//! ```text
//! F*'(p) = {f' ∈ F*(p) : f' ⊆ f(p)}       (chain truncation)
//! H'(p)  = H(p)@f(p)                       (history filtering)
//! S'(p)  = S(p, f(p))                      (state restore)
//! Q'(e)  = L(p, f(p)) @̸ f(dst(e))          (log replay)
//! ```
//!
//! Channel contents are reconciled per edge: a destination kept at ⊤
//! keeps queued messages whose times are fixed by the source's rollback
//! (`time ∈ φ(e)(f(src))` — the source will not regenerate those); a
//! destination restored to `f < ⊤` gets its queue rebuilt purely from
//! logs/replay (valid checkpoints are complete, so nothing inside `f`
//! can have been in flight).
//!
//! **Pause-drain-parallel-rollback.** When the system runs
//! multi-threaded ([`FtSystem::run_to_quiescence_parallel`]), every
//! drain recomposes the engine before returning: workers park at the
//! final barrier, their channels, processors, per-shard FT metadata and
//! progress deltas all merge back, and the threads join — and the
//! **persistence writer settles too**: the drain ends with a staging
//! barrier ([`crate::ft::storage::Store::flush_staged`]), so the store
//! image matches the mirrors whenever workers are parked. Failure
//! injection, availability assembly and the Fig. 6 solve always execute
//! against the composed sequential engine — the plan is computed "while
//! workers are parked", with no concurrent mutation possible by
//! construction. The §3.6 *reset and replay themselves* then run either
//! sequentially ([`FtSystem::recover`]) or decomposed back onto the
//! shard-group workers ([`FtSystem::recover_parallel`]): each group
//! restores its own rolled-back processors and replays its own logs
//! concurrently — per-processor volatile and durable state is disjoint
//! by construction, so the two paths produce byte-identical results.
//! A failure injected *between* staging barriers (sequential drains do
//! not flush) additionally discards the failed processors'
//! staged-but-unacknowledged writes, rolling them back to the ack
//! watermark — see [`FtSystem::inject_failures`]. Replays enqueue
//! through the coalescing-bypass path
//! ([`crate::engine::Engine::replay_batch`] / the workers'
//! `accept_replay`), so the rebuilt queues have batch
//! boundaries that are a deterministic function of the durable log — a
//! *second* failure during recovery (or the next parallel drain)
//! observes the same boundaries as the first.

use crate::engine::parallel::MailHub;
use crate::engine::scheduler::WorkerState;
use crate::engine::Batch;
use crate::frontier::Frontier;
use crate::ft::harness::{FtStats, FtSystem, FtView, HistoryEvent, HistoryKind, ProcFt};
use crate::ft::meta::CkptMeta;
use crate::ft::policy::Policy;
use crate::ft::rollback::{choose_frontiers, Available, RollbackInput, RollbackPlan};
use crate::ft::storage::{Key, Kind, Store};
use crate::graph::{EdgeId, ProcId, Topology};
use crate::progress::Summary;
use crate::time::Time;
use crate::util::ser::Encode;

/// What a recovery pass did (for logging, tests, and benches).
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    pub plan: RollbackPlan,
    /// Records replayed from logs / history regeneration (Q′) — counted
    /// per record so the number is invariant under `batch_cap`.
    pub replayed: usize,
    /// Queued records discarded during channel reconciliation.
    pub dropped: usize,
    /// Processors restored from a durable checkpoint.
    pub restored_from_checkpoint: usize,
    /// Processors reset to their initial state (∅).
    pub reset_to_empty: usize,
    /// Processors left untouched (⊤).
    pub untouched: usize,
}

impl FtSystem {
    /// Crash the given processors: volatile operator state, input-channel
    /// contents, pending notifications, and un-persisted FT deltas are
    /// destroyed. Durable chains/logs/histories survive — up to the
    /// store's **ack watermark**: the staged-but-unacknowledged tail of a
    /// crashed processor dies with it
    /// ([`crate::ft::storage::Store::discard_unacked`] removes it from
    /// the staging queue atomically),
    /// and the corresponding mirror suffix is truncated so the Fig. 6
    /// solver lands on the acknowledged frontier. Per-proc FIFO staging
    /// makes every truncated set a mirror *prefix* — the same
    /// suffix-casualty shape as the WAL's own crash model, which is why
    /// live failure and cold restart now share one recovery story. Under
    /// [`crate::ft::storage::PersistMode::Sync`] the watermark always
    /// equals the staged sequence and nothing is truncated.
    pub fn inject_failures(&mut self, procs: &[ProcId]) {
        // The recovery timeline's opening event: failure detection. The
        // detector model is external (tests/fuzzer inject directly), so
        // detection time is injection time.
        if let Some(tr) = self.tracer() {
            tr.instant(0, "recovery", "detect", &[("procs", procs.len() as u64)]);
        }
        for &p in procs {
            let w = self.store.discard_unacked(p.0);
            self.engine.fail_proc(p);
            let store = self.store.clone();
            let ft = &mut self.ft[p.0 as usize];
            ft.failed = true;
            let keep = crate::ft::harness::acked_prefix(&ft.chain_tags, w);
            ft.chain.truncate(keep);
            ft.chain_tags.truncate(keep);
            ft.chain_reported = ft.chain_reported.min(keep);
            // The discarded tail's snapshot records (and any chunks only
            // they referenced) die with it — exactly like any other
            // unacked write; the sweep also clears the mirror entries so
            // the next checkpoint's walk-length accounting stays honest.
            crate::ft::harness::sweep_unreachable_snapshots(&store, p.0, ft);
            let keep = crate::ft::harness::acked_prefix(&ft.log_tags, w);
            ft.log.truncate(keep);
            ft.log_tags.truncate(keep);
            let keep = crate::ft::harness::acked_prefix(&ft.history_tags, w);
            ft.history.truncate(keep);
            ft.history_tags.truncate(keep);
            ft.settle_marks_for_crash(w);
            ft.delivered_new.clear();
            ft.input_new.clear();
            ft.notified_new.clear();
            ft.discarded_new.clear();
            ft.sent_events.clear();
        }
    }

    /// Whether any processor is marked failed.
    pub fn any_failed(&self) -> bool {
        self.ft.iter().any(|f| f.failed)
    }

    /// Assemble solver availability. Failed processors offer only
    /// durably-complete frontiers; non-failed ones additionally offer ⊤
    /// (§4.4). Offerability is gated on the store's **ack watermark**:
    /// failed processors' mirrors were already truncated to their
    /// acknowledged prefixes by [`FtSystem::inject_failures`], and a
    /// non-failed chain processor likewise offers only its acknowledged
    /// checkpoints (plus the live ⊤) — a staged-but-unacked checkpoint is
    /// not yet a durable restore point, and rolling back slightly further
    /// to an acked one is always safe (the unacked suffix is simply
    /// re-executed). In sync mode every entry is acked and this reduces
    /// to the pre-pipeline behavior exactly. Public so the property suite
    /// can feed the *live* system's availability straight into
    /// [`choose_frontiers`] / [`crate::ft::rollback::verify_plan`].
    pub fn availability(&self) -> Vec<Available> {
        self.topo
            .proc_ids()
            .map(|p| {
                let ft = &self.ft[p.0 as usize];
                let dedup = self.engine.dedups(p);
                match (ft.failed, ft.policy) {
                    // Failed ephemeral processors lost everything; only ∅
                    // is known-complete (client retry / upstream
                    // re-execution resupplies them).
                    (true, Policy::Ephemeral) => Available::chain(vec![]),
                    // Failed logging firewall: its durable log survives,
                    // but log *completeness* is only certified for a
                    // source's input-frontier marker (the §4.2 Ξ it
                    // persists as its capability advances). With a
                    // marker it offers that frontier — stopping a cold
                    // restart from dragging the whole dataflow to ∅;
                    // without one, only ∅.
                    (true, Policy::LogOutputs) => match self.source_marker_meta(p) {
                        Some(meta) if dedup => Available::chain_dedup(
                            vec![meta],
                            self.engine.completed(p).clone(),
                        ),
                        Some(meta) => Available::chain(vec![meta]),
                        None => Available::chain(vec![]),
                    },
                    // Failed replayable processor: it can rebuild any
                    // frontier covered by durably-notified times (those
                    // are complete, hence nothing at them was in flight)
                    // — plus, for a source, its durable input-frontier
                    // marker (inputs completely consumed with their
                    // history events acknowledged).
                    (true, Policy::FullHistory) => {
                        let mut f = Frontier::Bottom;
                        for ev in &ft.history {
                            if let HistoryKind::Notification { time } = &ev.kind {
                                f.insert(*time);
                            }
                        }
                        f = f.union(&ft.input_mark);
                        if f.is_bottom() {
                            Available::chain(vec![])
                        } else if dedup {
                            Available::chain_dedup(
                                vec![self.history_meta(p, &f)],
                                self.engine.completed(p).clone(),
                            )
                        } else {
                            Available::chain(vec![self.history_meta(p, &f)])
                        }
                    }
                    // Failed chain processor: its durable checkpoints.
                    (true, _) => {
                        let chain: Vec<CkptMeta> =
                            ft.chain.iter().map(|c| c.meta.clone()).collect();
                        if dedup {
                            Available::chain_dedup(chain, self.engine.completed(p).clone())
                        } else {
                            Available::chain(chain)
                        }
                    }
                    // Non-failed stateless/replayable: any frontier incl. ⊤.
                    // A LogOutputs processor whose log has a refused-write
                    // gap may not claim D̄ = ∅ (the gapped send lives in
                    // D̄, not the log); full-history replay regenerates
                    // sends from the complete in-memory mirror, so its
                    // claim survives a durable gap.
                    (false, Policy::Ephemeral) if dedup => {
                        Available::any_dedup(false, self.engine.completed(p).clone())
                    }
                    (false, Policy::Ephemeral) => Available::any(false),
                    (false, Policy::LogOutputs) | (false, Policy::FullHistory) if dedup => {
                        let logs = ft.policy.records_history() || !ft.persist_gap;
                        Available::any_dedup(logs, self.engine.completed(p).clone())
                    }
                    (false, Policy::LogOutputs) | (false, Policy::FullHistory) => {
                        Available::any(ft.policy.records_history() || !ft.persist_gap)
                    }
                    // Non-failed chain processor: acked chain prefix +
                    // live ⊤ (the in-memory state is intact, so ⊤ is
                    // always offerable; mid-frontier restores must come
                    // from durable checkpoints).
                    (false, _) => {
                        let acked = crate::ft::harness::acked_prefix(
                            &ft.chain_tags,
                            self.store.acked_seq(p.0),
                        );
                        let mut chain: Vec<CkptMeta> =
                            ft.chain[..acked].iter().map(|c| c.meta.clone()).collect();
                        chain.push(self.live_top_meta(p));
                        if dedup {
                            Available::chain_dedup(chain, self.engine.completed(p).clone())
                        } else {
                            Available::chain(chain)
                        }
                    }
                }
            })
            .collect()
    }

    /// Synthesize Ξ(p,f) for a failed full-history processor from its
    /// durable history: M̄ from the recorded deliveries inside `f`,
    /// N̄ = recorded notifications inside `f`, D̄ = ∅ (replay regenerates
    /// sends, acting as a log), φ = static projection of `f` — or, on
    /// per-checkpoint (seq) out-edges, the exact watermark rebuilt from
    /// the send counts each history event carries
    /// ([`HistoryEvent::sent_seq`]): the volatile `sent_events` delta
    /// died with the process, but replaying H@f regenerates exactly the
    /// sends those durable counts record.
    fn history_meta(&self, p: ProcId, f: &Frontier) -> CkptMeta {
        let ft = &self.ft[p.0 as usize];
        let mut meta = CkptMeta::empty(self.topo.in_edges(p), self.topo.out_edges(p));
        meta.f = f.clone();
        for ev in &ft.history {
            match &ev.kind {
                HistoryKind::Message { edge, time, .. } if f.contains(time) => {
                    let cur = meta.m_bar.get_mut(edge).unwrap();
                    cur.insert(*time);
                }
                HistoryKind::Notification { time } if f.contains(time) => {
                    meta.n_bar.insert(*time);
                }
                _ => {}
            }
        }
        for &e in self.topo.out_edges(p) {
            let proj = self.topo.projection(e);
            let fr = if proj.is_per_checkpoint() {
                let count: u64 = ft
                    .history
                    .iter()
                    .filter(|ev| f.contains(&ev.time()))
                    .flat_map(|ev| ev.sent_seq.iter())
                    .filter(|(se, _)| *se == e)
                    .map(|(_, n)| *n)
                    .sum();
                Frontier::seq_watermarks([(e, count)])
            } else {
                proj.apply(f).expect("non-per-checkpoint projections are static")
            };
            meta.phi.insert(e, fr);
            meta.d_bar.insert(e, Frontier::Bottom);
        }
        meta
    }

    /// §4.4 recovery: solve for consistent frontiers and apply the §3.6
    /// reset. Panics if called with no failures (nothing to do).
    pub fn recover(&mut self) -> RecoveryReport {
        self.recover_with(None)
    }

    /// §4.4 recovery on the parallel worker pool. The solve still runs
    /// against the composed engine (availability and φ read the live
    /// mirrors and counters), but the §3.6 reset and replay fan out
    /// across the shard-group workers: the engine decomposes exactly as
    /// for a parallel drain, each group restores its own rolled-back
    /// processors (checkpoint restore, snapshot-chain materialization,
    /// mirror truncation) and replays its own logs concurrently, and
    /// cross-group replay traffic rides the mailbox exchange. Falls back
    /// to the sequential path at `threads <= 1`. The recovered state is
    /// byte-identical to [`FtSystem::recover`]'s by construction:
    /// per-processor state and durable `Key{proc,..}` ranges are
    /// disjoint, every edge has a single sending worker (per-edge replay
    /// order is the log order), and
    /// [`crate::engine::Channel::push_batch_replay`] boundaries depend
    /// only on the log and the cap — see `ft/README.md`.
    pub fn recover_parallel(&mut self, group_of: &[usize], threads: usize) -> RecoveryReport {
        if threads <= 1 {
            return self.recover();
        }
        self.recover_with(Some((group_of, threads)))
    }

    fn recover_with(&mut self, par: Option<(&[usize], usize)>) -> RecoveryReport {
        assert!(self.any_failed(), "recover() without failures");
        self.note_ack_lag();
        // Recovery timeline: one enclosing "recovery" span wrapping the
        // "solver" span here and the rollback/replay spans recorded by
        // the plan application (complete-event spans close child-first;
        // the export re-sorts by start time, longest first). The
        // sequential path records one tid-0 "rollback"/"replay" pair;
        // the parallel path records per-worker sub-spans on the worker
        // tids instead.
        let tracer = self.tracer().cloned();
        let t_recovery = tracer.as_ref().map(|t| t.now_ns());
        let t_solver = t_recovery;
        let avail = self.availability();
        let plan = {
            let input = RollbackInput { topo: &self.topo, avail: &avail };
            choose_frontiers(&input)
        };
        if let (Some(tr), Some(t0)) = (&tracer, t_solver) {
            tr.span(0, "recovery", "solver", t0, &[("procs", plan.f.len() as u64)]);
        }
        let report = match par {
            Some((group_of, ngroups)) => self.apply_plan_parallel(&plan, group_of, ngroups),
            None => self.apply_plan(&plan),
        };
        for ft in &mut self.ft {
            ft.failed = false;
        }
        let rolled = (report.restored_from_checkpoint + report.reset_to_empty) as u64;
        self.stats.recoveries += 1;
        self.stats.messages_replayed += report.replayed as u64;
        self.stats.procs_rolled_back += rolled;
        self.stats.procs_untouched += report.untouched as u64;
        if par.is_none() {
            // The sequential path is one restore/replay lane; the
            // parallel path records its group fan-out inside
            // `apply_plan_parallel`, where ownership is known.
            if rolled > 0 {
                self.stats.recovery_parallelism = self.stats.recovery_parallelism.max(1);
            }
            if report.replayed > 0 {
                self.stats.replay_workers = self.stats.replay_workers.max(1);
            }
        }
        if let (Some(tr), Some(t0)) = (&tracer, t_recovery) {
            tr.span(
                0,
                "recovery",
                "recovery",
                t0,
                &[
                    ("replayed", report.replayed as u64),
                    ("replayed_total", self.stats.messages_replayed),
                    ("procs_rolled_back", rolled),
                    ("rolled_back_total", self.stats.procs_rolled_back),
                ],
            );
        }
        report
    }

    /// Apply a rollback plan: restore processors, reconcile channels,
    /// replay Q′.
    pub(crate) fn apply_plan(&mut self, plan: &RollbackPlan) -> RecoveryReport {
        let mut report = RecoveryReport {
            plan: plan.clone(),
            replayed: 0,
            dropped: 0,
            restored_from_checkpoint: 0,
            reset_to_empty: 0,
            untouched: 0,
        };

        let tracer = self.tracer().cloned();
        let t_rollback = tracer.as_ref().map(|t| t.now_ns());

        // Phase 1: restore processor states and collect replay sources.
        // `regen[p]` holds history-regenerated sends for full-history
        // processors (their virtual log).
        let n = self.topo.num_procs();
        let mut regen: Vec<Vec<(crate::graph::EdgeId, Time, Batch)>> = vec![Vec::new(); n];
        let topo = self.topo.clone();
        let store = self.store.clone();
        for p in self.topo.proc_ids() {
            let fp = plan.f[p.0 as usize].clone();
            if fp.is_top() {
                report.untouched += 1;
                continue;
            }
            if let Some(tr) = &tracer {
                tr.instant(0, "recovery", "rollback_proc", &[("proc", p.0 as u64)]);
            }
            let (outcome, sends) = rollback_proc_on(
                &mut self.engine,
                &topo,
                &store,
                &mut self.ft[p.0 as usize],
                &mut self.stats,
                p,
                &fp,
            );
            match outcome {
                RestoreOutcome::Restored => report.restored_from_checkpoint += 1,
                RestoreOutcome::Reset => report.reset_to_empty += 1,
            }
            regen[p.0 as usize] = sends;
        }

        // Phase 2: channel reconciliation.
        for e in self.topo.edge_ids() {
            let src = self.topo.src(e);
            let dst = self.topo.dst(e);
            let f_src = plan.f[src.0 as usize].clone();
            let f_dst = plan.f[dst.0 as usize].clone();
            if f_dst.is_top() {
                if f_src.is_top() {
                    continue; // nothing moved on this edge
                }
                // Keep only messages fixed by the source's rollback; the
                // source re-executes and re-sends the rest. A batch
                // shares one time, so it is kept or dropped whole.
                let keep = self.phi_runtime(e, &f_src);
                let removed = self.engine.discard_from_channel(e, |t| !keep.contains(t));
                report.dropped += removed.iter().map(|b| b.len()).sum::<usize>();
            } else {
                // Destination restored: rebuild the queue from logs.
                let removed = self.engine.discard_from_channel(e, |_| true);
                report.dropped += removed.iter().map(|b| b.len()).sum::<usize>();
            }
        }

        // Rollback = phases 1–2 (state restores + channel reconciliation);
        // replay = phase 3. The span boundary is the point where undone
        // work stops and re-execution begins.
        if let (Some(tr), Some(t0)) = (&tracer, t_rollback) {
            tr.span(
                0,
                "recovery",
                "rollback",
                t0,
                &[
                    (
                        "procs",
                        (report.restored_from_checkpoint + report.reset_to_empty) as u64,
                    ),
                    ("dropped", report.dropped as u64),
                ],
            );
        }
        let t_replay = tracer.as_ref().map(|t| t.now_ns());

        // Phase 3: replay Q′(e) = L(p, f(p)) @̸ f(dst(e)).
        for p in self.topo.proc_ids() {
            let fp = plan.f[p.0 as usize].clone();
            if fp.is_bottom() {
                continue; // log was truncated to nothing
            }
            // Durable logged batches plus history-regenerated sends,
            // replayed byte-identically (a batch shares one time, so the
            // destination-frontier filter applies to it whole).
            let entries: Vec<(crate::graph::EdgeId, Time, Batch)> = self.ft[p.0 as usize]
                .log
                .iter()
                .map(|le| (le.edge, le.event_time, le.batch.clone()))
                .chain(std::mem::take(&mut regen[p.0 as usize]))
                .collect();
            for (e, evt, batch) in entries {
                if !fp.is_top() && !fp.contains(&evt) {
                    continue;
                }
                let f_dst = &plan.f[self.topo.dst(e).0 as usize];
                if f_dst.is_top() {
                    continue; // ⊤ kept its queue; nothing to resupply
                }
                if f_dst.contains(&batch.time) {
                    continue; // destination retained its effect
                }
                report.replayed += batch.len();
                self.engine.replay_batch(e, batch);
            }
        }
        if let (Some(tr), Some(t0)) = (&tracer, t_replay) {
            tr.span(0, "recovery", "replay", t0, &[("records", report.replayed as u64)]);
        }
        report
    }

    /// Apply a rollback plan on the worker pool. The engine decomposes
    /// into the same shard groups as a parallel drain; every group then
    /// restores its own rolled-back processors (phase 1), reconciles its
    /// own inbound channels (phase 2) and replays its own logs/history
    /// (phase 3) concurrently, with cross-group replay traffic riding a
    /// fresh [`MailHub`] that each worker drains after a single barrier
    /// — so every replayed batch is in a channel or a mailbox before
    /// anyone delivers. Safe without locks because per-processor state
    /// is disjoint by construction: each proc (operator, pending set,
    /// completed frontier, out-edge counters, `ProcFt` mirror, durable
    /// `Key{proc,..}` range) has exactly one owning worker, each edge
    /// exactly one sending and one receiving worker, and the store
    /// serializes its own staging internally. Phase-2 decisions need the
    /// composed engine (`phi_runtime` at ⊤ reads live sequence counters
    /// and chain markers), so they are precomputed before decomposing
    /// and applied per edge by the owner.
    pub(crate) fn apply_plan_parallel(
        &mut self,
        plan: &RollbackPlan,
        group_of: &[usize],
        ngroups: usize,
    ) -> RecoveryReport {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

        let np = self.topo.num_procs();
        assert_eq!(group_of.len(), np, "one group per processor");
        let mut report = RecoveryReport {
            plan: plan.clone(),
            replayed: 0,
            dropped: 0,
            restored_from_checkpoint: 0,
            reset_to_empty: 0,
            untouched: plan.f.iter().filter(|f| f.is_top()).count(),
        };

        // Phase-2 channel decisions, precomputed against the composed
        // engine (same per-edge cases as the sequential `apply_plan`).
        let actions: Vec<EdgeAction> = self
            .topo
            .edge_ids()
            .map(|e| {
                let f_src = &plan.f[self.topo.src(e).0 as usize];
                let f_dst = &plan.f[self.topo.dst(e).0 as usize];
                if f_dst.is_top() {
                    if f_src.is_top() {
                        EdgeAction::Untouched
                    } else {
                        EdgeAction::KeepWithin(self.phi_runtime(e, f_src))
                    }
                } else {
                    EdgeAction::DropAll
                }
            })
            .collect();

        let topo = self.topo.clone();
        let store = self.store.clone();

        // Decompose exactly like a parallel drain: the engine loans each
        // group its processors, channels and counters; the FT harness
        // loans each group its `ProcFt` mirrors.
        let engine_workers = self.engine.decompose(group_of, ngroups);
        struct Group {
            ws: WorkerState,
            ft: Vec<Option<ProcFt>>,
            stats: FtStats,
            restored: usize,
            reset: usize,
            replayed: usize,
            dropped: usize,
        }
        let mut groups: Vec<Group> = engine_workers
            .into_iter()
            .map(|ws| Group {
                ws,
                ft: (0..np).map(|_| None).collect(),
                stats: FtStats::default(),
                restored: 0,
                reset: 0,
                replayed: 0,
                dropped: 0,
            })
            .collect();
        for (pi, ft) in self.ft.iter_mut().enumerate() {
            groups[group_of[pi]].ft[pi] =
                Some(std::mem::replace(ft, ProcFt::new(Policy::Ephemeral)));
        }

        let hub = MailHub::new(ngroups);
        let barrier = std::sync::Barrier::new(ngroups);
        let mut panic_payload: Option<Box<dyn std::any::Any + Send>> = None;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for g in groups.iter_mut() {
                let hub = &hub;
                let barrier = &barrier;
                let topo = &topo;
                let actions = &actions;
                let store = store.clone();
                handles.push(s.spawn(move || {
                    // Phases run under catch_unwind so a panicking worker
                    // still reaches the barrier (its peers would deadlock
                    // otherwise); the payload re-raises after recompose.
                    let r1 = catch_unwind(AssertUnwindSafe(|| {
                        let t0 = g.ws.trace_begin();
                        let mut regen: Vec<Vec<(EdgeId, Time, Batch)>> =
                            (0..topo.num_procs()).map(|_| Vec::new()).collect();
                        // Phase 1: restore this group's rolled-back procs.
                        for p in topo.proc_ids() {
                            let pi = p.0 as usize;
                            if !g.ws.owns(p) || plan.f[pi].is_top() {
                                continue;
                            }
                            let fp = plan.f[pi].clone();
                            g.ws.trace_instant(
                                "recovery",
                                "rollback_proc",
                                &[("proc", pi as u64)],
                            );
                            let ft = g.ft[pi].as_mut().expect("proc loaned to its owner group");
                            let (outcome, sends) = rollback_proc_on(
                                &mut g.ws,
                                topo,
                                &store,
                                ft,
                                &mut g.stats,
                                p,
                                &fp,
                            );
                            match outcome {
                                RestoreOutcome::Restored => g.restored += 1,
                                RestoreOutcome::Reset => g.reset += 1,
                            }
                            regen[pi] = sends;
                        }
                        // Phase 2: reconcile this group's inbound channels.
                        let mut dropped = 0u64;
                        for e in topo.edge_ids() {
                            if group_of[topo.dst(e).0 as usize] != g.ws.group {
                                continue;
                            }
                            match &actions[e.0 as usize] {
                                EdgeAction::Untouched => {}
                                EdgeAction::KeepWithin(keep) => {
                                    dropped += g.ws.discard_where(e, |t| !keep.contains(t));
                                }
                                EdgeAction::DropAll => {
                                    dropped += g.ws.discard_where(e, |_| true);
                                }
                            }
                        }
                        g.dropped = dropped as usize;
                        if g.restored + g.reset > 0 || dropped > 0 {
                            g.ws.trace_span(
                                "recovery",
                                "rollback",
                                t0,
                                &[("procs", (g.restored + g.reset) as u64), ("dropped", dropped)],
                            );
                        }
                        // Phase 3: replay Q′ from this group's sources
                        // (including untouched ⊤ sources feeding
                        // rolled-back destinations). Per-edge order is the
                        // log order — one sending worker per edge.
                        let t1 = g.ws.trace_begin();
                        for p in topo.proc_ids() {
                            let pi = p.0 as usize;
                            if !g.ws.owns(p) || plan.f[pi].is_bottom() {
                                continue;
                            }
                            let fp = &plan.f[pi];
                            let ft = g.ft[pi].as_ref().expect("proc loaned to its owner group");
                            let entries: Vec<(EdgeId, Time, Batch)> = ft
                                .log
                                .iter()
                                .map(|le| (le.edge, le.event_time, le.batch.clone()))
                                .chain(std::mem::take(&mut regen[pi]))
                                .collect();
                            for (e, evt, batch) in entries {
                                if !fp.is_top() && !fp.contains(&evt) {
                                    continue;
                                }
                                let f_dst = &plan.f[topo.dst(e).0 as usize];
                                if f_dst.is_top() {
                                    continue;
                                }
                                if f_dst.contains(&batch.time) {
                                    continue;
                                }
                                g.replayed += batch.len();
                                g.ws.replay_send(e, batch, &mut |dg, e, b| hub.send(dg, e, b));
                            }
                        }
                        t1
                    }));
                    // Replay barrier: every cross-group send is in a
                    // mailbox before anyone drains. Reached even on panic
                    // or the peers would deadlock.
                    barrier.wait();
                    match r1 {
                        Ok(t1) => catch_unwind(AssertUnwindSafe(|| {
                            hub.drain_replay_into(g.ws.group, &mut g.ws);
                            if g.replayed > 0 {
                                g.ws.trace_span(
                                    "recovery",
                                    "replay",
                                    t1,
                                    &[("records", g.replayed as u64)],
                                );
                            }
                            g.ws.flush_trace();
                        }))
                        .err(),
                        Err(e) => Some(e),
                    }
                }));
            }
            for h in handles {
                let payload = match h.join() {
                    Ok(p) => p,
                    Err(p) => Some(p),
                };
                if panic_payload.is_none() {
                    panic_payload = payload;
                }
            }
        });

        // Merge back: counters and mirrors first, then the engine itself
        // (recompose applies the batched tracker deltas — the cross-worker
        // net of cancels, restores, discards and replays). On a worker
        // panic everything still merges before the payload re-raises, so
        // the system is structurally consistent for postmortems.
        let mut groups_restoring = 0u64;
        let mut groups_replaying = 0u64;
        let mut engine_workers = Vec::with_capacity(ngroups);
        for mut g in groups {
            if g.restored + g.reset > 0 {
                groups_restoring += 1;
            }
            if g.replayed > 0 {
                groups_replaying += 1;
            }
            report.restored_from_checkpoint += g.restored;
            report.reset_to_empty += g.reset;
            report.replayed += g.replayed;
            report.dropped += g.dropped;
            self.stats.merge(&g.stats);
            for (pi, slot) in g.ft.iter_mut().enumerate() {
                if let Some(ft) = slot.take() {
                    self.ft[pi] = ft;
                }
            }
            engine_workers.push(g.ws);
        }
        self.engine.recompose(engine_workers);
        if let Some(p) = panic_payload {
            resume_unwind(p);
        }
        self.stats.recovery_parallelism = self.stats.recovery_parallelism.max(groups_restoring);
        self.stats.replay_workers = self.stats.replay_workers.max(groups_replaying);
        report
    }
}

/// Precomputed per-edge channel reconciliation (phase 2) — decided
/// against the composed engine, applied by the edge's owning worker.
enum EdgeAction {
    /// Neither endpoint moved: the queue is untouched.
    Untouched,
    /// Destination stays at ⊤: keep only times fixed by the source's
    /// rollback (`φ(e)(f(src))`); the source re-executes the rest.
    KeepWithin(Frontier),
    /// Destination restored below ⊤: the queue is rebuilt purely from
    /// replay.
    DropAll,
}

/// What a per-processor rollback did (phase 1).
enum RestoreOutcome {
    /// Restored from a durable checkpoint (chain entry or full history).
    Restored,
    /// Reset to the initial state (∅, or stateless at a mid frontier).
    Reset,
}

/// Phase 1 of the §3.6 reset for one rolled-back processor (`f(p) < ⊤`),
/// generic over the engine view so it runs identically against the
/// composed sequential [`crate::engine::Engine`] and a decomposed
/// [`WorkerState`] during parallel recovery. Everything it touches is
/// owned by exactly one worker — the operator, its pending set,
/// completed frontier and out-edge sequence counters live on the
/// owning [`WorkerState`]; the `ProcFt` mirror and the durable
/// `Key{proc,..}` range are per-proc disjoint — so concurrent per-proc
/// rollbacks share nothing but the store handle, which serializes its
/// own staging. Restores operator state, re-arms pending
/// notifications, resets sequence counters, truncates the durable
/// mirrors, and returns the history-regenerated virtual log for
/// phase 3.
fn rollback_proc_on<V: FtView>(
    view: &mut V,
    topo: &Topology,
    store: &Store,
    ft: &mut ProcFt,
    stats: &mut FtStats,
    p: ProcId,
    fp: &Frontier,
) -> (RestoreOutcome, Vec<(EdgeId, Time, Batch)>) {
    // Cancel all pending notifications; restores re-arm them.
    view.cancel_all_pending(p);
    // Completed-time frontier: intersect the live one with the restored
    // frontier (chain restores below overwrite it with the checkpoint's
    // durable N̄ — the live one is ∅ for failed processors).
    let new_completed = if fp.is_bottom() {
        Frontier::Bottom
    } else {
        view.completed(p).intersect(fp)
    };
    view.set_completed(p, new_completed);
    let policy = ft.policy;
    let mut regen: Vec<(EdgeId, Time, Batch)> = Vec::new();
    let outcome;
    if fp.is_bottom() {
        view.proc_restore(p).reset();
        // Re-executed sends must reuse sequence numbers from the
        // beginning, or downstream dedup (and the paper's (e,s) time
        // identity) breaks. Logging processors replay 1..k from the log
        // and continue at k+1 — but a log truncated to ∅ holds nothing,
        // so they restart numbering too.
        for &e in topo.out_edges(p) {
            if topo.projection(e).is_per_checkpoint() {
                view.set_seq_counter(e, 0);
            }
        }
        outcome = RestoreOutcome::Reset;
    } else if policy.records_history() {
        // Replay recomputes state and notifications; completed = the
        // replayed notification frontier.
        let mut done = Frontier::Bottom;
        for ev in &ft.history {
            if let HistoryKind::Notification { time } = &ev.kind {
                if fp.contains(time) {
                    done.insert(*time);
                }
            }
        }
        view.set_completed(p, done);
        regen = replay_history_on(view, topo, ft, p, fp);
        // Replay renumbered seq-domain sends from 1; live execution must
        // continue where the regenerated virtual log left off or
        // downstream dedup breaks.
        for &e in topo.out_edges(p) {
            if topo.projection(e).is_per_checkpoint() {
                let c: u64 = regen
                    .iter()
                    .filter(|(se, _, _)| *se == e)
                    .map(|(_, _, b)| b.len() as u64)
                    .sum();
                view.set_seq_counter(e, c);
            }
        }
        outcome = RestoreOutcome::Restored;
    } else if policy.has_chain() {
        let (state, pending, phi_counts, n_bar) = {
            let ck = ft
                .chain
                .iter()
                .find(|c| c.meta.f == *fp)
                .unwrap_or_else(|| panic!("plan frontier {fp} not in chain of {p}"));
            let counts: Vec<(EdgeId, u64)> = ck
                .meta
                .phi
                .iter()
                .filter(|(e, _)| topo.projection(**e).is_per_checkpoint())
                .map(|(e, fr)| (*e, fr.watermark(*e)))
                .collect();
            (ck.state.clone(), ck.pending_notify.clone(), counts, ck.meta.n_bar.clone())
        };
        view.proc_restore(p).restore(&state);
        view.restore_pending(p, pending);
        view.set_completed(p, n_bar);
        for (e, c) in phi_counts {
            view.set_seq_counter(e, c);
        }
        outcome = RestoreOutcome::Restored;
    } else {
        // Stateless at a mid frontier: nothing to restore — but a
        // logging processor kept there (e.g. a source at its
        // input-frontier marker) must resume per-checkpoint (seq)
        // out-edge numbering where its durable log left off.
        view.proc_restore(p).reset();
        if policy.logs_outputs() {
            for &e in topo.out_edges(p) {
                if topo.projection(e).is_per_checkpoint() {
                    let count: u64 = ft
                        .log
                        .iter()
                        .filter(|le| le.edge == e && fp.contains(&le.event_time))
                        .map(|le| le.records() as u64)
                        .sum();
                    view.set_seq_counter(e, count);
                }
            }
        }
        outcome = RestoreOutcome::Reset;
    }
    // FT bookkeeping reset (F*'(p), H'(p), log truncation, delta
    // pruning). Every mirror entry carries its storage tag, so
    // truncation deletes exactly the undone durable blobs — the
    // store stays an image of the mirrors, which is what makes a
    // *second* cold reopen (or one after an in-process recovery)
    // see consistent state.
    //
    // The input-frontier marker shrinks with the rollback. It
    // must land in the WAL *before* the tombstones of the log
    // entries it certified: the WAL loses only suffixes, so
    // marker-then-tombstones can leave (at worst) a narrow
    // marker with stale entries behind it — harmless, they are
    // re-truncated on reopen — while the reverse order could
    // leave a wide marker certifying deleted entries.
    if !ft.input_mark.is_bottom() {
        let shrunk = ft.input_mark.intersect(fp);
        if shrunk != ft.input_mark {
            ft.drain_acked_marks(store.acked_seq(p.0));
            ft.input_mark = shrunk.clone();
            let key = Key { proc: p.0, kind: Kind::InputFrontier, tag: 0 };
            let (seq, durable) = if shrunk.is_bottom() {
                (store.stage_delete(key), Frontier::Bottom)
            } else {
                match store.stage_put(key, shrunk.to_bytes()) {
                    Ok(seq) => (seq, shrunk.clone()),
                    // The store refuses the shrunk marker (a
                    // byte limit small enough to reject a
                    // frontier blob — the same oversized-put
                    // regime whose log refusals forced this
                    // rollback in the first place). Deleting
                    // the durable marker is always expressible
                    // and strictly conservative: a cold restart
                    // or crash-settle sees no marker and offers
                    // ∅ for this source instead of a stale wide
                    // frontier certifying truncated logs.
                    Err(_) => {
                        ft.storage_errors += 1;
                        stats.storage_errors += 1;
                        store.trace_instant(
                            "storage",
                            "storage_refused",
                            &[("proc", p.0 as u64)],
                        );
                        (store.stage_delete(key), Frontier::Bottom)
                    }
                }
            };
            // The shrink rides the pending queue like any other
            // marker version: if a later crash discards it
            // unacked, the crash-settle intersection still lands
            // on the shrunk (or deleted) value — matching the
            // truncated mirrors below, which is what
            // availability offers.
            ft.mark_pending.push((seq, durable));
        }
    }
    // The chain ascends, so the kept set is a prefix. Per tag the
    // Ξ tombstone precedes the snapshot-record tombstones (the
    // reachability sweep below), mirroring the write order:
    // suffix loss can orphan a snapshot (collected on reopen),
    // never leave a Ξ whose chain the sweep already gutted.
    // Staged deletion keeps that ordering even against
    // still-queued writes of the same processor.
    let keep = ft.chain.iter().take_while(|c| c.meta.f.is_subset(fp)).count();
    for ts in ft.chain_tags.drain(keep..) {
        store.delete(&Key { proc: p.0, kind: Kind::Meta, tag: ts.tag });
    }
    ft.chain.truncate(keep);
    ft.chain_reported = ft.chain_reported.min(keep);
    crate::ft::harness::sweep_unreachable_snapshots(store, p.0, ft);
    crate::ft::harness::retain_with_tags(
        &mut ft.log,
        &mut ft.log_tags,
        |le| fp.contains(&le.event_time),
        |ts| store.delete(&Key { proc: p.0, kind: Kind::LogEntry, tag: ts.tag }),
    );
    crate::ft::harness::retain_with_tags(
        &mut ft.history,
        &mut ft.history_tags,
        |ev| fp.contains(&ev.time()),
        |ts| store.delete(&Key { proc: p.0, kind: Kind::HistoryEvent, tag: ts.tag }),
    );
    for times in ft.delivered_new.values_mut() {
        times.retain(|lt| fp.contains(&lt.0));
    }
    ft.notified_new.retain(|lt| fp.contains(&lt.0));
    ft.input_new.retain(|lt| fp.contains(&lt.0));
    for pairs in ft.discarded_new.values_mut() {
        pairs.retain(|(evt, _)| fp.contains(evt));
    }
    for v in ft.sent_events.values_mut() {
        v.retain(|t| fp.contains(t));
    }
    if fp.is_bottom() {
        // Initial state: nothing was ever sent.
        ft.sent_total.clear();
    }
    (outcome, regen)
}

/// Reset a full-history processor to H(p)@f by replaying the filtered
/// history through the operator — generic over the engine view like
/// [`rollback_proc_on`] (the replay touches only the processor itself
/// and its own mirror). Returns the regenerated sends (virtual log for
/// Q′). Notification requests regenerated by the replay that were not
/// consumed by replayed notifications are re-armed.
fn replay_history_on<V: FtView>(
    view: &mut V,
    topo: &Topology,
    ft: &ProcFt,
    p: ProcId,
    f: &Frontier,
) -> Vec<(EdgeId, Time, Batch)> {
    view.proc_restore(p).reset();
    let events: Vec<HistoryEvent> =
        ft.history.iter().filter(|ev| f.contains(&ev.time())).cloned().collect();
    let out_edges = topo.out_edges(p).to_vec();
    let summaries: Vec<Summary> =
        out_edges.iter().map(|&e| Summary::of(topo.projection(e))).collect();
    let seq_dst: Vec<bool> = out_edges
        .iter()
        .map(|&e| topo.domain(topo.dst(e)) == crate::time::TimeDomain::Seq)
        .collect();
    let mut sends = Vec::new();
    let mut requested: Vec<Time> = Vec::new();
    let mut consumed: Vec<Time> = Vec::new();
    // Sequence numbering restarts from the history's beginning, just
    // like the original execution did (pre-increment to match
    // `split_staged`: the first record gets `(e, 1)`).
    let mut seq_counts: Vec<u64> = vec![0; out_edges.len()];
    for ev in events {
        let t = ev.time();
        let mut ctx = crate::engine::Ctx::new(t, &out_edges, &summaries, &seq_dst);
        match &ev.kind {
            HistoryKind::Message { edge, time, data } => {
                // Re-deliver the recorded batch whole — replay is
                // byte-identical to the original delivery.
                let port = topo.input_port(*edge);
                view.proc_restore(p).on_batch(port, *time, data.records().to_vec(), &mut ctx);
            }
            HistoryKind::Notification { time } => {
                consumed.push(*time);
                view.proc_restore(p).on_notification(*time, &mut ctx);
            }
            HistoryKind::Input { time, data } => {
                view.proc_restore(p).on_input(*time, data.clone(), &mut ctx);
            }
        }
        let (staged, notify) = ctx.into_parts();
        for (port, batch) in staged {
            let e = out_edges[port];
            if seq_dst[port] {
                // Mirror the engine flush: every record into a seq
                // domain carries its own `(e, s)` time.
                for r in batch.into_records() {
                    let c = &mut seq_counts[port];
                    *c += 1;
                    sends.push((e, t, Batch::one(Time::seq(e, *c), r)));
                }
            } else {
                sends.push((e, t, batch));
            }
        }
        requested.extend(notify);
    }
    // Re-arm unconsumed notification requests.
    for t in consumed {
        if let Some(i) = requested.iter().position(|x| *x == t) {
            requested.swap_remove(i);
        }
    }
    requested.sort_by_key(|t| crate::time::LexTime(*t));
    requested.dedup();
    view.restore_pending(p, requested);
    sends
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Delivery, Processor, Record};
    use crate::graph::{GraphBuilder, Projection};
    use crate::operators::{shared_vec, Buffer, EpochToSeq, Sink, Source, SumByTime};
    use crate::ft::storage::Store;
    use crate::time::TimeDomain;
    use std::sync::Arc;

    /// src(LogOutputs) → sum(Lazy) → buffer(Lazy): the Fig. 3 fragment
    /// with logging upstream so recovery has something to replay.
    fn fig3_system() -> (FtSystem, ProcId, ProcId, ProcId) {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let sum = g.add_proc("sum", TimeDomain::EPOCH);
        let buf = g.add_proc("buffer", TimeDomain::EPOCH);
        g.connect(src, sum, Projection::Identity);
        g.connect(sum, buf, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(SumByTime::default()),
            Box::new(Buffer::default()),
        ];
        let sys = FtSystem::new(
            topo,
            procs,
            vec![
                Policy::LogOutputs,
                Policy::Lazy { every: 1, log_outputs: true },
                Policy::Lazy { every: 1, log_outputs: false },
            ],
            Delivery::Fifo,
            Store::new(1),
        );
        (sys, ProcId(0), ProcId(1), ProcId(2))
    }

    /// Drives two epochs through, then crashes `sum` mid-epoch-1 and
    /// recovers; epoch-0 work must be preserved, epoch 1 replayed.
    #[test]
    fn crash_and_recover_preserves_completed_epoch() {
        let (mut sys, src, sum, _buf) = fig3_system();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(3));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000); // epoch 0 completes; checkpoints taken
        assert_eq!(sys.chain_len(sum), 1);
        // Epoch 1 in flight: delivered to sum but not complete.
        sys.push_input(src, Time::epoch(1), Record::Int(10));
        sys.run_to_quiescence(1000);

        sys.inject_failures(&[sum]);
        let rep = sys.recover();
        // sum restored from its epoch-0 checkpoint.
        assert_eq!(rep.plan.f[sum.0 as usize], Frontier::upto_epoch(0));
        assert!(rep.restored_from_checkpoint >= 1);
        // The epoch-1 message was replayed from src's log.
        assert_eq!(rep.replayed, 1);
        // Finish epoch 1.
        sys.advance_input(src, Time::epoch(2));
        sys.run_to_quiescence(1000);
        // Buffer must hold exactly the two sums: 7 (epoch 0), 10 (epoch 1).
        let blob = sys.engine.proc(ProcId(2)).checkpoint_upto(&Frontier::Top);
        let mut b = Buffer::default();
        b.restore(&blob);
        let contents = b.contents();
        assert_eq!(contents.len(), 2);
        assert_eq!(contents[0].1, vec![Record::kv(0, 7.0)]);
        assert_eq!(contents[1].1, vec![Record::kv(0, 10.0)]);
    }

    /// Root cause (fuzzer: oversized-put fault + forced source
    /// rollback): the §3.6 reset shrinks a logging source's durable
    /// input-frontier marker to the plan frontier with
    /// `stage_put(..).expect("a marker frontier is never oversized")` —
    /// but under a byte limit small enough to refuse a frontier blob
    /// (the same limit whose log refusals force such rollbacks) the
    /// `expect` panicked *mid-recovery*. The refusal must degrade:
    /// delete the durable marker (always expressible, strictly
    /// conservative — a restart then offers ∅ for the source) and count
    /// a storage error.
    #[test]
    fn oversized_marker_shrink_degrades_to_delete() {
        let (mut sys, src, _sum, _buf) = fig3_system();
        for ep in 0..2u64 {
            sys.advance_input(src, Time::epoch(ep));
            sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 + 1));
            sys.advance_input(src, Time::epoch(ep + 1));
            sys.run_to_quiescence(1000);
        }
        let mark_key = Key { proc: src.0, kind: Kind::InputFrontier, tag: 0 };
        assert!(sys.store.get(&mark_key).is_some(), "marker advanced while writable");
        // The oversized-put regime arrives: every value is now refused.
        sys.store.set_max_value_len(2);
        // A plan that keeps the source at epoch 0 (downstream constraints
        // can force this on non-failed sources when a persist gap voids
        // their replay offer).
        let plan = RollbackPlan {
            f: vec![Frontier::upto_epoch(0), Frontier::Top, Frontier::Top],
            f_n: vec![Frontier::upto_epoch(0), Frontier::Top, Frontier::Top],
        };
        let errors_before = sys.stats.storage_errors;
        sys.apply_plan(&plan); // panicked before the fix
        assert_eq!(
            sys.ft[src.0 as usize].input_mark,
            Frontier::upto_epoch(0),
            "in-memory marker reflects the shrink"
        );
        assert!(sys.stats.storage_errors > errors_before, "refusal is counted");
        sys.store.flush_staged();
        assert!(
            sys.store.get(&mark_key).is_none(),
            "durable marker deleted: a stale wide marker must never certify truncated logs"
        );
        // A later crash settles the marker on the conservative ∅ offer.
        sys.inject_failures(&[src]);
        assert!(sys.ft[src.0 as usize].input_mark.is_bottom());
        sys.recover();
    }

    /// Recovered output must equal the failure-free run (the refinement
    /// claim), including when the failure hits *between* checkpoints.
    #[test]
    fn recovered_equals_failure_free() {
        let drive = |fail_at: Option<u64>| -> Vec<(Time, Vec<Record>)> {
            let (mut sys, src, sum, buf) = fig3_system();
            for ep in 0..4u64 {
                sys.advance_input(src, Time::epoch(ep));
                sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 + 1));
                sys.push_input(src, Time::epoch(ep), Record::Int(2 * ep as i64));
                sys.advance_input(src, Time::epoch(ep + 1));
                sys.run_to_quiescence(10_000);
                if fail_at == Some(ep) {
                    sys.inject_failures(&[sum]);
                    sys.recover();
                }
            }
            sys.close_input(src);
            sys.run_to_quiescence(10_000);
            let blob = sys.engine.proc(buf).checkpoint_upto(&Frontier::Top);
            let mut b = Buffer::default();
            b.restore(&blob);
            b.contents()
        };
        let clean = drive(None);
        assert_eq!(clean.len(), 4);
        for ep in 0..4 {
            assert_eq!(clean, drive(Some(ep)), "failure after epoch {ep} diverged");
        }
    }

    /// Failing an ephemeral processor rolls the ephemeral region to ∅ and
    /// the client-retry path (re-pushing inputs) reconverges.
    #[test]
    fn ephemeral_failure_requires_retry() {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let map = g.add_proc("map", TimeDomain::EPOCH);
        let snk = g.add_proc("sink", TimeDomain::EPOCH);
        g.connect(src, map, Projection::Identity);
        g.connect(map, snk, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let out = shared_vec();
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(crate::operators::Map(|r: Record| r)),
            Box::new(Sink(out.clone())),
        ];
        let mut sys = FtSystem::new(
            topo,
            procs,
            vec![Policy::Ephemeral; 3],
            Delivery::Fifo,
            Store::new(1),
        );
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(1));
        // Deliver into map only; map's output to sink still queued.
        sys.step();
        sys.inject_failures(&[ProcId(1)]);
        let rep = sys.recover();
        // Everything ephemeral rolls to ∅: nothing replayed.
        assert_eq!(rep.replayed, 0);
        assert!(rep.plan.f.iter().all(|f| f.is_bottom()));
        // Client retries the batch.
        sys.push_input(src, Time::epoch(0), Record::Int(1));
        sys.close_input(src);
        sys.run_to_quiescence(1000);
        assert_eq!(out.lock().unwrap().len(), 1);
    }

    /// A failed *logging source* resumes at its durable input-frontier
    /// marker instead of ∅: epochs whose capability has passed stay
    /// restorable (their sends are acknowledged in the log), and only
    /// the still-open epoch needs client retry.
    #[test]
    fn failed_logging_source_resumes_at_marker() {
        let (mut sys, src, _sum, buf) = fig3_system();
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(3));
        sys.push_input(src, Time::epoch(0), Record::Int(4));
        sys.advance_input(src, Time::epoch(1)); // closes epoch 0 → marker ↓0
        sys.run_to_quiescence(1000);
        // Epoch 1 pushed but not closed: not covered by the marker.
        sys.push_input(src, Time::epoch(1), Record::Int(10));
        sys.run_to_quiescence(1000);

        sys.inject_failures(&[src]);
        let rep = sys.recover();
        assert_eq!(
            rep.plan.f[src.0 as usize],
            Frontier::upto_epoch(0),
            "source offers its marker frontier, not ∅"
        );
        // Client retry covers exactly the unclosed epoch.
        sys.advance_input(src, Time::epoch(1));
        sys.push_input(src, Time::epoch(1), Record::Int(10));
        sys.advance_input(src, Time::epoch(2));
        sys.run_to_quiescence(1000);
        let blob = sys.engine.proc(buf).checkpoint_upto(&Frontier::Top);
        let mut b = Buffer::default();
        b.restore(&blob);
        let contents = b.contents();
        assert_eq!(contents.len(), 2);
        assert_eq!(contents[0].1, vec![Record::kv(0, 7.0)]);
        assert_eq!(contents[1].1, vec![Record::kv(0, 10.0)]);
    }

    /// Full-history processors replay to a notified frontier.
    #[test]
    fn full_history_replay_restores_state() {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let sum = g.add_proc("sum", TimeDomain::EPOCH);
        let buf = g.add_proc("buffer", TimeDomain::EPOCH);
        g.connect(src, sum, Projection::Identity);
        g.connect(sum, buf, Projection::Identity);
        let topo = Arc::new(g.build().unwrap());
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(SumByTime::default()),
            Box::new(Buffer::default()),
        ];
        let mut sys = FtSystem::new(
            topo,
            procs,
            vec![
                Policy::LogOutputs,
                Policy::FullHistory,
                Policy::Lazy { every: 1, log_outputs: false },
            ],
            Delivery::Fifo,
            Store::new(1),
        );
        let (src, sum) = (ProcId(0), ProcId(1));
        sys.advance_input(src, Time::epoch(0));
        sys.push_input(src, Time::epoch(0), Record::Int(5));
        sys.advance_input(src, Time::epoch(1));
        sys.run_to_quiescence(1000);
        sys.push_input(src, Time::epoch(1), Record::Int(9));
        sys.run_to_quiescence(1000);
        sys.inject_failures(&[sum]);
        let rep = sys.recover();
        // sum replays its history through epoch 0 (the notified frontier)…
        assert_eq!(rep.plan.f[sum.0 as usize], Frontier::upto_epoch(0));
        // …and the epoch-1 message is replayed from src's log.
        assert_eq!(rep.replayed, 1);
        sys.advance_input(src, Time::epoch(2));
        sys.run_to_quiescence(1000);
        let blob = sys.engine.proc(ProcId(2)).checkpoint_upto(&Frontier::Top);
        let mut b = Buffer::default();
        b.restore(&blob);
        let contents = b.contents();
        assert_eq!(contents.len(), 2);
        assert_eq!(contents[0].1, vec![Record::kv(0, 5.0)]);
        assert_eq!(contents[1].1, vec![Record::kv(0, 9.0)]);
    }

    /// The lifted FAILURE_MODES exclusion: a `FullHistory` processor
    /// whose out-edge projects `PerCheckpoint` (a seq-domain consumer).
    /// `history_meta` derives the offer's φ for that edge from
    /// `HistoryEvent::sent_seq` — the exact watermark replay regenerates
    /// — `replay_history` renumbers the regenerated sends from 1 exactly
    /// like the live flush, and `apply_plan` restores the engine's
    /// per-edge counter to the regenerated total, so post-recovery sends
    /// continue the numbering with no gap and no reuse.
    #[test]
    fn full_history_per_checkpoint_out_edge_recovers_exact_watermark() {
        let mut g = GraphBuilder::new();
        let src = g.add_proc("src", TimeDomain::EPOCH);
        let bridge = g.add_proc("bridge", TimeDomain::EPOCH);
        let probe = g.add_proc("probe", TimeDomain::Seq);
        g.connect(src, bridge, Projection::Identity);
        let seq_edge = g.connect(bridge, probe, Projection::PerCheckpoint);
        let topo = Arc::new(g.build().unwrap());
        let procs: Vec<Box<dyn Processor>> = vec![
            Box::new(Source),
            Box::new(EpochToSeq::default()),
            Box::new(Buffer::default()),
        ];
        let mut sys = FtSystem::new(
            topo,
            procs,
            vec![Policy::LogOutputs, Policy::FullHistory, Policy::Eager],
            Delivery::Fifo,
            Store::new(1),
        );
        let (src, bridge) = (ProcId(0), ProcId(1));
        for ep in 0..2u64 {
            sys.advance_input(src, Time::epoch(ep));
            for v in 0..3i64 {
                sys.push_input(src, Time::epoch(ep), Record::Int(ep as i64 * 10 + v));
            }
            sys.advance_input(src, Time::epoch(ep + 1));
            sys.run_to_quiescence(10_000);
        }
        assert_eq!(sys.engine.seq_counter(seq_edge), 6);
        sys.inject_failures(&[bridge]);
        sys.recover();
        // Both epochs were notified before the crash, so the whole
        // history is retained and replay regenerates all six sends — the
        // counter lands exactly where the live run left it.
        assert_eq!(
            sys.engine.seq_counter(seq_edge),
            6,
            "counter must be restored to the regenerated total"
        );
        // One more epoch: numbering continues at 7..9, and the eager
        // probe (which deduplicated the regenerated 1..6) holds every
        // sequence number exactly once.
        sys.advance_input(src, Time::epoch(2));
        for v in 0..3i64 {
            sys.push_input(src, Time::epoch(2), Record::Int(20 + v));
        }
        sys.advance_input(src, Time::epoch(3));
        sys.close_input(src);
        sys.run_to_quiescence(10_000);
        assert_eq!(sys.engine.seq_counter(seq_edge), 9);
        let blob = sys.engine.proc(ProcId(2)).checkpoint_upto(&Frontier::Top);
        let mut b = Buffer::default();
        b.restore(&blob);
        let seqs: Vec<u64> = b.contents().iter().map(|(t, _)| t.seq_of()).collect();
        assert_eq!(
            seqs,
            (1..=9).collect::<Vec<u64>>(),
            "seq consumer must observe every number exactly once, in order"
        );
    }
}
