//! Frontiers: downward-closed sets of logical times (§3.1).
//!
//! A rollback restores each processor to the state reflecting exactly the
//! events whose times lie inside a chosen *frontier*. A frontier must be
//! downward-closed: `t ∈ f ∧ t' ≤ t ⇒ t' ∈ f`. We represent frontiers
//! compactly per time domain:
//!
//! - **Seq domain**: a per-edge high watermark `e ↦ s`, denoting
//!   `{(e,1),…,(e,s)}` for each edge — exactly the paper's
//!   `f^s_{e₁…eₙ}(s₁,…,sₙ)` (Fig. 2a).
//! - **Structured domain**: an *antichain* of maximal elements; the
//!   frontier is the union of their down-sets. Loop coordinates may be
//!   [`CTR_INF`](crate::time::CTR_INF) to express "all iterations".
//! - [`Frontier::Bottom`] is ∅ and [`Frontier::Top`] is ⊤, the special
//!   frontier containing all event times that §4.4 temporarily adds to
//!   `F*(p)` for non-failed processors.
//!
//! All §3.5 consistency constraints reduce to [`Frontier::contains`] and
//! [`Frontier::is_subset`]; the Fig. 6 fixed point additionally uses
//! [`Frontier::intersect`] and [`Frontier::union`].

use crate::graph::EdgeId;
use crate::time::{Time, TimeDomain};
use crate::util::ser::{Decode, Encode, Reader, SerError, Writer};
use std::collections::BTreeMap;

/// A downward-closed set of logical times. See module docs.
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum Frontier {
    /// The empty frontier ∅ (roll back to initial state).
    Bottom,
    /// The full frontier ⊤ (keep everything; §4.4).
    Top,
    /// Seq-domain frontier: per-edge high watermarks (seq numbers start
    /// at 1; a watermark of `s` contains `(e,1)..(e,s)`). Edges absent
    /// from the map contribute no times. Invariant: no zero watermarks.
    Seq(BTreeMap<EdgeId, u64>),
    /// Structured-domain frontier: antichain of maximal elements, all of
    /// the same depth. Invariant: nonempty, mutually incomparable.
    Structured { depth: u8, maximal: Vec<Time> },
}

impl Frontier {
    /// The ∅ frontier.
    pub fn bottom() -> Frontier {
        Frontier::Bottom
    }

    /// The ⊤ frontier.
    pub fn top() -> Frontier {
        Frontier::Top
    }

    /// Seq-domain frontier from explicit watermarks (zeroes are dropped).
    pub fn seq_watermarks<I: IntoIterator<Item = (EdgeId, u64)>>(iter: I) -> Frontier {
        let m: BTreeMap<EdgeId, u64> = iter.into_iter().filter(|(_, s)| *s > 0).collect();
        if m.is_empty() {
            Frontier::Bottom
        } else {
            Frontier::Seq(m)
        }
    }

    /// The frontier ↓{t}: all times ≤ t.
    pub fn below(t: Time) -> Frontier {
        match t {
            Time::Seq { edge, seq } => Frontier::seq_watermarks([(edge, seq)]),
            Time::Structured { loops, .. } => {
                Frontier::Structured { depth: loops.depth() as u8, maximal: vec![t] }
            }
        }
    }

    /// Downward closure ↓T of an arbitrary set of times (§3.1). All times
    /// must share a domain.
    pub fn down_close<I: IntoIterator<Item = Time>>(times: I) -> Frontier {
        let mut f = Frontier::Bottom;
        for t in times {
            f.insert(t);
        }
        f
    }

    /// Epoch-domain frontier containing epochs `0..=e`.
    pub fn upto_epoch(e: u64) -> Frontier {
        Frontier::below(Time::epoch(e))
    }

    /// Whether this is the empty frontier.
    pub fn is_bottom(&self) -> bool {
        matches!(self, Frontier::Bottom)
    }

    /// Whether this is the full frontier.
    pub fn is_top(&self) -> bool {
        matches!(self, Frontier::Top)
    }

    /// Membership test `t ∈ f`.
    pub fn contains(&self, t: &Time) -> bool {
        match self {
            Frontier::Bottom => false,
            Frontier::Top => true,
            Frontier::Seq(wm) => match t {
                Time::Seq { edge, seq } => wm.get(edge).map(|s| *seq <= *s).unwrap_or(false),
                _ => false,
            },
            Frontier::Structured { maximal, .. } => maximal.iter().any(|m| t.le(m)),
        }
    }

    /// Subset test `self ⊆ other`. Frontiers of different concrete domains
    /// are only related through Bottom/Top.
    pub fn is_subset(&self, other: &Frontier) -> bool {
        match (self, other) {
            (Frontier::Bottom, _) => true,
            (_, Frontier::Top) => true,
            (Frontier::Top, _) => false,
            (_, Frontier::Bottom) => false,
            (Frontier::Seq(a), Frontier::Seq(b)) => {
                a.iter().all(|(e, s)| b.get(e).map(|s2| s <= s2).unwrap_or(false))
            }
            (Frontier::Structured { maximal: a, .. }, f @ Frontier::Structured { .. }) => {
                a.iter().all(|t| f.contains(t))
            }
            _ => false,
        }
    }

    /// Insert `↓{t}` into this frontier (mutating union).
    pub fn insert(&mut self, t: Time) {
        match self {
            Frontier::Top => {}
            Frontier::Bottom => *self = Frontier::below(t),
            Frontier::Seq(wm) => {
                if let Time::Seq { edge, seq } = t {
                    let w = wm.entry(edge).or_insert(0);
                    *w = (*w).max(seq);
                } else {
                    panic!("inserting structured time into seq frontier");
                }
            }
            Frontier::Structured { depth, maximal } => {
                let lt = t.loops_of();
                assert_eq!(lt.depth() as u8, *depth, "inserting time of wrong depth");
                if maximal.iter().any(|m| t.le(m)) {
                    return; // already contained
                }
                maximal.retain(|m| !m.le(&t));
                maximal.push(t);
            }
        }
    }

    /// Union of two frontiers (least upper bound in the subset lattice).
    pub fn union(&self, other: &Frontier) -> Frontier {
        match (self, other) {
            (Frontier::Top, _) | (_, Frontier::Top) => Frontier::Top,
            (Frontier::Bottom, f) | (f, Frontier::Bottom) => f.clone(),
            (Frontier::Seq(a), Frontier::Seq(b)) => {
                let mut m = a.clone();
                for (e, s) in b {
                    let w = m.entry(*e).or_insert(0);
                    *w = (*w).max(*s);
                }
                Frontier::Seq(m)
            }
            (Frontier::Structured { depth: d1, maximal: a }, Frontier::Structured { depth: d2, maximal: b }) => {
                assert_eq!(d1, d2, "union of different structured depths");
                let mut f = Frontier::Structured { depth: *d1, maximal: a.clone() };
                for t in b {
                    f.insert(*t);
                }
                f
            }
            _ => panic!("union of frontiers from different domains"),
        }
    }

    /// Intersection of two frontiers (greatest lower bound).
    pub fn intersect(&self, other: &Frontier) -> Frontier {
        match (self, other) {
            (Frontier::Top, f) | (f, Frontier::Top) => f.clone(),
            (Frontier::Bottom, _) | (_, Frontier::Bottom) => Frontier::Bottom,
            (Frontier::Seq(a), Frontier::Seq(b)) => Frontier::seq_watermarks(
                a.iter().filter_map(|(e, s)| b.get(e).map(|s2| (*e, (*s).min(*s2)))),
            ),
            (Frontier::Structured { depth: d1, maximal: a }, Frontier::Structured { depth: d2, maximal: b }) => {
                assert_eq!(d1, d2, "intersect of different structured depths");
                // Intersection of unions of down-sets = union of pairwise
                // meets of the maxima.
                let mut f = Frontier::Bottom;
                for ta in a {
                    for tb in b {
                        if let Some(m) = ta.meet(tb) {
                            f.insert(m);
                        }
                    }
                }
                f
            }
            _ => panic!("intersect of frontiers from different domains"),
        }
    }

    /// The maximal elements of a structured frontier (the antichain).
    /// Panics for seq frontiers; Bottom yields empty, Top panics.
    pub fn maximal_elements(&self) -> Vec<Time> {
        match self {
            Frontier::Bottom => Vec::new(),
            Frontier::Structured { maximal, .. } => maximal.clone(),
            Frontier::Top => panic!("maximal_elements of ⊤"),
            Frontier::Seq(wm) => {
                wm.iter().map(|(e, s)| Time::seq(*e, *s)).collect()
            }
        }
    }

    /// Seq-domain watermark for edge `e` (0 if absent / Bottom). Panics on
    /// structured frontiers.
    pub fn watermark(&self, e: EdgeId) -> u64 {
        match self {
            Frontier::Bottom => 0,
            Frontier::Top => u64::MAX,
            Frontier::Seq(wm) => wm.get(&e).copied().unwrap_or(0),
            Frontier::Structured { .. } => panic!("watermark of a structured frontier"),
        }
    }

    /// For a totally-ordered (epoch) frontier: the largest epoch, if any.
    /// Panics if the frontier has loop coordinates.
    pub fn max_epoch(&self) -> Option<u64> {
        match self {
            Frontier::Bottom => None,
            Frontier::Top => Some(u64::MAX),
            Frontier::Structured { depth: 0, maximal } => {
                maximal.iter().map(|t| t.epoch_of()).max()
            }
            _ => panic!("max_epoch of non-epoch frontier"),
        }
    }

    /// The concrete time domain, if determined (Bottom/Top fit any).
    pub fn domain(&self) -> Option<TimeDomain> {
        match self {
            Frontier::Bottom | Frontier::Top => None,
            Frontier::Seq(_) => Some(TimeDomain::Seq),
            Frontier::Structured { depth, .. } => Some(TimeDomain::Structured { depth: *depth }),
        }
    }
}

impl std::fmt::Display for Frontier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Frontier::Bottom => write!(f, "∅"),
            Frontier::Top => write!(f, "⊤"),
            Frontier::Seq(wm) => {
                write!(f, "{{")?;
                for (i, (e, s)) in wm.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "e{}≤{}", e.0, s)?;
                }
                write!(f, "}}")
            }
            Frontier::Structured { maximal, .. } => {
                write!(f, "↓{{")?;
                for (i, t) in maximal.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{t}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl Encode for Frontier {
    fn encode(&self, w: &mut Writer) {
        match self {
            Frontier::Bottom => w.u8(0),
            Frontier::Top => w.u8(1),
            Frontier::Seq(wm) => {
                w.u8(2);
                w.varint(wm.len() as u64);
                for (e, s) in wm {
                    w.varint(e.0 as u64);
                    w.varint(*s);
                }
            }
            Frontier::Structured { depth, maximal } => {
                w.u8(3);
                w.u8(*depth);
                w.varint(maximal.len() as u64);
                for t in maximal {
                    t.encode(w);
                }
            }
        }
    }
}

impl Decode for Frontier {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        match r.u8()? {
            0 => Ok(Frontier::Bottom),
            1 => Ok(Frontier::Top),
            2 => {
                let n = r.varint()? as usize;
                let mut m = BTreeMap::new();
                for _ in 0..n {
                    let e = EdgeId(r.varint()? as u32);
                    let s = r.varint()?;
                    m.insert(e, s);
                }
                Ok(if m.is_empty() { Frontier::Bottom } else { Frontier::Seq(m) })
            }
            _ => {
                let depth = r.u8()?;
                let n = r.varint()? as usize;
                let mut maximal = Vec::with_capacity(n);
                for _ in 0..n {
                    maximal.push(Time::decode(r)?);
                }
                Ok(if maximal.is_empty() {
                    Frontier::Bottom
                } else {
                    Frontier::Structured { depth, maximal }
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::CTR_INF;

    fn e(i: u32) -> EdgeId {
        EdgeId(i)
    }

    #[test]
    fn fig2a_seq_frontier() {
        // Fig. 2(a): f(p) = f^s_{e1,e2}(4,7).
        let f = Frontier::seq_watermarks([(e(1), 4), (e(2), 7)]);
        assert!(f.contains(&Time::seq(e(1), 4)));
        assert!(f.contains(&Time::seq(e(2), 1)));
        assert!(!f.contains(&Time::seq(e(1), 5)));
        assert!(!f.contains(&Time::seq(e(3), 1)));
        assert_eq!(f.watermark(e(1)), 4);
        assert_eq!(f.watermark(e(3)), 0);
    }

    #[test]
    fn epoch_frontier_downward_closed() {
        let f = Frontier::upto_epoch(2);
        for ep in 0..=2 {
            assert!(f.contains(&Time::epoch(ep)));
        }
        assert!(!f.contains(&Time::epoch(3)));
    }

    #[test]
    fn down_close_removes_dominated() {
        let f = Frontier::down_close([
            Time::structured(1, &[2]),
            Time::structured(1, &[1]), // dominated
            Time::structured(0, &[5]),
        ]);
        match &f {
            Frontier::Structured { maximal, .. } => {
                assert_eq!(maximal.len(), 2);
                assert!(maximal.contains(&Time::structured(1, &[2])));
                assert!(maximal.contains(&Time::structured(0, &[5])));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(f.contains(&Time::structured(1, &[1])));
        assert!(f.contains(&Time::structured(0, &[3])));
        assert!(!f.contains(&Time::structured(1, &[3])));
    }

    #[test]
    fn subset_laws() {
        let small = Frontier::upto_epoch(1);
        let big = Frontier::upto_epoch(5);
        assert!(small.is_subset(&big));
        assert!(!big.is_subset(&small));
        assert!(Frontier::Bottom.is_subset(&small));
        assert!(small.is_subset(&Frontier::Top));
        assert!(!Frontier::Top.is_subset(&small));
        assert!(small.is_subset(&small));
    }

    #[test]
    fn seq_subset() {
        let a = Frontier::seq_watermarks([(e(0), 3)]);
        let b = Frontier::seq_watermarks([(e(0), 5), (e(1), 2)]);
        assert!(a.is_subset(&b));
        assert!(!b.is_subset(&a));
    }

    #[test]
    fn union_intersect_seq() {
        let a = Frontier::seq_watermarks([(e(0), 3), (e(1), 9)]);
        let b = Frontier::seq_watermarks([(e(0), 5), (e(2), 1)]);
        let u = a.union(&b);
        assert_eq!(u.watermark(e(0)), 5);
        assert_eq!(u.watermark(e(1)), 9);
        assert_eq!(u.watermark(e(2)), 1);
        let i = a.intersect(&b);
        assert_eq!(i.watermark(e(0)), 3);
        assert_eq!(i.watermark(e(1)), 0);
    }

    #[test]
    fn union_intersect_structured() {
        let a = Frontier::down_close([Time::structured(1, &[3])]);
        let b = Frontier::down_close([Time::structured(3, &[1])]);
        let u = a.union(&b);
        assert!(u.contains(&Time::structured(1, &[3])));
        assert!(u.contains(&Time::structured(3, &[1])));
        assert!(!u.contains(&Time::structured(3, &[3])));
        let i = a.intersect(&b);
        // meet((1,3),(3,1)) = (1,1)
        assert!(i.contains(&Time::structured(1, &[1])));
        assert!(!i.contains(&Time::structured(1, &[2])));
    }

    #[test]
    fn intersect_with_bottom_top() {
        let a = Frontier::upto_epoch(4);
        assert_eq!(a.intersect(&Frontier::Top), a);
        assert_eq!(a.intersect(&Frontier::Bottom), Frontier::Bottom);
        assert_eq!(a.union(&Frontier::Bottom), a);
        assert_eq!(a.union(&Frontier::Top), Frontier::Top);
    }

    #[test]
    fn ctr_inf_frontier_covers_all_iterations() {
        // Loop-ingress projection: {(t, c) : t ∈ f, all c} (§3.2, Fig 2c).
        let f = Frontier::down_close([Time::structured(1, &[CTR_INF])]);
        assert!(f.contains(&Time::structured(1, &[0])));
        assert!(f.contains(&Time::structured(0, &[999_999])));
        assert!(!f.contains(&Time::structured(2, &[0])));
    }

    #[test]
    fn max_epoch() {
        assert_eq!(Frontier::upto_epoch(7).max_epoch(), Some(7));
        assert_eq!(Frontier::Bottom.max_epoch(), None);
    }

    #[test]
    fn encode_roundtrip() {
        for f in [
            Frontier::Bottom,
            Frontier::Top,
            Frontier::seq_watermarks([(e(0), 3), (e(9), 1)]),
            Frontier::down_close([Time::structured(1, &[2]), Time::structured(2, &[0])]),
        ] {
            let bytes = f.to_bytes();
            assert_eq!(Frontier::from_bytes(&bytes).unwrap(), f);
        }
    }

    #[test]
    fn insert_keeps_antichain_invariant() {
        let mut f = Frontier::Bottom;
        f.insert(Time::structured(5, &[5]));
        f.insert(Time::structured(1, &[1])); // dominated, ignored
        f.insert(Time::structured(5, &[5])); // duplicate
        match &f {
            Frontier::Structured { maximal, .. } => assert_eq!(maximal.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }
}
