//! Seeded generation of fault schedules (fuzzer stage 2 — see the
//! [module docs](crate::fuzz)).
//!
//! This is where the previously example-only [`crate::failure`] types
//! earn their keep: a [`FailureSchedule`] places multi-victim crashes in
//! virtual event time, a [`DetectorModel`] delays when the driver is
//! allowed to *act* on them (§4.4's "only when a failure detector
//! confirms"), and the surrounding [`FaultPlan`] layers on the faults
//! the schedule alone cannot express — cold restarts from the durable
//! WAL, torn segment tails, staged-but-unacknowledged tail discards,
//! oversized-value limits, and a second failure injected between a
//! recovery and the drain that follows it.
//!
//! The catalog of what each fault means and which invariants it may
//! legitimately weaken lives in `rust/src/fuzz/FAILURE_MODES.md`.

use crate::failure::{DetectorModel, FailureSchedule};
use crate::fuzz::gen::{Knobs, Shape};
use crate::ft::PersistMode;
use crate::graph::ProcId;
use crate::util::rng::Rng;

/// Cold crash-restart: drop the process after draining `after_epoch`,
/// [`crate::ft::Store::simulate_crash`] the store (the buffered WAL tail
/// dies), optionally chop the newest segment mid-record, then
/// `reopen_sharded` against a fresh `Store::open_dir`.
#[derive(Clone, Debug)]
pub struct Restart {
    /// Restart after this epoch has been offered and drained (1-based
    /// into the run, always < `shape.epochs` so the run continues).
    pub after_epoch: u64,
    /// Chop this many bytes off the newest WAL segment before reopening
    /// (0 = clean crash; >0 = torn tail, the power-loss model).
    pub torn_bytes: u64,
}

/// Pause the staged-persistence writer for one epoch. With a `victim`,
/// that processor is crashed at the end of the paused epoch — its
/// staged-but-unacknowledged tail is discarded by
/// [`crate::ft::FtSystem::inject_failures`] and recovery must fall back
/// to the acked prefix (the async pipeline's "staged is not durable"
/// window). Without one, the pause just drains late, exercising the
/// ack-lag bookkeeping.
#[derive(Clone, Debug)]
pub struct Pause {
    /// Pause before offering this epoch; resume after its drain.
    pub epoch: u64,
    /// Crash this processor at the paused epoch's drain boundary.
    pub victim: Option<ProcId>,
}

/// Impose a store-level value-size limit from a given epoch on, making
/// large checkpoint/log writes fail (counted, not fatal — the fix in
/// [`crate::ft::recovery`] for the marker-shrink path came out of this
/// fault).
#[derive(Clone, Debug)]
pub struct Oversize {
    /// Apply `Store::set_max_value_len` just before this epoch.
    pub from_epoch: u64,
    /// The byte limit.
    pub limit: usize,
}

/// Everything the driver will do to one run, drawn from the seed stream.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Crashes in virtual event time (consumed via
    /// [`FailureSchedule::due`] against the engine's processed-event
    /// counter, shifted by the detector's confirmation delay).
    pub crashes: FailureSchedule,
    /// Human-readable copy of the schedule for logs/digests (the live
    /// schedule is consumed as it fires).
    pub crash_desc: String,
    /// §4.4 failure detector: a crash at event `t` is acted on at
    /// `t + confirmation_delay()`.
    pub detector: DetectorModel,
    /// Second victim, injected *after* a recovery completes and before
    /// the post-recovery drain (the double-failure window).
    pub double_with: Option<ProcId>,
    pub restart: Option<Restart>,
    pub pause: Option<Pause>,
    pub oversize: Option<Oversize>,
}

impl FaultPlan {
    /// Draw a fault plan. `candidates` is every physical processor (any
    /// of them may crash — the solver owes a consistent frontier for an
    /// arbitrary victim set). The horizon is a generous estimate of the
    /// run's event count; crashes scheduled past the actual end simply
    /// never fire.
    pub fn generate(rng: &mut Rng, shape: &Shape, candidates: &[ProcId]) -> FaultPlan {
        let horizon = shape.epochs * (shape.records_per_epoch as u64 + 4) * 8;
        let n_crashes = rng.index(3);
        let crashes = FailureSchedule::random(rng.next_u64(), n_crashes, horizon, candidates);
        let crash_desc = format!("{crashes:?}");
        let detector = if rng.chance(0.5) {
            DetectorModel { heartbeat: 1 + rng.below(8), misses: 1 + rng.below(3) }
        } else {
            // Instant confirmation: act on the crash the step it happens.
            DetectorModel { heartbeat: 0, misses: 0 }
        };
        let double_with = if !crashes.is_empty() && rng.chance(0.3) {
            Some(*rng.choose(candidates))
        } else {
            None
        };
        let restart = (shape.epochs > 1 && rng.chance(0.35)).then(|| Restart {
            after_epoch: rng.range(1, shape.epochs),
            torn_bytes: if rng.chance(0.4) { 1 + rng.below(40) } else { 0 },
        });
        let pause = rng.chance(0.25).then(|| Pause {
            epoch: rng.below(shape.epochs),
            victim: (rng.chance(0.5) && !candidates.is_empty())
                .then(|| *rng.choose(candidates)),
        });
        let oversize = rng.chance(0.2).then(|| Oversize {
            from_epoch: rng.below(shape.epochs),
            limit: 96 + rng.index(160) * 8,
        });
        FaultPlan { crashes, crash_desc, detector, double_with, restart, pause, oversize }
    }

    /// Make the knobs able to host this plan: a cold restart or torn
    /// tail needs a durable file-backed store, and pausing the staged
    /// writer only means anything under asynchronous persistence. A
    /// *torn-tail* restart also turns the GC monitor off: garbage
    /// collection is sound against acknowledged durability, while a torn
    /// tail deliberately destroys acknowledged-but-unsynced bytes —
    /// state the external service would have been told it may forget
    /// (see `FAILURE_MODES.md`). Clean cold restarts run with whatever
    /// `gc` was drawn: reopen's conservative chain repair plus the
    /// snapshot reachability sweep make a GC'd-then-crashed store a
    /// recoverable one, and compaction folding the cold prefix into
    /// per-processor snapshot records is itself machinery GC+restart
    /// runs must exercise. The reconciliation is deterministic, so it is
    /// part of the seed → run mapping rather than a violation of it.
    pub fn reconcile(&self, knobs: &mut Knobs) {
        if let Some(r) = &self.restart {
            knobs.durable = true;
            if r.torn_bytes > 0 {
                knobs.gc = false;
            }
        }
        if self.pause.is_some() {
            if let PersistMode::Sync = knobs.persist_mode {
                knobs.persist_mode = PersistMode::Async { ack_every: 4 };
            }
        }
    }

    /// Whether this plan injects any fault at all (a fault-free run is a
    /// valid draw: it doubles as the determinism check for the knobs).
    pub fn is_quiet(&self) -> bool {
        self.crashes.is_empty()
            && self.restart.is_none()
            && self.pause.as_ref().map_or(true, |p| p.victim.is_none())
            && self.oversize.is_none()
    }

    /// Compact single-line description (campaign logs, corpus records).
    pub fn describe(&self) -> String {
        format!(
            "crashes={} detector={} double={:?} restart={:?} pause={:?} oversize={:?}",
            self.crash_desc,
            self.detector.confirmation_delay(),
            self.double_with,
            self.restart.as_ref().map(|r| (r.after_epoch, r.torn_bytes)),
            self.pause.as_ref().map(|p| (p.epoch, p.victim)),
            self.oversize.as_ref().map(|o| (o.from_epoch, o.limit)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::gen;

    fn plan_for(seed: u64) -> (Shape, FaultPlan) {
        let mut rng = Rng::new(seed);
        let shape = Shape::generate(&mut rng);
        let _knobs = Knobs::generate(&mut rng, &shape);
        let cands: Vec<ProcId> = (0..5).map(ProcId).collect();
        let plan = FaultPlan::generate(&mut rng, &shape, &cands);
        (shape, plan)
    }

    #[test]
    fn fault_plans_are_seed_deterministic() {
        for seed in [0u64, 1, 7, 42, 4096] {
            let (_, a) = plan_for(seed);
            let (_, b) = plan_for(seed);
            assert_eq!(a.describe(), b.describe());
        }
    }

    #[test]
    fn restarts_stay_inside_the_run() {
        for seed in 0..300u64 {
            let (shape, plan) = plan_for(seed);
            if let Some(r) = &plan.restart {
                assert!(r.after_epoch >= 1 && r.after_epoch < shape.epochs);
            }
            if let Some(p) = &plan.pause {
                assert!(p.epoch < shape.epochs);
            }
        }
    }

    #[test]
    fn reconcile_forces_durability_and_async() {
        for seed in 0..300u64 {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let mut knobs = gen::Knobs::generate(&mut rng, &shape);
            let cands: Vec<ProcId> = (0..4).map(ProcId).collect();
            let plan = FaultPlan::generate(&mut rng, &shape, &cands);
            let gc_drawn = knobs.gc;
            plan.reconcile(&mut knobs);
            if let Some(r) = &plan.restart {
                assert!(knobs.durable);
                if r.torn_bytes > 0 {
                    assert!(!knobs.gc, "GC must be off when the restart tears the WAL");
                } else {
                    assert_eq!(
                        knobs.gc, gc_drawn,
                        "clean cold restarts keep the drawn GC knob (lifted restriction)"
                    );
                }
            }
            if plan.pause.is_some() {
                assert!(matches!(knobs.persist_mode, PersistMode::Async { .. }));
            }
        }
    }

    /// The corner that used to panic end-to-end: a plan drawn against an
    /// empty candidate set (degenerate topology) must be quiet, not UB.
    #[test]
    fn empty_candidates_yield_quiet_crash_schedule() {
        let mut rng = Rng::new(9);
        let shape = Shape::generate(&mut rng);
        let plan = FaultPlan::generate(&mut rng, &shape, &[]);
        assert!(plan.crashes.is_empty());
        assert!(plan.double_with.is_none());
    }
}
