//! Invariant checks the fuzzer asserts after every drain and every
//! recovery (fuzzer stage 3 — see the [module docs](crate::fuzz)).
//!
//! The headline oracle — byte-equality of the sink against a no-fault
//! reference run — lives in the driver, because it needs both runs in
//! hand. This module holds the *structural* invariants, checkable on a
//! single live [`FtSystem`]:
//!
//! 1. **Mirror shape** — every durable mirror and its tag vector are
//!    parallel (`chain`/`chain_tags`, `log`/`log_tags`), and checkpoint
//!    chain frontiers ascend (F*(p) is a chain, §3.4).
//! 2. **Ack ordering** — per processor, the acknowledged staging
//!    sequence never exceeds the staged one.
//! 3. **Mirror ⊆ offered** — what [`FtSystem::availability`] offers the
//!    solver for a non-failed chain processor is *exactly* its
//!    acknowledged mirror prefix plus the live ⊤: every acked
//!    checkpoint is offered (losing one would roll back further than
//!    necessary), and nothing unacked is offered (offering one would
//!    restore from a checkpoint a crash may not have persisted).
//! 4. **GC ≤ acked** — the §4.2 monitor's low watermark for a chain
//!    processor stays at or below its newest *acknowledged* checkpoint
//!    frontier; the monitor must never authorize collecting state a
//!    recovery could still need, nor run ahead of durability.
//! 5. **Resident accounting** — the store's O(1) `resident_bytes`
//!    counter agrees with a fresh scan of every processor's entries.
//!
//! 6. **Trace/counter consistency** — every faulted run carries a
//!    [`Tracer`]; after each recovery the newest `"recovery"` span must
//!    agree with the [`RecoveryReport`] it described and the cumulative
//!    [`FtSystem`] counters at its close
//!    ([`recovery_span_violations`]), and at end of run the trace's
//!    totals (replayed messages, rolled-back processors, refused
//!    writes, checkpoints) must reconcile with the `FtStats` deltas
//!    since the tracer attached ([`counter_violations`]) — the
//!    observability layer and the counters are two recordings of one
//!    execution and must never disagree.
//!
//! Violations come back as strings (one per finding) rather than
//! panics, so the campaign driver can attribute them to a seed and keep
//! going.

use crate::ft::harness::acked_prefix;
use crate::ft::monitor::Monitor;
use crate::ft::recovery::RecoveryReport;
use crate::ft::{Available, FtSystem};
use crate::frontier::Frontier;
use crate::trace::Tracer;

/// Run every single-system structural invariant. `mon` is the campaign's
/// GC monitor when the run drives one (invariant 4 needs it).
pub fn structural_violations(sys: &FtSystem, mon: Option<&Monitor>) -> Vec<String> {
    let mut v = Vec::new();
    let avail = sys.availability();

    for p in sys.topo.proc_ids() {
        let i = p.0 as usize;
        let ft = &sys.ft[i];

        // 1. Mirror shape.
        if ft.chain.len() != ft.chain_tags.len() {
            v.push(format!(
                "proc {}: chain mirror {} entries but {} tags",
                p.0,
                ft.chain.len(),
                ft.chain_tags.len()
            ));
        }
        if ft.log.len() != ft.log_tags.len() {
            v.push(format!(
                "proc {}: log mirror {} entries but {} tags",
                p.0,
                ft.log.len(),
                ft.log_tags.len()
            ));
        }
        for w in ft.chain.windows(2) {
            if !w[0].meta.f.is_subset(&w[1].meta.f) {
                v.push(format!(
                    "proc {}: chain frontiers not ascending: {:?} ⊄ {:?}",
                    p.0, w[0].meta.f, w[1].meta.f
                ));
            }
        }

        // 2. Ack ordering.
        let (acked_w, staged_w) = (sys.store.acked_seq(p.0), sys.store.staged_seq(p.0));
        if acked_w > staged_w {
            v.push(format!(
                "proc {}: acked seq {} ahead of staged seq {}",
                p.0, acked_w, staged_w
            ));
        }

        // 3. Offered chain == acked mirror prefix (+ live ⊤ when alive).
        if ft.policy.has_chain() && ft.chain.len() == ft.chain_tags.len() {
            let acked = acked_prefix(&ft.chain_tags, acked_w);
            if let Available::Chain { chain: offered, .. } = &avail[i] {
                let expect = if ft.failed { acked } else { acked + 1 };
                if offered.len() != expect {
                    v.push(format!(
                        "proc {}: offers {} frontiers, expected {} (acked prefix {}{})",
                        p.0,
                        offered.len(),
                        expect,
                        acked,
                        if ft.failed { "" } else { " + live ⊤" }
                    ));
                } else {
                    for (k, meta) in offered.iter().take(acked).enumerate() {
                        if meta.f != ft.chain[k].meta.f {
                            v.push(format!(
                                "proc {}: offered frontier {k} is {:?}, mirror has {:?}",
                                p.0, meta.f, ft.chain[k].meta.f
                            ));
                        }
                    }
                    if !ft.failed && offered.last().map(|m| &m.f) != Some(&Frontier::Top) {
                        v.push(format!("proc {}: live chain proc does not offer ⊤", p.0));
                    }
                }
            } else {
                v.push(format!("proc {}: chain policy but non-chain availability", p.0));
            }

            // 4. GC watermark ≤ newest acked checkpoint frontier.
            if let Some(mon) = mon {
                let ceiling = ft
                    .chain
                    .get(acked.wrapping_sub(1))
                    .map(|c| c.meta.f.clone())
                    .unwrap_or(Frontier::Bottom);
                let wm = mon.low_watermark(p);
                if !wm.is_subset(&ceiling) {
                    v.push(format!(
                        "proc {}: GC watermark {:?} above acked ceiling {:?}",
                        p.0, wm, ceiling
                    ));
                }
            }
        }
    }

    // 5. Resident-byte accounting vs a fresh scan.
    let scanned: u64 = sys
        .store
        .procs()
        .into_iter()
        .map(|p| sys.store.scan_entries(p).into_iter().map(|(_, n)| n).sum::<u64>())
        .sum();
    let resident = sys.store.resident_bytes();
    if scanned != resident {
        v.push(format!(
            "store: resident_bytes {resident} disagrees with fresh scan {scanned}"
        ));
    }

    v
}

/// Snapshot of the reconcilable [`crate::ft::FtStats`] counters at the
/// moment a tracer attaches to a system; [`counter_violations`] holds
/// the trace to the *deltas* from here (a cold restart rebuilds the
/// system and attaches a fresh tracer after its reopen-recovery already
/// ran, so absolute totals would not line up).
#[derive(Clone, Copy, Debug, Default)]
pub struct CounterBase {
    pub messages_replayed: u64,
    pub procs_rolled_back: u64,
    pub storage_errors: u64,
    pub checkpoints_taken: u64,
}

impl CounterBase {
    pub fn snapshot(sys: &FtSystem) -> CounterBase {
        CounterBase {
            messages_replayed: sys.stats.messages_replayed,
            procs_rolled_back: sys.stats.procs_rolled_back,
            storage_errors: sys.stats.storage_errors,
            checkpoints_taken: sys.stats.checkpoints_taken,
        }
    }
}

/// Invariant 6a, checked immediately after each in-process recovery:
/// the newest traced `"recovery"` span carries the same counts as the
/// [`RecoveryReport`] the recovery returned, and its running totals
/// match the live [`FtSystem`] counters at span close.
pub fn recovery_span_violations(
    tracer: &Tracer,
    report: &RecoveryReport,
    sys: &FtSystem,
) -> Vec<String> {
    let mut v = Vec::new();
    let evs = tracer.events();
    let Some(span) = evs
        .iter()
        .filter(|e| e.cat == "recovery" && e.name == "recovery" && e.dur_ns > 0)
        .max_by_key(|e| e.ts_ns)
    else {
        v.push("completed recovery left no recovery span in the trace".to_string());
        return v;
    };
    let rolled = (report.restored_from_checkpoint + report.reset_to_empty) as u64;
    for (key, want) in [
        ("replayed", report.replayed as u64),
        ("procs_rolled_back", rolled),
        ("replayed_total", sys.stats.messages_replayed),
        ("rolled_back_total", sys.stats.procs_rolled_back),
    ] {
        match span.arg(key) {
            Some(got) if got == want => {}
            got => v.push(format!(
                "recovery span arg '{key}' is {got:?}, counters say {want}"
            )),
        }
    }
    v
}

/// Invariant 6b, checked at end of run: trace-derived totals reconcile
/// with the [`crate::ft::FtStats`] deltas since `base` — each replayed
/// message and rolled-back processor is claimed by exactly one traced
/// recovery span, and each refused write / taken checkpoint left
/// exactly one instant event.
pub fn counter_violations(tracer: &Tracer, sys: &FtSystem, base: &CounterBase) -> Vec<String> {
    let mut v = Vec::new();
    let evs = tracer.events();
    let spans: Vec<_> = evs
        .iter()
        .filter(|e| e.cat == "recovery" && e.name == "recovery" && e.dur_ns > 0)
        .collect();
    let span_sum =
        |key: &str| spans.iter().map(|e| e.arg(key).unwrap_or(0)).sum::<u64>();
    let instants = |cat: &str, name: &str| {
        evs.iter().filter(|e| e.cat == cat && e.name == name).count() as u64
    };
    let checks = [
        (
            "replayed messages (recovery spans)",
            span_sum("replayed"),
            sys.stats.messages_replayed - base.messages_replayed,
        ),
        (
            "rolled-back processors (recovery spans)",
            span_sum("procs_rolled_back"),
            sys.stats.procs_rolled_back - base.procs_rolled_back,
        ),
        (
            "refused writes (storage_refused instants)",
            instants("storage", "storage_refused"),
            sys.stats.storage_errors - base.storage_errors,
        ),
        (
            "checkpoints (checkpoint instants)",
            instants("ft", "checkpoint"),
            sys.stats.checkpoints_taken - base.checkpoints_taken,
        ),
    ];
    for (what, traced, counted) in checks {
        if traced != counted {
            v.push(format!(
                "trace/counter mismatch: {what} traced {traced}, counters say {counted}"
            ));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::sharded::{
        canonical_output, epoch_records, pipeline, ShardedConfig,
    };
    use crate::ft::Policy;
    use crate::time::Time;

    fn cfg() -> ShardedConfig {
        ShardedConfig {
            workers: 2,
            two_stage: true,
            count_policy: Policy::Lazy { every: 1, log_outputs: true },
            batch_cap: 4,
            threads: 1,
            ..Default::default()
        }
    }

    /// A healthy pipeline must be violation-free at every epoch
    /// boundary, after failure injection, and after recovery — the
    /// oracle's false-positive rate is zero on the suites' own
    /// workloads, which is what makes a fuzz violation meaningful.
    #[test]
    fn healthy_run_has_no_violations() {
        let mut p = pipeline(&cfg());
        let src = p.src_proc();
        for ep in 0..3u64 {
            p.sys.advance_input(src, Time::epoch(ep));
            for r in epoch_records(5, ep, 16, 4) {
                p.sys.push_input(src, Time::epoch(ep), r);
            }
            p.sys.advance_input(src, Time::epoch(ep + 1));
            p.run(5_000_000);
            let viol = structural_violations(&p.sys, None);
            assert!(viol.is_empty(), "epoch {ep}: {viol:?}");
        }

        let victim = p.plan.proc(p.count, 0);
        p.sys.inject_failures(&[victim]);
        let viol = structural_violations(&p.sys, None);
        assert!(viol.is_empty(), "post-injection: {viol:?}");
        let _report = p.sys.recover();
        p.run(5_000_000);
        let viol = structural_violations(&p.sys, None);
        assert!(viol.is_empty(), "post-recovery: {viol:?}");
        assert!(!canonical_output(&p.sys, p.collect_proc()).is_empty());
    }

    /// Trace/counter consistency on a healthy traced run: the recovery
    /// span agrees with its own report, and the end-of-run trace totals
    /// reconcile with the `FtStats` deltas.
    #[test]
    fn traced_run_reconciles_counters() {
        let mut p = pipeline(&cfg());
        let tracer = crate::trace::Tracer::new();
        p.sys.set_tracer(Some(tracer.clone()));
        let base = CounterBase::snapshot(&p.sys);
        let src = p.src_proc();
        for ep in 0..3u64 {
            p.sys.advance_input(src, Time::epoch(ep));
            for r in epoch_records(5, ep, 16, 4) {
                p.sys.push_input(src, Time::epoch(ep), r);
            }
            p.sys.advance_input(src, Time::epoch(ep + 1));
            p.run(5_000_000);
        }
        let victim = p.plan.proc(p.count, 0);
        p.sys.inject_failures(&[victim]);
        let report = p.sys.recover();
        let viol = recovery_span_violations(&tracer, &report, &p.sys);
        assert!(viol.is_empty(), "per-recovery: {viol:?}");
        p.run(5_000_000);
        let viol = counter_violations(&tracer, &p.sys, &base);
        assert!(viol.is_empty(), "totals: {viol:?}");
    }
}
