//! Invariant checks the fuzzer asserts after every drain and every
//! recovery (fuzzer stage 3 — see the [module docs](crate::fuzz)).
//!
//! The headline oracle — byte-equality of the sink against a no-fault
//! reference run — lives in the driver, because it needs both runs in
//! hand. This module holds the *structural* invariants, checkable on a
//! single live [`FtSystem`]:
//!
//! 1. **Mirror shape** — every durable mirror and its tag vector are
//!    parallel (`chain`/`chain_tags`, `log`/`log_tags`), and checkpoint
//!    chain frontiers ascend (F*(p) is a chain, §3.4).
//! 2. **Ack ordering** — per processor, the acknowledged staging
//!    sequence never exceeds the staged one.
//! 3. **Mirror ⊆ offered** — what [`FtSystem::availability`] offers the
//!    solver for a non-failed chain processor is *exactly* its
//!    acknowledged mirror prefix plus the live ⊤: every acked
//!    checkpoint is offered (losing one would roll back further than
//!    necessary), and nothing unacked is offered (offering one would
//!    restore from a checkpoint a crash may not have persisted).
//! 4. **GC ≤ acked** — the §4.2 monitor's low watermark for a chain
//!    processor stays at or below its newest *acknowledged* checkpoint
//!    frontier; the monitor must never authorize collecting state a
//!    recovery could still need, nor run ahead of durability.
//! 5. **Resident accounting** — the store's O(1) `resident_bytes`
//!    counter agrees with a fresh scan of every processor's entries.
//!
//! Violations come back as strings (one per finding) rather than
//! panics, so the campaign driver can attribute them to a seed and keep
//! going.

use crate::ft::harness::acked_prefix;
use crate::ft::monitor::Monitor;
use crate::ft::{Available, FtSystem};
use crate::frontier::Frontier;

/// Run every single-system structural invariant. `mon` is the campaign's
/// GC monitor when the run drives one (invariant 4 needs it).
pub fn structural_violations(sys: &FtSystem, mon: Option<&Monitor>) -> Vec<String> {
    let mut v = Vec::new();
    let avail = sys.availability();

    for p in sys.topo.proc_ids() {
        let i = p.0 as usize;
        let ft = &sys.ft[i];

        // 1. Mirror shape.
        if ft.chain.len() != ft.chain_tags.len() {
            v.push(format!(
                "proc {}: chain mirror {} entries but {} tags",
                p.0,
                ft.chain.len(),
                ft.chain_tags.len()
            ));
        }
        if ft.log.len() != ft.log_tags.len() {
            v.push(format!(
                "proc {}: log mirror {} entries but {} tags",
                p.0,
                ft.log.len(),
                ft.log_tags.len()
            ));
        }
        for w in ft.chain.windows(2) {
            if !w[0].meta.f.is_subset(&w[1].meta.f) {
                v.push(format!(
                    "proc {}: chain frontiers not ascending: {:?} ⊄ {:?}",
                    p.0, w[0].meta.f, w[1].meta.f
                ));
            }
        }

        // 2. Ack ordering.
        let (acked_w, staged_w) = (sys.store.acked_seq(p.0), sys.store.staged_seq(p.0));
        if acked_w > staged_w {
            v.push(format!(
                "proc {}: acked seq {} ahead of staged seq {}",
                p.0, acked_w, staged_w
            ));
        }

        // 3. Offered chain == acked mirror prefix (+ live ⊤ when alive).
        if ft.policy.has_chain() && ft.chain.len() == ft.chain_tags.len() {
            let acked = acked_prefix(&ft.chain_tags, acked_w);
            if let Available::Chain { chain: offered, .. } = &avail[i] {
                let expect = if ft.failed { acked } else { acked + 1 };
                if offered.len() != expect {
                    v.push(format!(
                        "proc {}: offers {} frontiers, expected {} (acked prefix {}{})",
                        p.0,
                        offered.len(),
                        expect,
                        acked,
                        if ft.failed { "" } else { " + live ⊤" }
                    ));
                } else {
                    for (k, meta) in offered.iter().take(acked).enumerate() {
                        if meta.f != ft.chain[k].meta.f {
                            v.push(format!(
                                "proc {}: offered frontier {k} is {:?}, mirror has {:?}",
                                p.0, meta.f, ft.chain[k].meta.f
                            ));
                        }
                    }
                    if !ft.failed && offered.last().map(|m| &m.f) != Some(&Frontier::Top) {
                        v.push(format!("proc {}: live chain proc does not offer ⊤", p.0));
                    }
                }
            } else {
                v.push(format!("proc {}: chain policy but non-chain availability", p.0));
            }

            // 4. GC watermark ≤ newest acked checkpoint frontier.
            if let Some(mon) = mon {
                let ceiling = ft
                    .chain
                    .get(acked.wrapping_sub(1))
                    .map(|c| c.meta.f.clone())
                    .unwrap_or(Frontier::Bottom);
                let wm = mon.low_watermark(p);
                if !wm.is_subset(&ceiling) {
                    v.push(format!(
                        "proc {}: GC watermark {:?} above acked ceiling {:?}",
                        p.0, wm, ceiling
                    ));
                }
            }
        }
    }

    // 5. Resident-byte accounting vs a fresh scan.
    let scanned: u64 = sys
        .store
        .procs()
        .into_iter()
        .map(|p| sys.store.scan_entries(p).into_iter().map(|(_, n)| n).sum::<u64>())
        .sum();
    let resident = sys.store.resident_bytes();
    if scanned != resident {
        v.push(format!(
            "store: resident_bytes {resident} disagrees with fresh scan {scanned}"
        ));
    }

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::sharded::{
        canonical_output, epoch_records, pipeline, ShardedConfig,
    };
    use crate::ft::Policy;
    use crate::time::Time;

    fn cfg() -> ShardedConfig {
        ShardedConfig {
            workers: 2,
            two_stage: true,
            count_policy: Policy::Lazy { every: 1, log_outputs: true },
            batch_cap: 4,
            threads: 1,
            ..Default::default()
        }
    }

    /// A healthy pipeline must be violation-free at every epoch
    /// boundary, after failure injection, and after recovery — the
    /// oracle's false-positive rate is zero on the suites' own
    /// workloads, which is what makes a fuzz violation meaningful.
    #[test]
    fn healthy_run_has_no_violations() {
        let mut p = pipeline(&cfg());
        let src = p.src_proc();
        for ep in 0..3u64 {
            p.sys.advance_input(src, Time::epoch(ep));
            for r in epoch_records(5, ep, 16, 4) {
                p.sys.push_input(src, Time::epoch(ep), r);
            }
            p.sys.advance_input(src, Time::epoch(ep + 1));
            p.run(5_000_000);
            let viol = structural_violations(&p.sys, None);
            assert!(viol.is_empty(), "epoch {ep}: {viol:?}");
        }

        let victim = p.plan.proc(p.count, 0);
        p.sys.inject_failures(&[victim]);
        let viol = structural_violations(&p.sys, None);
        assert!(viol.is_empty(), "post-injection: {viol:?}");
        let _report = p.sys.recover();
        p.run(5_000_000);
        let viol = structural_violations(&p.sys, None);
        assert!(viol.is_empty(), "post-recovery: {viol:?}");
        assert!(!canonical_output(&p.sys, p.collect_proc()).is_empty());
    }
}
