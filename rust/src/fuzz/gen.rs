//! Seeded generation of random dataflow shapes and configuration knobs
//! (fuzzer stage 1 — see the [module docs](crate::fuzz)).
//!
//! One [`Rng`] stream drives every choice, so a seed is a complete,
//! replayable description of the run: the topology (operator vocabulary,
//! shard width, optional two-input join, optional eager seq-domain
//! tail), the per-processor policies, and the engine/storage knobs. The
//! generated family deliberately brackets the hand-written suites
//! (`bench_support::sharded`, `test_sharded_recovery`,
//! `test_crash_restart`, `test_seq_replay`) so every fuzz run exercises
//! machinery whose intended semantics an existing test already pins
//! down — what the fuzzer adds is the *product* of the spaces, which no
//! hand-written grid covers.

use crate::engine::sharded::ProcFactory;
use crate::engine::{Delivery, Record};
use crate::ft::{FtSystem, PersistMode, Policy, SnapshotPolicy, Store};
use crate::graph::sharding::{LogicalId, ShardPlan, ShardedBuilder};
use crate::graph::Projection;
use crate::operators::{Buffer, CountByKey, Filter, Join, Map, Source, SumByTime};
use crate::time::TimeDomain;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Optional stage between the source and the sharded aggregation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MidKind {
    /// Source feeds the aggregation directly.
    None,
    /// Rekeying map (`key*3+1`): the mid→agg bundle becomes a genuine
    /// W×W cross-shard exchange.
    MapRekey,
    /// Drops odd keys: downstream sees a strict subset (exercises
    /// frontiers completing with no records at some shards).
    FilterHalf,
}

/// The sharded aggregation operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    /// Per-key sums per epoch ([`CountByKey`]).
    CountByKey,
    /// One total per epoch and shard ([`SumByTime`]).
    SumByTime,
}

/// A randomly generated dataflow topology.
///
/// ```text
///   src ───────────► [mid#0..W]? ──► agg#0..W ──► collect
///   src2? ──► join#0..W ──────┘          └─(per-ckpt)─► etail?  (seq)
/// ```
#[derive(Clone, Debug)]
pub struct Shape {
    /// Shards per sharded stage (1, 2, 4 or 8).
    pub workers: u32,
    /// Optional rekey/filter stage (single-source shapes only).
    pub mid: MidKind,
    /// Two-input symmetric hash [`Join`] fed by a second source.
    pub join: bool,
    /// Aggregation operator of the sharded `agg` stage.
    pub agg: AggKind,
    /// Seq-domain eager consumer behind a per-checkpoint edge (the
    /// `test_seq_replay` bridge pattern, sharded-upstream variant).
    pub eager_tail: bool,
    /// Input epochs to drive.
    pub epochs: u64,
    /// Records offered per source per epoch.
    pub records_per_epoch: usize,
    /// Key universe (keys cycle `0..keys`).
    pub keys: u64,
}

impl Shape {
    /// Draw a shape from the seed stream.
    pub fn generate(rng: &mut Rng) -> Shape {
        let join = rng.chance(0.25);
        let workers = *rng.choose(&[1u32, 2, 4, 8]);
        let mid = if join {
            MidKind::None
        } else {
            *rng.choose(&[MidKind::None, MidKind::MapRekey, MidKind::FilterHalf])
        };
        let agg =
            if rng.chance(0.25) { AggKind::SumByTime } else { AggKind::CountByKey };
        let eager_tail = rng.chance(0.3);
        let epochs = rng.range(2, 5);
        // Join output is quadratic in per-key duplicates: keep its
        // batches small so fuzz runs stay fast.
        let records_per_epoch =
            if join { 6 + rng.index(7) } else { 8 + rng.index(17) };
        let keys = workers as u64 * (1 + rng.below(3));
        Shape { workers, mid, join, agg, eager_tail, epochs, records_per_epoch, keys }
    }

    /// Compact single-line description (campaign logs, corpus records).
    pub fn describe(&self) -> String {
        format!(
            "W={} mid={:?} join={} agg={:?} etail={} epochs={} recs={} keys={}",
            self.workers,
            self.mid,
            self.join,
            self.agg,
            self.eager_tail,
            self.epochs,
            self.records_per_epoch,
            self.keys
        )
    }
}

/// Randomly drawn engine/storage/policy knobs for one run.
#[derive(Clone, Debug)]
pub struct Knobs {
    /// Channel coalescing cap.
    pub batch_cap: usize,
    /// Worker threads (1 = sequential engine; >1 = parallel executor —
    /// crashes land mid-drain between bounded slices, and recovery and
    /// cold reopen then run decomposed on the worker pool).
    pub threads: usize,
    /// Staged-writer discipline of the store.
    pub persist_mode: PersistMode,
    /// Virtual write cost.
    pub write_cost: u64,
    /// Durable file-backed WAL instead of the in-memory store. Forced
    /// on by fault plans that need a cold restart.
    pub durable: bool,
    /// Group-commit threshold of the durable WAL.
    pub flush_every_n: usize,
    /// Per-edge queue budget for credit-based backpressure (`None` =
    /// unbounded). Tiny caps (1–2) force constant parking/forced-round
    /// traffic, which is exactly where gating bugs would hide.
    pub mailbox_cap: Option<usize>,
    /// Policy of the `mid` stage (when present).
    pub mid_policy: Policy,
    /// Policy of the `join` stage (when present).
    pub join_policy: Policy,
    /// Policy of the `agg` shards.
    pub agg_policy: Policy,
    /// Policy of the `collect` buffer.
    pub collect_policy: Policy,
    /// Pump the §4.2 GC monitor every epoch.
    pub gc: bool,
    /// Durable representation of checkpoint state: monolithic-equivalent
    /// full snapshots vs. content-addressed delta chains. Must never
    /// change observable output — exactly what comparing against the
    /// (always-`Full`) reference checks.
    pub snapshot: SnapshotPolicy,
}

impl Knobs {
    /// Draw knobs from the seed stream. `shape` constrains the policy
    /// space: an eager seq tail hangs off a per-checkpoint edge, whose
    /// φ must be reconstructible after a crash — `agg` is then a logging
    /// lazy policy (φ per checkpoint) or `FullHistory` (exact φ rebuilt
    /// from the per-event `sent_seq` counts; see `FAILURE_MODES.md`).
    pub fn generate(rng: &mut Rng, shape: &Shape) -> Knobs {
        let batch_cap = *rng.choose(&[1usize, 2, 8, 64]);
        // Bias toward 1 (the reference shape), but keep the parallel
        // engine — and with it parallel recovery — well represented.
        let threads = *rng.choose(&[1usize, 1, 2, 4]);
        // Bias toward None (the pre-backpressure behavior), but make the
        // pathological tiny budgets common enough to matter.
        let mailbox_cap =
            *rng.choose(&[None, None, Some(1usize), Some(2), Some(8), Some(64)]);
        let persist_mode = if rng.chance(0.5) {
            PersistMode::Sync
        } else {
            PersistMode::Async { ack_every: *rng.choose(&[1usize, 4, 16]) }
        };
        let write_cost = *rng.choose(&[0u64, 1, 10]);
        let durable = rng.chance(0.4);
        let flush_every_n = *rng.choose(&[1usize, 4, 8]);
        let mid_policy = *rng.choose(&[
            Policy::LogOutputs,
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::FullHistory,
        ]);
        let join_policy = *rng.choose(&[
            Policy::Lazy { every: 1, log_outputs: true },
            Policy::FullHistory,
        ]);
        let every = 1 + rng.below(2);
        let agg_policy = if shape.eager_tail {
            *rng.choose(&[
                Policy::Lazy { every, log_outputs: true },
                Policy::FullHistory,
            ])
        } else {
            *rng.choose(&[
                Policy::Lazy { every, log_outputs: true },
                Policy::Lazy { every, log_outputs: false },
                Policy::FullHistory,
            ])
        };
        let collect_policy = Policy::Lazy { every: 1, log_outputs: false };
        let gc = rng.chance(0.3);
        // Delta{1} degenerates to Full through a different code path
        // (per-checkpoint forced-full), so it stays in the pool.
        let snapshot = *rng.choose(&[
            SnapshotPolicy::Full,
            SnapshotPolicy::Delta { max_chain: 1 },
            SnapshotPolicy::Delta { max_chain: 2 },
            SnapshotPolicy::Delta { max_chain: 8 },
        ]);
        Knobs {
            batch_cap,
            threads,
            persist_mode,
            write_cost,
            durable,
            flush_every_n,
            mailbox_cap,
            mid_policy,
            join_policy,
            agg_policy,
            collect_policy,
            gc,
            snapshot,
        }
    }

    /// The baseline the oracle compares against: record-at-a-time,
    /// sequential, synchronous, in-memory — and the same policies, so
    /// checkpoint cadence never influences what "correct output" means
    /// (it must not, which is exactly what comparing across knobs
    /// checks).
    pub fn reference(&self) -> Knobs {
        Knobs {
            batch_cap: 1,
            threads: 1,
            persist_mode: PersistMode::Sync,
            durable: false,
            gc: false,
            mailbox_cap: None,
            snapshot: SnapshotPolicy::Full,
            ..self.clone()
        }
    }

    /// Compact single-line description (campaign logs, corpus records).
    pub fn describe(&self) -> String {
        format!(
            "cap={} threads={} mbox={:?} persist={:?} cost={} durable={} flush={} agg={:?} gc={} snap={:?}",
            self.batch_cap,
            self.threads,
            self.mailbox_cap,
            self.persist_mode,
            self.write_cost,
            self.durable,
            self.flush_every_n,
            self.agg_policy,
            self.gc,
            self.snapshot
        )
    }
}

/// A built pipeline plus the logical handles the driver needs.
pub struct Built {
    pub sys: FtSystem,
    pub plan: Arc<ShardPlan>,
    /// External-input sources, in declaration order (`src`[, `src2`]).
    pub sources: Vec<LogicalId>,
    pub collect: LogicalId,
    pub etail: Option<LogicalId>,
    /// Policy per logical vertex, in add order (what the builder handed
    /// [`FtSystem`]; the driver needs it to classify processors for
    /// [`FtSystem::rebuild_monitor`]).
    pub policies: Vec<Policy>,
    /// Worker-group assignment for parallel drains.
    pub groups: Vec<usize>,
    pub threads: usize,
}

impl Built {
    /// Drain to quiescence under the configured thread count.
    pub fn run(&mut self, max_steps: usize) -> usize {
        if self.threads > 1 {
            self.sys.run_to_quiescence_parallel(&self.groups, self.threads, max_steps)
        } else {
            self.sys.run_to_quiescence(max_steps)
        }
    }

    /// The policy of a physical processor (its logical vertex's).
    pub fn policy_of(&self, p: crate::graph::ProcId) -> Policy {
        self.policies[self.plan.logical_of(p).0 .0 as usize]
    }

    /// A fresh §4.2 GC monitor classified exactly as
    /// [`FtSystem::rebuild_monitor`] documents: `stateless` = no durable
    /// chain to track, `logs` = upstream logs its outputs.
    pub fn monitor(&self) -> crate::ft::monitor::Monitor {
        let (mut stateless, mut logs) = (Vec::new(), Vec::new());
        for p in self.plan.topo.proc_ids() {
            let pol = self.policy_of(p);
            stateless.push(!pol.has_chain());
            logs.push(pol.logs_outputs());
        }
        self.sys.rebuild_monitor(stateless, logs)
    }
}

fn rekey(r: Record) -> Record {
    match r {
        Record::Kv { key, val } => Record::Kv { key: key * 3 + 1, val: val * 2.0 },
        other => other,
    }
}

fn keep_even(r: &Record) -> bool {
    match r {
        Record::Kv { key, .. } => key % 2 == 0,
        _ => true,
    }
}

/// Build the generated job against `store` (fresh system).
pub fn build(shape: &Shape, knobs: &Knobs, store: Store) -> Built {
    build_inner(shape, knobs, store, None)
}

/// Cold-restart the generated job from a reopened durable store; the
/// caller resupplies external inputs beyond each source's recovered
/// frontier (`report.plan.frontier(..)`), exactly as
/// [`crate::bench_support::sharded::reopen_pipeline`] documents.
pub fn reopen(
    shape: &Shape,
    knobs: &Knobs,
    store: Store,
) -> (Built, crate::ft::recovery::RecoveryReport) {
    let mut report = None;
    let b = build_inner(shape, knobs, store, Some(&mut report));
    (b, report.expect("reopen produced a recovery report"))
}

fn build_inner(
    shape: &Shape,
    knobs: &Knobs,
    store: Store,
    reopen: Option<&mut Option<crate::ft::recovery::RecoveryReport>>,
) -> Built {
    store.set_persist_mode(knobs.persist_mode);
    let mut b = ShardedBuilder::new();
    let mut factories: Vec<ProcFactory> = Vec::new();
    let mut policies: Vec<Policy> = Vec::new();

    let src = b.add_proc("src", TimeDomain::EPOCH);
    factories.push(Box::new(|_| Box::new(Source)));
    policies.push(Policy::LogOutputs);
    let mut sources = vec![src];

    let prev = if shape.join {
        let src2 = b.add_proc("src2", TimeDomain::EPOCH);
        factories.push(Box::new(|_| Box::new(Source)));
        policies.push(Policy::LogOutputs);
        sources.push(src2);
        let join = b.add_sharded("join", TimeDomain::EPOCH, shape.workers);
        factories.push(Box::new(|_| Box::new(Join::default())));
        policies.push(knobs.join_policy);
        // Connect order fixes the ports: src is the left side.
        b.connect(src, join, Projection::Identity);
        b.connect(src2, join, Projection::Identity);
        join
    } else {
        match shape.mid {
            MidKind::None => src,
            MidKind::MapRekey => {
                let mid = b.add_sharded("mid", TimeDomain::EPOCH, shape.workers);
                factories.push(Box::new(|_| Box::new(Map(rekey))));
                policies.push(knobs.mid_policy);
                b.connect(src, mid, Projection::Identity);
                mid
            }
            MidKind::FilterHalf => {
                let mid = b.add_sharded("mid", TimeDomain::EPOCH, shape.workers);
                factories.push(Box::new(|_| Box::new(Filter(keep_even))));
                policies.push(knobs.mid_policy);
                b.connect(src, mid, Projection::Identity);
                mid
            }
        }
    };

    let agg = b.add_sharded("agg", TimeDomain::EPOCH, shape.workers);
    match shape.agg {
        AggKind::CountByKey => {
            factories.push(Box::new(|_| Box::new(CountByKey::default())))
        }
        AggKind::SumByTime => factories.push(Box::new(|_| Box::new(SumByTime::default()))),
    }
    policies.push(knobs.agg_policy);
    b.connect(prev, agg, Projection::Identity);

    let collect = b.add_proc("collect", TimeDomain::EPOCH);
    factories.push(Box::new(|_| Box::new(Buffer::default())));
    policies.push(knobs.collect_policy);
    b.connect(agg, collect, Projection::Identity);

    let mut etail = None;
    if shape.eager_tail {
        let et = b.add_proc("etail", TimeDomain::Seq);
        factories.push(Box::new(|_| Box::new(Buffer::default())));
        policies.push(Policy::Eager);
        b.connect(agg, et, Projection::PerCheckpoint);
        etail = Some(et);
    }

    let plan = Arc::new(b.build().expect("generated topology is well-formed"));
    let mut sys = match reopen {
        None => FtSystem::new_sharded_with_cap(
            &plan,
            factories,
            &policies,
            Delivery::Fifo,
            store,
            knobs.batch_cap,
        ),
        Some(slot) => {
            // T > 1 fans the key-range scans, chain materializations and
            // the everyone-crashed recovery across the worker pool;
            // T = 1 is the sequential reopen. Byte-identical either way.
            let (sys, report) = FtSystem::reopen_sharded_parallel(
                &plan,
                factories,
                &policies,
                Delivery::Fifo,
                store,
                knobs.batch_cap,
                knobs.threads.max(1),
            );
            *slot = Some(report);
            sys
        }
    };
    // Not persisted: re-applied here on both fresh builds and reopens.
    sys.set_mailbox_cap(knobs.mailbox_cap);
    sys.set_snapshot_policy(knobs.snapshot);
    let threads = knobs.threads.max(1);
    let groups = crate::engine::shard_groups(&plan, threads);
    Built { sys, plan, sources, collect, etail, policies, groups, threads }
}

/// The deterministic record batch source `source` offers at epoch `ep`.
/// Keys cycle `0..keys`; values are small integers so every downstream
/// f64 aggregate is exact and order-independent (the property that makes
/// byte-equality a sound oracle).
pub fn epoch_batch(seed: u64, source: usize, ep: u64, shape: &Shape) -> Vec<Record> {
    let mut rng = Rng::new(
        seed ^ ep
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(source as u64 * 0x517C_C1B7_2722_0A95),
    );
    (0..shape.records_per_epoch)
        .map(|i| Record::kv((i as u64 % shape.keys) as i64, rng.below(50) as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_knobs_are_seed_deterministic() {
        for seed in [0u64, 1, 7, 99] {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let sa = Shape::generate(&mut a);
            let sb = Shape::generate(&mut b);
            assert_eq!(sa.describe(), sb.describe());
            let ka = Knobs::generate(&mut a, &sa);
            let kb = Knobs::generate(&mut b, &sb);
            assert_eq!(ka.describe(), kb.describe());
        }
    }

    #[test]
    fn eager_tail_admits_logging_chain_and_full_history() {
        let (mut lazy, mut hist) = (0u32, 0u32);
        for seed in 0..400u64 {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let knobs = Knobs::generate(&mut rng, &shape);
            if shape.eager_tail {
                match knobs.agg_policy {
                    Policy::Lazy { log_outputs, .. } => {
                        assert!(log_outputs, "unlogged lazy cannot replay the seq tail");
                        lazy += 1;
                    }
                    Policy::FullHistory => hist += 1,
                    other => panic!("eager tail over non-replayable agg policy {other:?}"),
                }
            }
        }
        assert!(lazy > 0, "logging-lazy agg never drawn under an eager tail");
        assert!(
            hist > 0,
            "FullHistory agg never drawn under an eager tail — the exclusion is lifted"
        );
    }

    #[test]
    fn mailbox_cap_knob_reaches_tiny_budgets() {
        let mut tiny = 0u32;
        let mut unbounded = 0u32;
        for seed in 0..400u64 {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let knobs = Knobs::generate(&mut rng, &shape);
            match knobs.mailbox_cap {
                Some(c) if c <= 2 => tiny += 1,
                None => unbounded += 1,
                _ => {}
            }
            assert_eq!(knobs.reference().mailbox_cap, None, "oracle runs unbounded");
        }
        assert!(tiny > 0, "caps 1–2 must be generated");
        assert!(unbounded > 0, "the pre-backpressure configuration must stay covered");
    }

    #[test]
    fn snapshot_policy_knob_covers_full_and_delta() {
        let (mut full, mut delta) = (0u32, 0u32);
        for seed in 0..400u64 {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let knobs = Knobs::generate(&mut rng, &shape);
            match knobs.snapshot {
                SnapshotPolicy::Full => full += 1,
                SnapshotPolicy::Delta { max_chain } => {
                    assert!(matches!(max_chain, 1 | 2 | 8));
                    delta += 1;
                }
            }
            assert_eq!(
                knobs.reference().snapshot,
                SnapshotPolicy::Full,
                "oracle runs monolithic-equivalent Full snapshots"
            );
        }
        assert!(full > 0, "Full must stay in the pool");
        assert!(delta > 0, "delta chains must be generated");
    }

    #[test]
    fn generated_shapes_build_and_run_clean() {
        for seed in [3u64, 17, 42] {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let knobs = Knobs::generate(&mut rng, &shape).reference();
            let mut built = build(&shape, &knobs, Store::new(knobs.write_cost));
            for ep in 0..shape.epochs {
                for (i, &s) in built.sources.clone().iter().enumerate() {
                    let sp = built.plan.proc(s, 0);
                    built.sys.advance_input(sp, crate::time::Time::epoch(ep));
                    for r in epoch_batch(seed, i, ep, &shape) {
                        built.sys.push_input(sp, crate::time::Time::epoch(ep), r);
                    }
                    built.sys.advance_input(sp, crate::time::Time::epoch(ep + 1));
                }
                built.run(5_000_000);
            }
            for &s in &built.sources.clone() {
                let sp = built.plan.proc(s, 0);
                built.sys.close_input(sp);
            }
            built.run(5_000_000);
            let out = crate::bench_support::sharded::canonical_output(
                &built.sys,
                built.plan.proc(built.collect, 0),
            );
            assert!(!out.is_empty(), "seed {seed} produced no output");
        }
    }
}
