//! Seeded failure-simulation fuzzer (`falkirk fuzz`).
//!
//! One [`crate::util::rng::Rng`] seed deterministically drives three
//! generators and a driver:
//!
//! 1. [`gen`] — a random dataflow over the existing operator vocabulary
//!    ([`crate::operators::Map`]/[`crate::operators::Filter`]/
//!    [`crate::operators::SumByTime`]/[`crate::operators::CountByKey`]/
//!    [`crate::operators::Join`], sharded W ∈ {1,2,4,8}, optional
//!    two-stage and eager seq-domain tail) plus random engine/storage
//!    knobs (batch cap, threads, [`crate::ft::PersistMode`], WAL group
//!    commit, per-vertex [`crate::ft::Policy`]).
//! 2. [`schedule`] — a random fault plan over the [`crate::failure`]
//!    machinery: multi-victim crashes in virtual event time behind a
//!    [`crate::failure::DetectorModel`], cold crash-restarts
//!    ([`crate::ft::FtSystem::reopen_sharded`]) with optionally torn WAL
//!    tails, staged-unacked-tail discards, oversized-value limits, and a
//!    second failure injected between a recovery and its drain.
//! 3. [`oracle`] — structural invariants checked after every drain and
//!    recovery (mirror ⊆ offered, GC ≤ acked, resident-byte
//!    accounting, …).
//!
//! The headline check is the paper's own claim (§3–§4): after any
//! sequence of failures and recoveries, the sink's canonical output is
//! **byte-identical** to a no-fault reference run of the same seed —
//! executed record-at-a-time, single-threaded, synchronously persisted,
//! so the comparison simultaneously proves failure transparency *and*
//! knob-independence. The one documented exception: a run whose
//! oversized-value limit actually refused writes is only held to
//! graceful degradation (structural invariants, bounded drains), since
//! refused durability legitimately costs replay completeness — see
//! `FAILURE_MODES.md` next to this module for the full catalog.
//!
//! Every run is bit-for-bit reproducible from its seed; failing seeds
//! are recorded under `rust/tests/corpus/` and replayed by
//! `test_fuzz_corpus` (see `ft/README.md` for the recording workflow).

pub mod gen;
pub mod oracle;
pub mod schedule;

pub use gen::{Knobs, Shape};
pub use schedule::FaultPlan;

use crate::bench_support::sharded::canonical_output;
use crate::failure::FailureSchedule;
use crate::ft::external::ExternalInput;
use crate::ft::monitor::Monitor;
use crate::ft::{FileBackendOptions, Store};
use crate::graph::ProcId;
use crate::time::Time;
use crate::util::rng::Rng;
use crate::util::tmp::TempDir;
use std::path::Path;

/// Everything one fuzz run decided and concluded.
#[derive(Clone, Debug)]
pub struct RunVerdict {
    pub seed: u64,
    pub pass: bool,
    /// FNV-1a digest of the run's shape, knobs, faults, outputs, and
    /// violations — the "same seed ⇒ same everything" fingerprint.
    pub digest: u64,
    pub shape: String,
    pub knobs: String,
    pub faults: String,
    /// Recoveries performed (scheduled crashes, pause victims, doubles;
    /// cold restarts count via their all-processors recovery).
    pub recoveries: u64,
    /// Oracle findings, empty on a pass. A panic in the run surfaces as
    /// a single `panic: …` entry.
    pub violations: Vec<String>,
}

/// A batch of [`RunVerdict`]s from consecutive seeds.
#[derive(Clone, Debug, Default)]
pub struct CampaignReport {
    pub verdicts: Vec<RunVerdict>,
}

impl CampaignReport {
    pub fn all_pass(&self) -> bool {
        self.verdicts.iter().all(|v| v.pass)
    }

    pub fn failures(&self) -> Vec<&RunVerdict> {
        self.verdicts.iter().filter(|v| !v.pass).collect()
    }

    /// Combined fingerprint over every verdict.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for v in &self.verdicts {
            fnv(&mut h, &v.seed.to_le_bytes());
            fnv(&mut h, &v.digest.to_le_bytes());
        }
        h
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

/// Run `runs` consecutive seeds starting at `seed`. A panicking run is
/// caught and reported as a failing verdict rather than aborting the
/// campaign.
pub fn campaign(seed: u64, runs: u64, max_steps: usize) -> CampaignReport {
    let mut report = CampaignReport::default();
    for k in 0..runs {
        let s = seed.wrapping_add(k);
        let verdict =
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_one(s, max_steps)))
            {
                Ok(v) => v,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "<non-string panic payload>".to_string());
                    let mut h = FNV_OFFSET;
                    fnv(&mut h, msg.as_bytes());
                    RunVerdict {
                        seed: s,
                        pass: false,
                        digest: h,
                        shape: String::new(),
                        knobs: String::new(),
                        faults: String::new(),
                        recoveries: 0,
                        violations: vec![format!("panic: {msg}")],
                    }
                }
            };
        report.verdicts.push(verdict);
    }
    report
}

/// Execute one seed end to end: generate, run the no-fault reference,
/// run the faulted execution, and judge it.
pub fn run_one(seed: u64, max_steps: usize) -> RunVerdict {
    let mut rng = Rng::new(seed);
    let shape = Shape::generate(&mut rng);
    let mut knobs = Knobs::generate(&mut rng, &shape);

    // ---- Reference run (no faults; record-at-a-time, sequential,
    // synchronous, in-memory; same shape, policies, and inputs). Its
    // plan also tells the fault generator which processors exist.
    let ref_knobs = knobs.reference();
    let mut reference = gen::build(&shape, &ref_knobs, Store::new(ref_knobs.write_cost));
    let candidates: Vec<ProcId> = reference.plan.topo.proc_ids().collect();
    let faults = FaultPlan::generate(&mut rng, &shape, &candidates);
    faults.reconcile(&mut knobs);

    let mut violations: Vec<String> = Vec::new();
    for ep in 0..shape.epochs {
        offer_epoch(&mut reference, None, seed, ep, &shape);
        let steps = reference.run(max_steps);
        if steps >= max_steps {
            violations.push(format!("reference: epoch {ep} drain did not quiesce"));
        }
    }
    close_all(&mut reference);
    reference.run(max_steps);
    let ref_collect = canonical_output(&reference.sys, reference.plan.proc(reference.collect, 0));
    let ref_etail = reference
        .etail
        .map(|e| canonical_output(&reference.sys, reference.plan.proc(e, 0)));
    drop(reference);

    // ---- Faulted run.
    let mut d = Driver::new(seed, &shape, &knobs, &faults, max_steps);
    d.drive();
    violations.extend(d.violations);

    let out_collect = canonical_output(&d.built.sys, d.built.plan.proc(d.built.collect, 0));
    let out_etail =
        d.built.etail.map(|e| canonical_output(&d.built.sys, d.built.plan.proc(e, 0)));

    let storage_errors: u64 =
        d.built.plan.topo.proc_ids().map(|p| d.built.sys.storage_errors(p)).sum();
    let degraded = faults.oversize.is_some() && storage_errors > 0;
    if degraded {
        // Refused durable writes legitimately cost replay completeness;
        // the run is held to graceful degradation only (structural
        // invariants above, plus having drained at all).
    } else {
        if out_collect != ref_collect {
            violations.push(format!(
                "sink output diverges from no-fault reference ({} vs {} bytes)",
                out_collect.len(),
                ref_collect.len()
            ));
        }
        if out_etail != ref_etail {
            violations.push("eager seq tail diverges from no-fault reference".to_string());
        }
    }

    let mut h = FNV_OFFSET;
    fnv(&mut h, shape.describe().as_bytes());
    fnv(&mut h, knobs.describe().as_bytes());
    fnv(&mut h, faults.describe().as_bytes());
    fnv(&mut h, &ref_collect);
    fnv(&mut h, &out_collect);
    if let Some(b) = &out_etail {
        fnv(&mut h, b);
    }
    for v in &violations {
        fnv(&mut h, v.as_bytes());
    }

    RunVerdict {
        seed,
        pass: violations.is_empty(),
        digest: h,
        shape: shape.describe(),
        knobs: knobs.describe(),
        faults: faults.describe(),
        recoveries: d.recoveries,
        violations,
    }
}

/// Offer epoch `ep`'s batches to every source (and, when driving the
/// faulted run, to its acknowledged-external-input services).
fn offer_epoch(
    built: &mut gen::Built,
    mut exts: Option<&mut Vec<ExternalInput>>,
    seed: u64,
    ep: u64,
    shape: &Shape,
) {
    for (i, &s) in built.sources.clone().iter().enumerate() {
        let sp = built.plan.proc(s, 0);
        let batch = gen::epoch_batch(seed, i, ep, shape);
        if let Some(exts) = exts.as_deref_mut() {
            exts[i].offer(Time::epoch(ep), batch.clone());
        }
        built.sys.advance_input(sp, Time::epoch(ep));
        for r in batch {
            built.sys.push_input(sp, Time::epoch(ep), r);
        }
        built.sys.advance_input(sp, Time::epoch(ep + 1));
    }
}

fn close_all(built: &mut gen::Built) {
    for &s in &built.sources.clone() {
        let sp = built.plan.proc(s, 0);
        built.sys.close_input(sp);
    }
}

/// Chop `n` bytes off the newest WAL segment (the power-loss torn-tail
/// model; [`crate::ft::backend_file::FileBackend`] repairs exactly this
/// on reopen).
fn torn_chop(dir: &Path, n: u64) {
    let newest = std::fs::read_dir(dir)
        .expect("reading WAL directory")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "seg").unwrap_or(false))
        .max();
    let Some(seg) = newest else { return };
    let len = std::fs::metadata(&seg).expect("segment metadata").len();
    if len == 0 {
        return;
    }
    let keep = len.saturating_sub(n.min(len));
    let f = std::fs::OpenOptions::new().write(true).open(&seg).expect("opening segment");
    f.set_len(keep).expect("truncating segment");
}

/// The faulted execution: owns the system, the external-input services,
/// the live fault state, and the violation log.
struct Driver<'a> {
    seed: u64,
    shape: &'a Shape,
    knobs: &'a Knobs,
    faults: &'a FaultPlan,
    max_steps: usize,
    built: gen::Built,
    store: Store,
    dir: Option<TempDir>,
    exts: Vec<ExternalInput>,
    mon: Option<Monitor>,
    crashes: FailureSchedule,
    double_pending: Option<ProcId>,
    /// Epoch boundary inputs have been advanced to (resupply target).
    next_ep: u64,
    recoveries: u64,
    violations: Vec<String>,
    /// Every faulted run is traced (oracle invariant 6: the trace and
    /// the FT counters must reconcile); a fresh tracer per system
    /// generation — [`Driver::cold_restart`] reconciles and replaces it.
    tracer: crate::trace::Tracer,
    /// [`crate::trace::ENV_TRACE_JSON`] target, when set: each
    /// generation's events are appended there at reconcile points.
    trace_path: Option<String>,
    /// Counter snapshot at tracer attach (reopen's internal recovery
    /// runs before the tracer can attach, so totals are delta-based).
    base: oracle::CounterBase,
}

impl<'a> Driver<'a> {
    fn new(
        seed: u64,
        shape: &'a Shape,
        knobs: &'a Knobs,
        faults: &'a FaultPlan,
        max_steps: usize,
    ) -> Driver<'a> {
        let dir = knobs.durable.then(|| TempDir::new("fuzz"));
        let store = match &dir {
            Some(t) => Store::open_dir(
                t.path(),
                knobs.write_cost,
                FileBackendOptions { flush_every_n: knobs.flush_every_n, ..Default::default() },
            )
            .expect("opening WAL store"),
            None => Store::new(knobs.write_cost),
        };
        let mut built = gen::build(shape, knobs, store.clone());
        let tracer = crate::trace::Tracer::new();
        built.sys.set_tracer(Some(tracer.clone()));
        let base = oracle::CounterBase::snapshot(&built.sys);
        let trace_path =
            std::env::var(crate::trace::ENV_TRACE_JSON).ok().filter(|p| !p.is_empty());
        let exts = built.sources.iter().map(|_| ExternalInput::new()).collect();
        let mon = knobs.gc.then(|| built.monitor());
        Driver {
            seed,
            shape,
            knobs,
            faults,
            max_steps,
            built,
            store,
            dir,
            exts,
            mon,
            crashes: faults.crashes.clone(),
            double_pending: faults.double_with,
            next_ep: 0,
            recoveries: 0,
            violations: Vec::new(),
            tracer,
            trace_path,
            base,
        }
    }

    fn drive(&mut self) {
        for ep in 0..self.shape.epochs {
            if let Some(p) = &self.faults.pause {
                if p.epoch == ep {
                    self.store.pause_persistence();
                }
            }
            if let Some(o) = &self.faults.oversize {
                if o.from_epoch == ep {
                    self.store.set_max_value_len(o.limit as u64);
                }
            }

            offer_epoch(&mut self.built, Some(&mut self.exts), self.seed, ep, self.shape);
            self.next_ep = ep + 1;
            self.drain(ep);

            if let Some(p) = self.faults.pause.clone() {
                if p.epoch == ep {
                    if let Some(v) = p.victim {
                        self.crash_and_recover(vec![v]);
                        self.drain(ep);
                    }
                    self.store.resume_persistence();
                }
            }

            if let Some(m) = &mut self.mon {
                for a in self.built.sys.pump_monitor(m) {
                    self.built.sys.apply_gc(&a);
                }
            }

            // Barrier before judging: sequential drains deliberately leave
            // staged tails for the *crash* paths to catch, but the oracle
            // reads the ack watermarks twice (inside availability() and
            // again for the prefix) — an async writer advancing between
            // the reads would fabricate timing-dependent violations.
            self.store.flush_staged();
            for v in oracle::structural_violations(&self.built.sys, self.mon.as_ref()) {
                self.violations.push(format!("epoch {ep}: {v}"));
            }

            if let Some(r) = self.faults.restart.clone() {
                if r.after_epoch == ep + 1 {
                    self.cold_restart(r.torn_bytes, ep);
                }
            }
        }

        // The fault window is the driven epochs: scheduled crashes that
        // have not fired by now are dropped, and the close-and-drain tail
        // runs fault-free (matching the hand-written suites, which never
        // crash a closed source).
        close_all(&mut self.built);
        let steps = self.built.run(self.max_steps);
        if steps >= self.max_steps {
            self.violations.push("final drain did not quiesce".to_string());
        }
        self.store.flush_staged();
        for v in oracle::structural_violations(&self.built.sys, self.mon.as_ref()) {
            self.violations.push(format!("final: {v}"));
        }
        for v in oracle::counter_violations(&self.tracer, &self.built.sys, &self.base) {
            self.violations.push(format!("final: {v}"));
        }
        if let Some(path) = &self.trace_path {
            if let Err(e) = self.tracer.append_json_lines(path) {
                eprintln!("fuzz seed {}: cannot append trace to '{path}': {e}", self.seed);
            }
        }
    }

    /// Drain to quiescence, firing scheduled crashes. The sequential
    /// engine checks the schedule before every step; the parallel
    /// executor runs the drain in bounded slices and fires due crashes
    /// between them, so faults land genuinely *mid-drain* (queues
    /// non-empty, epoch in flight) and recovery itself then runs
    /// decomposed on the worker pool
    /// ([`crate::ft::FtSystem::recover_parallel`]).
    fn drain(&mut self, ep: u64) {
        let delay = self.faults.detector.confirmation_delay();
        if self.built.threads > 1 {
            // Fixed slice budget — no RNG draws, so fault schedules stay
            // a pure function of the seed and old corpus entries keep
            // their meaning.
            const MID_DRAIN_BUDGET: usize = 24;
            let mut total = 0usize;
            loop {
                let budget = MID_DRAIN_BUDGET.min(self.max_steps);
                let steps = self.built.run(budget);
                total += steps;
                let now = self.built.sys.engine.events_processed().saturating_sub(delay);
                let due = self.crashes.due(now);
                if !due.is_empty() {
                    self.crash_and_recover(due);
                    continue;
                }
                if steps < budget {
                    return; // quiesced, nothing due
                }
                if total >= self.max_steps {
                    self.violations.push(format!("epoch {ep}: drain did not quiesce"));
                    return;
                }
            }
        } else {
            let mut steps = 0usize;
            loop {
                let now = self.built.sys.engine.events_processed().saturating_sub(delay);
                let due = self.crashes.due(now);
                if !due.is_empty() {
                    self.crash_and_recover(due);
                    continue;
                }
                if self.built.sys.step().is_none() {
                    return;
                }
                steps += 1;
                if steps >= self.max_steps {
                    self.violations.push(format!("epoch {ep}: drain did not quiesce"));
                    return;
                }
            }
        }
    }

    /// §4.4 pause → solve → reset → replay, then §4.3 resupply of every
    /// rolled-back source from its acknowledged external service — and,
    /// once per run, the second failure injected right here, between a
    /// recovery and its post-recovery drain.
    fn crash_and_recover(&mut self, victims: Vec<ProcId>) {
        self.built.sys.inject_failures(&victims);
        let report = self.recover_now();
        self.recoveries += 1;
        self.check_recovery_trace(&report);
        self.resupply(&report.plan);
        if let Some(m) = &mut self.mon {
            // Recovery may have truncated chains; the monitor's own
            // availability is append-only, so rebuild it.
            *m = self.built.monitor();
        }
        if let Some(v) = self.double_pending.take() {
            self.built.sys.inject_failures(&[v]);
            let report = self.recover_now();
            self.recoveries += 1;
            self.check_recovery_trace(&report);
            self.resupply(&report.plan);
            if let Some(m) = &mut self.mon {
                *m = self.built.monitor();
            }
        }
    }

    /// Run one recovery on whichever engine the knobs selected: the
    /// multi-threaded driver rolls back and replays decomposed on the
    /// worker pool, the sequential one stays on the tid-0 path. Both
    /// produce byte-identical state, which the output digest checks.
    fn recover_now(&mut self) -> crate::ft::recovery::RecoveryReport {
        if self.built.threads > 1 {
            self.built.sys.recover_parallel(&self.built.groups, self.built.threads)
        } else {
            self.built.sys.recover()
        }
    }

    /// Oracle invariant 6a: the recovery that just completed must have
    /// left a `"recovery"` span whose counts match its report and the
    /// live counters.
    fn check_recovery_trace(&mut self, report: &crate::ft::recovery::RecoveryReport) {
        let n = self.recoveries;
        for v in oracle::recovery_span_violations(&self.tracer, report, &self.built.sys) {
            self.violations.push(format!("recovery {n}: {v}"));
        }
    }

    fn resupply(&mut self, plan: &crate::ft::RollbackPlan) {
        for (i, &s) in self.built.sources.clone().iter().enumerate() {
            let sp = self.built.plan.proc(s, 0);
            let f_src = plan.frontier(sp).clone();
            if f_src.is_top() {
                continue;
            }
            for (tm, recs) in self.exts[i].replay_from(&f_src) {
                self.built.sys.advance_input(sp, tm);
                for r in recs {
                    self.built.sys.push_input(sp, tm, r);
                }
            }
            self.built.sys.advance_input(sp, Time::epoch(self.next_ep));
        }
    }

    /// Cold crash-restart: the process dies (buffered WAL tail with it),
    /// the tail is optionally torn, and a fresh process reopens the
    /// directory — `reopen_sharded` runs the all-processors-failed
    /// recovery, after which the external services resupply everything
    /// past the recovered frontiers.
    fn cold_restart(&mut self, torn_bytes: u64, ep: u64) {
        let dir = self.dir.as_ref().expect("restart requires a durable store");
        // The dying generation's trace must already reconcile with its
        // counters (oracle invariant 6b) — settle the account before
        // the system and its stats go away.
        for v in oracle::counter_violations(&self.tracer, &self.built.sys, &self.base) {
            self.violations.push(format!("pre-restart epoch {ep}: {v}"));
        }
        if let Some(path) = &self.trace_path {
            if let Err(e) = self.tracer.append_json_lines(path) {
                eprintln!("fuzz seed {}: cannot append trace to '{path}': {e}", self.seed);
            }
        }
        // Replace the live system with a throwaway before dropping it.
        let dead = std::mem::replace(
            &mut self.built,
            gen::build(self.shape, &self.knobs.reference(), Store::new(0)),
        );
        drop(dead);
        self.store.simulate_crash();
        if torn_bytes > 0 {
            torn_chop(dir.path(), torn_bytes);
        }
        let store = Store::open_dir(
            dir.path(),
            self.knobs.write_cost,
            FileBackendOptions {
                flush_every_n: self.knobs.flush_every_n,
                ..Default::default()
            },
        )
        .expect("reopening WAL store");
        let (built, report) = gen::reopen(self.shape, self.knobs, store.clone());
        self.built = built;
        self.store = store;
        // A fresh process gets a fresh tracer; the reopen's internal
        // recovery ran before it could attach, so the counter base is
        // re-snapshotted rather than zeroed.
        self.tracer = crate::trace::Tracer::new();
        self.built.sys.set_tracer(Some(self.tracer.clone()));
        self.base = oracle::CounterBase::snapshot(&self.built.sys);
        // A fresh process means a fresh §4.2 monitor: the old one's
        // availability is append-only and tracks chains the reopen just
        // rebuilt (and possibly conservatively truncated).
        if self.mon.is_some() {
            self.mon = Some(self.built.monitor());
        }
        // The value limit is a property of the store *handle*, not the
        // directory — re-impose it on the new one.
        if let Some(o) = &self.faults.oversize {
            if o.from_epoch <= ep {
                self.store.set_max_value_len(o.limit as u64);
            }
        }
        self.resupply(&report.plan);
        self.drain(ep);
        self.store.flush_staged();
        for v in oracle::structural_violations(&self.built.sys, self.mon.as_ref()) {
            self.violations.push(format!("post-restart epoch {ep}: {v}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The contract the corpus leans on: one seed fixes the shape, the
    /// knobs, the fault plan, both executions, and the verdict.
    #[test]
    fn same_seed_same_digest() {
        for seed in [1u64, 7, 23] {
            let a = run_one(seed, 5_000_000);
            let b = run_one(seed, 5_000_000);
            assert_eq!(a.digest, b.digest, "seed {seed} verdict not reproducible");
            assert_eq!(a.pass, b.pass);
            assert_eq!(a.faults, b.faults);
        }
    }

    /// A short slice of the development campaign stays green: every
    /// violation here is a real regression in recovery, not fuzz noise.
    #[test]
    fn short_campaign_passes() {
        let report = campaign(1, 10, 5_000_000);
        for v in &report.verdicts {
            assert!(
                v.pass,
                "seed {} failed: {:?}\n shape {}\n knobs {}\n faults {}",
                v.seed, v.violations, v.shape, v.knobs, v.faults
            );
        }
        assert_eq!(report.digest(), campaign(1, 10, 5_000_000).digest());
    }

    /// Generator coverage: across a modest seed range every fault kind
    /// in the catalog is actually drawn — the campaign is not quietly
    /// fuzzing a corner of the schedule space. (Each kind has ≥ 0.2
    /// probability per seed, so 200 seeds miss one with probability
    /// < 1e-19; a failure here means the generator changed.)
    #[test]
    fn fault_kinds_all_reachable() {
        let (mut crash, mut multi, mut restart, mut torn, mut pausev, mut over, mut dbl) =
            (false, false, false, false, false, false, false);
        for seed in 0..200u64 {
            let mut rng = Rng::new(seed);
            let shape = Shape::generate(&mut rng);
            let _knobs = Knobs::generate(&mut rng, &shape);
            let cands: Vec<ProcId> = (0..6).map(ProcId).collect();
            let plan = FaultPlan::generate(&mut rng, &shape, &cands);
            crash |= !plan.crashes.is_empty();
            multi |= plan.crashes.remaining() >= 2;
            restart |= plan.restart.is_some();
            torn |= plan.restart.as_ref().map_or(false, |r| r.torn_bytes > 0);
            pausev |= plan.pause.as_ref().map_or(false, |p| p.victim.is_some());
            over |= plan.oversize.is_some();
            dbl |= plan.double_with.is_some();
        }
        assert!(
            crash && multi && restart && torn && pausev && over && dbl,
            "unreachable fault kind: crash={crash} multi={multi} restart={restart} \
             torn={torn} pause-victim={pausev} oversize={over} double={dbl}"
        );
    }
}
