//! # Falkirk Wheel — rollback recovery for dataflow systems
//!
//! A Rust reproduction of *"Falkirk Wheel: Rollback Recovery for Dataflow
//! Systems"* (Michael Isard and Martín Abadi, 2015). The library contains:
//!
//! - a deterministic timely-dataflow-style execution engine with cyclic
//!   graphs, structured logical times and notifications ([`engine`],
//!   [`progress`], [`graph`], [`operators`]). The execution core is
//!   **batch-at-a-time** and **zero-copy**: channels queue [`engine::Batch`]
//!   units — one time plus an `Arc`-shared record payload — coalesced up
//!   to a configurable `batch_cap`; splits are sub-range views, mutation
//!   is copy-on-write, and capture/log/history views alias the queued
//!   allocation, so the capture-off FIFO path performs zero record
//!   clones from ingestion to sink (audited by `tests/test_zero_copy.rs`).
//!   Operators implement a batch entry point (`on_batch`, with a
//!   per-record default shim), and every layer above — Table-1 metadata,
//!   message logs, histories, sharded exchange — moves at batch
//!   granularity. A batch of records at one logical time is a *single
//!   event* under the rollback model (every Table-1 structure is a
//!   frontier of times, blind to record multiplicity within a time), so
//!   rollback semantics are unchanged and `batch_cap = 1` reproduces
//!   record-at-a-time delivery exactly. Every queue is boundable:
//!   an optional per-edge `mailbox_cap` applies credit-based
//!   backpressure (`engine::scheduler` module docs; `--mailbox-cap` on
//!   the CLI), deferring — never denying — deliveries, so bounded runs
//!   produce byte-identical output;
//! - a **sharded multi-worker layer**: logical vertices partition into W
//!   worker shards connected by hash-exchange edges
//!   ([`graph::sharding`], [`engine::sharded`]); each shard is a
//!   processor with its own logical-time frontier and checkpoint chain,
//!   so the Fig. 6 solver computes per-shard rollback plans and a
//!   single-shard failure recovers only that shard's key range
//!   (`ft/README.md` documents the model);
//! - a **parallel multi-threaded executor** ([`engine::parallel`]): one
//!   OS thread per shard group, each running its own scheduler loop over
//!   its local channels, with cross-shard exchange carried through
//!   mailboxes and the shared pointstamp tracker updated from batched
//!   deltas at barriers. Notifications fire only at global message
//!   quiescence (the sequential phase-2 precondition), per-shard
//!   delivery order equals the sequential round-robin restricted to the
//!   shard, and a drain always recomposes the sequential engine before
//!   returning — so failure injection and recovery run unchanged while
//!   workers are parked (pause-drain-rollback; `--threads` on the
//!   `falkirk shard` CLI, `threads` in `ShardedConfig`);
//! - a **durable storage subsystem** behind the pluggable
//!   [`ft::storage::StorageBackend`] trait: the in-memory default, plus
//!   an on-disk segmented write-ahead log ([`ft::backend_file`]) with
//!   group commit, crash-scan reopen (torn tails truncated), tombstones
//!   and threshold-triggered segment compaction — enabling **true
//!   cold-restart recovery** ([`ft::harness::FtSystem::reopen`]): a
//!   process crash is a first-class failure scenario, recovered from
//!   storage alone to byte-identical output (`--data-dir` on the
//!   `falkirk fig1` / `falkirk shard` CLI, `falkirk store inspect`);
//! - the paper's fault-tolerance framework: logical-time frontiers
//!   ([`frontier`]), per-edge time-domain projections φ(e) ([`graph`]),
//!   checkpoint/log policies and Table-1 metadata, selective rollback, the
//!   Figure-6 consistent-frontier fixed point, the garbage-collection
//!   monitor and recovery orchestration ([`ft`]);
//! - baselines it subsumes: Chandy–Lamport snapshots, exactly-once /
//!   at-least-once streaming, Spark-style RDD lineage ([`baselines`]);
//! - a **seeded failure-simulation fuzzer** ([`fuzz`], `falkirk fuzz`):
//!   one seed deterministically generates a dataflow shape, engine and
//!   storage knobs, and a fault schedule over the [`failure`] machinery
//!   (multi-victim crashes behind a detector model, cold crash-restarts
//!   with torn WAL tails, staged-tail discards, oversized writes,
//!   double failures), then asserts byte-equality against a no-fault
//!   reference run plus structural invariants ([`fuzz::oracle`]);
//!   failing seeds land in `rust/tests/corpus/` as regression tests;
//! - an XLA/PJRT runtime that loads AOT-compiled JAX+Pallas analytics
//!   kernels from `artifacts/*.hlo.txt` and runs them on the hot path of
//!   stateful vertices ([`runtime`], [`operators::tensor`]);
//! - a capture-gated **observability layer** ([`trace`], [`metrics`]):
//!   an `Arc`-shared structured tracer recording epoch/delivery/barrier
//!   events and a nested **recovery timeline** (detect → solver →
//!   rollback → replay) as `falkirk-trace/1` JSON-lines
//!   (`FALKIRK_TRACE_JSON=file`, convertible to chrome://tracing via
//!   `falkirk trace convert`), per-worker lock-free event buffers merged
//!   at barriers, and a `--metrics-json` end-of-run summary
//!   (`falkirk-metrics/1`) with log2-histogram latency percentiles
//!   ([`util::stats::LogHistogram`]). Tracing off = one `Option` branch
//!   per site, same discipline the zero-copy audit enforces.
//!
//! Python (`python/compile/`) is build-time only: it lowers the L2 JAX
//! model (which calls the L1 Pallas kernels) to HLO text once; the Rust
//! binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the reproduction of every figure in the paper.

pub mod util;
pub mod time;
pub mod frontier;
pub mod graph;
pub mod progress;
pub mod engine;
pub mod operators;
pub mod ft;
pub mod baselines;
pub mod failure;
pub mod fuzz;
pub mod runtime;
pub mod coordinator;
pub mod metrics;
pub mod trace;
pub mod bench_support;

pub use crate::frontier::Frontier;
pub use crate::graph::sharding::{LogicalId, Partition, ShardPlan, ShardedBuilder};
pub use crate::graph::{EdgeId, GraphBuilder, ProcId, Projection, Topology};
pub use crate::time::{Time, TimeDomain};
