//! Failure injection and detection.
//!
//! The paper assumes "detecting failures … [is] adequately covered by
//! existing techniques" (§1) and describes the operational flow in §4.4:
//! a peer notices a broken connection, keeps buffering output, and only
//! when a *failure detector* confirms the crash does the system pause and
//! recover. This module provides the deterministic crash schedule used by
//! the examples/benches and a simple timeout-style detector model whose
//! confirmation delay the benches can charge to recovery latency.

use crate::graph::ProcId;
use crate::util::rng::Rng;

/// A deterministic schedule of crash events, in virtual event time.
#[derive(Clone, Debug, Default)]
pub struct FailureSchedule {
    /// Sorted (event-count, victim) pairs.
    crashes: Vec<(u64, ProcId)>,
    next: usize,
}

impl FailureSchedule {
    pub fn new(mut crashes: Vec<(u64, ProcId)>) -> FailureSchedule {
        crashes.sort_by_key(|(at, p)| (*at, p.0));
        FailureSchedule { crashes, next: 0 }
    }

    /// Random schedule: `n` crashes uniformly over `[0, horizon)` events
    /// choosing victims from `candidates`. An empty candidate set or a
    /// zero horizon means "nothing can crash" and yields the empty
    /// schedule — the fuzzer's generator reaches both corners routinely
    /// (a topology with no eligible victims, a run too short to host a
    /// crash), and they used to panic via `Rng::choose` / the old
    /// `Rng::below` debug assertion.
    pub fn random(seed: u64, n: usize, horizon: u64, candidates: &[ProcId]) -> FailureSchedule {
        if candidates.is_empty() || horizon == 0 {
            return FailureSchedule::default();
        }
        let mut rng = Rng::new(seed);
        let crashes = (0..n)
            .map(|_| (rng.below(horizon), *rng.choose(candidates)))
            .collect();
        FailureSchedule::new(crashes)
    }

    /// Whether any crashes remain to fire.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Victims due at-or-before virtual time `now` (consumed).
    pub fn due(&mut self, now: u64) -> Vec<ProcId> {
        let mut out = Vec::new();
        while self.next < self.crashes.len() && self.crashes[self.next].0 <= now {
            out.push(self.crashes[self.next].1);
            self.next += 1;
        }
        out
    }

    pub fn remaining(&self) -> usize {
        self.crashes.len() - self.next
    }
}

/// Timeout-based failure-detector model: confirmation arrives a fixed
/// number of virtual time units after the crash (§4.4's "when q's failure
/// is confirmed by a failure detector"). Benches add this to recovery
/// latency.
#[derive(Clone, Copy, Debug)]
pub struct DetectorModel {
    /// Heartbeat interval (virtual units).
    pub heartbeat: u64,
    /// Missed heartbeats before declaring failure.
    pub misses: u64,
}

impl Default for DetectorModel {
    fn default() -> Self {
        DetectorModel { heartbeat: 10, misses: 3 }
    }
}

impl DetectorModel {
    /// Virtual delay between a crash and its confirmation.
    pub fn confirmation_delay(&self) -> u64 {
        self.heartbeat * self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_fires_in_order() {
        let mut s = FailureSchedule::new(vec![(10, ProcId(2)), (5, ProcId(1))]);
        assert!(s.due(4).is_empty());
        assert_eq!(s.due(5), vec![ProcId(1)]);
        assert_eq!(s.due(100), vec![ProcId(2)]);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn random_schedule_is_deterministic() {
        let cands = [ProcId(0), ProcId(1), ProcId(2)];
        let a = FailureSchedule::random(9, 5, 1000, &cands);
        let b = FailureSchedule::random(9, 5, 1000, &cands);
        assert_eq!(a.crashes, b.crashes);
    }

    /// Root cause (fuzzer seed-space corner): `random` with no eligible
    /// victims called `Rng::choose(&[])` — a release-mode out-of-bounds
    /// read. It must mean "no crashes", not "undefined behaviour".
    #[test]
    fn random_with_no_candidates_is_empty() {
        let s = FailureSchedule::random(3, 5, 1000, &[]);
        assert!(s.is_empty());
        assert_eq!(s.remaining(), 0);
    }

    /// Root cause: a zero-event horizon fed `Rng::below(0)`, which
    /// debug-asserted (and silently returned 0 in release, scheduling
    /// every crash at event 0 of a run that has no events).
    #[test]
    fn random_with_zero_horizon_is_empty() {
        let cands = [ProcId(0), ProcId(1)];
        let mut s = FailureSchedule::random(3, 4, 0, &cands);
        assert!(s.is_empty());
        assert!(s.due(u64::MAX).is_empty());
    }

    #[test]
    fn zero_crashes_is_empty() {
        let cands = [ProcId(0)];
        let s = FailureSchedule::random(1, 0, 100, &cands);
        assert!(s.is_empty());
    }

    #[test]
    fn detector_delay() {
        let d = DetectorModel { heartbeat: 7, misses: 2 };
        assert_eq!(d.confirmation_delay(), 14);
    }
}
