//! Logical times (§2 of the paper).
//!
//! Every event — a message delivery or a notification — carries a logical
//! time from one of two families:
//!
//! - **Sequence numbers** (§2.1, Fig. 2a): a time is a pair `(edge, seq)`;
//!   times on different edges are incomparable, times on the same edge are
//!   ordered by sequence number.
//! - **Structured times** (§2.2–2.3, Fig. 2b/c): a time is an epoch plus
//!   zero or more nested loop counters. Epochs are the depth-0 special
//!   case. The partial order is the *product order* (as in Naiad/timely
//!   dataflow): `(e, c₁..cₖ) ≤ (e', c'₁..c'ₖ)` iff every coordinate is ≤.
//!
//! §4.1 of the paper additionally imposes a *lexicographic* total order on
//! times at a given processor so that frontiers collapse to a single
//! largest element; [`LexTime`] provides that order. The general frontier
//! algebra in [`crate::frontier`] works with the partial order.

use crate::graph::EdgeId;
use crate::util::ser::{Decode, Encode, Reader, SerError, Writer};
use std::cmp::Ordering;

/// Maximum nesting depth of loops supported in structured times. Keeping
/// this fixed lets [`Time`] be `Copy`, which keeps the per-message cost of
/// time tags at a few machine words (this matters: every message carries
/// one).
pub const MAX_LOOP_DEPTH: usize = 3;

/// Loop-counter value meaning "all iterations" (⊤ in the counter
/// coordinate). Used by frontiers to express e.g. `{(t, c) : all c}`,
/// which arises from loop-ingress edge projections (§3.2).
pub const CTR_INF: u64 = u64::MAX;

/// The loop-counter coordinates of a structured time.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, Default)]
pub struct Loops {
    depth: u8,
    c: [u64; MAX_LOOP_DEPTH],
}

impl Loops {
    /// No loop coordinates (a plain epoch).
    pub const NONE: Loops = Loops { depth: 0, c: [0; MAX_LOOP_DEPTH] };

    pub fn from_slice(cs: &[u64]) -> Loops {
        assert!(cs.len() <= MAX_LOOP_DEPTH, "loop depth {} exceeds max {MAX_LOOP_DEPTH}", cs.len());
        let mut c = [0u64; MAX_LOOP_DEPTH];
        c[..cs.len()].copy_from_slice(cs);
        Loops { depth: cs.len() as u8, c }
    }

    pub fn depth(&self) -> usize {
        self.depth as usize
    }

    pub fn as_slice(&self) -> &[u64] {
        &self.c[..self.depth as usize]
    }

    /// Push an innermost loop coordinate (entering a loop).
    pub fn enter(&self, ctr: u64) -> Loops {
        let mut l = *self;
        assert!((l.depth as usize) < MAX_LOOP_DEPTH, "loop nesting exceeds MAX_LOOP_DEPTH");
        l.c[l.depth as usize] = ctr;
        l.depth += 1;
        l
    }

    /// Pop the innermost loop coordinate (leaving a loop).
    pub fn exit(&self) -> Loops {
        assert!(self.depth > 0, "exit on depth-0 time");
        let mut l = *self;
        l.depth -= 1;
        l.c[l.depth as usize] = 0;
        l
    }

    /// Increment the innermost loop coordinate (feedback edge). Saturates
    /// at [`CTR_INF`].
    pub fn increment(&self) -> Loops {
        assert!(self.depth > 0, "increment on depth-0 time");
        let mut l = *self;
        let i = (l.depth - 1) as usize;
        l.c[i] = l.c[i].saturating_add(1);
        l
    }

    pub fn innermost(&self) -> u64 {
        assert!(self.depth > 0);
        self.c[(self.depth - 1) as usize]
    }
}

/// A logical time (see module docs).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum Time {
    /// Sequence-number time `(edge, seq)`; `seq` starts at 1 as in the
    /// paper's `{(e,1),…,(e,s)}` notation.
    Seq { edge: EdgeId, seq: u64 },
    /// Structured time: epoch + nested loop counters.
    Structured { epoch: u64, loops: Loops },
}

impl Time {
    /// A plain epoch time (depth-0 structured time).
    pub fn epoch(e: u64) -> Time {
        Time::Structured { epoch: e, loops: Loops::NONE }
    }

    /// A structured time with explicit loop counters.
    pub fn structured(epoch: u64, loops: &[u64]) -> Time {
        Time::Structured { epoch, loops: Loops::from_slice(loops) }
    }

    /// A sequence-number time.
    pub fn seq(edge: EdgeId, seq: u64) -> Time {
        Time::Seq { edge, seq }
    }

    /// The time domain this time belongs to.
    pub fn domain(&self) -> TimeDomain {
        match self {
            Time::Seq { .. } => TimeDomain::Seq,
            Time::Structured { loops, .. } => TimeDomain::Structured { depth: loops.depth },
        }
    }

    /// Partial order `self ≤ other` (§3.1). Returns `false` for
    /// incomparable or unrelated-domain pairs.
    pub fn le(&self, other: &Time) -> bool {
        match (self, other) {
            (Time::Seq { edge: e1, seq: s1 }, Time::Seq { edge: e2, seq: s2 }) => {
                e1 == e2 && s1 <= s2
            }
            (
                Time::Structured { epoch: t1, loops: l1 },
                Time::Structured { epoch: t2, loops: l2 },
            ) => {
                if l1.depth != l2.depth {
                    return false;
                }
                t1 <= t2 && l1.as_slice().iter().zip(l2.as_slice()).all(|(a, b)| a <= b)
            }
            _ => false,
        }
    }

    /// Strict partial order.
    pub fn lt(&self, other: &Time) -> bool {
        self.le(other) && self != other
    }

    /// True iff `self` and `other` are comparable in the partial order.
    pub fn comparable(&self, other: &Time) -> bool {
        self.le(other) || other.le(self)
    }

    /// Componentwise join (least upper bound) for structured times of
    /// equal depth; `None` otherwise.
    pub fn join(&self, other: &Time) -> Option<Time> {
        match (self, other) {
            (
                Time::Structured { epoch: t1, loops: l1 },
                Time::Structured { epoch: t2, loops: l2 },
            ) if l1.depth == l2.depth => {
                let mut c = [0u64; MAX_LOOP_DEPTH];
                for i in 0..l1.depth as usize {
                    c[i] = l1.c[i].max(l2.c[i]);
                }
                Some(Time::Structured {
                    epoch: (*t1).max(*t2),
                    loops: Loops { depth: l1.depth, c },
                })
            }
            (Time::Seq { edge: e1, seq: s1 }, Time::Seq { edge: e2, seq: s2 }) if e1 == e2 => {
                Some(Time::Seq { edge: *e1, seq: (*s1).max(*s2) })
            }
            _ => None,
        }
    }

    /// Componentwise meet (greatest lower bound), same domain rules as
    /// [`Time::join`].
    pub fn meet(&self, other: &Time) -> Option<Time> {
        match (self, other) {
            (
                Time::Structured { epoch: t1, loops: l1 },
                Time::Structured { epoch: t2, loops: l2 },
            ) if l1.depth == l2.depth => {
                let mut c = [0u64; MAX_LOOP_DEPTH];
                for i in 0..l1.depth as usize {
                    c[i] = l1.c[i].min(l2.c[i]);
                }
                Some(Time::Structured {
                    epoch: (*t1).min(*t2),
                    loops: Loops { depth: l1.depth, c },
                })
            }
            (Time::Seq { edge: e1, seq: s1 }, Time::Seq { edge: e2, seq: s2 }) if e1 == e2 => {
                Some(Time::Seq { edge: *e1, seq: (*s1).min(*s2) })
            }
            _ => None,
        }
    }

    /// The epoch coordinate of a structured time (panics on seq times).
    pub fn epoch_of(&self) -> u64 {
        match self {
            Time::Structured { epoch, .. } => *epoch,
            Time::Seq { .. } => panic!("epoch_of on a sequence-number time"),
        }
    }

    /// The loop coordinates of a structured time (panics on seq times).
    pub fn loops_of(&self) -> Loops {
        match self {
            Time::Structured { loops, .. } => *loops,
            Time::Seq { .. } => panic!("loops_of on a sequence-number time"),
        }
    }

    /// The sequence number of a seq time (panics on structured times).
    pub fn seq_of(&self) -> u64 {
        match self {
            Time::Seq { seq, .. } => *seq,
            Time::Structured { .. } => panic!("seq_of on a structured time"),
        }
    }
}

impl std::fmt::Display for Time {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Time::Seq { edge, seq } => write!(f, "(e{}, s{})", edge.0, seq),
            Time::Structured { epoch, loops } => {
                write!(f, "({epoch}")?;
                for c in loops.as_slice() {
                    if *c == CTR_INF {
                        write!(f, ", ∞")?;
                    } else {
                        write!(f, ", {c}")?;
                    }
                }
                write!(f, ")")
            }
        }
    }
}

/// A time domain: which family of logical times a processor uses (§3.2).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum TimeDomain {
    /// Sequence numbers on input edges.
    Seq,
    /// Structured times with the given loop-nesting depth (0 = epochs).
    Structured { depth: u8 },
}

impl TimeDomain {
    /// The epoch domain (depth-0 structured).
    pub const EPOCH: TimeDomain = TimeDomain::Structured { depth: 0 };

    /// Domain one loop deeper (entering a loop scope).
    pub fn deeper(&self) -> TimeDomain {
        match self {
            TimeDomain::Structured { depth } => TimeDomain::Structured { depth: depth + 1 },
            TimeDomain::Seq => panic!("loops in a seq-number domain are not supported"),
        }
    }

    /// Domain one loop shallower (leaving a loop scope).
    pub fn shallower(&self) -> TimeDomain {
        match self {
            TimeDomain::Structured { depth } => {
                assert!(*depth > 0, "shallower on depth-0 domain");
                TimeDomain::Structured { depth: depth - 1 }
            }
            TimeDomain::Seq => panic!("loops in a seq-number domain are not supported"),
        }
    }

    /// Whether `t` belongs to this domain.
    pub fn admits(&self, t: &Time) -> bool {
        t.domain() == *self
    }
}

/// Wrapper giving [`Time`] the *lexicographic total order* the paper's
/// Naiad implementation imposes per processor (§4.1): structured times
/// compare by epoch, then loop counters outermost-first; seq times by
/// (edge, seq). Seq times order before structured ones so `LexTime` is a
/// total order on all of `Time` (cross-domain comparisons never arise in
/// practice; the order just needs to be consistent for `BTreeMap` keys).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub struct LexTime(pub Time);

impl Ord for LexTime {
    fn cmp(&self, other: &Self) -> Ordering {
        match (&self.0, &other.0) {
            (Time::Seq { edge: e1, seq: s1 }, Time::Seq { edge: e2, seq: s2 }) => {
                e1.cmp(e2).then(s1.cmp(s2))
            }
            (
                Time::Structured { epoch: t1, loops: l1 },
                Time::Structured { epoch: t2, loops: l2 },
            ) => t1.cmp(t2).then_with(|| l1.as_slice().cmp(l2.as_slice())),
            (Time::Seq { .. }, Time::Structured { .. }) => Ordering::Less,
            (Time::Structured { .. }, Time::Seq { .. }) => Ordering::Greater,
        }
    }
}

impl PartialOrd for LexTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Encode for Time {
    fn encode(&self, w: &mut Writer) {
        match self {
            Time::Seq { edge, seq } => {
                w.u8(0);
                w.varint(edge.0 as u64);
                w.varint(*seq);
            }
            Time::Structured { epoch, loops } => {
                w.u8(1);
                w.varint(*epoch);
                w.u8(loops.depth);
                for c in loops.as_slice() {
                    w.varint(*c);
                }
            }
        }
    }
}

impl Decode for Time {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        match r.u8()? {
            0 => {
                let edge = EdgeId(r.varint()? as u32);
                let seq = r.varint()?;
                Ok(Time::Seq { edge, seq })
            }
            _ => {
                let epoch = r.varint()?;
                let depth = r.u8()? as usize;
                let mut cs = [0u64; MAX_LOOP_DEPTH];
                for c in cs.iter_mut().take(depth) {
                    *c = r.varint()?;
                }
                Ok(Time::Structured { epoch, loops: Loops { depth: depth as u8, c: cs } })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(i: u32) -> EdgeId {
        EdgeId(i)
    }

    #[test]
    fn seq_partial_order() {
        let a = Time::seq(e(0), 3);
        let b = Time::seq(e(0), 5);
        let c = Time::seq(e(1), 4);
        assert!(a.le(&b));
        assert!(!b.le(&a));
        assert!(a.le(&a));
        // Different edges are incomparable (§3.1).
        assert!(!a.le(&c) && !c.le(&a));
        assert!(!a.comparable(&c));
    }

    #[test]
    fn epoch_total_order() {
        let t1 = Time::epoch(1);
        let t2 = Time::epoch(2);
        assert!(t1.le(&t2));
        assert!(!t2.le(&t1));
        assert!(t1.comparable(&t2));
    }

    #[test]
    fn structured_product_order() {
        let a = Time::structured(1, &[2]);
        let b = Time::structured(2, &[3]);
        let c = Time::structured(2, &[1]);
        assert!(a.le(&b));
        // (1,2) vs (2,1): incomparable in the product order.
        assert!(!a.le(&c) && !c.le(&a));
        // but lexicographically ordered:
        assert!(LexTime(a) < LexTime(c));
    }

    #[test]
    fn cross_domain_incomparable() {
        let s = Time::seq(e(0), 1);
        let t = Time::epoch(1);
        assert!(!s.le(&t) && !t.le(&s));
        let d0 = Time::epoch(5);
        let d1 = Time::structured(5, &[0]);
        assert!(!d0.le(&d1) && !d1.le(&d0), "different depths are different domains");
    }

    #[test]
    fn join_meet() {
        let a = Time::structured(1, &[4]);
        let b = Time::structured(2, &[3]);
        assert_eq!(a.join(&b), Some(Time::structured(2, &[4])));
        assert_eq!(a.meet(&b), Some(Time::structured(1, &[3])));
        let s = Time::seq(e(0), 2);
        let t = Time::seq(e(0), 9);
        assert_eq!(s.join(&t), Some(Time::seq(e(0), 9)));
        assert_eq!(s.meet(&t), Some(Time::seq(e(0), 2)));
        assert_eq!(s.join(&a), None);
    }

    #[test]
    fn loops_enter_exit_increment() {
        let t = Time::epoch(7);
        let inner = Time::Structured { epoch: 7, loops: t.loops_of().enter(0) };
        assert_eq!(inner, Time::structured(7, &[0]));
        let inc = Time::Structured { epoch: 7, loops: inner.loops_of().increment() };
        assert_eq!(inc, Time::structured(7, &[1]));
        let out = Time::Structured { epoch: 7, loops: inc.loops_of().exit() };
        assert_eq!(out, Time::epoch(7));
    }

    #[test]
    fn ctr_inf_saturates() {
        let t = Time::structured(0, &[CTR_INF]);
        let inc = Time::Structured { epoch: 0, loops: t.loops_of().increment() };
        assert_eq!(inc, t);
        // (0, c) ≤ (0, ∞) for any c.
        assert!(Time::structured(0, &[12345]).le(&t));
    }

    #[test]
    fn lex_order_is_total_on_structured() {
        let mut ts = vec![
            Time::structured(2, &[0]),
            Time::structured(1, &[9]),
            Time::structured(1, &[0]),
            Time::structured(0, &[5]),
        ];
        ts.sort_by_key(|t| LexTime(*t));
        assert_eq!(
            ts,
            vec![
                Time::structured(0, &[5]),
                Time::structured(1, &[0]),
                Time::structured(1, &[9]),
                Time::structured(2, &[0]),
            ]
        );
    }

    #[test]
    fn time_encode_roundtrip() {
        use crate::util::ser::{Decode, Encode};
        for t in [
            Time::seq(e(3), 17),
            Time::epoch(0),
            Time::structured(5, &[1, 2]),
            Time::structured(1, &[CTR_INF]),
        ] {
            let bytes = t.to_bytes();
            assert_eq!(Time::from_bytes(&bytes).unwrap(), t);
        }
    }

    #[test]
    fn domain_admits() {
        assert!(TimeDomain::EPOCH.admits(&Time::epoch(3)));
        assert!(!TimeDomain::EPOCH.admits(&Time::structured(3, &[0])));
        assert!(TimeDomain::Seq.admits(&Time::seq(e(0), 1)));
        assert_eq!(TimeDomain::EPOCH.deeper(), TimeDomain::Structured { depth: 1 });
    }
}
