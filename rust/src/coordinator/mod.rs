//! The leader/coordinator layer: application assembly (the Figure-1
//! app), scenario drivers for the paper's figures, and the CLI.

pub mod cli;
pub mod fig1;

pub use fig1::{
    build as build_fig1, build_with_store as build_fig1_with_store, reopen as reopen_fig1,
    run as run_fig1, Fig1App, Fig1Config, Fig1Outcome,
};
