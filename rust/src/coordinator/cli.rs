//! Command-line interface of the `falkirk` binary.
//!
//! ```text
//! falkirk fig1   [--epochs N] [--fail rank_store] [--fail-after E] [--xla false] …
//! falkirk shard  [--workers W] [--fail-shard S] …  # sharded engine demo
//! falkirk fig7 --panel a|b|c      # the paper's worked rollback examples
//! falkirk gc-demo [--epochs N]    # §4.2 monitor watermark demo
//! falkirk selftest                # quick smoke of all layers
//! ```

use crate::coordinator::fig1::{run as run_fig1, Fig1Config};
use crate::metrics::json::{JsonArr, JsonObj};
use crate::util::cli::Args;
use crate::util::stats::LogHistogram;

const HELP: &str = "falkirk — rollback recovery for dataflow systems (Isard & Abadi, 2015)

USAGE: falkirk <command> [options]

COMMANDS:
  fig1      Run the Figure-1 four-regime application on synthetic streams.
            --epochs N (6) --queries N (4) --records N (32) --iters N (4)
            --window N (16) --keys N (8) --seed S (7) --write-cost C (10)
            --fail <proc> --fail-after E (2) --xla <true|false> (true)
            --batch-cap B (1) --mailbox-cap M (unbounded)
            --data-dir DIR --flush-every N (8)  # durable WAL store
            --persist-async --ack-every N (8)   # staged writer pipeline
            --snapshot-delta --snapshot-max-chain N (8)
                             # content-addressed incremental checkpoints
            --metrics-json FILE  # end-of-run falkirk-metrics/1 summary
  shard     Run the sharded keyed-aggregation job, optionally crashing
            worker shards and recovering only their key ranges.
            --workers W (4) --epochs N (6) --records N (64) --keys N (16)
            --seed S (7) --two-stage <true|false> (false)
            --fail-shard S[,S..] --fail-after E (2) --batch-cap B (1)
            --mailbox-cap M  # per-edge record budget; credit-based
                             # backpressure (default: unbounded;
                             # --keys 1 makes a fully skewed hot-key load)
            --threads T (1)  # T>1 drains AND recovers on the parallel
                             # engine (failing shards in different shard
                             # groups exercises parallel rollback)
            --data-dir DIR --flush-every N (8)  # durable WAL store
            --persist-async --ack-every N (8)   # staged writer pipeline
            --snapshot-delta --snapshot-max-chain N (8)
                             # content-addressed incremental checkpoints
            --metrics-json FILE  # end-of-run falkirk-metrics/1 summary
  store     Durable-store tooling.
            inspect <dir> [--json]
                             # dump segment / key / byte counts of a WAL,
                             # plus per-processor snapshot-chain depth,
                             # chunk counts, and dedup-reused bytes;
                             # --json emits one falkirk-store/1 document
  trace     Trace-file tooling. Set FALKIRK_TRACE_JSON=FILE on any fig1 /
            shard / fuzz run to capture a falkirk-trace/1 JSON-lines
            trace (epochs, deliveries, barriers, checkpoints, WAL and
            ack watermarks, and the recovery timeline: detect -> solver
            -> rollback -> replay).
            convert <file> [--out F]  # re-emit as Chrome trace_event
                                      # JSON for chrome://tracing
  fig7      Run a worked rollback example.  --panel a|b|c (c)
  gc-demo   Drive the §4.2 GC monitor and print watermark advances.
            --epochs N (8)
  fuzz      Seeded failure-simulation fuzzing: each seed generates a
            dataflow, knobs, and a fault schedule, then checks the run
            against a no-fault reference (see rust/src/fuzz/).
            --seed N (1) --runs K (1) --steps S (5000000)
            --metrics-json FILE  # end-of-run falkirk-metrics/1 summary
            Consecutive seeds N..N+K; exit 1 lists each failing seed
            (re-run with --seed <failing> --runs 1 to reproduce).
  selftest  Smoke-test all layers (engine, FT, recovery, kernels).
  help      Show this message.
";

/// Parse `--mailbox-cap` (absent = unbounded queues, the historical
/// behavior).
fn mailbox_cap_for(args: &Args) -> Result<Option<usize>, i32> {
    match args.get("mailbox-cap") {
        None => Ok(None),
        Some(raw) => match raw.parse::<usize>() {
            Ok(0) => {
                eprintln!("--mailbox-cap must be at least 1");
                Err(2)
            }
            Ok(n) => Ok(Some(n)),
            Err(_) => {
                eprintln!("--mailbox-cap '{raw}' is not a record count");
                Err(2)
            }
        },
    }
}

/// Resolve `--snapshot-delta` / `--snapshot-max-chain` into a
/// [`crate::ft::SnapshotPolicy`]: absent = monolithic full snapshots
/// (the historical behavior), `--snapshot-delta` = content-addressed
/// delta chains with a forced-full walk bound.
fn snapshot_policy_for(args: &Args) -> Result<crate::ft::SnapshotPolicy, i32> {
    if !args.flag("snapshot-delta") {
        if args.get("snapshot-max-chain").is_some() {
            eprintln!("--snapshot-max-chain requires --snapshot-delta");
            return Err(2);
        }
        return Ok(crate::ft::SnapshotPolicy::Full);
    }
    let max_chain = args.get_u64("snapshot-max-chain", 8);
    if max_chain == 0 {
        eprintln!("--snapshot-max-chain must be at least 1");
        return Err(2);
    }
    Ok(crate::ft::SnapshotPolicy::Delta { max_chain })
}

/// Resolve `--persist-async` / `--ack-every` into a [`PersistMode`].
fn persist_mode_for(args: &Args) -> Result<crate::ft::PersistMode, i32> {
    if !args.flag("persist-async") {
        return Ok(crate::ft::PersistMode::Sync);
    }
    let ack_every = args.get_usize("ack-every", 8);
    if ack_every == 0 {
        eprintln!("--ack-every must be at least 1");
        return Err(2);
    }
    Ok(crate::ft::PersistMode::Async { ack_every })
}

/// Open a durable store when `--data-dir` was given, the in-memory one
/// otherwise. A fresh run restarts storage-key numbering, so reusing a
/// directory that already holds a WAL would splice two runs' histories —
/// refuse it instead.
fn store_for(args: &Args, write_cost: u64) -> Result<crate::ft::Store, i32> {
    match args.get("data-dir") {
        None => Ok(crate::ft::Store::new(write_cost)),
        Some(dir) => {
            let flush_every_n = args.get_usize("flush-every", 8);
            if flush_every_n == 0 {
                eprintln!("--flush-every must be at least 1");
                return Err(2);
            }
            // Probe read-only first: the emptiness check must not repair
            // (truncate) a crashed WAL it is about to refuse — that would
            // destroy the very tail `store inspect` preserves.
            if std::path::Path::new(dir).is_dir() {
                let probe = crate::ft::Store::open_dir_read_only(
                    dir,
                    crate::ft::FileBackendOptions::default(),
                )
                .map_err(|e| {
                    eprintln!("cannot open durable store at '{dir}': {e}");
                    2
                })?;
                let live = probe.backend_info().live_keys;
                if live > 0 {
                    eprintln!(
                        "refusing --data-dir '{dir}': it already holds a WAL with {live} live \
                         keys from a previous run; use an empty directory (or examine the old \
                         one with `falkirk store inspect {dir}`)"
                    );
                    return Err(2);
                }
            }
            crate::ft::Store::open_dir(
                dir,
                write_cost,
                crate::ft::FileBackendOptions { flush_every_n, ..Default::default() },
            )
            .map_err(|e| {
                eprintln!("cannot open durable store at '{dir}': {e}");
                2
            })
        }
    }
}

/// Schema tag of the `--metrics-json` end-of-run summary documents.
const METRICS_SCHEMA: &str = "falkirk-metrics/1";

/// A [`LogHistogram`] as one JSON object (ns-valued percentiles).
fn histogram_json(h: &LogHistogram) -> String {
    let mut o = JsonObj::new();
    o.u64_field("count", h.count())
        .f64_field("mean_ns", h.mean())
        .u64_field("p50_ns", h.p50())
        .u64_field("p99_ns", h.p99())
        .u64_field("max_ns", h.max());
    o.finish()
}

/// Write a finished `falkirk-metrics/1` document where `--metrics-json`
/// points (no-op when the option is absent).
fn emit_metrics(args: &Args, doc: String) -> Result<(), i32> {
    let Some(path) = args.get("metrics-json") else { return Ok(()) };
    std::fs::write(path, doc + "\n").map_err(|e| {
        eprintln!("cannot write --metrics-json '{path}': {e}");
        1
    })
}

/// Append a run's trace where [`crate::trace::ENV_TRACE_JSON`] points
/// (no-op when the tracer was not attached).
fn flush_trace(trace: &Option<(crate::trace::Tracer, String)>) -> Result<(), i32> {
    let Some((tr, path)) = trace else { return Ok(()) };
    tr.append_json_lines(path).map_err(|e| {
        eprintln!("cannot append trace to '{path}' ({}): {e}", crate::trace::ENV_TRACE_JSON);
        1
    })
}

/// Entry point; returns the process exit code.
pub fn run(raw: &[String]) -> i32 {
    let args = Args::parse(raw);
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "fig1" => cmd_fig1(&args),
        "shard" => cmd_shard(&args),
        "store" => cmd_store(&args),
        "trace" => cmd_trace(&args),
        "fig7" => cmd_fig7(&args),
        "gc-demo" => cmd_gc_demo(&args),
        "fuzz" => cmd_fuzz(&args),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            2
        }
    }
}

fn cmd_fig1(args: &Args) -> i32 {
    let cfg = Fig1Config {
        epochs: args.get_u64("epochs", 6),
        queries_per_epoch: args.get_usize("queries", 4),
        records_per_epoch: args.get_usize("records", 32),
        iters: args.get_u64("iters", 4),
        window: args.get_usize("window", 16),
        num_keys: args.get_usize("keys", 8),
        fail_proc: args.get("fail").map(|s| s.to_string()),
        fail_after_epoch: args.get_u64("fail-after", 2),
        seed: args.get_u64("seed", 7),
        write_cost: args.get_u64("write-cost", 10),
        use_xla: args.get_str("xla", "true") == "true",
        batch_cap: args.get_usize("batch-cap", 1),
        mailbox_cap: match mailbox_cap_for(args) {
            Ok(m) => m,
            Err(code) => return code,
        },
        persist_mode: match persist_mode_for(args) {
            Ok(m) => m,
            Err(code) => return code,
        },
        snapshot_policy: match snapshot_policy_for(args) {
            Ok(p) => p,
            Err(code) => return code,
        },
    };
    let store = match store_for(args, cfg.write_cost) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let trace = crate::trace::Tracer::from_env();
    let out = crate::coordinator::fig1::run_traced(
        &cfg,
        store,
        trace.as_ref().map(|(t, _)| t.clone()),
    );
    println!("fig1: kernels = {}", if out.used_xla { "XLA artifacts" } else { "reference (run `make artifacts`)" });
    println!("  responses        {}", out.responses);
    println!("  db commits       {}  (duplicates suppressed: {})", out.db_commits, out.db_duplicates);
    println!("  checkpoints      {}", out.checkpoints);
    println!("  log entries      {}", out.log_entries);
    println!("  storage writes   {} ({} bytes)", out.storage_writes, out.storage_bytes);
    if let crate::ft::PersistMode::Async { ack_every } = cfg.persist_mode {
        println!("  persist          async (ack_every {ack_every}), peak ack-lag {}", out.ack_lag);
    }
    if let crate::ft::SnapshotPolicy::Delta { max_chain } = cfg.snapshot_policy {
        println!(
            "  snapshots        delta (max_chain {max_chain}); chunks reused {} ({} bytes)",
            out.chunks_reused, out.chunk_bytes_reused
        );
    }
    if out.storage_errors > 0 {
        println!("  storage errors   {}", out.storage_errors);
    }
    println!("  events           {}", out.events);
    println!("  elapsed          {:.2} ms", out.elapsed_ms);
    if let Some(rec) = &out.recovery {
        println!("  RECOVERY ({} failed):", rec.victim);
        println!("    solve+reset wall   {:.1} µs", rec.recover_wall_us);
        println!("    replayed from logs {}", rec.replayed);
        println!("    queued dropped     {}", rec.dropped);
        println!("    restored/reset/⊤   {}/{}/{}", rec.restored, rec.reset_to_empty, rec.untouched);
        println!("    client redelivered {}", rec.input_redeliveries);
        println!("    re-quiesce events  {}", rec.requiesce_events);
    }
    let mut epoch_h = LogHistogram::new();
    for &ns in &out.epoch_wall_ns {
        epoch_h.record(ns);
    }
    let mut counters = JsonObj::new();
    counters
        .u64_field("responses", out.responses as u64)
        .u64_field("db_commits", out.db_commits as u64)
        .u64_field("db_duplicates", out.db_duplicates)
        .u64_field("checkpoints", out.checkpoints)
        .u64_field("log_entries", out.log_entries)
        .u64_field("storage_writes", out.storage_writes)
        .u64_field("storage_bytes", out.storage_bytes)
        .u64_field("ack_lag_peak", out.ack_lag)
        .u64_field("chunks_reused", out.chunks_reused)
        .u64_field("chunk_bytes_reused", out.chunk_bytes_reused)
        .u64_field("storage_errors", out.storage_errors)
        .u64_field("events", out.events);
    let mut doc = JsonObj::new();
    doc.str_field("schema", METRICS_SCHEMA)
        .str_field("command", "fig1")
        .u64_field("seed", cfg.seed)
        .u64_field("epochs", cfg.epochs)
        .bool_field("used_xla", out.used_xla)
        .f64_field("elapsed_ms", out.elapsed_ms)
        .raw_field("epoch_wall", &histogram_json(&epoch_h))
        .raw_field("counters", &counters.finish());
    if let Some(rec) = &out.recovery {
        let mut r = JsonObj::new();
        r.str_field("victim", &rec.victim)
            .f64_field("recover_wall_us", rec.recover_wall_us)
            .u64_field("replayed", rec.replayed as u64)
            .u64_field("dropped", rec.dropped as u64)
            .u64_field("restored_from_checkpoint", rec.restored as u64)
            .u64_field("reset_to_empty", rec.reset_to_empty as u64)
            .u64_field("untouched", rec.untouched as u64)
            .u64_field("input_redeliveries", rec.input_redeliveries)
            .u64_field("requiesce_events", rec.requiesce_events);
        doc.raw_field("recovery", &r.finish());
    }
    if let Err(code) = emit_metrics(args, doc.finish()) {
        return code;
    }
    if let Err(code) = flush_trace(&trace) {
        return code;
    }
    0
}

fn cmd_shard(args: &Args) -> i32 {
    use crate::bench_support::sharded::{canonical_output, drive_epoch, ShardedConfig, Throughput};
    let workers = args.get_u64("workers", 4) as u32;
    let epochs = args.get_u64("epochs", 6);
    let records = args.get_usize("records", 64);
    let keys = args.get_u64("keys", 16);
    let seed = args.get_u64("seed", 7);
    let two_stage = args.get_str("two-stage", "false") == "true";
    let batch_cap = args.get_usize("batch-cap", 1);
    let threads = args.get_usize("threads", 1);
    // One shard index or a comma-separated list: failing shards in
    // different shard groups is what exercises parallel recovery.
    let fail_shards: Vec<usize> = match args.get("fail-shard") {
        None => Vec::new(),
        Some(raw) => {
            let mut out = Vec::new();
            for part in raw.split(',') {
                match part.trim().parse::<usize>() {
                    Ok(s) => out.push(s),
                    Err(_) => {
                        eprintln!("--fail-shard '{part}' is not a shard index");
                        return 2;
                    }
                }
            }
            out
        }
    };
    let fail_after = args.get_u64("fail-after", 2);

    if workers == 0 {
        eprintln!("--workers must be at least 1");
        return 2;
    }
    if threads == 0 {
        eprintln!("--threads must be at least 1");
        return 2;
    }
    let persist_mode = match persist_mode_for(args) {
        Ok(m) => m,
        Err(code) => return code,
    };
    let mailbox_cap = match mailbox_cap_for(args) {
        Ok(m) => m,
        Err(code) => return code,
    };
    let snapshot_policy = match snapshot_policy_for(args) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let cfg = ShardedConfig {
        workers,
        two_stage,
        batch_cap,
        threads,
        mailbox_cap,
        persist_mode,
        snapshot_policy,
        ..Default::default()
    };
    for &s in &fail_shards {
        if s >= workers as usize {
            eprintln!("--fail-shard {s} out of range (workers = {workers})");
            return 2;
        }
    }
    let store = match store_for(args, cfg.write_cost) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let trace = crate::trace::Tracer::from_env();
    let mut p = crate::bench_support::sharded::pipeline_with_store(&cfg, store);
    p.sys.set_tracer(trace.as_ref().map(|(t, _)| t.clone()));
    let mut epoch_h = LogHistogram::new();
    let t0 = std::time::Instant::now();
    for ep in 0..epochs {
        let t_epoch = std::time::Instant::now();
        let trace_t0 = trace.as_ref().map(|(t, _)| t.now_ns());
        drive_epoch(&mut p, seed, ep, records, keys);
        if !fail_shards.is_empty() && ep == fail_after {
            let victims: Vec<crate::graph::ProcId> =
                fail_shards.iter().map(|&s| p.plan.proc(p.count, s)).collect();
            p.sys.inject_failures(&victims);
            // T > 1 runs the §3.6 reset and replay decomposed onto the
            // same shard groups as the drains; T = 1 is the sequential
            // path. Byte-identical either way (checksum below).
            let rep = if threads > 1 {
                p.sys.recover_parallel(&p.groups, threads)
            } else {
                p.sys.recover()
            };
            let names: Vec<String> =
                fail_shards.iter().map(|s| format!("count#{s}")).collect();
            println!("crash {} after epoch {ep}:", names.join(", "));
            for sh in 0..workers as usize {
                println!(
                    "  f(count#{sh}) = {}",
                    rep.plan.frontier(p.plan.proc(p.count, sh))
                );
            }
            println!(
                "  rolled back {} of {} processors, replayed {} logged messages \
                 (restore lanes {}, replay lanes {})",
                rep.plan.rolled_back().len(),
                p.plan.topo.num_procs(),
                rep.replayed,
                p.sys.stats.recovery_parallelism,
                p.sys.stats.replay_workers
            );
        }
        epoch_h.record(t_epoch.elapsed().as_nanos() as u64);
        if let (Some((tr, _)), Some(ts)) = (&trace, trace_t0) {
            tr.span(0, "driver", "epoch", ts, &[("epoch", ep)]);
        }
    }
    let src = p.src_proc();
    p.sys.close_input(src);
    p.run(5_000_000);
    let tp = Throughput {
        records: epochs * records as u64,
        events: p.sys.engine.events_processed(),
        elapsed_secs: t0.elapsed().as_secs_f64(),
    };
    let cap_str = match mailbox_cap {
        Some(c) => c.to_string(),
        None => "unbounded".to_string(),
    };
    println!(
        "shard: W={workers} threads={threads} two_stage={two_stage} epochs={epochs} \
         batch_cap={batch_cap} mailbox_cap={cap_str}"
    );
    println!("  events           {}", tp.events);
    println!("  peak mailbox     {} records", p.sys.engine.peak_queue_records());
    println!("  events/sec       {:.0}", tp.events_per_sec());
    println!("  records/sec      {:.0}", tp.records_per_sec());
    println!("  log writes       {} batches / {} records", p.sys.stats.log_entries, p.sys.stats.log_records);
    if let crate::ft::PersistMode::Async { ack_every } = persist_mode {
        println!(
            "  persist          async (ack_every {ack_every}), peak ack-lag {}, errors {}",
            p.sys.stats.ack_lag, p.sys.stats.storage_errors
        );
    }
    println!("  checkpoints      {}", p.sys.stats.checkpoints_taken);
    if let crate::ft::SnapshotPolicy::Delta { max_chain } = snapshot_policy {
        let st = p.sys.store.stats();
        println!(
            "  snapshots        delta (max_chain {max_chain}); chunks reused {} ({} bytes)",
            st.chunks_reused, st.chunk_bytes_reused
        );
    }
    println!("  recoveries       {}", p.sys.stats.recoveries);
    println!("  replayed msgs    {}", p.sys.stats.messages_replayed);
    let out = canonical_output(&p.sys, p.collect_proc());
    // Checksum of the canonical bytes: identical across thread counts and
    // batch caps iff the observable output is identical.
    let h = crate::util::hash::fnv1a(&out);
    println!("  output bytes     {} (fnv1a {h:016x})", out.len());
    let mut counters = JsonObj::new();
    counters
        .u64_field("records", tp.records)
        .u64_field("events", tp.events)
        .u64_field("peak_mailbox_records", p.sys.engine.peak_queue_records() as u64)
        .u64_field("log_entries", p.sys.stats.log_entries)
        .u64_field("log_records", p.sys.stats.log_records)
        .u64_field("checkpoints", p.sys.stats.checkpoints_taken)
        .u64_field("ack_lag_peak", p.sys.stats.ack_lag)
        .u64_field("storage_errors", p.sys.stats.storage_errors)
        .u64_field("recoveries", p.sys.stats.recoveries)
        .u64_field("messages_replayed", p.sys.stats.messages_replayed)
        .u64_field("recovery_parallelism", p.sys.stats.recovery_parallelism)
        .u64_field("replay_workers", p.sys.stats.replay_workers)
        .u64_field("output_bytes", out.len() as u64);
    let mut doc = JsonObj::new();
    doc.str_field("schema", METRICS_SCHEMA)
        .str_field("command", "shard")
        .u64_field("seed", seed)
        .u64_field("epochs", epochs)
        .u64_field("workers", workers as u64)
        .u64_field("threads", threads as u64)
        .f64_field("elapsed_secs", tp.elapsed_secs)
        .f64_field("records_per_sec", tp.records_per_sec())
        .f64_field("events_per_sec", tp.events_per_sec())
        .raw_field("epoch_wall", &histogram_json(&epoch_h))
        .raw_field("counters", &counters.finish())
        .str_field("output_fnv1a", &format!("{h:016x}"));
    if let Err(code) = emit_metrics(args, doc.finish()) {
        return code;
    }
    if let Err(code) = flush_trace(&trace) {
        return code;
    }
    0
}

fn cmd_store(args: &Args) -> i32 {
    let pos = args.positional();
    match pos.get(1).map(|s| s.as_str()) {
        Some("inspect") => {
            let Some(dir) = pos.get(2) else {
                eprintln!("usage: falkirk store inspect <dir>");
                return 2;
            };
            let store = match store_for_dir(dir) {
                Ok(s) => s,
                Err(code) => return code,
            };
            let info = store.backend_info();
            // Per-kind breakdown over the processors actually present.
            // Sizes come from the index — no blob reads.
            use crate::ft::Kind;
            let mut counts: std::collections::BTreeMap<&str, (u64, u64)> = Default::default();
            for proc in store.procs() {
                for (k, size) in store.scan_entries(proc) {
                    let name = match k.kind {
                        Kind::Meta => "meta (Ξ)",
                        Kind::State => "state",
                        Kind::LogEntry => "log entries",
                        Kind::HistoryEvent => "history events",
                        Kind::InputFrontier => "input markers",
                        Kind::Chunk => "state chunks",
                        Kind::Snapshot => "snapshot records",
                    };
                    let e = counts.entry(name).or_insert((0, 0));
                    e.0 += 1;
                    e.1 += size;
                }
            }
            let chains = snapshot_chain_rows(&store);
            if args.flag("json") {
                let mut backend = JsonObj::new();
                backend
                    .str_field("name", &info.name)
                    .u64_field("segments", info.segments as u64)
                    .u64_field("file_bytes", info.file_bytes as u64)
                    .u64_field("live_keys", info.live_keys as u64)
                    .u64_field("live_bytes", info.live_bytes as u64)
                    .u64_field("dead_bytes", info.dead_bytes as u64)
                    .u64_field("compactions", info.compactions as u64);
                let mut kinds = JsonArr::new();
                for (name, (n, bytes)) in &counts {
                    let mut k = JsonObj::new();
                    k.str_field("kind", name).u64_field("keys", *n).u64_field("bytes", *bytes);
                    kinds.push_raw(&k.finish());
                }
                let mut arr = JsonArr::new();
                for c in &chains {
                    let mut o = JsonObj::new();
                    o.u64_field("proc", c.proc.0 as u64)
                        .u64_field("snapshots", c.records)
                        .u64_field("newest_chain_depth", c.depth)
                        .u64_field("chunks", c.chunk_keys)
                        .u64_field("chunk_bytes", c.chunk_bytes)
                        .u64_field("dedup_reused_bytes", c.dedup_reused);
                    arr.push_raw(&o.finish());
                }
                let mut doc = JsonObj::new();
                doc.str_field("schema", "falkirk-store/1")
                    .str_field("dir", dir)
                    .raw_field("backend", &backend.finish())
                    .raw_field("kinds", &kinds.finish())
                    .raw_field("snapshot_chains", &arr.finish());
                println!("{}", doc.finish());
                return 0;
            }
            println!("store {dir} ({}):", info.name);
            println!("  segments         {}", info.segments);
            println!("  file bytes       {}", info.file_bytes);
            println!("  live keys        {}", info.live_keys);
            println!("  live bytes       {}", info.live_bytes);
            println!("  dead bytes       {}", info.dead_bytes);
            println!("  compactions      {}", info.compactions);
            for (name, (n, bytes)) in counts {
                println!("  {name:<16} {n} keys / {bytes} bytes");
            }
            for c in &chains {
                println!(
                    "  proc {}: {} snapshot records (newest chain depth {}), \
                     {} chunks / {} bytes, dedup-reused {} bytes",
                    c.proc, c.records, c.depth, c.chunk_keys, c.chunk_bytes, c.dedup_reused
                );
            }
            0
        }
        other => {
            eprintln!(
                "unknown store subcommand {:?}\nusage: falkirk store inspect <dir> [--json]",
                other.unwrap_or("<none>")
            );
            2
        }
    }
}

/// One processor's durable snapshot-chain summary (see
/// [`snapshot_chain_rows`]); rendered as text by `store inspect` and as
/// one `snapshot_chains` element by `store inspect --json`.
struct ChainRow {
    proc: crate::graph::ProcId,
    records: u64,
    depth: u64,
    chunk_keys: u64,
    chunk_bytes: u64,
    dedup_reused: u64,
}

/// Per-processor breakdown of the durable snapshot chains: how many
/// snapshot records exist and how deep the newest chain walks, how many
/// content-addressed chunks back them, and how many bytes the snapshot
/// listings reference beyond what is stored once (the durable dedup
/// win). Only `Kind::Snapshot` records are decoded — chunk sizes come
/// from the index, so no chunk blob is read.
fn snapshot_chain_rows(store: &crate::ft::Store) -> Vec<ChainRow> {
    use crate::ft::storage::chunk_span;
    use crate::ft::{Kind, Snapshot};
    use crate::util::ser::Decode;
    let mut rows = Vec::new();
    for proc in store.procs() {
        let mut records = std::collections::BTreeMap::new();
        for key in store.keys_for(proc, Kind::Snapshot) {
            let Some(bytes) = store.get(&key) else { continue };
            if let Ok(snap) = Snapshot::from_bytes(&bytes) {
                records.insert(key.tag, snap);
            }
        }
        let Some(&newest) = records.keys().next_back() else { continue };
        let (chunk_keys, chunk_bytes) = store
            .scan_entries(proc)
            .iter()
            .filter(|(k, _)| k.kind == Kind::Chunk)
            .fold((0u64, 0u64), |(n, b), (_, size)| (n + 1, b + size));
        // Depth of the newest chain. Prior tags strictly decrease along
        // a well-formed chain; stop at a non-decreasing pointer or a
        // pruned base rather than looping.
        let mut depth = 1u64;
        let mut tag = newest;
        while let Some(prior) =
            records.get(&tag).and_then(|s| s.prior_snapshot).filter(|&p| p < tag)
        {
            if !records.contains_key(&prior) {
                break;
            }
            depth += 1;
            tag = prior;
        }
        // Bytes the listings cover, minus bytes stored once = bytes the
        // content-addressed representation never re-wrote.
        let listed: u64 = records
            .values()
            .map(|s| {
                s.chunks
                    .iter()
                    .map(|&(pos, _)| chunk_span(pos as usize, s.state_len as usize).len() as u64)
                    .sum::<u64>()
            })
            .sum();
        rows.push(ChainRow {
            proc,
            records: records.len() as u64,
            depth,
            chunk_keys,
            chunk_bytes,
            dedup_reused: listed.saturating_sub(chunk_bytes),
        });
    }
    rows
}

fn cmd_trace(args: &Args) -> i32 {
    let pos = args.positional();
    match pos.get(1).map(|s| s.as_str()) {
        Some("convert") => {
            let Some(file) = pos.get(2) else {
                eprintln!("usage: falkirk trace convert <file> [--out F]");
                return 2;
            };
            let text = match std::fs::read_to_string(file) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("cannot read trace '{file}': {e}");
                    return 2;
                }
            };
            let (doc, stats) = match crate::trace::convert::to_chrome(&text) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("cannot convert '{file}': {e}");
                    return 2;
                }
            };
            let out_path = args
                .get("out")
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("{file}.chrome.json"));
            if let Err(e) = std::fs::write(&out_path, doc + "\n") {
                eprintln!("cannot write '{out_path}': {e}");
                return 1;
            }
            println!(
                "trace: {} events ({} spans, {} instants) -> {out_path}",
                stats.events, stats.spans, stats.instants
            );
            0
        }
        other => {
            eprintln!(
                "unknown trace subcommand {:?}\nusage: falkirk trace convert <file> [--out F]",
                other.unwrap_or("<none>")
            );
            2
        }
    }
}

/// Open an existing WAL directory for inspection — read-only: no tail
/// repair, so inspecting a just-crashed store destroys nothing.
fn store_for_dir(dir: &str) -> Result<crate::ft::Store, i32> {
    crate::ft::Store::open_dir_read_only(dir, crate::ft::FileBackendOptions::default()).map_err(
        |e| {
            eprintln!("cannot open durable store at '{dir}': {e}");
            2
        },
    )
}

fn cmd_fig7(args: &Args) -> i32 {
    let panel = args.get_str("panel", "c");
    match panel {
        "a" => {
            // Seq-number pipeline where everyone logs: non-failed keep
            // state, failed x replays from upstream logs.
            let mut sc = crate::baselines::exactly_once(1);
            sc.sys.advance_input(sc.src, crate::time::Time::epoch(0));
            for i in 1..=6 {
                sc.sys.push_input(sc.src, crate::time::Time::epoch(0), crate::engine::Record::Int(i));
            }
            sc.sys.run_to_quiescence(10_000);
            sc.sys.inject_failures(&[sc.mid]);
            let rep = sc.sys.recover();
            println!("fig7(a): f = {:?}", rep.plan.f.iter().map(|f| format!("{f}")).collect::<Vec<_>>());
            println!("  replayed {} / dropped {}", rep.replayed, rep.dropped);
        }
        "b" => {
            let mut sc = crate::baselines::spark_lineage(1);
            sc.sys.advance_input(sc.src, crate::time::Time::epoch(0));
            for i in 0..6 {
                sc.sys.push_input(sc.src, crate::time::Time::epoch(0), crate::engine::Record::Int(i));
            }
            sc.sys.advance_input(sc.src, crate::time::Time::epoch(1));
            sc.sys.run_to_quiescence(10_000);
            sc.sys.inject_failures(&[sc.sink_proc]);
            let rep = sc.sys.recover();
            println!("fig7(b): f = {:?}", rep.plan.f.iter().map(|f| format!("{f}")).collect::<Vec<_>>());
            println!("  RDD firewall kept p,q,r at ⊤; replayed {}", rep.replayed);
        }
        _ => {
            let mut cfg = Fig1Config { epochs: 3, use_xla: false, ..Default::default() };
            cfg.fail_proc = Some("rank_store".to_string());
            cfg.fail_after_epoch = 1;
            let out = run_fig1(&cfg);
            let rec = out.recovery.unwrap();
            println!("fig7(c): loop rollback — rank_store failed inside the iterative regime");
            println!("  restored {} / reset {} / untouched {}", rec.restored, rec.reset_to_empty, rec.untouched);
            println!("  replayed {} logged messages", rec.replayed);
        }
    }
    0
}

fn cmd_gc_demo(args: &Args) -> i32 {
    use crate::frontier::Frontier;
    use crate::ft::monitor::Monitor;
    use crate::ft::meta::CkptMeta;
    use crate::graph::{GraphBuilder, ProcId, Projection};
    use crate::time::TimeDomain;
    let epochs = args.get_u64("epochs", 8);
    let mut g = GraphBuilder::new();
    let a = g.add_proc("a", TimeDomain::EPOCH);
    let b = g.add_proc("b", TimeDomain::EPOCH);
    let c = g.add_proc("c", TimeDomain::EPOCH);
    let e0 = g.connect(a, b, Projection::Identity);
    let e1 = g.connect(b, c, Projection::Identity);
    let topo = std::sync::Arc::new(g.build().unwrap());
    let mut mon = Monitor::new(topo, vec![false; 3], vec![false; 3]);
    let ck = |ep: u64, ins: &[crate::graph::EdgeId], outs: &[crate::graph::EdgeId]| {
        let f = Frontier::upto_epoch(ep);
        CkptMeta {
            f: f.clone(),
            n_bar: f.clone(),
            m_bar: ins.iter().map(|d| (*d, f.clone())).collect(),
            d_bar: outs.iter().map(|o| (*o, f.clone())).collect(),
            phi: outs.iter().map(|o| (*o, f.clone())).collect(),
        }
    };
    for ep in 0..epochs {
        // b persists one epoch behind c, a on time: watermark trails the
        // slowest persister.
        let acts_a = mon.on_persisted(ProcId(0), ck(ep, &[], &[e0]));
        let acts_c = mon.on_persisted(ProcId(2), ck(ep, &[e1], &[]));
        let acts_b = if ep > 0 {
            mon.on_persisted(ProcId(1), ck(ep - 1, &[e0], &[e1]))
        } else {
            vec![]
        };
        println!(
            "epoch {ep}: watermark(b) = {}  (gc actions: {})",
            mon.low_watermark(ProcId(1)),
            acts_a.len() + acts_b.len() + acts_c.len()
        );
    }
    0
}

fn cmd_fuzz(args: &Args) -> i32 {
    let seed = args.get_u64("seed", 1);
    let runs = args.get_u64("runs", 1);
    let steps = args.get_usize("steps", 5_000_000);
    if runs == 0 {
        eprintln!("--runs must be at least 1");
        return 2;
    }
    let report = crate::fuzz::campaign(seed, runs, steps);
    for v in &report.verdicts {
        println!(
            "seed {:>6} {} digest {:016x} recoveries {} | {} | {} | {}",
            v.seed,
            if v.pass { "PASS" } else { "FAIL" },
            v.digest,
            v.recoveries,
            v.shape,
            v.knobs,
            v.faults
        );
        for viol in &v.violations {
            println!("         - {viol}");
        }
    }
    let failures = report.failures();
    println!(
        "fuzz: {}/{} seeds passed (campaign digest {:016x})",
        report.verdicts.len() - failures.len(),
        report.verdicts.len(),
        report.digest()
    );
    let mut verdicts = JsonArr::new();
    for v in &report.verdicts {
        let mut o = JsonObj::new();
        o.u64_field("seed", v.seed)
            .bool_field("pass", v.pass)
            .str_field("digest", &format!("{:016x}", v.digest))
            .u64_field("recoveries", v.recoveries as u64)
            .u64_field("violations", v.violations.len() as u64);
        verdicts.push_raw(&o.finish());
    }
    let mut doc = JsonObj::new();
    doc.str_field("schema", METRICS_SCHEMA)
        .str_field("command", "fuzz")
        .u64_field("seed", seed)
        .u64_field("runs", runs)
        .u64_field("passed", (report.verdicts.len() - failures.len()) as u64)
        .u64_field("failed", failures.len() as u64)
        .str_field("campaign_digest", &format!("{:016x}", report.digest()))
        .raw_field("verdicts", &verdicts.finish());
    if let Err(code) = emit_metrics(args, doc.finish()) {
        return code;
    }
    if failures.is_empty() {
        0
    } else {
        for v in &failures {
            eprintln!("failing seed: {} (reproduce: falkirk fuzz --seed {} --runs 1)", v.seed, v.seed);
        }
        1
    }
}

fn cmd_selftest() -> i32 {
    // Engine + FT + recovery.
    let mut cfg = Fig1Config { epochs: 3, use_xla: true, ..Default::default() };
    cfg.fail_proc = Some("rank_store".to_string());
    cfg.fail_after_epoch = 1;
    let out = run_fig1(&cfg);
    let ok_recovery = out.recovery.as_ref().map(|r| r.restored >= 1).unwrap_or(false);
    println!(
        "selftest: kernels={} responses={} db={} recovery_restored={}",
        if out.used_xla { "xla" } else { "mock" },
        out.responses,
        out.db_commits,
        ok_recovery
    );
    if out.responses > 0 && out.db_commits > 0 && ok_recovery {
        println!("selftest OK");
        0
    } else {
        println!("selftest FAILED");
        1
    }
}
