//! The Figure-1 application: "a complex streaming application" combining
//! all four fault-tolerance regimes in one dataflow.
//!
//! ```text
//!  queries ──► select ──► to_kv ──────────────► join_batch ─► join_iter ──► resp (user)
//!  (ephemeral)                                     ▲              ▲   └───► db (eager, seq)
//!  records ──► reduce ──┬─► batch_agg (XLA) ───────┘              │
//!  (ephemeral)          └─► t_collect ─► [ingress ► iterate(XLA) ► egress] ─► rank_store
//!                                            ▲ feedback ◄┘                   (lazy ckpt)
//! ```
//!
//! Regimes (shading in the paper's figure):
//! - **ephemeral**: query/record ingestion and pre-reduction — nothing
//!   persisted; clients retry unacknowledged batches (§4.3);
//! - **batch**: the periodically-recomputed aggregation — stateless with
//!   logged outputs (Spark-RDD firewall);
//! - **lazy checkpoint**: the continuously-updated iterative computation
//!   (rank propagation in a loop) feeding `rank_store`, selectively
//!   checkpointed on epoch completion;
//! - **eager checkpoint**: the database writer — sequence-number domain,
//!   state + outputs persisted per event, consistent with delivered
//!   results.
//!
//! The analytics compute (windowed segment-sum, rank propagation) runs in
//! AOT-compiled XLA kernels when `artifacts/` exists, otherwise in the
//! in-process reference kernels (numerically identical; see
//! `python/tests/`).

use crate::engine::{Ctx, Delivery, Processor, Record, Statefulness};
use crate::frontier::Frontier;
use crate::ft::external::{ExternalInput, ExternalOutput};
use crate::ft::{FtSystem, Policy, Store};
use crate::graph::{GraphBuilder, ProcId, Projection};
use crate::operators::tensor::mock::{MockAgg, MockIterate};
use crate::operators::{
    shared_vec, Egress, Feedback, Ingress, Join, KernelHandle, RankStore, Select, SharedVec,
    Sink, Source, TensorApply, TensorCollect, WindowAggregate,
};
use crate::runtime::ArtifactRegistry;
use crate::time::{Time, TimeDomain};
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Configuration for the Figure-1 run.
#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub epochs: u64,
    pub queries_per_epoch: usize,
    pub records_per_epoch: usize,
    /// Loop iterations for the iterative computation.
    pub iters: u64,
    /// Window size / key count for the aggregation kernel (must match
    /// the compiled artifact when XLA kernels are used).
    pub window: usize,
    pub num_keys: usize,
    /// Inject a crash of the named processor after this epoch completes.
    pub fail_proc: Option<String>,
    pub fail_after_epoch: u64,
    pub seed: u64,
    /// Storage write cost (virtual latency units per write).
    pub write_cost: u64,
    /// Use real XLA artifacts if available.
    pub use_xla: bool,
    /// Channel coalescing cap (1 = record-at-a-time).
    pub batch_cap: usize,
    /// Per-edge mailbox budget for credit-based backpressure (`None` =
    /// unbounded, the historical behavior). A runtime knob, not
    /// persisted state — [`reopen`] re-applies it; see
    /// [`crate::engine::Engine::set_mailbox_cap`].
    pub mailbox_cap: Option<usize>,
    /// Persistence discipline of the store (sync ack-per-write vs. the
    /// asynchronous staged pipeline; see
    /// [`crate::ft::storage::PersistMode`]).
    pub persist_mode: crate::ft::PersistMode,
    /// Durable representation of checkpoint state: monolithic full
    /// snapshots or content-addressed delta chains (see
    /// [`crate::ft::SnapshotPolicy`]). A runtime knob like
    /// `mailbox_cap` — [`reopen`] re-applies it; the recorded chains in
    /// the store remain readable either way.
    pub snapshot_policy: crate::ft::SnapshotPolicy,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            epochs: 6,
            queries_per_epoch: 4,
            records_per_epoch: 32,
            iters: 4,
            window: 16,
            num_keys: 8,
            fail_proc: None,
            fail_after_epoch: 2,
            seed: 7,
            write_cost: 10,
            use_xla: true,
            batch_cap: 1,
            mailbox_cap: None,
            persist_mode: crate::ft::PersistMode::Sync,
            snapshot_policy: crate::ft::SnapshotPolicy::Full,
        }
    }
}

/// The database writer of the eager regime: a seq-domain processor that
/// applies each stats record to its running state and commits it to the
/// external store, deduplicated by sequence number so that post-recovery
/// re-sends are idempotent (§4.3).
pub struct DbWriter {
    pub committed: Arc<Mutex<ExternalOutput>>,
    total: f64,
    applied: u64,
}

impl DbWriter {
    pub fn new(committed: Arc<Mutex<ExternalOutput>>) -> DbWriter {
        DbWriter { committed, total: 0.0, applied: 0 }
    }
}

impl Processor for DbWriter {
    fn on_message(&mut self, _port: usize, t: Time, d: Record, _ctx: &mut Ctx) {
        let (k, v) = d.as_kv().unwrap_or((0, 0.0));
        self.total += v;
        self.applied += 1;
        // Commit keyed by the seq number: replays after recovery dedup.
        let seq = t.seq_of();
        self.committed.lock().unwrap().deliver(
            Time::epoch(0),
            seq as usize - 1,
            Record::kv(k, self.total),
        );
    }

    fn statefulness(&self) -> Statefulness {
        Statefulness::Monolithic
    }

    fn checkpoint_upto(&self, _f: &Frontier) -> Vec<u8> {
        let mut w = crate::util::ser::Writer::new();
        w.f64(self.total);
        w.varint(self.applied);
        w.into_bytes()
    }

    fn restore(&mut self, blob: &[u8]) {
        if blob.is_empty() {
            self.total = 0.0;
            self.applied = 0;
            return;
        }
        let mut r = crate::util::ser::Reader::new(blob);
        self.total = r.f64().expect("corrupt DbWriter state");
        self.applied = r.varint().expect("corrupt DbWriter state");
    }

    fn reset(&mut self) {
        self.total = 0.0;
        self.applied = 0;
    }
}

/// Handles into a built Figure-1 application.
pub struct Fig1App {
    pub sys: FtSystem,
    pub q_src: ProcId,
    pub d_src: ProcId,
    pub resp: SharedVec,
    pub db: Arc<Mutex<ExternalOutput>>,
    pub db_proc: ProcId,
    pub rank_proc: ProcId,
    pub used_xla: bool,
}

/// Resolve the kernels: XLA artifacts when present, reference mocks
/// otherwise.
fn kernels(cfg: &Fig1Config) -> (KernelHandle, KernelHandle, bool) {
    if cfg.use_xla {
        let reg = ArtifactRegistry::default_dir();
        if reg.available("stream_agg") && reg.available("iterate") {
            let agg = reg.kernel("stream_agg", 2).expect("loading stream_agg");
            let it = reg.kernel("iterate", 1).expect("loading iterate");
            return (agg, it, true);
        }
    }
    (
        Arc::new(MockAgg { num_keys: cfg.num_keys }),
        Arc::new(MockIterate { damping: 0.85 }),
        false,
    )
}

/// Everything [`build`] and [`reopen`] share: the wiring, fresh operator
/// instances, policies, and the in-process ends of the external services.
struct Fig1Parts {
    topo: Arc<crate::graph::Topology>,
    procs: Vec<Box<dyn Processor>>,
    policies: Vec<Policy>,
    resp: SharedVec,
    q_src: ProcId,
    d_src: ProcId,
    db_proc: ProcId,
    rank_proc: ProcId,
    used_xla: bool,
}

/// Build the application (see module docs for the wiring).
pub fn build(cfg: &Fig1Config) -> Fig1App {
    build_with_store(cfg, Store::new(cfg.write_cost))
}

/// [`build`] against a caller-provided store (e.g. a
/// [`crate::ft::backend_file::FileBackend`] directory via
/// [`Store::open_dir`], which `falkirk fig1 --data-dir` uses).
pub fn build_with_store(cfg: &Fig1Config, store: Store) -> Fig1App {
    store.set_persist_mode(cfg.persist_mode);
    let db_out = Arc::new(Mutex::new(ExternalOutput::new()));
    let parts = assemble(cfg, db_out.clone());
    let mut sys = FtSystem::new_with_cap(
        parts.topo,
        parts.procs,
        parts.policies,
        Delivery::Fifo,
        store,
        cfg.batch_cap,
    );
    sys.set_mailbox_cap(cfg.mailbox_cap);
    sys.set_snapshot_policy(cfg.snapshot_policy);
    Fig1App {
        sys,
        q_src: parts.q_src,
        d_src: parts.d_src,
        resp: parts.resp,
        db: db_out,
        db_proc: parts.db_proc,
        rank_proc: parts.rank_proc,
        used_xla: parts.used_xla,
    }
}

/// Cold-restart the Figure-1 application from a reopened durable store
/// (see [`FtSystem::reopen`]). The deduplicating database consumer is
/// external — it survives the crash — so the caller passes the surviving
/// handle back in; the eager regime's committed state is then preserved
/// exactly (replayed commits dedup on their sequence numbers). The
/// response sink is a plain user stream and starts fresh.
pub fn reopen(
    cfg: &Fig1Config,
    store: Store,
    db_out: Arc<Mutex<ExternalOutput>>,
) -> (Fig1App, crate::ft::recovery::RecoveryReport) {
    store.set_persist_mode(cfg.persist_mode);
    let parts = assemble(cfg, db_out.clone());
    let (mut sys, report) = FtSystem::reopen(
        parts.topo,
        parts.procs,
        parts.policies,
        Delivery::Fifo,
        store,
        cfg.batch_cap,
    );
    sys.set_mailbox_cap(cfg.mailbox_cap);
    sys.set_snapshot_policy(cfg.snapshot_policy);
    let app = Fig1App {
        sys,
        q_src: parts.q_src,
        d_src: parts.d_src,
        resp: parts.resp,
        db: db_out,
        db_proc: parts.db_proc,
        rank_proc: parts.rank_proc,
        used_xla: parts.used_xla,
    };
    (app, report)
}

/// Assemble the graph, operators and policies (shared by [`build`] and
/// [`reopen`]).
fn assemble(cfg: &Fig1Config, db_out: Arc<Mutex<ExternalOutput>>) -> Fig1Parts {
    let (agg_kernel, iter_kernel, used_xla) = kernels(cfg);
    let mut g = GraphBuilder::new();
    let d1 = TimeDomain::Structured { depth: 1 };

    let q_src = g.add_proc("q_src", TimeDomain::EPOCH);
    let q_select = g.add_proc("q_select", TimeDomain::EPOCH);
    let q_tokv = g.add_proc("q_tokv", TimeDomain::EPOCH);
    let d_src = g.add_proc("d_src", TimeDomain::EPOCH);
    let reduce = g.add_proc("reduce", TimeDomain::EPOCH);
    let batch_agg = g.add_proc("batch_agg", TimeDomain::EPOCH);
    let t_collect = g.add_proc("t_collect", TimeDomain::EPOCH);
    let ingress = g.add_proc("ingress", d1);
    let body = g.add_proc("iterate", d1);
    let feedback = g.add_proc("feedback", d1);
    let egress = g.add_proc("egress", TimeDomain::EPOCH);
    let rank_store = g.add_proc("rank_store", TimeDomain::EPOCH);
    let join_batch = g.add_proc("join_batch", TimeDomain::EPOCH);
    let join_iter = g.add_proc("join_iter", TimeDomain::EPOCH);
    let db = g.add_proc("db", TimeDomain::Seq);
    let resp = g.add_proc("resp", TimeDomain::EPOCH);

    // Query path.
    g.connect(q_src, q_select, Projection::Identity);
    g.connect(q_select, q_tokv, Projection::Identity);
    g.connect(q_tokv, join_batch, Projection::Identity); // join_batch port 0
    // Record path: pre-reduction then both analytics.
    g.connect(d_src, reduce, Projection::Identity);
    g.connect(reduce, batch_agg, Projection::Identity);
    g.connect(reduce, t_collect, Projection::Identity);
    // Batch regime output into the first join.
    g.connect(batch_agg, join_batch, Projection::Identity); // port 1
    // Iterative loop.
    g.connect(t_collect, ingress, Projection::LoopEnter);
    g.connect(ingress, body, Projection::Identity);
    g.connect(body, feedback, Projection::Identity); // body port 0
    g.connect(feedback, body, Projection::LoopFeedback);
    g.connect(body, egress, Projection::LoopExit); // body port 1
    g.connect(egress, rank_store, Projection::Identity);
    // Joins and outputs.
    g.connect(join_batch, join_iter, Projection::Identity); // join_iter port 0
    g.connect(rank_store, join_iter, Projection::Identity); // join_iter port 1
    g.connect(join_iter, db, Projection::PerCheckpoint); // seq domain
    g.connect(join_iter, resp, Projection::Identity);

    let topo = Arc::new(g.build().expect("fig1 topology"));
    let resp_out = shared_vec();

    /// Body emits to both feedback (port 0) and egress (port 1), but only
    /// the final iteration should leave the loop; Feedback::max_iters
    /// bounds the cycle and egress receives every iterate — rank_store
    /// overwrites per epoch, so the last write wins deterministically
    /// under FIFO delivery.
    struct BodyWrap(TensorApply);
    impl Processor for BodyWrap {
        fn on_message(&mut self, port: usize, t: Time, d: Record, ctx: &mut Ctx) {
            self.0.on_message(port, t, d, ctx);
        }
    }

    let procs: Vec<Box<dyn Processor>> = vec![
        Box::new(Source),                                            // q_src
        Box::new(Select),                                            // q_select
        Box::new(crate::operators::Map(|r: Record| match r {
            Record::Int(i) => Record::kv(i, 1.0),
            other => other,
        })),                                                         // q_tokv
        Box::new(Source),                                            // d_src
        Box::new(crate::operators::CountByKey::default()),          // reduce
        Box::new(WindowAggregate::new_kv(agg_kernel, cfg.window, cfg.num_keys)), // batch_agg
        Box::new(TensorCollect::new(cfg.num_keys)),                 // t_collect
        Box::new(Ingress),                                          // ingress
        Box::new(BodyWrap(TensorApply::new(iter_kernel))),          // iterate
        Box::new(Feedback::new(cfg.iters)),                         // feedback
        Box::new(Egress),                                           // egress
        Box::new(RankStore::new()),                                 // rank_store
        Box::new(Join::default()),                                  // join_batch
        Box::new(Join::default()),                                  // join_iter
        Box::new(DbWriter::new(db_out.clone())),                    // db
        Box::new(Sink(resp_out.clone())),                           // resp
    ];
    let policies = vec![
        Policy::Ephemeral,                                // q_src
        Policy::Ephemeral,                                // q_select
        Policy::Ephemeral,                                // q_tokv
        Policy::Ephemeral,                                // d_src
        Policy::Ephemeral,                                // reduce
        Policy::LogOutputs,                               // batch_agg (batch regime)
        Policy::Ephemeral,                                // t_collect
        Policy::Ephemeral,                                // ingress
        Policy::Ephemeral,                                // iterate
        Policy::Ephemeral,                                // feedback
        Policy::Ephemeral,                                // egress
        Policy::Lazy { every: 1, log_outputs: true },     // rank_store (lazy regime)
        Policy::Lazy { every: 1, log_outputs: true },     // join_batch
        Policy::Lazy { every: 1, log_outputs: true },     // join_iter
        Policy::Eager,                                    // db (eager regime)
        Policy::Ephemeral,                                // resp
    ];
    Fig1Parts {
        topo,
        procs,
        policies,
        resp: resp_out,
        q_src,
        d_src,
        db_proc: db,
        rank_proc: rank_store,
        used_xla,
    }
}

/// Outcome of a driven Figure-1 run.
#[derive(Clone, Debug)]
pub struct Fig1Outcome {
    pub responses: usize,
    pub db_commits: usize,
    pub db_duplicates: u64,
    pub checkpoints: u64,
    pub log_entries: u64,
    pub storage_writes: u64,
    pub storage_bytes: u64,
    /// Peak staged-minus-acked durable operations (0 in sync mode).
    pub ack_lag: u64,
    /// Content-addressed chunks a snapshot listed but never re-wrote
    /// (0 under [`crate::ft::SnapshotPolicy::Full`] with distinct
    /// states; the dedup win under `Delta`).
    pub chunks_reused: u64,
    /// Bytes those reused chunks would have re-written.
    pub chunk_bytes_reused: u64,
    /// Durable writes the store refused (oversized payloads).
    pub storage_errors: u64,
    pub events: u64,
    /// Present if a failure was injected.
    pub recovery: Option<RecoverySummary>,
    pub used_xla: bool,
    pub elapsed_ms: f64,
    /// Wall time each epoch took to drive to quiescence, in
    /// nanoseconds, in epoch order (feeds the `--metrics-json`
    /// percentile summary).
    pub epoch_wall_ns: Vec<u64>,
}

/// Recovery measurements for EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct RecoverySummary {
    pub victim: String,
    pub replayed: usize,
    pub dropped: usize,
    pub restored: usize,
    pub reset_to_empty: usize,
    pub untouched: usize,
    pub input_redeliveries: u64,
    /// Events needed to re-quiesce after recovery (re-execution cost).
    pub requiesce_events: u64,
    pub recover_wall_us: f64,
}

/// Drive the application for `cfg.epochs` epochs of synthetic queries and
/// records, optionally crashing one processor, and report.
pub fn run(cfg: &Fig1Config) -> Fig1Outcome {
    run_with_store(cfg, Store::new(cfg.write_cost))
}

/// [`run`] against a caller-provided (e.g. durable) store.
pub fn run_with_store(cfg: &Fig1Config, store: Store) -> Fig1Outcome {
    run_traced(cfg, store, None)
}

/// [`run_with_store`] with an optional tracer attached to the system for
/// the whole run. Each epoch becomes an `"epoch"` span on the driving
/// thread; the recovery timeline (detect → solver → rollback → replay)
/// nests inside whichever epoch injected the failure.
pub fn run_traced(
    cfg: &Fig1Config,
    store: Store,
    tracer: Option<crate::trace::Tracer>,
) -> Fig1Outcome {
    let t_start = std::time::Instant::now();
    let mut app = build_with_store(cfg, store);
    app.sys.set_tracer(tracer.clone());
    let mut rng = Rng::new(cfg.seed);
    let mut q_ext = ExternalInput::new();
    let mut d_ext = ExternalInput::new();
    let words = ["one", "two", "three", "four", "five", "six", "seven", "eight"];
    let mut recovery = None;
    let mut epoch_wall_ns = Vec::with_capacity(cfg.epochs as usize);

    for ep in 0..cfg.epochs {
        let t_epoch = std::time::Instant::now();
        let trace_t0 = tracer.as_ref().map(|tr| tr.now_ns());
        let t = Time::epoch(ep);
        // Offer this epoch's batches to the external services.
        let queries: Vec<Record> = (0..cfg.queries_per_epoch)
            .map(|_| Record::text(words[rng.index(words.len())]))
            .collect();
        let records: Vec<Record> = (0..cfg.records_per_epoch)
            .map(|_| Record::kv(rng.below(cfg.num_keys as u64) as i64, rng.f64() * 10.0))
            .collect();
        q_ext.offer(t, queries.clone());
        d_ext.offer(t, records.clone());

        app.sys.advance_input(app.q_src, t);
        app.sys.advance_input(app.d_src, t);
        for q in queries {
            app.sys.push_input(app.q_src, t, q);
        }
        for r in records {
            app.sys.push_input(app.d_src, t, r);
        }
        app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
        app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
        app.sys.run_to_quiescence(2_000_000);

        // External acknowledgement follows durability (a real deployment
        // uses the GC monitor's watermark; with checkpoint-every-1
        // regimes, a two-epoch lag is a safe conservative stand-in).
        if ep >= 2 {
            q_ext.ack_upto(&Frontier::upto_epoch(ep - 2));
            d_ext.ack_upto(&Frontier::upto_epoch(ep - 2));
        }

        if let Some(victim_name) = &cfg.fail_proc {
            if ep == cfg.fail_after_epoch && recovery.is_none() {
                let victim = app
                    .sys
                    .topology()
                    .find(victim_name)
                    .unwrap_or_else(|| panic!("unknown fail_proc {victim_name}"));
                app.sys.inject_failures(&[victim]);
                let t0 = std::time::Instant::now();
                let rep = app.sys.recover();
                let recover_wall_us = t0.elapsed().as_nanos() as f64 / 1e3;
                // Client retry (§4.3): re-push unacknowledged batches not
                // covered by the sources' restored frontiers.
                let fq = rep.plan.f[app.q_src.0 as usize].clone();
                let fd = rep.plan.f[app.d_src.0 as usize].clone();
                let mut redeliveries = 0;
                for (t, batch) in q_ext.replay_from(&fq) {
                    app.sys.advance_input(app.q_src, t);
                    for r in batch {
                        app.sys.push_input(app.q_src, t, r);
                        redeliveries += 1;
                    }
                }
                for (t, batch) in d_ext.replay_from(&fd) {
                    app.sys.advance_input(app.d_src, t);
                    for r in batch {
                        app.sys.push_input(app.d_src, t, r);
                        redeliveries += 1;
                    }
                }
                app.sys.advance_input(app.q_src, Time::epoch(ep + 1));
                app.sys.advance_input(app.d_src, Time::epoch(ep + 1));
                let ev0 = app.sys.engine.events_processed();
                app.sys.run_to_quiescence(2_000_000);
                recovery = Some(RecoverySummary {
                    victim: victim_name.clone(),
                    replayed: rep.replayed,
                    dropped: rep.dropped,
                    restored: rep.restored_from_checkpoint,
                    reset_to_empty: rep.reset_to_empty,
                    untouched: rep.untouched,
                    input_redeliveries: redeliveries,
                    requiesce_events: app.sys.engine.events_processed() - ev0,
                    recover_wall_us,
                });
            }
        }
        epoch_wall_ns.push(t_epoch.elapsed().as_nanos() as u64);
        if let (Some(tr), Some(t0)) = (&tracer, trace_t0) {
            tr.span(0, "driver", "epoch", t0, &[("epoch", ep)]);
        }
    }
    app.sys.close_input(app.q_src);
    app.sys.close_input(app.d_src);
    app.sys.run_to_quiescence(2_000_000);

    let st = app.sys.store.stats();
    let responses = app.resp.lock().unwrap().len();
    let (db_commits, db_duplicates) = {
        let db = app.db.lock().unwrap();
        (db.contents().first().map(|(_, v)| v.len()).unwrap_or(0), db.duplicates)
    };
    Fig1Outcome {
        responses,
        db_commits,
        db_duplicates,
        checkpoints: app.sys.stats.checkpoints_taken,
        log_entries: app.sys.stats.log_entries,
        storage_writes: st.writes,
        storage_bytes: st.bytes_written,
        ack_lag: app.sys.stats.ack_lag,
        chunks_reused: st.chunks_reused,
        chunk_bytes_reused: st.chunk_bytes_reused,
        storage_errors: app.sys.stats.storage_errors,
        events: app.sys.engine.events_processed(),
        recovery,
        used_xla: app.used_xla,
        elapsed_ms: t_start.elapsed().as_nanos() as f64 / 1e6,
        epoch_wall_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Fig1Config {
        Fig1Config {
            epochs: 3,
            queries_per_epoch: 3,
            records_per_epoch: 12,
            iters: 3,
            window: 8,
            num_keys: 4,
            use_xla: false, // deterministic unit tests use the mocks
            ..Default::default()
        }
    }

    #[test]
    fn fig1_runs_clean() {
        let out = run(&small_cfg());
        assert!(out.responses > 0, "queries produced responses");
        assert!(out.db_commits > 0, "stats reached the database");
        assert_eq!(out.db_duplicates, 0);
        assert!(out.checkpoints > 0, "lazy + eager regimes checkpointed");
        assert!(out.log_entries > 0, "batch firewall logged");
        assert!(out.storage_writes > 0);
        assert!(out.recovery.is_none());
    }

    #[test]
    fn fig1_survives_rank_store_failure() {
        let mut cfg = small_cfg();
        cfg.fail_proc = Some("rank_store".to_string());
        cfg.fail_after_epoch = 1;
        let out = run(&cfg);
        let rec = out.recovery.expect("failure was injected");
        assert!(rec.restored >= 1, "rank_store restored from its selective checkpoint");
        assert_eq!(out.db_duplicates, 0, "eager DB dedups replayed commits");
    }

    #[test]
    fn fig1_survives_db_failure_without_duplicate_commits() {
        let mut cfg = small_cfg();
        cfg.fail_proc = Some("db".to_string());
        cfg.fail_after_epoch = 1;
        let out = run(&cfg);
        let clean = run(&small_cfg());
        assert_eq!(
            out.db_commits, clean.db_commits,
            "post-recovery commit count equals the failure-free run"
        );
    }

    #[test]
    fn fig1_failure_free_equals_failed_run_on_db_contents() {
        // The refinement-mapping claim on the end-to-end app: the eager
        // regime's externally-visible commits match exactly.
        let clean = run(&small_cfg());
        for victim in ["rank_store", "join_iter", "reduce", "batch_agg"] {
            let mut cfg = small_cfg();
            cfg.fail_proc = Some(victim.to_string());
            cfg.fail_after_epoch = 1;
            let failed = run(&cfg);
            assert_eq!(
                failed.db_commits, clean.db_commits,
                "victim {victim}: db commits diverged"
            );
            assert_eq!(failed.responses >= clean.responses, true,
                "victim {victim}: responses may include client-retry duplicates but not fewer");
        }
    }
}
