//! Capture-gated structured tracing (`falkirk-trace/1`).
//!
//! A [`Tracer`] is a cloneable handle on one shared event sink plus a
//! monotonic clock origin. Every instrumented layer — the engines, the
//! FT harness, the staging pipeline, the WAL backend, recovery — holds
//! an `Option<Tracer>` that is `None` by default, so the hot path pays
//! exactly one branch when tracing is off (the same gating discipline
//! as the engine's `capture_data` / `capture_sent` flags, audited by
//! `rust/tests/test_zero_copy.rs`).
//!
//! Two recording paths:
//!
//! - **cold paths** (recovery phases, checkpoints, WAL rotation and
//!   compaction, ack-watermark publication) push events straight into
//!   the shared sink — one short mutex hold per event;
//! - **hot paths** (the parallel workers' delivery loops) record into a
//!   per-worker [`TraceBuf`] — a plain `Vec` push, no shared state —
//!   and merge it into the sink at the barrier rounds where the worker
//!   already synchronizes (`engine/parallel.rs`).
//!
//! Events are *complete* records: an instant has `dur_ns = 0`, a span
//! carries its duration and is pushed when it closes. Nested spans
//! (e.g. the recovery timeline's solver/rollback/replay inside the
//! enclosing recovery span) are therefore pushed child-first;
//! [`Tracer::events`] and the JSON-lines writer re-sort by start time
//! (ties broken longest-first) so exported order is monotone and an
//! enclosing span precedes its children.
//!
//! # Export
//!
//! - `FALKIRK_TRACE_JSON=file` — the CLI commands attach a tracer and
//!   append the run's events to `file` as JSON lines (schema
//!   `falkirk-trace/1`: one header object, then one event object per
//!   line). See [`Tracer::append_json_lines`].
//! - `falkirk trace convert <file>` — re-emit a `falkirk-trace/1` file
//!   in Chrome `trace_event` format for chrome://tracing ([`convert`]).
//! - `--metrics-json` on `falkirk fig1` / `shard` / `fuzz` — an
//!   end-of-run `falkirk-metrics/1` summary (assembled by the CLI from
//!   [`crate::util::stats::LogHistogram`] and the FT counters).
//!
//! The recovery timeline (detection → solver → rollback → replay, with
//! per-processor undone/replayed counts) is documented in
//! `ft/README.md` § Observability; its schema invariants are validated
//! by `python/tests/test_trace_schema.py`.

pub mod convert;

use crate::metrics::json::JsonObj;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Schema tag of the JSON-lines trace format.
pub const SCHEMA: &str = "falkirk-trace/1";

/// Environment variable naming the JSON-lines trace output file.
pub const ENV_TRACE_JSON: &str = "FALKIRK_TRACE_JSON";

/// One trace event: an instant (`dur_ns == 0`) or a completed span.
/// Names and categories are `&'static str` — instrumentation sites are
/// compiled in, so recording never allocates for identity, only for
/// the (small, bounded) argument vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Start, in nanoseconds since the tracer was created (monotonic).
    pub ts_ns: u64,
    /// Duration; 0 for instant events.
    pub dur_ns: u64,
    /// Logical thread: 0 = the driving thread, `g + 1` = parallel
    /// worker group `g`.
    pub tid: u32,
    /// Category (one per instrumented layer: `engine`, `parallel`,
    /// `ft`, `storage`, `wal`, `recovery`, `driver`).
    pub cat: &'static str,
    pub name: &'static str,
    /// Counted measurements attached to the event.
    pub args: Vec<(&'static str, u64)>,
}

impl TraceEvent {
    /// End timestamp (`ts_ns + dur_ns`, saturating).
    pub fn end_ns(&self) -> u64 {
        self.ts_ns.saturating_add(self.dur_ns)
    }

    /// Look up an argument by key.
    pub fn arg(&self, key: &str) -> Option<u64> {
        self.args.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Interval containment: does this span cover `other` entirely?
    pub fn contains(&self, other: &TraceEvent) -> bool {
        self.ts_ns <= other.ts_ns && other.end_ns() <= self.end_ns()
    }

    /// The event as one `falkirk-trace/1` JSON object (one line).
    pub fn json(&self) -> String {
        let mut args = JsonObj::new();
        for (k, v) in &self.args {
            args.u64_field(k, *v);
        }
        let mut o = JsonObj::new();
        o.u64_field("ts_ns", self.ts_ns)
            .u64_field("dur_ns", self.dur_ns)
            .u64_field("tid", self.tid as u64)
            .str_field("cat", self.cat)
            .str_field("name", self.name)
            .raw_field("args", &args.finish());
        o.finish()
    }
}

struct TracerInner {
    start: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

/// Cloneable handle on one trace sink (see the module docs).
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                start: Instant::now(),
                events: Mutex::new(Vec::new()),
            }),
        }
    }

    /// A tracer plus the output path when [`ENV_TRACE_JSON`] names a
    /// file, `None` otherwise — the CLI's one-line opt-in.
    pub fn from_env() -> Option<(Tracer, String)> {
        match std::env::var(ENV_TRACE_JSON) {
            Ok(path) if !path.is_empty() => Some((Tracer::new(), path)),
            _ => None,
        }
    }

    /// Nanoseconds since this tracer was created (monotonic).
    pub fn now_ns(&self) -> u64 {
        self.inner.start.elapsed().as_nanos() as u64
    }

    /// Open a span: record the start timestamp, pass it back to
    /// [`Tracer::span`] at close.
    pub fn begin(&self) -> u64 {
        self.now_ns()
    }

    pub fn push(&self, ev: TraceEvent) {
        self.inner.events.lock().unwrap().push(ev);
    }

    /// Record an instant event on logical thread `tid`.
    pub fn instant(&self, tid: u32, cat: &'static str, name: &'static str, args: &[(&'static str, u64)]) {
        self.push(TraceEvent {
            ts_ns: self.now_ns(),
            dur_ns: 0,
            tid,
            cat,
            name,
            args: args.to_vec(),
        });
    }

    /// Close a span opened at `t0_ns` (from [`Tracer::begin`]).
    pub fn span(
        &self,
        tid: u32,
        cat: &'static str,
        name: &'static str,
        t0_ns: u64,
        args: &[(&'static str, u64)],
    ) {
        let now = self.now_ns();
        self.push(TraceEvent {
            ts_ns: t0_ns,
            dur_ns: now.saturating_sub(t0_ns),
            tid,
            cat,
            name,
            args: args.to_vec(),
        });
    }

    /// Events recorded so far (a merge point for incremental readers,
    /// e.g. the fuzzer's counter-reconciliation oracle).
    pub fn len(&self) -> usize {
        self.inner.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A sorted snapshot: ascending start time, ties longest-first, so
    /// an enclosing span sorts before the spans it contains.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut v = self.inner.events.lock().unwrap().clone();
        v.sort_by(|a, b| a.ts_ns.cmp(&b.ts_ns).then(b.dur_ns.cmp(&a.dur_ns)));
        v
    }

    /// The whole trace as `falkirk-trace/1` JSON lines: one header
    /// object, then one event object per line, start-time sorted.
    pub fn json_lines(&self) -> String {
        let mut header = JsonObj::new();
        header.str_field("schema", SCHEMA).str_field("clock", "mono_ns");
        let mut out = header.finish();
        out.push('\n');
        for ev in self.events() {
            out.push_str(&ev.json());
            out.push('\n');
        }
        out
    }

    /// Append this trace to `path` (creating it if needed). The header
    /// line is written only when the file is new or empty, so several
    /// runs (e.g. consecutive fuzz seeds) share one well-formed file.
    pub fn append_json_lines(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        let fresh = f.metadata()?.len() == 0;
        let body = self.json_lines();
        let text = if fresh {
            body.as_str()
        } else {
            // Skip the header line on append.
            body.split_once('\n').map(|(_, rest)| rest).unwrap_or("")
        };
        f.write_all(text.as_bytes())
    }
}

/// Per-worker event buffer for the parallel hot path: plain `Vec`
/// pushes on the worker thread, merged into the shared sink at the
/// barriers where the worker already synchronizes (or on drop, which
/// covers the recompose path).
pub struct TraceBuf {
    tracer: Tracer,
    tid: u32,
    events: Vec<TraceEvent>,
}

impl TraceBuf {
    pub fn new(tracer: Tracer, tid: u32) -> TraceBuf {
        TraceBuf { tracer, tid, events: Vec::new() }
    }

    pub fn begin(&self) -> u64 {
        self.tracer.now_ns()
    }

    pub fn instant(&mut self, cat: &'static str, name: &'static str, args: &[(&'static str, u64)]) {
        self.events.push(TraceEvent {
            ts_ns: self.tracer.now_ns(),
            dur_ns: 0,
            tid: self.tid,
            cat,
            name,
            args: args.to_vec(),
        });
    }

    pub fn span(&mut self, cat: &'static str, name: &'static str, t0_ns: u64, args: &[(&'static str, u64)]) {
        let now = self.tracer.now_ns();
        self.events.push(TraceEvent {
            ts_ns: t0_ns,
            dur_ns: now.saturating_sub(t0_ns),
            tid: self.tid,
            cat,
            name,
            args: args.to_vec(),
        });
    }

    /// Merge everything buffered into the shared sink (the barrier
    /// hand-off). Cheap when empty.
    pub fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = self.tracer.inner.events.lock().unwrap();
        sink.append(&mut self.events);
    }
}

impl Drop for TraceBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instants_and_spans_round_trip() {
        let t = Tracer::new();
        let t0 = t.begin();
        t.instant(0, "ft", "checkpoint", &[("proc", 3), ("bytes", 128)]);
        t.span(0, "recovery", "recovery", t0, &[("replayed", 5)]);
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        // The span opened first (t0) and covers the instant.
        assert_eq!(evs[0].name, "recovery");
        assert!(evs[0].dur_ns > 0);
        assert_eq!(evs[0].arg("replayed"), Some(5));
        assert_eq!(evs[1].name, "checkpoint");
        assert_eq!(evs[1].dur_ns, 0);
        assert!(evs[0].contains(&evs[1]));
    }

    #[test]
    fn sorted_snapshot_is_start_time_monotone_parent_first() {
        let t = Tracer::new();
        // Push out of order, as span-close recording naturally does.
        t.push(TraceEvent { ts_ns: 10, dur_ns: 5, tid: 0, cat: "c", name: "child", args: vec![] });
        t.push(TraceEvent { ts_ns: 10, dur_ns: 50, tid: 0, cat: "c", name: "parent", args: vec![] });
        t.push(TraceEvent { ts_ns: 5, dur_ns: 0, tid: 0, cat: "c", name: "first", args: vec![] });
        let evs = t.events();
        assert_eq!(
            evs.iter().map(|e| e.name).collect::<Vec<_>>(),
            vec!["first", "parent", "child"]
        );
        assert!(evs.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
    }

    #[test]
    fn json_lines_have_header_then_events() {
        let t = Tracer::new();
        t.instant(2, "engine", "deliver", &[("edge", 1), ("records", 8)]);
        let text = t.json_lines();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"schema\":\"falkirk-trace/1\""));
        assert!(lines[1].contains("\"cat\":\"engine\""));
        assert!(lines[1].contains("\"args\":{\"edge\":1,\"records\":8}"));
    }

    #[test]
    fn worker_buffer_merges_at_flush() {
        let t = Tracer::new();
        let mut buf = TraceBuf::new(t.clone(), 3);
        buf.instant("parallel", "stall", &[("edge", 2)]);
        let t0 = buf.begin();
        buf.span("engine", "deliver", t0, &[("records", 4)]);
        assert_eq!(t.len(), 0, "buffered events are local until the barrier");
        buf.flush();
        assert_eq!(t.len(), 2);
        assert!(t.events().iter().all(|e| e.tid == 3));
    }

    #[test]
    fn append_writes_header_once() {
        let dir = crate::util::tmp::TempDir::new("trace");
        let path = dir.path().join("t.jsonl");
        let path = path.to_str().unwrap().to_string();
        let t1 = Tracer::new();
        t1.instant(0, "run", "epoch", &[("ep", 0)]);
        t1.append_json_lines(&path).unwrap();
        let t2 = Tracer::new();
        t2.instant(0, "run", "epoch", &[("ep", 1)]);
        t2.append_json_lines(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let headers = text.lines().filter(|l| l.contains("\"schema\"")).count();
        assert_eq!(headers, 1, "one header per file, however many runs append");
        assert_eq!(text.lines().count(), 3);
    }
}
