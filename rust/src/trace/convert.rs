//! `falkirk trace convert` — re-emit a `falkirk-trace/1` JSON-lines
//! file in Chrome `trace_event` format (the JSON Array Format), so a
//! captured run opens directly in chrome://tracing / Perfetto as a
//! flamegraph: spans become `"ph":"X"` complete events, instants
//! become `"ph":"i"` thread-scoped marks, timestamps land in
//! microseconds as the format requires.
//!
//! The input parser is deliberately minimal: it accepts exactly the
//! shape [`crate::trace::Tracer::json_lines`] emits (flat objects with
//! string and unsigned-integer fields plus one flat `args` object) —
//! hand-rolled because the offline registry has no serde, and shared
//! with the Python schema checker's expectations
//! (`python/tests/test_trace_schema.py`).

use crate::metrics::json::{JsonArr, JsonObj};
use crate::trace::SCHEMA;

/// One parsed `falkirk-trace/1` line.
#[derive(Clone, Debug, PartialEq)]
pub enum Line {
    /// The file header: `{"schema":"falkirk-trace/1",...}`.
    Header { schema: String },
    /// An event line.
    Event(LineEvent),
}

/// An event as read back from a trace file (owned strings — the
/// `&'static str` identities of [`crate::trace::TraceEvent`] exist
/// only in the emitting process).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LineEvent {
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
    pub cat: String,
    pub name: String,
    pub args: Vec<(String, u64)>,
}

/// Cursor over one line's bytes.
struct P<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn new(s: &'a str) -> P<'a> {
        P { s: s.as_bytes(), i: 0 }
    }

    fn ws(&mut self) {
        while self.i < self.s.len() && (self.s[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.s.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.s.get(self.i) else {
                return Err("unterminated string".to_string());
            };
            self.i += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&e) = self.s.get(self.i) else {
                        return Err("dangling escape".to_string());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .s
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            self.i += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("unknown escape '\\{}'", other as char)),
                    }
                }
                b => out.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<u64, String> {
        self.ws();
        let start = self.i;
        while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("expected a number at byte {start}"));
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| "number out of range".to_string())
    }

    /// A flat `{"key":u64,...}` object (the `args` value).
    fn flat_obj(&mut self) -> Result<Vec<(String, u64)>, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            let k = self.string()?;
            self.expect(b':')?;
            out.push((k, self.number()?));
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(out);
                }
                _ => return Err("malformed args object".to_string()),
            }
        }
    }
}

/// Parse one `falkirk-trace/1` line (header or event).
pub fn parse_line(line: &str) -> Result<Line, String> {
    let mut p = P::new(line);
    p.expect(b'{')?;
    let mut schema = None;
    let mut ev = LineEvent::default();
    let mut is_event = false;
    if p.peek() == Some(b'}') {
        return Err("empty object".to_string());
    }
    loop {
        let key = p.string()?;
        p.expect(b':')?;
        match key.as_str() {
            "schema" => schema = Some(p.string()?),
            "clock" => {
                p.string()?;
            }
            "ts_ns" => {
                ev.ts_ns = p.number()?;
                is_event = true;
            }
            "dur_ns" => ev.dur_ns = p.number()?,
            "tid" => ev.tid = p.number()?,
            "cat" => ev.cat = p.string()?,
            "name" => ev.name = p.string()?,
            "args" => ev.args = p.flat_obj()?,
            other => return Err(format!("unknown field '{other}'")),
        }
        match p.peek() {
            Some(b',') => p.i += 1,
            Some(b'}') => break,
            _ => return Err("malformed object".to_string()),
        }
    }
    match schema {
        Some(s) => Ok(Line::Header { schema: s }),
        None if is_event => Ok(Line::Event(ev)),
        None => Err("line is neither a header nor an event".to_string()),
    }
}

/// Conversion outcome (reported by the CLI).
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvertStats {
    pub events: usize,
    pub spans: usize,
    pub instants: usize,
}

/// Convert `falkirk-trace/1` text to a Chrome `trace_event` JSON
/// document. The first line must be the schema header.
pub fn to_chrome(input: &str) -> Result<(String, ConvertStats), String> {
    let mut lines = input.lines().filter(|l| !l.trim().is_empty());
    match lines.next().map(parse_line) {
        Some(Ok(Line::Header { schema })) if schema == SCHEMA => {}
        Some(Ok(Line::Header { schema })) => {
            return Err(format!("unsupported schema '{schema}' (want '{SCHEMA}')"));
        }
        Some(Ok(Line::Event(_))) | None => {
            return Err(format!("missing '{SCHEMA}' header line"));
        }
        Some(Err(e)) => return Err(format!("line 1: {e}")),
    }
    let mut stats = ConvertStats::default();
    let mut arr = JsonArr::new();
    for (n, line) in lines.enumerate() {
        let ev = match parse_line(line).map_err(|e| format!("line {}: {e}", n + 2))? {
            Line::Header { .. } => continue, // concatenated runs: tolerate repeats
            Line::Event(ev) => ev,
        };
        stats.events += 1;
        let mut args = JsonObj::new();
        for (k, v) in &ev.args {
            args.u64_field(k, *v);
        }
        let mut o = JsonObj::new();
        o.str_field("name", &ev.name)
            .str_field("cat", &ev.cat)
            .u64_field("pid", 1)
            .u64_field("tid", ev.tid)
            .f64_field("ts", ev.ts_ns as f64 / 1e3);
        if ev.dur_ns > 0 {
            stats.spans += 1;
            o.str_field("ph", "X").f64_field("dur", ev.dur_ns as f64 / 1e3);
        } else {
            stats.instants += 1;
            o.str_field("ph", "i").str_field("s", "t");
        }
        o.raw_field("args", &args.finish());
        arr.push_raw(&o.finish());
    }
    let mut doc = JsonObj::new();
    doc.raw_field("traceEvents", &arr.finish()).str_field("displayTimeUnit", "ns");
    Ok((doc.finish(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Tracer;

    #[test]
    fn parses_what_the_tracer_emits() {
        let t = Tracer::new();
        t.instant(2, "engine", "deliver", &[("edge", 3), ("records", 8)]);
        let t0 = t.begin();
        t.span(0, "recovery", "recovery", t0, &[("replayed", 5)]);
        let text = t.json_lines();
        let mut lines = text.lines();
        assert_eq!(
            parse_line(lines.next().unwrap()).unwrap(),
            Line::Header { schema: SCHEMA.to_string() }
        );
        let mut names = Vec::new();
        for l in lines {
            match parse_line(l).unwrap() {
                Line::Event(ev) => names.push(ev.name),
                Line::Header { .. } => panic!("unexpected second header"),
            }
        }
        names.sort();
        assert_eq!(names, vec!["deliver", "recovery"]);
    }

    #[test]
    fn string_unescaping_round_trips() {
        match parse_line("{\"schema\":\"a\\\"b\\\\c\\n\\u0041\"}").unwrap() {
            Line::Header { schema } => assert_eq!(schema, "a\"b\\c\nA"),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn chrome_output_has_complete_and_instant_phases() {
        let t = Tracer::new();
        let t0 = t.begin();
        t.instant(1, "ft", "checkpoint", &[("proc", 2)]);
        t.span(0, "run", "epoch", t0, &[("ep", 0)]);
        let (doc, stats) = to_chrome(&t.json_lines()).unwrap();
        assert_eq!(stats.events, 2);
        assert_eq!(stats.spans, 1);
        assert_eq!(stats.instants, 1);
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.contains("\"ph\":\"X\""));
        assert!(doc.contains("\"ph\":\"i\""));
        assert!(doc.contains("\"name\":\"checkpoint\""));
    }

    #[test]
    fn rejects_missing_or_foreign_headers() {
        assert!(to_chrome("").is_err());
        assert!(to_chrome("{\"schema\":\"other/9\"}\n").is_err());
        let t = Tracer::new();
        t.instant(0, "run", "epoch", &[]);
        let headerless: String =
            t.json_lines().lines().skip(1).map(|l| format!("{l}\n")).collect();
        assert!(to_chrome(&headerless).is_err());
    }

    #[test]
    fn concatenated_runs_convert_as_one_stream() {
        let t1 = Tracer::new();
        t1.instant(0, "run", "epoch", &[("ep", 0)]);
        let t2 = Tracer::new();
        t2.instant(0, "run", "epoch", &[("ep", 1)]);
        let mut text = t1.json_lines();
        text.push_str(&t2.json_lines()); // repeated header mid-file
        let (_, stats) = to_chrome(&text).unwrap();
        assert_eq!(stats.events, 2);
    }
}
