//! Small self-contained utilities.
//!
//! The build image's vendored crate registry does not include `rand`,
//! `serde`, `clap`, `criterion` or `proptest`, so the few pieces of those
//! we need are implemented here: a seeded xorshift RNG ([`rng`]), a compact
//! binary serializer for checkpoints ([`ser`]), summary statistics
//! ([`stats`]), a tiny CLI argument parser ([`cli`]), a miniature
//! property-testing harness ([`prop`]) and self-cleaning temp dirs for
//! the durable-storage tests ([`tmp`]).

pub mod cli;
pub mod hash;
pub mod prop;
pub mod rng;
pub mod ser;
pub mod stats;
pub mod tmp;
