//! Miniature property-testing harness (the offline registry has no
//! `proptest`/`quickcheck`).
//!
//! A property is a closure from a seeded [`Rng`](crate::util::rng::Rng) to
//! `Result<(), String>`; the harness runs it for `cases` deterministic
//! seeds and reports the first failing seed. No shrinking — failures print
//! the seed so the case can be replayed under a debugger.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u64,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, base_seed: 0xFA1C1_u64 }
    }
}

/// Run `prop` for `cfg.cases` seeds; panic (with the seed) on first failure.
pub fn check_with<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (seed {seed}, case {i}/{}): {msg}", cfg.cases);
        }
    }
}

/// Run with the default config.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_with(Config::default(), name, prop)
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 halving", |rng| {
            let x = rng.next_u64();
            prop_assert!(x / 2 <= x);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_rng| Err("nope".to_string()));
    }

    #[test]
    fn deterministic_case_seeds() {
        let mut seen = Vec::new();
        check_with(Config { cases: 4, base_seed: 99 }, "collect", |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        let mut again = Vec::new();
        check_with(Config { cases: 4, base_seed: 99 }, "collect2", |rng| {
            again.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen, again);
    }
}
