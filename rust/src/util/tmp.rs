//! Self-cleaning temporary directories for the durable-storage tests,
//! benches and examples (the offline registry has no `tempfile` crate).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static SEQ: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp dir, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `<tmp>/falkirk-<label>-<pid>-<nanos>-<seq>`.
    pub fn new(label: &str) -> TempDir {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "falkirk-{label}-{}-{nanos}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        std::fs::create_dir_all(&path).expect("creating temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept: PathBuf;
        {
            let t = TempDir::new("unit");
            kept = t.path().to_path_buf();
            assert!(kept.is_dir());
            std::fs::write(kept.join("x"), b"hi").unwrap();
        }
        assert!(!kept.exists(), "dropped TempDir removes its tree");
    }

    #[test]
    fn distinct_paths() {
        let a = TempDir::new("unit");
        let b = TempDir::new("unit");
        assert_ne!(a.path(), b.path());
    }
}
