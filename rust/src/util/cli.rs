//! Minimal command-line argument parser (the vendored registry has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed arguments: options plus positionals, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse a raw argument list. An option consumes the following token as
    /// its value unless it is of the form `--key=value` or is followed by
    /// another `--option` (in which case it is a boolean flag).
    pub fn parse(raw: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.opts.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.pos.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::parse(&strs(&["run", "--steps", "100", "--fast", "--seed=7"]));
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.get_u64("steps", 0), 100);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&strs(&[]));
        assert_eq!(a.get_u64("missing", 42), 42);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
        assert_eq!(a.get_str("missing", "x"), "x");
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::parse(&strs(&["--verbose", "--n", "3"]));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("n", 0), 3);
    }
}
