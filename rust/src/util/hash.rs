//! FNV-1a: the one non-cryptographic byte hash the crate uses (shard
//! routing of text keys, output checksums). Kept in one place so the
//! magic constants cannot drift between call sites.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_and_input_sensitive() {
        assert_eq!(fnv1a(b"falkirk"), fnv1a(b"falkirk"));
        assert_ne!(fnv1a(b"falkirk"), fnv1a(b"falkirK"));
        // The canonical FNV-1a offset basis is the hash of the empty
        // string.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
    }
}
