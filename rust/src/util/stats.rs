//! Summary statistics for benches and the metrics layer.

/// Online summary of a sequence of f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.samples.len() as f64;
        var.sqrt()
    }

    /// Percentile in `[0, 100]` by nearest-rank on a sorted copy.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket log2 histogram of `u64` samples (typically latencies
/// in nanoseconds): 64 buckets, sample `v` lands in bucket
/// `floor(log2(max(v,1)))`, so recording is branch-free O(1) with no
/// allocation — safe to keep on hot paths, unlike [`Summary`], which
/// retains every sample. Percentiles come back as the upper bound of
/// the bucket the nearest rank falls in (clamped to the observed
/// maximum): exact to within a factor of 2, which is what p50/p99
/// latency reporting needs.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: [u64; 64],
    count: u64,
    sum: u128,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: [0; 64], count: 0, sum: 0, max: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_of(v: u64) -> usize {
        63 - (v | 1).leading_zeros() as usize
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Percentile in `[0, 100]` by nearest rank over the buckets.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank.min(self.count) {
                let upper = if i >= 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
                return upper.min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }
}

/// Format a nanosecond quantity human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a rate (per second) human-readably.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut s = Summary::new();
        for _ in 0..10 {
            s.add(7.0);
        }
        assert!(s.stddev().abs() < 1e-12);
    }

    #[test]
    fn log_histogram_buckets_and_percentiles() {
        let mut h = LogHistogram::new();
        assert_eq!(h.p50(), 0);
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 100);
        // Rank 50 falls in bucket [32,64): upper bound 63.
        assert_eq!(h.p50(), 63);
        // Rank 99 falls in bucket [64,128): clamped to the observed max.
        assert_eq!(h.p99(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn log_histogram_handles_extremes() {
        let mut h = LogHistogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.0), 1); // bucket 0 upper bound
        assert_eq!(h.percentile(100.0), u64::MAX);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_rate(2.5e6), "2.50 M/s");
    }
}
