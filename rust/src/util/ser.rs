//! Compact binary serialization for checkpoints and logged messages.
//!
//! `serde` is not available in the offline registry, so this module
//! provides a small hand-rolled encoder/decoder: LEB128 varints, length-
//! prefixed byte strings, and an [`Encode`]/[`Decode`] trait pair that the
//! checkpoint layer (`ft::checkpoint`) and the message log implement.
//! The format is deliberately simple and versioned with a leading tag so
//! that decode failures are detected rather than mis-read.

use std::collections::BTreeMap;

/// Serialization error.
#[derive(Debug)]
pub enum SerError {
    /// Unexpected end of input at the given byte offset.
    Eof(usize),
    /// Varint exceeded 64 bits at the given byte offset.
    VarintOverflow(usize),
    /// A format tag did not match what the decoder expected.
    BadTag { expected: u8, found: u8, at: usize },
    /// A byte string was not valid UTF-8.
    Utf8,
}

impl std::fmt::Display for SerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SerError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            SerError::VarintOverflow(at) => write!(f, "varint too long at byte {at}"),
            SerError::BadTag { expected, found, at } => {
                write!(f, "bad tag {found} (expected {expected}) at byte {at}")
            }
            SerError::Utf8 => write!(f, "invalid utf-8 string"),
        }
    }
}

impl std::error::Error for SerError {}

/// Byte-buffer writer.
#[derive(Default, Debug, Clone)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// LEB128 unsigned varint.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                return;
            }
            self.buf.push(b | 0x80);
        }
    }

    /// Zig-zag signed varint.
    pub fn varint_i(&mut self, v: i64) {
        self.varint(((v << 1) ^ (v >> 63)) as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.varint(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }
}

/// Byte-buffer reader with position tracking.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn u8(&mut self) -> Result<u8, SerError> {
        let b = *self.buf.get(self.pos).ok_or(SerError::Eof(self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    pub fn expect_tag(&mut self, expected: u8) -> Result<(), SerError> {
        let at = self.pos;
        let found = self.u8()?;
        if found != expected {
            return Err(SerError::BadTag { expected, found, at });
        }
        Ok(())
    }

    pub fn varint(&mut self) -> Result<u64, SerError> {
        let start = self.pos;
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 64 {
                return Err(SerError::VarintOverflow(start));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn varint_i(&mut self) -> Result<i64, SerError> {
        let v = self.varint()?;
        Ok(((v >> 1) as i64) ^ -((v & 1) as i64))
    }

    pub fn f64(&mut self) -> Result<f64, SerError> {
        if self.remaining() < 8 {
            return Err(SerError::Eof(self.pos));
        }
        let mut a = [0u8; 8];
        a.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(f64::from_le_bytes(a))
    }

    pub fn f32(&mut self) -> Result<f32, SerError> {
        if self.remaining() < 4 {
            return Err(SerError::Eof(self.pos));
        }
        let mut a = [0u8; 4];
        a.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        self.pos += 4;
        Ok(f32::from_le_bytes(a))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], SerError> {
        let n = self.varint()? as usize;
        if self.remaining() < n {
            return Err(SerError::Eof(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn str(&mut self) -> Result<&'a str, SerError> {
        std::str::from_utf8(self.bytes()?).map_err(|_| SerError::Utf8)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, SerError> {
        let n = self.varint()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
}

/// Types that can write themselves into a [`Writer`].
pub trait Encode {
    fn encode(&self, w: &mut Writer);

    fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.into_bytes()
    }
}

/// Types that can read themselves from a [`Reader`].
pub trait Decode: Sized {
    fn decode(r: &mut Reader) -> Result<Self, SerError>;

    fn from_bytes(buf: &[u8]) -> Result<Self, SerError> {
        let mut r = Reader::new(buf);
        let v = Self::decode(&mut r)?;
        Ok(v)
    }
}

impl Encode for u64 {
    fn encode(&self, w: &mut Writer) {
        w.varint(*self);
    }
}

impl Decode for u64 {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        r.varint()
    }
}

impl Encode for i64 {
    fn encode(&self, w: &mut Writer) {
        w.varint_i(*self);
    }
}

impl Decode for i64 {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        r.varint_i()
    }
}

impl Encode for f64 {
    fn encode(&self, w: &mut Writer) {
        w.f64(*self);
    }
}

impl Decode for f64 {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        r.f64()
    }
}

impl Encode for String {
    fn encode(&self, w: &mut Writer) {
        w.str(self);
    }
}

impl Decode for String {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        Ok(r.str()?.to_owned())
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, w: &mut Writer) {
        w.varint(self.len() as u64);
        for x in self {
            x.encode(w);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let n = r.varint()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<A: Encode, B: Encode> Encode for (A, B) {
    fn encode(&self, w: &mut Writer) {
        self.0.encode(w);
        self.1.encode(w);
    }
}

impl<A: Decode, B: Decode> Decode for (A, B) {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl<K: Encode + Ord, V: Encode> Encode for BTreeMap<K, V> {
    fn encode(&self, w: &mut Writer) {
        w.varint(self.len() as u64);
        for (k, v) in self {
            k.encode(w);
            v.encode(w);
        }
    }
}

impl<K: Decode + Ord, V: Decode> Decode for BTreeMap<K, V> {
    fn decode(r: &mut Reader) -> Result<Self, SerError> {
        let n = r.varint()? as usize;
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        let mut w = Writer::new();
        for &v in &vals {
            w.varint(v);
        }
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.varint().unwrap(), v);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn signed_varint_roundtrip() {
        let vals = [0i64, -1, 1, -64, 64, i64::MIN, i64::MAX];
        let mut w = Writer::new();
        for &v in &vals {
            w.varint_i(v);
        }
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.varint_i().unwrap(), v);
        }
    }

    #[test]
    fn string_and_bytes_roundtrip() {
        let mut w = Writer::new();
        w.str("falkirk wheel");
        w.bytes(&[1, 2, 3]);
        w.f64(3.5);
        w.f32s(&[1.0, -2.0]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.str().unwrap(), "falkirk wheel");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.f64().unwrap(), 3.5);
        assert_eq!(r.f32s().unwrap(), vec![1.0, -2.0]);
    }

    #[test]
    fn truncated_input_is_eof() {
        let mut w = Writer::new();
        w.str("hello");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..3]);
        assert!(r.str().is_err());
    }

    #[test]
    fn container_roundtrip() {
        let v: Vec<(u64, String)> = vec![(1, "a".into()), (2, "b".into())];
        let bytes = v.to_bytes();
        let back: Vec<(u64, String)> = Vec::from_bytes(&bytes).unwrap();
        assert_eq!(v, back);

        let mut m = BTreeMap::new();
        m.insert(9u64, 4.25f64);
        let bytes = m.to_bytes();
        let back: BTreeMap<u64, f64> = BTreeMap::from_bytes(&bytes).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn bad_tag_detected() {
        let mut w = Writer::new();
        w.u8(7);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        match r.expect_tag(8) {
            Err(SerError::BadTag { expected: 8, found: 7, at: 0 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }
}
