//! Deterministic xorshift128+ RNG.
//!
//! Used everywhere randomness is needed (workload generation, failure
//! injection, property tests) so that every run is reproducible from a
//! seed — determinism is what lets the integration tests assert that a
//! failed-and-recovered execution equals a failure-free one bit-for-bit.

/// A small, fast, seedable PRNG (xorshift128+).
#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    /// Create an RNG from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over both words.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Rng { s0, s1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, n)`; `below(0)` is 0 (the only value the
    /// multiply-shift can produce for an empty range). Callers that mean
    /// "pick one of n things" with a possibly-empty n should use
    /// [`Rng::try_choose`] instead — indexing with the 0 would read out
    /// of bounds.
    ///
    /// Degenerate inputs still consume one RNG step, so a schedule that
    /// happens to request an empty range stays stream-compatible with
    /// one that does not.
    pub fn below(&mut self, n: u64) -> u64 {
        // Rejection-free multiply-shift; bias is negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)` (0 when `n == 0`; see [`Rng::below`]).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (u64). `lo == hi` yields `lo` (empty range
    /// collapses to its bound, identically in debug and release); `lo`
    /// must not exceed `hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range({lo}, {hi}) is inverted");
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a uniformly random element. Panics on an
    /// empty slice (with a message, not a release-mode out-of-bounds
    /// read via `below(0) → 0`); use [`Rng::try_choose`] when the slice
    /// may be empty.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.try_choose(xs).expect("Rng::choose on an empty slice")
    }

    /// Pick a reference to a uniformly random element, or `None` if the
    /// slice is empty. Consumes one RNG step either way, so generators
    /// stay stream-compatible across empty and non-empty inputs.
    pub fn try_choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        let i = self.index(xs.len());
        xs.get(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} not ~10k");
        }
    }

    /// `below(0)` must be a total function: the fuzzer's schedule
    /// generator asks for "uniformly below the remaining horizon" where
    /// the horizon can legitimately be zero. The old `debug_assert`
    /// made debug and release disagree (panic vs 0).
    #[test]
    fn below_zero_is_zero() {
        let mut r = Rng::new(5);
        for _ in 0..10 {
            assert_eq!(r.below(0), 0);
        }
    }

    /// An empty `range` collapses to its bound instead of diverging
    /// between debug (underflow panic was never possible — `hi - lo`
    /// is 0 — but `below` asserted) and release builds.
    #[test]
    fn range_empty_and_singleton() {
        let mut r = Rng::new(6);
        assert_eq!(r.range(9, 9), 9);
        for _ in 0..100 {
            assert_eq!(r.range(4, 5), 4);
        }
    }

    #[test]
    #[should_panic(expected = "inverted")]
    fn range_inverted_panics() {
        Rng::new(1).range(3, 2);
    }

    /// `choose` on an empty slice used to index out of bounds in
    /// release builds (`below(0)` → 0 → `xs[0]`); it must be a clear
    /// panic, and `try_choose` the non-panicking alternative.
    #[test]
    #[should_panic(expected = "empty slice")]
    fn choose_empty_panics_with_message() {
        let xs: [u32; 0] = [];
        Rng::new(2).choose(&xs);
    }

    #[test]
    fn try_choose_empty_is_none() {
        let xs: [u32; 0] = [];
        assert_eq!(Rng::new(2).try_choose(&xs), None);
        let ys = [7u32];
        assert_eq!(Rng::new(2).try_choose(&ys), Some(&7));
    }

    /// Degenerate draws still advance the stream — a generator that
    /// consumed an empty-range draw stays aligned with one that did not
    /// skip it.
    #[test]
    fn degenerate_draws_advance_stream() {
        let mut a = Rng::new(13);
        let mut b = Rng::new(13);
        let _ = a.below(0);
        let _ = b.below(10);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
