//! Deterministic xorshift128+ RNG.
//!
//! Used everywhere randomness is needed (workload generation, failure
//! injection, property tests) so that every run is reproducible from a
//! seed — determinism is what lets the integration tests assert that a
//! failed-and-recovered execution equals a failure-free one bit-for-bit.

/// A small, fast, seedable PRNG (xorshift128+).
#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    /// Create an RNG from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over both words.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Rng { s0, s1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (u64).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} not ~10k");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
