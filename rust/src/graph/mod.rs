//! Dataflow topology: processors, edges, and the per-edge *projection
//! functions* φ(e) that bridge time domains (§3.2).
//!
//! A processing node in the dataflow graph is a *processor* (the paper's
//! terminology); each processor lives in a [`TimeDomain`]. Every directed
//! edge `e: p → q` carries a projection φ(e) mapping frontiers at `p` into
//! the time domain of `q`, conservatively under-approximating the times
//! that are "fixed" on `e` by `p`'s rollback: `p` is guaranteed not to
//! have produced any message with time in φ(e)(f) from an event outside f.
//!
//! Static projections (identity, loop enter/exit/feedback) are pure
//! functions of the frontier and are evaluated by [`Projection::apply`].
//! History-dependent projections (sequence-number counts, the §3.2
//! epoch→seq buffering transformer) are declared [`Projection::PerCheckpoint`]
//! and their values are captured in the Table-1 checkpoint metadata
//! ([`crate::ft::meta`]) — the paper notes φ(e)(f) need only be defined
//! for frontiers in the history of `p`, which is exactly what storing it
//! per checkpoint provides.

pub mod sharding;

use crate::frontier::Frontier;
use crate::time::{Time, TimeDomain, CTR_INF};

/// Epoch value standing for "every epoch" in frontier *preimages* (never
/// appears in message times). `(EPOCH_ANY, …, ∞-1)` is the largest
/// structured time with a finite innermost counter.
pub const EPOCH_ANY: u64 = u64::MAX;

/// The maximal structured time at `depth` whose innermost counter is
/// finite: `(EPOCH_ANY, ∞, …, ∞, ∞-1)`.
fn all_finite_iterations(depth: u8) -> Time {
    assert!(depth >= 1);
    let mut cs = vec![CTR_INF; depth as usize];
    *cs.last_mut().unwrap() = CTR_INF - 1;
    Time::structured(EPOCH_ANY, &cs)
}

/// Identifier of a processor in a [`Topology`].
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ProcId(pub u32);

/// Identifier of an edge in a [`Topology`].
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct EdgeId(pub u32);

impl std::fmt::Display for ProcId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}

impl std::fmt::Display for EdgeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}", self.0)
    }
}

/// The projection function φ(e) attached to an edge (§3.2).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Projection {
    /// φ(f) = f. Requires src and dst domains equal. The common case for
    /// epoch systems ("messages cannot be sent backwards in time").
    Identity,
    /// Loop ingress `r` in Fig. 2(c): dst domain is one loop deeper;
    /// φ(f) = ↓{(t, c) : t ∈ f, all c} — maximal elements get counter ∞.
    LoopEnter,
    /// Loop egress: dst domain one loop shallower; φ(f) = {t : (t, ∞) ∈ f}
    /// — an epoch leaves the loop only once every iteration is fixed.
    LoopExit,
    /// Feedback edge (Fig. 7c's `w`): same domain, increments the
    /// innermost counter; φ(f) = ↓{(t, c+1) : (t, c) maximal in f}.
    LoopFeedback,
    /// History-dependent projection whose value is recorded per checkpoint
    /// in the Table-1 metadata: seq-number output counts (Fig. 2a), the
    /// epoch→seq buffering transformer, or seq→epoch windowing (§3.2).
    PerCheckpoint,
    /// φ(f) = ∅ — always safe, maximally conservative (§3.2 notes this is
    /// always a legal choice; it just preserves no downstream work).
    Empty,
}

impl Projection {
    /// Evaluate a *static* projection on a frontier. Returns `None` for
    /// [`Projection::PerCheckpoint`], whose value must be looked up in the
    /// checkpoint metadata instead.
    pub fn apply(&self, f: &Frontier) -> Option<Frontier> {
        match self {
            Projection::Identity => Some(f.clone()),
            Projection::Empty => Some(Frontier::Bottom),
            Projection::PerCheckpoint => None,
            Projection::LoopEnter => Some(match f {
                Frontier::Bottom => Frontier::Bottom,
                Frontier::Top => Frontier::Top,
                _ => Frontier::down_close(f.maximal_elements().into_iter().map(|t| {
                    Time::Structured { epoch: t.epoch_of(), loops: t.loops_of().enter(CTR_INF) }
                })),
            }),
            Projection::LoopExit => Some(match f {
                Frontier::Bottom => Frontier::Bottom,
                Frontier::Top => Frontier::Top,
                _ => Frontier::down_close(f.maximal_elements().into_iter().filter_map(|t| {
                    let loops = t.loops_of();
                    // Only epochs whose *every* iteration is inside f are
                    // fixed outside the loop.
                    if loops.innermost() == CTR_INF {
                        Some(Time::Structured { epoch: t.epoch_of(), loops: loops.exit() })
                    } else {
                        None
                    }
                })),
            }),
            Projection::LoopFeedback => Some(match f {
                Frontier::Bottom => Frontier::Bottom,
                Frontier::Top => Frontier::Top,
                _ => Frontier::down_close(f.maximal_elements().into_iter().map(|t| {
                    Time::Structured { epoch: t.epoch_of(), loops: t.loops_of().increment() }
                })),
            }),
        }
    }

    /// Whether φ must be captured per checkpoint rather than computed.
    pub fn is_per_checkpoint(&self) -> bool {
        matches!(self, Projection::PerCheckpoint)
    }

    /// Preimage: the **largest** frontier `g` (in the source domain at
    /// depth `src_depth`) such that `φ(g) ⊆ limit`. Used by the Fig. 6
    /// solver for processors that can restore to *any* frontier (§3.4's
    /// "can restore to any requested frontier" class): their D̄(e,g) =
    /// φ(e)(g) constraint `φ(e)(g) ⊆ f(dst)` becomes the upper bound
    /// `g ⊆ preimage(f(dst))`.
    ///
    /// Only defined for static projections (`None` for
    /// [`Projection::PerCheckpoint`]).
    pub fn preimage(&self, limit: &Frontier, src_depth: u8) -> Option<Frontier> {
        match self {
            Projection::Identity => Some(limit.clone()),
            Projection::Empty => Some(Frontier::Top),
            Projection::PerCheckpoint => None,
            _ if limit.is_top() => Some(Frontier::Top),
            _ if limit.is_bottom() => Some(match self {
                // φ(g) = ∅ requires: Enter — g = ∅ (every t maps in);
                // Exit — g may contain any (t, c) with c finite;
                // Feedback — g may contain only counter-0 times... which
                // still project to (t, 1) ⊉ ∅; so g = ∅.
                Projection::LoopEnter | Projection::LoopFeedback => Frontier::Bottom,
                Projection::LoopExit => {
                    Frontier::below(all_finite_iterations(src_depth))
                }
                _ => unreachable!(),
            }),
            Projection::LoopEnter => {
                // φ(g) = ↓{(t,∞) : t ∈ g} ⊆ limit ⟺ g ⊆ {t : (t,∞) ∈ limit},
                // which is exactly the LoopExit image of `limit`.
                Projection::LoopExit.apply(limit)
            }
            Projection::LoopExit => {
                // φ(g) = {t : (t,∞) ∈ g} ⊆ limit: g may contain any time
                // with a finite innermost counter, plus (t,∞) for t ∈ limit.
                let mut f = Frontier::below(all_finite_iterations(src_depth));
                for t in limit.maximal_elements() {
                    f.insert(Time::Structured { epoch: t.epoch_of(), loops: t.loops_of().enter(CTR_INF) });
                }
                Some(f)
            }
            Projection::LoopFeedback => {
                // φ(g) = ↓{(t,c+1)} ⊆ limit ⟺ (t,c) ∈ g ⇒ (t,c+1) ∈ limit:
                // decrement the innermost counter of limit's maxima;
                // counter-0 maxima contribute nothing.
                let mut f = Frontier::Bottom;
                for t in limit.maximal_elements() {
                    let loops = t.loops_of();
                    let c = loops.innermost();
                    if c == 0 {
                        continue;
                    }
                    // `∞-1` is the reserved "all finite iterations"
                    // marker (it only arises from LoopExit preimages);
                    // decrementing it stepwise would descend for 2⁶⁴
                    // fixed-point rounds, so we conservatively drop it —
                    // a cycle whose only bound is "any finite iteration"
                    // admits no nonempty fixed point anyway.
                    if c == CTR_INF - 1 {
                        continue;
                    }
                    let dec = if c == CTR_INF { CTR_INF } else { c - 1 };
                    let mut cs: Vec<u64> = loops.as_slice().to_vec();
                    *cs.last_mut().unwrap() = dec;
                    f.insert(Time::structured(t.epoch_of(), &cs));
                }
                Some(f)
            }
        }
    }

    /// Validate that this projection is compatible with the given endpoint
    /// domains; returns a human-readable error otherwise.
    pub fn check_domains(&self, src: TimeDomain, dst: TimeDomain) -> Result<(), String> {
        let ok = match self {
            Projection::Identity => src == dst,
            Projection::LoopEnter => {
                matches!(src, TimeDomain::Structured { .. }) && dst == src.deeper()
            }
            Projection::LoopExit => {
                matches!(src, TimeDomain::Structured { depth } if depth > 0)
                    && dst == src.shallower()
            }
            Projection::LoopFeedback => {
                matches!(src, TimeDomain::Structured { depth } if depth > 0) && src == dst
            }
            Projection::PerCheckpoint | Projection::Empty => true,
        };
        if ok {
            Ok(())
        } else {
            Err(format!("projection {self:?} incompatible with domains {src:?} → {dst:?}"))
        }
    }
}

/// Per-processor static information.
#[derive(Clone, Debug)]
pub struct ProcInfo {
    pub name: String,
    pub domain: TimeDomain,
}

/// Per-edge static information.
#[derive(Clone, Debug)]
pub struct EdgeInfo {
    pub src: ProcId,
    pub dst: ProcId,
    pub projection: Projection,
}

/// An immutable dataflow topology. Build with [`GraphBuilder`].
#[derive(Clone, Debug)]
pub struct Topology {
    procs: Vec<ProcInfo>,
    edges: Vec<EdgeInfo>,
    in_edges: Vec<Vec<EdgeId>>,
    out_edges: Vec<Vec<EdgeId>>,
}

impl Topology {
    pub fn num_procs(&self) -> usize {
        self.procs.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn proc_ids(&self) -> impl Iterator<Item = ProcId> {
        (0..self.procs.len() as u32).map(ProcId)
    }

    pub fn edge_ids(&self) -> impl Iterator<Item = EdgeId> {
        (0..self.edges.len() as u32).map(EdgeId)
    }

    pub fn name(&self, p: ProcId) -> &str {
        &self.procs[p.0 as usize].name
    }

    pub fn domain(&self, p: ProcId) -> TimeDomain {
        self.procs[p.0 as usize].domain
    }

    pub fn src(&self, e: EdgeId) -> ProcId {
        self.edges[e.0 as usize].src
    }

    pub fn dst(&self, e: EdgeId) -> ProcId {
        self.edges[e.0 as usize].dst
    }

    pub fn projection(&self, e: EdgeId) -> Projection {
        self.edges[e.0 as usize].projection
    }

    /// Input edges of `p`, in connection order (= local input port order).
    pub fn in_edges(&self, p: ProcId) -> &[EdgeId] {
        &self.in_edges[p.0 as usize]
    }

    /// Output edges of `p`, in connection order (= local output port order).
    pub fn out_edges(&self, p: ProcId) -> &[EdgeId] {
        &self.out_edges[p.0 as usize]
    }

    /// The local input-port index of edge `e` at its destination.
    pub fn input_port(&self, e: EdgeId) -> usize {
        let dst = self.dst(e);
        self.in_edges(dst).iter().position(|x| *x == e).unwrap()
    }

    /// Find a processor by name (for tests / examples).
    pub fn find(&self, name: &str) -> Option<ProcId> {
        self.procs.iter().position(|p| p.name == name).map(|i| ProcId(i as u32))
    }
}

/// Builder for [`Topology`]. Validates projection/domain compatibility at
/// [`GraphBuilder::build`].
#[derive(Default, Debug)]
pub struct GraphBuilder {
    procs: Vec<ProcInfo>,
    edges: Vec<EdgeInfo>,
}

impl GraphBuilder {
    pub fn new() -> GraphBuilder {
        GraphBuilder::default()
    }

    /// Add a processor in the given time domain.
    pub fn add_proc(&mut self, name: &str, domain: TimeDomain) -> ProcId {
        self.procs.push(ProcInfo { name: name.to_string(), domain });
        ProcId(self.procs.len() as u32 - 1)
    }

    /// Connect `src → dst` with projection φ.
    pub fn connect(&mut self, src: ProcId, dst: ProcId, projection: Projection) -> EdgeId {
        self.edges.push(EdgeInfo { src, dst, projection });
        EdgeId(self.edges.len() as u32 - 1)
    }

    /// Validate and freeze the topology.
    pub fn build(self) -> Result<Topology, String> {
        let mut in_edges = vec![Vec::new(); self.procs.len()];
        let mut out_edges = vec![Vec::new(); self.procs.len()];
        for (i, e) in self.edges.iter().enumerate() {
            let id = EdgeId(i as u32);
            let sdom = self.procs[e.src.0 as usize].domain;
            let ddom = self.procs[e.dst.0 as usize].domain;
            e.projection.check_domains(sdom, ddom).map_err(|err| {
                format!(
                    "edge {id} ({} → {}): {err}",
                    self.procs[e.src.0 as usize].name, self.procs[e.dst.0 as usize].name
                )
            })?;
            out_edges[e.src.0 as usize].push(id);
            in_edges[e.dst.0 as usize].push(id);
        }
        Ok(Topology { procs: self.procs, edges: self.edges, in_edges, out_edges })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_simple_pipeline() {
        let mut g = GraphBuilder::new();
        let a = g.add_proc("a", TimeDomain::EPOCH);
        let b = g.add_proc("b", TimeDomain::EPOCH);
        let e = g.connect(a, b, Projection::Identity);
        let t = g.build().unwrap();
        assert_eq!(t.num_procs(), 2);
        assert_eq!(t.src(e), a);
        assert_eq!(t.dst(e), b);
        assert_eq!(t.in_edges(b), &[e]);
        assert_eq!(t.out_edges(a), &[e]);
        assert_eq!(t.input_port(e), 0);
        assert_eq!(t.find("b"), Some(b));
    }

    #[test]
    fn identity_requires_same_domain() {
        let mut g = GraphBuilder::new();
        let a = g.add_proc("a", TimeDomain::EPOCH);
        let b = g.add_proc("b", TimeDomain::Structured { depth: 1 });
        g.connect(a, b, Projection::Identity);
        assert!(g.build().is_err());
    }

    #[test]
    fn loop_projection_domains() {
        let mut g = GraphBuilder::new();
        let outer = g.add_proc("outer", TimeDomain::EPOCH);
        let body = g.add_proc("body", TimeDomain::Structured { depth: 1 });
        let out = g.add_proc("out", TimeDomain::EPOCH);
        g.connect(outer, body, Projection::LoopEnter);
        g.connect(body, body, Projection::LoopFeedback);
        g.connect(body, out, Projection::LoopExit);
        assert!(g.build().is_ok());
    }

    #[test]
    fn loop_enter_projection_covers_all_iterations() {
        // Fig 2(c): φ(e)(f) = {(t, c) : t ∈ f} for ingress.
        let f = Frontier::upto_epoch(1);
        let proj = Projection::LoopEnter.apply(&f).unwrap();
        assert!(proj.contains(&Time::structured(1, &[0])));
        assert!(proj.contains(&Time::structured(0, &[712])));
        assert!(!proj.contains(&Time::structured(2, &[0])));
    }

    #[test]
    fn loop_exit_projection_requires_all_iterations_fixed() {
        // Epoch 0 is fixed for all iterations; epoch 1 only up to c=3.
        let f = Frontier::down_close([
            Time::structured(0, &[CTR_INF]),
            Time::structured(1, &[3]),
        ]);
        let proj = Projection::LoopExit.apply(&f).unwrap();
        assert!(proj.contains(&Time::epoch(0)));
        assert!(!proj.contains(&Time::epoch(1)));
    }

    #[test]
    fn loop_feedback_increments() {
        let f = Frontier::down_close([Time::structured(1, &[2])]);
        let proj = Projection::LoopFeedback.apply(&f).unwrap();
        assert!(proj.contains(&Time::structured(1, &[3])));
        assert!(!proj.contains(&Time::structured(1, &[4])));
        // ∞ stays ∞ under increment.
        let f = Frontier::down_close([Time::structured(0, &[CTR_INF])]);
        let proj = Projection::LoopFeedback.apply(&f).unwrap();
        assert!(proj.contains(&Time::structured(0, &[CTR_INF])));
    }

    #[test]
    fn static_projections_on_bottom_top() {
        for p in [
            Projection::Identity,
            Projection::LoopEnter,
            Projection::LoopExit,
            Projection::LoopFeedback,
        ] {
            assert_eq!(p.apply(&Frontier::Bottom).unwrap(), Frontier::Bottom);
            assert_eq!(p.apply(&Frontier::Top).unwrap(), Frontier::Top);
        }
        assert_eq!(Projection::Empty.apply(&Frontier::Top).unwrap(), Frontier::Bottom);
        assert!(Projection::PerCheckpoint.apply(&Frontier::Top).is_none());
    }

    #[test]
    fn preimage_identity_and_empty() {
        let f = Frontier::upto_epoch(3);
        assert_eq!(Projection::Identity.preimage(&f, 0).unwrap(), f);
        assert_eq!(Projection::Empty.preimage(&f, 0).unwrap(), Frontier::Top);
        assert!(Projection::PerCheckpoint.preimage(&f, 0).is_none());
    }

    /// Check the Galois property φ(preimage(F)) ⊆ F and that preimage is
    /// the largest such frontier for a few probe points.
    fn check_preimage(proj: Projection, limit: &Frontier, src_depth: u8, probes: &[Time]) {
        let pre = proj.preimage(limit, src_depth).unwrap();
        let img = proj.apply(&pre).unwrap();
        assert!(img.is_subset(limit), "{proj:?}: φ(pre)={img} ⊄ {limit}");
        for t in probes {
            // t ∈ pre ⟺ φ(↓t) ⊆ limit (maximality pointwise).
            let img_t = proj.apply(&Frontier::below(*t)).unwrap();
            assert_eq!(
                pre.contains(t),
                img_t.is_subset(limit),
                "{proj:?}: probe {t} membership mismatch (φ(↓t)={img_t}, limit={limit})"
            );
        }
    }

    #[test]
    fn preimage_loop_enter() {
        // limit covers (0,∞) and (1,3): only epoch 0 fully fixed inside.
        let limit =
            Frontier::down_close([Time::structured(0, &[CTR_INF]), Time::structured(1, &[3])]);
        check_preimage(
            Projection::LoopEnter,
            &limit,
            0,
            &[Time::epoch(0), Time::epoch(1), Time::epoch(2)],
        );
    }

    #[test]
    fn preimage_loop_exit() {
        let limit = Frontier::upto_epoch(1);
        check_preimage(
            Projection::LoopExit,
            &limit,
            1,
            &[
                Time::structured(0, &[CTR_INF]),
                Time::structured(1, &[CTR_INF]),
                Time::structured(2, &[CTR_INF]),
                Time::structured(2, &[7]),
                Time::structured(99, &[0]),
            ],
        );
    }

    #[test]
    fn preimage_loop_feedback() {
        let limit =
            Frontier::down_close([Time::structured(5, &[3]), Time::structured(7, &[0])]);
        check_preimage(
            Projection::LoopFeedback,
            &limit,
            1,
            &[
                Time::structured(5, &[2]),
                Time::structured(5, &[3]),
                Time::structured(7, &[0]),
                Time::structured(4, &[2]),
            ],
        );
        // All-zero-counter limit has empty feedback preimage.
        let limit = Frontier::down_close([Time::structured(5, &[0])]);
        assert_eq!(
            Projection::LoopFeedback.preimage(&limit, 1).unwrap(),
            Frontier::Bottom
        );
    }

    #[test]
    fn preimage_of_bottom() {
        assert_eq!(Projection::LoopEnter.preimage(&Frontier::Bottom, 0).unwrap(), Frontier::Bottom);
        assert_eq!(
            Projection::LoopFeedback.preimage(&Frontier::Bottom, 1).unwrap(),
            Frontier::Bottom
        );
        // Exit: any finite iteration count is allowed.
        let pre = Projection::LoopExit.preimage(&Frontier::Bottom, 1).unwrap();
        assert!(pre.contains(&Time::structured(42, &[1000])));
        assert!(!pre.contains(&Time::structured(42, &[CTR_INF])));
    }

    #[test]
    fn feedback_requires_loop_domain() {
        let mut g = GraphBuilder::new();
        let a = g.add_proc("a", TimeDomain::EPOCH);
        g.connect(a, a, Projection::LoopFeedback);
        assert!(g.build().is_err());
    }
}
