//! Shard-aware topology expansion: the *logical* dataflow an application
//! declares, and its expansion into the *physical* processor graph the
//! engine executes.
//!
//! An application describes logical vertices, each with a worker-shard
//! count W, and logical edges between them. [`ShardedBuilder::build`]
//! expands every logical vertex into W physical processors (the paper's
//! "processors" stay the unit of failure, checkpointing and rollback —
//! per-shard logical-time domains are exactly the §3.2 mechanism that
//! lets each shard checkpoint and roll back independently) and every
//! logical edge into a bundle of *exchange edges*:
//!
//! ```text
//!   src W=1 → dst W=3 :  1×3 edges (hash-partition the stream)
//!   src W=2 → dst W=3 :  2×3 edges (full hash exchange)
//!   src W=2 → dst W=1 :  2×1 edges (fan-in)
//! ```
//!
//! Records are routed to destination shards by [`Partition`]: keyed
//! partitioning (`key mod W`, the default) or broadcast. Routing is
//! performed by the [`crate::engine::sharded::ShardRouter`] wrapper that
//! [`ShardPlan`] parameterizes; this module is purely the static
//! expansion plus the lookup tables the router needs.
//!
//! Because every physical edge carries the logical edge's projection
//! φ(e), the Fig. 6 consistent-frontier machinery applies unchanged: a
//! shard is a processor, so it has its own frontier, checkpoint chain and
//! Table-1 metadata, and the solver computes a per-shard rollback plan —
//! recovering a single failed shard's key range instead of the whole
//! logical vertex (see `ft/README.md`).

use crate::graph::{EdgeId, GraphBuilder, ProcId, Projection, Topology};
use crate::time::TimeDomain;
use std::sync::Arc;

/// Identifier of a logical (pre-expansion) vertex.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LogicalId(pub u32);

impl std::fmt::Display for LogicalId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "v{}", self.0)
    }
}

/// How records on a logical edge are distributed over destination shards.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Partition {
    /// Route by the record's key (`key mod W`; integer records route by
    /// value, text by a stable FNV hash, everything else to shard 0).
    /// The default: each key's state lives on exactly one shard.
    ByKey,
    /// Deliver a copy to every destination shard (parameter/config
    /// streams).
    Broadcast,
}

/// Routing entry for one logical output port of a physical processor:
/// the port's exchange-edge bundle occupies physical output ports
/// `base .. base + fanout`.
#[derive(Copy, Clone, Debug)]
pub struct PortRoute {
    /// First physical output-port index of the bundle.
    pub base: usize,
    /// Number of destination shards (bundle width).
    pub fanout: usize,
    /// How records pick a destination shard.
    pub partition: Partition,
}

struct LogicalVertex {
    name: String,
    domain: TimeDomain,
    shards: u32,
}

struct LogicalEdge {
    src: LogicalId,
    dst: LogicalId,
    projection: Projection,
    partition: Partition,
}

/// Builder for a sharded dataflow. Mirrors [`GraphBuilder`] at the
/// logical level; [`ShardedBuilder::build`] performs the expansion.
#[derive(Default)]
pub struct ShardedBuilder {
    verts: Vec<LogicalVertex>,
    edges: Vec<LogicalEdge>,
}

impl ShardedBuilder {
    pub fn new() -> ShardedBuilder {
        ShardedBuilder::default()
    }

    /// Add an unsharded logical vertex (W = 1).
    pub fn add_proc(&mut self, name: &str, domain: TimeDomain) -> LogicalId {
        self.add_sharded(name, domain, 1)
    }

    /// Add a logical vertex partitioned into `shards` workers. Physical
    /// processors are named `name#0 … name#{W-1}` (plain `name` for
    /// W = 1).
    pub fn add_sharded(&mut self, name: &str, domain: TimeDomain, shards: u32) -> LogicalId {
        assert!(shards >= 1, "a vertex needs at least one shard");
        self.verts.push(LogicalVertex { name: name.to_string(), domain, shards });
        LogicalId(self.verts.len() as u32 - 1)
    }

    /// Connect two logical vertices with keyed partitioning.
    pub fn connect(&mut self, src: LogicalId, dst: LogicalId, projection: Projection) -> usize {
        self.connect_with(src, dst, projection, Partition::ByKey)
    }

    /// Connect with an explicit partitioning strategy. Returns the
    /// logical edge index (the local input-port order at `dst` is the
    /// order of `connect` calls targeting it, as in [`GraphBuilder`]).
    pub fn connect_with(
        &mut self,
        src: LogicalId,
        dst: LogicalId,
        projection: Projection,
        partition: Partition,
    ) -> usize {
        self.edges.push(LogicalEdge { src, dst, projection, partition });
        self.edges.len() - 1
    }

    /// Expand to the physical topology plus the routing tables. Fails if
    /// any projection is incompatible with its endpoint domains (checked
    /// by the underlying [`GraphBuilder`]).
    pub fn build(self) -> Result<ShardPlan, String> {
        let nv = self.verts.len();
        let mut g = GraphBuilder::new();
        let mut shards: Vec<Vec<ProcId>> = Vec::with_capacity(nv);
        let mut proc_logical: Vec<(u32, u32)> = Vec::new();
        for (vi, v) in self.verts.iter().enumerate() {
            let mut ps = Vec::with_capacity(v.shards as usize);
            for s in 0..v.shards {
                let name =
                    if v.shards == 1 { v.name.clone() } else { format!("{}#{s}", v.name) };
                ps.push(g.add_proc(&name, v.domain));
                proc_logical.push((vi as u32, s));
            }
            shards.push(ps);
        }

        // Logical port orders (connect order, as in GraphBuilder).
        let mut l_out: Vec<Vec<usize>> = vec![Vec::new(); nv];
        let mut l_in: Vec<Vec<usize>> = vec![Vec::new(); nv];
        for (ei, e) in self.edges.iter().enumerate() {
            l_out[e.src.0 as usize].push(ei);
            l_in[e.dst.0 as usize].push(ei);
        }

        // Physical exchange edges, grouped per (src shard, logical port):
        // the group layout is identical for every shard of a vertex, so
        // the routing tables are recorded once per logical vertex.
        let mut edge_logical: Vec<usize> = Vec::new();
        let mut routes: Vec<Vec<PortRoute>> = vec![Vec::new(); nv];
        let mut port_edges: Vec<Vec<EdgeId>> = vec![Vec::new(); nv];
        for vi in 0..nv {
            for s in 0..self.verts[vi].shards {
                let src_p = shards[vi][s as usize];
                let mut base = 0usize;
                for &le in &l_out[vi] {
                    let e = &self.edges[le];
                    let dst_w = self.verts[e.dst.0 as usize].shards as usize;
                    for j in 0..dst_w {
                        let pe = g.connect(src_p, shards[e.dst.0 as usize][j], e.projection);
                        edge_logical.push(le);
                        if s == 0 && j == 0 {
                            port_edges[vi].push(pe);
                        }
                    }
                    if s == 0 {
                        routes[vi].push(PortRoute {
                            base,
                            fanout: dst_w,
                            partition: e.partition,
                        });
                    }
                    base += dst_w;
                }
            }
        }
        let topo = Arc::new(g.build()?);

        let out_projections: Vec<Vec<Projection>> = (0..nv)
            .map(|vi| l_out[vi].iter().map(|&le| self.edges[le].projection).collect())
            .collect();
        let out_seq_dst: Vec<Vec<bool>> = (0..nv)
            .map(|vi| {
                l_out[vi]
                    .iter()
                    .map(|&le| {
                        self.verts[self.edges[le].dst.0 as usize].domain == TimeDomain::Seq
                    })
                    .collect()
            })
            .collect();

        // Physical input port → logical input port, per physical proc.
        let mut in_maps: Vec<Vec<usize>> = Vec::with_capacity(topo.num_procs());
        for p in topo.proc_ids() {
            let (vi, _s) = proc_logical[p.0 as usize];
            let map = topo
                .in_edges(p)
                .iter()
                .map(|&pe| {
                    let le = edge_logical[pe.0 as usize];
                    l_in[vi as usize]
                        .iter()
                        .position(|&x| x == le)
                        .expect("physical in-edge must map to a logical in-port")
                })
                .collect();
            in_maps.push(map);
        }

        let names = self.verts.into_iter().map(|v| v.name).collect();
        Ok(ShardPlan {
            topo,
            names,
            shards,
            proc_logical,
            routes,
            out_projections,
            out_seq_dst,
            in_maps,
            port_edges,
        })
    }
}

/// The expanded physical topology plus everything the per-shard routers
/// and the fault-tolerance harness need to relate physical processors
/// back to logical vertices.
pub struct ShardPlan {
    /// The physical topology the engine executes.
    pub topo: Arc<Topology>,
    names: Vec<String>,
    /// Physical processors per logical vertex, shard order.
    shards: Vec<Vec<ProcId>>,
    /// Physical processor → (logical vertex, shard index).
    proc_logical: Vec<(u32, u32)>,
    /// Routing table per logical vertex, per logical output port.
    routes: Vec<Vec<PortRoute>>,
    /// Logical out-edge projections (for the router's time translation).
    out_projections: Vec<Vec<Projection>>,
    /// Whether each logical out-port feeds a seq-domain destination.
    out_seq_dst: Vec<Vec<bool>>,
    /// Physical input port → logical input port, per physical proc.
    in_maps: Vec<Vec<usize>>,
    /// One representative physical edge per logical out-port (placeholder
    /// ids for the router's staging context).
    port_edges: Vec<Vec<EdgeId>>,
}

impl ShardPlan {
    /// Number of logical vertices.
    pub fn num_logical(&self) -> usize {
        self.names.len()
    }

    /// Shard count of a logical vertex.
    pub fn shard_count(&self, v: LogicalId) -> usize {
        self.shards[v.0 as usize].len()
    }

    /// All physical processors of a logical vertex, shard order.
    pub fn shards_of(&self, v: LogicalId) -> &[ProcId] {
        &self.shards[v.0 as usize]
    }

    /// The physical processor implementing shard `s` of vertex `v`.
    pub fn proc(&self, v: LogicalId, s: usize) -> ProcId {
        self.shards[v.0 as usize][s]
    }

    /// The logical vertex and shard index of a physical processor.
    pub fn logical_of(&self, p: ProcId) -> (LogicalId, usize) {
        let (v, s) = self.proc_logical[p.0 as usize];
        (LogicalId(v), s as usize)
    }

    /// The logical vertex's name.
    pub fn name(&self, v: LogicalId) -> &str {
        &self.names[v.0 as usize]
    }

    /// Find a logical vertex by name.
    pub fn find(&self, name: &str) -> Option<LogicalId> {
        self.names.iter().position(|n| n == name).map(|i| LogicalId(i as u32))
    }

    /// Routing table of a logical vertex (one entry per logical out-port).
    pub fn routes_of(&self, v: LogicalId) -> &[PortRoute] {
        &self.routes[v.0 as usize]
    }

    /// Logical out-port projections of a vertex.
    pub fn projections_of(&self, v: LogicalId) -> &[Projection] {
        &self.out_projections[v.0 as usize]
    }

    /// Per-logical-out-port flags: destination is a seq-domain vertex.
    pub fn seq_dst_of(&self, v: LogicalId) -> &[bool] {
        &self.out_seq_dst[v.0 as usize]
    }

    /// Representative physical edge per logical out-port.
    pub fn port_edges_of(&self, v: LogicalId) -> &[EdgeId] {
        &self.port_edges[v.0 as usize]
    }

    /// Physical-to-logical input-port map of a physical processor.
    pub fn in_map_of(&self, p: ProcId) -> &[usize] {
        &self.in_maps[p.0 as usize]
    }

    /// Expand per-logical-vertex values (e.g. policies) to one value per
    /// physical processor, in [`ProcId`] order.
    pub fn expand_per_proc<T: Clone>(&self, per_logical: &[T]) -> Vec<T> {
        assert_eq!(per_logical.len(), self.num_logical());
        self.proc_logical.iter().map(|&(v, _)| per_logical[v as usize].clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_stage(w1: u32, w2: u32) -> ShardPlan {
        let mut b = ShardedBuilder::new();
        let src = b.add_proc("src", TimeDomain::EPOCH);
        let map = b.add_sharded("map", TimeDomain::EPOCH, w1);
        let count = b.add_sharded("count", TimeDomain::EPOCH, w2);
        let col = b.add_proc("collect", TimeDomain::EPOCH);
        b.connect(src, map, Projection::Identity);
        b.connect(map, count, Projection::Identity);
        b.connect(count, col, Projection::Identity);
        b.build().unwrap()
    }

    #[test]
    fn expansion_counts() {
        let plan = three_stage(2, 3);
        // 1 + 2 + 3 + 1 physical procs.
        assert_eq!(plan.topo.num_procs(), 7);
        // Edges: 1×2 + 2×3 + 3×1 = 11.
        assert_eq!(plan.topo.num_edges(), 11);
        let map = plan.find("map").unwrap();
        let count = plan.find("count").unwrap();
        assert_eq!(plan.shard_count(map), 2);
        assert_eq!(plan.shard_count(count), 3);
        assert_eq!(plan.name(count), "count");
        // Physical names carry the shard suffix.
        assert_eq!(plan.topo.find("map#1"), Some(plan.proc(map, 1)));
        assert_eq!(plan.topo.find("src"), Some(plan.proc(plan.find("src").unwrap(), 0)));
    }

    #[test]
    fn out_ports_are_grouped_per_logical_port() {
        let plan = three_stage(2, 3);
        let map = plan.find("map").unwrap();
        // map has one logical out-port fanning out to 3 count shards.
        let routes = plan.routes_of(map);
        assert_eq!(routes.len(), 1);
        assert_eq!(routes[0].base, 0);
        assert_eq!(routes[0].fanout, 3);
        for s in 0..2 {
            let p = plan.proc(map, s);
            let outs = plan.topo.out_edges(p);
            assert_eq!(outs.len(), 3);
            for (j, &e) in outs.iter().enumerate() {
                let count = plan.find("count").unwrap();
                assert_eq!(plan.topo.dst(e), plan.proc(count, j), "bundle is shard-ordered");
            }
        }
    }

    #[test]
    fn in_maps_point_at_logical_ports() {
        // Two logical inputs into a sharded join: every physical in-edge
        // must map back to the right logical port regardless of expansion
        // interleaving.
        let mut b = ShardedBuilder::new();
        let l = b.add_proc("left", TimeDomain::EPOCH);
        let r = b.add_proc("right", TimeDomain::EPOCH);
        let j = b.add_sharded("join", TimeDomain::EPOCH, 2);
        b.connect(l, j, Projection::Identity); // logical port 0
        b.connect(r, j, Projection::Identity); // logical port 1
        let plan = b.build().unwrap();
        let j = plan.find("join").unwrap();
        for s in 0..2 {
            let p = plan.proc(j, s);
            let map = plan.in_map_of(p);
            let ins = plan.topo.in_edges(p);
            assert_eq!(map.len(), 2);
            for (pi, &e) in ins.iter().enumerate() {
                let src_name = plan.topo.name(plan.topo.src(e));
                let expect = if src_name == "left" { 0 } else { 1 };
                assert_eq!(map[pi], expect, "physical port {pi} of join#{s}");
            }
        }
    }

    #[test]
    fn expand_per_proc_replicates_by_shard() {
        let plan = three_stage(2, 2);
        let vals = plan.expand_per_proc(&["a", "b", "c", "d"]);
        assert_eq!(vals, vec!["a", "b", "b", "c", "c", "d"]);
    }

    #[test]
    fn bad_projection_is_rejected() {
        let mut b = ShardedBuilder::new();
        let a = b.add_proc("a", TimeDomain::EPOCH);
        let c = b.add_sharded("c", TimeDomain::Structured { depth: 1 }, 2);
        b.connect(a, c, Projection::Identity); // domain mismatch
        assert!(b.build().is_err());
    }
}
